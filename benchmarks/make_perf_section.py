"""Render EXPERIMENTS.md §Perf from perf_iterations.json + the baseline
roofline JSON (replaces the <!-- PERF_RESULTS --> marker).

    PYTHONPATH=src python -m benchmarks.make_perf_section
"""
import json
import os

HERE = os.path.dirname(__file__)
PERF = os.path.join(HERE, "data", "perf_iterations.json")
BASE = os.path.join(HERE, "data", "roofline_single_pod.json")
EXP = os.path.join(HERE, "..", "EXPERIMENTS.md")

NARRATIVE = {
    ("whisper-base", "pad_vocab"):
        ("H1: 51865 % 16 != 0 forces GSPMD to replicate the head matmul and "
         "(B,S,V) logits per model shard (~16x waste); padding the vocab to "
         "52096 restores sharding. Predicted: HBM ~-110 GiB, compute ~-70%.",
         "CONFIRMED — the single biggest win of the whole pass."),
    ("whisper-base", "masked_nll,pad_vocab"):
        ("H2: the gold-logit gather over the (now sharded) vocab forces an "
         "all-gather of the logits; a masked sum stays shard-local.",
         "REFUTED — no change; XLA already partitioned the gather."),
    ("qwen3-32b", "masked_nll"):
        ("H2 on qwen3-32b (vocab already divisible): same gather hypothesis.",
         "REFUTED — identical terms; the gather was never the bottleneck."),
    ("qwen3-32b", "masked_nll,zero_opt"):
        ("H3: Adam's f32 m/v for 32.8B params, sharded only 16-way on the "
         "model axis, hold ~16.4 GiB/chip; ZeRO-sharding the stacked-unit "
         "axis over the data axes cuts them 16x. Predicted: ~-16 GiB, no "
         "new collectives (Adam is elementwise).",
         "CONFIRMED — HBM 64.1 -> 46.5 GiB, collective term unchanged."),
    ("qwen3-32b", "act_shard,masked_nll,zero_opt"):
        ("H4: Megatron sequence parallelism (activations sequence-sharded "
         "between units) should cut the saved-residual footprint 16x and "
         "split TP all-reduces into RS+AG.",
         "REFUTED, HARMFUL — XLA SPMD cannot reshard the (remat-transposed) "
         "constraint efficiently ('involuntary full rematerialization'): "
         "+996% compute, +869% memory. Reverted; see the SPMD warning in "
         "the log (Shardy tracking bug b/433785288)."),
    ("zamba2-7b", "zero_opt"):
        ("H3 on zamba2: ZeRO the Adam moments. Zamba2's stacked-unit axis "
         "is 13 (not divisible by 16), so only the shared-attn/tail params "
         "reshard — predicted near-zero effect.",
         "CONFIRMED (null result as predicted): terms and HBM unchanged."),
    ("zamba2-7b", "microbatch=4,zero_opt"):
        ("H5: the per-unit residuals saved for backward dominate memory "
         "(13 units x ~2.9 GiB); accumulating gradients over 4 microbatches "
         "keeps one slice live at a time. Predicted ~-28 GiB, identical "
         "math (tests/test_perf_levers.py), collective ~unchanged.",
         "CONFIRMED — 43.7 -> 15.4 GiB/chip: zamba2-7b train_4k now FITS "
         "the 16 GiB HBM. Roofline terms within ~2% of baseline."),
    ("qwen3-32b", "microbatch=4,zero_opt"):
        ("H5 on qwen3-32b: 64 units x ~671 MiB residuals ~= 42 GiB; k=4 "
         "microbatches should reclaim ~3/4 of that.",
         "CONFIRMED — 64.1 -> 22.8 GiB/chip; terms ~unchanged."),
    ("qwen3-32b", "microbatch=8,zero_opt"):
        ("H6: one more doubling (k=8) to get under the 16 GiB line.",
         None),  # filled from data
    ("whisper-base", "microbatch=4,pad_vocab"):
        ("H6 (whisper): combine the vocab fix with k=4 microbatches.",
         "CONFIRMED — 2.9 GiB/chip; whisper train_4k is now ~7% of HBM."),
}


def main():
    with open(PERF) as f:
        perf = json.load(f)
    with open(BASE) as f:
        base = {(r["arch"], r["shape"]): r for r in json.load(f)}
    # newest record per (arch, levers) wins
    dedup = {}
    for r in perf:
        dedup[(r["arch"], ",".join(r["levers"]))] = r
    lines = []
    lines.append("| arch | levers (cumulative) | t_comp (s) | t_mem (s) | "
                 "t_coll (s) | HBM GiB/chip | useful | verdict |")
    lines.append("|---|---|---|---|---|---|---|---|")
    order = [k for k in NARRATIVE if k in dedup]
    for key in order:
        r = dedup[key]
        b = base[(r["arch"], r["shape"])]
        lines.append(
            f"| {r['arch']} | baseline (paper-faithful) | {b['t_compute']:.3e} "
            f"| {b['t_memory']:.3e} | {b['t_collective']:.3e} | "
            f"{b['peak_bytes_per_chip']/2**30:.1f} | "
            f"{b['useful_flops_ratio']:.2f} | — |"
            if key == order[0] or key[0] != order[order.index(key)-1][0]
            else "")
        hyp, verdict = NARRATIVE[key]
        if verdict is None:
            fits = r["peak_bytes_per_chip"] / 2**30
            verdict = (f"{'CONFIRMED' if fits <= 16.5 else 'PARTIAL'} — "
                       f"{fits:.1f} GiB/chip")
        lines.append(
            f"| {r['arch']} | {','.join(r['levers'])} | {r['t_compute']:.3e} "
            f"| {r['t_memory']:.3e} | {r['t_collective']:.3e} | "
            f"{r['peak_bytes_per_chip']/2**30:.1f} | "
            f"{r['useful_flops_ratio']:.2f} | see below |")
    lines = [l for l in lines if l]

    notes = ["", "### Iteration log (hypothesis -> change -> measured -> verdict)", ""]
    for i, key in enumerate(order, 1):
        hyp, verdict = NARRATIVE[key]
        r = dedup[key]
        if verdict is None:
            fits = r["peak_bytes_per_chip"] / 2**30
            verdict = (f"{'CONFIRMED' if fits <= 16.5 else 'PARTIAL'} — "
                       f"{fits:.1f} GiB/chip.")
        notes.append(f"{i}. **{key[0]} + [{key[1]}]** — {hyp}\n"
                     f"   **Measured:** t=({r['t_compute']:.2e}, "
                     f"{r['t_memory']:.2e}, {r['t_collective']:.2e}) s, "
                     f"HBM {r['peak_bytes_per_chip']/2**30:.1f} GiB. "
                     f"**{verdict}**")
    section = "\n".join(lines + notes)

    with open(EXP) as f:
        doc = f.read()
    doc = doc.replace("<!-- PERF_RESULTS -->", section)
    with open(EXP, "w") as f:
        f.write(doc)
    print("patched EXPERIMENTS.md with", len(order), "iterations")


if __name__ == "__main__":
    main()
