"""Paper Table S1 + Figure S2 — empirically-Bayesian multinomial regression.

Table S1: train/test accuracy of Independent, SFVI-Avg (single late average),
SFVI-Avg (frequent averaging), and SFVI, in small-silo (J=25, N_j=200) and
large-silo (J=5, N_j large) regimes.
Figure S2: warm-starting SFVI from a few SFVI-Avg rounds reaches a target
ELBO in fewer rounds than cold-started SFVI.

Every fit is one declarative spec over the compiled runtime: the data is
staged once per regime through the model registry, and each table row is
a ``staged_experiment`` over that bundle (``benchmarks/common.py``).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import print_table, silo_subset, staged_experiment
from repro.models.paper.registry import get_model

# SFVI syncs every optimizer step; batching K steps per compiled round
# keeps the Python loop short without changing the sync count.
K = 25


def _acc(model, eta_G, split):
    return 100 * float(model.accuracy(eta_G["mu"], split["x"], split["y"]))


def _sfvi(bundle, *, J, steps, lr, seed, staging, warm=None):
    exp = staged_experiment(
        "multinomial", bundle, algorithm="sfvi", num_silos=J,
        rounds=max(steps // K, 1), local_steps=min(K, steps), lr=lr, seed=seed,
        data_seed=staging[0], model_kwargs=staging[1])
    if warm is not None:
        exp.warm_start(theta=warm[0], eta_G=warm[1])
    hist = exp.run()
    return exp, hist


def _avg(bundle, *, J, rounds, local_steps, lr, seed, staging):
    exp = staged_experiment(
        "multinomial", bundle, algorithm="sfvi_avg", num_silos=J,
        rounds=rounds, local_steps=local_steps, lr=lr, seed=seed,
        data_seed=staging[0], model_kwargs=staging[1])
    hist = exp.run()
    return exp, hist


def run(quick: bool = True) -> dict:
    in_dim = 196 if quick else 784
    lr = 2e-2
    results = {}
    rows = []
    for J, n_per, label in [(8, 60, "J=8 N_j=60") if quick else (25, 200, "J=25 N_j=200"),
                            (3, 400, "J=3 N_j=400") if quick else (5, 2000, "J=5 N_j=2000")]:
        kw = dict(n_per=n_per, in_dim=in_dim)
        staging = (J, kw)  # (data_seed, model kwargs) — recorded in specs
        bundle = get_model("multinomial").build(J, J, **kw)
        model = bundle.extras["model"]
        train_all, test = bundle.extras["train_all"], bundle.extras["test"]
        total_steps = 400 if quick else 3000

        # Independent: single silos fitting alone (paper baseline, averaged).
        ind_tr, ind_te = [], []
        for j in range(min(3, J)):
            exp, _ = _sfvi(silo_subset(bundle, [j]), J=1, steps=total_steps,
                           lr=lr, seed=1, staging=staging)
            ind_tr.append(_acc(model, exp.eta_G, bundle.datas[j]))
            ind_te.append(_acc(model, exp.eta_G, test))
        rows.append({"Regime": label, "Method": "Independent", "Rounds": 0,
                     "Train %": round(np.mean(ind_tr), 1), "Test %": round(np.mean(ind_te), 1)})

        # SFVI-Avg, single late average (1 round of many local steps).
        exp, _ = _avg(bundle, J=J, rounds=1, local_steps=total_steps, lr=lr,
                      seed=1, staging=staging)
        rows.append({"Regime": label, "Method": f"SFVI-Avg({total_steps})", "Rounds": 1,
                     "Train %": round(_acc(model, exp.eta_G, train_all), 1),
                     "Test %": round(_acc(model, exp.eta_G, test), 1)})

        # SFVI-Avg, frequent averaging.
        n_rounds = 20 if quick else 50
        exp, _ = _avg(bundle, J=J, rounds=n_rounds,
                      local_steps=total_steps // n_rounds, lr=lr, seed=1,
                      staging=staging)
        rows.append({"Regime": label, "Method": f"SFVI-Avg({total_steps//n_rounds})", "Rounds": n_rounds,
                     "Train %": round(_acc(model, exp.eta_G, train_all), 1),
                     "Test %": round(_acc(model, exp.eta_G, test), 1)})

        # SFVI (one sync per optimizer step).
        exp, _ = _sfvi(bundle, J=J, steps=total_steps, lr=lr, seed=1,
                       staging=staging)
        sfvi_test = _acc(model, exp.eta_G, test)
        rows.append({"Regime": label, "Method": "SFVI", "Rounds": total_steps,
                     "Train %": round(_acc(model, exp.eta_G, train_all), 1),
                     "Test %": round(sfvi_test, 1)})
        results[label] = sfvi_test

    print_table("Table S1 — EB multinomial regression accuracy", rows,
                ["Regime", "Method", "Rounds", "Train %", "Test %"])

    # ---- Figure S2: SFVI-Avg warm start halves SFVI convergence ----
    kw = dict(n_per=100, in_dim=in_dim)
    staging = (7, kw)
    bundle = get_model("multinomial").build(7, 4, **kw)
    warm_exp, _ = _avg(bundle, J=4, rounds=5,
                       local_steps=60 if quick else 1000, lr=lr, seed=2,
                       staging=staging)

    iters = 150 if quick else 2000
    _, cold_h = _sfvi(bundle, J=4, steps=iters, lr=lr, seed=2, staging=staging)
    _, warm_h = _sfvi(bundle, J=4, steps=iters, lr=lr, seed=2, staging=staging,
                      warm=(warm_exp.theta, warm_exp.eta_G))
    cold, warm = cold_h["elbo_trace"], warm_h["elbo_trace"]
    target = cold[-1]
    reach_cold = next((i for i, v in enumerate(cold) if v >= target), iters)
    reach_warm = next((i for i, v in enumerate(warm) if v >= target), iters)
    print(f"\nFigure S2 — rounds for SFVI to reach ELBO target {target:.0f}: "
          f"cold={reach_cold + 1}, warm(5 SFVI-Avg rounds)={reach_warm + 1}")
    results["warmstart_speedup"] = (reach_cold + 1) / (reach_warm + 1)
    return results


if __name__ == "__main__":
    run(quick=True)
