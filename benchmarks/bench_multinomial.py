"""Paper Table S1 + Figure S2 — empirically-Bayesian multinomial regression.

Table S1: train/test accuracy of Independent, SFVI-Avg (single late average),
SFVI-Avg (frequent averaging), and SFVI, in small-silo (J=25, N_j=200) and
large-silo (J=5, N_j large) regimes.
Figure S2: warm-starting SFVI from a few SFVI-Avg rounds reaches a target
ELBO in fewer rounds than cold-started SFVI.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from repro.core import SFVIAvgServer, SFVIServer, Silo
from repro.data import iid_partition, make_synthetic_mnist
from repro.models.paper import build_multinomial
from repro.models.paper.multinomial import init_theta
from repro.optim import adam


def _make(in_dim, J, n_per, seed):
    # Hard-mode synthetic data: linear classifier cannot saturate, so the
    # Independent < SFVI-Avg < SFVI ordering of Table S1 is visible.
    tr, te = make_synthetic_mnist(
        jax.random.PRNGKey(seed), J * n_per, max(200, J * 20), dim=in_dim,
        prototype_scale=0.6, noise_scale=3.0,
    )
    rng = np.random.default_rng(seed)
    parts = iid_partition(rng, len(tr.y), J)
    datas = [{"x": jnp.asarray(tr.x[p]), "y": jnp.asarray(tr.y[p])} for p in parts]
    test = {"x": jnp.asarray(te.x), "y": jnp.asarray(te.y)}
    train_all = {"x": jnp.asarray(tr.x), "y": jnp.asarray(tr.y)}
    return datas, train_all, test


def _acc(model, eta_G, split):
    return 100 * float(model.accuracy(eta_G["mu"], split["x"], split["y"]))


def _silos(prob, datas):
    return [Silo(j, prob, datas[j], None, None, int(datas[j]["y"].shape[0])) for j in range(len(datas))]


def run(quick: bool = True) -> dict:
    in_dim = 196 if quick else 784
    lr = 2e-2
    results = {}
    rows = []
    for J, n_per, label in [(8, 60, "J=8 N_j=60") if quick else (25, 200, "J=25 N_j=200"),
                            (3, 400, "J=3 N_j=400") if quick else (5, 2000, "J=5 N_j=2000")]:
        datas, train_all, test = _make(in_dim, J, n_per, seed=J)
        model = build_multinomial(in_dim=in_dim)
        prob = model.problem
        total_steps = 400 if quick else 3000

        # Independent: silo 0 alone (paper's per-silo baseline, averaged).
        ind_tr, ind_te = [], []
        for j in range(min(3, J)):
            srv = SFVIServer(prob, [_silos(prob, [datas[j]])[0]], init_theta(),
                             prob.global_family.init(jax.random.PRNGKey(1)), adam(lr))
            srv.run(total_steps)
            ind_tr.append(_acc(model, srv.eta_G, datas[j]))
            ind_te.append(_acc(model, srv.eta_G, test))
        rows.append({"Regime": label, "Method": "Independent", "Rounds": 0,
                     "Train %": round(np.mean(ind_tr), 1), "Test %": round(np.mean(ind_te), 1)})

        # SFVI-Avg, single late average (1 round of many local steps).
        srv = SFVIAvgServer(prob, _silos(prob, datas), init_theta(),
                            prob.global_family.init(jax.random.PRNGKey(1)), lambda: adam(lr))
        srv.run(1, local_steps=total_steps)
        rows.append({"Regime": label, "Method": f"SFVI-Avg({total_steps})", "Rounds": 1,
                     "Train %": round(_acc(model, srv.eta_G, train_all), 1),
                     "Test %": round(_acc(model, srv.eta_G, test), 1)})

        # SFVI-Avg, frequent averaging.
        n_rounds = 20 if quick else 50
        srv = SFVIAvgServer(prob, _silos(prob, datas), init_theta(),
                            prob.global_family.init(jax.random.PRNGKey(1)), lambda: adam(lr))
        srv.run(n_rounds, local_steps=total_steps // n_rounds)
        rows.append({"Regime": label, "Method": f"SFVI-Avg({total_steps//n_rounds})", "Rounds": n_rounds,
                     "Train %": round(_acc(model, srv.eta_G, train_all), 1),
                     "Test %": round(_acc(model, srv.eta_G, test), 1)})

        # SFVI.
        srv = SFVIServer(prob, _silos(prob, datas), init_theta(),
                         prob.global_family.init(jax.random.PRNGKey(1)), adam(lr))
        srv.run(total_steps)
        sfvi_test = _acc(model, srv.eta_G, test)
        rows.append({"Regime": label, "Method": "SFVI", "Rounds": total_steps,
                     "Train %": round(_acc(model, srv.eta_G, train_all), 1),
                     "Test %": round(sfvi_test, 1)})
        results[label] = sfvi_test

    print_table("Table S1 — EB multinomial regression accuracy", rows,
                ["Regime", "Method", "Rounds", "Train %", "Test %"])

    # ---- Figure S2: SFVI-Avg warm start halves SFVI convergence ----
    datas, train_all, test = _make(in_dim, 4, 100, seed=7)
    model = build_multinomial(in_dim=in_dim)
    prob = model.problem
    warm_srv = SFVIAvgServer(prob, _silos(prob, datas), init_theta(),
                             prob.global_family.init(jax.random.PRNGKey(2)), lambda: adam(lr))
    warm_srv.run(5, local_steps=60 if quick else 1000)

    def sfvi_curve(theta0, eta0, iters):
        srv = SFVIServer(prob, _silos(prob, datas), theta0, eta0, adam(lr))
        return srv.run(iters)["elbo"]

    iters = 150 if quick else 2000
    cold = sfvi_curve(init_theta(), prob.global_family.init(jax.random.PRNGKey(2)), iters)
    warm = sfvi_curve(warm_srv.theta, warm_srv.eta_G, iters)
    target = cold[-1]
    reach_cold = next((i for i, v in enumerate(cold) if v >= target), iters)
    reach_warm = next((i for i, v in enumerate(warm) if v >= target), iters)
    print(f"\nFigure S2 — rounds for SFVI to reach ELBO target {target:.0f}: "
          f"cold={reach_cold + 1}, warm(5 SFVI-Avg rounds)={reach_warm + 1}")
    results["warmstart_speedup"] = (reach_cold + 1) / (reach_warm + 1)
    return results


if __name__ == "__main__":
    run(quick=True)
