"""Paper Figure S1 — Bayesian logistic GLMM (six cities), marginal posteriors:
SFVI on a federated two-silo split vs an HMC oracle on the pooled data.

Reproduces the paper's claim: SFVI recovers the pooled-posterior marginals of
β accurately even though the per-silo posteriors barely overlap. The silo
split is staged by the model registry (even shards — the compiled runtime
stacks silo data along the ``silo`` mesh axis, so every silo carries the
same number of children; the paper's uneven 300/237 split is a host-level
protocol detail that does not change the pooled posterior being targeted).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, staged_experiment
from repro.inference import hmc_sample
from repro.models.paper.glmm import glmm_log_joint_local
from repro.models.paper.registry import get_model

PARAM_NAMES = ["beta0", "beta1(smoke)", "beta2(age)", "beta3(smoke*age)", "omega"]

K = 25  # local steps per compiled SFVI round (sync still every step)


def _fit_sfvi(bundle, n_children, iters, lr, seed):
    """Federated SFVI fit over the staged two-silo bundle."""
    exp = staged_experiment(
        "glmm", bundle, algorithm="sfvi", num_silos=len(bundle.datas),
        rounds=max(iters // K, 1), local_steps=K, lr=lr, seed=seed,
        model_kwargs={"num_children": n_children})
    hist = exp.run()
    return exp, hist


def _hmc_oracle(data, num_children, num_samples, num_warmup, seed):
    """HMC on the pooled joint (β, ω, b) — the NUTS stand-in."""
    dim = 5 + num_children

    def log_prob(q):
        z_G, b = q[:5], q[5:]
        lp_g = jnp.sum(-0.5 * z_G**2 / 100.0)
        return lp_g + glmm_log_joint_local(z_G, b, data)

    init = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 99), (dim,))
    samples, acc = hmc_sample(
        log_prob, init, jax.random.PRNGKey(seed),
        num_samples=num_samples, num_warmup=num_warmup, num_leapfrog=24,
    )
    return samples[:, :5], float(acc)


def run(quick: bool = True) -> dict:
    n_children = 120 if quick else 536
    iters = 1500 if quick else 6000
    mcmc_n = (400, 400) if quick else (1500, 1500)

    bundle = get_model("glmm").build(0, 2, num_children=n_children)
    pooled = bundle.extras["pooled"]
    total_children = bundle.extras["num_children"]

    exp, hist = _fit_sfvi(bundle, n_children, iters, lr=2e-2, seed=0)
    mcmc_global, acc_rate = _hmc_oracle(pooled, total_children, *mcmc_n, seed=0)

    vi_mu = np.asarray(exp.eta_G["mu"])
    vi_sd = np.asarray(jnp.exp(exp.eta_G["log_sigma"]))
    mc_mu = np.asarray(mcmc_global.mean(0))
    mc_sd = np.asarray(mcmc_global.std(0))

    rows = []
    for i, name in enumerate(PARAM_NAMES):
        rows.append({
            "param": name,
            "SFVI mean": round(float(vi_mu[i]), 3),
            "HMC mean": round(float(mc_mu[i]), 3),
            "SFVI sd": round(float(vi_sd[i]), 3),
            "HMC sd": round(float(mc_sd[i]), 3),
            "|Δmean|/sd": round(abs(float(vi_mu[i] - mc_mu[i])) / float(mc_sd[i]), 2),
        })
    print_table(
        f"Figure S1 — GLMM marginals, SFVI (federated even 2-silo split) vs "
        f"HMC oracle (accept={acc_rate:.2f})",
        rows, ["param", "SFVI mean", "HMC mean", "SFVI sd", "HMC sd", "|Δmean|/sd"],
    )
    max_z = max(r["|Δmean|/sd"] for r in rows[:4])  # β marginals
    print(f"\nmax |Δmean|/sd over β: {max_z}   ELBO {hist['elbo'][0]:.1f} -> {hist['elbo'][-1]:.1f}")
    return {"max_z_beta": max_z, "vi_mu": vi_mu.tolist(), "mc_mu": mc_mu.tolist()}


if __name__ == "__main__":
    run(quick=True)
