"""Paper Figure S1 — Bayesian logistic GLMM (six cities), marginal posteriors:
SFVI on the federated (300/237) split vs an HMC oracle on the pooled data vs
independent per-silo fits.

Reproduces the paper's claim: SFVI recovers the pooled-posterior marginals of
β accurately even though the independent-silo posteriors barely overlap.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from repro.core import SFVIServer, Silo
from repro.data import make_six_cities, sizes_partition
from repro.inference import hmc_sample
from repro.models.paper import build_glmm
from repro.models.paper.glmm import glmm_log_joint_local
from repro.optim import adam

PARAM_NAMES = ["beta0", "beta1(smoke)", "beta2(age)", "beta3(smoke*age)", "omega"]


def _fit_sfvi(datas, sizes, iters, lr, seed):
    """Federated fit. Each silo has its own GLMM problem instance
    (different n_children per silo — allowed: conditional independence only)."""
    from repro.core import SFVIProblem
    from repro.models.paper.glmm import build_glmm as _b

    # Shared global family; per-silo local dims differ -> build per-silo problems
    # sharing log_prior_global (SFVI supports non-identically-sized silos).
    probs = [_b(num_children_j=s).problem for s in sizes]
    base = probs[0]
    silos = [
        Silo(j, probs[j], datas[j], probs[j].local_family.init(jax.random.PRNGKey(70 + j)),
             adam(lr), sizes[j])
        for j in range(len(datas))
    ]
    srv = SFVIServer(base, silos, {}, base.global_family.init(jax.random.PRNGKey(seed)), adam(lr))
    hist = srv.run(iters)
    return srv, hist


def _hmc_oracle(data, num_children, num_samples, num_warmup, seed):
    """HMC on the pooled joint (β, ω, b) — the NUTS stand-in."""
    dim = 5 + num_children

    def log_prob(q):
        z_G, b = q[:5], q[5:]
        lp_g = jnp.sum(-0.5 * z_G**2 / 100.0)
        return lp_g + glmm_log_joint_local(z_G, b, data)

    init = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 99), (dim,))
    samples, acc = hmc_sample(
        log_prob, init, jax.random.PRNGKey(seed),
        num_samples=num_samples, num_warmup=num_warmup, num_leapfrog=24,
    )
    return samples[:, :5], float(acc)


def run(quick: bool = True) -> dict:
    n_children = 120 if quick else 537
    sizes = [round(n_children * 300 / 537), n_children - round(n_children * 300 / 537)]
    iters = 1500 if quick else 6000
    mcmc_n = (400, 400) if quick else (1500, 1500)

    data, truth = make_six_cities(jax.random.PRNGKey(3), num_children=n_children)
    rng = np.random.default_rng(0)
    parts = sizes_partition(rng, n_children, sizes)
    datas = [{k: jnp.asarray(v[p]) for k, v in data.items()} for p in parts]
    pooled = {k: jnp.asarray(v) for k, v in data.items()}

    srv, hist = _fit_sfvi(datas, sizes, iters, lr=2e-2, seed=0)
    mcmc_global, acc_rate = _hmc_oracle(pooled, n_children, *mcmc_n, seed=0)

    vi_mu = np.asarray(srv.eta_G["mu"])
    vi_sd = np.asarray(jnp.exp(srv.eta_G["log_sigma"]))
    mc_mu = np.asarray(mcmc_global.mean(0))
    mc_sd = np.asarray(mcmc_global.std(0))

    rows = []
    for i, name in enumerate(PARAM_NAMES):
        rows.append({
            "param": name,
            "SFVI mean": round(float(vi_mu[i]), 3),
            "HMC mean": round(float(mc_mu[i]), 3),
            "SFVI sd": round(float(vi_sd[i]), 3),
            "HMC sd": round(float(mc_sd[i]), 3),
            "|Δmean|/sd": round(abs(float(vi_mu[i] - mc_mu[i])) / float(mc_sd[i]), 2),
        })
    print_table(
        f"Figure S1 — GLMM marginals, SFVI (federated 300/237 split) vs HMC "
        f"oracle (accept={acc_rate:.2f})",
        rows, ["param", "SFVI mean", "HMC mean", "SFVI sd", "HMC sd", "|Δmean|/sd"],
    )
    max_z = max(r["|Δmean|/sd"] for r in rows[:4])  # β marginals
    print(f"\nmax |Δmean|/sd over β: {max_z}   ELBO {hist['elbo'][0]:.1f} -> {hist['elbo'][-1]:.1f}")
    return {"max_z_beta": max_z, "vi_mu": vi_mu.tolist(), "mc_mu": mc_mu.tolist()}


if __name__ == "__main__":
    run(quick=True)
