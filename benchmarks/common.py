"""Shared benchmark utilities."""
from __future__ import annotations

import time
from contextlib import contextmanager


@contextmanager
def timed(label: str, results: dict):
    t0 = time.perf_counter()
    yield
    results[f"{label}_seconds"] = round(time.perf_counter() - t0, 2)


def print_table(title: str, rows: list[dict], cols: list[str]) -> None:
    print(f"\n== {title} ==")
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows)) for c in cols}
    header = " | ".join(c.ljust(widths[c]) for c in cols)
    print(header)
    print("-" * len(header))
    for r in rows:
        print(" | ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))


def fmt(x, nd=3):
    if isinstance(x, float):
        return round(x, nd)
    return x
