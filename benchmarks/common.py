"""Shared benchmark utilities.

``staged_experiment`` / ``silo_subset`` are the single data-staging path
for every benchmark: models are staged once through the registry
(:mod:`repro.models.paper.registry`) and each benchmarked configuration
is one declarative :class:`~repro.federated.api.ExperimentSpec` built
over that bundle — no benchmark constructs silos or servers by hand.
"""
from __future__ import annotations

import dataclasses
import time
from contextlib import contextmanager


@contextmanager
def timed(label: str, results: dict):
    t0 = time.perf_counter()
    yield
    results[f"{label}_seconds"] = round(time.perf_counter() - t0, 2)


def print_table(title: str, rows: list[dict], cols: list[str]) -> None:
    print(f"\n== {title} ==")
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows)) for c in cols}
    header = " | ".join(c.ljust(widths[c]) for c in cols)
    print(header)
    print("-" * len(header))
    for r in rows:
        print(" | ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))


def fmt(x, nd=3):
    if isinstance(x, float):
        return round(x, nd)
    return x


def staged_experiment(model: str, bundle, *, num_silos: int, rounds: int,
                      local_steps: int = 1, scenario=None, algorithm=None,
                      lr: float = 2e-2, local_lr=None, seed: int = 0,
                      data_seed=None, eta_mode: str = "barycenter",
                      model_kwargs=None, eval_every: int = 0,
                      wire: str = "flat", mesh=None):
    """Spec-build an Experiment over a pre-staged registry bundle.

    One bundle (one dataset staging) can serve many specs — algorithms,
    scenarios, seeds — which is exactly how the benchmark tables are
    built. Pass either a full ``scenario`` or just ``algorithm``.

    For the spec to faithfully describe the run (so ``Experiment.save``
    -> ``resume`` re-stages the same data), ``model_kwargs`` and
    ``data_seed`` must match what the bundle was built with. Bundles
    restricted with :func:`silo_subset` are NOT spec-describable — don't
    resume those from disk.
    """
    from repro.federated import (ExperimentSpec, MeshSpec, ModelSpec,
                                 OptimizerSpec, RuntimeSpec, Scenario, build)

    sc = scenario if scenario is not None else Scenario(
        algorithm=algorithm or "sfvi")
    spec = ExperimentSpec(
        model=ModelSpec(model, kwargs=dict(model_kwargs or {})),
        scenario=sc,
        num_silos=num_silos,
        rounds=rounds,
        local_steps=local_steps,
        server_opt=OptimizerSpec("adam", lr),
        local_opt=OptimizerSpec("adam", local_lr) if local_lr else None,
        eta_mode=eta_mode,
        eval_every=eval_every,
        seed=seed,
        data_seed=data_seed,
        # Execution topology rides the spec (RuntimeSpec), so every
        # benchmarked row is fully spec-describable — wire layout and
        # device mesh included.
        runtime=RuntimeSpec(wire=wire, mesh=mesh if mesh is not None
                            else MeshSpec()),
    )
    return build(spec, bundle=bundle)


def silo_subset(bundle, indices):
    """Restrict a staged bundle to a subset of its silos.

    Used for the paper's "independent" baselines (one silo fitting
    alone) without re-staging data.
    """
    return dataclasses.replace(
        bundle,
        datas=[bundle.datas[j] for j in indices],
        num_obs=([bundle.num_obs[j] for j in indices]
                 if bundle.num_obs is not None else None),
    )
