"""CI perf-regression gate for the federated benchmark.

    python benchmarks/check_perf.py BENCH_federated.json benchmarks/baseline.json
    python benchmarks/check_perf.py BENCH_federated.json benchmarks/baseline.json --update

Compares a fresh ``bench_federated.py --smoke --json`` result against the
committed baseline, per scenario:

  * **bytes/round** — deterministic; any drift beyond 0.5% fails (a wire
    regression is a bug, not noise);
  * **calibrated time** — the benchmark's ``calibrated_round`` (median
    over rounds of round-seconds / interleaved-yardstick-seconds; the
    fixed NumPy yardstick cancels runner speed and even mid-benchmark
    load out of the ratio); fails when it exceeds the baseline's by
    more than ``TIME_REGRESSION`` (25%);
  * **ELBO** — a loose 10% sanity band (cross-platform float drift is
    ~1e-6; a 10% move means the optimization changed, which a perf PR
    must not do silently);
  * **simulated async wall-clock** — deterministic (event-loop output);
    0.5% band.

Scenarios present only in the new result are reported but do not fail
(they need a baseline refresh); scenarios missing from the new result
fail (coverage must not silently shrink). ``--update`` rewrites the
baseline from the new result instead of gating — run it locally and
commit the file whenever the smoke config or scenario list changes.

Exit codes: 0 pass, 1 regression, 2 usage.
"""
from __future__ import annotations

import json
import sys

TIME_REGRESSION = 0.25  # fail when calibrated time grows more than this
BYTES_TOLERANCE = 0.005
ELBO_TOLERANCE = 0.10
SIM_TOLERANCE = 0.005


def _rel(new: float, old: float) -> float:
    return abs(new - old) / max(abs(old), 1e-12)


def _calibrated(entry: dict, top: dict) -> float:
    """The gated time: pre-normalized if present, else normalize here."""
    if "calibrated_round" in entry:
        return float(entry["calibrated_round"])
    return float(entry["s_per_round"]) / float(top["calibration_s"])


def compare(new: dict, base: dict) -> list:
    """Return a list of human-readable regression strings (empty = pass)."""
    problems = []
    new_sc = new["scenarios"]
    base_sc = base["scenarios"]

    for name in sorted(set(base_sc) - set(new_sc)):
        problems.append(f"scenario dropped from the benchmark: {name!r}")
    for name in sorted(set(new_sc) - set(base_sc)):
        print(f"note: new scenario {name!r} has no baseline yet "
              "(run check_perf.py --update and commit)")

    for name in sorted(set(new_sc) & set(base_sc)):
        a, b = new_sc[name], base_sc[name]
        if _rel(a["bytes_per_round"], b["bytes_per_round"]) > BYTES_TOLERANCE:
            problems.append(
                f"{name}: bytes/round {b['bytes_per_round']:.0f} -> "
                f"{a['bytes_per_round']:.0f}")
        if _rel(a["elbo"], b["elbo"]) > ELBO_TOLERANCE:
            problems.append(
                f"{name}: ELBO moved {b['elbo']:.3f} -> {a['elbo']:.3f} "
                f"(>{ELBO_TOLERANCE:.0%})")
        # No zero-baseline guard: 0 -> 0 passes (rel 0), but a sync
        # scenario STARTING to accumulate simulated time must fail just
        # like an async scenario losing it.
        if _rel(a.get("sim_seconds", 0.0), b.get("sim_seconds", 0.0)) \
                > SIM_TOLERANCE:
            problems.append(
                f"{name}: simulated wall-clock {b['sim_seconds']:.3f}s -> "
                f"{a['sim_seconds']:.3f}s")
        t_new = _calibrated(a, new)
        t_base = _calibrated(b, base)
        if t_new > t_base * (1.0 + TIME_REGRESSION):
            problems.append(
                f"{name}: calibrated s/round {t_base:.3f} -> {t_new:.3f} "
                f"(+{(t_new / t_base - 1.0):.0%}, gate {TIME_REGRESSION:.0%})")
        else:
            print(f"ok: {name}  calibrated {t_base:.3f} -> {t_new:.3f}  "
                  f"bytes {a['bytes_per_round']:.0f}")
    return problems


def main(argv) -> int:
    if len(argv) not in (3, 4) or (len(argv) == 4 and argv[3] != "--update"):
        print(__doc__)
        return 2
    new_path, base_path = argv[1], argv[2]
    with open(new_path) as f:
        new = json.load(f)
    if len(argv) == 4:
        with open(base_path, "w") as f:
            json.dump(new, f, indent=2)
            f.write("\n")
        print(f"baseline updated: {base_path}")
        return 0
    try:
        with open(base_path) as f:
            base = json.load(f)
    except FileNotFoundError:
        print(f"REGRESSION GATE: no baseline at {base_path} — generate one "
              "with --update and commit it")
        return 1
    problems = compare(new, base)
    if problems:
        print(f"\nPERF REGRESSION ({len(problems)}):")
        for p in problems:
            print(f"  {p}")
        return 1
    print(f"\nno perf regressions vs {base_path} "
          f"({len(base['scenarios'])} scenarios)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
