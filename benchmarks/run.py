"""Benchmark harness — one benchmark per paper table/figure, plus the
roofline suite for the assigned architectures.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Default mode is CPU-budget "quick" (reduced dims/iters; same protocols).
"""
from __future__ import annotations

import argparse
import time
import traceback

SUITES = ["hier_bnn", "prodlda", "glmm", "multinomial", "kernels", "serving",
          "federated", "roofline"]


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--full", action="store_true", help="paper-scale (slow) settings")
    parser.add_argument("--only", type=str, default=None, help="comma-separated suite names")
    args = parser.parse_args()
    quick = not args.full
    wanted = args.only.split(",") if args.only else SUITES

    print(f"# SFVI benchmark harness (quick={quick})")
    t_all = time.perf_counter()
    failures = []
    for name in wanted:
        print(f"\n{'='*72}\n# suite: {name}\n{'='*72}")
        t0 = time.perf_counter()
        try:
            if name == "hier_bnn":
                from benchmarks import bench_hier_bnn
                bench_hier_bnn.run(quick=quick, seeds=(0,) if quick else (0, 1, 2, 3, 4))
            elif name == "prodlda":
                from benchmarks import bench_prodlda
                bench_prodlda.run(quick=quick)
            elif name == "glmm":
                from benchmarks import bench_glmm
                bench_glmm.run(quick=quick)
            elif name == "multinomial":
                from benchmarks import bench_multinomial
                bench_multinomial.run(quick=quick)
            elif name == "kernels":
                from benchmarks import bench_kernels
                bench_kernels.run(quick=quick)
            elif name == "serving":
                from benchmarks import bench_serving
                bench_serving.run(quick=quick)
            elif name == "federated":
                from benchmarks import bench_federated
                bench_federated.run(quick=quick)
            elif name == "roofline":
                from benchmarks import bench_roofline
                bench_roofline.run(quick=quick)
            else:
                print(f"unknown suite {name}")
                continue
            print(f"[{name}] OK in {time.perf_counter()-t0:.1f}s")
        except Exception:
            failures.append(name)
            print(f"[{name}] FAILED in {time.perf_counter()-t0:.1f}s")
            traceback.print_exc()
    print(f"\n# total {time.perf_counter()-t_all:.1f}s; failures: {failures or 'none'}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
