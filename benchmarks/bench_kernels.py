"""Kernel micro-benchmarks: Pallas kernels (interpret mode) vs their jnp
oracles — correctness deltas + CPU wall-times for the jnp paths.

On CPU the interpret-mode kernel is NOT a performance path (it executes
Python per grid cell); the numbers that matter here are (a) max|err| vs
the oracle across a shape sweep and (b) the jnp fallback's throughput,
which IS the shipped CPU path. TPU wall-time belongs to real hardware.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from repro.kernels import ops, ref


def _time(f, *args, iters=3):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters


def run(quick: bool = True) -> dict:
    key = jax.random.PRNGKey(0)
    rows = []

    shapes = [(1, 256, 4, 64), (2, 512, 8, 64)] if quick else [
        (1, 256, 4, 64), (2, 512, 8, 64), (2, 1024, 8, 128), (4, 2048, 16, 128)]
    for (B, S, H, hd) in shapes:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
        out = ops.flash_attention(q, k, v, causal=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        err = float(jnp.abs(out - want).max())
        t_ref = _time(lambda: ref.flash_attention_ref(q, k, v, causal=True))
        rows.append({"kernel": "flash_attention", "shape": f"B{B} S{S} H{H} hd{hd}",
                     "max|err|": f"{err:.1e}",
                     "jnp-ref ms": f"{t_ref*1e3:.1f}"})
        assert err < 1e-4

    for (R, D) in [(4096, 1024), (16384, 4096)][: 1 if quick else 2]:
        x = jax.random.normal(key, (R, D), jnp.float32)
        w = jnp.ones((D,))
        err = float(jnp.abs(ops.rmsnorm(x, w) - ref.rmsnorm_ref(x, w)).max())
        t_ref = _time(lambda: ref.rmsnorm_ref(x, w))
        rows.append({"kernel": "rmsnorm", "shape": f"{R}x{D}",
                     "max|err|": f"{err:.1e}", "jnp-ref ms": f"{t_ref*1e3:.1f}"})
        assert err < 1e-5

    N = 100_000 if quick else 2_000_000
    ks = jax.random.split(key, 3)
    mu = jax.random.normal(ks[0], (N,))
    ls = -1 + 0.2 * jax.random.normal(ks[1], (N,))
    eps = jax.random.normal(ks[2], (N,))
    z, lq = ops.reparam_stl(mu, ls, eps)
    z_r, lq_r = ref.reparam_stl_ref(mu, ls, eps)
    err = max(float(jnp.abs(z - z_r).max()),
              float(abs(lq - lq_r.sum())) / N)
    t_ref = _time(lambda: ref.reparam_stl_ref(mu, ls, eps))
    rows.append({"kernel": "reparam_stl", "shape": f"N={N}",
                 "max|err|": f"{err:.1e}", "jnp-ref ms": f"{t_ref*1e3:.1f}"})
    assert err < 1e-5

    print_table("Pallas kernels (interpret mode) vs jnp oracles", rows,
                ["kernel", "shape", "max|err|", "jnp-ref ms"])
    return {"kernels": len(rows)}


if __name__ == "__main__":
    run(quick=True)
