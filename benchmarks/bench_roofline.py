"""Render the roofline table (EXPERIMENTS.md §Roofline) from the dry-run
results JSON produced by ``repro.launch.dryrun --out``.

    PYTHONPATH=src python -m benchmarks.bench_roofline \
        [--json benchmarks/data/roofline_single_pod.json] [--markdown]
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import print_table

DEFAULT = os.path.join(os.path.dirname(__file__), "data",
                       "roofline_single_pod.json")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(path: str):
    with open(path) as f:
        recs = json.load(f)
    key = lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]))  # noqa: E731
    return sorted(recs, key=lambda r: (r.get("mesh", ""),) + key(r))


def fmt(x, digits=3):
    return f"{x:.{digits}e}" if isinstance(x, float) else str(x)


def rows_from(recs):
    rows = []
    for r in recs:
        if r["status"] == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "t_comp (s)": "skip", "t_mem (s)": "-", "t_coll (s)": "-",
                         "bound": "-", "useful": "-", "HBM GiB/chip": "-"})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "t_comp (s)": "FAIL", "t_mem (s)": "-", "t_coll (s)": "-",
                         "bound": "-", "useful": "-", "HBM GiB/chip": "-"})
            continue
        rows.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "t_comp (s)": fmt(r["t_compute"]),
            "t_mem (s)": fmt(r["t_memory"]),
            "t_coll (s)": fmt(r["t_collective"]),
            "bound": r["bottleneck"],
            "useful": f"{r['useful_flops_ratio']:.2f}",
            "HBM GiB/chip": f"{r['peak_bytes_per_chip']/2**30:.2f}",
        })
    return rows


def run(json_path: str = DEFAULT, markdown: bool = False,
        quick: bool = True) -> dict:  # quick: accepted for harness parity
    recs = load(json_path)
    cols = ["arch", "shape", "t_comp (s)", "t_mem (s)", "t_coll (s)", "bound",
            "useful", "HBM GiB/chip"]
    rows = rows_from(recs)
    if markdown:
        print("| " + " | ".join(cols) + " |")
        print("|" + "|".join("---" for _ in cols) + "|")
        for row in rows:
            print("| " + " | ".join(str(row[c]) for c in cols) + " |")
    else:
        print_table(f"Roofline terms per (arch x shape) [{recs[0].get('mesh')}]",
                    rows, cols)
    ok = [r for r in recs if r["status"] == "ok"]
    by_bound = {}
    for r in ok:
        by_bound.setdefault(r["bottleneck"], []).append(
            f"{r['arch']}/{r['shape']}")
    print("\nbottleneck distribution:",
          {k: len(v) for k, v in by_bound.items()})
    return {"records": len(recs), "ok": len(ok), "by_bound": by_bound}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=DEFAULT)
    ap.add_argument("--markdown", action="store_true")
    a = ap.parse_args()
    run(a.json, a.markdown)
