"""Paper Table 1 — hierarchical BNN / fully-Bayesian FedPop on heterogeneous
MNIST-like data: test accuracy of SFVI vs SFVI-Avg under severe label skew.

The offline container substitutes synthetic-MNIST (same 784-dim, 10-class,
90%-one-label-per-silo protocol; see DESIGN.md §7). CPU budget forces
scaled-down iteration counts vs the paper's 10^4; the *ordering* claims
(SFVI ≥ SFVI-Avg in accuracy; SFVI-Avg within a few points at ~500× less
communication) are what we validate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from repro.core import SFVIAvgServer, SFVIServer, Silo
from repro.data import heterogeneous_label_partition, make_synthetic_mnist
from repro.models.paper import build_hier_bnn
from repro.optim import adam


def _posterior_mean_accuracy(bnn, server, silos, test_sets):
    """Per-silo test accuracy using posterior means (MC-1 at the mean)."""
    accs = []
    for j, silo in enumerate(silos):
        z_G = server.eta_G["mu"]
        z_L = silo.eta_L["mu_bar"]
        accs.append(float(bnn.accuracy(z_G, z_L, test_sets[j]["x"], test_sets[j]["y"])))
    return float(np.mean(accs)), float(np.std(accs))


def run_once(seed: int, fedpop: bool, num_silos: int, quick: bool):
    in_dim, hidden = (196, 32) if quick else (784, 64)
    n_train = 200 * num_silos if quick else 600 * num_silos
    n_test = 40 * num_silos if quick else 100 * num_silos
    sfvi_iters = 150 if quick else 800
    avg_rounds, avg_local = (10, 15) if quick else (20, 40)
    lr = 2e-2

    key = jax.random.PRNGKey(seed)
    # Harder-than-default noise so accuracies land in the paper's 90s range
    # rather than saturating (synthetic prototypes are more separable than MNIST).
    tr, te = make_synthetic_mnist(
        key, n_train, n_test, dim=in_dim, prototype_scale=1.0, noise_scale=2.5
    )
    rng = np.random.default_rng(seed)
    parts_tr = heterogeneous_label_partition(rng, tr.y, num_silos)
    parts_te = heterogeneous_label_partition(rng, te.y, num_silos)
    train = [{"x": jnp.asarray(tr.x[p]), "y": jnp.asarray(tr.y[p])} for p in parts_tr]
    test = [{"x": jnp.asarray(te.x[p]), "y": jnp.asarray(te.y[p])} for p in parts_te]

    bnn = build_hier_bnn(in_dim=in_dim, hidden=hidden, fedpop=fedpop)
    prob = bnn.problem

    def make_silos():
        return [
            Silo(j, prob, train[j],
                 prob.local_family.init(jax.random.PRNGKey(1000 + seed * 100 + j)),
                 adam(lr), len(parts_tr[j]))
            for j in range(num_silos)
        ]

    results = {}
    # --- SFVI ---
    silos = make_silos()
    srv = SFVIServer(prob, silos, {}, prob.global_family.init(jax.random.PRNGKey(seed)), adam(lr))
    srv.run(sfvi_iters)
    acc, std = _posterior_mean_accuracy(bnn, srv, silos, test)
    results["SFVI"] = (acc, std, srv.comm.rounds, srv.comm.total)

    # --- SFVI-Avg ---
    silos = make_silos()
    srv2 = SFVIAvgServer(prob, silos, {}, prob.global_family.init(jax.random.PRNGKey(seed)), lambda: adam(lr))
    srv2.run(avg_rounds, local_steps=avg_local)
    acc2, std2 = _posterior_mean_accuracy(bnn, srv2, silos, test)
    results["SFVI-Avg"] = (acc2, std2, srv2.comm.rounds, srv2.comm.total)
    return results


def run(quick: bool = True, seeds=(0, 1, 2)) -> dict:
    rows = []
    summary = {}
    for fedpop in (False, True):
        model_name = "Fully-Bayesian FedPop" if fedpop else "Hierarchical BNN"
        for inference in ("SFVI", "SFVI-Avg"):
            accs, rounds, bytes_total = [], None, None
            for seed in seeds:
                res = run_once(seed, fedpop, num_silos=4 if quick else 10, quick=quick)
                acc, std, rounds, bytes_total = res[inference]
                accs.append(acc)
            rows.append({
                "Model": model_name,
                "Inference": inference,
                "Acc %": round(100 * float(np.mean(accs)), 1),
                "std": round(100 * float(np.std(accs)), 2),
                "Rounds": rounds,
                "Comm MiB": round(bytes_total / 2**20, 1),
            })
            summary[f"{model_name}/{inference}"] = rows[-1]
    print_table("Table 1 — heterogeneous-data BNN test accuracy", rows,
                ["Model", "Inference", "Acc %", "std", "Rounds", "Comm MiB"])
    return summary


if __name__ == "__main__":
    run(quick=True)
