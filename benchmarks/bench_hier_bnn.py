"""Paper Table 1 — hierarchical BNN / fully-Bayesian FedPop on heterogeneous
MNIST-like data: test accuracy of SFVI vs SFVI-Avg under severe label skew.

The offline container substitutes synthetic-MNIST (same 784-dim, 10-class,
90%-one-label-per-silo protocol; see DESIGN.md §7). CPU budget forces
scaled-down iteration counts vs the paper's 10^4; the *ordering* claims
(SFVI ≥ SFVI-Avg in accuracy; SFVI-Avg within a few points at far less
communication) are what we validate.

Data is staged once per (model, seed) by the registry; each table cell is
one declarative spec over the compiled runtime.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import print_table, staged_experiment
from repro.models.paper.registry import get_model

K = 25  # local steps per compiled SFVI round (sync still every step)


def run_once(seed: int, fedpop: bool, num_silos: int, quick: bool):
    in_dim, hidden = (196, 32) if quick else (784, 64)
    train_per, test_per = (200, 40) if quick else (600, 100)
    sfvi_iters = 150 if quick else 800
    avg_rounds, avg_local = (10, 15) if quick else (20, 40)
    lr = 2e-2

    name = "fedpop_bnn" if fedpop else "hier_bnn"
    kw = dict(in_dim=in_dim, hidden=hidden,
              train_per_silo=train_per, test_per_silo=test_per)
    bundle = get_model(name).build(seed, num_silos, **kw)

    results = {}
    # --- SFVI (sync every optimizer step) ---
    exp = staged_experiment(
        name, bundle, algorithm="sfvi", num_silos=num_silos,
        rounds=max(sfvi_iters // K, 1), local_steps=K, lr=lr, seed=seed,
        model_kwargs=kw)
    exp.run()
    scores = exp.evaluate()
    results["SFVI"] = (scores["test_acc"], scores["test_acc_std"],
                       exp.comm.rounds, exp.comm.total)

    # --- SFVI-Avg (one sync per round of avg_local steps) ---
    exp2 = staged_experiment(
        name, bundle, algorithm="sfvi_avg", num_silos=num_silos,
        rounds=avg_rounds, local_steps=avg_local, lr=lr, seed=seed,
        model_kwargs=kw)
    exp2.run()
    scores2 = exp2.evaluate()
    results["SFVI-Avg"] = (scores2["test_acc"], scores2["test_acc_std"],
                           exp2.comm.rounds, exp2.comm.total)
    return results


def run(quick: bool = True, seeds=(0, 1, 2)) -> dict:
    rows = []
    summary = {}
    for fedpop in (False, True):
        model_name = "Fully-Bayesian FedPop" if fedpop else "Hierarchical BNN"
        for inference in ("SFVI", "SFVI-Avg"):
            accs, rounds, bytes_total = [], None, None
            for seed in seeds:
                res = run_once(seed, fedpop, num_silos=4 if quick else 10, quick=quick)
                acc, std, rounds, bytes_total = res[inference]
                accs.append(acc)
            rows.append({
                "Model": model_name,
                "Inference": inference,
                "Acc %": round(100 * float(np.mean(accs)), 1),
                "std": round(100 * float(np.std(accs)), 2),
                "Rounds": rounds,
                "Comm MiB": round(bytes_total / 2**20, 1),
            })
            summary[f"{model_name}/{inference}"] = rows[-1]
    print_table("Table 1 — heterogeneous-data BNN test accuracy", rows,
                ["Model", "Inference", "Acc %", "std", "Rounds", "Comm MiB"])
    return summary


if __name__ == "__main__":
    run(quick=True)
