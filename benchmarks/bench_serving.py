"""Serving micro-benchmark: prefill + decode throughput on CPU for the
reduced configs (the mesh-scale serving path is lowered in the dry-run;
these numbers verify the END-TO-END serve loop executes and give a CPU
baseline for regression tracking).

Also surfaces the federated runtime's per-round communication accounting
for the serving tier: each replica refreshes its posterior (θ, η_G) from
the training federation once per round, so the round-sync column is the
bytes a replica pulls per refresh — raw and under int8 wire compression
(``repro.federated.aggregation``)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import print_table
from repro.configs import get_config
from repro.federated import Int8Compressor, NoCompression
from repro.launch import steps as S


def run(quick: bool = True) -> dict:
    archs = ["qwen3-4b", "zamba2-7b", "olmoe-1b-7b"] if quick else [
        "qwen3-4b", "zamba2-7b", "olmoe-1b-7b", "xlstm-1.3b", "qwen2-vl-2b",
        "llama3.2-3b"]
    rows = []
    key = jax.random.PRNGKey(0)
    for arch in archs:
        cfg = get_config(arch).reduced()
        J, B, P, G = 2, 4, 32, 8
        state, _ = S.init_train_state(key, cfg, J)
        prefill = jax.jit(S.make_serve_prefill(cfg, J, max_len=P + G
                                               + cfg.num_vision_tokens))
        decode = jax.jit(S.make_serve_decode(cfg, J))
        batch = {"tokens": jax.random.randint(key, (B, P), 0, cfg.vocab_size)}
        if cfg.is_encoder_decoder:
            batch["frames"] = jax.random.normal(
                key, (B, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
        if cfg.num_vision_tokens:
            batch["vision"] = jax.random.normal(
                key, (B, cfg.num_vision_tokens, cfg.d_model), jnp.float32)
        logits, cache = prefill(state.theta, state.eta_G, state.eta_L, batch)
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        logits, cache2 = prefill(state.theta, state.eta_G, state.eta_L, batch)
        jax.block_until_ready(logits)
        t_pre = time.perf_counter() - t0
        tok = jnp.argmax(logits[:, -1], axis=-1)
        # warm decode
        lg, cache2 = decode(state.theta, state.eta_G, state.eta_L,
                            tok[:, None], cache2)
        jax.block_until_ready(lg)
        t0 = time.perf_counter()
        for _ in range(G):
            lg, cache2 = decode(state.theta, state.eta_G, state.eta_L,
                                tok[:, None], cache2)
            tok = jnp.argmax(lg[:, -1], axis=-1)
        jax.block_until_ready(tok)
        t_dec = time.perf_counter() - t0
        sync_tree = {"theta": state.theta, "eta_G": state.eta_G}
        # The federated sync ships over the flat (J, P) wire: one int8
        # payload + ONE f32 scale per silo, not one scale per leaf.
        raw_b = NoCompression().wire_bytes(sync_tree, wire="flat")
        int8_b = Int8Compressor().wire_bytes(sync_tree, wire="flat")
        rows.append({
            "arch": cfg.name,
            "prefill tok/s": f"{B * P / t_pre:.0f}",
            "decode tok/s": f"{B * G / t_dec:.0f}",
            "sync MiB/round": f"{raw_b / 2**20:.1f}",
            "int8 MiB/round": f"{int8_b / 2**20:.1f}",
        })
    print_table("CPU serving throughput (reduced configs, B=4) + per-round "
                "posterior sync cost", rows,
                ["arch", "prefill tok/s", "decode tok/s", "sync MiB/round",
                 "int8 MiB/round"])
    return {"rows": len(rows)}


if __name__ == "__main__":
    run(quick=True)
