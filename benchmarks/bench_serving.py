"""Serving micro-benchmark: prefill + decode throughput on CPU for the
reduced configs (the mesh-scale serving path is lowered in the dry-run;
these numbers verify the END-TO-END serve loop executes and give a CPU
baseline for regression tracking).

Also surfaces the federated runtime's per-round communication accounting
for the serving tier: each replica refreshes its posterior (θ, η_G) from
the training federation once per round, so the round-sync column is the
bytes a replica pulls per refresh — raw and under int8 wire compression
(``repro.federated.aggregation``)."""
from __future__ import annotations

import statistics
import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.common import print_table
from repro.configs import get_config
from repro.federated import Int8Compressor, NoCompression
from repro.launch import steps as S


def federated_posterior_row(yardstick=None) -> dict:
    """Latency/throughput of the ``q(Z_L|Z_G)`` serving endpoint.

    Trains a small toy CHURN run (population dynamics exercised end to
    end), checkpoints it, restores a :class:`repro.federated.serve.
    Posterior` and times batched query serving: a fixed mixed batch
    (per-silo joint samples + global samples, grouped by silo into one
    vectorized draw per group) served repeatedly, median latency.

    Returns a row in the ``check_perf.py`` gate schema — ``elbo`` (the
    checkpointed training run; moves only if training changed),
    ``bytes_per_round`` (the posterior-refresh pull a replica pays, a
    deterministic wire quantity), ``s_per_round`` (median batch
    latency) and, when a ``yardstick`` callable is supplied,
    ``calibrated_round`` (latency / yardstick ratio, machine-neutral) —
    plus ungated ``queries_per_s`` / ``samples_per_s`` throughput.
    """
    from repro.federated import (ExperimentSpec, ModelSpec, PopulationSpec,
                                 Scenario, build)
    from repro.federated.serve import Posterior, Query

    spec = ExperimentSpec(
        model=ModelSpec("toy", {"num_obs": 40}),
        scenario=Scenario(algorithm="sfvi"),
        num_silos=6, rounds=8, seed=0,
        population=PopulationSpec(initial=2, arrival_rate=0.6,
                                  departure_rate=0.2, return_rate=0.5,
                                  seed=3))
    exp = build(spec)
    hist = exp.run()
    ckpt = tempfile.mkdtemp(prefix="bench_serving_")
    exp.save(ckpt)

    post = Posterior.from_checkpoint(ckpt)
    queries = [Query("sample", silo=j % post.num_silos, n=32)
               for j in range(48)] + [Query("global_sample", n=32)]
    n_samples = sum(q.n for q in queries)
    post.answer_batch(queries, seed=0)  # compile warmup
    lats, ratios = [], []
    for rep in range(16):
        tick = yardstick() if yardstick is not None else None
        t0 = time.perf_counter()
        ans = post.answer_batch(queries, seed=rep)
        jax.block_until_ready([a["z_G"] for a in ans])
        dt = time.perf_counter() - t0
        lats.append(dt)
        if tick is not None:
            ratios.append(dt / tick)
    lat = statistics.median(lats)
    refresh = {"theta": exp.theta, "eta_G": exp.eta_G}
    row = {
        "elbo": float(hist["elbo"][-1]),
        "bytes_per_round": float(
            NoCompression().wire_bytes(refresh, wire="flat")),
        "s_per_round": lat,
        "sim_seconds": 0.0,
        "epsilon": None,
        "queries_per_s": len(queries) / lat,
        "samples_per_s": n_samples / lat,
        "served_silos": post.num_silos,
    }
    if ratios:
        row["calibrated_round"] = statistics.median(ratios)
    return row


def run(quick: bool = True) -> dict:
    archs = ["qwen3-4b", "zamba2-7b", "olmoe-1b-7b"] if quick else [
        "qwen3-4b", "zamba2-7b", "olmoe-1b-7b", "xlstm-1.3b", "qwen2-vl-2b",
        "llama3.2-3b"]
    rows = []
    key = jax.random.PRNGKey(0)
    for arch in archs:
        cfg = get_config(arch).reduced()
        J, B, P, G = 2, 4, 32, 8
        state, _ = S.init_train_state(key, cfg, J)
        prefill = jax.jit(S.make_serve_prefill(cfg, J, max_len=P + G
                                               + cfg.num_vision_tokens))
        decode = jax.jit(S.make_serve_decode(cfg, J))
        batch = {"tokens": jax.random.randint(key, (B, P), 0, cfg.vocab_size)}
        if cfg.is_encoder_decoder:
            batch["frames"] = jax.random.normal(
                key, (B, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
        if cfg.num_vision_tokens:
            batch["vision"] = jax.random.normal(
                key, (B, cfg.num_vision_tokens, cfg.d_model), jnp.float32)
        logits, cache = prefill(state.theta, state.eta_G, state.eta_L, batch)
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        logits, cache2 = prefill(state.theta, state.eta_G, state.eta_L, batch)
        jax.block_until_ready(logits)
        t_pre = time.perf_counter() - t0
        tok = jnp.argmax(logits[:, -1], axis=-1)
        # warm decode
        lg, cache2 = decode(state.theta, state.eta_G, state.eta_L,
                            tok[:, None], cache2)
        jax.block_until_ready(lg)
        t0 = time.perf_counter()
        for _ in range(G):
            lg, cache2 = decode(state.theta, state.eta_G, state.eta_L,
                                tok[:, None], cache2)
            tok = jnp.argmax(lg[:, -1], axis=-1)
        jax.block_until_ready(tok)
        t_dec = time.perf_counter() - t0
        sync_tree = {"theta": state.theta, "eta_G": state.eta_G}
        # The federated sync ships over the flat (J, P) wire: one int8
        # payload + ONE f32 scale per silo, not one scale per leaf.
        raw_b = NoCompression().wire_bytes(sync_tree, wire="flat")
        int8_b = Int8Compressor().wire_bytes(sync_tree, wire="flat")
        rows.append({
            "arch": cfg.name,
            "prefill tok/s": f"{B * P / t_pre:.0f}",
            "decode tok/s": f"{B * G / t_dec:.0f}",
            "sync MiB/round": f"{raw_b / 2**20:.1f}",
            "int8 MiB/round": f"{int8_b / 2**20:.1f}",
        })
    print_table("CPU serving throughput (reduced configs, B=4) + per-round "
                "posterior sync cost", rows,
                ["arch", "prefill tok/s", "decode tok/s", "sync MiB/round",
                 "int8 MiB/round"])
    fed = federated_posterior_row()
    print_table(
        "federated posterior serving (q(Z_L|Z_G) endpoint from a churn "
        "checkpoint; batched queries grouped per silo)",
        [{"served silos": fed["served_silos"],
          "batch s": f"{fed['s_per_round'] * 1e3:.2f} ms",
          "queries/s": f"{fed['queries_per_s']:.0f}",
          "samples/s": f"{fed['samples_per_s']:.0f}",
          "refresh KiB": f"{fed['bytes_per_round'] / 1024:.1f}",
          "ckpt ELBO": f"{fed['elbo']:.1f}"}],
        ["served silos", "batch s", "queries/s", "samples/s", "refresh KiB",
         "ckpt ELBO"])
    return {"rows": len(rows), "federated_posterior": fed}


if __name__ == "__main__":
    run(quick=True)
