"""Paper Figure 2 — ProdLDA on a 20Newsgroups-like corpus (3 silos):
(a) UMass topic coherence for SFVI / SFVI-Avg / per-silo independent fits,
(b) ELBO trajectories.

The paper's headline findings to reproduce: federated fits beat independent
per-silo fits on coherence, and SFVI-Avg can beat SFVI on coherence despite
a lower ELBO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from repro.core import SFVIAvgServer, SFVIServer, Silo
from repro.data import make_lda_corpus
from repro.models.paper import build_prodlda
from repro.models.paper.prodlda import init_theta, umass_coherence
from repro.optim import adam


def _fit_sfvi(lda, datas, iters, lr, seed):
    prob = lda.problem
    silos = [
        Silo(j, prob, datas[j], prob.local_family.init(jax.random.PRNGKey(50 + j)),
             adam(lr), lda.docs_per_silo)
        for j in range(len(datas))
    ]
    srv = SFVIServer(prob, silos, init_theta(), prob.global_family.init(jax.random.PRNGKey(seed)), adam(lr))
    hist = srv.run(iters)
    return srv, hist


def _fit_avg(lda, datas, rounds, local_steps, lr, seed):
    prob = lda.problem
    silos = [
        Silo(j, prob, datas[j], prob.local_family.init(jax.random.PRNGKey(50 + j)),
             adam(lr), lda.docs_per_silo)
        for j in range(len(datas))
    ]
    srv = SFVIAvgServer(prob, silos, init_theta(), prob.global_family.init(jax.random.PRNGKey(seed)), lambda: adam(lr))
    hist = srv.run(rounds, local_steps=local_steps)
    return srv, hist


def _fit_independent(lda, data_j, iters, lr, seed):
    """One silo fitting alone (the paper's per-silo baseline)."""
    prob = lda.problem
    silo = Silo(0, prob, data_j, prob.local_family.init(jax.random.PRNGKey(60 + seed)),
                adam(lr), lda.docs_per_silo)
    srv = SFVIServer(prob, [silo], init_theta(), prob.global_family.init(jax.random.PRNGKey(seed)), adam(lr))
    srv.run(iters)
    return srv


def run(quick: bool = True, iters_scale: float = 1.0) -> dict:
    # Scarce per-silo data (the regime where federation pays off,
    # as in the paper's 3-silo 20NG split): few docs per silo.
    vocab, topics, dps = (300, 8, 40) if quick else (2000, 21, 400)
    iters = int((200 if quick else 1500) * iters_scale)
    rounds, local = ((8, 25) if quick else (30, 50))
    rounds = max(1, int(rounds * iters_scale))
    lr = 5e-2
    J = 3

    counts, _true = make_lda_corpus(
        jax.random.PRNGKey(0), num_docs=J * dps, vocab_size=vocab, num_topics=topics
    )
    lda = build_prodlda(vocab_size=vocab, num_topics=topics, docs_per_silo=dps)
    datas = [{"counts": jnp.asarray(counts[j * dps : (j + 1) * dps])} for j in range(J)]

    srv_sfvi, hist_sfvi = _fit_sfvi(lda, datas, iters, lr, seed=1)
    srv_avg, hist_avg = _fit_avg(lda, datas, rounds, local, lr, seed=1)
    indep = [_fit_independent(lda, datas[j], iters, lr, seed=j) for j in range(J)]

    def coherence_of(eta_G):
        t = np.asarray(lda.topics(eta_G["mu"]))
        return umass_coherence(t, np.asarray(counts), top_n=8)

    rows = []
    coh = {}
    for name, srv in [("SFVI", srv_sfvi), ("SFVI-Avg", srv_avg)]:
        c = coherence_of(srv.eta_G)
        coh[name] = c
        rows.append({"Method": name, "Coherence median": round(float(np.median(c)), 2),
                     "Coherence mean": round(float(np.mean(c)), 2),
                     "Rounds": srv.comm.rounds, "Comm MiB": round(srv.comm.total / 2**20, 1)})
    c_ind = np.concatenate([coherence_of(s.eta_G) for s in indep])
    coh["Independent"] = c_ind
    rows.append({"Method": "Independent silos", "Coherence median": round(float(np.median(c_ind)), 2),
                 "Coherence mean": round(float(np.mean(c_ind)), 2), "Rounds": 0, "Comm MiB": 0.0})
    print_table("Figure 2(a) — ProdLDA UMass topic coherence (higher is better)",
                rows, ["Method", "Coherence median", "Coherence mean", "Rounds", "Comm MiB"])

    print("\nFigure 2(b) — ELBO trajectory endpoints:")
    print(f"  SFVI     : {hist_sfvi['elbo'][0]:.0f} -> {hist_sfvi['elbo'][-1]:.0f}"
          f"  ({iters} rounds)")
    print(f"  SFVI-Avg : {hist_avg['elbo'][0]:.0f} -> {hist_avg['elbo'][-1]:.0f}"
          f"  ({rounds} rounds x {local} local steps)")
    return {
        "coherence": {k: float(np.median(v)) for k, v in coh.items()},
        "elbo_sfvi": hist_sfvi["elbo"][-1],
        "elbo_avg": hist_avg["elbo"][-1],
    }


if __name__ == "__main__":
    run(quick=True)
