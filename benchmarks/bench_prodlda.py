"""Paper Figure 2 — ProdLDA on a 20Newsgroups-like corpus (3 silos):
(a) UMass topic coherence for SFVI / SFVI-Avg / per-silo independent fits,
(b) ELBO trajectories.

The paper's headline findings to reproduce: federated fits beat independent
per-silo fits on coherence, and SFVI-Avg can beat SFVI on coherence despite
a lower ELBO.

The corpus is staged once by the registry; every fit (including the
per-silo independent baselines, via ``silo_subset``) is one declarative
spec over the compiled runtime.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import print_table, silo_subset, staged_experiment
from repro.models.paper.prodlda import umass_coherence
from repro.models.paper.registry import get_model

K = 25  # local steps per compiled SFVI round (sync still every step)


def _fit(bundle, *, algorithm, rounds, local_steps, lr, seed, staging):
    exp = staged_experiment(
        "prodlda", bundle, algorithm=algorithm, num_silos=len(bundle.datas),
        rounds=rounds, local_steps=local_steps, lr=lr, seed=seed,
        data_seed=staging[0], model_kwargs=staging[1])
    hist = exp.run()
    return exp, hist


def run(quick: bool = True, iters_scale: float = 1.0) -> dict:
    # Scarce per-silo data (the regime where federation pays off,
    # as in the paper's 3-silo 20NG split): few docs per silo.
    vocab, topics, dps = (300, 8, 40) if quick else (2000, 21, 400)
    iters = int((200 if quick else 1500) * iters_scale)
    rounds, local = ((8, 25) if quick else (30, 50))
    rounds = max(1, int(rounds * iters_scale))
    lr = 5e-2
    J = 3

    kw = dict(vocab_size=vocab, num_topics=topics, docs_per_silo=dps)
    staging = (0, kw)  # (data_seed, model kwargs) — recorded in specs
    bundle = get_model("prodlda").build(0, J, **kw)
    lda, counts = bundle.extras["lda"], bundle.extras["counts"]

    exp_sfvi, hist_sfvi = _fit(bundle, algorithm="sfvi",
                               rounds=max(iters // K, 1), local_steps=K,
                               lr=lr, seed=1, staging=staging)
    exp_avg, hist_avg = _fit(bundle, algorithm="sfvi_avg", rounds=rounds,
                             local_steps=local, lr=lr, seed=1, staging=staging)
    indep = [_fit(silo_subset(bundle, [j]), algorithm="sfvi",
                  rounds=max(iters // K, 1), local_steps=K, lr=lr, seed=j,
                  staging=staging)[0]
             for j in range(J)]

    def coherence_of(eta_G):
        t = np.asarray(lda.topics(eta_G["mu"]))
        return umass_coherence(t, np.asarray(counts), top_n=8)

    rows = []
    coh = {}
    for name, exp in [("SFVI", exp_sfvi), ("SFVI-Avg", exp_avg)]:
        c = coherence_of(exp.eta_G)
        coh[name] = c
        rows.append({"Method": name, "Coherence median": round(float(np.median(c)), 2),
                     "Coherence mean": round(float(np.mean(c)), 2),
                     "Rounds": exp.comm.rounds, "Comm MiB": round(exp.comm.total / 2**20, 1)})
    c_ind = np.concatenate([coherence_of(e.eta_G) for e in indep])
    coh["Independent"] = c_ind
    rows.append({"Method": "Independent silos", "Coherence median": round(float(np.median(c_ind)), 2),
                 "Coherence mean": round(float(np.mean(c_ind)), 2), "Rounds": 0, "Comm MiB": 0.0})
    print_table("Figure 2(a) — ProdLDA UMass topic coherence (higher is better)",
                rows, ["Method", "Coherence median", "Coherence mean", "Rounds", "Comm MiB"])

    print("\nFigure 2(b) — ELBO trajectory endpoints:")
    print(f"  SFVI     : {hist_sfvi['elbo_trace'][0]:.0f} -> {hist_sfvi['elbo_trace'][-1]:.0f}"
          f"  ({iters} sync steps)")
    print(f"  SFVI-Avg : {hist_avg['elbo'][0]:.0f} -> {hist_avg['elbo'][-1]:.0f}"
          f"  ({rounds} rounds x {local} local steps)")
    return {
        "coherence": {k: float(np.median(v)) for k, v in coh.items()},
        "elbo_sfvi": hist_sfvi["elbo"][-1],
        "elbo_avg": hist_avg["elbo"][-1],
    }


if __name__ == "__main__":
    run(quick=True)
