"""Federated-runtime scenario sweep (paper §3.2 + robustness + privacy).

Runs the hierarchical BNN through the declarative experiment API
(``repro.federated.api``) under the scenario grid the runtime exposes —
sync cadence (SFVI vs SFVI-Avg), wire compression (int8), robust
aggregation (trimmed mean), partial participation with stragglers, and
differentially private rounds — and reports final ELBO, test accuracy,
per-round communication, per-round wall time and cumulative ε. Each row
is one :class:`ExperimentSpec` (the same object ``--sweep`` builds in the
CLI), so every benchmarked configuration is serializable and resumable.

``privacy_utility_sweep`` traces the ε↔utility frontier: one row per
noise multiplier, ε vs ELBO vs accuracy vs wire bytes.

``--smoke --json BENCH_federated.json`` runs a tiny fixed configuration
(toy model) and writes a machine-readable result — the CI perf gate
(``benchmarks/check_perf.py``) compares it against the committed
``benchmarks/baseline.json`` and fails on >25% calibrated regression.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import sys
import time

from benchmarks.common import print_table, staged_experiment
from repro.federated import AsyncConfig, Scenario
from repro.models.paper.fixtures import bnn_posterior_accuracy
from repro.models.paper.registry import get_model

# The same declarative Scenario the CLI's --sweep walks (scheduler.py);
# row labels come from Scenario.name.
SCENARIOS = [
    Scenario(algorithm="sfvi"),
    Scenario(algorithm="sfvi_avg"),
    # Natural-parameter strategies from the registry: same round cadence
    # and wire as SFVI-Avg, but silos ship damped natural-parameter site
    # deltas instead of posterior averages.
    Scenario(algorithm="pvi"),
    Scenario(algorithm="fed_ep"),
    Scenario(algorithm="sfvi_avg", compression="int8"),
    Scenario(algorithm="sfvi", aggregator="trimmed", trim_frac=0.1,
             participation=0.5, dropout=0.1),
    Scenario(algorithm="sfvi_avg", dp_noise=1.0),
    Scenario(algorithm="sfvi_avg", dp_noise=1.0, compression="int8",
             participation=0.5),
    # Buffered-async rows: flush every B=2 arrivals under a heavy
    # straggler tail — the regime the synchronous server pays for in
    # wall-clock, composed with DP + int8 to cover the whole stack.
    Scenario(algorithm="sfvi_avg",
             async_cfg=AsyncConfig(buffer_size=2, latency="straggler")),
    Scenario(algorithm="sfvi_avg", dp_noise=1.0, compression="int8",
             async_cfg=AsyncConfig(buffer_size=2, latency="straggler")),
]


def _fit(bundle, sc: Scenario, *, J, rounds, local, lr, seed):
    exp = staged_experiment(
        "hier_bnn", bundle, scenario=sc, num_silos=J, rounds=rounds,
        local_steps=local, lr=lr, seed=seed)
    t0 = time.time()
    hist = exp.run()
    dt = time.time() - t0
    bnn, test = bundle.extras["bnn"], bundle.extras["test"]
    acc, _ = bnn_posterior_accuracy(bnn, exp.eta_G, exp.eta_L, test)
    eps = hist["epsilon"][-1] if "epsilon" in hist else math.inf
    return exp, hist, acc, eps, dt


def run(quick: bool = True, seed: int = 0) -> dict:
    J = 4 if quick else 8
    rounds, local = (6, 10) if quick else (20, 25)
    lr = 2e-2

    bundle = get_model("hier_bnn").build(seed, J)

    rows, out = [], {}
    for sc in SCENARIOS:
        exp, hist, acc, eps, dt = _fit(
            bundle, sc, J=J, rounds=rounds, local=local, lr=lr, seed=seed)
        rows.append({
            "Scenario": sc.name,
            "ELBO": round(hist["elbo"][-1], 0),
            "Acc %": round(100 * acc, 1),
            "eps": "inf" if eps == math.inf else round(eps, 2),
            "KiB/round": round(exp.comm.per_round / 1024, 1),
            "s/round": round(dt / rounds, 2),
            "Sim s": round(exp.comm.sim_seconds, 1),
            "Total MiB": round(exp.comm.total / 2**20, 2),
        })
        out[sc.name] = rows[-1]

    print_table(
        f"Federated runtime scenarios (hier BNN, J={J}, "
        f"{rounds} rounds x {local} local steps; DP at delta=1e-05)",
        rows, ["Scenario", "ELBO", "Acc %", "eps", "KiB/round", "s/round",
               "Sim s", "Total MiB"],
    )
    sfvi, avg = out["SFVI"], out["SFVI-Avg"]
    dp = out[Scenario(algorithm="sfvi_avg", dp_noise=1.0).name]
    int8 = out[Scenario(algorithm="sfvi_avg", compression="int8").name]
    assert avg["KiB/round"] < sfvi["KiB/round"], (
        "SFVI-Avg must ship strictly fewer bytes per round than SFVI")
    assert dp["eps"] != "inf", (
        "DP scenario must report a finite cumulative epsilon")
    print(f"\nSFVI-Avg ships {sfvi['KiB/round']/avg['KiB/round']:.1f}x fewer "
          f"bytes/round than SFVI; int8 compression a further "
          f"{avg['KiB/round']/int8['KiB/round']:.1f}x; "
          f"DP adds eps={dp['eps']} at identical wire cost.")
    return out


def privacy_utility_sweep(quick: bool = True, seed: int = 0,
                          noise_multipliers=(0.0, 0.1, 0.25, 0.5, 1.0)) -> list:
    """ε vs ELBO vs accuracy vs comm bytes, one row per noise multiplier."""
    J = 4 if quick else 8
    rounds, local = (6, 10) if quick else (20, 25)
    lr = 2e-2
    bundle = get_model("hier_bnn").build(seed, J)

    rows = []
    for z in noise_multipliers:
        sc = Scenario(algorithm="sfvi_avg", dp_noise=z)
        exp, hist, acc, eps, dt = _fit(
            bundle, sc, J=J, rounds=rounds, local=local, lr=lr, seed=seed)
        rows.append({
            "z": z,
            "eps": "inf" if eps == math.inf else round(eps, 2),
            "ELBO": round(hist["elbo"][-1], 0),
            "Acc %": round(100 * acc, 1),
            "KiB/round": round(exp.comm.per_round / 1024, 1),
            "s/round": round(dt / rounds, 2),
        })

    print_table(
        f"Privacy-utility frontier (SFVI-Avg, hier BNN, J={J}, "
        f"{rounds} rounds x {local} local steps, delta=1e-5)",
        rows, ["z", "eps", "ELBO", "Acc %", "KiB/round", "s/round"],
    )
    return rows


# ---------------------------------------------------------------------------
# CI smoke benchmark + machine-readable output (the perf-gate input)
# ---------------------------------------------------------------------------

# The tiny FIXED configuration the CI gate tracks across commits. Never
# tune these to make a regression disappear — change them only together
# with a regenerated benchmarks/baseline.json (check_perf.py --update).
# rounds = 1 warmup (compile, reported but not gated) + 24 individually
# timed rounds; s_per_round is their median (robust under runner noise).
# The multinomial model (1970-dim global) keeps per-round work well
# above host-dispatch jitter, unlike the microscopic toy posterior.
SMOKE_CONFIG = {"model": "multinomial",
                "model_kwargs": {"n_per": 60, "in_dim": 196}, "silos": 4,
                "rounds": 25, "local_steps": 4, "lr": 2e-2, "seed": 0}

# DP rows use a gentle (z, C): the gate tracks ELBO as a sanity band,
# which needs a stable (non-diverging) trajectory on the toy posterior.
SMOKE_SCENARIOS = [
    Scenario(algorithm="sfvi"),
    Scenario(algorithm="sfvi_avg"),
    Scenario(algorithm="sfvi_avg", compression="int8"),
    Scenario(algorithm="sfvi_avg", dp_noise=0.3, dp_clip=0.3),
    Scenario(algorithm="sfvi_avg",
             async_cfg=AsyncConfig(buffer_size=2, latency="straggler")),
    Scenario(algorithm="sfvi_avg", dp_noise=0.3, dp_clip=0.3,
             compression="int8",
             async_cfg=AsyncConfig(buffer_size=2, latency="straggler")),
]


# 1-D vs 2-D mesh scaling row: the smoke config on a forced 4-host-device
# world, once on a (silo=2) mesh and once on (silo=2, model=2) — same
# J, same rounds, SFVI (the gather-heaviest cadence, one sync per local
# step). The 2-D mesh must reproduce the 1-D ELBO bit for bit (the
# sharding-layout contract, docs/federated.md) and both rows ride the
# same check_perf.py gate as every other scenario. Runs in a subprocess
# because XLA_FLAGS must be set before JAX initializes.
_MESH_PROBE_DEVICES = 4
_MESH_PROBE_MESHES = [("silo=2", {"silo": 2}),
                      ("silo=2,model=2", {"silo": 2, "model": 2})]
_MESH_PROBE_ROUNDS = 9  # 1 compile + 8 timed

_YARD_INPUT = None


def _yardstick(reps: int = 3) -> float:
    """Seconds for a fixed NumPy workload — a machine-speed yardstick.

    CI runners and developer laptops differ in raw speed by more than
    any regression we want to catch, so ``check_perf.py`` gates
    CALIBRATED times (round seconds / yardstick seconds): the yardstick
    cancels the machine out of the ratio. The smoke benchmark measures
    it INTERLEAVED with every timed round, so even load that arrives
    mid-benchmark hits both sides of the ratio. Deliberately
    single-threaded elementwise work (no BLAS): threaded matmuls
    measure the scheduler, not the machine, and flap ±25% run to run.
    """
    import numpy as np

    global _YARD_INPUT
    if _YARD_INPUT is None:
        _YARD_INPUT = np.linspace(0.0, 1.0, 1 << 20, dtype=np.float32)
    x = _YARD_INPUT
    t0 = time.perf_counter()
    for _ in range(reps):
        x = np.tanh(x) * 0.5 + 0.25
    return time.perf_counter() - t0


def _mesh_probe_rows() -> dict:
    """The 1-D vs 2-D mesh rows — call only under forced host devices."""
    import statistics

    from repro.federated import MeshSpec

    cfg = dict(SMOKE_CONFIG)
    bundle = get_model(cfg["model"]).build(
        cfg["seed"], cfg["silos"], **cfg["model_kwargs"])
    rows = {}
    for label, axes in _MESH_PROBE_MESHES:
        exp = staged_experiment(
            cfg["model"], bundle, scenario=Scenario(algorithm="sfvi"),
            num_silos=cfg["silos"], rounds=_MESH_PROBE_ROUNDS,
            local_steps=cfg["local_steps"], lr=cfg["lr"], seed=cfg["seed"],
            model_kwargs=cfg["model_kwargs"], mesh=MeshSpec(**axes))
        exp.run(1)  # compile
        per, ratios = [], []
        while exp.remaining_rounds:
            tick = _yardstick()
            t0 = time.perf_counter()
            exp.run(1)
            dt = time.perf_counter() - t0
            per.append(dt)
            ratios.append(dt / tick)
        rows[f"SFVI [mesh {label}]"] = {
            "elbo": float(exp.history["elbo"][-1]),
            "bytes_per_round": float(exp.comm.per_round),
            "s_per_round": statistics.median(per),
            "calibrated_round": statistics.median(ratios),
            "sim_seconds": 0.0,
            "epsilon": None,
        }
    one_d, two_d = (rows[f"SFVI [mesh {label}]"]
                    for label, _ in _MESH_PROBE_MESHES)
    assert one_d["elbo"] == two_d["elbo"], (
        "2-D (silo, model) mesh must reproduce the 1-D silo mesh "
        "bit-exactly", one_d["elbo"], two_d["elbo"])
    return rows


def _mesh_probe() -> dict:
    """Run the mesh rows in a fresh subprocess with forced host devices."""
    here = os.path.abspath(__file__)
    repo = os.path.dirname(os.path.dirname(here))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_MESH_PROBE_DEVICES}")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), repo]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run([sys.executable, here, "--mesh-probe"],
                         capture_output=True, text=True, env=env, cwd=repo)
    if out.returncode != 0:
        raise RuntimeError("mesh probe failed:\n"
                           + out.stdout[-2000:] + out.stderr[-2000:])
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("MESHPROBE ")][-1]
    return json.loads(line[len("MESHPROBE "):])


def smoke(json_path: str | None = None, seed: int | None = None) -> dict:
    """Tiny fixed benchmark for the CI perf gate (writes ``json_path``).

    One row per SMOKE_SCENARIO over the toy model: final ELBO,
    bytes/round (deterministic), wall s/round and the simulated async
    wall-clock, plus a calibration measurement so times compare across
    machines. The output schema is what ``benchmarks/check_perf.py``
    consumes.
    """
    cfg = dict(SMOKE_CONFIG)
    if seed is not None:
        cfg["seed"] = seed
    bundle = get_model(cfg["model"]).build(
        cfg["seed"], cfg["silos"], **cfg["model_kwargs"])

    import statistics

    scenarios = {}
    yardsticks = []

    def _timed_row(sc, wire="flat"):
        exp = staged_experiment(
            cfg["model"], bundle, scenario=sc, num_silos=cfg["silos"],
            rounds=cfg["rounds"], local_steps=cfg["local_steps"],
            lr=cfg["lr"], seed=cfg["seed"],
            model_kwargs=cfg["model_kwargs"], wire=wire)
        # Round 0 pays tracing + XLA compile; report it separately and
        # gate only the steady-state per-round time (compile latency on
        # shared CI runners is far noisier than the 25% gate). Every
        # remaining round is timed individually, bracketed by a
        # yardstick tick; the gated quantity is the MEDIAN of the
        # per-round (round s / yardstick s) ratios — machine speed and
        # even mid-benchmark load cancel, spikes fall to the median.
        t0 = time.perf_counter()
        exp.run(1)
        compile_s = time.perf_counter() - t0
        per_round, ratios = [], []
        while exp.remaining_rounds:
            tick = _yardstick()
            t0 = time.perf_counter()
            exp.run(1)
            dt = time.perf_counter() - t0
            per_round.append(dt)
            ratios.append(dt / tick)
            yardsticks.append(tick)
        hist = exp.history
        return exp, {
            "elbo": float(hist["elbo"][-1]),
            "bytes_per_round": float(exp.comm.per_round),
            "s_per_round": statistics.median(per_round),
            "calibrated_round": statistics.median(ratios),
            "compile_s": compile_s,
            "sim_seconds": float(exp.comm.sim_seconds),
            "epsilon": (float(hist["epsilon"][-1])
                        if "epsilon" in hist else None),
        }

    for sc in SMOKE_SCENARIOS:
        _, scenarios[sc.name] = _timed_row(sc)

    # The fused Pallas wire rides the same gate as every other row:
    # identical scenario to the int8 row, wire="fused" — a slowdown in
    # the kernels' interpret path (or a semantic drift moving the ELBO)
    # fails CI like any other regression.
    fused_sc = SMOKE_SCENARIOS[2]
    _, scenarios[fused_sc.name + " [wire=fused]"] = _timed_row(
        fused_sc, wire="fused")

    # Wire layouts head to head: the flat (J, P) relayout vs the fused
    # Pallas kernels vs the per-leaf legacy reference — same config,
    # same bundle, timed back to back (median of per-round ratios
    # against the interleaved yardstick, like the gated rows), plus the
    # roofline terms of each compiled round (HBM bytes moved is what
    # the fused kernels attack). Reported for visibility — the gated
    # fused row above is what CI enforces.
    wire_compare = {}
    for sc in (SMOKE_SCENARIOS[1], SMOKE_SCENARIOS[2]):
        per, roofline = {}, {}
        for layout in ("flat", "fused", "legacy"):
            exp = staged_experiment(
                cfg["model"], bundle, scenario=sc, num_silos=cfg["silos"],
                rounds=cfg["rounds"], local_steps=cfg["local_steps"],
                lr=cfg["lr"], seed=cfg["seed"],
                model_kwargs=cfg["model_kwargs"], wire=layout)
            exp.run(1)  # compile
            ratios = []
            for _ in range(8):
                tick = _yardstick()
                t0 = time.perf_counter()
                exp.run(1)
                ratios.append((time.perf_counter() - t0) / tick)
                yardsticks.append(tick)
            per[layout] = statistics.median(ratios)
            roofline[layout] = exp.server.compiled_roofline(
                sc.algorithm, cfg["local_steps"])
        wire_compare[sc.name] = {
            **per,
            "flat_speedup": per["legacy"] / per["flat"],
            "fused_speedup": per["flat"] / per["fused"],
            "roofline": roofline,
        }

    # Server strategies head to head: the registry's round-cadence
    # entries on the identical config and wire. PVI/FedEP ship
    # natural-parameter site deltas over the same flat (J, P) gather, so
    # bytes/round must match SFVI-Avg exactly; ELBO and calibrated time
    # are reported for visibility (not gated — the strategies optimize
    # different local objectives, so their trajectories diverge by
    # design; check_perf.py only gates the ``scenarios`` block).
    strategy_compare = {}
    for strat in ("sfvi_avg", "pvi", "fed_ep"):
        exp = staged_experiment(
            cfg["model"], bundle, scenario=Scenario(algorithm=strat),
            num_silos=cfg["silos"], rounds=9,
            local_steps=cfg["local_steps"], lr=cfg["lr"], seed=cfg["seed"],
            model_kwargs=cfg["model_kwargs"], wire="flat")
        exp.run(1)  # compile
        ratios = []
        while exp.remaining_rounds:
            tick = _yardstick()
            t0 = time.perf_counter()
            exp.run(1)
            ratios.append((time.perf_counter() - t0) / tick)
            yardsticks.append(tick)
        strategy_compare[strat] = {
            "elbo": float(exp.history["elbo"][-1]),
            "bytes_per_round": float(exp.comm.per_round),
            "calibrated_round": statistics.median(ratios),
        }

    # Posterior-serving row (bench_serving.federated_posterior_row):
    # trains + checkpoints a small CHURN run, restores the q(Z_L|Z_G)
    # endpoint and times batched query serving. Lands in ``scenarios``
    # so check_perf.py gates its ELBO (training determinism), refresh
    # bytes and calibrated batch latency like every other row; the
    # ungated queries_per_s / samples_per_s extras ride along for
    # visibility.
    from benchmarks.bench_serving import federated_posterior_row
    scenarios["serving(posterior)"] = federated_posterior_row(_yardstick)

    # 1-D vs 2-D mesh scaling (subprocess, 4 forced host devices): both
    # rows land in ``scenarios`` so check_perf.py gates their bytes,
    # ELBO and calibrated time like every other row.
    mesh_rows = _mesh_probe()
    scenarios.update(mesh_rows)
    (l1, _), (l2, _) = _MESH_PROBE_MESHES
    r1 = mesh_rows[f"SFVI [mesh {l1}]"]["calibrated_round"]
    r2 = mesh_rows[f"SFVI [mesh {l2}]"]["calibrated_round"]
    print(f"\nmesh scaling ({_MESH_PROBE_DEVICES} forced host devices): "
          f"{l1} {r1:.3f} vs {l2} {r2:.3f} calibrated s/round "
          f"(x{r1 / r2:.2f}); ELBO bit-identical")

    result = {
        "benchmark": "bench_federated-smoke",
        "config": cfg,
        "calibration_s": statistics.median(yardsticks),
        "scenarios": scenarios,
        "wire_compare": wire_compare,
        "strategy_compare": strategy_compare,
    }
    rows = [{"Scenario": name, **{k: (round(v, 4) if isinstance(v, float)
                                      else v) for k, v in r.items()}}
            for name, r in scenarios.items()]
    print_table(
        f"bench-smoke (toy, J={cfg['silos']}, {cfg['rounds']} rounds; "
        f"calibration {result['calibration_s']:.3f}s)",
        rows, ["Scenario", "elbo", "bytes_per_round", "s_per_round",
               "calibrated_round", "compile_s", "sim_seconds", "epsilon"],
    )
    print_table(
        "wire layout: fused Pallas vs flat (J, P) vs legacy per-leaf "
        "(calibrated s/round; MB = bytes accessed per compiled round)",
        [{"Scenario": name,
          "wire=fused": round(r["fused"], 4),
          "wire=flat": round(r["flat"], 4),
          "wire=legacy": round(r["legacy"], 4),
          "fused speedup": f"x{r['fused_speedup']:.2f}",
          "flat speedup": f"x{r['flat_speedup']:.2f}",
          "fused MB": round(r["roofline"]["fused"]["bytes_accessed"] / 1e6, 2),
          "flat MB": round(r["roofline"]["flat"]["bytes_accessed"] / 1e6, 2)}
         for name, r in wire_compare.items()],
        ["Scenario", "wire=fused", "wire=flat", "wire=legacy",
         "fused speedup", "flat speedup", "fused MB", "flat MB"],
    )
    print_table(
        "server strategies head to head (round cadence, wire=flat; "
        "identical bytes/round by construction)",
        [{"Strategy": name,
          "elbo": round(r["elbo"], 2),
          "bytes/round": round(r["bytes_per_round"], 0),
          "calibrated s/round": round(r["calibrated_round"], 4)}
         for name, r in strategy_compare.items()],
        ["Strategy", "elbo", "bytes/round", "calibrated s/round"],
    )
    if json_path:
        with open(json_path, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        print(f"\nwrote {json_path}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="bench_federated",
        description="Federated runtime scenario benchmark")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed config for the CI perf gate")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="write machine-readable results to FILE")
    ap.add_argument("--full", action="store_true",
                    help="non-quick sizes for the hier_bnn tables")
    ap.add_argument("--mesh-probe", action="store_true",
                    help=argparse.SUPPRESS)  # internal: smoke's subprocess
    args = ap.parse_args(argv)
    if args.mesh_probe:
        print("MESHPROBE " + json.dumps(_mesh_probe_rows()))
        return 0
    if args.smoke:
        smoke(json_path=args.json)
        return 0
    run(quick=not args.full)
    privacy_utility_sweep(quick=not args.full)
    return 0


if __name__ == "__main__":
    sys.exit(main())
