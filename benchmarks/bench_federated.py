"""Federated-runtime scenario sweep (paper §3.2 + robustness scenarios).

Runs the hierarchical BNN through ``repro.federated.Server`` under the
scenario grid the runtime exposes — sync cadence (SFVI vs SFVI-Avg),
wire compression (int8), robust aggregation (trimmed mean) and partial
participation with stragglers — and reports final ELBO, test accuracy
and per-round communication. This is the communication-accounting
surface the acceptance claim of §3.2 reads from.
"""
from __future__ import annotations

import jax

from benchmarks.common import print_table
from repro.federated import (
    Int8Compressor,
    MeanAggregator,
    NoCompression,
    RoundScheduler,
    Server,
    TrimmedMeanAggregator,
)
from repro.models.paper.fixtures import bnn_posterior_accuracy, hier_bnn_federation
from repro.optim import adam

SCENARIOS = [
    # (name, algorithm, aggregator, compressor, scheduler-kwargs)
    ("SFVI", "sfvi", MeanAggregator(), NoCompression(), {}),
    ("SFVI-Avg", "sfvi_avg", MeanAggregator(), NoCompression(), {}),
    ("SFVI-Avg+int8", "sfvi_avg", MeanAggregator(), Int8Compressor(), {}),
    ("SFVI trimmed 50%part", "sfvi", TrimmedMeanAggregator(0.1), NoCompression(),
     {"participation": 0.5, "dropout": 0.1}),
]


def run(quick: bool = True, seed: int = 0) -> dict:
    J = 4 if quick else 8
    rounds, local = (6, 10) if quick else (20, 25)
    lr = 2e-2

    bnn, train, test = hier_bnn_federation(seed=seed, num_silos=J)

    rows, out = [], {}
    for name, algo, agg, comp, sched_kw in SCENARIOS:
        prob = bnn.problem
        srv = Server(
            prob, train, {}, prob.global_family.init(jax.random.PRNGKey(seed)),
            server_opt=adam(lr), local_opt=adam(lr),
            aggregator=agg, compressor=comp, seed=seed,
        )
        sched = RoundScheduler(J, seed=seed, **sched_kw)
        hist = srv.run(rounds, algorithm=algo, local_steps=local, scheduler=sched)
        acc, _ = bnn_posterior_accuracy(bnn, srv.eta_G, srv.eta_L, test)
        rows.append({
            "Scenario": name,
            "ELBO": round(hist["elbo"][-1], 0),
            "Acc %": round(100 * acc, 1),
            "KiB/round": round(srv.comm.per_round / 1024, 1),
            "Total MiB": round(srv.comm.total / 2**20, 2),
        })
        out[name] = rows[-1]

    print_table(
        f"Federated runtime scenarios (hier BNN, J={J}, "
        f"{rounds} rounds x {local} local steps)",
        rows, ["Scenario", "ELBO", "Acc %", "KiB/round", "Total MiB"],
    )
    sfvi, avg = out["SFVI"], out["SFVI-Avg"]
    assert avg["KiB/round"] < sfvi["KiB/round"], (
        "SFVI-Avg must ship strictly fewer bytes per round than SFVI")
    print(f"\nSFVI-Avg ships {sfvi['KiB/round']/avg['KiB/round']:.1f}x fewer "
          f"bytes/round than SFVI; int8 compression a further "
          f"{avg['KiB/round']/out['SFVI-Avg+int8']['KiB/round']:.1f}x.")
    return out


if __name__ == "__main__":
    run(quick=True)
