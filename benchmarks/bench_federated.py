"""Federated-runtime scenario sweep (paper §3.2 + robustness + privacy).

Runs the hierarchical BNN through the declarative experiment API
(``repro.federated.api``) under the scenario grid the runtime exposes —
sync cadence (SFVI vs SFVI-Avg), wire compression (int8), robust
aggregation (trimmed mean), partial participation with stragglers, and
differentially private rounds — and reports final ELBO, test accuracy,
per-round communication, per-round wall time and cumulative ε. Each row
is one :class:`ExperimentSpec` (the same object ``--sweep`` builds in the
CLI), so every benchmarked configuration is serializable and resumable.

``privacy_utility_sweep`` traces the ε↔utility frontier: one row per
noise multiplier, ε vs ELBO vs accuracy vs wire bytes.
"""
from __future__ import annotations

import math
import time

from benchmarks.common import print_table, staged_experiment
from repro.federated import Scenario
from repro.models.paper.fixtures import bnn_posterior_accuracy
from repro.models.paper.registry import get_model

# The same declarative Scenario the CLI's --sweep walks (scheduler.py);
# row labels come from Scenario.name.
SCENARIOS = [
    Scenario(algorithm="sfvi"),
    Scenario(algorithm="sfvi_avg"),
    Scenario(algorithm="sfvi_avg", compression="int8"),
    Scenario(algorithm="sfvi", aggregator="trimmed", trim_frac=0.1,
             participation=0.5, dropout=0.1),
    Scenario(algorithm="sfvi_avg", dp_noise=1.0),
    Scenario(algorithm="sfvi_avg", dp_noise=1.0, compression="int8",
             participation=0.5),
]


def _fit(bundle, sc: Scenario, *, J, rounds, local, lr, seed):
    exp = staged_experiment(
        "hier_bnn", bundle, scenario=sc, num_silos=J, rounds=rounds,
        local_steps=local, lr=lr, seed=seed)
    t0 = time.time()
    hist = exp.run()
    dt = time.time() - t0
    bnn, test = bundle.extras["bnn"], bundle.extras["test"]
    acc, _ = bnn_posterior_accuracy(bnn, exp.eta_G, exp.eta_L, test)
    eps = hist["epsilon"][-1] if "epsilon" in hist else math.inf
    return exp, hist, acc, eps, dt


def run(quick: bool = True, seed: int = 0) -> dict:
    J = 4 if quick else 8
    rounds, local = (6, 10) if quick else (20, 25)
    lr = 2e-2

    bundle = get_model("hier_bnn").build(seed, J)

    rows, out = [], {}
    for sc in SCENARIOS:
        exp, hist, acc, eps, dt = _fit(
            bundle, sc, J=J, rounds=rounds, local=local, lr=lr, seed=seed)
        rows.append({
            "Scenario": sc.name,
            "ELBO": round(hist["elbo"][-1], 0),
            "Acc %": round(100 * acc, 1),
            "eps": "inf" if eps == math.inf else round(eps, 2),
            "KiB/round": round(exp.comm.per_round / 1024, 1),
            "s/round": round(dt / rounds, 2),
            "Total MiB": round(exp.comm.total / 2**20, 2),
        })
        out[sc.name] = rows[-1]

    print_table(
        f"Federated runtime scenarios (hier BNN, J={J}, "
        f"{rounds} rounds x {local} local steps; DP at delta=1e-05)",
        rows, ["Scenario", "ELBO", "Acc %", "eps", "KiB/round", "s/round",
               "Total MiB"],
    )
    sfvi, avg = out["SFVI"], out["SFVI-Avg"]
    dp = out[Scenario(algorithm="sfvi_avg", dp_noise=1.0).name]
    int8 = out[Scenario(algorithm="sfvi_avg", compression="int8").name]
    assert avg["KiB/round"] < sfvi["KiB/round"], (
        "SFVI-Avg must ship strictly fewer bytes per round than SFVI")
    assert dp["eps"] != "inf", (
        "DP scenario must report a finite cumulative epsilon")
    print(f"\nSFVI-Avg ships {sfvi['KiB/round']/avg['KiB/round']:.1f}x fewer "
          f"bytes/round than SFVI; int8 compression a further "
          f"{avg['KiB/round']/int8['KiB/round']:.1f}x; "
          f"DP adds eps={dp['eps']} at identical wire cost.")
    return out


def privacy_utility_sweep(quick: bool = True, seed: int = 0,
                          noise_multipliers=(0.0, 0.1, 0.25, 0.5, 1.0)) -> list:
    """ε vs ELBO vs accuracy vs comm bytes, one row per noise multiplier."""
    J = 4 if quick else 8
    rounds, local = (6, 10) if quick else (20, 25)
    lr = 2e-2
    bundle = get_model("hier_bnn").build(seed, J)

    rows = []
    for z in noise_multipliers:
        sc = Scenario(algorithm="sfvi_avg", dp_noise=z)
        exp, hist, acc, eps, dt = _fit(
            bundle, sc, J=J, rounds=rounds, local=local, lr=lr, seed=seed)
        rows.append({
            "z": z,
            "eps": "inf" if eps == math.inf else round(eps, 2),
            "ELBO": round(hist["elbo"][-1], 0),
            "Acc %": round(100 * acc, 1),
            "KiB/round": round(exp.comm.per_round / 1024, 1),
            "s/round": round(dt / rounds, 2),
        })

    print_table(
        f"Privacy-utility frontier (SFVI-Avg, hier BNN, J={J}, "
        f"{rounds} rounds x {local} local steps, delta=1e-5)",
        rows, ["z", "eps", "ELBO", "Acc %", "KiB/round", "s/round"],
    )
    return rows


if __name__ == "__main__":
    run(quick=True)
    privacy_utility_sweep(quick=True)
