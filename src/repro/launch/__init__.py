"""Launch layer: production mesh, sharding rules, SPMD train/serve steps,
multi-pod dry-run, and roofline extraction."""
