import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: measure one (arch, shape) pair under a named
set of PerfConfig levers and append the result to a JSON log.

    PYTHONPATH=src python -m repro.launch.perf --arch qwen3-32b \
        --shape train_4k --levers masked_nll,zero_opt \
        --out benchmarks/data/perf_iterations.json

Each record carries the lever set, the three roofline terms, peak HBM, and
the collective breakdown — EXPERIMENTS.md §Perf is written from this log.
"""
import argparse
import dataclasses
import json
import sys
import time

import jax

from repro.configs import INPUT_SHAPES, get_config
from repro.launch import roofline as R
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.launch.specs import build_lowering
from repro.models.backbone.config import PerfConfig

LEVERS = ("masked_nll", "pad_vocab", "zero_opt", "act_shard", "microbatch", "pad_heads")


def _parse_levers(levers: list) -> dict:
    kw = {}
    for lv in levers:
        if "=" in lv:
            k, v = lv.split("=")
            kw[k] = int(v)
        else:
            kw[lv] = True
    return kw


def measure(arch: str, shape_name: str, levers: list) -> dict:
    cfg = get_config(arch)
    cfg = dataclasses.replace(cfg, perf=PerfConfig(**_parse_levers(levers)))
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh()
    t0 = time.time()
    with use_mesh(mesh):
        fn, args = build_lowering(cfg, shape, mesh)
        compiled = jax.jit(fn).lower(*args).compile()
        roof = R.analyze(compiled, arch, shape_name, "single_pod", mesh.size,
                         model_flops=R.model_flops(cfg, shape))
        n_units = cfg.num_layers // R._unit_period(cfg)
        ms = []
        for k in (1, 2):
            cfg_k = R.analysis_variant(cfg, k)
            fnk, argsk = build_lowering(cfg_k, shape, mesh)
            ms.append(R._extract(jax.jit(fnk).lower(*argsk).compile()))
        ext = R.extrapolate(ms[0], ms[1], n_units)
        # The microbatch accumulation loop is itself a lax.scan whose body
        # XLA cost-counts once; scale by k (the optimizer epilogue outside
        # the loop is negligible, and the per-microbatch gradient
        # all-reduce genuinely runs k times).
        k_mb = max(1, cfg.perf.microbatch)
        roof.flops_per_chip = ext["flops"] * k_mb
        roof.bytes_per_chip = ext["bytes"] * k_mb
        roof.coll_bytes_per_chip = ext["coll"] * k_mb
        roof.coll_breakdown = {kk: v * k_mb for kk, v in ext["coll_breakdown"].items()}
    rec = roof.to_dict()
    rec.update(levers=sorted(levers), wall_s=round(time.time() - t0, 1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--levers", default="", help="comma-separated PerfConfig fields")
    ap.add_argument("--out", default="benchmarks/data/perf_iterations.json")
    args = ap.parse_args(argv)
    levers = [lv for lv in args.levers.split(",") if lv]
    for lv in levers:
        assert lv.split("=")[0] in LEVERS, lv
    rec = measure(args.arch, args.shape, levers)
    rows = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            rows = json.load(f)
    rows.append(rec)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    print(json.dumps({k: rec[k] for k in (
        "arch", "shape", "levers", "t_compute", "t_memory", "t_collective",
        "bottleneck", "useful_flops_ratio")}, indent=1))
    print(f"peak HBM {rec['peak_bytes_per_chip']/2**30:.1f} GiB/chip; "
          f"coll {rec['coll_bytes_per_chip']:.3g} B/chip")
    return 0


if __name__ == "__main__":
    sys.exit(main())
