"""ShapeDtypeStruct stand-ins for every (arch x input-shape) combination.

Nothing here allocates device memory: parameters and optimizer state come
from ``jax.eval_shape`` over the real initializers, inputs are structs,
and shardings are attached directly to the structs so ``jit(...).lower``
sees the production layout.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch import steps as S
from repro.launch.mesh import data_axes, data_world
from repro.launch.shardings import (
    batch_spec,
    opt_state_shardings,
    param_shardings,
    path_names,
)
from repro.models.backbone import transformer as T
from repro.models.backbone.config import ArchConfig, InputShape

PyTree = Any


def num_silos_for(shape: InputShape, mesh) -> int:
    """Silos ride the data axes; a batch smaller than the data world means
    fewer active silos (long_500k: one)."""
    return math.gcd(shape.global_batch, data_world(mesh))


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _with_shardings(mesh, struct_tree, sharding_tree):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        struct_tree,
        sharding_tree,
    )


def _batch_structs(cfg: ArchConfig, shape: InputShape, mesh, with_labels: bool):
    dp = data_axes(mesh)
    B, Sq = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {
        "tokens": _sds((B, Sq), jnp.int32, mesh, batch_spec(mesh, (B, Sq), dp))
    }
    if with_labels:
        out["labels"] = _sds((B, Sq), jnp.int32, mesh, batch_spec(mesh, (B, Sq), dp))
    if cfg.is_encoder_decoder:
        fs = (B, cfg.encoder_seq_len, cfg.d_model)
        out["frames"] = _sds(fs, jnp.dtype(cfg.dtype), mesh, batch_spec(mesh, fs, dp))
    if cfg.num_vision_tokens:
        vs = (B, cfg.num_vision_tokens, cfg.d_model)
        out["vision"] = _sds(vs, jnp.dtype(cfg.dtype), mesh, batch_spec(mesh, vs, dp))
    return out


# ---------------------------------------------------------------------------
# Cache sharding rules (decode shapes)
# ---------------------------------------------------------------------------

def _cache_spec(mesh, name: str, leaf, dp) -> P:
    m = mesh.shape.get("model", 1)
    dpsz = data_world(mesh)
    nd = leaf.ndim
    spec = [None] * nd

    def div(i):
        return leaf.shape[i] % m == 0 and leaf.shape[i] >= m

    # Leading stacked-unit axis present for unit caches: detect via name tag.
    if name in ("pos", "t"):
        return P()
    # batch axis: first axis unless leaf is stacked (then second).
    b_ax = 1 if name.startswith("stacked:") else 0
    if nd > b_ax and leaf.shape[b_ax] % dpsz == 0 and leaf.shape[b_ax] >= dpsz:
        spec[b_ax] = dp
    base = name.split(":")[-1]
    if base in ("k", "v") and nd >= b_ax + 4:
        kv_ax, hd_ax = nd - 2, nd - 1
        if div(kv_ax):
            spec[kv_ax] = "model"
        elif div(hd_ax):
            spec[hd_ax] = "model"
    elif base == "ssm" and nd >= b_ax + 4:
        if div(b_ax + 1):
            spec[b_ax + 1] = "model"  # heads
    elif base == "conv" and nd >= b_ax + 3:
        if div(nd - 1):
            spec[nd - 1] = "model"
    elif base in ("state", "c", "n", "h", "m") and nd >= b_ax + 3:
        if div(nd - 2) and base == "state":
            spec[nd - 2] = "model"
        elif div(nd - 1) and base != "state":
            spec[nd - 1] = "model"
    elif base == "memory" and nd >= 2:
        pass  # batch-only
    return P(*spec)


def cache_shardings(mesh, cache_struct: PyTree) -> PyTree:
    dp = data_axes(mesh)

    def rule(path, leaf):
        names = path_names(path)
        stacked = "units" in names
        name = (("stacked:" if stacked else "") + (names[-1] if names else ""))
        return NamedSharding(mesh, _cache_spec(mesh, name, leaf, dp))

    return jax.tree_util.tree_map_with_path(rule, cache_struct)


# ---------------------------------------------------------------------------
# Per-(arch, shape) lowering spec
# ---------------------------------------------------------------------------

def build_lowering(cfg: ArchConfig, shape: InputShape, mesh,
                   lr: float = 3e-4) -> Tuple[Any, tuple]:
    """Returns (step_fn, arg_structs) ready for jit(...).lower(*args)."""
    if shape.kind == "decode" and shape.name == "long_500k":
        cfg = cfg.long_context_variant()
    silos = num_silos_for(shape, mesh)

    # repro-lint: allow[R1] — shape-only lowering spec: the key feeds eval_shape and is never executed
    key = jax.random.PRNGKey(0)
    uneven = False  # vocab lever realized via padding (cfg.padded_vocab)
    theta_struct = jax.eval_shape(lambda k: T.init_params(k, cfg), key)
    theta_sh = param_shardings(mesh, theta_struct, uneven_vocab=uneven)
    theta = _with_shardings(mesh, theta_struct, theta_sh)

    eG_struct = jax.eval_shape(lambda k: S.init_eta_G(k, cfg), key)
    eG = _with_shardings(
        mesh, eG_struct, jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), eG_struct)
    )
    dp = data_axes(mesh)
    eL_struct = jax.eval_shape(lambda k: S.init_eta_L(k, cfg, silos), key)
    eL = _with_shardings(
        mesh, eL_struct, jax.tree_util.tree_map(
            lambda leaf: NamedSharding(
                mesh, P(dp, *([None] * (leaf.ndim - 1)))
                if leaf.shape[0] % data_world(mesh) == 0 and leaf.shape[0] >= data_world(mesh)
                else P()),
            eL_struct),
    )

    if shape.kind == "train":
        from repro.optim.adam import adam

        opt = adam(lr)
        batch = _batch_structs(cfg, shape, mesh, with_labels=True)
        opt_t_struct = jax.eval_shape(opt.init, theta_struct)
        if cfg.perf.zero_opt:
            opt_t_sh = opt_state_shardings(mesh, opt_t_struct, dp,
                                           uneven_vocab=uneven)
        else:
            opt_t_sh = param_shardings(mesh, opt_t_struct, uneven_vocab=uneven)
        opt_t = _with_shardings(mesh, opt_t_struct, opt_t_sh)
        opt_g = _with_shardings(
            mesh, jax.eval_shape(opt.init, eG_struct),
            jax.tree_util.tree_map(
                lambda _: NamedSharding(mesh, P()), jax.eval_shape(opt.init, eG_struct)),
        )
        eL_opt_struct = jax.eval_shape(opt.init, eL_struct)
        opt_l = _with_shardings(
            mesh, eL_opt_struct,
            jax.tree_util.tree_map(
                lambda leaf: NamedSharding(
                    mesh, P(dp, *([None] * (leaf.ndim - 1)))
                    if leaf.ndim >= 1 and leaf.shape[:1] == (silos,) and silos % data_world(mesh) == 0
                    else P()),
                eL_opt_struct),
        )
        step_sds = _sds((), jnp.int32, mesh, P())
        state = S.TrainState(theta, eG, eL, opt_t, opt_g, opt_l, step_sds)
        seed = _sds((), jnp.int32, mesh, P())
        fn = S.make_train_step(cfg, silos, lr=lr)
        return fn, (state, batch, seed)

    if shape.kind == "prefill":
        batch = _batch_structs(cfg, shape, mesh, with_labels=False)
        fn = S.make_serve_prefill(cfg, silos, max_len=shape.seq_len)
        return fn, (theta, eG, eL, batch)

    # decode
    B = shape.global_batch
    cache_struct = jax.eval_shape(
        lambda th: T.init_cache(th, cfg, B, shape.seq_len), theta_struct
    )
    cache = _with_shardings(mesh, cache_struct, cache_shardings(mesh, cache_struct))
    tokens = _sds((B, 1), jnp.int32, mesh, batch_spec(mesh, (B, 1), dp))
    fn = S.make_serve_decode(cfg, silos)
    return fn, (theta, eG, eL, tokens, cache)


def build_avg_lowering(cfg: ArchConfig, shape: InputShape, mesh,
                       include_barycenter: bool, lr: float = 3e-4):
    """Lowering spec for the SFVI-Avg mesh step (per-silo eta_G carried on
    the data axes; barycenter statically in/excluded for the communication
    measurement)."""
    assert shape.kind == "train"
    from repro.optim.adam import adam

    silos = num_silos_for(shape, mesh)
    # repro-lint: allow[R1] — shape-only lowering spec: the key feeds eval_shape and is never executed
    key = jax.random.PRNGKey(0)
    dp = data_axes(mesh)
    theta_struct = jax.eval_shape(lambda k: T.init_params(k, cfg), key)
    theta = _with_shardings(mesh, theta_struct, param_shardings(mesh, theta_struct))

    def silo_sharded(tree):
        return _with_shardings(
            mesh, tree, jax.tree_util.tree_map(
                lambda leaf: NamedSharding(
                    mesh, P(dp, *([None] * (leaf.ndim - 1)))
                    if leaf.ndim >= 1
                    and leaf.shape[0] % data_world(mesh) == 0
                    and leaf.shape[0] >= data_world(mesh) else P()),
                tree))

    eG_struct = jax.eval_shape(lambda k: S.init_eta_G_silo(k, cfg, silos), key)
    eG = silo_sharded(eG_struct)
    eL_struct = jax.eval_shape(lambda k: S.init_eta_L(k, cfg, silos), key)
    eL = silo_sharded(eL_struct)
    opt = adam(lr)
    opt_t_struct = jax.eval_shape(opt.init, theta_struct)
    opt_t = _with_shardings(mesh, opt_t_struct, param_shardings(mesh, opt_t_struct))
    opt_g = silo_sharded(jax.eval_shape(opt.init, eG_struct))
    opt_l = silo_sharded(jax.eval_shape(opt.init, eL_struct))
    batch = _batch_structs(cfg, shape, mesh, with_labels=True)
    state = S.TrainState(theta, eG, eL, opt_t, opt_g, opt_l,
                         _sds((), jnp.int32, mesh, P()))
    seed = _sds((), jnp.int32, mesh, P())
    fn = S.make_train_step_avg(cfg, silos, avg_every=10, lr=lr,
                               include_barycenter=include_barycenter)
    return fn, (state, batch, seed)
