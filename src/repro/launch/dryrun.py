import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and extract roofline terms.

The two lines above MUST run before any other import — jax locks the
device count at first init. 512 host devices back both the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # full 40x2 matrix
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi_pod
    PYTHONPATH=src python -m repro.launch.dryrun --out results.json

Results (roofline terms, memory analysis, collective breakdown) append to
a JSON file consumed by benchmarks/bench_roofline.py and EXPERIMENTS.md.
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_NAMES, INPUT_SHAPES, get_config
from repro.launch import roofline as R
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.launch.specs import build_lowering


def should_skip(cfg, shape) -> str:
    if shape.name == "long_500k" and cfg.is_encoder_decoder:
        return ("enc-dec (whisper): no 500k-token decode use-case; "
                "see DESIGN.md §Arch-applicability")
    return ""


def run_one(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
            analyze: bool = True, optimized: bool = False):
    import dataclasses

    from repro.models.backbone.config import PerfConfig

    cfg = get_config(arch)
    if optimized:
        # The §Perf-validated production set (EXPERIMENTS.md §Perf
        # conclusions): masked_nll measured neutral, act_shard measured
        # HARMFUL under current XLA SPMD — both stay off.
        cfg = dataclasses.replace(cfg, perf=PerfConfig(
            pad_vocab=True, zero_opt=True, microbatch=4, pad_heads=16))
    shape = INPUT_SHAPES[shape_name]
    skip = should_skip(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi_pod" if multi_pod else "single_pod",
                "status": "skipped", "reason": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi_pod" if multi_pod else "single_pod"
    chips = mesh.size
    t0 = time.time()
    with use_mesh(mesh):
        # --- production compile: proves lowering; memory analysis ---------
        fn, args = build_lowering(cfg, shape, mesh)
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        if verbose:
            print(f"--- {arch} x {shape_name} on {mesh_name} ({chips} chips) ---")
            print(f"memory_analysis: {mem}")
        roof = R.analyze(
            compiled, arch, shape_name, mesh_name, chips,
            model_flops=R.model_flops(cfg, shape),
        )
        # --- analysis compiles: scan-aware flops/bytes/collectives --------
        if analyze:
            period = R._unit_period(cfg)
            n_units = cfg.num_layers // period
            ms = []
            for k in (1, 2):
                cfg_k = R.analysis_variant(cfg, k)
                fnk, argsk = build_lowering(cfg_k, shape, mesh)
                ck = jax.jit(fnk).lower(*argsk).compile()
                ms.append(R._extract(ck))
            ext = R.extrapolate(ms[0], ms[1], n_units)
            # Microbatch accumulation is a lax.scan: scale by k (see
            # launch/perf.py — the same scan-body-counted-once caveat).
            k_mb = max(1, cfg.perf.microbatch)
            roof.flops_per_chip = ext["flops"] * k_mb
            roof.bytes_per_chip = ext["bytes"] * k_mb
            roof.coll_bytes_per_chip = ext["coll"] * k_mb
            roof.coll_breakdown = {kk: v * k_mb
                                   for kk, v in ext["coll_breakdown"].items()}
    rec = roof.to_dict()
    rec.update(status="ok", t_lower_s=round(t_lower, 1),
               t_compile_s=round(t_compile, 1),
               analysis="2pt-extrapolated" if analyze else "scan-undercount")
    if verbose:
        print(f"t_compute={roof.t_compute:.3e}s t_memory={roof.t_memory:.3e}s "
              f"t_collective={roof.t_collective:.3e}s -> {roof.bottleneck}; "
              f"useful_flops_ratio={roof.useful_flops_ratio:.2f} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="single_pod",
                    choices=["single_pod", "multi_pod", "both"])
    ap.add_argument("--out", default=None, help="append results to this JSON file")
    ap.add_argument("--optimized", action="store_true",
                    help="enable all §Perf levers (beyond-paper optimized run)")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ARCH_NAMES)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = (["single_pod", "multi_pod"] if args.mesh == "both" else [args.mesh])

    results, failures = [], []
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                try:
                    # Roofline analysis compiles are single-pod only
                    # (the roofline table is single-pod per EXPERIMENTS.md).
                    rec = run_one(arch, shape_name, mesh_name == "multi_pod",
                                  analyze=(mesh_name == "single_pod"),
                                  optimized=args.optimized)
                    results.append(rec)
                except Exception as e:  # noqa: BLE001 — report, continue
                    traceback.print_exc()
                    failures.append((arch, shape_name, mesh_name, str(e)))
                    results.append({
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "status": "FAILED", "error": str(e)[:500],
                    })
    if args.out:
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        # Newest record wins per (arch, shape, mesh).
        key = lambda r: (r["arch"], r["shape"], r["mesh"])  # noqa: E731
        merged = {key(r): r for r in existing}
        merged.update({key(r): r for r in results})
        with open(args.out, "w") as f:
            json.dump(list(merged.values()), f, indent=1)
        print(f"wrote {len(merged)} records to {args.out}")
    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skipped")
    print(f"\n=== dry-run summary: {ok} ok, {sk} skipped, {len(failures)} failed ===")
    for f_ in failures:
        print("FAILED:", f_)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
