"""Sharding rules: param-tree paths -> PartitionSpec.

Megatron-style tensor parallelism on the ``model`` axis:
  * column-parallel (shard output features): wq/wk/wv, MLP up/gate,
    mixer input projections, lm_head (vocab out);
  * row-parallel (shard input features): wo, MLP down, mixer out
    projections;
  * expert-parallel: MoE expert stacks shard their leading E axis;
  * everything small (norms, gates, biases, routers) is replicated.

Stacked layers (under "units"/"encoder") carry one leading n_units axis,
which is never sharded. Divisibility is checked per leaf: if a dim does
not divide the axis size, the rule degrades to replication for that dim
(GSPMD requires even shards).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any


def path_names(path) -> list:
    """Key names along a ``tree_map_with_path`` path.

    Param/optimizer trees are nested dicts, so the named entries are
    ``DictKey`` (plus ``FlattenedIndexKey`` after partial flattens);
    sequence positions carry no name and are skipped.
    """
    return [p.key for p in path
            if isinstance(p, (jax.tree_util.DictKey,
                              jax.tree_util.FlattenedIndexKey))]


# Column-parallel leaf names (shard LAST dim over 'model').
_COL = {
    "wq", "wk", "wv", "w_gate", "w_up", "w_ff1", "in_proj", "w_in",
    "lm_head", "conv_w",
}
# Row-parallel leaf names (shard FIRST non-stack dim over 'model').
_ROW = {"wo", "w_down", "out_proj", "w_ff2"}
# Embedding table: shard vocab (first dim).
_VOCAB_ROW = {"tok"}


def _num_stack_dims(path_names) -> int:
    return 1 if ("units" in path_names or "encoder" in path_names) else 0


def param_spec(path, leaf, model_size: int, uneven_vocab: bool = False) -> P:
    names = path_names(path)
    name = names[-1] if names else ""
    stack = _num_stack_dims(names)
    ndim = leaf.ndim
    body = ndim - stack

    def ok(dim_size):
        return dim_size % model_size == 0 and dim_size >= model_size

    # (uneven_vocab retained for API stability; §Perf lever 2 is realized
    # by PADDING the vocab — see ArchConfig.padded_vocab — so the padded
    # dims divide evenly and the standard rule applies.)
    ok_vocab = ok

    spec = [None] * ndim
    is_moe = "moe" in names
    if is_moe and name in ("w_gate", "w_up", "w_down") and body == 3:
        # (E, d, f) expert-parallel over the leading expert axis.
        if ok(leaf.shape[stack]):
            spec[stack] = "model"
        return P(*spec)
    if name in _COL and body >= 2:
        check = ok_vocab if name == "lm_head" else ok
        if check(leaf.shape[-1]):
            spec[-1] = "model"
        return P(*spec)
    if name in _ROW and body >= 2:
        if ok(leaf.shape[stack]):
            spec[stack] = "model"
        return P(*spec)
    if name in _VOCAB_ROW and body == 2:
        if ok_vocab(leaf.shape[stack]):
            spec[stack] = "model"
        return P(*spec)
    return P()  # replicate


def param_shardings(mesh, params: PyTree, uneven_vocab: bool = False) -> PyTree:
    m = mesh.shape.get("model", 1)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf, m, uneven_vocab)), params
    )


def opt_state_shardings(mesh, opt_state: PyTree, dp: tuple,
                        uneven_vocab: bool = False) -> PyTree:
    """ZeRO-style optimizer-state sharding (§Perf lever 3): Adam moments
    mirror the param sharding AND additionally shard their leading
    stacked-unit axis across the data axes. Adam is elementwise, so this
    costs no collectives in the update itself; it cuts the f32 m/v
    residency by the data-world factor."""
    m = mesh.shape.get("model", 1)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def rule(path, leaf):
        spec = list(param_spec(path, leaf, m, uneven_vocab))
        names = path_names(path)
        if ("units" in names and leaf.ndim >= 1 and spec and spec[0] is None
                and leaf.shape[0] % dp_size == 0 and leaf.shape[0] >= dp_size):
            spec[0] = dp
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(rule, opt_state)


def batch_spec(mesh, shape, dp: tuple) -> P:
    """Shard the leading batch axis over the data axes when divisible."""
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if shape and shape[0] % dp_size == 0 and shape[0] >= dp_size:
        return P(dp, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def batch_shardings(mesh, tree: PyTree, dp: tuple) -> PyTree:
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, batch_spec(mesh, leaf.shape, dp)), tree
    )


def replicated(mesh, tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)


def eta_local_shardings(mesh, tree: PyTree, dp: tuple) -> PyTree:
    """Per-silo variational parameters: leading J axis over the data axes —
    each silo's eta_L lives only on that silo's devices (privacy by
    placement)."""
    return jax.tree_util.tree_map(
        lambda leaf: NamedSharding(mesh, P(dp, *([None] * (leaf.ndim - 1)))), tree
    )
