"""Batched serving driver: posterior-mean model, prefill + decode loop.

Serving uses the SFVI posterior means (θ, E[Z_G], E[Z_Lj]) — every silo
keeps its personal head adapter, so one batch can serve requests from
different silos simultaneously (requests are grouped by silo along the
batch axis, exactly how the decode shapes shard on the mesh).

    PYTHONPATH=src python -m repro.launch.serve_backbone --arch qwen3-4b \
        --batch 8 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import steps as S
from repro.models.backbone import transformer as T


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--silos", type=int, default=4)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    # repro-lint: allow[R1] — demo CLI entry point roots its own init stream
    key = jax.random.PRNGKey(0)
    state, _ = S.init_train_state(key, cfg, args.silos)
    max_len = args.prompt_len + args.gen + cfg.num_vision_tokens

    prefill = jax.jit(S.make_serve_prefill(cfg, args.silos, max_len=max_len))
    decode = jax.jit(S.make_serve_decode(cfg, args.silos))

    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    if cfg.num_vision_tokens:
        batch["vision"] = jax.random.normal(
            key, (args.batch, cfg.num_vision_tokens, cfg.d_model), jnp.float32)

    t0 = time.time()
    logits, cache = prefill(state.theta, state.eta_G, state.eta_L, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}: "
          f"prefill {t_prefill*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")

    def sample(logits, k):
        if args.temperature <= 0:
            return jnp.argmax(logits[:, -1], axis=-1)
        return jax.random.categorical(k, logits[:, -1] / args.temperature)

    tok = sample(logits, key)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(state.theta, state.eta_G, state.eta_L,
                               tok[:, None], cache)
        tok = sample(logits, jax.random.fold_in(key, i))
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    print(f"decode {args.gen-1} steps: {t_dec*1e3:.1f} ms "
          f"({args.batch*(args.gen-1)/max(t_dec,1e-9):.0f} tok/s)")
    gen = jnp.stack(out, axis=1)
    print("generated token ids (first request):", gen[0][:16].tolist())
    return gen


if __name__ == "__main__":
    main()
