"""Roofline-term extraction from a compiled (dry-run) artifact.

    compute term    = HLO_FLOPs      / (chips * PEAK_FLOPS_BF16)
    memory term     = HLO_bytes      / (chips * HBM_BW)
    collective term = collective_bytes / (chips * ICI_BW)

``cost_analysis()`` reports per-partition (per-device) FLOPs/bytes for an
SPMD executable, so the per-chip terms divide by peak directly; the
"chips" division is kept explicit for the global view. Collective bytes
are NOT in cost_analysis — we parse the optimized HLO and sum the result
sizes of every collective op (a standard proxy: all-reduce moves ~2x its
operand over the ring, all-gather/reduce-scatter ~1x the full result;
we apply per-op multipliers below).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

# op -> (regex fragment, ring-traffic multiplier per byte of result)
_COLLECTIVES = {
    "all-reduce": 2.0,  # reduce-scatter + all-gather equivalent
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Ring-traffic bytes per collective kind from optimized HLO text."""
    out = {k: 0.0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str) * _COLLECTIVES[kind]
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, float]
    peak_bytes_per_chip: float  # memory_analysis: peak HBM
    model_flops: float  # 6*N*D (active) — analytic useful work, GLOBAL

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_breakdown": self.coll_breakdown,
            "peak_bytes_per_chip": self.peak_bytes_per_chip,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analyze(compiled, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float) -> Roofline:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax < 0.5 wraps it per-program
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    peak = 0.0
    if mem is not None:
        peak = float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        )
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, bytes_per_chip=byts,
        coll_bytes_per_chip=sum(coll.values()),
        coll_breakdown=coll, peak_bytes_per_chip=peak,
        model_flops=model_flops,
    )


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS: 6 * N_active * D_tokens (decode: D = batch tokens)
# ---------------------------------------------------------------------------

def active_param_count(cfg) -> int:
    """Parameter count with MoE experts counted at top-k/E (active share)."""
    d, V = cfg.d_model, cfg.vocab_size
    hd = cfg.head_dim_
    n = V * d  # embed
    if not cfg.tie_embeddings:
        n += d * V
    per_attn = d * (cfg.num_heads * hd) + 2 * d * (cfg.num_kv_heads * hd) + (
        cfg.num_heads * hd) * d
    if cfg.is_moe:
        per_ffn = 3 * d * (cfg.d_expert or cfg.d_ff) * cfg.num_experts_per_tok
    else:
        per_ffn = 3 * d * cfg.d_ff if cfg.d_ff else 0
    d_inner = cfg.ssm_expand * d
    N = cfg.ssm_state
    per_mamba = d * (2 * d_inner + 2 * N + (d_inner // max(cfg.ssm_head_dim, 1))) + d_inner * d
    per_mlstm = d * 4 * d + (2 * d) * (2 * d) * 3 + 2 * d * d
    per_slstm = d * 4 * d + d * (4 * d // 3) * 2
    for i in range(cfg.num_layers):
        kind = cfg.block_kind(i)
        if kind == "attn":
            n += per_attn + per_ffn
        elif kind == "mamba2":
            n += per_mamba
        elif kind == "mlstm":
            n += per_mlstm
        elif kind == "slstm":
            n += per_slstm
    if cfg.is_encoder_decoder:
        n += cfg.num_encoder_layers * (per_attn + per_ffn)
        n += cfg.num_layers * 2 * d * (cfg.num_kv_heads * hd + cfg.num_heads * hd // 2)
    return n


def model_flops(cfg, shape) -> float:
    """Global useful FLOPs for one step: 6ND train, 2ND forward-only."""
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


# ---------------------------------------------------------------------------
# Scan-aware measurement: XLA cost_analysis counts scan bodies ONCE, so the
# production (scan-over-units) compile undercounts per-layer work. We compile
# two ANALYSIS variants (units unrolled, attention unblocked) at k=1 and k=2
# units and extrapolate linearly to the full depth:
#     f(n_units) = f1 + (n_units - 1) * (f2 - f1)
# which is exact for homogeneous unit stacks (it captures both per-layer
# compute/collectives and depth-scaling gradient reductions). Remaining
# in-scan work (the GLA cross-chunk state scan, the sLSTM time scan) is
# documented as a small undercount in EXPERIMENTS.md.
# ---------------------------------------------------------------------------

def _unit_period(cfg) -> int:
    return cfg.hybrid_attn_period or cfg.slstm_period or 1


def analysis_variant(cfg, k_units: int):
    import dataclasses

    period = _unit_period(cfg)
    tail = cfg.num_layers % period
    return dataclasses.replace(
        cfg, num_layers=k_units * period + tail, analysis_mode=True,
        name=f"{cfg.name}-analysis{k_units}",
    )


def _extract(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax < 0.5 wraps it per-program
        ca = ca[0] if ca else {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": sum(coll.values()),
        "coll_breakdown": coll,
    }


def extrapolate(m1: Dict, m2: Dict, n_units: int) -> Dict[str, float]:
    out = {}
    for k in ("flops", "bytes", "coll"):
        out[k] = m1[k] + (n_units - 1) * (m2[k] - m1[k])
    out["coll_breakdown"] = {
        kk: m1["coll_breakdown"][kk]
        + (n_units - 1) * (m2["coll_breakdown"][kk] - m1["coll_breakdown"][kk])
        for kk in m1["coll_breakdown"]
    }
    # Guard against tiny negative extrapolations from fusion differences.
    for k in ("flops", "bytes", "coll"):
        out[k] = max(out[k], 0.0)
    return out


def save_results(path: str, rows) -> None:
    with open(path, "w") as f:
        json.dump([r.to_dict() for r in rows], f, indent=1)


def load_results(path: str):
    with open(path) as f:
        return json.load(f)
