"""End-to-end SFVI training driver for the assigned LLM architectures.

On the production mesh this is the SPMD path (silos = data-axis slices,
server = psum; DESIGN.md §5.1). On CPU it runs the same jitted step on one
device with the reduced config — the math is identical (SFVI's partition
invariance), only the mesh differs.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b \
        --full --steps 200          # full config (needs the real mesh)
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --algo avg \
        --avg-every 10              # SFVI-Avg schedule
"""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import make_token_stream
from repro.checkpoint.io import CheckpointManager
from repro.federated import (CommMeter, ExperimentSpec, MeshSpec, ModelSpec,
                             NoCompression, OptimizerSpec, RuntimeSpec,
                             Scenario, run_rounds)
from repro.launch import steps as S
from repro.launch.mesh import build_mesh, use_mesh
from repro.models.backbone import transformer as T


def make_batches(key, cfg, batch: int, seq: int, steps: int):
    """Synthetic token stream (Zipf unigram; offline container has no real
    corpora — DESIGN.md §7) pre-chunked into (steps, batch, seq)."""
    toks = make_token_stream(key, steps * batch * (seq + 1), cfg.vocab_size)
    toks = np.asarray(toks[: steps * batch * (seq + 1)]).reshape(
        steps, batch, seq + 1
    )
    return toks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--silos", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--algo", choices=["sfvi", "avg"], default="sfvi")
    ap.add_argument("--avg-every", type=int, default=10)
    ap.add_argument("--full", action="store_true",
                    help="use the FULL config (production mesh required)")
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="account the sync schedule as (eps, delta)-DP with "
                         "this Gaussian noise multiplier (0 = off). The "
                         "mechanism itself rides repro.federated.Server "
                         "(docs/privacy.md); the SPMD psum path reports "
                         "the equivalent accounting for its exchange "
                         "cadence.")
    ap.add_argument("--dp-clip", type=float, default=1.0)
    ap.add_argument("--dp-delta", type=float, default=1e-5)
    ap.add_argument("--mesh", default="", metavar="SPEC",
                    help="federated mesh topology ('silo=N[,model=N]'), "
                         "recorded on the run's provenance spec "
                         "(spec.runtime.mesh) and activated for the jitted "
                         "step via launch.mesh.build_mesh; empty = the "
                         "default single-process device set")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--dump-spec", action="store_true",
                    help="print this run's declarative spec as JSON and "
                         "exit. The SPMD path executes outside "
                         "federated.api.build (its server is a psum, not "
                         "a Server object), so the spec is the run's "
                         "provenance record: the same scenario fields the "
                         "compiled runtime would be built from.")
    args = ap.parse_args(argv)

    # Declarative record of the run: the SPMD cadence expressed in the
    # same (scenario, optimizer, seed) vocabulary as repro.federated.api.
    scenario = Scenario(
        algorithm="sfvi" if args.algo == "sfvi" else "sfvi_avg",
        dp_noise=args.dp_noise, dp_clip=args.dp_clip, dp_delta=args.dp_delta,
    )
    spec = ExperimentSpec(
        model=ModelSpec(f"llm/{args.arch}",
                        kwargs={"batch": args.batch, "seq": args.seq,
                                "full": bool(args.full)}),
        scenario=scenario,
        num_silos=args.silos,
        rounds=args.steps,
        local_steps=1 if args.algo == "sfvi" else args.avg_every,
        server_opt=OptimizerSpec("adam", args.lr),
        seed=0,
        runtime=RuntimeSpec(mesh=MeshSpec.parse(args.mesh)),
    )
    if args.dump_spec:
        print(spec.to_json())
        return None

    # The declared topology is also the executed one: the jitted step
    # lowers against the spec's mesh (one factory, launch.mesh.build_mesh,
    # for the CLI, api.build and the benchmarks alike).
    mesh_ctx = (use_mesh(build_mesh(spec.runtime.mesh,
                                    num_silos=args.silos))
                if args.mesh else contextlib.nullcontext())

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    assert args.batch % args.silos == 0
    # repro-lint: allow[R1] — demo CLI entry point roots its own init stream
    key = jax.random.PRNGKey(0)

    state, _ = S.init_train_state(key, cfg, args.silos, lr=args.lr)
    if args.algo == "avg":
        state = S.TrainState(
            theta=state.theta,
            eta_G=S.init_eta_G_silo(key, cfg, args.silos),
            eta_L=state.eta_L,
            opt_theta=state.opt_theta,
            opt_eta_G=None, opt_eta_L=state.opt_eta_L,
            step=state.step,
        )
        from repro.optim.adam import adam
        opt = adam(args.lr)
        state = S.TrainState(state.theta, state.eta_G, state.eta_L,
                             state.opt_theta, opt.init(state.eta_G),
                             state.opt_eta_L, state.step)
        step_fn = S.make_train_step_avg(cfg, args.silos, args.avg_every,
                                        lr=args.lr, remat=False)
    else:
        step_fn = S.make_train_step(cfg, args.silos, lr=args.lr, remat=False)
    step_fn = jax.jit(step_fn)

    # repro-lint: allow[R1] — demo CLI data stream root, disjoint from the init root above
    toks = make_batches(jax.random.PRNGKey(1), cfg, args.batch, args.seq,
                        args.steps)
    n_params = T.param_count(state.theta)
    print(f"arch={cfg.name} params={n_params:,} silos={args.silos} "
          f"algo={args.algo}")

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    t0 = time.time()

    def batches():
        for i in range(args.steps):
            batch = {
                "tokens": jnp.asarray(toks[i, :, :-1]),
                "labels": jnp.asarray(toks[i, :, 1:]),
            }
            if cfg.is_encoder_decoder:
                batch["frames"] = jax.random.normal(
                    jax.random.fold_in(key, i),
                    (args.batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
            if cfg.num_vision_tokens:
                batch["vision"] = jax.random.normal(
                    jax.random.fold_in(key, i),
                    (args.batch, cfg.num_vision_tokens, cfg.d_model), jnp.float32)
            yield batch

    def on_metrics(i, m, st):
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={m['loss']:.4f} "
                  + " ".join(f"{k}={v:.4f}" for k, v in m.items() if k != "loss")
                  + f" ({time.time()-t0:.1f}s)")
        if ckpt and (i + 1) % 50 == 0:
            ckpt.save(i + 1, {"theta": st.theta, "eta_G": st.eta_G})

    # On the SPMD mesh a "round" is one synchronized step: every silo ships
    # its global-shaped gradient tree to the virtual server (the psum).
    # SFVI-Avg amortizes that over --avg-every local steps. Under --algo avg
    # state.eta_G is silo-stacked (silos, n_G): each silo ships only its own
    # slice, so the per-silo cost divides the stacked size by --silos.
    meter = CommMeter()
    theta_bytes = NoCompression().wire_bytes({"theta": state.theta})
    eta_bytes = NoCompression().wire_bytes({"eta_G": state.eta_G})
    if args.algo == "avg":
        per_silo = theta_bytes + eta_bytes // args.silos
    else:
        per_silo = theta_bytes + eta_bytes
    syncs_per_step = 1.0 if args.algo == "sfvi" else 1.0 / args.avg_every
    per_round = int(args.silos * per_silo * syncs_per_step)

    # DP accounting for the sync schedule: SFVI ships per step, SFVI-Avg
    # every --avg-every steps. The noising itself lives in the compiled
    # round of repro.federated.Server; here we compose the equivalent
    # Gaussian-mechanism ledger so the SPMD path reports (eps, delta).
    # The policy comes from the run's declarative scenario so the two
    # paths can never configure DP differently.
    privacy = spec.scenario.privacy()
    exchanges = (1 if args.algo == "sfvi"
                 else (lambda i: 1 if (i + 1) % args.avg_every == 0 else 0))

    with mesh_ctx:
        state, hist = run_rounds(
            lambda st, batch, i: step_fn(st, batch, jnp.int32(i)),
            state, batches(), meter=meter,
            bytes_per_round=(per_round, per_round),
            privacy=privacy, exchanges_per_round=exchanges,
            on_metrics=on_metrics,
        )
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s; "
          f"comm {meter.total/2**20:.1f} MiB "
          f"({meter.per_round/2**20:.2f} MiB/step, algo={args.algo})")
    if privacy is not None:
        # Accounting only: the psum path exchanges raw gradients — the
        # clip+noise mechanism exists in repro.federated.Server. This
        # reports what the SAME sync cadence would cost there; it is NOT
        # a guarantee held by this run. Count exchanges from the same
        # schedule run_rounds composed so the two can never disagree.
        n_ex = (sum(exchanges(i) for i in range(args.steps))
                if callable(exchanges) else exchanges * args.steps)
        if n_ex == 0:
            print(f"privacy accounting: no silo->server exchange completed "
                  f"(steps={args.steps} < avg-every={args.avg_every}); "
                  f"nothing to account")
        else:
            print(f"privacy accounting (hypothetical — mechanism lives in "
                  f"repro.federated.Server, this run shipped raw gradients): "
                  f"{n_ex} exchanges at z={args.dp_noise:g}, "
                  f"C={args.dp_clip:g} would cost "
                  f"({hist['epsilon'][-1]:.3f}, {args.dp_delta:g})-DP")
    return state


if __name__ == "__main__":
    main()
