import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Communication-efficiency measurement on the production mesh: per-step
collective traffic of SFVI vs SFVI-Avg's local step vs its averaging step
— the paper's §3.2 claim expressed in compiled-HLO bytes at LLM scale.

    PYTHONPATH=src python -m repro.launch.comm --arch qwen3-4b \
        --out benchmarks/data/comm.json
"""
import argparse
import json
import sys

import jax

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.launch.roofline import collective_bytes
from repro.launch.specs import build_avg_lowering, build_lowering


def measure(arch: str, shape_name: str = "train_4k") -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh()
    out = {"arch": arch, "shape": shape_name}
    with use_mesh(mesh):
        fn, args = build_lowering(cfg, shape, mesh)
        c = jax.jit(fn).lower(*args).compile()
        out["sfvi"] = sum(collective_bytes(c.as_text()).values())
        for name, inc in [("avg_local", False), ("avg_round", True)]:
            fn, args = build_avg_lowering(cfg, shape, mesh, include_barycenter=inc)
            c = jax.jit(fn).lower(*args).compile()
            out[name] = sum(collective_bytes(c.as_text()).values())
    # NOTE: production compiles (scan-over-units counted once) — identical
    # structure across the three variants, so the RATIOS are meaningful
    # even though absolute bytes undercount per-layer collectives.
    for m in (10, 100, 1000):
        out[f"avg_amortized_m{m}"] = (
            out["avg_local"] * (m - 1) + out["avg_round"]) / m
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    rec = measure(args.arch, args.shape)
    print(json.dumps(rec, indent=1))
    eta_saving = rec["sfvi"] / max(rec["avg_amortized_m100"], 1.0)
    print(f"\nSFVI-Avg(m=100) moves {1/eta_saving:.2%} of SFVI's per-step "
          f"collective bytes (theta psum remains every step on the mesh; "
          f"the eta_G barycenter collective amortizes 1/m).")
    if args.out:
        rows = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                rows = json.load(f)
        rows.append(rec)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
