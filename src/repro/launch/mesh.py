"""Production mesh definition (TPU v5e target).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods = 512 chips as (pod=2, data=16, model=16).

The paper's federation maps onto the mesh as: silos ride the data-parallel
axes (pod x data); the server reduction g = sum_j g_j is a psum over those
axes; the model axis is ordinary tensor/expert parallelism inside each
silo's shard (DESIGN.md §3/§5).

``make_production_mesh`` is a function — importing this module never
touches jax device state (device count is locked at first jax init).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) for the roofline model.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # jax < 0.5 has neither sharding.AxisType nor make_mesh(axis_types=...);
    # Auto is the default there, so the kwarg is only needed when it exists.
    if hasattr(jax.sharding, "AxisType"):  # repro-lint: allow[R6] — jax cross-version feature shim, not a protocol probe
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def use_mesh(mesh):
    """Version-portable mesh context: ``jax.set_mesh`` where it exists
    (jax >= 0.6), else the ``Mesh`` object itself (a context manager that
    sets the physical mesh on 0.4.x)."""
    if hasattr(jax, "set_mesh"):  # repro-lint: allow[R6] — jax cross-version feature shim, not a protocol probe
        return jax.set_mesh(mesh)
    return mesh


def make_silo_mesh(num_silos: int, devices=None):
    """1-D mesh with a dedicated ``silo`` axis for the federated runtime.

    The axis spans ``min(num_silos, available devices)`` devices —
    unconditionally, not the largest divisor of J. A divisor rule
    collapses catastrophically for prime federations (J=7 on 4 devices
    ran the whole federation on ONE device); instead the runtime pads
    its stacked silo axis up to a multiple of the mesh size with masked
    dummy silos (``Server`` handles the padding), so every device is
    used for any J. On the single-device CPU container this degenerates
    to a 1-device mesh (all silos stacked, collectives become local
    no-ops) — the compiled graph is identical in structure to the
    multi-host lowering.
    """
    devices = list(jax.devices() if devices is None else devices)
    n = max(min(len(devices), num_silos), 1)
    return jax.sharding.Mesh(devices[:n], ("silo",))


def data_axes(mesh) -> tuple:
    """Mesh axes that carry silos / the batch (the 'federation' axes)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def data_world(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def model_world(mesh) -> int:
    return mesh.shape.get("model", 1)
