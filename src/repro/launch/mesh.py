"""Mesh topology: one declarative ``MeshSpec``, one ``build_mesh`` factory.

Two mesh families share this module:

  * the **federated** mesh — axes ``(silo, model)``. Silo rows of the
    stacked federation ride the ``silo`` axis (the runtime pads J up to
    a multiple of its size with masked dummy silos); each row's P wire
    parameters are sharded along ``model`` so one silo's upload never
    has to fit on a single device. ``model=1`` degenerates to the
    historical 1-D ``(silo,)`` mesh — same axis name, same compiled
    graph.
  * the **production** mesh (TPU v5e target) — 256 chips as
    (data=16, model=16), or (pod=2, data=16, model=16) for two pods.
    Silos ride the data-parallel axes; the model axis is ordinary
    tensor/expert parallelism inside each silo's shard (DESIGN.md §3/§5).

``MeshSpec`` is the JSON-native description the experiment spec carries
(:class:`repro.federated.api.ExperimentSpec` — ``spec.runtime.mesh``);
``build_mesh`` is the only construction path, so every version shim
(``AxisType``, ``jax.set_mesh``) lives here exactly once.

Everything is a function — importing this module never touches jax
device state (device count is locked at first jax init).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import numpy as np

# TPU v5e hardware constants (per chip) for the roofline model.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Declarative federated mesh topology (JSON-native, spec-carried).

    Attributes:
      silo: devices on the ``silo`` axis. ``None`` (default) spans
        ``min(num_silos, available // model)`` devices — the historical
        auto rule, now per model-column.
      model: devices each silo row's P wire parameters shard across
        (tensor parallelism of the wire). 1 keeps the 1-D mesh.
      multiprocess: build over the GLOBAL device list of a
        ``jax.distributed`` run (every process constructs the same mesh;
        each owns the silo rows living on its local devices). False
        restricts the mesh to this process's devices.
    """

    silo: Optional[int] = None
    model: int = 1
    multiprocess: bool = False

    def __post_init__(self):
        if self.model < 1:
            raise ValueError(f"MeshSpec.model must be >= 1, got {self.model}")
        if self.silo is not None and self.silo < 1:
            raise ValueError(f"MeshSpec.silo must be >= 1, got {self.silo}")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "MeshSpec":
        return cls(silo=d.get("silo"), model=d.get("model", 1),
                   multiprocess=d.get("multiprocess", False))

    @classmethod
    def parse(cls, text: str) -> "MeshSpec":
        """CLI form: ``"silo=8,model=2[,multiprocess]"`` (any subset)."""
        kwargs: Dict[str, Any] = {}
        for part in filter(None, (p.strip() for p in text.split(","))):
            if part == "multiprocess":
                kwargs["multiprocess"] = True
                continue
            key, _, value = part.partition("=")
            if key not in ("silo", "model", "multiprocess"):
                raise ValueError(
                    f"unknown mesh axis {key!r} in {text!r} "
                    "(silo=N,model=N,multiprocess)")
            kwargs[key] = (value.lower() in ("1", "true", "yes")
                           if key == "multiprocess" else int(value))
        return cls(**kwargs)


def _mk_mesh(devices, axes):
    """The one construction shim: a Mesh with Auto axis types everywhere.

    jax < 0.5 has no ``sharding.AxisType``; Auto is the default there,
    so the kwarg is only passed when it exists.
    """
    devices = np.asarray(devices)
    if hasattr(jax.sharding, "AxisType"):  # repro-lint: allow[R6] — jax cross-version feature shim, not a protocol probe
        return jax.sharding.Mesh(
            devices, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.sharding.Mesh(devices, axes)


def use_mesh(mesh):
    """Version-portable mesh context: ``jax.set_mesh`` where it exists
    (jax >= 0.6), else the ``Mesh`` object itself (a context manager that
    sets the physical mesh on 0.4.x)."""
    if hasattr(jax, "set_mesh"):  # repro-lint: allow[R6] — jax cross-version feature shim, not a protocol probe
        return jax.set_mesh(mesh)
    return mesh


def build_mesh(spec: Optional[MeshSpec] = None, *,
               num_silos: Optional[int] = None, devices=None):
    """The single federated-mesh factory: ``MeshSpec`` → ``Mesh``.

    The ``silo`` axis spans ``spec.silo`` devices when pinned, else
    ``min(num_silos, available // model)`` — unconditionally, not the
    largest divisor of J. A divisor rule collapses catastrophically for
    prime federations (J=7 on 4 devices ran the whole federation on ONE
    device); instead the runtime pads its stacked silo axis up to a
    multiple of the mesh size with masked dummy silos (``Server``
    handles the padding), so every device is used for any J. On the
    single-device CPU container this degenerates to a 1-device mesh
    (all silos stacked, collectives become local no-ops) — the compiled
    graph is identical in structure to the multi-host lowering.

    ``model=1`` returns the historical 1-D ``(silo,)`` mesh; ``model>1``
    returns a 2-D ``(silo, model)`` mesh whose rows each hold one silo
    block and whose columns shard the block's P wire parameters.

    ``spec.multiprocess`` builds over the global ``jax.devices()`` of a
    ``jax.distributed`` run (identical on every process); otherwise the
    mesh is restricted to this process's addressable devices so a
    single-process build never spans hosts by accident.
    """
    spec = spec or MeshSpec()
    if devices is None:
        devices = (jax.devices() if spec.multiprocess
                   else jax.local_devices())
    devices = list(devices)
    mw = spec.model
    if mw > len(devices):
        raise ValueError(
            f"MeshSpec.model={mw} needs at least {mw} devices, "
            f"have {len(devices)}")
    if spec.silo is not None:
        n = spec.silo
        if n * mw > len(devices):
            raise ValueError(
                f"MeshSpec(silo={n}, model={mw}) needs {n * mw} devices, "
                f"have {len(devices)}")
    else:
        n = max(min(len(devices) // mw,
                    num_silos if num_silos is not None else len(devices)), 1)
    if mw == 1:
        return _mk_mesh(devices[: n], ("silo",))
    grid = np.asarray(devices[: n * mw]).reshape(n, mw)
    return _mk_mesh(grid, ("silo", "model"))


def make_production_mesh(*, multi_pod: bool = False):
    """Production TPU mesh: (data=16, model=16), ×2 pods when asked."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk_mesh(np.asarray(jax.devices()[: int(np.prod(shape))])
                    .reshape(shape), axes)


def make_silo_mesh(num_silos: int, devices=None):
    """Back-compat wrapper: the 1-D federated mesh via :func:`build_mesh`."""
    return build_mesh(MeshSpec(), num_silos=num_silos, devices=devices)


def data_axes(mesh) -> tuple:
    """Mesh axes that carry silos / the batch (the 'federation' axes).

    On the production mesh these are (pod, data); on the federated mesh
    the ``silo`` axis itself — the axis the stacked (J, ...) state and
    the (J, P) wire rows shard over.
    """
    return tuple(a for a in mesh.axis_names if a in ("pod", "data", "silo"))


def data_world(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def model_world(mesh) -> int:
    """Devices sharding each row's parameters (1 on a 1-D mesh)."""
    return mesh.shape.get("model", 1)


def mesh_process_count(mesh) -> int:
    """Distinct jax processes the mesh spans (1 = single-process)."""
    return len({d.process_index for d in np.asarray(mesh.devices).flat})
