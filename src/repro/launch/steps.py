"""SPMD train/serve steps: the paper's SFVI iteration as ONE jitted graph.

``train_step`` is Algorithm 1 with the server virtualized into collectives
(DESIGN.md §5.1):

  * ε_G comes from a REPLICATED PRNG key — every silo sees the same draw,
    replacing the server's ε_G broadcast with shared randomness (zero
    bytes on the wire).
  * Each silo j (= one slice of the batch along the data axes) computes
    L̂_j = log p_θ(y_j, Z_Lj | Z_G) − log q(Z_Lj | Z_G) on ITS data with
    ITS η_Lj (sharded over the data axes — privacy by placement).
  * The server term L̂_0 = log p(Z_G) − log q_{η_G}(Z_G) is added once.
  * jax.grad of the summed objective realizes (S4)-(S8): the cross-silo
    psum of g_jθ and g_jη is inserted by GSPMD exactly where Algorithm 1
    ships gradients to the server; ∇η_Lj stays silo-local (no collective).
  * Adam updates θ, η_G (replicated) and η_L (sharded) in-graph.

``serve_step_prefill`` / ``serve_step_decode`` run the posterior-mean
model (θ, E[Z_G], E[Z_Lj]) for inference shapes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.backbone import transformer as T
from repro.models.backbone.bayes import (
    bayes_logits,
    latent_dims,
    log_prior_global,
    log_prior_local,
    token_nll,
)
from repro.models.backbone.config import ArchConfig
from repro.optim.adam import adam
from repro.optim.base import apply_updates

PyTree = Any

_LOG_2PI = 1.8378770664093453

AUX_LOSS_WEIGHT = 0.01  # MoE load-balance coefficient


# ---------------------------------------------------------------------------
# Variational state (diag Gaussians; paper §S2.1 uses the same family)
# ---------------------------------------------------------------------------

def init_eta_G(key, cfg: ArchConfig):
    n_G, _ = latent_dims(cfg)
    return {
        "mu": 0.01 * jax.random.normal(key, (n_G,), jnp.float32),
        "log_sigma": jnp.full((n_G,), -3.0, jnp.float32),
    }


def init_eta_L(key, cfg: ArchConfig, num_silos: int):
    _, n_L = latent_dims(cfg)
    return {
        "mu": 0.01 * jax.random.normal(key, (num_silos, n_L), jnp.float32),
        "log_sigma": jnp.full((num_silos, n_L), -3.0, jnp.float32),
    }


def _diag_sample(eta, eps):
    return eta["mu"] + jnp.exp(eta["log_sigma"]) * eps


def _diag_logq_stl(eta, z):
    """log q(z) with variational params stop-gradiented (STL estimator)."""
    mu = jax.lax.stop_gradient(eta["mu"])
    ls = jax.lax.stop_gradient(eta["log_sigma"])
    e = (z - mu) * jnp.exp(-ls)
    return -0.5 * jnp.sum(e * e) - jnp.sum(ls) - 0.5 * z.size * _LOG_2PI


@dataclasses.dataclass(frozen=True)
class TrainState:
    theta: PyTree
    eta_G: PyTree
    eta_L: PyTree
    opt_theta: PyTree
    opt_eta_G: PyTree
    opt_eta_L: PyTree
    step: jnp.ndarray

    def tree_flatten(self):
        return (
            (self.theta, self.eta_G, self.eta_L, self.opt_theta,
             self.opt_eta_G, self.opt_eta_L, self.step),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, lambda aux, ch: TrainState(*ch)
)


def init_train_state(key, cfg: ArchConfig, num_silos: int, lr: float = 3e-4):
    k1, k2, k3 = jax.random.split(key, 3)
    theta = T.init_params(k1, cfg)
    eta_G = init_eta_G(k2, cfg)
    eta_L = init_eta_L(k3, cfg, num_silos)
    opt = adam(lr)
    return TrainState(
        theta=theta,
        eta_G=eta_G,
        eta_L=eta_L,
        opt_theta=opt.init(theta),
        opt_eta_G=opt.init(eta_G),
        opt_eta_L=opt.init(eta_L),
        step=jnp.zeros((), jnp.int32),
    ), opt


# ---------------------------------------------------------------------------
# The SFVI train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, num_silos: int, lr: float = 3e-4,
                    remat: bool = True):
    n_G, n_L = latent_dims(cfg)
    opt = adam(lr)

    def objective(theta, eta_G, eta_L, batch, rng,
                  l0_weight=1.0, ntok_total=None, silo_mask=None):
        kG, kL = jax.random.split(jax.random.fold_in(rng, 0))
        eps_G = jax.random.normal(kG, (n_G,), jnp.float32)  # shared randomness
        eps_L = jax.random.normal(kL, (num_silos, n_L), jnp.float32)

        z_G = _diag_sample(eta_G, eps_G)
        # Server term L̂_0 (computed once, replicated). Under microbatch
        # accumulation each slice carries 1/k of the L0/prior/entropy terms
        # so the SUM over slices equals the full-batch objective exactly.
        L0 = l0_weight * (log_prior_global(cfg, z_G) - _diag_logq_stl(eta_G, z_G))

        base_logits, aux_moe, h = T.forward(theta, cfg, batch, remat=remat)
        B, S, V = base_logits.shape
        Bj = B // num_silos
        base_j = base_logits.reshape(num_silos, Bj, S, V)
        h_j = h.reshape(num_silos, Bj, S, -1)
        labels_j = batch["labels"].reshape(num_silos, Bj, S)

        def silo_term(base, hh, lbl, eta_mu, eta_ls, eps):
            eta_Lj = {"mu": eta_mu, "log_sigma": eta_ls}
            z_Lj = _diag_sample(eta_Lj, eps)
            logits = bayes_logits(cfg, base, hh, z_G, z_Lj)
            loglik = -token_nll(logits, lbl, masked_gather=cfg.perf.masked_nll)
            return (
                loglik
                + l0_weight * (log_prior_local(cfg, z_G, z_Lj)
                               - _diag_logq_stl(eta_Lj, z_Lj))
            )

        Lj = jax.vmap(silo_term)(
            base_j, h_j, labels_j, eta_L["mu"], eta_L["log_sigma"], eps_L
        )
        if silo_mask is not None:
            # Partial silo participation (paper §1): only active silos
            # contribute; J/|active| rescale keeps the estimator unbiased
            # (matches core/runtime.py::SFVIServer.run participation).
            m = silo_mask.astype(jnp.float32)
            Lj = Lj * m * (num_silos / jnp.maximum(jnp.sum(m), 1.0))
        elbo = L0 + jnp.sum(Lj)
        ntok = ntok_total if ntok_total is not None else B * S
        loss = -elbo / ntok + AUX_LOSS_WEIGHT * l0_weight * aux_moe
        return loss, {"elbo": elbo, "nll_per_tok": -jnp.sum(Lj) / ntok,
                      "aux_moe": aux_moe}

    def _grads_microbatched(state, batch, rng, k):
        """§Perf lever 5: gradient accumulation over k microbatches via
        lax.scan — only one microbatch's activations are live at a time,
        cutting the residual-saved-for-backward footprint ~k-fold. The
        SAME (ε_G, ε_L) draw serves every slice (one sample per SFVI
        iteration, Algorithm 1); L̂_0/prior terms carry weight 1/k so the
        accumulated gradient equals the full-batch gradient EXACTLY."""
        B, S = batch["tokens"].shape[:2]
        Bj = B // num_silos
        assert Bj % k == 0, (B, num_silos, k)

        def slice_mb(a):
            lead = a.shape[1:]
            a = a.reshape(num_silos, k, Bj // k, *lead)
            return jnp.moveaxis(a, 1, 0).reshape(
                k, num_silos * (Bj // k), *lead)

        mb = {kk: slice_mb(v) for kk, v in batch.items()}
        ntok_total = B * S

        def body(acc, mb_i):
            (loss, metrics), grads = jax.value_and_grad(
                objective, argnums=(0, 1, 2), has_aux=True
            )(state.theta, state.eta_G, state.eta_L, mb_i, rng,
              1.0 / k, ntok_total)
            acc_loss, acc_metrics, acc_grads = acc
            return (acc_loss + loss,
                    jax.tree_util.tree_map(jnp.add, acc_metrics, metrics),
                    jax.tree_util.tree_map(jnp.add, acc_grads, grads)), None

        zero_g = jax.tree_util.tree_map(
            lambda a: jnp.zeros(a.shape, a.dtype),
            (state.theta, state.eta_G, state.eta_L))
        zero_m = {"elbo": jnp.zeros(()), "nll_per_tok": jnp.zeros(()),
                  "aux_moe": jnp.zeros(())}
        (loss, metrics, grads), _ = jax.lax.scan(
            body, (jnp.zeros(()), zero_m, zero_g), mb)
        return (loss, metrics), grads

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray], seed,
                   silo_mask=None):
        # repro-lint: allow[R1] — in-graph key derivation from the caller's per-step seed argument (pure function of it)
        rng = jax.random.PRNGKey(seed)
        k = cfg.perf.microbatch
        if k and k > 1:
            (loss, metrics), grads = _grads_microbatched(state, batch, rng, k)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                objective, argnums=(0, 1, 2), has_aux=True
            )(state.theta, state.eta_G, state.eta_L, batch, rng,
              1.0, None, silo_mask)
        g_theta, g_eta_G, g_eta_L = grads
        up_t, opt_t = opt.update(g_theta, state.opt_theta, state.theta)
        up_g, opt_g = opt.update(g_eta_G, state.opt_eta_G, state.eta_G)
        up_l, opt_l = opt.update(g_eta_L, state.opt_eta_L, state.eta_L)
        new_state = TrainState(
            theta=apply_updates(state.theta, up_t),
            eta_G=apply_updates(state.eta_G, up_g),
            eta_L=apply_updates(state.eta_L, up_l),
            opt_theta=opt_t,
            opt_eta_G=opt_g,
            opt_eta_L=opt_l,
            step=state.step + 1,
        )
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# SFVI-Avg on the mesh (communication-avoiding schedule for the latent head)
# ---------------------------------------------------------------------------

def make_train_step_avg(cfg: ArchConfig, num_silos: int, avg_every: int,
                        lr: float = 3e-4, remat: bool = True,
                        include_barycenter=None):
    """SFVI-Avg adapted to the mesh (DESIGN.md §5.3): η_G is carried
    PER-SILO (leading J axis, sharded like η_L); silos run local VI steps
    and every ``avg_every`` steps the server averages the per-silo global
    posteriors with the diagonal-Gaussian Wasserstein barycenter
    (μ* = mean μ_j, σ* = mean σ_j — the paper's analytic solution). θ uses
    the standard psum path every step (per-silo θ replicas are infeasible
    at LLM scale on one mesh; recorded as a deviation)."""
    n_G, n_L = latent_dims(cfg)
    opt = adam(lr)

    def objective(theta, eta_G_silo, eta_L, batch, rng):
        kG, kL = jax.random.split(jax.random.fold_in(rng, 0))
        # Per-silo eps_G: local steps use silo-local draws.
        eps_G = jax.random.normal(kG, (num_silos, n_G), jnp.float32)
        eps_L = jax.random.normal(kL, (num_silos, n_L), jnp.float32)

        base_logits, aux_moe, h = T.forward(theta, cfg, batch, remat=remat)
        B, S, V = base_logits.shape
        Bj = B // num_silos
        base_j = base_logits.reshape(num_silos, Bj, S, V)
        h_j = h.reshape(num_silos, Bj, S, -1)
        labels_j = batch["labels"].reshape(num_silos, Bj, S)
        scale = float(num_silos)  # N/N_j likelihood rescale (§3.2 point 2)

        def silo_term(base, hh, lbl, gmu, gls, lmu, lls, epsg, epsl):
            eta_Gj = {"mu": gmu, "log_sigma": gls}
            eta_Lj = {"mu": lmu, "log_sigma": lls}
            z_Gj = _diag_sample(eta_Gj, epsg)
            z_Lj = _diag_sample(eta_Lj, epsl)
            logits = bayes_logits(cfg, base, hh, z_Gj, z_Lj)
            loglik = -token_nll(logits, lbl, masked_gather=cfg.perf.masked_nll)
            L0 = log_prior_global(cfg, z_Gj) - _diag_logq_stl(eta_Gj, z_Gj)
            return (
                L0
                + scale * (loglik + log_prior_local(cfg, z_Gj, z_Lj))
                - _diag_logq_stl(eta_Lj, z_Lj)
            )

        Lj = jax.vmap(silo_term)(
            base_j, h_j, labels_j,
            eta_G_silo["mu"], eta_G_silo["log_sigma"],
            eta_L["mu"], eta_L["log_sigma"], eps_G, eps_L,
        )
        # Local objectives are independent; summing just runs them jointly.
        ntok = B * S
        loss = -jnp.sum(Lj) / (ntok * scale) + AUX_LOSS_WEIGHT * aux_moe
        return loss, {"elbo_local_mean": jnp.mean(Lj)}

    def barycenter(eta_G_silo):
        """Diagonal-Gaussian Wasserstein barycenter across the silo axis."""
        mu = jnp.mean(eta_G_silo["mu"], axis=0, keepdims=True)
        sigma = jnp.mean(jnp.exp(eta_G_silo["log_sigma"]), axis=0, keepdims=True)
        return {
            "mu": jnp.broadcast_to(mu, eta_G_silo["mu"].shape),
            "log_sigma": jnp.broadcast_to(
                jnp.log(sigma), eta_G_silo["log_sigma"].shape
            ),
        }

    def train_step(state: TrainState, batch, seed):
        # repro-lint: allow[R1] — in-graph key derivation from the caller's per-step seed argument (pure function of it)
        rng = jax.random.PRNGKey(seed)
        (loss, metrics), grads = jax.value_and_grad(
            objective, argnums=(0, 1, 2), has_aux=True
        )(state.theta, state.eta_G, state.eta_L, batch, rng)
        g_theta, g_eta_G, g_eta_L = grads
        up_t, opt_t = opt.update(g_theta, state.opt_theta, state.theta)
        up_g, opt_g = opt.update(g_eta_G, state.opt_eta_G, state.eta_G)
        up_l, opt_l = opt.update(g_eta_L, state.opt_eta_L, state.eta_L)
        eta_G = apply_updates(state.eta_G, up_g)
        # Every avg_every steps: the ONLY cross-silo communication for η_G.
        # ``include_barycenter`` statically includes/excludes the averaging
        # collective from the compiled graph (the communication-efficiency
        # measurement in benchmarks/bench_comm needs both variants); None
        # keeps the runtime-conditional path for actual training loops.
        if include_barycenter is None:
            do_avg = (state.step + 1) % avg_every == 0
            eta_G = jax.tree_util.tree_map(
                lambda avg, cur: jnp.where(do_avg, avg, cur),
                barycenter(eta_G), eta_G)
        elif include_barycenter:
            eta_G = barycenter(eta_G)
        new_state = TrainState(
            theta=apply_updates(state.theta, up_t),
            eta_G=eta_G,
            eta_L=apply_updates(state.eta_L, up_l),
            opt_theta=opt_t,
            opt_eta_G=opt_g,
            opt_eta_L=opt_l,
            step=state.step + 1,
        )
        return new_state, dict(metrics, loss=loss)

    return train_step


def init_eta_G_silo(key, cfg: ArchConfig, num_silos: int):
    n_G, _ = latent_dims(cfg)
    return {
        "mu": 0.01 * jax.random.normal(key, (num_silos, n_G), jnp.float32),
        "log_sigma": jnp.full((num_silos, n_G), -3.0, jnp.float32),
    }


# ---------------------------------------------------------------------------
# Serve steps (posterior-mean model)
# ---------------------------------------------------------------------------

def make_serve_prefill(cfg: ArchConfig, num_silos: int, max_len: int):
    def serve_step_prefill(theta, eta_G, eta_L, batch):
        logits, cache, h = T.prefill(theta, cfg, batch, max_len=max_len)
        B = logits.shape[0]
        Bj = B // num_silos
        z_G = eta_G["mu"]
        lj = logits.reshape(num_silos, Bj, 1, -1)
        hj = h.reshape(num_silos, Bj, 1, -1)
        out = jax.vmap(lambda b, hh, zl: bayes_logits(cfg, b, hh, z_G, zl))(
            lj, hj, eta_L["mu"]
        )
        return out.reshape(B, 1, -1), cache

    return serve_step_prefill


def make_serve_decode(cfg: ArchConfig, num_silos: int):
    def serve_step_decode(theta, eta_G, eta_L, tokens, cache):
        logits, new_cache, h = T.decode_step(theta, cfg, tokens, cache)
        B = logits.shape[0]
        Bj = B // num_silos
        z_G = eta_G["mu"]
        lj = logits.reshape(num_silos, Bj, 1, -1)
        hj = h.reshape(num_silos, Bj, 1, -1)
        out = jax.vmap(lambda b, hh, zl: bayes_logits(cfg, b, hh, z_G, zl))(
            lj, hj, eta_L["mu"]
        )
        return out.reshape(B, 1, -1), new_cache

    return serve_step_decode
