"""Federated silo partitioners, including the paper's heterogeneity protocol."""
from __future__ import annotations

from typing import List

import numpy as np


def iid_partition(rng: np.random.Generator, n: int, num_silos: int) -> List[np.ndarray]:
    """Random equal split."""
    perm = rng.permutation(n)
    return [np.sort(chunk) for chunk in np.array_split(perm, num_silos)]


def sizes_partition(rng: np.random.Generator, n: int, sizes: List[int]) -> List[np.ndarray]:
    """Random split with explicit per-silo sizes (e.g. the GLMM's 300/237)."""
    assert sum(sizes) == n, f"sizes {sizes} must sum to n={n}"
    perm = rng.permutation(n)
    out, start = [], 0
    for s in sizes:
        out.append(np.sort(perm[start : start + s]))
        start += s
    return out


def dirichlet_label_partition(
    rng: np.random.Generator,
    labels: np.ndarray,
    num_silos: int,
    alpha: float = 0.5,
    min_per_silo: int = 1,
) -> List[np.ndarray]:
    """Dirichlet non-IID partition (Hsu et al., 2019) with unequal N_j.

    For every class (or topic — any integer assignment works: partition
    a corpus by each document's dominant topic to get topic-skewed
    silos), draw per-silo proportions ``p ~ Dir(alpha · 1_J)`` and split
    that class's samples accordingly. Small ``alpha`` concentrates each
    class on few silos (extreme heterogeneity, with naturally unequal
    silo sizes); large ``alpha`` recovers an IID-like split. Silos left
    below ``min_per_silo`` samples are topped up from the largest silo
    so every silo stays non-empty (the compiled runtime needs at least
    one observation per silo).
    """
    labels = np.asarray(labels)
    num_classes = int(labels.max()) + 1
    assignments: List[List[int]] = [[] for _ in range(num_silos)]
    for c in range(num_classes):
        idx = rng.permutation(np.where(labels == c)[0])
        if len(idx) == 0:
            continue
        p = rng.dirichlet(np.full(num_silos, alpha))
        # Largest-remainder apportionment of len(idx) samples to silos.
        quota = p * len(idx)
        counts = np.floor(quota).astype(np.int64)
        short = len(idx) - int(counts.sum())
        for j in np.argsort(-(quota - counts))[:short]:
            counts[j] += 1
        start = 0
        for j in range(num_silos):
            assignments[j].extend(idx[start : start + counts[j]])
            start += counts[j]
    # Re-balance pathological draws so no silo is empty.
    for j in range(num_silos):
        while len(assignments[j]) < min_per_silo:
            donor = max(range(num_silos), key=lambda i: len(assignments[i]))
            if len(assignments[donor]) <= min_per_silo:
                raise ValueError(
                    f"cannot give every silo {min_per_silo} samples: "
                    f"only {len(labels)} samples over {num_silos} silos")
            assignments[j].append(assignments[donor].pop())
    return [np.sort(np.asarray(a, np.int64)) for a in assignments]


def pad_ragged_silos(datas: List[dict], weight_key: str = "w") -> List[dict]:
    """Pad unequal-N_j silo dicts to a common leading size + 0/1 weights.

    The compiled runtime stacks silo data along a leading axis, which
    requires equal leaf shapes; a ragged federation pads every array to
    the widest silo (repeating row 0 — values are inert) and adds a
    ``weight_key`` vector that is 1.0 on real rows and 0.0 on padding.
    Models consume the weights in their likelihood (e.g. the registry's
    ``hetero_mn``), so padded rows contribute exactly nothing.
    """
    sizes = [len(next(iter(d.values()))) for d in datas]
    n_max = max(sizes)
    out = []
    for d, n in zip(datas, sizes, strict=True):
        if weight_key in d:
            raise ValueError(f"silo data already has a {weight_key!r} key")
        pad = n_max - n
        padded = {
            k: np.concatenate([v, np.repeat(v[:1], pad, axis=0)], axis=0)
            if pad else np.asarray(v)
            for k, v in d.items()
        }
        w = np.zeros((n_max,), np.float32)
        w[:n] = 1.0
        padded[weight_key] = w
        out.append(padded)
    return out


def heterogeneous_label_partition(
    rng: np.random.Generator,
    labels: np.ndarray,
    num_silos: int,
    dominant_frac: float = 0.9,
) -> List[np.ndarray]:
    """The paper's §4.1 protocol: each silo gets an equal number of samples,
    ``dominant_frac`` of which carry a single (round-robin) label; the rest
    are drawn ~uniformly from the other labels.
    """
    n = len(labels)
    num_classes = int(labels.max()) + 1
    per_silo = n // num_silos
    n_dom = int(round(dominant_frac * per_silo))

    by_class = [list(rng.permutation(np.where(labels == c)[0])) for c in range(num_classes)]
    assignments: List[List[int]] = [[] for _ in range(num_silos)]

    # Dominant label pass (round-robin over classes).
    for j in range(num_silos):
        c = j % num_classes
        take = min(n_dom, len(by_class[c]))
        assignments[j].extend(by_class[c][:take])
        by_class[c] = by_class[c][take:]

    # Fill the remainder uniformly from leftovers.
    leftovers = list(rng.permutation([i for pool in by_class for i in pool]))
    for j in range(num_silos):
        need = per_silo - len(assignments[j])
        assignments[j].extend(leftovers[:need])
        leftovers = leftovers[need:]

    return [np.sort(np.asarray(a, np.int64)) for a in assignments]
