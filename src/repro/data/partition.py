"""Federated silo partitioners, including the paper's heterogeneity protocol."""
from __future__ import annotations

from typing import List

import numpy as np


def iid_partition(rng: np.random.Generator, n: int, num_silos: int) -> List[np.ndarray]:
    """Random equal split."""
    perm = rng.permutation(n)
    return [np.sort(chunk) for chunk in np.array_split(perm, num_silos)]


def sizes_partition(rng: np.random.Generator, n: int, sizes: List[int]) -> List[np.ndarray]:
    """Random split with explicit per-silo sizes (e.g. the GLMM's 300/237)."""
    assert sum(sizes) == n, f"sizes {sizes} must sum to n={n}"
    perm = rng.permutation(n)
    out, start = [], 0
    for s in sizes:
        out.append(np.sort(perm[start : start + s]))
        start += s
    return out


def heterogeneous_label_partition(
    rng: np.random.Generator,
    labels: np.ndarray,
    num_silos: int,
    dominant_frac: float = 0.9,
) -> List[np.ndarray]:
    """The paper's §4.1 protocol: each silo gets an equal number of samples,
    ``dominant_frac`` of which carry a single (round-robin) label; the rest
    are drawn ~uniformly from the other labels.
    """
    n = len(labels)
    num_classes = int(labels.max()) + 1
    per_silo = n // num_silos
    n_dom = int(round(dominant_frac * per_silo))

    by_class = [list(rng.permutation(np.where(labels == c)[0])) for c in range(num_classes)]
    assignments: List[List[int]] = [[] for _ in range(num_silos)]

    # Dominant label pass (round-robin over classes).
    for j in range(num_silos):
        c = j % num_classes
        take = min(n_dom, len(by_class[c]))
        assignments[j].extend(by_class[c][:take])
        by_class[c] = by_class[c][take:]

    # Fill the remainder uniformly from leftovers.
    leftovers = list(rng.permutation([i for pool in by_class for i in pool]))
    for j in range(num_silos):
        need = per_silo - len(assignments[j])
        assignments[j].extend(leftovers[:need])
        leftovers = leftovers[need:]

    return [np.sort(np.asarray(a, np.int64)) for a in assignments]
