"""Data pipeline: synthetic dataset generators + federated silo partitioners.

The container is offline, so the paper's datasets (MNIST, 20Newsgroups,
six-cities) are simulated by generators that preserve every property the
algorithms interact with: dimensionality, class structure, label skew across
silos (the paper's 90%-one-digit protocol), document length distributions,
and the longitudinal covariate structure of the GLMM.
"""
from repro.data.synthetic import (
    SyntheticClassification,
    make_synthetic_mnist,
    make_lda_corpus,
    make_six_cities,
    make_token_stream,
)
from repro.data.partition import (
    dirichlet_label_partition,
    heterogeneous_label_partition,
    iid_partition,
    pad_ragged_silos,
    sizes_partition,
)

__all__ = [
    "SyntheticClassification",
    "make_synthetic_mnist",
    "make_lda_corpus",
    "make_six_cities",
    "make_token_stream",
    "dirichlet_label_partition",
    "heterogeneous_label_partition",
    "iid_partition",
    "pad_ragged_silos",
    "sizes_partition",
]
