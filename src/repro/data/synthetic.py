"""Synthetic dataset generators matching the paper's experimental shapes."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticClassification:
    x: np.ndarray  # (n, d) float32
    y: np.ndarray  # (n,) int32 labels
    num_classes: int


def make_synthetic_mnist(
    key,
    num_train: int = 6000,
    num_test: int = 1000,
    dim: int = 784,
    num_classes: int = 10,
    prototype_scale: float = 2.0,
    noise_scale: float = 1.0,
) -> tuple[SyntheticClassification, SyntheticClassification]:
    """MNIST stand-in: class-conditional Gaussians around smooth prototypes.

    Prototypes are low-frequency random images (so nearby pixels correlate,
    like real digits), one per class; samples add isotropic noise. This keeps
    the learning problem that the hierarchical BNN experiment probes —
    a shared global structure plus silo-specific label skew — while being
    generable offline.
    """
    kp, ktr, kte, kytr, kyte = jax.random.split(key, 5)
    side = int(np.sqrt(dim))
    # Low-frequency prototypes: upsampled coarse grids.
    coarse = jax.random.normal(kp, (num_classes, 7, 7))
    protos = jax.image.resize(coarse, (num_classes, side, side), "bilinear")
    protos = prototype_scale * protos.reshape(num_classes, dim)

    def sample_split(k, ky, n):
        y = jax.random.randint(ky, (n,), 0, num_classes)
        noise = noise_scale * jax.random.normal(k, (n, dim))
        x = protos[y] + noise
        return SyntheticClassification(
            x=np.asarray(x, np.float32), y=np.asarray(y, np.int32), num_classes=num_classes
        )

    return sample_split(ktr, kytr, num_train), sample_split(kte, kyte, num_test)


def make_lda_corpus(
    key,
    num_docs: int = 1200,
    vocab_size: int = 2000,
    num_topics: int = 21,
    doc_length_mean: int = 80,
    beta: float = 0.05,
    alpha: float = 0.3,
):
    """Generate a corpus from a *true* LDA model (20Newsgroups stand-in).

    Returns (counts, true_topics): counts is (num_docs, vocab_size) int32
    bag-of-words; true_topics is (num_topics, vocab_size) — the ground-truth
    word distributions, so topic-recovery (coherence proxy) is measurable.
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    true_topics = jax.random.dirichlet(k1, beta * jnp.ones(vocab_size), (num_topics,))
    doc_topic = jax.random.dirichlet(k2, alpha * jnp.ones(num_topics), (num_docs,))
    lengths = jnp.clip(
        jax.random.poisson(k3, doc_length_mean, (num_docs,)), 10, None
    )
    word_probs = doc_topic @ true_topics  # (num_docs, vocab)
    max_len = int(jnp.max(lengths))
    keys = jax.random.split(k4, num_docs)

    def one_doc(kd, probs, length):
        words = jax.random.choice(kd, vocab_size, shape=(max_len,), p=probs)
        mask = jnp.arange(max_len) < length
        return jnp.zeros(vocab_size, jnp.int32).at[words].add(mask.astype(jnp.int32))

    counts = jax.vmap(one_doc)(keys, word_probs, lengths)
    return np.asarray(counts, np.int32), np.asarray(true_topics, np.float32)


def make_six_cities(key, num_children: int = 537):
    """Six-cities longitudinal wheeze stand-in (Fitzmaurice & Laird 1993).

    537 children × 4 yearly visits; covariates: maternal smoking (binary,
    per-child) and age centred at 9 (−2..1, per-visit). Responses are drawn
    from the paper's logistic mixed model with known ground-truth parameters,
    so posterior-recovery can be checked against an MCMC oracle.
    """
    ks, kb, ky = jax.random.split(key, 3)
    smoke = jax.random.bernoulli(ks, 0.4, (num_children,)).astype(jnp.float32)
    age = jnp.tile(jnp.array([-2.0, -1.0, 0.0, 1.0]), (num_children, 1))
    true_beta = jnp.array([-1.8, 0.4, -0.15, 0.08])  # intercept, smoke, age, smoke*age
    true_omega = 0.0  # random-intercept sd = exp(-omega) = 1.0
    b = jnp.exp(-true_omega) * jax.random.normal(kb, (num_children,))
    logits = (
        true_beta[0]
        + true_beta[1] * smoke[:, None]
        + true_beta[2] * age
        + true_beta[3] * smoke[:, None] * age
        + b[:, None]
    )
    y = jax.random.bernoulli(ky, jax.nn.sigmoid(logits)).astype(jnp.float32)
    data = {
        "smoke": np.asarray(smoke, np.float32),
        "age": np.asarray(age, np.float32),
        "y": np.asarray(y, np.float32),
    }
    truth = {"beta": np.asarray(true_beta), "omega": float(true_omega)}
    return data, truth


def make_token_stream(key, num_tokens: int, vocab_size: int, zipf_a: float = 1.2):
    """Zipf-distributed token stream for the LLM training drivers."""
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = ranks ** (-zipf_a)
    probs /= probs.sum()
    tokens = jax.random.choice(
        key, vocab_size, shape=(num_tokens,), p=jnp.asarray(probs, jnp.float32)
    )
    return np.asarray(tokens, np.int32)
