"""Runtime sanitizers for the compiled federated round.

`repro-lint` (tools/repro_lint) enforces the *static* invariants; this
module is the dynamic half — :func:`sanitize` wires three checks around
a round loop:

* a host-direction ``jax.transfer_guard`` ("disallow") — any *implicit*
  device↔host transfer inside the loop raises (device-to-device stays
  free: a multi-device mesh legitimately spreads replicated state on
  first touch).  The runtime's sanctioned pulls are
  explicit ``jax.device_get``/``device_put`` (which the guard permits),
  so a guard trip localizes exactly the stray host sync that would
  stall the round pipeline in production.
* ``jax_debug_nans`` — re-runs the op that produced a NaN un-jitted and
  raises with a usable traceback instead of letting the NaN wash
  through the ELBO history.
* a **recompile watchdog** — the compiled round calls
  :func:`trace_event` from inside its traced body, which executes once
  per (re)trace and never at run time.  The watchdog budgets one trace
  per ``(strategy, local_steps, wire)`` config; a second trace (shape
  drift in the carry, a non-hashable static, a rebuilt ``Server``
  bypassing the process-level graph cache of
  ``repro.federated.graph_cache``) raises :class:`RecompileError` at
  the moment it happens, not as a mystery slowdown.  ``save→resume``
  on the same device count shares compiled rounds through the graph
  cache, so the budget holds across resume too (regression-tested in
  tests/test_sanitize.py).

Entry points: ``Experiment.run(sanitize=True)``, the CLI's
``--sanitize`` flag, or the context manager directly::

    with repro.debug.sanitize() as watchdog:
        exp.run(rounds)
    assert watchdog.total == 1

Not thread-safe: the active watchdog is process-global, matching jax's
own config flags.
"""

from __future__ import annotations

import contextlib
from collections import Counter
from typing import Any, Iterator, Optional

import jax

__all__ = [
    "RecompileError", "TraceWatchdog", "host_bridge", "sanitize",
    "suspended_tracing", "trace_event", "watch_recompiles",
]


class RecompileError(RuntimeError):
    """The compiled round retraced beyond its budget."""


class TraceWatchdog:
    """Counts traces per tag; raises when a tag exceeds ``limit``."""

    def __init__(self, limit: int = 1):
        self.limit = int(limit)
        self.counts: Counter = Counter()
        self._suspend = 0

    def record(self, tag: Any) -> None:
        if self._suspend:
            return
        self.counts[tag] += 1
        if self.counts[tag] > self.limit:
            raise RecompileError(
                f"round graph {tag!r} traced {self.counts[tag]} times "
                f"(budget {self.limit}) — the jit cache missed. Usual "
                "causes: shape/dtype/weak-type drift in the carried state, "
                "an unhashable static argument, or a rebuilt Server outside "
                "the process-level graph cache (bundle-overridden builds "
                "opt out — see repro.federated.graph_cache).")

    @property
    def total(self) -> int:
        """Traces observed across all configs since the watch began."""
        return sum(self.counts.values())

    @contextlib.contextmanager
    def suspended(self) -> Iterator[None]:
        self._suspend += 1
        try:
            yield
        finally:
            self._suspend -= 1


_ACTIVE: Optional[TraceWatchdog] = None


def trace_event(tag: Any) -> None:
    """Trace-count hook: call from *inside* a jitted function body.

    The Python body of a jitted function executes only while jax traces
    it, so this records compilations, never steady-state rounds.  No-op
    (one global read) when no watchdog is active.
    """
    if _ACTIVE is not None:
        _ACTIVE.record(tag)


@contextlib.contextmanager
def host_bridge() -> Iterator[None]:
    """Sanctioned control-plane window inside a guarded round loop.

    The loop's host side legitimately builds tiny device values each
    round — the PRNG root, ``fold_in`` of a Python round index, the
    scheduler's participation mask — whose constructors transfer
    scalars implicitly, which ``jax.transfer_guard("disallow")`` would
    reject.  Wrapping exactly those construction sites keeps the guard
    meaningful everywhere else: a stray ``np.asarray(metrics)`` or an
    implicit device pull in a callback still raises.
    """
    with jax.transfer_guard("allow"):
        yield


@contextlib.contextmanager
def suspended_tracing() -> Iterator[None]:
    """Window where deliberate traces (``.lower()`` inspection) are free."""
    if _ACTIVE is None:
        yield
    else:
        with _ACTIVE.suspended():
            yield


@contextlib.contextmanager
def watch_recompiles(limit: int = 1) -> Iterator[TraceWatchdog]:
    """Install a fresh watchdog as the process-global trace listener."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = wd = TraceWatchdog(limit)
    try:
        yield wd
    finally:
        _ACTIVE = prev


@contextlib.contextmanager
def _config_flag(name: str, value: Any) -> Iterator[None]:
    old = getattr(jax.config, name)
    jax.config.update(name, value)
    try:
        yield
    finally:
        jax.config.update(name, old)


@contextlib.contextmanager
def sanitize(
    *,
    transfer_guard: Optional[str] = "disallow",
    debug_nans: bool = True,
    watchdog: bool = True,
    trace_limit: int = 1,
) -> Iterator[Optional[TraceWatchdog]]:
    """All three sanitizers around a round loop; yields the watchdog.

    ``transfer_guard`` takes jax's levels ("allow"/"log"/"disallow"/
    "log_explicit"/"disallow_explicit") or None to leave transfers
    unguarded; ``trace_limit`` is the per-config trace budget.
    """
    with contextlib.ExitStack() as stack:
        wd = (stack.enter_context(watch_recompiles(trace_limit))
              if watchdog else None)
        if transfer_guard is not None:
            # Host directions only: device-to-device movement is how a
            # multi-device mesh spreads replicated state on first touch
            # (legitimate, one-time), while implicit host transfers are
            # exactly the stray syncs this sanitizer exists to catch.
            stack.enter_context(
                jax.transfer_guard_host_to_device(transfer_guard))
            stack.enter_context(
                jax.transfer_guard_device_to_host(transfer_guard))
        if debug_nans:
            stack.enter_context(_config_flag("jax_debug_nans", True))
        yield wd
