"""Fused Gaussian reparametrization + STL log q as a Pallas kernel.

Every SFVI iteration evaluates, for millions of latent components,

    z      = mu + exp(log_sigma) * eps
    logq_i = -0.5 eps_i^2 - log_sigma_i - 0.5 log 2*pi      (STL form)

Unfused, that is 4 HBM round-trips over (mu, log_sigma, eps) plus a
separate reduction pass. The kernel reads each operand once, emits z, and
reduces the per-element logq terms to ONE partial per grid block in the
same pass — the classic fuse-map-with-reduction pattern; the caller sums
the (n_blocks,) partials (a trivially small array).

This is the SFVI-specific hot-spot kernel: it is memory-bound and sits on
the critical path of every silo's local step (paper Algorithm 1 lines
4-6), between the PRNG and the model forward.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_HALF_LOG_2PI = 0.5 * math.log(2.0 * math.pi)


def _reparam_kernel(mu_ref, ls_ref, eps_ref, z_ref, lq_ref):
    mu = mu_ref[...].astype(jnp.float32)
    ls = ls_ref[...].astype(jnp.float32)
    eps = eps_ref[...].astype(jnp.float32)
    z_ref[...] = (mu + jnp.exp(ls) * eps).astype(z_ref.dtype)
    lq = -0.5 * eps * eps - ls - _HALF_LOG_2PI
    lq_ref[0, 0] = jnp.sum(lq)


def _reparam_bwd_kernel(ls_ref, eps_ref, dz_ref, dlq_ref, dmu_ref, dls_ref,
                        deps_ref):
    """Fused VJP: one pass over (log_sigma, eps, dz) emits all three grads.

        dmu  = dz
        dls  = dz * exp(ls) * eps - dlq          (entropy term: d(-ls)/dls)
        deps = dz * exp(ls)       - dlq * eps    (d(-eps^2/2)/deps)
    """
    ls = ls_ref[...].astype(jnp.float32)
    eps = eps_ref[...].astype(jnp.float32)
    dz = dz_ref[...].astype(jnp.float32)
    dlq = dlq_ref[0, 0]
    sig = jnp.exp(ls)
    dmu_ref[...] = dz.astype(dmu_ref.dtype)
    dls_ref[...] = (dz * sig * eps - dlq).astype(dls_ref.dtype)
    deps_ref[...] = (dz * sig - dlq * eps).astype(deps_ref.dtype)


def reparam_stl(
    mu: jnp.ndarray,  # (N,) flattened latent vector
    log_sigma: jnp.ndarray,
    eps: jnp.ndarray,
    block: int = 4096,
    interpret: bool = False,
):
    """Fused Gaussian reparametrization + STL log q in one HBM pass.

    Shapes: ``mu``, ``log_sigma``, ``eps`` are (N,) flattened latent
    vectors of matching length; returns ``(z, logq)`` with z (N,) in
    ``mu.dtype`` and logq a f32 scalar (the block partials are reduced
    in f32 regardless of input dtype). Pads internally to a ``block``
    multiple; the pad contributes 0 to logq via eps=0, log_sigma=0
    padding and the −0.5·log 2π constant is corrected analytically.
    Differentiable via a fused Pallas backward kernel (custom VJP — the
    STL stop-gradient is structural: logq's pathwise term never
    references mu/log_sigma). Reference implementation:
    ``kernels/ref.py::reparam_stl_ref`` (elementwise logq; sum to match).
    """
    return _reparam_stl_vjp(mu, log_sigma, eps, block, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _reparam_stl_vjp(mu, log_sigma, eps, block, interpret):
    z, lq, _ = _reparam_fwd_impl(mu, log_sigma, eps, block, interpret)
    return z, lq


def _blocked(x, block):
    (N,) = x.shape
    pad = (-N) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    return x.reshape(-1, block), pad


def _reparam_fwd_impl(mu, log_sigma, eps, block, interpret):
    (N,) = mu.shape
    block = min(block, max(N, 1))
    mu2, pad = _blocked(mu, block)
    ls2, _ = _blocked(log_sigma, block)
    eps2, _ = _blocked(eps, block)
    n_blocks = mu2.shape[0]
    z, lq = pl.pallas_call(
        _reparam_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, block), mu.dtype),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
        ],
        interpret=interpret,
    )(mu2, ls2, eps2)
    logq = jnp.sum(lq) + pad * _HALF_LOG_2PI  # remove pad's constant terms
    return z.reshape(-1)[:N], logq, (log_sigma, eps, block, N)


def _reparam_fwd(mu, log_sigma, eps, block, interpret):
    z, lq, res = _reparam_fwd_impl(mu, log_sigma, eps, block, interpret)
    return (z, lq), res


def _reparam_bwd(block_arg, interpret, res, cts):
    log_sigma, eps, block, N = res
    dz, dlq = cts
    ls2, pad = _blocked(log_sigma, block)
    eps2, _ = _blocked(eps, block)
    dz2, _ = _blocked(dz, block)
    n_blocks = ls2.shape[0]
    dlq_blocks = jnp.broadcast_to(
        jnp.asarray(dlq, jnp.float32).reshape(1, 1), (n_blocks, 1)
    )
    dmu, dls, deps = pl.pallas_call(
        _reparam_bwd_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, block), log_sigma.dtype),
            jax.ShapeDtypeStruct((n_blocks, block), log_sigma.dtype),
            jax.ShapeDtypeStruct((n_blocks, block), eps.dtype),
        ],
        interpret=interpret,
    )(ls2, eps2, dz2, dlq_blocks)
    unpad = lambda a: a.reshape(-1)[:N]  # noqa: E731
    return unpad(dmu), unpad(dls), unpad(deps)


_reparam_stl_vjp.defvjp(_reparam_fwd, _reparam_bwd)
