"""RMSNorm as a Pallas kernel: one VMEM pass per row block.

The reduction (mean of squares) and the scale are fused so each activation
row is read from HBM exactly once and written once — RMSNorm is purely
memory-bound, so the kernel's job is to hit streaming bandwidth with
(8k-aligned) row tiles and do the arithmetic in f32 on the fly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (br, D)
    rms = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = (x * rms * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_rows(
    x: jnp.ndarray,  # (R, D) — caller flattens leading dims
    weight: jnp.ndarray,  # (D,)
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Row-blocked RMSNorm: ``x * rsqrt(mean(x², -1) + eps) * weight``.

    Shapes: ``x`` is (R, D) — callers flatten leading dims (see
    ``ops.rmsnorm``) — and ``weight`` is (D,); returns (R, D) in
    ``x.dtype``. R must be divisible by ``block_rows`` (the wrapper
    halves the block until it divides). Any float dtype is accepted;
    the reduction and scale are computed in f32 and cast back on store,
    so bf16 inputs lose no precision in the mean-of-squares. Reference
    implementation: ``kernels/ref.py::rmsnorm_ref``.
    """
    R, D = x.shape
    block_rows = min(block_rows, R)
    assert R % block_rows == 0, (R, block_rows)
    grid = (R // block_rows,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x, weight)
