"""Gated linear attention (Mamba2-SSD / mLSTM) as a Pallas TPU kernel.

The recurrence  S_t = exp(a_t) S_{t-1} + k_t v_t^T ;  y_t = q_t . S_t
is computed chunkwise: the grid is (B, H, n_chunks) with the chunk axis
sequential, and the (dk, dv) f32 state lives in VMEM scratch across chunk
steps — the TPU analogue of the CUDA "chunk-scan" SSD kernel, with the
within-chunk quadratic part expressed as two MXU matmuls:

    y_intra = (q k^T  *  D) v          D_ij = exp(L_i - L_j) for j <= i
    y_inter = (q * exp(L)) S_in
    S_out   = exp(L_C) S_in + (k * exp(L_C - L))^T v

Chunk length defaults to 128 (MXU-aligned); dk/dv are the model's
ssm_state / head_dim (64/64 for zamba2) — padding to the 128 lane width is
the wrapper's job. One kernel instance handles ONE (batch, head) pair per
grid cell, so GQA-style head grouping is not needed (every head owns its
state).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.attention import pl_scratch


def _gla_kernel(q_ref, k_ref, v_ref, a_ref, o_ref, state_ref, *, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (C, dk)
    k = k_ref[0, 0].astype(jnp.float32)  # (C, dk)
    v = v_ref[0, 0].astype(jnp.float32)  # (C, dv)
    a = a_ref[0, 0].astype(jnp.float32)  # (C,)
    C = q.shape[0]

    cum = jnp.cumsum(a)  # (C,) L_i = sum_{r<=i} a_r
    total = cum[-1]
    # Within-chunk decay matrix, masked BEFORE exp (no inf * 0).
    diff = cum[:, None] - cum[None, :]
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    )
    D = jnp.exp(jnp.where(tri, diff, -jnp.inf))

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * D  # (C, C)
    y = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # Cross-chunk: contribution of the state entering this chunk.
    q_dec = q * jnp.exp(cum)[:, None]
    y = y + jax.lax.dot_general(
        q_dec, state_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[0, 0, ...] = y.astype(o_ref.dtype)

    k_dec = k * jnp.exp(total - cum)[:, None]
    state_ref[...] = state_ref[...] * jnp.exp(total) + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def gla_bhsd(
    q: jnp.ndarray,  # (B, H, S, dk)
    k: jnp.ndarray,  # (B, H, S, dk)
    v: jnp.ndarray,  # (B, H, S, dv)
    log_a: jnp.ndarray,  # (B, H, S)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Chunkwise gated linear attention over (batch, head)-major layout.

    Shapes: ``q``/``k`` are (B, H, S, dk), ``v`` is (B, H, S, dv),
    ``log_a`` is (B, H, S) per-step log decay (must be ≤ 0 for a stable
    recurrence); returns (B, H, S, dv) in ``q.dtype``. S must be a
    multiple of ``chunk`` — ``ops.gla`` pads with identity steps
    (log_a = 0, k/v = 0, which neither read nor write the state). Inputs
    may be bf16/f32; the (dk, dv) recurrent state and all matmuls run in
    f32 VMEM scratch. The chunk axis of the grid is sequential, so the
    state carries across grid steps per (b, h). Reference implementation:
    ``kernels/ref.py::gla_chunk_ref`` (exact per-step recurrence).
    """
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    nc = S // chunk
    grid = (B, H, nc)
    return pl.pallas_call(
        functools.partial(_gla_kernel, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, dk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, dk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, dv), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, dv), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, dv), q.dtype),
        scratch_shapes=[pl_scratch((dk, dv))],
        interpret=interpret,
    )(q, k, v, log_a)
