"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run in ``interpret=True`` mode (the
kernel body executes in Python per grid cell — bit-accurate to the TPU
lowering's semantics); on a TPU runtime ``interpret=False`` compiles to
Mosaic. The default is resolved LAZILY per call (``interpret_default``)
so importing this module never initializes the XLA backend — tests that
force host device counts (``--xla_force_host_platform_device_count``)
must be able to import kernels before touching a device.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import wire as _wire
from repro.kernels.attention import flash_attention_bhsd
from repro.kernels.gla import gla_bhsd
from repro.kernels.reparam import reparam_stl as _reparam_stl
from repro.kernels.rmsnorm import rmsnorm_rows


def interpret_default() -> bool:
    """True when the kernels must run in interpret mode (non-TPU host)."""
    return jax.default_backend() == "cpu"


def __getattr__(name: str):
    # Legacy alias: ``ops.INTERPRET`` used to be computed at import time,
    # which initialized the backend as a side effect of the import.
    if name == "INTERPRET":
        return interpret_default()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@partial(jax.jit, static_argnames=("causal", "window", "q_offset", "block_q",
                                   "block_kv", "interpret"))
def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, KV, hd)
    v: jnp.ndarray,  # (B, Skv, KV, hd)
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Flash attention for (batch, seq, head)-major activations.

    Shapes: ``q`` is (B, Sq, H, hd); ``k``/``v`` are (B, Skv, KV, hd)
    with H % KV == 0 (GQA groups of H/KV query heads share a kv head);
    returns (B, Sq, H, hd) in ``q.dtype`` (bf16/f32; softmax state is
    f32 inside the kernel). ``window`` enables sliding-window masking
    and ``q_offset`` positions the query block for decode. Pads Sq/Skv
    to block multiples (masked inside the kernel) and adapts the layout
    to the (B, H, S, hd) kernel. Reference implementation:
    ``kernels/ref.py::flash_attention_ref``.
    """
    interpret = interpret_default() if interpret is None else interpret
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, _round_up(Sq, 8))
    block_kv = min(block_kv, _round_up(Skv, 8))
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    pq = (-Sq) % block_q
    pkv = (-Skv) % block_kv
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pkv:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pkv), (0, 0)))
    out = flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
        true_sq=Sq, true_skv=Skv,
    )
    return jnp.moveaxis(out[:, :, :Sq], 2, 1)


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
            block_rows: int = 256, interpret: Optional[bool] = None) -> jnp.ndarray:
    """RMSNorm over the last axis for arbitrary leading shape.

    Shapes: ``x`` is (..., D) with any leading dims, ``weight`` is (D,);
    returns (..., D) in ``x.dtype`` (bf16/f32; mean-of-squares in f32).
    Flattens leading dims to rows and halves ``block_rows`` until it
    divides the row count. Reference implementation:
    ``kernels/ref.py::rmsnorm_ref``.
    """
    interpret = interpret_default() if interpret is None else interpret
    lead = x.shape[:-1]
    D = x.shape[-1]
    R = 1
    for s in lead:
        R *= s
    xf = x.reshape(R, D)
    br = block_rows
    while R % br:
        br //= 2
    br = max(br, 1)
    out = rmsnorm_rows(xf, weight, eps=eps, block_rows=br, interpret=interpret)
    return out.reshape(*lead, D)


@partial(jax.jit, static_argnames=("block", "interpret"))
def reparam_stl(mu, log_sigma, eps, block: int = 4096,
                interpret: Optional[bool] = None):
    """Fused z = mu + exp(log_sigma)·eps and STL log q, one HBM pass.

    Shapes: ``mu``/``log_sigma``/``eps`` are (N,) flattened latents of
    equal length (f32; bf16 inputs are upcast per-block inside the
    kernel); returns ``(z, logq)`` with z (N,) in ``mu.dtype`` and logq
    a f32 scalar. Differentiable (fused custom VJP). Reference
    implementation: ``kernels/ref.py::reparam_stl_ref``.
    """
    interpret = interpret_default() if interpret is None else interpret
    return _reparam_stl(mu, log_sigma, eps, block=block, interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def gla(q, k, v, log_a, chunk: int = 128, interpret: Optional[bool] = None):
    """Gated linear attention (Mamba2-SSD/mLSTM recurrence).

    Shapes: ``q``/``k`` are (B, S, H, dk); ``v`` is (B, S, H, dv);
    ``log_a`` is (B, S, H) per-step log decay (≤ 0); returns
    (B, S, H, dv) in ``q.dtype`` (bf16/f32; the recurrent state is f32).
    Pads S to a chunk multiple with identity steps (log_a = 0, k/v = 0 →
    the padded steps neither read nor write the state) and adapts the
    layout to the (B, H, S, ·) kernel. Reference implementation:
    ``kernels/ref.py::gla_chunk_ref``.
    """
    interpret = interpret_default() if interpret is None else interpret
    B, S, H, dk = q.shape
    chunk = min(chunk, _round_up(S, 8))
    pad = (-S) % chunk
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    at = jnp.moveaxis(log_a, 1, 2)
    if pad:
        zpad = ((0, 0), (0, 0), (0, pad), (0, 0))
        qt = jnp.pad(qt, zpad)
        kt = jnp.pad(kt, zpad)
        vt = jnp.pad(vt, zpad)
        at = jnp.pad(at, ((0, 0), (0, 0), (0, pad)))
    out = gla_bhsd(qt, kt, vt, at, chunk=chunk, interpret=interpret)
    return jnp.moveaxis(out[:, :, :S], 2, 1)


@partial(jax.jit, static_argnames=("clip_norm", "noise_multiplier", "quantize",
                                   "block_rows", "interpret"))
def wire_upload(
    x: jnp.ndarray,  # (J, P) stacked wire matrix
    mask: jnp.ndarray,  # (J,) participation mask
    keys: Optional[jnp.ndarray] = None,  # (J, 2) uint32 per-row noise keys
    reference: Optional[jnp.ndarray] = None,  # (P,) broadcast row
    clip_norm: Optional[float] = None,
    noise_multiplier: float = 0.0,
    quantize: bool = False,
    block_rows: Optional[int] = None,
    interpret: Optional[bool] = None,
):
    """Fused per-silo upload: clip + DP noise + mask + int8 quantize.

    One pass over the (J, P) wire matrix; noise drawn in-kernel from the
    per-row ``keys`` (pass ``fold_in(policy.upload_key(rk, t, j), 0)``
    per row for bit-exactness with ``PrivacyPolicy``'s stream). Returns
    the privatized f32 matrix, or ``(q, scales)`` with one scale per
    silo row when ``quantize``. Reference implementation:
    ``kernels/ref.py::wire_upload_ref``.
    """
    interpret = interpret_default() if interpret is None else interpret
    return _wire.fused_upload(
        x, mask=mask, keys=keys, reference=reference, clip_norm=clip_norm,
        noise_multiplier=noise_multiplier, quantize=quantize,
        block_rows=block_rows, interpret=interpret,
    )


@partial(jax.jit, static_argnames=("trim_frac", "block_cols", "interpret"))
def wire_combine(
    x: jnp.ndarray,  # (J, P) gathered wire matrix (f32, or int8 + scales)
    weights: jnp.ndarray,  # (J,) 0/1 or fractional async weights
    scales: Optional[jnp.ndarray] = None,  # (J,) int8 scales (fused dequant)
    trim_frac: Optional[float] = None,
    block_cols: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Fused masked/weighted (trimmed-)mean over the silo axis.

    ``trim_frac=None`` is ``MeanAggregator`` semantics, a float is
    ``TrimmedMeanAggregator`` semantics; int8 payloads dequantize inside
    the same pass when ``scales`` is given. Returns the (P,) combined
    row. Reference implementations:
    ``kernels/ref.py::masked_weighted_mean_ref`` /
    ``masked_trimmed_mean_ref``.
    """
    interpret = interpret_default() if interpret is None else interpret
    return _wire.fused_combine(
        x, weights, scales=scales, trim_frac=trim_frac,
        block_cols=block_cols, interpret=interpret,
    )


@partial(jax.jit, static_argnames=("num_iters", "interpret"))
def sqrtm_ns(mat: jnp.ndarray, num_iters: int = 25,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    """PSD matrix sqrt via the fused Newton–Schulz step kernel.

    Shapes: ``mat`` is (d, d) symmetric PSD; returns (d, d) in
    ``mat.dtype``. Same normalization/iteration as
    ``core.barycenter.sqrtm_newton_schulz``. Reference implementation:
    ``kernels/ref.py::newton_schulz_sqrtm_ref``.
    """
    interpret = interpret_default() if interpret is None else interpret
    return _wire.sqrtm_newton_schulz_fused(mat, num_iters=num_iters,
                                           interpret=interpret)
