"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical definition, written for clarity not
speed; kernel tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, H, hd)
    v: jnp.ndarray,  # (B, Skv, H, hd)
    causal: bool = True,
    sliding_window: Optional[int] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Softmax attention with optional causal / sliding-window masking."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    q_pos = jnp.arange(Sq) + q_offset
    kv_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if sliding_window is not None:
        mask &= kv_pos[None, :] > q_pos[:, None] - sliding_window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return ((x32 * rms) * weight.astype(jnp.float32)).astype(x.dtype)


def reparam_stl_ref(mu: jnp.ndarray, log_sigma: jnp.ndarray, eps: jnp.ndarray):
    """Fused Gaussian reparametrization + STL log q evaluation.

    Returns (z, logq_contrib) where z = mu + exp(log_sigma) * eps and
    logq_contrib are the per-element terms of log q_eta(z)|stop-grad(eta):
        -0.5 * eps^2 - log_sigma - 0.5 log(2 pi)
    (summing them gives the scalar STL log q; keeping them elementwise lets
    the caller fuse the reduction with other work).
    """
    z = mu + jnp.exp(log_sigma) * eps
    lq = -0.5 * eps.astype(jnp.float32) ** 2 - log_sigma.astype(jnp.float32) \
        - 0.5 * math.log(2.0 * math.pi)
    return z, lq


def gla_chunk_ref(q, k, v, log_a):
    """One gated-linear-attention chunk, exact recurrence (no chunking).

    q/k: (S, H, dk); v: (S, H, dv); log_a: (S, H). Returns (y, final_state)
    with y: (S, H, dv), state: (H, dk, dv). Used as oracle for the Pallas
    GLA kernel (single-chunk grid cell) AND for ssm.chunked_gla.
    """
    S, H, dk = q.shape
    dv = v.shape[-1]

    def step(state, inp):
        qt, kt, vt, at = inp
        state = state * jnp.exp(at.astype(jnp.float32))[:, None, None] + jnp.einsum(
            "hd,hv->hdv", kt.astype(jnp.float32), vt.astype(jnp.float32)
        )
        y = jnp.einsum("hd,hdv->hv", qt.astype(jnp.float32), state)
        return state, y

    init = jnp.zeros((H, dk, dv), jnp.float32)
    state, ys = jax.lax.scan(step, init, (q, k, v, log_a))
    return ys.astype(q.dtype), state
