"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical definition, written for clarity not
speed; kernel tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, H, hd)
    v: jnp.ndarray,  # (B, Skv, H, hd)
    causal: bool = True,
    sliding_window: Optional[int] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Softmax attention with optional causal / sliding-window masking."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    q_pos = jnp.arange(Sq) + q_offset
    kv_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if sliding_window is not None:
        mask &= kv_pos[None, :] > q_pos[:, None] - sliding_window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return ((x32 * rms) * weight.astype(jnp.float32)).astype(x.dtype)


def reparam_stl_ref(mu: jnp.ndarray, log_sigma: jnp.ndarray, eps: jnp.ndarray):
    """Fused Gaussian reparametrization + STL log q evaluation.

    Returns (z, logq_contrib) where z = mu + exp(log_sigma) * eps and
    logq_contrib are the per-element terms of log q_eta(z)|stop-grad(eta):
        -0.5 * eps^2 - log_sigma - 0.5 log(2 pi)
    (summing them gives the scalar STL log q; keeping them elementwise lets
    the caller fuse the reduction with other work).
    """
    z = mu + jnp.exp(log_sigma) * eps
    lq = -0.5 * eps.astype(jnp.float32) ** 2 - log_sigma.astype(jnp.float32) \
        - 0.5 * math.log(2.0 * math.pi)
    return z, lq


def wire_upload_ref(
    x: jnp.ndarray,  # (J, P) stacked wire matrix
    *,
    mask: jnp.ndarray,  # (J,) participation mask
    keys: Optional[jnp.ndarray] = None,  # (J, 2) per-row noise keys
    reference: Optional[jnp.ndarray] = None,  # (P,) public broadcast row
    clip_norm: Optional[float] = None,
    noise_multiplier: float = 0.0,
    quantize: bool = False,
):
    """Oracle for the fused upload kernel (``kernels/wire.py``).

    Per silo row: (delta from reference →) L2 clip → Gaussian noise from
    the row's folded key (the exact ``PrivacyPolicy`` stream) → add the
    reference back → participation-mask select (reference or zeros
    fallback) → optional symmetric int8 quantization with one scale per
    row. Written as the plain per-stage pipeline; returns the float
    matrix, or ``(q, scales)`` when ``quantize``.
    """
    x = x.astype(jnp.float32)
    y = x
    if clip_norm is not None:
        d = x - reference[None, :] if reference is not None else x
        norm = jnp.sqrt(jnp.sum(jnp.square(d), axis=1, keepdims=True))
        factor = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))
        d = d * factor
        if noise_multiplier > 0.0:
            std = noise_multiplier * clip_norm
            noise = jax.vmap(
                lambda k: jax.random.normal(k, (x.shape[1],), jnp.float32)
            )(keys)
            d = d + std * noise
        y = reference[None, :] + d if reference is not None else d
    fallback = (reference[None, :] if reference is not None
                else jnp.zeros_like(y))
    y = jnp.where(mask[:, None] > 0.5, y, fallback)
    if not quantize:
        return y
    scale = jnp.max(jnp.abs(y), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(y / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def masked_weighted_mean_ref(x: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the fused combine kernel, mean mode.

    ``MeanAggregator`` semantics on a (J, P) matrix: weighted sum over
    silos divided by the weight total, guarding ONLY exact zero (so
    fractional async weights summing below 1 normalize correctly).
    """
    w = weights.astype(jnp.float32)
    total = jnp.sum(w)
    denom = jnp.where(total > 0.0, total, 1.0)
    return jnp.sum(w[:, None] * x.astype(jnp.float32), axis=0) / denom


def masked_trimmed_mean_ref(
    x: jnp.ndarray, weights: jnp.ndarray, trim_frac: float
) -> jnp.ndarray:
    """Oracle for the fused combine kernel, trimmed-mean mode.

    ``TrimmedMeanAggregator`` semantics: silos with weight > 0 are
    active; per coordinate, sort actives (inactives as a +inf sentinel),
    drop the k = min(floor(tf·n), floor((n−1)/2)) smallest and largest
    ranks, average the survivors. Rank statistics ignore the weight
    magnitudes (a stale arrival is one vote, not a fractional one); zero
    active silos return zeros (never the sentinel).
    """
    x = x.astype(jnp.float32)
    w = weights.astype(jnp.float32)
    any_active = jnp.sum((w > 0.0).astype(jnp.float32)) > 0.0
    n_active = jnp.maximum(jnp.sum((w > 0.0).astype(jnp.float32)), 1.0)
    k = jnp.floor(trim_frac * n_active)
    k = jnp.minimum(k, jnp.floor((n_active - 1.0) / 2.0))
    order = jnp.sort(jnp.where(w[:, None] > 0.0, x, jnp.inf), axis=0)
    rank = jnp.arange(x.shape[0]).reshape(-1, 1)
    keep = (rank >= k) & (rank < n_active - k)
    total = jnp.sum(jnp.where(keep, order, 0.0), axis=0)
    mean = total / jnp.maximum(jnp.sum(keep, axis=0), 1)
    return jnp.where(any_active, mean, jnp.zeros_like(mean))


def int8_rows_dequant_ref(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the in-kernel dequantize: q·scale per row, in f32."""
    return q.astype(jnp.float32) * scales.astype(jnp.float32)[:, None]


def newton_schulz_sqrtm_ref(mat: jnp.ndarray, num_iters: int = 25) -> jnp.ndarray:
    """Oracle for the fused Newton–Schulz sqrt (== core.barycenter's).

    Frobenius-normalize, iterate t = ½(3I − zy); y←yt, z←tz, rescale.
    Kept here (dependency-free) so kernel tests need no federated/core
    imports; ``core.barycenter.sqrtm_newton_schulz`` is the live copy.
    """
    dim = mat.shape[-1]
    norm = jnp.sqrt(jnp.sum(mat * mat)) + 1e-12
    y = mat / norm
    z = jnp.eye(dim, dtype=mat.dtype)
    for _ in range(num_iters):
        t = 0.5 * (3.0 * jnp.eye(dim, dtype=mat.dtype) - z @ y)
        y = y @ t
        z = t @ z
    return y * jnp.sqrt(norm)


def gla_chunk_ref(q, k, v, log_a):
    """One gated-linear-attention chunk, exact recurrence (no chunking).

    q/k: (S, H, dk); v: (S, H, dv); log_a: (S, H). Returns (y, final_state)
    with y: (S, H, dv), state: (H, dk, dv). Used as oracle for the Pallas
    GLA kernel (single-chunk grid cell) AND for ssm.chunked_gla.
    """
    S, H, dk = q.shape
    dv = v.shape[-1]

    def step(state, inp):
        qt, kt, vt, at = inp
        state = state * jnp.exp(at.astype(jnp.float32))[:, None, None] + jnp.einsum(
            "hd,hv->hdv", kt.astype(jnp.float32), vt.astype(jnp.float32)
        )
        y = jnp.einsum("hd,hdv->hv", qt.astype(jnp.float32), state)
        return state, y

    init = jnp.zeros((H, dk, dv), jnp.float32)
    state, ys = jax.lax.scan(step, init, (q, k, v, log_a))
    return ys.astype(q.dtype), state
