"""Fused Pallas kernels for the federated (J, P) wire hot path.

PR 5's flat wire format turned each round's upload path into a dense
matrix pipeline over the stacked silo uploads:

    L2-norm -> clip -> Gaussian noise -> int8 quantize   (per silo row)
    gather  -> dequantize -> (trimmed-)mean              (per column)

plus, for full-covariance barycenter merges, a Newton–Schulz matrix
square root (the most FLOP-dense per-round loop). Each stage is a
separate XLA op on the ``wire="flat"`` path; the kernels here fuse them
so ``Server(wire="fused")`` reads each operand from memory once:

  * :func:`fused_upload` — one pass per silo row over the ``(J, P)``
    wire matrix: delta-from-broadcast (SFVI-Avg), L2 clip, Gaussian
    noise (drawn in-kernel from per-row folded keys, bit-identical to
    ``federated.privacy.PrivacyPolicy``'s stream), participation-mask
    select, and symmetric int8 quantization with ONE scale per row.
  * :func:`fused_combine` — masked/weighted (trimmed-)mean reduction
    over the gathered ``(J, P)`` matrix, accepting the async engine's
    fractional staleness weights, with optional in-kernel int8
    dequantize so the server never materializes the dequantized matrix.
  * :func:`newton_schulz_step` / :func:`sqrtm_newton_schulz_fused` —
    one fused Newton–Schulz iteration (three chained matmuls per step)
    for the full-covariance barycenter fixed point.

Every kernel has a pure-jnp oracle in :mod:`repro.kernels.ref`
(``wire_upload_ref`` / ``masked_weighted_mean_ref`` /
``masked_trimmed_mean_ref`` / ``newton_schulz_sqrtm_ref``) and the fused
pipeline is property-tested against both the oracles and the live
``PrivacyPolicy`` / aggregation objects (``tests/test_wire_kernels.py``),
so the fusion can never silently change what is transmitted: the DP
accountant's soundness contract (Mironov et al., 2019) is a statement
about the bytes on the wire, and those must be bit-identical across
``wire="flat"`` and ``wire="fused"``.

Portability: on this CPU container the kernels run in ``interpret=True``
mode (grid cells execute as traced JAX ops — semantically identical to
the Mosaic lowering's grid/BlockSpec behaviour). The in-kernel noise
draw uses the threefry PRNG (``jax.random.normal`` on the per-row folded
key) so it is bit-exact with the host policy's stream; a Mosaic TPU
lowering would swap it for ``pltpu.prng_random_bits`` (a *different*
stream) and is deliberately out of scope — ``wire="fused"`` therefore
requires interpret mode off-TPU and documents the stream contract.

Mesh contract: on a 2-D (silo x model) mesh the runtime calls
:func:`fused_upload` on each silo's FULL P-row (clip norms, noise keys
and the one-scale-per-row int8 quantization are row-global and must
never see a column slice), slices the result into model-axis column
blocks only for the silo gather, and rejoins the full ``(J, P)`` matrix
before :func:`fused_combine` — so both kernels always operate on
complete rows regardless of topology (``docs/federated.md`` §Sharding
layout explains why the rejoin also keeps the reduction bit-exact).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _interpret_default(interpret: Optional[bool]) -> bool:
    """Resolve the interpret flag LAZILY (never at import time).

    Querying ``jax.default_backend()`` at import initializes the XLA
    backend, which locks the device count before test subprocesses can
    set ``--xla_force_host_platform_device_count``; resolving per call
    keeps `import repro.kernels.wire` side-effect free.
    """
    if interpret is not None:
        return interpret
    return jax.default_backend() == "cpu"


def _divisor_block(n: int, block: Optional[int]) -> int:
    """Largest power-of-two-ish block <= ``block`` that divides ``n``."""
    b = n if block is None else min(block, max(n, 1))
    while n % b:
        b //= 2
    return max(b, 1)


# ---------------------------------------------------------------------------
# Kernel 1: per-row clip + noise + mask-select + int8 quantize (the upload)
# ---------------------------------------------------------------------------


def _upload_kernel(x_ref, mask_ref, key_ref, ref_ref, *out_refs,
                   clip_norm, noise_std, quantize, has_ref):
    """One pass over a (R, P) row block of the wire matrix.

    Stages (each optional, all fused):
      1. delta from the broadcast reference row (SFVI-Avg parameter
         uploads — the private quantity is the update, not the value);
      2. L2 clip of each row to ``clip_norm`` (the DP sensitivity bound);
      3. additive Gaussian noise, std ``noise_std``, drawn from the
         row's folded threefry key — the SAME primitive chain as
         ``PrivacyPolicy.noise``, so the stream is bit-identical;
      4. add the reference back (wire stays a parameter row);
      5. participation mask: inactive rows ship the data-independent
         fallback (the reference, or zeros) — the subsampling-
         amplification contract on the wire;
      6. symmetric int8 quantization, ONE scale per row (what
         ``Int8Compressor`` pays per leaf, and the flat wire per silo).
    """
    x = x_ref[...].astype(jnp.float32)        # (R, P)
    m = mask_ref[...]                          # (R,)
    ref = ref_ref[...].astype(jnp.float32) if has_ref else None  # (1, P)
    y = x
    if clip_norm is not None:
        d = x - ref if has_ref else x
        # Exactly PrivacyPolicy.clip: norm -> min(1, C/max(norm, eps)).
        norm = jnp.sqrt(jnp.sum(jnp.square(d), axis=1, keepdims=True))
        factor = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))
        d = d * factor
        if noise_std > 0.0:
            keys = key_ref[...]                # (R, 2) raw threefry words
            noise = jax.vmap(
                lambda k: jax.random.normal(k, (d.shape[1],), jnp.float32)
            )(keys)
            d = d + noise_std * noise
        y = ref + d if has_ref else d
    fallback = ref if has_ref else jnp.zeros_like(y)
    y = jnp.where(m[:, None] > 0.5, y, fallback)
    if quantize:
        scale = jnp.max(jnp.abs(y), axis=1) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(y / scale[:, None]), -127, 127)
        out_refs[0][...] = q.astype(jnp.int8)
        out_refs[1][...] = scale.astype(jnp.float32)
    else:
        out_refs[0][...] = y


def fused_upload(
    x: jnp.ndarray,  # (J, P) stacked wire matrix, one row per silo
    *,
    mask: jnp.ndarray,  # (J,) participation mask (0/1)
    keys: Optional[jnp.ndarray] = None,  # (J, 2) uint32 per-row noise keys
    reference: Optional[jnp.ndarray] = None,  # (P,) broadcast row (SFVI-Avg)
    clip_norm: Optional[float] = None,
    noise_multiplier: float = 0.0,
    quantize: bool = False,
    block_rows: Optional[int] = None,
    interpret: Optional[bool] = None,
):
    """Fused clip + noise + mask + int8 quantize over the wire matrix.

    Shapes: ``x`` is the stacked (J, P) float32 wire matrix; ``mask``
    is (J,); ``keys`` (required when ``noise_multiplier > 0``) is the
    (J, 2) uint32 matrix of per-row noise keys — for bit-exactness with
    the policy stream pass ``fold_in(policy.upload_key(rk, t, j), 0)``
    per row (the single-leaf fold of ``PrivacyPolicy.noise``);
    ``reference`` is the (P,) public broadcast row for parameter
    uploads. Returns the privatized (J, P) float32 matrix, or a
    ``(q, scales)`` pair ((J, P) int8 + (J,) float32 — one scale per
    row) when ``quantize``. Reference implementation:
    ``kernels/ref.py::wire_upload_ref``.
    """
    interpret = _interpret_default(interpret)
    J, P = x.shape
    if noise_multiplier > 0.0 and clip_norm is None:
        raise ValueError("noise_multiplier > 0 requires clip_norm")
    if noise_multiplier > 0.0 and keys is None:
        raise ValueError("noise_multiplier > 0 requires per-row keys")
    br = _divisor_block(J, block_rows)
    if keys is None:
        keys = jnp.zeros((J, 2), jnp.uint32)
    has_ref = reference is not None
    ref2 = (reference.reshape(1, P) if has_ref
            else jnp.zeros((1, 1), jnp.float32))
    noise_std = (float(noise_multiplier) * float(clip_norm)
                 if clip_norm is not None else 0.0)
    kernel = functools.partial(
        _upload_kernel,
        clip_norm=None if clip_norm is None else float(clip_norm),
        noise_std=noise_std, quantize=quantize, has_ref=has_ref,
    )
    out_specs = [pl.BlockSpec((br, P), lambda i: (i, 0))]
    out_shape = [jax.ShapeDtypeStruct((J, P),
                                      jnp.int8 if quantize else jnp.float32)]
    if quantize:
        out_specs.append(pl.BlockSpec((br,), lambda i: (i,)))
        out_shape.append(jax.ShapeDtypeStruct((J,), jnp.float32))
    out = pl.pallas_call(
        kernel,
        grid=(J // br,),
        in_specs=[
            pl.BlockSpec((br, P), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((br, 2), lambda i: (i, 0)),
            pl.BlockSpec(ref2.shape, lambda i: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, mask.astype(jnp.float32), keys, ref2)
    return (out[0], out[1]) if quantize else out[0]


# ---------------------------------------------------------------------------
# Kernel 2: masked/weighted (trimmed-)mean reduction over (J, P)
# ---------------------------------------------------------------------------


def _combine_kernel(x_ref, w_ref, s_ref, o_ref, *, trim_frac, dequant):
    """Weighted mean / trimmed mean over the silo axis of a column block.

    Mirrors ``MeanAggregator.combine`` / ``TrimmedMeanAggregator.combine``
    exactly (including the only-exact-zero denominator guard that keeps
    fractional async weights summing below 1 from shrinking parameter
    aggregates, and the +inf-sentinel rank masking of the trim), with
    the int8 dequantize fused in so the server never materializes the
    dequantized (J, P) float matrix.
    """
    x = x_ref[...]                             # (J, bp)
    if dequant:
        x = x.astype(jnp.float32) * s_ref[...][:, None]
    w = w_ref[...]                             # (J,)
    if trim_frac is None:
        total = jnp.sum(w)
        denom = jnp.where(total > 0.0, total, 1.0)
        o_ref[...] = jnp.sum(w[:, None] * x, axis=0) / denom
        return
    any_active = jnp.sum((w > 0.0).astype(w.dtype)) > 0.0
    n_active = jnp.maximum(jnp.sum((w > 0.0).astype(w.dtype)), 1.0)
    k = jnp.floor(trim_frac * n_active)
    k = jnp.minimum(k, jnp.floor((n_active - 1.0) / 2.0))
    m = w[:, None] > 0.0
    order = jnp.sort(jnp.where(m, x, jnp.inf), axis=0)
    rank = jnp.arange(x.shape[0]).reshape(-1, 1)
    keep = (rank >= k) & (rank < n_active - k)
    total = jnp.sum(jnp.where(keep, order, 0.0), axis=0)
    mean = total / jnp.maximum(jnp.sum(keep, axis=0), 1)
    # Zero active silos would average the +inf sentinel; return zeros,
    # exactly like TrimmedMeanAggregator's guard.
    o_ref[...] = jnp.where(any_active, mean, jnp.zeros_like(mean))


def fused_combine(
    x: jnp.ndarray,  # (J, P) gathered wire matrix (f32, or int8 with scales)
    weights: jnp.ndarray,  # (J,) aggregation weights (0/1 or fractional)
    *,
    scales: Optional[jnp.ndarray] = None,  # (J,) int8 scales -> fused dequant
    trim_frac: Optional[float] = None,
    block_cols: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Fused masked/weighted (trimmed-)mean over the silo axis.

    Shapes: ``x`` is the gathered (J, P) matrix — float32, or int8 with
    the (J,) per-row ``scales`` to fuse the dequantize into the same
    pass; ``weights`` is (J,) and may be fractional (the async engine's
    staleness decay). ``trim_frac=None`` computes the weighted mean
    (``MeanAggregator`` semantics); a float computes the coordinate-wise
    trimmed mean over silos with weight > 0 (``TrimmedMeanAggregator``
    semantics — rank statistics ignore the weight magnitudes). Returns
    the (P,) combined row. Reference implementations:
    ``kernels/ref.py::masked_weighted_mean_ref`` /
    ``masked_trimmed_mean_ref``.
    """
    interpret = _interpret_default(interpret)
    J, P = x.shape
    dequant = scales is not None
    if dequant and x.dtype != jnp.int8:
        raise ValueError(f"scales given but payload dtype is {x.dtype}")
    bp = _divisor_block(P, block_cols)
    kernel = functools.partial(
        _combine_kernel,
        trim_frac=None if trim_frac is None else float(trim_frac),
        dequant=dequant,
    )
    if scales is None:
        scales = jnp.zeros((J,), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(P // bp,),
        in_specs=[
            pl.BlockSpec((J, bp), lambda i: (0, i)),
            pl.BlockSpec((J,), lambda i: (0,)),
            pl.BlockSpec((J,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((P,), jnp.float32),
        interpret=interpret,
    )(x, weights.astype(jnp.float32), scales.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Kernel 3: fused Newton–Schulz iteration step (barycenter matrix sqrt)
# ---------------------------------------------------------------------------


def _ns_step_kernel(y_ref, z_ref, yo_ref, zo_ref):
    """One Newton–Schulz step: t = ½(3I − zy); y←yt, z←tz — fused.

    Three chained (d, d) matmuls per step; fusing them keeps t resident
    instead of round-tripping it to memory between the matmuls.
    """
    y = y_ref[...]
    z = z_ref[...]
    eye3 = 3.0 * jnp.eye(y.shape[-1], dtype=y.dtype)
    t = 0.5 * (eye3 - z @ y)
    yo_ref[...] = y @ t
    zo_ref[...] = t @ z


def newton_schulz_step(
    y: jnp.ndarray, z: jnp.ndarray, interpret: Optional[bool] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused single Newton–Schulz iteration on (d, d) operands.

    Returns ``(y @ t, t @ z)`` with ``t = 0.5 * (3I - z @ y)`` computed
    once in-kernel. Semantics identical to the loop body of
    ``core.barycenter.sqrtm_newton_schulz`` (the pure-jnp oracle is
    ``kernels/ref.py::newton_schulz_sqrtm_ref``).
    """
    interpret = _interpret_default(interpret)
    d = y.shape[-1]
    spec = pl.BlockSpec((d, d), lambda: (0, 0))
    yo, zo = pl.pallas_call(
        _ns_step_kernel,
        grid=(),
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((d, d), y.dtype),
                   jax.ShapeDtypeStruct((d, d), z.dtype)],
        interpret=interpret,
    )(y, z)
    return yo, zo


def sqrtm_newton_schulz_fused(
    mat: jnp.ndarray, num_iters: int = 25, interpret: Optional[bool] = None
) -> jnp.ndarray:
    """PSD matrix square root via the fused Newton–Schulz step kernel.

    Drop-in for ``core.barycenter.sqrtm_newton_schulz`` (same
    normalization, same iteration, same ``num_iters`` knob — the
    ``family_barycenter`` signature probe forwards ``sqrtm_iters`` to
    it); each iteration is one fused kernel instead of three separate
    matmul ops. Matches the jnp backend bit-for-bit in interpret mode.
    """
    interpret = _interpret_default(interpret)
    dim = mat.shape[-1]
    norm = jnp.sqrt(jnp.sum(mat * mat)) + 1e-12
    y = mat / norm
    z = jnp.eye(dim, dtype=mat.dtype)

    def body(_, carry):
        return newton_schulz_step(*carry, interpret=interpret)

    y, _ = jax.lax.fori_loop(0, num_iters, body, (y, z))
    return y * jnp.sqrt(norm)
