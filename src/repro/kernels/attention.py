"""Flash attention as a Pallas TPU kernel.

TPU adaptation (DESIGN.md §5.4): the grid is (B, H, n_q, n_kv) with the
innermost kv dimension marked "arbitrary" (sequential) so the online-softmax
state (acc, m, l) lives in VMEM scratch across kv steps — the TPU analogue
of a CUDA flash kernel's shared-memory tile loop. Block shapes default to
(128, 128): multiples of the (8, 128) sublane x lane tile and of the 128-wide
MXU systolic dims. GQA is handled in the K/V index maps (kv head = h // G),
so KV tiles are fetched once per group, not repeated H times — this is where
a TPU kernel saves HBM bandwidth over the naive jnp path.

Causal masking skips fully-masked kv blocks with ``pl.when`` (block-level
sparsity); sliding windows additionally skip blocks left of the window.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # (1, 1, bq, hd), (1, 1, bk, hd) x2
    o_ref,  # (1, 1, bq, hd)
    acc_ref, m_ref, l_ref,  # VMEM scratch: (bq, hd) f32, (bq, 1), (bq, 1)
    *,
    sq: int,
    skv: int,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    scale: float,
):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)
    n_kv = pl.num_programs(3)
    bq = q_ref.shape[2]
    bk = k_ref.shape[2]

    @pl.when(ikv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kv_pos = ikv * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # Block-level skip: run only if some (q, kv) pair in this tile is live.
    q_max = iq * bq + bq - 1 + q_offset
    kv_min = ikv * bk
    live = jnp.asarray(True)
    if causal:
        live = live & (kv_min <= q_max)
    if window is not None:
        q_min = iq * bq + q_offset
        kv_max = ikv * bk + bk - 1
        live = live & (kv_max > q_min - window)

    @pl.when(live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        mask = kv_pos < skv  # kv padding
        mask &= q_pos < sq + q_offset  # q padding (never attends garbage)
        if causal:
            mask &= kv_pos <= q_pos
        if window is not None:
            mask &= kv_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)  # rows with all-masked history stay 0
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ikv == n_kv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jnp.ndarray,  # (B, H, Sq_padded, hd)
    k: jnp.ndarray,  # (B, KV, Skv_padded, hd)
    v: jnp.ndarray,  # (B, KV, Skv_padded, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
    true_sq: Optional[int] = None,
    true_skv: Optional[int] = None,
) -> jnp.ndarray:
    """Flash attention over (batch, head)-major layout with online softmax.

    Shapes: ``q`` is (B, H, Sq, hd); ``k``/``v`` are (B, KV, Skv, hd)
    with KV ≤ H and H % KV == 0 (GQA: query head h reads kv head
    h // (H // KV)); returns (B, H, Sq, hd) in ``q.dtype``. Sq/Skv must
    be multiples of ``block_q``/``block_kv`` — ``ops.flash_attention``
    pads and passes the unpadded lengths as ``true_sq``/``true_skv`` for
    masking. Inputs may be bf16/f32; scores, the running max/normalizer
    and the accumulator are f32 (VMEM scratch), cast back on the final
    flush. ``causal``/``window`` masking skips fully-dead kv blocks at
    block granularity. Reference implementation:
    ``kernels/ref.py::flash_attention_ref``.
    """
    B, H, Sq, hd = q.shape
    KV = k.shape[1]
    Skv = k.shape[2]
    true_sq = Sq if true_sq is None else true_sq
    true_skv = Skv if true_skv is None else true_skv
    group = H // KV
    n_q = Sq // block_q
    n_kv = Skv // block_kv
    grid = (B, H, n_q, n_kv)

    kernel = functools.partial(
        _flash_kernel,
        sq=true_sq, skv=true_skv, causal=causal, window=window,
        q_offset=q_offset, scale=1.0 / math.sqrt(hd),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ikv: (b, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, hd), lambda b, h, iq, ikv: (b, h // group, ikv, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_kv, hd), lambda b, h, iq, ikv: (b, h // group, ikv, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, hd), lambda b, h, iq, ikv: (b, h, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            # (bq, hd) f32 accumulator + (bq, 1) running max / normalizer
            pl_scratch((block_q, hd)),
            pl_scratch((block_q, 1)),
            pl_scratch((block_q, 1)),
        ],
        interpret=interpret,
    )(q, k, v)


def pl_scratch(shape):
    """VMEM f32 scratch (TPU: pltpu.VMEM; interpret mode: plain MemoryRef)."""
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, jnp.float32)
    except Exception:  # pragma: no cover
        return pl.MemoryRef(shape, jnp.float32)
