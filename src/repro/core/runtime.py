"""Host-level federated runtime — literal transcriptions of Algorithms 1 & 2.

This runtime keeps the hub-and-spoke structure of the paper: a ``Server``
object and J ``Silo`` objects exchange explicit message pytrees, and every
message is metered (bytes up / bytes down) so the communication-efficiency
claims of §3.2 are measurable. The silo's data, its η_{L_j}, and its
optimizer state for η_{L_j} live *inside* the Silo object and never appear
in any message — the privacy structure of the paper enforced by construction.

The mesh/SPMD execution path (launch/train.py) reuses the same per-silo math
(`SFVIProblem.silo_grads`) but virtualizes the server into a psum; see
DESIGN.md §5.1.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.barycenter import barycenter_params_diag, barycenter_params_full
from repro.core.families import CholeskyGaussian, DiagGaussian
from repro.core.sfvi import SFVIProblem
from repro.optim.base import GradientTransformation, apply_updates

PyTree = Any


def tree_bytes(tree: PyTree) -> int:
    """Metered size of a message pytree in bytes."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
        if hasattr(x, "shape")
    )


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(a: PyTree, s: float) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_mean(trees: Sequence[PyTree]) -> PyTree:
    return jax.tree_util.tree_map(lambda *xs: sum(xs) / len(xs), *trees)


@dataclasses.dataclass
class CommLog:
    """Per-round communication accounting."""

    rounds: int = 0
    bytes_up: int = 0  # silo -> server
    bytes_down: int = 0  # server -> silo

    def record(self, up: int, down: int):
        self.rounds += 1
        self.bytes_up += up
        self.bytes_down += down

    @property
    def total(self) -> int:
        return self.bytes_up + self.bytes_down


class Silo:
    """One data owner. Holds y_j, η_{L_j} and its local optimizer privately."""

    def __init__(
        self,
        silo_id: int,
        problem: SFVIProblem,
        data: Any,
        eta_L: Optional[PyTree],
        local_optimizer: Optional[GradientTransformation],
        num_obs: int,
        seed: int = 0,
    ):
        self.silo_id = silo_id
        self.problem = problem
        self.data = data
        self.eta_L = eta_L
        self.num_obs = num_obs
        self._key = jax.random.PRNGKey(seed * 7919 + silo_id)
        self._local_opt = local_optimizer
        self._local_opt_state = (
            local_optimizer.init(eta_L) if (local_optimizer and eta_L is not None) else None
        )
        self._jit_step = jax.jit(self._step_impl, static_argnames=("likelihood_scale",))
        self._jit_local_rounds = jax.jit(
            self._local_rounds_impl, static_argnames=("num_steps", "likelihood_scale")
        )

    # ---------------- Algorithm 1 body ----------------

    def _step_impl(self, theta, eta_G, eta_L, local_opt_state, eps_G, eps_L, likelihood_scale=1.0):
        g_theta, g_eta, g_local, hatLj = self.problem.silo_grads(
            theta, eta_G, eta_L, eps_G, eps_L, self.data, likelihood_scale
        )
        if g_local is not None and self._local_opt is not None:
            # Optimizers are descent-convention; we ascend the ELBO.
            descent = tree_scale(g_local, -1.0)
            updates, local_opt_state = self._local_opt.update(descent, local_opt_state, eta_L)
            eta_L = apply_updates(eta_L, updates)
        return g_theta, g_eta, eta_L, local_opt_state, hatLj

    def sfvi_step(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        """Receive (θ, η_G, ε_G); update η_{L_j} in place; reply (g_j^θ, g_j^η)."""
        eps_L = None
        if self.problem.model.has_local:
            self._key, sub = jax.random.split(self._key)
            eps_L = jax.random.normal(sub, self._local_eps_shape())
        g_theta, g_eta, self.eta_L, self._local_opt_state, hatLj = self._jit_step(
            msg["theta"], msg["eta_G"], self.eta_L, self._local_opt_state,
            msg["eps_G"], eps_L,
        )
        return {"g_theta": g_theta, "g_eta": g_eta, "hat_Lj": hatLj}

    def _local_eps_shape(self):
        fam = self.problem.local_family
        if hasattr(fam, "batch"):
            return (fam.batch, fam.dim)
        return (fam.dim,)

    # ---------------- Algorithm 2 body ----------------

    def _local_rounds_impl(
        self, theta, eta_G, eta_L, key, opt_states, num_steps, likelihood_scale
    ):
        """m steps of *local* stochastic-gradient VI on L̂_0 + (N/N_j) L̂_j."""
        server_opt, local_opt = self._avg_opts

        def objective(th, eg, el, eps_G, eps_L):
            val = self.problem.hat_L0(th, eg, eps_G)
            val = val + self.problem.hat_Lj(
                th, eg, el, eps_G, eps_L, self.data, likelihood_scale
            )
            return val

        def body(carry, key_i):
            th, eg, el, (s_state, l_state) = carry
            kG, kL = jax.random.split(key_i)
            eps_G = jax.random.normal(kG, (self.problem.model.global_dim,))
            eps_L = (
                jax.random.normal(kL, self._local_eps_shape())
                if self.problem.model.has_local
                else None
            )
            if el is not None:
                val, grads = jax.value_and_grad(objective, argnums=(0, 1, 2))(
                    th, eg, el, eps_G, eps_L
                )
                g_th, g_eg, g_el = grads
                upd_l, l_state = local_opt.update(tree_scale(g_el, -1.0), l_state, el)
                el = apply_updates(el, upd_l)
            else:
                val, (g_th, g_eg) = jax.value_and_grad(objective, argnums=(0, 1))(
                    th, eg, el, eps_G, eps_L
                )
            descent = tree_scale({"theta": g_th, "eta_G": g_eg}, -1.0)
            upd_s, s_state = server_opt.update(descent, s_state, {"theta": th, "eta_G": eg})
            merged = apply_updates({"theta": th, "eta_G": eg}, upd_s)
            return (merged["theta"], merged["eta_G"], el, (s_state, l_state)), val

        keys = jax.random.split(key, num_steps)
        (theta, eta_G, eta_L, opt_states), elbos = jax.lax.scan(
            body, (theta, eta_G, eta_L, opt_states), keys
        )
        return theta, eta_G, eta_L, opt_states, elbos

    def sfvi_avg_round(self, msg: Dict[str, Any], num_steps: int, total_obs: int,
                       server_opt: GradientTransformation) -> Dict[str, Any]:
        """Algorithm 2 inner loop: m local VI steps, reply (θ^(j), η_G^(j))."""
        self._avg_opts = (server_opt, self._local_opt)
        scale = float(total_obs) / float(self.num_obs)
        self._key, sub = jax.random.split(self._key)
        s_state = server_opt.init({"theta": msg["theta"], "eta_G": msg["eta_G"]})
        l_state = self._local_opt_state
        theta_j, eta_G_j, self.eta_L, (s_state, self._local_opt_state), elbos = (
            self._jit_local_rounds(
                msg["theta"], msg["eta_G"], self.eta_L, sub, (s_state, l_state),
                num_steps=num_steps, likelihood_scale=scale,
            )
        )
        return {"theta": theta_j, "eta_G": eta_G_j, "elbos": elbos}


class SFVIServer:
    """Algorithm 1 driver. Owns (θ, η_G) and the server-side optimizer."""

    def __init__(
        self,
        problem: SFVIProblem,
        silos: List[Silo],
        theta: PyTree,
        eta_G: PyTree,
        optimizer: GradientTransformation,
        seed: int = 0,
    ):
        self.problem = problem
        self.silos = silos
        self.theta = theta
        self.eta_G = eta_G
        self.optimizer = optimizer
        self.opt_state = optimizer.init({"theta": theta, "eta_G": eta_G})
        self.key = jax.random.PRNGKey(seed)
        self.comm = CommLog()
        self._jit_update = jax.jit(self._update_impl)

    def _update_impl(self, theta, eta_G, opt_state, eps_G, g_theta_sum, g_eta_sum):
        # Server's own L̂_0 terms (S4)/(S7) — prior of Z_G and q_G entropy.
        g_theta0, g_eta0, hatL0 = self.problem.server_grads(theta, eta_G, eps_G)
        g = {"theta": tree_add(g_theta_sum, g_theta0), "eta_G": tree_add(g_eta_sum, g_eta0)}
        # Ascent on the ELBO: flip sign via maximize-style application.
        g = tree_scale(g, -1.0)  # optimizers are descent-convention
        updates, opt_state = self.optimizer.update(g, opt_state, {"theta": theta, "eta_G": eta_G})
        merged = apply_updates({"theta": theta, "eta_G": eta_G}, updates)
        return merged["theta"], merged["eta_G"], opt_state, hatL0

    def run(
        self,
        num_iters: int,
        participation: float = 1.0,
        callback: Optional[Callable[[int, dict], None]] = None,
    ) -> Dict[str, list]:
        """Run Algorithm 1 for ``num_iters`` rounds.

        ``participation`` < 1 activates partial silo participation: each round
        a random subset of silos contributes (gradients are rescaled by
        J/|participants| to keep the estimator unbiased).
        """
        history = {"elbo": [], "bytes_up": [], "bytes_down": []}
        J = len(self.silos)
        for it in range(num_iters):
            self.key, k_eps, k_part = jax.random.split(self.key, 3)
            eps_G = jax.random.normal(k_eps, (self.problem.model.global_dim,))
            msg_down = {"theta": self.theta, "eta_G": self.eta_G, "eps_G": eps_G}

            if participation >= 1.0:
                active = list(range(J))
            else:
                n_active = max(1, int(round(participation * J)))
                active = list(
                    np.asarray(
                        jax.random.choice(k_part, J, shape=(n_active,), replace=False)
                    )
                )
            rescale = float(J) / float(len(active))

            g_theta_sum = g_eta_sum = None
            elbo = 0.0
            up = down = 0
            for j in active:
                down += tree_bytes(msg_down)
                reply = self.silos[j].sfvi_step(msg_down)
                up += tree_bytes({"g_theta": reply["g_theta"], "g_eta": reply["g_eta"]})
                g_theta_sum = (
                    reply["g_theta"] if g_theta_sum is None else tree_add(g_theta_sum, reply["g_theta"])
                )
                g_eta_sum = (
                    reply["g_eta"] if g_eta_sum is None else tree_add(g_eta_sum, reply["g_eta"])
                )
                elbo += float(reply["hat_Lj"])
            g_theta_sum = tree_scale(g_theta_sum, rescale)
            g_eta_sum = tree_scale(g_eta_sum, rescale)

            self.theta, self.eta_G, self.opt_state, hatL0 = self._jit_update(
                self.theta, self.eta_G, self.opt_state, eps_G, g_theta_sum, g_eta_sum
            )
            self.comm.record(up, down)
            history["elbo"].append(elbo * rescale + float(hatL0))
            history["bytes_up"].append(up)
            history["bytes_down"].append(down)
            if callback:
                callback(it, {"elbo": history["elbo"][-1]})
        return history


class SFVIAvgServer:
    """Algorithm 2 driver: m local steps per silo, then θ-average + η_G barycenter."""

    def __init__(
        self,
        problem: SFVIProblem,
        silos: List[Silo],
        theta: PyTree,
        eta_G: PyTree,
        local_optimizer_factory: Callable[[], GradientTransformation],
        seed: int = 0,
    ):
        self.problem = problem
        self.silos = silos
        self.theta = theta
        self.eta_G = eta_G
        self.local_optimizer_factory = local_optimizer_factory
        self.key = jax.random.PRNGKey(seed)
        self.comm = CommLog()

    def _barycenter(self, eta_G_list: List[PyTree]) -> PyTree:
        fam = self.problem.global_family
        if isinstance(fam, DiagGaussian):
            return barycenter_params_diag(fam, eta_G_list)
        if isinstance(fam, CholeskyGaussian):
            return barycenter_params_full(fam, eta_G_list)
        raise TypeError(f"No barycenter rule for family {type(fam).__name__}")

    def run(
        self,
        num_rounds: int,
        local_steps: int,
        participation: float = 1.0,
        callback: Optional[Callable[[int, dict], None]] = None,
    ) -> Dict[str, list]:
        history = {"elbo": [], "bytes_up": [], "bytes_down": []}
        J = len(self.silos)
        total_obs = sum(s.num_obs for s in self.silos)
        for rnd in range(num_rounds):
            self.key, k_part = jax.random.split(self.key)
            if participation >= 1.0:
                active = list(range(J))
            else:
                n_active = max(1, int(round(participation * J)))
                active = list(
                    np.asarray(
                        jax.random.choice(k_part, J, shape=(n_active,), replace=False)
                    )
                )

            msg_down = {"theta": self.theta, "eta_G": self.eta_G}
            thetas, etas, elbo = [], [], 0.0
            up = down = 0
            for j in active:
                down += tree_bytes(msg_down)
                reply = self.silos[j].sfvi_avg_round(
                    msg_down, local_steps, total_obs, self.local_optimizer_factory()
                )
                up += tree_bytes({"theta": reply["theta"], "eta_G": reply["eta_G"]})
                thetas.append(reply["theta"])
                etas.append(reply["eta_G"])
                elbo += float(reply["elbos"][-1])

            if jax.tree_util.tree_leaves(thetas[0]):
                self.theta = tree_mean(thetas)  # FedAvg in parameter space for θ
            self.eta_G = self._barycenter(etas)
            self.comm.record(up, down)
            history["elbo"].append(elbo / len(active))
            history["bytes_up"].append(up)
            history["bytes_down"].append(down)
            if callback:
                callback(rnd, {"elbo": history["elbo"][-1]})
        return history
