"""Legacy hub-and-spoke runtime — now thin adapters over the compiled Server.

Historically this module ran Algorithms 1 & 2 eagerly: a Python loop over
J :class:`Silo` objects exchanging explicit message pytrees with a server
object, re-entering Python every round. That eager loop is retired — ONE
compiled runtime (:class:`repro.federated.runtime.Server`, all J silos
advancing inside a single ``shard_map`` graph) now serves every workload,
and the classes here remain only as **deprecated adapters** that preserve
the old constructor/run signatures for existing call sites:

  * :class:`SFVIServer` / :class:`SFVIAvgServer` translate the eager API
    (a list of Silos, an optimizer, ``run(iters, participation)``) into a
    compiled ``Server`` run, then write the updated η_{L_j} back into the
    Silo objects so code that reads ``silo.eta_L`` afterwards still works.
    New code should use :mod:`repro.federated.api` (declarative spec →
    build → run → resume) or ``repro.federated.Server`` directly.
  * :class:`Silo` survives as the per-silo state container (data, η_{L_j},
    local optimizer) plus the literal single-silo transcription of the
    paper's message protocol — useful for tests that assert the privacy
    structure of one exchange.
  * ``CommLog`` is a deprecated alias of
    :class:`repro.federated.runtime.CommMeter`; ``tree_bytes`` re-exports
    the single byte-accounting primitive from the same module.

The privacy structure of the paper is unchanged: a silo's data, its
η_{L_j} and its local optimizer state never appear in any cross-silo
message (in the compiled runtime this holds by mesh placement — silo
state is sharded over the ``silo`` axis and only global-shaped uploads
cross it).
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.barycenter import barycenter_params_diag, barycenter_params_full
from repro.core.family import eps_shape, supports_moments
from repro.core.sfvi import SFVIProblem
# Leaf module: safe while repro.federated.runtime (which imports repro.core
# submodules) may itself be mid-import. Server/stack_silos are imported
# lazily inside the adapters for the same reason.
from repro.federated.metering import CommMeter, tree_bytes
from repro.federated.scheduler import RoundScheduler
from repro.optim.base import GradientTransformation, apply_updates

PyTree = Any

__all__ = [
    "CommLog",
    "SFVIAvgServer",
    "SFVIServer",
    "Silo",
    "tree_add",
    "tree_bytes",
    "tree_mean",
    "tree_scale",
]


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(a: PyTree, s: float) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_mean(trees: Sequence[PyTree]) -> PyTree:
    return jax.tree_util.tree_map(lambda *xs: sum(xs) / len(xs), *trees)


class CommLog(CommMeter):
    """Deprecated alias of :class:`repro.federated.runtime.CommMeter`.

    Kept for one release so ``from repro.core import CommLog`` keeps
    working; it IS a CommMeter (same counters, plus ``per_round`` and
    ``state_dict``). New code should import CommMeter.
    """

    def __init__(self, *args, **kwargs):
        warnings.warn(
            "repro.core.runtime.CommLog is deprecated; use "
            "repro.federated.CommMeter",
            DeprecationWarning,
            stacklevel=2,
        )
        super().__init__(*args, **kwargs)


class Silo:
    """One data owner. Holds y_j, η_{L_j} and its local optimizer privately.

    In the compiled runtime the Silo is a *state container*: the adapters
    stack ``silo.eta_L`` across the federation, run the compiled round
    graph, and write the updated slices back. The single-silo step
    methods below remain the literal transcription of one protocol
    exchange (Algorithm 1's silo body) for tests that assert the message
    structure — e.g. that no local-dimension leaf ever leaves the silo.
    """

    def __init__(
        self,
        silo_id: int,
        problem: SFVIProblem,
        data: Any,
        eta_L: Optional[PyTree],
        local_optimizer: Optional[GradientTransformation],
        num_obs: int,
        seed: int = 0,
    ):
        self.silo_id = silo_id
        self.problem = problem
        self.data = data
        self.eta_L = eta_L
        self.num_obs = num_obs
        # repro-lint: allow[R1] — deprecated eager adapter: per-silo stream rooted at a pure function of (seed, silo_id)
        self._key = jax.random.PRNGKey(seed * 7919 + silo_id)
        self._local_opt = local_optimizer
        self._local_opt_state = (
            local_optimizer.init(eta_L) if (local_optimizer and eta_L is not None) else None
        )
        self._jit_step = jax.jit(self._step_impl, static_argnames=("likelihood_scale",))

    def _local_eps_shape(self):
        return eps_shape(self.problem.local_family)

    # ---------------- Algorithm 1 body (single-exchange reference) ----------

    def _step_impl(self, theta, eta_G, eta_L, local_opt_state, eps_G, eps_L, likelihood_scale=1.0):
        g_theta, g_eta, g_local, hatLj = self.problem.silo_grads(
            theta, eta_G, eta_L, eps_G, eps_L, self.data, likelihood_scale
        )
        if g_local is not None and self._local_opt is not None:
            # Optimizers are descent-convention; we ascend the ELBO.
            descent = tree_scale(g_local, -1.0)
            updates, local_opt_state = self._local_opt.update(descent, local_opt_state, eta_L)
            eta_L = apply_updates(eta_L, updates)
        return g_theta, g_eta, eta_L, local_opt_state, hatLj

    def sfvi_step(self, msg: Dict[str, Any]) -> Dict[str, Any]:
        """Receive (θ, η_G, ε_G); update η_{L_j} in place; reply (g_j^θ, g_j^η)."""
        eps_L = None
        if self.problem.model.has_local:
            self._key, sub = jax.random.split(self._key)
            eps_L = jax.random.normal(sub, self._local_eps_shape())
        g_theta, g_eta, self.eta_L, self._local_opt_state, hatLj = self._jit_step(
            msg["theta"], msg["eta_G"], self.eta_L, self._local_opt_state,
            msg["eps_G"], eps_L,
        )
        return {"g_theta": g_theta, "g_eta": g_eta, "hat_Lj": hatLj}


def _adapter_server(
    problem: SFVIProblem,
    silos: List[Silo],
    theta: PyTree,
    eta_G: PyTree,
    server_opt: GradientTransformation,
    eta_mode: str,
    seed: int,
):
    """Build the compiled Server behind an eager-API adapter.

    Silo data must share leaf shapes across the federation (the stacked
    ``silo``-axis layout); caller-initialized η_{L_j} are preserved by
    overwriting the Server's own init with the stacked silo values.
    """
    from repro.federated.runtime import Server, stack_silos

    local_opt = next((s._local_opt for s in silos if s._local_opt is not None), None)
    srv = Server(
        problem,
        [s.data for s in silos],
        theta,
        eta_G,
        num_obs=[s.num_obs for s in silos],
        server_opt=server_opt,
        local_opt=local_opt if problem.model.has_local else None,
        eta_mode=eta_mode,
        seed=seed,
    )
    if problem.model.has_local and all(s.eta_L is not None for s in silos):
        srv.state["eta_L"] = stack_silos([s.eta_L for s in silos])
    return srv


class _AdapterBase:
    """Shared plumbing of the two deprecated eager-API adapters."""

    _compiled: Any  # repro.federated.runtime.Server
    silos: List[Silo]

    @property
    def theta(self) -> PyTree:
        return self._compiled.theta

    @theta.setter
    def theta(self, value: PyTree) -> None:
        self._compiled.state["theta"] = value

    @property
    def eta_G(self) -> PyTree:
        return self._compiled.eta_G

    @eta_G.setter
    def eta_G(self, value: PyTree) -> None:
        self._compiled.state["eta_G"] = value

    @property
    def comm(self) -> CommMeter:
        return self._compiled.comm

    def _writeback(self) -> None:
        """Propagate updated η_{L_j} slices back into the Silo objects."""
        if not self.problem.model.has_local:
            return
        eta_L = self._compiled.eta_L
        opt_L = self._compiled.state["opt_local"]
        for j, silo in enumerate(self.silos):
            silo.eta_L = jax.tree_util.tree_map(lambda x, jj=j: x[jj], eta_L)
            silo._local_opt_state = jax.tree_util.tree_map(
                lambda x, jj=j: x[jj], opt_L)


class SFVIServer(_AdapterBase):
    """DEPRECATED eager-API adapter: Algorithm 1 on the compiled Server.

    Preserves the original constructor and ``run(num_iters,
    participation)`` signature, but every round now executes inside the
    single ``shard_map`` graph of :class:`repro.federated.runtime.Server`
    (algorithm ``"sfvi"``, one local step per round). After ``run``
    returns, updated η_{L_j} are written back into the Silo objects.

    Use :mod:`repro.federated.api` (or ``repro.federated.Server``) for
    new code; this adapter exists so pre-API call sites keep running on
    the one compiled runtime.
    """

    def __init__(
        self,
        problem: SFVIProblem,
        silos: List[Silo],
        theta: PyTree,
        eta_G: PyTree,
        optimizer: GradientTransformation,
        seed: int = 0,
    ):
        warnings.warn(
            "SFVIServer is a deprecated adapter over the compiled "
            "repro.federated.Server; build runs through "
            "repro.federated.api instead",
            DeprecationWarning,
            stacklevel=2,
        )
        self.problem = problem
        self.silos = silos
        self.optimizer = optimizer
        self.seed = seed
        # eta_mode is unused by the SFVI round graph; "param" skips the
        # barycenter's moment-bridge validation.
        self._compiled = _adapter_server(
            problem, silos, theta, eta_G, optimizer, "param", seed)
        self._round = 0

    def run(
        self,
        num_iters: int,
        participation: float = 1.0,
        callback: Optional[Callable[[int, dict], None]] = None,
    ) -> Dict[str, list]:
        """Run Algorithm 1 for ``num_iters`` rounds (one sync per round).

        ``participation`` < 1 invites a random subset per round; the
        aggregation rescales by the realized active count (unbiased,
        §3 Remark). Consecutive ``run`` calls continue the same round
        stream, as the eager loop did.
        """
        sched = RoundScheduler(
            len(self.silos), participation=participation, seed=self.seed)
        history = self._compiled.run(
            num_iters, algorithm="sfvi", local_steps=1, scheduler=sched,
            callback=callback, start_round=self._round)
        self._round += num_iters
        self._writeback()
        return history


class SFVIAvgServer(_AdapterBase):
    """DEPRECATED eager-API adapter: Algorithm 2 on the compiled Server.

    ``run(num_rounds, local_steps)`` executes ``local_steps`` local VI
    steps per silo and one parameter merge per round inside the compiled
    graph (algorithm ``"sfvi_avg"``): FedAvg for θ and the W2 barycenter
    for η_G — analytic for ``moment_form == "diag"`` families, the
    Newton–Schulz fixed point for ``"full"`` ones (CholeskyGaussian,
    LowRankGaussian), all in-graph via
    :func:`repro.core.barycenter.family_barycenter`. Families without
    the moment bridge are rejected with a ``ValueError`` at
    construction (there is no silent parameter-space downgrade).
    :meth:`_barycenter` keeps the eager host-side rule for reference.
    """

    def __init__(
        self,
        problem: SFVIProblem,
        silos: List[Silo],
        theta: PyTree,
        eta_G: PyTree,
        local_optimizer_factory: Callable[[], GradientTransformation],
        seed: int = 0,
    ):
        warnings.warn(
            "SFVIAvgServer is a deprecated adapter over the compiled "
            "repro.federated.Server; build runs through "
            "repro.federated.api instead",
            DeprecationWarning,
            stacklevel=2,
        )
        self.problem = problem
        self.silos = silos
        self.local_optimizer_factory = local_optimizer_factory
        self.seed = seed
        # The generic in-graph barycenter (family_barycenter) covers any
        # family exposing the moment bridge — diag analytically, full-
        # covariance ones through the Newton–Schulz fixed point — so the
        # adapter always runs the eager server's true merge rule. A
        # family without to_moments has no barycenter at all: fail loudly
        # instead of silently averaging parameters.
        if not supports_moments(problem.global_family):
            raise ValueError(
                f"SFVIAvgServer: {type(problem.global_family).__name__} "
                f"exposes no to_moments/from_moments — the W2 barycenter "
                f"merge is undefined for it. Use repro.federated.Server "
                f"with eta_mode='param' for parameter-space averaging.")
        eta_mode = "barycenter"
        # The factory's optimizer drives each silo's local (θ, η_G) steps
        # (a fresh state per round, as the eager loop created one per
        # sfvi_avg_round call); the silos' own optimizer drives η_{L_j}.
        self._compiled = _adapter_server(
            problem, silos, theta, eta_G, local_optimizer_factory(),
            eta_mode, seed)
        self._round = 0

    def _barycenter(self, eta_G_list: List[PyTree]) -> PyTree:
        """Host-side η_G merge rule of the eager server (kept for tests)."""
        fam = self.problem.global_family
        form = getattr(fam, "moment_form", None)
        if form == "diag":
            return barycenter_params_diag(fam, eta_G_list)
        if form == "full":
            return barycenter_params_full(fam, eta_G_list)
        raise TypeError(f"No barycenter rule for family {type(fam).__name__}")

    def run(
        self,
        num_rounds: int,
        local_steps: int,
        participation: float = 1.0,
        callback: Optional[Callable[[int, dict], None]] = None,
    ) -> Dict[str, list]:
        """Run Algorithm 2: ``local_steps`` local VI steps, 1 merge/round."""
        sched = RoundScheduler(
            len(self.silos), participation=participation, seed=self.seed)
        history = self._compiled.run(
            num_rounds, algorithm="sfvi_avg", local_steps=local_steps,
            scheduler=sched, callback=callback, start_round=self._round)
        self._round += num_rounds
        self._writeback()
        return history
