"""Variational families (paper §2–3.1).

The paper's structured Gaussian family:

    Z_G           = mu_G + sigma_G ⊙ (L_G @ eps_G)
    Z_{L_j} | Z_G = mu_bar_j + C_j (Z_G − mu_G) + sigma_j ⊙ (L_j @ eps_{L_j})

with L_G, L_j lower-unitriangular. ``DiagGaussian`` is the special case
L ≡ I (used in the paper's MNIST/ProdLDA experiments); ``CholeskyGaussian``
carries the full unitriangular factor; ``ConditionalGaussian`` adds the
coupling C_j that models Cov(Z_G, Z_{L_j}) = Σ_GG C_jᵀ.

All families are immutable descriptors; parameters live in plain dict
pytrees so they flow through jit/grad/psum and the Wasserstein barycenter.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]

_LOG_2PI = math.log(2.0 * math.pi)


def _tril_indices(dim: int):
    return jnp.tril_indices(dim, k=-1)


def _unpack_unitriangular(packed: jnp.ndarray, dim: int) -> jnp.ndarray:
    """Packed strictly-lower entries -> lower-unitriangular (dim, dim) matrix."""
    rows, cols = _tril_indices(dim)
    mat = jnp.eye(dim, dtype=packed.dtype)
    if dim > 1:
        mat = mat.at[rows, cols].set(packed)
    return mat


@dataclasses.dataclass(frozen=True)
class DiagGaussian:
    """Mean-field Gaussian: z = mu + sigma ⊙ eps. The paper's workhorse family."""

    dim: int

    def init(self, key, mu_scale: float = 0.01, log_sigma_init: float = -2.0) -> Params:
        return {
            "mu": mu_scale * jax.random.normal(key, (self.dim,)),
            "log_sigma": jnp.full((self.dim,), log_sigma_init),
        }

    def sample(self, params: Params, eps: jnp.ndarray) -> jnp.ndarray:
        return params["mu"] + jnp.exp(params["log_sigma"]) * eps

    def log_prob(self, params: Params, z: jnp.ndarray) -> jnp.ndarray:
        sigma = jnp.exp(params["log_sigma"])
        eps = (z - params["mu"]) / sigma
        return -0.5 * jnp.sum(eps**2) - jnp.sum(params["log_sigma"]) - 0.5 * self.dim * _LOG_2PI

    def entropy(self, params: Params) -> jnp.ndarray:
        return jnp.sum(params["log_sigma"]) + 0.5 * self.dim * (1.0 + _LOG_2PI)

    def to_moments(self, params: Params):
        """(mean, marginal std) — consumed by the Wasserstein barycenter."""
        return params["mu"], jnp.exp(params["log_sigma"])

    def from_moments(self, mu: jnp.ndarray, sigma: jnp.ndarray) -> Params:
        return {"mu": mu, "log_sigma": jnp.log(sigma)}

    @property
    def num_params(self) -> int:
        return 2 * self.dim


@dataclasses.dataclass(frozen=True)
class CholeskyGaussian:
    """z = mu + sigma ⊙ (L eps), L lower-unitriangular (paper §3.1).

    Covariance = D L Lᵀ D with D = diag(sigma); log|det| = Σ log sigma.
    """

    dim: int

    def init(self, key, mu_scale: float = 0.01, log_sigma_init: float = -2.0) -> Params:
        n_off = self.dim * (self.dim - 1) // 2
        return {
            "mu": mu_scale * jax.random.normal(key, (self.dim,)),
            "log_sigma": jnp.full((self.dim,), log_sigma_init),
            "L_packed": jnp.zeros((n_off,)),
        }

    def _chol(self, params: Params) -> jnp.ndarray:
        sigma = jnp.exp(params["log_sigma"])
        L = _unpack_unitriangular(params["L_packed"], self.dim)
        return sigma[:, None] * L  # scaled Cholesky factor of the covariance

    def sample(self, params: Params, eps: jnp.ndarray) -> jnp.ndarray:
        L = _unpack_unitriangular(params["L_packed"], self.dim)
        return params["mu"] + jnp.exp(params["log_sigma"]) * (L @ eps)

    def log_prob(self, params: Params, z: jnp.ndarray) -> jnp.ndarray:
        scaled = self._chol(params)
        eps = jax.scipy.linalg.solve_triangular(scaled, z - params["mu"], lower=True)
        return -0.5 * jnp.sum(eps**2) - jnp.sum(params["log_sigma"]) - 0.5 * self.dim * _LOG_2PI

    def entropy(self, params: Params) -> jnp.ndarray:
        return jnp.sum(params["log_sigma"]) + 0.5 * self.dim * (1.0 + _LOG_2PI)

    def covariance(self, params: Params) -> jnp.ndarray:
        chol = self._chol(params)
        return chol @ chol.T

    def to_moments(self, params: Params):
        """(mean, full covariance) — consumed by the full-Σ barycenter."""
        return params["mu"], self.covariance(params)

    def from_moments(self, mu: jnp.ndarray, cov: jnp.ndarray) -> Params:
        chol = jnp.linalg.cholesky(cov)
        diag = jnp.diagonal(chol)
        L = chol / diag[:, None]
        rows, cols = _tril_indices(self.dim)
        packed = L[rows, cols] if self.dim > 1 else jnp.zeros((0,))
        return {"mu": mu, "log_sigma": jnp.log(diag), "L_packed": packed}

    @property
    def num_params(self) -> int:
        return 2 * self.dim + self.dim * (self.dim - 1) // 2


@dataclasses.dataclass(frozen=True)
class ConditionalGaussian:
    """q(Z_L | Z_G) = N(mu_bar + C (z_G − mu_G), D L Lᵀ D)  (paper §3.1).

    ``use_coupling=False`` drops C (mean-field across the G/L boundary);
    ``use_chol=False`` sets L ≡ I (the paper does this for the GLMM, where
    the local latents are conditionally independent a posteriori).
    """

    dim: int
    global_dim: int
    use_coupling: bool = True
    use_chol: bool = False

    def init(self, key, mu_scale: float = 0.01, log_sigma_init: float = -2.0) -> Params:
        k1, _ = jax.random.split(key)
        params = {
            "mu_bar": mu_scale * jax.random.normal(k1, (self.dim,)),
            "log_sigma": jnp.full((self.dim,), log_sigma_init),
        }
        if self.use_coupling:
            params["C"] = jnp.zeros((self.dim, self.global_dim))
        if self.use_chol:
            params["L_packed"] = jnp.zeros((self.dim * (self.dim - 1) // 2,))
        return params

    def _cond_mean(self, params: Params, z_G, mu_G):
        mean = params["mu_bar"]
        if self.use_coupling:
            mean = mean + params["C"] @ (z_G - mu_G)
        return mean

    def sample(self, params: Params, z_G: jnp.ndarray, mu_G: jnp.ndarray, eps: jnp.ndarray):
        noise = eps
        if self.use_chol:
            L = _unpack_unitriangular(params["L_packed"], self.dim)
            noise = L @ eps
        return self._cond_mean(params, z_G, mu_G) + jnp.exp(params["log_sigma"]) * noise

    def log_prob(self, params: Params, z_L: jnp.ndarray, z_G: jnp.ndarray, mu_G: jnp.ndarray):
        resid = z_L - self._cond_mean(params, z_G, mu_G)
        if self.use_chol:
            L = _unpack_unitriangular(params["L_packed"], self.dim)
            scaled = jnp.exp(params["log_sigma"])[:, None] * L
            eps = jax.scipy.linalg.solve_triangular(scaled, resid, lower=True)
        else:
            eps = resid / jnp.exp(params["log_sigma"])
        return -0.5 * jnp.sum(eps**2) - jnp.sum(params["log_sigma"]) - 0.5 * self.dim * _LOG_2PI

    @property
    def num_params(self) -> int:
        n = 2 * self.dim
        if self.use_coupling:
            n += self.dim * self.global_dim
        if self.use_chol:
            n += self.dim * (self.dim - 1) // 2
        return n


@dataclasses.dataclass(frozen=True)
class BatchedDiagGaussian:
    """A batch of independent diagonal Gaussians, e.g. per-document W_k in
    ProdLDA or per-silo adapters in the LLM configs. Shape (batch, dim)."""

    batch: int
    dim: int

    def init(self, key, mu_scale: float = 0.01, log_sigma_init: float = -2.0) -> Params:
        return {
            "mu": mu_scale * jax.random.normal(key, (self.batch, self.dim)),
            "log_sigma": jnp.full((self.batch, self.dim), log_sigma_init),
        }

    def sample(self, params: Params, eps: jnp.ndarray) -> jnp.ndarray:
        return params["mu"] + jnp.exp(params["log_sigma"]) * eps

    def log_prob(self, params: Params, z: jnp.ndarray) -> jnp.ndarray:
        sigma = jnp.exp(params["log_sigma"])
        eps = (z - params["mu"]) / sigma
        return (
            -0.5 * jnp.sum(eps**2)
            - jnp.sum(params["log_sigma"])
            - 0.5 * self.batch * self.dim * _LOG_2PI
        )

    @property
    def num_params(self) -> int:
        return 2 * self.batch * self.dim
