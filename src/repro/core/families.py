"""Variational families (paper §2–3.1) — concrete `VariationalFamily`s.

The paper's structured Gaussian family:

    Z_G           = mu_G + sigma_G ⊙ (L_G @ eps_G)
    Z_{L_j} | Z_G = mu_bar_j + C_j (Z_G − mu_G) + sigma_j ⊙ (L_j @ eps_{L_j})

with L_G, L_j lower-unitriangular. ``DiagGaussian`` is the special case
L ≡ I (used in the paper's MNIST/ProdLDA experiments); ``CholeskyGaussian``
carries the full unitriangular factor; ``ConditionalGaussian`` adds the
coupling C_j that models Cov(Z_G, Z_{L_j}) = Σ_GG C_jᵀ;
``LowRankGaussian`` (diag + rank-r factor) extends the family beyond the
paper — its existence is the proof the protocol is open.

Every family implements the :class:`~repro.core.family.VariationalFamily`
protocol: capability flags instead of isinstance probes, a
``pack``/``unpack`` flat-vector bijection (derived from
:meth:`param_shapes`), and — where Gaussian moments exist — the
``to_moments``/``from_moments`` bridge the §3.2 barycenter merge
consumes. All families are immutable descriptors; parameters live in
plain dict pytrees so they flow through jit/grad/psum.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.family import VariationalFamily, register_family

Params = Dict[str, jnp.ndarray]

_LOG_2PI = math.log(2.0 * math.pi)


def _tril_indices(dim: int):
    return jnp.tril_indices(dim, k=-1)


def _unpack_unitriangular(packed: jnp.ndarray, dim: int) -> jnp.ndarray:
    """Packed strictly-lower entries -> lower-unitriangular (dim, dim) matrix."""
    rows, cols = _tril_indices(dim)
    mat = jnp.eye(dim, dtype=packed.dtype)
    if dim > 1:
        mat = mat.at[rows, cols].set(packed)
    return mat


@register_family("diag")
@dataclasses.dataclass(frozen=True)
class DiagGaussian(VariationalFamily):
    """Mean-field Gaussian: z = mu + sigma ⊙ eps. The paper's workhorse family."""

    dim: int

    has_moments = True
    moment_form = "diag"

    def param_shapes(self) -> Dict[str, Tuple[int, ...]]:
        return {"mu": (self.dim,), "log_sigma": (self.dim,)}

    def init(self, key, mu_scale: float = 0.01, log_sigma_init: float = -2.0) -> Params:
        # Explicit dtype: a weak-typed leaf here strengthens after one
        # server update, changing the carry aval and retracing the
        # compiled round (caught by repro.debug's recompile watchdog).
        return {
            "mu": mu_scale * jax.random.normal(key, (self.dim,)),
            "log_sigma": jnp.full((self.dim,), log_sigma_init, dtype=jnp.float32),
        }

    def sample(self, params: Params, eps: jnp.ndarray) -> jnp.ndarray:
        return params["mu"] + jnp.exp(params["log_sigma"]) * eps

    def log_prob(self, params: Params, z: jnp.ndarray) -> jnp.ndarray:
        sigma = jnp.exp(params["log_sigma"])
        eps = (z - params["mu"]) / sigma
        return -0.5 * jnp.sum(eps**2) - jnp.sum(params["log_sigma"]) - 0.5 * self.dim * _LOG_2PI

    def entropy(self, params: Params) -> jnp.ndarray:
        return jnp.sum(params["log_sigma"]) + 0.5 * self.dim * (1.0 + _LOG_2PI)

    def to_moments(self, params: Params):
        """(mean, marginal std) — consumed by the Wasserstein barycenter."""
        return params["mu"], jnp.exp(params["log_sigma"])

    def from_moments(self, mu: jnp.ndarray, sigma: jnp.ndarray) -> Params:
        return {"mu": mu, "log_sigma": jnp.log(sigma)}


@register_family("cholesky")
@dataclasses.dataclass(frozen=True)
class CholeskyGaussian(VariationalFamily):
    """z = mu + sigma ⊙ (L eps), L lower-unitriangular (paper §3.1).

    Covariance = D L Lᵀ D with D = diag(sigma); log|det| = Σ log sigma.
    """

    dim: int

    has_moments = True
    moment_form = "full"

    def param_shapes(self) -> Dict[str, Tuple[int, ...]]:
        return {
            "mu": (self.dim,),
            "log_sigma": (self.dim,),
            "L_packed": (self.dim * (self.dim - 1) // 2,),
        }

    def init(self, key, mu_scale: float = 0.01, log_sigma_init: float = -2.0) -> Params:
        n_off = self.dim * (self.dim - 1) // 2
        return {
            "mu": mu_scale * jax.random.normal(key, (self.dim,)),
            "log_sigma": jnp.full((self.dim,), log_sigma_init, dtype=jnp.float32),
            "L_packed": jnp.zeros((n_off,)),
        }

    def _chol(self, params: Params) -> jnp.ndarray:
        sigma = jnp.exp(params["log_sigma"])
        L = _unpack_unitriangular(params["L_packed"], self.dim)
        return sigma[:, None] * L  # scaled Cholesky factor of the covariance

    def sample(self, params: Params, eps: jnp.ndarray) -> jnp.ndarray:
        L = _unpack_unitriangular(params["L_packed"], self.dim)
        return params["mu"] + jnp.exp(params["log_sigma"]) * (L @ eps)

    def log_prob(self, params: Params, z: jnp.ndarray) -> jnp.ndarray:
        scaled = self._chol(params)
        eps = jax.scipy.linalg.solve_triangular(scaled, z - params["mu"], lower=True)
        return -0.5 * jnp.sum(eps**2) - jnp.sum(params["log_sigma"]) - 0.5 * self.dim * _LOG_2PI

    def entropy(self, params: Params) -> jnp.ndarray:
        return jnp.sum(params["log_sigma"]) + 0.5 * self.dim * (1.0 + _LOG_2PI)

    def covariance(self, params: Params) -> jnp.ndarray:
        chol = self._chol(params)
        return chol @ chol.T

    def to_moments(self, params: Params):
        """(mean, full covariance) — consumed by the full-Σ barycenter."""
        return params["mu"], self.covariance(params)

    def from_moments(self, mu: jnp.ndarray, cov: jnp.ndarray) -> Params:
        chol = jnp.linalg.cholesky(cov)
        diag = jnp.diagonal(chol)
        L = chol / diag[:, None]
        rows, cols = _tril_indices(self.dim)
        packed = L[rows, cols] if self.dim > 1 else jnp.zeros((0,), mu.dtype)
        return {"mu": mu, "log_sigma": jnp.log(diag), "L_packed": packed}


@register_family("lowrank")
@dataclasses.dataclass(frozen=True)
class LowRankGaussian(VariationalFamily):
    """z = mu + sigma ⊙ eps_d + U eps_r  with  Σ = diag(σ²) + U Uᵀ.

    The classic diag-plus-low-rank posterior: O(d·r) parameters capture
    the r strongest posterior correlation directions without the O(d²)
    cost of :class:`CholeskyGaussian`. ``eps_shape`` is ``(dim + rank,)``
    — the first ``dim`` coordinates drive the diagonal part, the last
    ``rank`` the factor. ``log_prob`` uses the Woodbury identity and the
    matrix determinant lemma, so it stays O(d·r² + r³).

    Not in the paper — this family exists to prove the
    :class:`~repro.core.family.VariationalFamily` protocol is open: it
    plugs into the runtime, the flat wire format and the generic
    barycenter merge (``moment_form == "full"``) with no changes
    anywhere else.
    """

    dim: int
    rank: int = 1

    has_moments = True
    moment_form = "full"

    def __post_init__(self):
        if not 1 <= self.rank <= self.dim:
            raise ValueError(
                f"rank must be in [1, dim={self.dim}], got {self.rank}")

    def param_shapes(self) -> Dict[str, Tuple[int, ...]]:
        return {
            "mu": (self.dim,),
            "log_sigma": (self.dim,),
            "U": (self.dim, self.rank),
        }

    @property
    def eps_shape(self) -> Tuple[int, ...]:
        return (self.dim + self.rank,)

    def init(self, key, mu_scale: float = 0.01, log_sigma_init: float = -2.0) -> Params:
        return {
            "mu": mu_scale * jax.random.normal(key, (self.dim,)),
            "log_sigma": jnp.full((self.dim,), log_sigma_init, dtype=jnp.float32),
            "U": jnp.zeros((self.dim, self.rank)),
        }

    def sample(self, params: Params, eps: jnp.ndarray) -> jnp.ndarray:
        eps_d, eps_r = eps[: self.dim], eps[self.dim :]
        return (
            params["mu"]
            + jnp.exp(params["log_sigma"]) * eps_d
            + params["U"] @ eps_r
        )

    def _capacitance(self, params: Params) -> jnp.ndarray:
        """M = I_r + Uᵀ D⁻¹ U with D = diag(σ²) (the Woodbury core)."""
        inv_d = jnp.exp(-2.0 * params["log_sigma"])
        u = params["U"]
        return jnp.eye(self.rank, dtype=u.dtype) + (u.T * inv_d) @ u

    def _logdet(self, params: Params) -> jnp.ndarray:
        """log|Σ| = Σ log σ² + log|M| (matrix determinant lemma)."""
        _, logdet_m = jnp.linalg.slogdet(self._capacitance(params))
        return 2.0 * jnp.sum(params["log_sigma"]) + logdet_m

    def log_prob(self, params: Params, z: jnp.ndarray) -> jnp.ndarray:
        inv_d = jnp.exp(-2.0 * params["log_sigma"])
        u = params["U"]
        x = z - params["mu"]
        dx = inv_d * x
        # Woodbury: Σ⁻¹x = D⁻¹x − D⁻¹U M⁻¹ Uᵀ D⁻¹ x
        w = jnp.linalg.solve(self._capacitance(params), u.T @ dx)
        quad = jnp.dot(x, dx) - jnp.dot(u.T @ dx, w)
        return -0.5 * quad - 0.5 * self._logdet(params) - 0.5 * self.dim * _LOG_2PI

    def entropy(self, params: Params) -> jnp.ndarray:
        return 0.5 * self._logdet(params) + 0.5 * self.dim * (1.0 + _LOG_2PI)

    def covariance(self, params: Params) -> jnp.ndarray:
        u = params["U"]
        return jnp.diag(jnp.exp(2.0 * params["log_sigma"])) + u @ u.T

    def to_moments(self, params: Params):
        """(mean, full covariance) — the barycenter's ``"full"`` form."""
        return params["mu"], self.covariance(params)

    def from_moments(self, mu: jnp.ndarray, cov: jnp.ndarray,
                     num_iters: int = 200) -> Params:
        """Best diag + rank-r fit of ``cov`` by alternating projection.

        Alternates (a) the top-r eigenpair factor of ``cov − diag(s)``
        and (b) the diagonal that matches ``diag(cov)`` given the
        factor, starting from the Guttman bound ``1 / diag(Σ⁻¹)``
        (which under-counts the factor mass less than ``diag(Σ)``).
        The rate is linear, so this is a PROJECTION, not an exact
        inverse: for Σ of the family's own form it converges to the
        true factorization (U up to right-rotation — every density
        unchanged), for a general PSD matrix to a locally-best
        diag + rank-r approximation.
        """
        r = self.rank

        def body(_, carry):
            diag_s, _u = carry
            vals, vecs = jnp.linalg.eigh(cov - jnp.diag(diag_s))
            top = jnp.clip(vals[-r:], 0.0, None)
            u = vecs[:, -r:] * jnp.sqrt(top)
            diag_s = jnp.clip(
                jnp.diagonal(cov) - jnp.sum(u * u, axis=1), 1e-12, None)
            return diag_s, u

        init = (jnp.clip(1.0 / jnp.diagonal(jnp.linalg.inv(cov)), 1e-12,
                         None),
                jnp.zeros((self.dim, r), cov.dtype))
        diag_s, u = jax.lax.fori_loop(0, num_iters, body, init)
        return {"mu": mu, "log_sigma": 0.5 * jnp.log(diag_s), "U": u}


@register_family("conditional")
@dataclasses.dataclass(frozen=True)
class ConditionalGaussian(VariationalFamily):
    """q(Z_L | Z_G) = N(mu_bar + C (z_G − mu_G), D L Lᵀ D)  (paper §3.1).

    ``use_coupling=False`` drops C (mean-field across the G/L boundary);
    ``use_chol=False`` sets L ≡ I (the paper does this for the GLMM, where
    the local latents are conditionally independent a posteriori).
    """

    dim: int
    global_dim: int
    use_coupling: bool = True
    use_chol: bool = False

    conditional = True

    def param_shapes(self) -> Dict[str, Tuple[int, ...]]:
        shapes: Dict[str, Tuple[int, ...]] = {
            "mu_bar": (self.dim,),
            "log_sigma": (self.dim,),
        }
        if self.use_coupling:
            shapes["C"] = (self.dim, self.global_dim)
        if self.use_chol:
            shapes["L_packed"] = (self.dim * (self.dim - 1) // 2,)
        return shapes

    def mean(self, params: Params) -> jnp.ndarray:
        return params["mu_bar"]

    def init(self, key, mu_scale: float = 0.01, log_sigma_init: float = -2.0) -> Params:
        k1, _ = jax.random.split(key)
        params = {
            "mu_bar": mu_scale * jax.random.normal(k1, (self.dim,)),
            "log_sigma": jnp.full((self.dim,), log_sigma_init, dtype=jnp.float32),
        }
        if self.use_coupling:
            params["C"] = jnp.zeros((self.dim, self.global_dim))
        if self.use_chol:
            params["L_packed"] = jnp.zeros((self.dim * (self.dim - 1) // 2,))
        return params

    def _cond_mean(self, params: Params, z_G, mu_G):
        mean = params["mu_bar"]
        if self.use_coupling:
            mean = mean + params["C"] @ (z_G - mu_G)
        return mean

    def sample(self, params: Params, z_G: jnp.ndarray, mu_G: jnp.ndarray, eps: jnp.ndarray):
        noise = eps
        if self.use_chol:
            L = _unpack_unitriangular(params["L_packed"], self.dim)
            noise = L @ eps
        return self._cond_mean(params, z_G, mu_G) + jnp.exp(params["log_sigma"]) * noise

    def log_prob(self, params: Params, z_L: jnp.ndarray, z_G: jnp.ndarray, mu_G: jnp.ndarray):
        resid = z_L - self._cond_mean(params, z_G, mu_G)
        if self.use_chol:
            L = _unpack_unitriangular(params["L_packed"], self.dim)
            scaled = jnp.exp(params["log_sigma"])[:, None] * L
            eps = jax.scipy.linalg.solve_triangular(scaled, resid, lower=True)
        else:
            eps = resid / jnp.exp(params["log_sigma"])
        return -0.5 * jnp.sum(eps**2) - jnp.sum(params["log_sigma"]) - 0.5 * self.dim * _LOG_2PI

    def entropy(self, params: Params) -> jnp.ndarray:
        """H[q(Z_L | Z_G)] — independent of z_G (L is unitriangular)."""
        return jnp.sum(params["log_sigma"]) + 0.5 * self.dim * (1.0 + _LOG_2PI)


@register_family("batched_diag")
@dataclasses.dataclass(frozen=True)
class BatchedDiagGaussian(VariationalFamily):
    """A batch of independent diagonal Gaussians, e.g. per-document W_k in
    ProdLDA or per-silo adapters in the LLM configs. Shape (batch, dim)."""

    batch: int
    dim: int

    has_moments = True
    moment_form = "diag"

    def param_shapes(self) -> Dict[str, Tuple[int, ...]]:
        return {
            "mu": (self.batch, self.dim),
            "log_sigma": (self.batch, self.dim),
        }

    @property
    def batch_shape(self) -> Tuple[int, ...]:
        return (self.batch,)

    def init(self, key, mu_scale: float = 0.01, log_sigma_init: float = -2.0) -> Params:
        return {
            "mu": mu_scale * jax.random.normal(key, (self.batch, self.dim)),
            "log_sigma": jnp.full((self.batch, self.dim), log_sigma_init, dtype=jnp.float32),
        }

    def sample(self, params: Params, eps: jnp.ndarray) -> jnp.ndarray:
        return params["mu"] + jnp.exp(params["log_sigma"]) * eps

    def log_prob(self, params: Params, z: jnp.ndarray) -> jnp.ndarray:
        sigma = jnp.exp(params["log_sigma"])
        eps = (z - params["mu"]) / sigma
        return (
            -0.5 * jnp.sum(eps**2)
            - jnp.sum(params["log_sigma"])
            - 0.5 * self.batch * self.dim * _LOG_2PI
        )

    def entropy(self, params: Params) -> jnp.ndarray:
        return (jnp.sum(params["log_sigma"])
                + 0.5 * self.batch * self.dim * (1.0 + _LOG_2PI))

    def to_moments(self, params: Params):
        """(mean, marginal std), both (batch, dim) — elementwise diag form."""
        return params["mu"], jnp.exp(params["log_sigma"])

    def from_moments(self, mu: jnp.ndarray, sigma: jnp.ndarray) -> Params:
        return {"mu": mu, "log_sigma": jnp.log(sigma)}
