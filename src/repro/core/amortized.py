"""Amortized inference (paper Remark, §3.2): instead of training η_{L_j}
directly, an inference network f_φ maps each observation to its local
variational parameters — η_{L_{j,k}} = f_φ(y_{j,k}, Z_G), with φ ∈ θ.

In SFVI this slots in transparently: φ is part of θ, so it is trained by
the same summed silo gradients g_j^θ and never exposes per-observation
posteriors; the silo evaluates its own encoder on its own data. The
encoder is a small MLP producing (μ, log σ) per observation.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

_LOG_2PI = math.log(2.0 * math.pi)


def encoder_init(key, in_dim: int, hidden: int, latent_dim: int) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    s1 = 1.0 / math.sqrt(in_dim)
    s2 = 1.0 / math.sqrt(hidden)
    return {
        "w1": s1 * jax.random.normal(k1, (in_dim, hidden)),
        "b1": jnp.zeros((hidden,)),
        "w_mu": s2 * jax.random.normal(k2, (hidden, latent_dim)),
        "b_mu": jnp.zeros((latent_dim,)),
        "w_ls": 0.1 * s2 * jax.random.normal(k3, (hidden, latent_dim)),
        "b_ls": jnp.full((latent_dim,), -1.0),
    }


def encoder_warm_init(in_dim: int, latent_dim: int, *, pre_scale: float = 0.1,
                      gain: float = 1.0, log_sigma: float = -1.0
                      ) -> Dict[str, Any]:
    """Deterministic near-linear encoder for cold-silo warm starts.

    A closed-form φ (no PRNG draw, so a resumed run re-derives it
    bit-exactly): the hidden layer is a scaled identity kept inside
    tanh's linear regime, and the mean head averages it back out, so
    ``encode(φ, y)[0][k] ≈ gain · mean_i(y[k, i])`` per observation —
    the data-mean statistic a joining silo's ``η_L`` should start from
    (population engine, :mod:`repro.federated.population`). The
    log-σ head is constant at ``log_sigma``.
    """
    w1 = pre_scale * jnp.eye(in_dim)
    w_mu = jnp.full((in_dim, latent_dim), gain / (pre_scale * in_dim))
    return {
        "w1": w1,
        "b1": jnp.zeros((in_dim,)),
        "w_mu": w_mu,
        "b_mu": jnp.zeros((latent_dim,)),
        "w_ls": jnp.zeros((in_dim, latent_dim)),
        "b_ls": jnp.full((latent_dim,), log_sigma),
    }


def encode(phi: Dict[str, Any], y: jnp.ndarray):
    """y: (N, in_dim) -> (mu, log_sigma), each (N, latent_dim)."""
    h = jnp.tanh(y @ phi["w1"] + phi["b1"])
    return h @ phi["w_mu"] + phi["b_mu"], h @ phi["w_ls"] + phi["b_ls"]


def sample_local(phi, y, eps):
    """z_{L,k} = mu_k + sigma_k * eps_k per observation; eps: (N, latent)."""
    mu, ls = encode(phi, y)
    return mu + jnp.exp(ls) * eps


def log_q_local(phi, y, z, stop_params: bool = True):
    """Σ_k log q(z_k ; f_φ(y_k)) with the STL stop-gradient on φ."""
    if stop_params:
        phi = jax.tree_util.tree_map(jax.lax.stop_gradient, phi)
    mu, ls = encode(phi, y)
    e = (z - mu) * jnp.exp(-ls)
    return (
        -0.5 * jnp.sum(e * e) - jnp.sum(ls) - 0.5 * z.size * _LOG_2PI
    )
