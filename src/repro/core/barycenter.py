"""2-Wasserstein barycenters of Gaussians (paper §3.2, point 3).

For Gaussians {N(μ_j, Σ_j)} the barycenter is Gaussian (Mallasto & Feragen
2017, Thm 4) with

    μ* = J⁻¹ Σ_j μ_j
    Σ* = the unique PSD root of   Σ* = J⁻¹ Σ_j (Σ*^{1/2} Σ_j Σ*^{1/2})^{1/2}

solved by fixed-point iteration (Álvarez-Esteban et al., 2016). When every
Σ_j is diagonal the solution is analytic:  Σ* = (J⁻¹ Σ_j Σ_j^{1/2})².

Two matrix-sqrt backends are provided:
  * ``sqrtm_eigh``  — eigendecomposition; exact, host/runtime friendly.
  * ``sqrtm_newton_schulz`` — pure-matmul Newton–Schulz iteration; this is
    the TPU-native form (MXU-friendly, no data-dependent control flow) used
    inside jitted/sharded graphs.
"""
from __future__ import annotations

import inspect
from typing import Sequence

import jax
import jax.numpy as jnp


def diag_barycenter(mus: jnp.ndarray, sigmas: jnp.ndarray, weights=None):
    """Analytic barycenter for diagonal Gaussians.

    Args:
      mus:    (J, d) stacked means.
      sigmas: (J, d) stacked marginal standard deviations.
      weights: optional (J,) simplex weights (default uniform — the paper's J⁻¹).

    Returns (mu*, sigma*): each (d,).
    """
    if weights is None:
        mu = jnp.mean(mus, axis=0)
        sigma = jnp.mean(sigmas, axis=0)  # ((1/J) Σ Σ_j^{1/2}) — std is sqrt(Σ) already
    else:
        w = weights[:, None]
        mu = jnp.sum(w * mus, axis=0)
        sigma = jnp.sum(w * sigmas, axis=0)
    return mu, sigma


def sqrtm_eigh(mat: jnp.ndarray) -> jnp.ndarray:
    """PSD matrix square root via symmetric eigendecomposition."""
    vals, vecs = jnp.linalg.eigh(mat)
    vals = jnp.clip(vals, 0.0, None)
    return (vecs * jnp.sqrt(vals)) @ vecs.T


def sqrtm_newton_schulz(mat: jnp.ndarray, num_iters: int = 25) -> jnp.ndarray:
    """Newton–Schulz iteration for the PSD square root — matmuls only.

    Converges quadratically for ||I − A/||A||||₂ < 1, which holds for PSD A.
    This is the in-graph (TPU/MXU) backend: no eigh, no branching.
    """
    dim = mat.shape[-1]
    norm = jnp.sqrt(jnp.sum(mat * mat)) + 1e-12
    y = mat / norm
    z = jnp.eye(dim, dtype=mat.dtype)
    eye3 = 3.0 * jnp.eye(dim, dtype=mat.dtype)

    def body(_, carry):
        y, z = carry
        t = 0.5 * (eye3 - z @ y)
        return (y @ t, t @ z)

    y, _ = jax.lax.fori_loop(0, num_iters, body, (y, z))
    return y * jnp.sqrt(norm)


def gaussian_barycenter_cov(
    covs: jnp.ndarray,
    weights=None,
    num_fp_iters: int = 50,
    sqrtm=sqrtm_eigh,
) -> jnp.ndarray:
    """Fixed-point iteration for the barycenter covariance (full Σ_j).

    Args:
      covs: (J, d, d) stacked covariance matrices.
      weights: optional (J,) simplex weights.
      num_fp_iters: outer fixed-point iterations.
      sqrtm: matrix-sqrt backend (eigh or Newton–Schulz).
    """
    J, d, _ = covs.shape
    w = jnp.full((J,), 1.0 / J) if weights is None else weights

    def step(_, cov):
        root = sqrtm(cov)
        inner = jax.vmap(lambda c: sqrtm(root @ c @ root))(covs)
        mixed = jnp.einsum("j,jab->ab", w, inner)
        # Enforce symmetry against fp drift.
        return 0.5 * (mixed + mixed.T)

    init = jnp.einsum("j,jab->ab", w, covs)  # start from the linear mixture
    return jax.lax.fori_loop(0, num_fp_iters, step, init)


def gaussian_barycenter(mus: jnp.ndarray, covs: jnp.ndarray, weights=None, **kw):
    """(μ*, Σ*) for full-covariance Gaussians."""
    if weights is None:
        mu = jnp.mean(mus, axis=0)
    else:
        mu = jnp.einsum("j,jd->d", weights, mus)
    return mu, gaussian_barycenter_cov(covs, weights=weights, **kw)


def wasserstein2_gaussian(mu1, cov1, mu2, cov2, sqrtm=sqrtm_eigh) -> jnp.ndarray:
    """Squared 2-Wasserstein distance between Gaussians (Bures metric).

    W₂² = ||μ₁−μ₂||² + tr(Σ₁ + Σ₂ − 2 (Σ₁^{1/2} Σ₂ Σ₁^{1/2})^{1/2})
    """
    root1 = sqrtm(cov1)
    cross = sqrtm(root1 @ cov2 @ root1)
    bures = jnp.trace(cov1) + jnp.trace(cov2) - 2.0 * jnp.trace(cross)
    return jnp.sum((mu1 - mu2) ** 2) + jnp.clip(bures, 0.0, None)


def barycenter_params_diag(family, params_list: Sequence[dict]) -> dict:
    """Barycenter in *parameter space representation* for DiagGaussian params."""
    mus = jnp.stack([p["mu"] for p in params_list])
    sigmas = jnp.stack([jnp.exp(p["log_sigma"]) for p in params_list])
    mu, sigma = diag_barycenter(mus, sigmas)
    return family.from_moments(mu, sigma)


def barycenter_params_full(family, params_list: Sequence[dict], **kw) -> dict:
    """Barycenter for CholeskyGaussian params (full covariance)."""
    mus = jnp.stack([p["mu"] for p in params_list])
    covs = jnp.stack([family.covariance(p) for p in params_list])
    mu, cov = gaussian_barycenter(mus, covs, **kw)
    return family.from_moments(mu, cov)


def family_barycenter(
    family,
    stacked_params,
    weights: jnp.ndarray,
    aggregator=None,
    *,
    sqrtm=sqrtm_newton_schulz,
    num_fp_iters: int = 50,
    sqrtm_iters: int = 40,
):
    """W2 barycenter of J family members — generic over the moment bridge.

    The §3.2 η_G merge for ANY family implementing the
    :class:`~repro.core.family.VariationalFamily` moment protocol
    (``has_moments``): map the stacked parameters to moments with
    ``vmap(to_moments)``, merge in moment space, map back with
    ``from_moments``. Dispatch is on ``family.moment_form``:

      * ``"diag"`` — the analytic solution (mean of μ_j, mean of σ_j;
        Mallasto & Feragen 2017). The plugged-in ``aggregator`` performs
        both means, so a trimmed-mean scenario robustifies the merge
        exactly as it robustifies every other reduction.
      * ``"full"`` — the Álvarez-Esteban et al. (2016) fixed point on
        the stacked covariances, weights normalized to the simplex. The
        default Newton–Schulz square root keeps the whole merge inside
        the compiled round graph (matmuls only — no eigh, no host
        callback); zero-weight members are excluded by their weight.
        The aggregator still merges the means; rank statistics have no
        canonical covariance analogue, so the covariance fixed point is
        weight-based only.

    Args:
      family: the global family (must have ``has_moments``).
      stacked_params: parameter pytree with a leading (J,) axis.
      weights: (J,) nonnegative aggregation weights (a 0/1 mask, or the
        async engine's staleness-decayed weights).
      aggregator: optional cross-silo combine rule (default: weighted
        mean) applied to the analytic moment merges.
      sqrtm: matrix square-root backend for the ``"full"`` fixed point.
      num_fp_iters: fixed-point iterations for the ``"full"`` form.
      sqrtm_iters: Newton–Schulz iterations per square root.

    Raises:
      ValueError: if the family exposes no moment bridge.
    """
    form = getattr(family, "moment_form", None)
    if not getattr(family, "has_moments", False) or form is None:
        raise ValueError(
            f"eta_mode='barycenter' needs a family with to_moments/"
            f"from_moments; {type(family).__name__} has none — use "
            f"eta_mode='param'")
    means, seconds = jax.vmap(family.to_moments)(stacked_params)

    def combine(stacked):
        if aggregator is not None:
            return aggregator.combine(stacked, weights)
        w = weights / jnp.maximum(jnp.sum(weights), 1e-12)
        return jnp.tensordot(w, stacked, axes=1)

    if form == "diag":
        return family.from_moments(combine(means), combine(seconds))
    if form != "full":
        raise ValueError(f"unknown moment_form {form!r} (diag/full)")
    w = weights / jnp.maximum(jnp.sum(weights), 1e-12)
    # Forward sqrtm_iters to ANY backend exposing a num_iters knob
    # (sqrtm_newton_schulz, a functools.partial of it, a user variant) —
    # an identity check on the function object would silently drop the
    # caller's iteration count for wrapped backends.
    try:
        takes_iters = "num_iters" in inspect.signature(sqrtm).parameters
    except (TypeError, ValueError):
        takes_iters = False
    root = (lambda m: sqrtm(m, num_iters=sqrtm_iters)) if takes_iters \
        else sqrtm
    cov = gaussian_barycenter_cov(
        seconds, weights=w, num_fp_iters=num_fp_iters, sqrtm=root)
    return family.from_moments(combine(means), cov)
