"""2-Wasserstein barycenters of Gaussians (paper §3.2, point 3).

For Gaussians {N(μ_j, Σ_j)} the barycenter is Gaussian (Mallasto & Feragen
2017, Thm 4) with

    μ* = J⁻¹ Σ_j μ_j
    Σ* = the unique PSD root of   Σ* = J⁻¹ Σ_j (Σ*^{1/2} Σ_j Σ*^{1/2})^{1/2}

solved by fixed-point iteration (Álvarez-Esteban et al., 2016). When every
Σ_j is diagonal the solution is analytic:  Σ* = (J⁻¹ Σ_j Σ_j^{1/2})².

Two matrix-sqrt backends are provided:
  * ``sqrtm_eigh``  — eigendecomposition; exact, host/runtime friendly.
  * ``sqrtm_newton_schulz`` — pure-matmul Newton–Schulz iteration; this is
    the TPU-native form (MXU-friendly, no data-dependent control flow) used
    inside jitted/sharded graphs.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def diag_barycenter(mus: jnp.ndarray, sigmas: jnp.ndarray, weights=None):
    """Analytic barycenter for diagonal Gaussians.

    Args:
      mus:    (J, d) stacked means.
      sigmas: (J, d) stacked marginal standard deviations.
      weights: optional (J,) simplex weights (default uniform — the paper's J⁻¹).

    Returns (mu*, sigma*): each (d,).
    """
    if weights is None:
        mu = jnp.mean(mus, axis=0)
        sigma = jnp.mean(sigmas, axis=0)  # ((1/J) Σ Σ_j^{1/2}) — std is sqrt(Σ) already
    else:
        w = weights[:, None]
        mu = jnp.sum(w * mus, axis=0)
        sigma = jnp.sum(w * sigmas, axis=0)
    return mu, sigma


def sqrtm_eigh(mat: jnp.ndarray) -> jnp.ndarray:
    """PSD matrix square root via symmetric eigendecomposition."""
    vals, vecs = jnp.linalg.eigh(mat)
    vals = jnp.clip(vals, 0.0, None)
    return (vecs * jnp.sqrt(vals)) @ vecs.T


def sqrtm_newton_schulz(mat: jnp.ndarray, num_iters: int = 25) -> jnp.ndarray:
    """Newton–Schulz iteration for the PSD square root — matmuls only.

    Converges quadratically for ||I − A/||A||||₂ < 1, which holds for PSD A.
    This is the in-graph (TPU/MXU) backend: no eigh, no branching.
    """
    dim = mat.shape[-1]
    norm = jnp.sqrt(jnp.sum(mat * mat)) + 1e-12
    y = mat / norm
    z = jnp.eye(dim, dtype=mat.dtype)
    eye3 = 3.0 * jnp.eye(dim, dtype=mat.dtype)

    def body(_, carry):
        y, z = carry
        t = 0.5 * (eye3 - z @ y)
        return (y @ t, t @ z)

    y, _ = jax.lax.fori_loop(0, num_iters, body, (y, z))
    return y * jnp.sqrt(norm)


def gaussian_barycenter_cov(
    covs: jnp.ndarray,
    weights=None,
    num_fp_iters: int = 50,
    sqrtm=sqrtm_eigh,
) -> jnp.ndarray:
    """Fixed-point iteration for the barycenter covariance (full Σ_j).

    Args:
      covs: (J, d, d) stacked covariance matrices.
      weights: optional (J,) simplex weights.
      num_fp_iters: outer fixed-point iterations.
      sqrtm: matrix-sqrt backend (eigh or Newton–Schulz).
    """
    J, d, _ = covs.shape
    w = jnp.full((J,), 1.0 / J) if weights is None else weights

    def step(_, cov):
        root = sqrtm(cov)
        inner = jax.vmap(lambda c: sqrtm(root @ c @ root))(covs)
        mixed = jnp.einsum("j,jab->ab", w, inner)
        # Enforce symmetry against fp drift.
        return 0.5 * (mixed + mixed.T)

    init = jnp.einsum("j,jab->ab", w, covs)  # start from the linear mixture
    return jax.lax.fori_loop(0, num_fp_iters, step, init)


def gaussian_barycenter(mus: jnp.ndarray, covs: jnp.ndarray, weights=None, **kw):
    """(μ*, Σ*) for full-covariance Gaussians."""
    if weights is None:
        mu = jnp.mean(mus, axis=0)
    else:
        mu = jnp.einsum("j,jd->d", weights, mus)
    return mu, gaussian_barycenter_cov(covs, weights=weights, **kw)


def wasserstein2_gaussian(mu1, cov1, mu2, cov2, sqrtm=sqrtm_eigh) -> jnp.ndarray:
    """Squared 2-Wasserstein distance between Gaussians (Bures metric).

    W₂² = ||μ₁−μ₂||² + tr(Σ₁ + Σ₂ − 2 (Σ₁^{1/2} Σ₂ Σ₁^{1/2})^{1/2})
    """
    root1 = sqrtm(cov1)
    cross = sqrtm(root1 @ cov2 @ root1)
    bures = jnp.trace(cov1) + jnp.trace(cov2) - 2.0 * jnp.trace(cross)
    return jnp.sum((mu1 - mu2) ** 2) + jnp.clip(bures, 0.0, None)


def barycenter_params_diag(family, params_list: Sequence[dict]) -> dict:
    """Barycenter in *parameter space representation* for DiagGaussian params."""
    mus = jnp.stack([p["mu"] for p in params_list])
    sigmas = jnp.stack([jnp.exp(p["log_sigma"]) for p in params_list])
    mu, sigma = diag_barycenter(mus, sigmas)
    return family.from_moments(mu, sigma)


def barycenter_params_full(family, params_list: Sequence[dict], **kw) -> dict:
    """Barycenter for CholeskyGaussian params (full covariance)."""
    mus = jnp.stack([p["mu"] for p in params_list])
    covs = jnp.stack([family.covariance(p) for p in params_list])
    mu, cov = gaussian_barycenter(mus, covs, **kw)
    return family.from_moments(mu, cov)
