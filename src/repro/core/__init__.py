"""Core SFVI library — the paper's contribution as composable JAX modules."""
from repro.core.family import (
    FAMILIES,
    FamilySpec,
    VariationalFamily,
    build_family,
    family_names,
    get_family,
    register_family,
)
from repro.core.families import (
    BatchedDiagGaussian,
    CholeskyGaussian,
    ConditionalGaussian,
    DiagGaussian,
    LowRankGaussian,
)
from repro.core.model import StructuredModel, empty_theta
from repro.core.elbo import (elbo_objective, elbo_value, iwae_objective,
                             iwae_value, stl_objective)
from repro.core.sfvi import SFVIProblem
from repro.core.barycenter import (
    diag_barycenter,
    family_barycenter,
    gaussian_barycenter,
    gaussian_barycenter_cov,
    sqrtm_eigh,
    sqrtm_newton_schulz,
    wasserstein2_gaussian,
)
from repro.core.runtime import (
    CommLog,
    SFVIAvgServer,
    SFVIServer,
    Silo,
    tree_add,
    tree_bytes,
    tree_mean,
    tree_scale,
)

__all__ = [
    "FAMILIES",
    "FamilySpec",
    "VariationalFamily",
    "build_family",
    "family_names",
    "get_family",
    "register_family",
    "BatchedDiagGaussian",
    "CholeskyGaussian",
    "ConditionalGaussian",
    "DiagGaussian",
    "LowRankGaussian",
    "StructuredModel",
    "empty_theta",
    "elbo_objective",
    "elbo_value",
    "iwae_objective",
    "iwae_value",
    "stl_objective",
    "SFVIProblem",
    "diag_barycenter",
    "family_barycenter",
    "gaussian_barycenter",
    "gaussian_barycenter_cov",
    "sqrtm_eigh",
    "sqrtm_newton_schulz",
    "wasserstein2_gaussian",
    "CommLog",
    "SFVIAvgServer",
    "SFVIServer",
    "Silo",
    "tree_add",
    "tree_bytes",
    "tree_mean",
    "tree_scale",
]
