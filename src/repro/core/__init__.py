"""Core SFVI library — the paper's contribution as composable JAX modules."""
from repro.core.families import (
    BatchedDiagGaussian,
    CholeskyGaussian,
    ConditionalGaussian,
    DiagGaussian,
)
from repro.core.model import StructuredModel, empty_theta
from repro.core.elbo import (elbo_objective, elbo_value, iwae_objective,
                             iwae_value, stl_objective)
from repro.core.sfvi import SFVIProblem
from repro.core.barycenter import (
    diag_barycenter,
    gaussian_barycenter,
    gaussian_barycenter_cov,
    sqrtm_eigh,
    sqrtm_newton_schulz,
    wasserstein2_gaussian,
)
from repro.core.runtime import (
    CommLog,
    SFVIAvgServer,
    SFVIServer,
    Silo,
    tree_add,
    tree_bytes,
    tree_mean,
    tree_scale,
)

__all__ = [
    "BatchedDiagGaussian",
    "CholeskyGaussian",
    "ConditionalGaussian",
    "DiagGaussian",
    "StructuredModel",
    "empty_theta",
    "elbo_objective",
    "elbo_value",
    "iwae_objective",
    "iwae_value",
    "stl_objective",
    "SFVIProblem",
    "diag_barycenter",
    "gaussian_barycenter",
    "gaussian_barycenter_cov",
    "sqrtm_eigh",
    "sqrtm_newton_schulz",
    "wasserstein2_gaussian",
    "CommLog",
    "SFVIAvgServer",
    "SFVIServer",
    "Silo",
    "tree_add",
    "tree_bytes",
    "tree_mean",
    "tree_scale",
]
