"""SFVI — Structured Federated Variational Inference (paper Algorithm 1 + supplement S1).

The federated decomposition rests on the block upper-triangular reparametrization
Jacobian (S1): the STL gradient splits into

    ∇̂_{η_G} L   = (∂f_G/∂η_G)ᵀ ∇_{Z_G} L̂_0  +  Σ_j g_j^η          (S4)
    g_j^η       = (∂f_G/∂η_G)ᵀ ∇_{Z_G} L̂_j + (∂f_{η'_j}/∂η_G)ᵀ ∇_{Z_L} L̂_j   (S5)
    ∇̂_{η_{L_j}} L = (∂f_{η'_j}/∂η_{L_j})ᵀ ∇_{Z_L} L̂_j               (S6)
    ∇_θ L̂       = ∇_θ log p_θ(Z_G) + Σ_j g_j^θ                      (S7)

with L̂_0 = log[p_θ(Z_G)/q_{η_G}(Z_G)] and L̂_j = log[p_θ(y_j, Z_{L_j}|Z_G)/q(Z_{L_j}|Z_G)].

Everything a silo ships to the server is (g_j^θ, g_j^η) — sums of
global-shaped pytrees. Nothing about η_{L_j}, Z_{L_j} or y_j leaves the silo.

All four gradients fall out of ``jax.grad`` applied to the right closures with
stop-gradient on the variational parameters *inside the log q terms only*
(the STL trick); this module is therefore a direct executable transcription
of the supplement's algebra.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.family import eps_shape, is_conditional
from repro.core.model import StructuredModel

PyTree = Any


def _stop(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jax.lax.stop_gradient, tree)


@dataclasses.dataclass(frozen=True)
class SFVIProblem:
    """Bundles the generative model with the variational families."""

    model: StructuredModel
    global_family: Any  # VariationalFamily over Z_G (diag/cholesky/lowrank)
    local_family: Optional[Any] = None  # family over Z_{L_j} (conditional or batched)

    # ---- objective pieces -------------------------------------------------

    def hat_L0(self, theta: PyTree, eta_G: PyTree, eps_G: jnp.ndarray) -> jnp.ndarray:
        """L̂_0 = log p_θ(Z_G) − log q_{η_G}(Z_G), STL-stopped inside log q."""
        z_G = self.global_family.sample(eta_G, eps_G)
        logq = self.global_family.log_prob(_stop(eta_G), z_G)
        return self.model.log_prior_global(theta, z_G) - logq

    def hat_Lj(
        self,
        theta: PyTree,
        eta_G: PyTree,
        eta_Lj: Optional[PyTree],
        eps_G: jnp.ndarray,
        eps_Lj: Optional[jnp.ndarray],
        data_j: Any,
        likelihood_scale: float = 1.0,
    ) -> jnp.ndarray:
        """L̂_j = log p_θ(y_j, Z_{L_j}|Z_G) − log q_{η_{L_j}}(Z_{L_j}|Z_G).

        ``likelihood_scale`` implements SFVI-Avg's N/N_j rescaling (§3.2, point 2);
        SFVI uses 1.0.
        """
        z_G = self.global_family.sample(eta_G, eps_G)
        if self.model.has_local:
            z_L = self._sample_local(eta_Lj, z_G, eta_G, eps_Lj)
            logq = self._log_prob_local(_stop(eta_Lj), z_L, z_G, _stop(eta_G))
        else:
            z_L, logq = None, 0.0
        loglik = self.model.log_local(theta, z_G, z_L, data_j)
        return likelihood_scale * (loglik - logq)

    def _global_mean(self, eta_G):
        mean = getattr(self.global_family, "mean", None)
        return mean(eta_G) if mean is not None else eta_G["mu"]

    def _sample_local(self, eta_Lj, z_G, eta_G, eps_Lj):
        fam = self.local_family
        if is_conditional(fam):
            return fam.sample(eta_Lj, z_G, self._global_mean(eta_G), eps_Lj)
        # Unconditional local family (no C coupling): ignore z_G.
        return fam.sample(eta_Lj, eps_Lj)

    def _log_prob_local(self, eta_Lj, z_L, z_G, eta_G):
        fam = self.local_family
        if is_conditional(fam):
            return fam.log_prob(eta_Lj, z_L, z_G, self._global_mean(eta_G))
        return fam.log_prob(eta_Lj, z_L)

    # ---- per-silo gradient computation (the silo's inner loop body) -------

    def silo_grads(
        self,
        theta: PyTree,
        eta_G: PyTree,
        eta_Lj: Optional[PyTree],
        eps_G: jnp.ndarray,
        eps_Lj: Optional[jnp.ndarray],
        data_j: Any,
        likelihood_scale: float = 1.0,
    ) -> Tuple[PyTree, PyTree, Optional[PyTree], jnp.ndarray]:
        """Returns (g_j^θ, g_j^η, ∇̂_{η_{L_j}}L, L̂_j).

        A single jax.grad over (θ, η_G, η_{L_j}) of L̂_j realizes (S5)–(S8):
        the autodiff path through the reparametrized samples *is* the
        vector-Jacobian product structure of the supplement.
        """
        if self.model.has_local:
            def obj(th, eg, el):
                return self.hat_Lj(th, eg, el, eps_G, eps_Lj, data_j, likelihood_scale)

            val, grads = jax.value_and_grad(obj, argnums=(0, 1, 2))(theta, eta_G, eta_Lj)
            g_theta, g_eta, g_local = grads
        else:
            def obj(th, eg):
                return self.hat_Lj(th, eg, None, eps_G, None, data_j, likelihood_scale)

            val, grads = jax.value_and_grad(obj, argnums=(0, 1))(theta, eta_G)
            g_theta, g_eta = grads
            g_local = None
        return g_theta, g_eta, g_local, val

    def server_grads(
        self, theta: PyTree, eta_G: PyTree, eps_G: jnp.ndarray
    ) -> Tuple[PyTree, PyTree, jnp.ndarray]:
        """The server's own contribution: gradients of L̂_0 (prior & entropy terms)."""
        val, (g_theta, g_eta) = jax.value_and_grad(self.hat_L0, argnums=(0, 1))(
            theta, eta_G, eps_G
        )
        return g_theta, g_eta, val

    # ---- single-machine reference (for the partition-invariance Remark) ---

    def centralized_objective(
        self,
        theta: PyTree,
        eta_G: PyTree,
        eta_L_all: Optional[list],
        eps_G: jnp.ndarray,
        eps_L_all: Optional[list],
        data_all: list,
    ) -> jnp.ndarray:
        """L̂ = L̂_0 + Σ_j L̂_j computed in one graph — the single-silo answer.

        The paper's Remark (§3): SFVI is invariant to data partitioning; this
        function is the oracle the property test compares against.
        """
        total = self.hat_L0(theta, eta_G, eps_G)
        for j, data_j in enumerate(data_all):
            eta_Lj = eta_L_all[j] if eta_L_all is not None else None
            eps_Lj = eps_L_all[j] if eps_L_all is not None else None
            total = total + self.hat_Lj(theta, eta_G, eta_Lj, eps_G, eps_Lj, data_j)
        return total

    # ---- convenience ------------------------------------------------------

    def sample_posterior(self, eta_G, eta_L, key, num_samples: int = 1):
        """Draw (Z_G, Z_L) from the variational posterior (for prediction)."""
        kG, kL = jax.random.split(key)
        eps_G = jax.random.normal(
            kG, (num_samples,) + eps_shape(self.global_family))
        z_G = jax.vmap(lambda e: self.global_family.sample(eta_G, e))(eps_G)
        if not self.model.has_local or eta_L is None:
            return z_G, None
        eps_L = jax.random.normal(
            kL, (num_samples,) + eps_shape(self.local_family))
        z_L = jax.vmap(lambda zg, e: self._sample_local(eta_L, zg, eta_G, e))(z_G, eps_L)
        return z_G, z_L
