"""First-class variational-family API (paper §2–3.1).

Families used to be ad-hoc duck-typed objects: the runtime probed them
with ``isinstance(fam, ConditionalGaussian)`` and ``hasattr(fam,
"batch")``, and the barycenter merge hard-rejected anything but
``DiagGaussian``. This module replaces those probes with one explicit
contract:

  * :class:`VariationalFamily` — the protocol base every family in
    :mod:`repro.core.families` implements: ``init / sample / log_prob /
    entropy / num_params / pack / unpack`` plus the optional moment
    bridge ``to_moments / from_moments`` (the barycenter surface).
    Capability *flags* replace runtime type probes:

      - ``conditional`` — the family parameterizes q(Z_L | Z_G); its
        ``sample``/``log_prob`` take ``(params, z_G, mu_G, eps)`` /
        ``(params, z_L, z_G, mu_G)`` instead of the unconditional
        ``(params, eps)`` / ``(params, z)``;
      - ``batch_shape`` / ``eps_shape`` — the leading batch axes and
        the full shape of the standard-normal draw ``sample`` consumes
        (replaces every ``hasattr(fam, "batch")`` probe);
      - ``has_moments`` + ``moment_form`` (``"diag"`` | ``"full"``) —
        whether ``to_moments``/``from_moments`` exist and whether the
        second moment is a vector of marginal stds or a full covariance
        (what :func:`repro.core.barycenter.family_barycenter` dispatches
        on).

  * ``FAMILIES`` — a name-keyed registry (``register_family`` /
    ``get_family`` / ``family_names``), so a family is selectable from a
    serialized spec exactly like a model.

  * :class:`FamilySpec` — the declarative ``(name, kwargs)`` node that
    rides on ``ModelSpec`` (``repro.federated.api``) with a lossless
    JSON round trip; :func:`build_family` resolves it against the
    registry, filling the structural dimensions (``dim``,
    ``global_dim``) from the model so specs stay model-agnostic.

The module-level helpers :func:`eps_shape` and :func:`is_conditional`
are the ONLY place legacy duck-typed probing survives (as a fallback for
third-party families that predate the protocol); everything else in the
repo goes through the flags.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Dict, Optional, Tuple, Type

import jax.numpy as jnp

from repro.core.flatten import VectorSpec

Params = Dict[str, jnp.ndarray]


class VariationalFamily:
    """Protocol base class for variational families.

    Concrete families are frozen dataclasses deriving from this base.
    The base supplies the packed-vector bijection (``pack``/``unpack``
    from :meth:`param_shapes`), the derived ``num_params`` /
    ``eps_shape`` and the default capability flags; subclasses implement
    the distribution itself.

    Unconditional families (``conditional = False``)::

        z  = sample(params, eps)         # eps ~ N(0, I) of shape eps_shape
        lp = log_prob(params, z)

    Conditional families (``conditional = True``) parameterize
    q(Z_L | Z_G) and additionally receive the conditioning draw and the
    global mean::

        z  = sample(params, z_G, mu_G, eps)
        lp = log_prob(params, z_L, z_G, mu_G)

    Families with ``has_moments = True`` expose the Gaussian moment
    bridge used by the §3.2 Wasserstein-barycenter merge:
    ``to_moments(params) -> (mean, second)`` and its inverse
    ``from_moments(mean, second)``, where ``second`` is a vector of
    marginal stds (``moment_form == "diag"``) or a full covariance
    matrix (``moment_form == "full"``).
    """

    # -- capability flags (class-level; override in subclasses) -------------
    conditional: ClassVar[bool] = False
    has_moments: ClassVar[bool] = False
    moment_form: ClassVar[Optional[str]] = None  # "diag" | "full" | None

    # -- structure ----------------------------------------------------------

    def param_shapes(self) -> Dict[str, Tuple[int, ...]]:
        """Name -> shape of every parameter leaf (defines the pack layout)."""
        raise NotImplementedError

    @property
    def batch_shape(self) -> Tuple[int, ...]:
        """Leading batch axes of one sample (``()`` for unbatched families)."""
        return ()

    @property
    def eps_shape(self) -> Tuple[int, ...]:
        """Shape of the standard-normal draw ``sample`` consumes."""
        return self.batch_shape + (self.dim,)  # type: ignore[attr-defined]

    @property
    def num_params(self) -> int:
        """Total scalar parameter count (= the packed vector length)."""
        return self.vector_spec.dim

    @property
    def vector_spec(self) -> VectorSpec:
        """The flat-vector bijection over :meth:`param_shapes`."""
        return VectorSpec.create(self.param_shapes())

    def pack(self, params: Params) -> jnp.ndarray:
        """Parameters -> one contiguous ``(num_params,)`` vector."""
        return self.vector_spec.pack(params)

    def unpack(self, vec: jnp.ndarray) -> Params:
        """Inverse of :meth:`pack` (jit-safe: static shapes/slices)."""
        return self.vector_spec.unpack(vec)

    # -- distribution (subclass responsibility) -----------------------------

    def init(self, key, **kwargs) -> Params:
        raise NotImplementedError

    def sample(self, params: Params, *args) -> jnp.ndarray:
        raise NotImplementedError

    def log_prob(self, params: Params, *args) -> jnp.ndarray:
        raise NotImplementedError

    def entropy(self, params: Params) -> jnp.ndarray:
        raise NotImplementedError

    def mean(self, params: Params) -> jnp.ndarray:
        """The (unconditional) mean — the μ the C-coupling centers on."""
        return params["mu"]

    # -- moment bridge (only when has_moments) ------------------------------

    def to_moments(self, params: Params):
        raise NotImplementedError(
            f"{type(self).__name__} exposes no Gaussian moments "
            "(has_moments=False); eta_mode='barycenter' needs a family "
            "with to_moments/from_moments")

    def from_moments(self, mean, second) -> Params:
        raise NotImplementedError(
            f"{type(self).__name__} exposes no Gaussian moments "
            "(has_moments=False)")


# ---------------------------------------------------------------------------
# Probe helpers — the single home of legacy duck-type fallbacks
# ---------------------------------------------------------------------------


def eps_shape(family: Any) -> Tuple[int, ...]:
    """Shape of the N(0, I) draw ``family.sample`` consumes.

    Protocol families answer via ``family.eps_shape``; pre-protocol
    duck-typed families fall back to the historical ``(batch, dim)`` /
    ``(dim,)`` convention. This function is the only place that probe
    lives.
    """
    shape = getattr(family, "eps_shape", None)
    if shape is not None:
        return tuple(shape)
    if hasattr(family, "batch"):  # legacy duck-typed batched family
        return (family.batch, family.dim)
    return (family.dim,)


def is_conditional(family: Any) -> bool:
    """True when ``family`` parameterizes q(Z_L | Z_G) (the C-coupling)."""
    return bool(getattr(family, "conditional", False))


def supports_moments(family: Any) -> bool:
    """True when ``family`` exposes the to_moments/from_moments bridge."""
    return bool(getattr(family, "has_moments", False))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


FAMILIES: Dict[str, Type[VariationalFamily]] = {}


def register_family(name: str):
    """Class decorator: register a family under ``name`` in ``FAMILIES``."""

    def deco(cls: Type[VariationalFamily]) -> Type[VariationalFamily]:
        if name in FAMILIES:
            raise ValueError(f"family {name!r} registered twice")
        FAMILIES[name] = cls
        return cls

    return deco


def _ensure_registered() -> None:
    # The concrete families live in repro.core.families (which imports
    # this module for the base class); importing it here, lazily, fills
    # the registry without a circular import at module load.
    if not FAMILIES:
        import repro.core.families  # noqa: F401


def get_family(name: str) -> Type[VariationalFamily]:
    """Resolve a registered family class; raises with available names."""
    _ensure_registered()
    try:
        return FAMILIES[name]
    except KeyError:
        raise KeyError(
            f"unknown family {name!r}; registered families: "
            + ", ".join(sorted(FAMILIES))
        ) from None


def family_names() -> Tuple[str, ...]:
    """Sorted registered names (CLI choices, docs tables)."""
    _ensure_registered()
    return tuple(sorted(FAMILIES))


# ---------------------------------------------------------------------------
# FamilySpec: the declarative (name, kwargs) node on ModelSpec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FamilySpec:
    """Declarative reference to a registered family.

    ``kwargs`` must be JSON-native (the spec rides inside
    ``ExperimentSpec.to_json``); structural dimensions the model owns
    (``dim``, ``global_dim``) are filled at build time by
    :func:`build_family`, so the same spec applies to any model —
    ``FamilySpec("cholesky")`` upgrades whatever the model's global
    family is to a full unitriangular factor, ``FamilySpec("lowrank",
    {"rank": 2})`` to a diag + rank-2 one.
    """

    name: str
    kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> FamilySpec:
        return cls(name=d["name"], kwargs=dict(d.get("kwargs", {})))


def build_family(
    spec: FamilySpec,
    dim: Optional[int] = None,
    global_dim: Optional[int] = None,
) -> VariationalFamily:
    """Instantiate ``spec`` against the registry.

    ``dim`` / ``global_dim`` are the model-owned structural dimensions;
    they fill the family's matching constructor fields unless the spec's
    kwargs already pin them (explicit kwargs win, e.g. to build a family
    for a different latent block).
    """
    cls = get_family(spec.name)
    kwargs = dict(spec.kwargs)
    fields = dataclasses.fields(cls)
    if dim is not None and any(f.name == "dim" for f in fields):
        kwargs.setdefault("dim", dim)
    if global_dim is not None and any(f.name == "global_dim" for f in fields):
        kwargs.setdefault("global_dim", global_dim)
    missing = [
        f.name for f in fields
        if f.name not in kwargs
        and f.default is dataclasses.MISSING
        and f.default_factory is dataclasses.MISSING
    ]
    if missing:
        raise ValueError(
            f"family {spec.name!r} needs explicit kwargs for {missing} — "
            f"only dim/global_dim are derivable from the model; pass them "
            f"in FamilySpec.kwargs (got {sorted(kwargs)})")
    return cls(**kwargs)
