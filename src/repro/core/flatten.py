"""Flat-vector <-> structured packing (families, latents, wire payloads).

Two bijections, both jit-safe (static shapes/slices):

  * :class:`VectorSpec` — named blocks <-> one flat vector. Variational
    families operate on flat latent vectors while models think in named
    blocks (weights, biases, variance parameters); this is the bridge,
    and it also backs ``VariationalFamily.pack``/``unpack``.
  * :class:`TreeSpec` — an arbitrary pytree of array leaves <-> ONE
    contiguous float32 vector. This is the federated wire format: a
    silo's whole upload (gradients or parameters, however nested) packs
    to a single ``(P,)`` vector, so the stacked federation is a single
    ``(J, P)`` matrix and aggregation / DP clip+noise / quantization /
    the cross-silo gather are all single-array ops instead of per-leaf
    ``tree_map``s.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class VectorSpec:
    shapes: Tuple[Tuple[str, Tuple[int, ...]], ...]

    @staticmethod
    def create(shapes: Dict[str, Tuple[int, ...]]) -> VectorSpec:
        return VectorSpec(tuple((k, tuple(v)) for k, v in shapes.items()))

    @property
    def dim(self) -> int:
        return int(sum(np.prod(s, dtype=np.int64) for _, s in self.shapes))

    def unpack(self, vec: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        out, start = {}, 0
        for name, shape in self.shapes:
            size = int(np.prod(shape, dtype=np.int64))
            out[name] = vec[start : start + size].reshape(shape)
            start += size
        return out

    def pack(self, parts: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        return jnp.concatenate([parts[name].reshape(-1) for name, _ in self.shapes])


@dataclasses.dataclass(frozen=True)
class TreeSpec:
    """Static descriptor of a pytree of array leaves: treedef + shapes.

    ``pack`` flattens every leaf (cast to float32 — the wire dtype) into
    one contiguous ``(dim,)`` vector in treedef leaf order; ``unpack``
    is the exact inverse, restoring shapes, dtypes and structure.
    Hashable and equality-comparable, so it rides into jitted closures
    as a static value.
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[str, ...]

    @classmethod
    def of(cls, tree: Any) -> TreeSpec:
        """Descriptor for ``tree``'s structure (values are ignored)."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        return cls(
            treedef=treedef,
            shapes=tuple(tuple(x.shape) for x in leaves),
            dtypes=tuple(jnp.dtype(x.dtype).name for x in leaves),
        )

    @property
    def dim(self) -> int:
        """Total scalar count P of the packed vector."""
        return int(sum(np.prod(s, dtype=np.int64) for s in self.shapes))

    def pack(self, tree: Any) -> jnp.ndarray:
        """Pytree -> one contiguous (dim,) float32 wire vector."""
        leaves = jax.tree_util.tree_leaves(tree)
        if not leaves:
            return jnp.zeros((0,), jnp.float32)
        return jnp.concatenate(
            [x.astype(jnp.float32).reshape(-1) for x in leaves]
        )

    def unpack(self, vec: jnp.ndarray) -> Any:
        """Inverse of :meth:`pack`: restore shapes, dtypes, structure."""
        leaves, off = [], 0
        for shape, dtype in zip(self.shapes, self.dtypes, strict=True):
            size = int(np.prod(shape, dtype=np.int64))
            leaves.append(vec[off : off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree_util.tree_unflatten(self.treedef, leaves)
