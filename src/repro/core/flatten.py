"""Flat-vector <-> structured-latent packing.

Variational families operate on flat latent vectors; models think in named
blocks (weights, biases, variance parameters). ``VectorSpec`` provides the
bijection, jit-safely (static shapes/slices).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class VectorSpec:
    shapes: Tuple[Tuple[str, Tuple[int, ...]], ...]

    @staticmethod
    def create(shapes: Dict[str, Tuple[int, ...]]) -> "VectorSpec":
        return VectorSpec(tuple((k, tuple(v)) for k, v in shapes.items()))

    @property
    def dim(self) -> int:
        return int(sum(np.prod(s, dtype=np.int64) for _, s in self.shapes))

    def unpack(self, vec: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        out, start = {}, 0
        for name, shape in self.shapes:
            size = int(np.prod(shape, dtype=np.int64))
            out[name] = vec[start : start + size].reshape(shape)
            start += size
        return out

    def pack(self, parts: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        return jnp.concatenate([parts[name].reshape(-1) for name, _ in self.shapes])
