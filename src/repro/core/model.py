"""The structured latent-variable model contract (paper eqs. (1)–(3)).

A model supplies three log-density callables:

    log_prior_global(theta, z_G)          = log p_θ(Z_G)
    log_local(theta, z_G, z_L, data_j)    = log p_θ(y_j, Z_{L_j} | Z_G)
    (optional) predict(theta, z_G, z_L, inputs)

plus the latent dimensionalities. Models with no local latents (e.g. the
empirical-Bayes multinomial regression, where Z_L = ∅) set ``local_dim=0``
and receive ``z_L=None``; models with θ = ∅ pass an empty dict.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp

LogDensity = Callable[..., jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class StructuredModel:
    """Generative model p_θ(Z_G) ∏_j p_θ(y_j, Z_{L_j} | Z_G)."""

    global_dim: int
    local_dim: int  # n_{L_j}; 0 means Z_{L_j} = ∅
    log_prior_global: LogDensity  # (theta, z_G) -> scalar
    log_local: LogDensity  # (theta, z_G, z_L, data_j) -> scalar
    predict: Optional[Callable[..., Any]] = None
    name: str = "structured_model"

    @property
    def has_local(self) -> bool:
        return self.local_dim > 0


def empty_theta() -> dict:
    """θ = ∅ — fully-Bayesian inference over latents only."""
    return {}
