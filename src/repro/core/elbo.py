"""ELBO and the sticking-the-landing (STL) gradient estimator (paper §2, eq. (6)).

The STL estimator is the path derivative of

    L̂ = log p_θ(Z, y) − log q_η̃(Z),     Z = f_η(ε),  η̃ = stop_gradient(η).

Stopping the gradient of the variational parameters *inside log q only*
removes the score term, whose expectation is zero, leaving a lower-variance
estimator that is exact at q = p(·|y). Differentiating ``stl_objective``
w.r.t. η with JAX's autodiff therefore yields (6) — the vector-Jacobian
product the paper highlights as "straightforward in JAX".
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def stl_objective(
    log_joint: Callable[[jnp.ndarray], jnp.ndarray],
    family,
    params,
    eps: jnp.ndarray,
) -> jnp.ndarray:
    """Single-sample STL surrogate: grad w.r.t. ``params`` is the STL gradient."""
    z = family.sample(params, eps)
    params_stop = jax.tree_util.tree_map(jax.lax.stop_gradient, params)
    return log_joint(z) - family.log_prob(params_stop, z)


def elbo_objective(
    log_joint: Callable[[jnp.ndarray], jnp.ndarray],
    family,
    params,
    eps: jnp.ndarray,
) -> jnp.ndarray:
    """Plain (total-derivative) single-sample ELBO estimator, for comparison."""
    z = family.sample(params, eps)
    return log_joint(z) - family.log_prob(params, z)


def elbo_value(
    log_joint: Callable[[jnp.ndarray], jnp.ndarray],
    family,
    params,
    key,
    num_samples: int = 32,
) -> jnp.ndarray:
    """Monte-Carlo ELBO value (no gradient tricks) for monitoring."""
    from repro.core.family import eps_shape

    eps = jax.random.normal(key, (num_samples,) + eps_shape(family))

    def one(e):
        z = family.sample(params, e)
        return log_joint(z) - family.log_prob(params, z)

    return jnp.mean(jax.vmap(one)(eps))


def iwae_objective(
    log_joint: Callable[[jnp.ndarray], jnp.ndarray],
    family,
    params,
    eps: jnp.ndarray,  # (K, dim) — K importance samples
) -> jnp.ndarray:
    """K-sample importance-weighted bound (Burda et al., 2016) with the
    DOUBLY-reparametrized gradient estimator (DReG; Tan et al., 2020 —
    the extension the paper's Discussion names explicitly).

    L_K = E[ log 1/K Σ_k w_k ],  w_k = p(z_k, y)/q(z_k). DReG stops the
    variational parameters inside log q AND squares the normalized
    weights on the path term, removing the score contribution entirely:

        ∇η L_K = E[ Σ_k  ŵ_k²  ∂(log w_k)/∂z_k · ∂z_k/∂η ]

    which this surrogate realizes via a stop-gradient on the normalized
    weights (differentiating it with jax.grad gives the DReG estimator).
    """
    params_stop = jax.tree_util.tree_map(jax.lax.stop_gradient, params)

    def log_w(e):
        z = family.sample(params, e)
        return log_joint(z) - family.log_prob(params_stop, z)

    lw = jax.vmap(log_w)(eps)  # (K,)
    w_norm = jax.lax.stop_gradient(jax.nn.softmax(lw))
    # Surrogate whose gradient is the DReG estimator; its VALUE is the
    # standard IWAE bound estimate.
    surrogate = jnp.sum(w_norm * lw)
    bound = jax.lax.stop_gradient(
        jax.nn.logsumexp(lw) - jnp.log(lw.shape[0]) - surrogate
    )
    return surrogate + bound


def iwae_value(log_joint, family, params, key, num_samples: int = 32) -> jnp.ndarray:
    """Monte-Carlo IWAE bound value (monitoring; >= ELBO in expectation)."""
    dim = getattr(family, "dim")
    eps = jax.random.normal(key, (num_samples, dim))

    def log_w(e):
        z = family.sample(params, e)
        return log_joint(z) - family.log_prob(params, z)

    lw = jax.vmap(log_w)(eps)
    return jax.nn.logsumexp(lw) - jnp.log(float(num_samples))
