"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(value: float):
    return lambda count: jnp.asarray(value, jnp.float32)


def warmup_schedule(base: float, warmup_steps: int):
    def schedule(count):
        frac = jnp.minimum(1.0, (count.astype(jnp.float32) + 1.0) / max(warmup_steps, 1))
        return base * frac

    return schedule


def cosine_decay_schedule(base: float, decay_steps: int, alpha: float = 0.0):
    def schedule(count):
        frac = jnp.clip(count.astype(jnp.float32) / max(decay_steps, 1), 0.0, 1.0)
        cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return base * ((1.0 - alpha) * cosine + alpha)

    return schedule


def linear_warmup_cosine_decay(base: float, warmup_steps: int, total_steps: int, alpha: float = 0.0):
    cos = cosine_decay_schedule(base, max(total_steps - warmup_steps, 1), alpha)

    def schedule(count):
        count_f = count.astype(jnp.float32)
        warm = base * (count_f + 1.0) / max(warmup_steps, 1)
        return jnp.where(count < warmup_steps, warm, cos(count - warmup_steps))

    return schedule
