"""Optimizers implemented from scratch (optax is unavailable offline).

The API mirrors optax's GradientTransformation so the rest of the framework
reads idiomatically: ``init(params) -> state``, ``update(grads, state, params)
-> (updates, state)``, and ``apply_updates(params, updates)``.
"""
from repro.optim.base import (
    GradientTransformation,
    apply_updates,
    chain,
    clip_by_global_norm,
    scale,
    scale_by_schedule,
)
from repro.optim.adam import adam, adamw, scale_by_adam
from repro.optim.sgd import sgd, momentum
from repro.optim.schedules import (
    constant_schedule,
    cosine_decay_schedule,
    linear_warmup_cosine_decay,
    warmup_schedule,
)

__all__ = [
    "GradientTransformation",
    "apply_updates",
    "chain",
    "clip_by_global_norm",
    "scale",
    "scale_by_schedule",
    "adam",
    "adamw",
    "scale_by_adam",
    "sgd",
    "momentum",
    "constant_schedule",
    "cosine_decay_schedule",
    "linear_warmup_cosine_decay",
    "warmup_schedule",
]
