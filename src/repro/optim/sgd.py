"""SGD and momentum — used as baselines and in tests."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.base import GradientTransformation


def sgd(learning_rate: float, maximize: bool = False) -> GradientTransformation:
    sign = 1.0 if maximize else -1.0

    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        return jax.tree_util.tree_map(lambda g: sign * learning_rate * g, grads), state

    return GradientTransformation(init, update)


class MomentumState(NamedTuple):
    velocity: object


def momentum(learning_rate: float, beta: float = 0.9) -> GradientTransformation:
    def init(params):
        return MomentumState(
            velocity=jax.tree_util.tree_map(jnp.zeros_like, params)
        )

    def update(grads, state, params=None):
        del params
        velocity = jax.tree_util.tree_map(
            lambda v, g: beta * v + g, state.velocity, grads
        )
        updates = jax.tree_util.tree_map(lambda v: -learning_rate * v, velocity)
        return updates, MomentumState(velocity=velocity)

    return GradientTransformation(init, update)
