"""Core optimizer plumbing: GradientTransformation, chain, clipping."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class GradientTransformation(NamedTuple):
    """A pair of pure functions (init, update) — the optax contract."""

    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    """params <- params + updates (updates already carry the sign/LR)."""
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p, params, updates
    )


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    """Compose gradient transformations left-to-right."""

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state, strict=True):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        norm = global_norm(grads)
        scale_factor = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        return jax.tree_util.tree_map(lambda g: g * scale_factor, grads), state

    return GradientTransformation(init, update)


def scale(factor: float) -> GradientTransformation:
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        return jax.tree_util.tree_map(lambda g: g * factor, grads), state

    return GradientTransformation(init, update)


class ScaleByScheduleState(NamedTuple):
    count: jnp.ndarray


def scale_by_schedule(schedule: Callable[[jnp.ndarray], jnp.ndarray]) -> GradientTransformation:
    def init(params):
        del params
        return ScaleByScheduleState(count=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        del params
        step_size = schedule(state.count)
        updates = jax.tree_util.tree_map(lambda g: g * step_size, grads)
        return updates, ScaleByScheduleState(count=state.count + 1)

    return GradientTransformation(init, update)
