"""Adam / AdamW (Kingma & Ba, 2015) — the optimizer used by every paper experiment."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim.base import GradientTransformation, chain, scale


class ScaleByAdamState(NamedTuple):
    count: jnp.ndarray
    mu: object  # first-moment pytree
    nu: object  # second-moment pytree


def scale_by_adam(
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    mu_dtype: Optional[jnp.dtype] = None,
) -> GradientTransformation:
    def init(params):
        mu = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=mu_dtype or p.dtype), params
        )
        nu = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return ScaleByAdamState(count=jnp.zeros((), jnp.int32), mu=mu, nu=nu)

    def update(grads, state, params=None):
        del params
        count = state.count + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1.0 - b1) * g.astype(m.dtype), state.mu, grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1.0 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        bc1 = 1.0 - b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - b2 ** count.astype(jnp.float32)
        updates = jax.tree_util.tree_map(
            lambda m, v: (m / bc1) / (jnp.sqrt(v / bc2) + eps), mu, nu
        )
        return updates, ScaleByAdamState(count=count, mu=mu, nu=nu)

    return GradientTransformation(init, update)


def adam(
    learning_rate: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    maximize: bool = False,
) -> GradientTransformation:
    """Adam. ``maximize=True`` flips the sign (VI *maximizes* the ELBO)."""
    sign = 1.0 if maximize else -1.0
    return chain(scale_by_adam(b1=b1, b2=b2, eps=eps), scale(sign * learning_rate))


class AdamWState(NamedTuple):
    adam: ScaleByAdamState


def adamw(
    learning_rate: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
) -> GradientTransformation:
    inner = scale_by_adam(b1=b1, b2=b2, eps=eps)

    def init(params):
        return AdamWState(adam=inner.init(params))

    def update(grads, state, params):
        updates, adam_state = inner.update(grads, state.adam, params)
        updates = jax.tree_util.tree_map(
            lambda u, p: -learning_rate * (u + weight_decay * p.astype(u.dtype)),
            updates,
            params,
        )
        return updates, AdamWState(adam=adam_state)

    return GradientTransformation(init, update)
