"""Self-contained Hamiltonian Monte Carlo (the paper's MCMC oracle role).

The paper compares SFVI's GLMM posterior against NUTS (NumPyro); NumPyro is
unavailable offline, so we provide HMC with dual-averaging step-size
adaptation and diagonal mass-matrix adaptation — ample for the 542-dim
GLMM posterior whose marginals we compare.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


def _leapfrog(grad_fn, position, momentum, step_size, num_steps, inv_mass):
    def body(_, carry):
        q, p = carry
        p = p + 0.5 * step_size * grad_fn(q)
        q = q + step_size * inv_mass * p
        p = p + 0.5 * step_size * grad_fn(q)
        return (q, p)

    return jax.lax.fori_loop(0, num_steps, body, (position, momentum))


@partial(jax.jit, static_argnames=("log_prob_fn", "num_samples", "num_warmup", "num_leapfrog"))
def hmc_sample(
    log_prob_fn: Callable[[jnp.ndarray], jnp.ndarray],
    init_position: jnp.ndarray,
    key,
    num_samples: int = 1000,
    num_warmup: int = 1000,
    num_leapfrog: int = 32,
    target_accept: float = 0.8,
):
    """Returns (samples (num_samples, dim), accept_rate)."""
    dim = init_position.shape[0]
    grad_fn = jax.grad(log_prob_fn)

    # Dual averaging (Hoffman & Gelman 2014, §3.2) during warmup.
    mu = jnp.log(10.0 * 0.1)
    gamma, t0, kappa = 0.05, 10.0, 0.75

    def kinetic(p, inv_mass):
        return 0.5 * jnp.sum(p * p * inv_mass)

    def step(carry, inp):
        q, log_eps, log_eps_bar, h_bar, warm_i, inv_mass, welford = carry
        key_i, is_warmup = inp
        k1, k2 = jax.random.split(key_i)
        eps = jnp.exp(log_eps)
        p0 = jax.random.normal(k1, (dim,)) / jnp.sqrt(inv_mass)
        q_new, p_new = _leapfrog(grad_fn, q, p0, eps, num_leapfrog, inv_mass)
        h0 = -log_prob_fn(q) + kinetic(p0, inv_mass)
        h1 = -log_prob_fn(q_new) + kinetic(p_new, inv_mass)
        log_alpha = jnp.clip(h0 - h1, -1e3, 0.0)
        alpha = jnp.exp(log_alpha)
        accept = jax.random.uniform(k2) < alpha
        q = jnp.where(accept, q_new, q)

        # Dual averaging updates (warmup only).
        warm_i = warm_i + is_warmup
        eta = 1.0 / (warm_i + t0)
        h_bar = jnp.where(
            is_warmup > 0, (1.0 - eta) * h_bar + eta * (target_accept - alpha), h_bar
        )
        log_eps_w = mu - jnp.sqrt(warm_i) / gamma * h_bar
        pow_ = warm_i ** (-kappa)
        log_eps_bar_w = pow_ * log_eps_w + (1.0 - pow_) * log_eps_bar
        log_eps = jnp.where(is_warmup > 0, log_eps_w, log_eps_bar)
        log_eps_bar = jnp.where(is_warmup > 0, log_eps_bar_w, log_eps_bar)

        # Welford variance accumulation for the mass matrix (warmup only).
        count, mean, m2 = welford
        count_n = count + is_warmup
        delta = q - mean
        mean_n = mean + jnp.where(is_warmup > 0, delta / jnp.maximum(count_n, 1.0), 0.0)
        m2_n = m2 + jnp.where(is_warmup > 0, delta * (q - mean_n), 0.0)
        welford = (count_n, mean_n, m2_n)
        # Refresh the mass matrix halfway through warmup.
        var = m2_n / jnp.maximum(count_n - 1.0, 1.0)
        refresh = (warm_i == num_warmup // 2).astype(q.dtype)
        inv_mass = refresh * jnp.clip(var, 1e-4, 1e4) + (1.0 - refresh) * inv_mass

        return (q, log_eps, log_eps_bar, h_bar, warm_i, inv_mass, welford), (q, alpha)

    total = num_warmup + num_samples
    keys = jax.random.split(key, total)
    is_warm = (jnp.arange(total) < num_warmup).astype(jnp.float32)
    welford0 = (jnp.zeros(()), jnp.zeros(dim), jnp.zeros(dim))
    carry0 = (
        init_position,
        jnp.log(0.1),
        jnp.log(0.1),
        jnp.zeros(()),
        jnp.zeros(()),
        jnp.ones(dim),
        welford0,
    )
    _, (qs, alphas) = jax.lax.scan(step, carry0, (keys, is_warm))
    return qs[num_warmup:], jnp.mean(alphas[num_warmup:])
