from repro.inference.hmc import hmc_sample

__all__ = ["hmc_sample"]
