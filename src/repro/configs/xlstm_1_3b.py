"""xLSTM-1.3b — sLSTM + mLSTM blocks at the paper's 7:1 ratio
[arXiv:2405.04517]. 48L d_model=2048 4H (kv=4) d_ff=0 (blocks carry their
own projections) vocab=50304."""
from repro.models.backbone.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_period=8,
    source="arXiv:2405.04517 (xLSTM)",
)
