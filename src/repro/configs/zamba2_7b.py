"""Zamba2-7B — Mamba2 backbone with a SHARED attention block applied every
6th layer [arXiv:2411.15242]. 81L d_model=3584 32H (kv=32) d_ff=14336
vocab=32000, ssm_state=64."""
from repro.models.backbone.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    hybrid_attn_period=6,
    shared_attn=True,
    source="arXiv:2411.15242 (Zamba2)",
)
