"""Whisper-base — encoder-decoder audio transformer [arXiv:2212.04356].
6L (enc + dec) d_model=512 8H (kv=8) d_ff=2048 vocab=51865. The
mel-spectrogram + conv frontend is a STUB per the assignment carve-out:
``input_specs()`` provides precomputed frame embeddings (B, 1500, 512)."""
from repro.models.backbone.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    arch_type="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    is_encoder_decoder=True,
    num_encoder_layers=6,
    encoder_seq_len=1500,
    source="arXiv:2212.04356 (Whisper)",
)
