"""Qwen3-4B — dense GQA with qk-norm [hf:Qwen/Qwen3-8B family].
36L d_model=2560 32H (kv=8) d_ff=9728 vocab=151936."""
from repro.models.backbone.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    arch_type="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B (family card)",
)
