"""Phi-3.5-MoE (42B total / 6.6B active) — 16-expert top-2 MoE
[hf:microsoft/Phi-3.5-MoE-instruct]. 32L d_model=4096 32H (kv=8)
per-expert d_ff=6400 vocab=32064."""
from repro.models.backbone.config import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    num_experts_per_tok=2,
    d_expert=6400,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
