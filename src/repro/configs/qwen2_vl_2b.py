"""Qwen2-VL-2B — VLM with M-RoPE and dynamic resolution [arXiv:2409.12191].
28L d_model=1536 12H (kv=2) d_ff=8960 vocab=151936. The ViT vision encoder
+ projector is a STUB per the assignment carve-out: ``input_specs()``
provides precomputed patch embeddings (B, 256, 1536)."""
from repro.models.backbone.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    arch_type="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mrope=True,
    num_vision_tokens=256,
    rope_theta=1e6,
    tie_embeddings=True,
    source="arXiv:2409.12191 (Qwen2-VL)",
)
