"""Architecture config registry: ``get_config("qwen3-8b")`` etc.

Each module defines one ``CONFIG`` with the exact assigned dimensions and a
source citation; ``ArchConfig.reduced()`` derives the CPU smoke variant and
``ArchConfig.long_context_variant()`` the sliding-window variant used for
long_500k on dense architectures.
"""
from __future__ import annotations

from typing import Dict

from repro.models.backbone.config import INPUT_SHAPES, ArchConfig, InputShape

from repro.configs.zamba2_7b import CONFIG as _zamba2
from repro.configs.qwen3_4b import CONFIG as _qwen3_4b
from repro.configs.qwen3_8b import CONFIG as _qwen3_8b
from repro.configs.llama3_2_3b import CONFIG as _llama32
from repro.configs.qwen3_32b import CONFIG as _qwen3_32b
from repro.configs.whisper_base import CONFIG as _whisper
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2vl
from repro.configs.xlstm_1_3b import CONFIG as _xlstm
from repro.configs.phi3_5_moe import CONFIG as _phi35

REGISTRY: Dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _zamba2,
        _qwen3_4b,
        _qwen3_8b,
        _llama32,
        _qwen3_32b,
        _whisper,
        _olmoe,
        _qwen2vl,
        _xlstm,
        _phi35,
    ]
}

ARCH_NAMES = tuple(REGISTRY)


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = ["ARCH_NAMES", "INPUT_SHAPES", "REGISTRY", "ArchConfig", "InputShape", "get_config"]
