"""Qwen3-32B — dense GQA with qk-norm [hf:Qwen/Qwen3-8B family].
64L d_model=5120 64H (kv=8) d_ff=25600 vocab=151936."""
from repro.models.backbone.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    arch_type="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B (family card)",
)
