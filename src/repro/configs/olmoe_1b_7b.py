"""OLMoE-1B-7B — 64-expert top-8 MoE [arXiv:2409.02060].
16L d_model=2048 16H (kv=16) per-expert d_ff=1024 vocab=50304."""
from repro.models.backbone.config import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    qk_norm=True,
    num_experts=64,
    num_experts_per_tok=8,
    d_expert=1024,
    source="arXiv:2409.02060 (OLMoE)",
)
