"""Llama-3.2-3B — small llama3 dense GQA [hf:meta-llama/Llama-3.2-1B family].
28L d_model=3072 24H (kv=8) d_ff=8192 vocab=128256."""
from repro.models.backbone.config import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    arch_type="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=5e5,
    tie_embeddings=True,
    source="hf:meta-llama/Llama-3.2-1B (family card)",
)
