"""Compiled federated orchestration: Algorithms 1 & 2 as ONE sharded graph.

The host-level runtime (``repro.core.runtime``) exchanges explicit Python
message dicts — faithful to the protocol, but it executes silos serially
and re-enters Python every round. This module is the scale path: all J
silos advance together inside a single ``shard_map`` over the dedicated
``silo`` mesh axis (``launch.mesh.make_silo_mesh``), with the server
virtualized into collectives:

  * silo state (η_{L_j}, its optimizer, its data shard) is stacked along
    a leading axis of size J and sharded over ``silo`` — privacy by
    placement, exactly as in ``launch/steps.py``;
  * the silo→server ship of (g_j^θ, g_j^η) (SFVI) or (θ^(j), η_G^(j))
    (SFVI-Avg) is an ``all_gather`` over ``silo``, with a pluggable
    :mod:`~repro.federated.aggregation` compressor applied *before* the
    collective so quantization reduces real bytes-on-wire;
  * the server reduction is a pluggable aggregator (mean, trimmed mean)
    evaluated redundantly on every device (standard SPMD replication).

One compiled round covers ``local_steps`` optimizer steps for both
algorithms, which makes the §3.2 communication claim directly measurable:
SFVI synchronizes after every step (``local_steps`` gathers per round)
while SFVI-Avg gathers once per round after ``local_steps`` local VI
steps on the N/N_j-rescaled objective.

Randomness: the server broadcasts only a per-round PRNG key. ε_G at local
step t is derived from (round_key, t) and therefore *shared* by all silos
(common-random-numbers — replaces the ε_G broadcast of Algorithm 1 with
zero wire bytes); ε_{L_j} additionally folds in the silo id.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.sfvi import SFVIProblem
from repro.core.families import DiagGaussian
from repro.federated.aggregation import MeanAggregator, NoCompression
from repro.federated.scheduler import RoundScheduler
from repro.launch.mesh import make_silo_mesh
from repro.optim.base import GradientTransformation, apply_updates

PyTree = Any


# ---------------------------------------------------------------------------
# Shared-randomness helpers (exported: tests replay the exact draws)
# ---------------------------------------------------------------------------


def global_eps(problem: SFVIProblem, round_key: jnp.ndarray, t) -> jnp.ndarray:
    """ε_G for local step ``t`` of a round — identical on every silo."""
    return jax.random.normal(
        jax.random.fold_in(round_key, t), (problem.model.global_dim,)
    )


def silo_eps(problem: SFVIProblem, round_key: jnp.ndarray, t, silo_id):
    """ε_{L_j} for local step ``t`` on silo ``silo_id`` (None if Z_L = ∅)."""
    if not problem.model.has_local:
        return None
    fam = problem.local_family
    shape = (fam.batch, fam.dim) if hasattr(fam, "batch") else (fam.dim,)
    key = jax.random.fold_in(jax.random.fold_in(round_key, 100_003 + t), silo_id)
    return jax.random.normal(key, shape)


def stack_silos(datas: Sequence[PyTree]) -> PyTree:
    """Stack J per-silo data pytrees along a new leading silo axis.

    All silos must share leaf shapes (equal-sized shards — what the
    partitioners in ``repro.data.partition`` produce); ragged federations
    pad to the max and mask inside ``log_local``.
    """
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *datas)


def _neg(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x: -x, tree)


def _add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def _select(keep, new: PyTree, old: PyTree) -> PyTree:
    """Per-leaf ``where`` that preserves dtypes (masked silo-state update)."""
    return jax.tree_util.tree_map(lambda n, o: jnp.where(keep, n, o), new, old)


@dataclasses.dataclass
class CommMeter:
    """Algorithm-level bytes-on-wire accounting (host side, per round)."""

    rounds: int = 0
    bytes_up: int = 0  # silo -> server (post-compression)
    bytes_down: int = 0  # server -> silo broadcast

    def record(self, up: int, down: int) -> None:
        """Log one round's realized (up, down) bytes."""
        self.rounds += 1
        self.bytes_up += int(up)
        self.bytes_down += int(down)

    @property
    def total(self) -> int:
        return self.bytes_up + self.bytes_down

    @property
    def per_round(self) -> float:
        return self.total / max(self.rounds, 1)


class Server:
    """Round-based federation driver over a compiled multi-silo graph.

    Owns the replicated server state (θ, η_G, server optimizer) and the
    silo-sharded state (stacked η_{L_j} and local optimizer states), and
    advances them one *round* at a time through a jitted ``shard_map``
    graph. ``run(algorithm="sfvi")`` synchronizes every local step;
    ``run(algorithm="sfvi_avg")`` runs ``local_steps`` local VI steps on
    the N/N_j-rescaled objective and aggregates parameters once per round
    (FedAvg for θ, Wasserstein barycenter — or parameter-space mean —
    for η_G).

    Args:
      problem: the :class:`~repro.core.sfvi.SFVIProblem` to optimize.
      datas: list of J per-silo data pytrees with equal leaf shapes.
      theta: initial model parameters θ (``{}`` for fully-Bayesian).
      eta_G: initial global variational parameters η_G.
      num_obs: per-silo observation counts N_j (default: leading dim of
        each silo's first data leaf) — drives SFVI-Avg's N/N_j rescale.
      server_opt: optimizer for (θ, η_G). Descent convention; the runtime
        flips signs to ascend the ELBO.
      local_opt: optimizer for each η_{L_j} (state is stacked per silo).
      aggregator: cross-silo combine rule (mean / trimmed mean / custom).
      compressor: silo→server wire codec (identity / int8 quantization).
      eta_mode: ``"barycenter"`` (paper §3.2; DiagGaussian only) or
        ``"param"`` (FedAvg in parameter space) for SFVI-Avg's η_G merge.
      mesh: optional silo mesh (default ``make_silo_mesh(J)``).
      seed: base seed for the round key stream.
    """

    def __init__(
        self,
        problem: SFVIProblem,
        datas: Sequence[PyTree],
        theta: PyTree,
        eta_G: PyTree,
        *,
        num_obs: Optional[Sequence[int]] = None,
        server_opt: GradientTransformation,
        local_opt: Optional[GradientTransformation] = None,
        aggregator=None,
        compressor=None,
        eta_mode: str = "barycenter",
        mesh=None,
        seed: int = 0,
    ):
        self.problem = problem
        self.J = len(datas)
        self.data = stack_silos(datas)
        self.aggregator = aggregator or MeanAggregator()
        self.compressor = compressor or NoCompression()
        self.mesh = mesh if mesh is not None else make_silo_mesh(self.J)
        self.seed = seed
        self._server_opt = server_opt
        self._local_opt = local_opt
        self._has_local = problem.model.has_local
        if eta_mode not in ("barycenter", "param"):
            raise ValueError(f"unknown eta_mode {eta_mode!r}")
        if eta_mode == "barycenter" and not isinstance(
            problem.global_family, DiagGaussian
        ):
            raise ValueError(
                "in-graph barycenter aggregation is implemented for "
                "DiagGaussian η_G; pass eta_mode='param' for other families"
            )
        self.eta_mode = eta_mode

        if num_obs is None:
            num_obs = [
                int(jax.tree_util.tree_leaves(d)[0].shape[0]) for d in datas
            ]
        self.num_obs = np.asarray(num_obs, np.float32)

        if self._has_local:
            if local_opt is None:
                raise ValueError("local_opt is required when the model has Z_L")
            keys = jax.random.split(jax.random.PRNGKey(seed + 1), self.J)
            eta_L = jax.vmap(problem.local_family.init)(keys)
            opt_L = jax.vmap(local_opt.init)(eta_L)
        else:
            eta_L, opt_L = {}, {}
        self.state: Dict[str, PyTree] = {
            "theta": theta,
            "eta_G": eta_G,
            "eta_L": eta_L,
            "opt_server": server_opt.init({"theta": theta, "eta_G": eta_G}),
            "opt_local": opt_L,
        }
        self.comm = CommMeter()
        self._round_fns: Dict[tuple, Callable] = {}

    # -- convenience accessors (mirror the host runtime's attributes) -------

    @property
    def theta(self) -> PyTree:
        """Current model parameters θ (replicated)."""
        return self.state["theta"]

    @property
    def eta_G(self) -> PyTree:
        """Current global variational parameters η_G (replicated)."""
        return self.state["eta_G"]

    @property
    def eta_L(self) -> PyTree:
        """Stacked per-silo variational parameters η_{L_j}, leading axis J."""
        return self.state["eta_L"]

    # -- wire accounting -----------------------------------------------------

    def ship_template(self, algorithm: str) -> PyTree:
        """Shape-only pytree of one silo's upload (pre-compression)."""
        if algorithm == "sfvi":
            return {"g_theta": self.state["theta"], "g_eta": self.state["eta_G"]}
        return {"theta": self.state["theta"], "eta_G": self.state["eta_G"]}

    def bytes_up_per_silo(self, algorithm: str) -> int:
        """Post-compression upload bytes for one silo, one gather."""
        return self.compressor.wire_bytes(self.ship_template(algorithm))

    def bytes_down_per_silo(self) -> int:
        """Broadcast bytes: (θ, η_G) raw; the round key is ~0 and elided."""
        return NoCompression().wire_bytes(
            {"theta": self.state["theta"], "eta_G": self.state["eta_G"]}
        )

    def compiled_collective_bytes(
        self, algorithm: str = "sfvi", local_steps: int = 1
    ) -> Dict[str, float]:
        """Ring-traffic bytes per collective kind in the compiled round.

        Lowers the jitted round function and applies
        ``launch.roofline.collective_bytes`` to the optimized HLO. On a
        single-device mesh XLA elides the collectives entirely (all
        entries 0); run under a multi-device mesh (or the forced-host-
        device trick of ``launch/comm.py``) for real numbers.
        """
        from repro.launch.roofline import collective_bytes

        fn = self._get_round(algorithm, local_steps)
        args = (
            self.state,
            self.data,
            jax.random.PRNGKey(0),
            jnp.ones((self.J,), jnp.float32),
        )
        return collective_bytes(fn.lower(*args).compile().as_text())

    # -- the compiled round --------------------------------------------------

    def _get_round(self, algorithm: str, local_steps: int) -> Callable:
        key = (algorithm, local_steps)
        if key not in self._round_fns:
            if algorithm == "sfvi":
                body = self._sfvi_body(local_steps)
            elif algorithm == "sfvi_avg":
                body = self._avg_body(local_steps)
            else:
                raise ValueError(f"unknown algorithm {algorithm!r}")
            sharded = shard_map(
                body,
                mesh=self.mesh,
                in_specs=(
                    P(), P(), P(),  # theta, eta_G, opt_server (replicated)
                    P("silo"), P("silo"),  # eta_L, opt_local
                    P("silo"), P("silo"), P("silo"), P("silo"),  # data, sids, n_j, mask shard
                    P(), P(),  # full mask (for aggregation), round key
                ),
                out_specs=(P(), P(), P(), P("silo"), P("silo"), P()),
                check_rep=False,
            )

            def round_fn(state, data, round_key, mask):
                sids = jnp.arange(self.J, dtype=jnp.int32)
                n_j = jnp.asarray(self.num_obs)
                theta, eta_G, opt_server, eta_L, opt_L, elbos = sharded(
                    state["theta"], state["eta_G"], state["opt_server"],
                    state["eta_L"], state["opt_local"],
                    data, sids, n_j, mask, mask, round_key,
                )
                new_state = {
                    "theta": theta, "eta_G": eta_G, "eta_L": eta_L,
                    "opt_server": opt_server, "opt_local": opt_L,
                }
                return new_state, {"elbo": elbos}

            self._round_fns[key] = jax.jit(round_fn)
        return self._round_fns[key]

    def _sfvi_body(self, K: int) -> Callable:
        """Round = K synchronized steps: gather + server update every step."""
        problem, J = self.problem, self.J
        agg, comp = self.aggregator, self.compressor
        server_opt, local_opt = self._server_opt, self._local_opt
        has_local = self._has_local

        def body(theta, eta_G, opt_server, eta_L, opt_L,
                 data_sh, sids, n_j, mask_sh, mask_full, round_key):
            del n_j  # SFVI needs no N/N_j rescale (likelihood_scale = 1)
            n_active = jnp.maximum(jnp.sum(mask_full), 1.0)

            def sync_step(carry, t):
                theta, eta_G, opt_server, eta_L, opt_L = carry
                eps_G = global_eps(problem, round_key, t)

                def per_silo(eta_Lj, opt_Lj, data_j, sid, m_j):
                    el = eta_Lj if has_local else None
                    eps_L = silo_eps(problem, round_key, t, sid)
                    g_th, g_eta, g_loc, hatLj = problem.silo_grads(
                        theta, eta_G, el, eps_G, eps_L, data_j
                    )
                    if has_local:
                        upd, new_opt = local_opt.update(_neg(g_loc), opt_Lj, el)
                        eta_Lj = _select(m_j > 0.5, apply_updates(el, upd), el)
                        opt_Lj = _select(m_j > 0.5, new_opt, opt_Lj)
                    ship = comp.encode({"g_theta": g_th, "g_eta": g_eta})
                    return eta_Lj, opt_Lj, ship, hatLj * m_j

                eta_L, opt_L, enc, hatL = jax.vmap(per_silo)(
                    eta_L, opt_L, data_sh, sids, mask_sh
                )
                enc = jax.tree_util.tree_map(
                    lambda x: jax.lax.all_gather(x, "silo", axis=0, tiled=True),
                    enc,
                )
                shipped = jax.vmap(comp.decode)(enc)  # (J, ...) per leaf
                hatL_sum = jax.lax.psum(jnp.sum(hatL), "silo")

                mean_g = agg.combine(shipped, mask_full)
                g_sum = jax.tree_util.tree_map(lambda x: x * float(J), mean_g)
                g_th0, g_eta0, hatL0 = problem.server_grads(theta, eta_G, eps_G)
                g = {
                    "theta": _add(g_sum["g_theta"], g_th0),
                    "eta_G": _add(g_sum["g_eta"], g_eta0),
                }
                params = {"theta": theta, "eta_G": eta_G}
                updates, opt_server = server_opt.update(_neg(g), opt_server, params)
                merged = apply_updates(params, updates)
                elbo = hatL0 + (float(J) / n_active) * hatL_sum
                carry = (merged["theta"], merged["eta_G"], opt_server, eta_L, opt_L)
                return carry, elbo

            carry = (theta, eta_G, opt_server, eta_L, opt_L)
            carry, elbos = jax.lax.scan(sync_step, carry, jnp.arange(K))
            return (*carry, elbos)

        return body

    def _avg_body(self, K: int) -> Callable:
        """Round = K local VI steps per silo, ONE gather + parameter merge."""
        problem, J = self.problem, self.J
        agg, comp = self.aggregator, self.compressor
        server_opt, local_opt = self._server_opt, self._local_opt
        has_local = self._has_local
        eta_mode = self.eta_mode
        total_obs = float(np.sum(self.num_obs))

        def body(theta, eta_G, opt_server, eta_L, opt_L,
                 data_sh, sids, n_j, mask_sh, mask_full, round_key):
            n_active = jnp.maximum(jnp.sum(mask_full), 1.0)

            def per_silo(eta_Lj, opt_Lj, data_j, sid, m_j, n_obs_j):
                scale = total_obs / n_obs_j  # §3.2 point 2: N / N_j
                el0 = eta_Lj if has_local else None
                s_state = server_opt.init({"theta": theta, "eta_G": eta_G})

                def local_step(carry, t):
                    th, eg, el, s_st, l_st = carry
                    eps_G = global_eps(problem, round_key, t)
                    eps_L = silo_eps(problem, round_key, t, sid)

                    def objective(th_, eg_, el_):
                        val = problem.hat_L0(th_, eg_, eps_G)
                        return val + problem.hat_Lj(
                            th_, eg_, el_, eps_G, eps_L, data_j, scale
                        )

                    if has_local:
                        val, (g_th, g_eg, g_el) = jax.value_and_grad(
                            objective, argnums=(0, 1, 2)
                        )(th, eg, el)
                        upd_l, l_st = local_opt.update(_neg(g_el), l_st, el)
                        el = apply_updates(el, upd_l)
                    else:
                        val, (g_th, g_eg) = jax.value_and_grad(
                            lambda a, b: objective(a, b, None), argnums=(0, 1)
                        )(th, eg)
                    params = {"theta": th, "eta_G": eg}
                    upd_s, s_st = server_opt.update(
                        _neg({"theta": g_th, "eta_G": g_eg}), s_st, params
                    )
                    merged = apply_updates(params, upd_s)
                    return (merged["theta"], merged["eta_G"], el, s_st, l_st), val

                carry = (theta, eta_G, el0, s_state, opt_Lj)
                (th, eg, el, _, l_st), elbos = jax.lax.scan(
                    local_step, carry, jnp.arange(K)
                )
                if has_local:
                    eta_Lj = _select(m_j > 0.5, el, el0)
                    opt_Lj = _select(m_j > 0.5, l_st, opt_Lj)
                ship = comp.encode({"theta": th, "eta_G": eg})
                return eta_Lj, opt_Lj, ship, elbos * m_j

            eta_L, opt_L, enc, elbos = jax.vmap(per_silo)(
                eta_L, opt_L, data_sh, sids, mask_sh, n_j
            )
            enc = jax.tree_util.tree_map(
                lambda x: jax.lax.all_gather(x, "silo", axis=0, tiled=True), enc
            )
            shipped = jax.vmap(comp.decode)(enc)
            elbo_t = jax.lax.psum(jnp.sum(elbos, axis=0), "silo") / n_active

            theta_new = agg.combine(shipped["theta"], mask_full)
            if eta_mode == "param":
                eta_new = agg.combine(shipped["eta_G"], mask_full)
            else:
                # Analytic diag-Gaussian W2 barycenter in moment space:
                # mean of μ_j, mean of σ_j (core.barycenter.diag_barycenter)
                # — robustified by whatever aggregator is plugged in.
                mu = agg.combine(shipped["eta_G"]["mu"], mask_full)
                sigma = agg.combine(
                    jnp.exp(shipped["eta_G"]["log_sigma"]), mask_full
                )
                eta_new = {"mu": mu, "log_sigma": jnp.log(sigma)}
            return theta_new, eta_new, opt_server, eta_L, opt_L, elbo_t

        return body

    # -- driver --------------------------------------------------------------

    def run(
        self,
        num_rounds: int,
        *,
        algorithm: str = "sfvi",
        local_steps: int = 1,
        scheduler: Optional[RoundScheduler] = None,
        callback: Optional[Callable[[int, dict], None]] = None,
    ) -> Dict[str, list]:
        """Advance the federation ``num_rounds`` rounds; returns history.

        One round is ``local_steps`` optimizer steps: SFVI pays one
        up+down exchange per step, SFVI-Avg one per round — the meter
        (``self.comm``) records exactly that asymmetry. ``scheduler``
        injects partial participation / straggler masks: uninvited silos
        cost nothing; invited stragglers (dropout) receive the broadcast
        (download is billed) but never upload, and the aggregation is
        rescaled by the realized active count (unbiased, §3 Remark).
        """
        if local_steps < 1:
            raise ValueError(f"local_steps must be >= 1, got {local_steps}")
        fn = self._get_round(algorithm, local_steps)
        sched = scheduler or RoundScheduler(self.J, seed=self.seed)
        up1 = self.bytes_up_per_silo(algorithm)
        down1 = self.bytes_down_per_silo()
        exchanges = local_steps if algorithm == "sfvi" else 1
        history: Dict[str, list] = {
            "elbo": [], "elbo_trace": [], "bytes_up": [], "bytes_down": [],
            "n_active": [],
        }
        base_key = jax.random.PRNGKey(self.seed)
        for r in range(num_rounds):
            mask = sched.mask(r)
            n_active = int(np.sum(np.asarray(mask)))
            # Stragglers received the broadcast before dropping: bill their
            # download. Custom schedulers without invited() bill reporters.
            invited = sched.invited(r) if hasattr(sched, "invited") else mask
            n_invited = max(int(np.sum(np.asarray(invited))), n_active)
            round_key = jax.random.fold_in(base_key, r)
            self.state, metrics = fn(self.state, self.data, round_key, mask)
            elbos = np.asarray(metrics["elbo"])
            up = exchanges * n_active * up1
            down = exchanges * n_invited * down1
            self.comm.record(up, down)
            history["elbo"].append(float(elbos[-1]))
            history["elbo_trace"].extend(float(e) for e in elbos)
            history["bytes_up"].append(up)
            history["bytes_down"].append(down)
            history["n_active"].append(n_active)
            if callback:
                callback(r, {
                    "elbo": history["elbo"][-1], "bytes_up": up,
                    "bytes_down": down, "n_active": n_active,
                })
        return history
