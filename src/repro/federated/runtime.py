"""Compiled federated orchestration: one sharded graph per round.

The host-level runtime (``repro.core.runtime``) exchanges explicit Python
message dicts — faithful to the protocol, but it executes silos serially
and re-enters Python every round. This module is the scale path: all J
silos advance together inside a single ``shard_map`` over the federated
``(silo[, model])`` mesh (``launch.mesh.build_mesh``), with the server
virtualized into collectives:

  * silo state (η_{L_j}, its optimizer, its data shard, and any per-silo
    strategy state such as PVI's site approximations λ_j) is stacked
    along a leading axis of size J and sharded over ``silo`` — privacy
    by placement, exactly as in ``launch/steps.py``;
  * the silo→server ship — whatever pytree the active
    :class:`~repro.federated.strategy.ServerStrategy` emits (gradients,
    locally-updated parameters, natural-parameter deltas) — is packed
    into ONE contiguous float32 vector per silo (the flat wire format,
    :class:`~repro.core.flatten.TreeSpec`), so DP clip+noise, the
    pluggable :mod:`~repro.federated.aggregation` compressor (applied
    *before* the collective — quantization reduces real bytes-on-wire,
    with a single int8 scale per silo), the ``all_gather`` over ``silo``
    and the server-side aggregation all operate on a single (J, P)
    matrix instead of per-leaf tree_maps;
  * the server reduction is a pluggable aggregator (mean, trimmed mean)
    evaluated redundantly on every device (standard SPMD replication);
  * on a 2-D ``(silo, model)`` mesh each row's P wire parameters are
    additionally sharded along ``model``: the whole upload pipeline
    (pack → DP clip+noise → mask → encode, or the fused kernel pass)
    runs on full rows — so noise streams and int8 row scales are
    bit-identical to the 1-D mesh — and each device then slices its
    model-column block, so the big gather over ``silo`` moves
    ``(J_pad, P/model)`` blocks; a second row-local ``all_gather`` over
    ``model`` rejoins the blocks before decode/aggregation, so the
    combine sees the exact (J_pad, P) matrix of the 1-D mesh and 2-D
    trajectories are bit-exact, reported ELBO included.
    ``model > 1`` requires the flat/fused wire and an identity or int8
    codec (custom codecs see arbitrary pytrees the runtime cannot
    column-slice).

Multi-process execution (``jax.distributed``) runs the same SPMD graph
over a global mesh: every process computes the identical control plane
(masks, keys, metering — pure functions of seed and round) while silo
state and data exist only on the owning process's devices
(:mod:`repro.federated.distributed`).

WHAT each silo computes and HOW the server folds the aggregate back into
(θ, η_G) is not this module's business: both live behind the
:class:`~repro.federated.strategy.ServerStrategy` registry. The runtime
only distinguishes the two *cadences* — step-cadence strategies gather
after every local optimizer step (``local_steps`` gathers per round);
round-cadence strategies run ``local_steps`` local VI steps and gather
once — which makes the paper's §3.2 communication claim directly
measurable and extends it unchanged to PVI / federated EP.

Randomness: the server broadcasts only a per-round PRNG key. ε_G at local
step t is derived from (round_key, t) and therefore *shared* by all silos
(common-random-numbers — replaces the ε_G broadcast of Algorithm 1 with
zero wire bytes); ε_{L_j} additionally folds in the silo id.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro import debug
from repro.core.family import supports_moments
from repro.core.flatten import TreeSpec
from repro.core.sfvi import SFVIProblem
from repro.federated import graph_cache
from repro.federated.aggregation import MeanAggregator, NoCompression
from repro.federated.metering import CommMeter
from repro.federated.strategy import (
    DEFAULT_STRATEGY,
    ServerStrategy,
    StrategyContext,
    _select,
    global_eps,
    resolve_strategy,
    silo_eps,
)
from repro.kernels import wire as wire_kernels
from repro.federated.privacy import PrivacyPolicy, RdpAccountant
from repro.federated.scheduler import RoundScheduler
from repro.launch.mesh import (
    MeshSpec,
    build_mesh,
    mesh_process_count,
    model_world,
)
from repro.optim.base import GradientTransformation

__all__ = [
    "Server", "global_eps", "silo_eps", "stack_silos",
]

PyTree = Any


def stack_silos(datas: Sequence[PyTree]) -> PyTree:
    """Stack J per-silo data pytrees along a new leading silo axis.

    All silos must share leaf shapes (equal-sized shards — what the
    partitioners in ``repro.data.partition`` produce); ragged federations
    pad to the max and mask inside ``log_local``.
    """
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *datas)


def _coalesced_all_gather(tree: PyTree, axis_name: str) -> PyTree:
    """Cross-silo gather as ONE ``all_gather`` per wire dtype.

    A naive per-leaf ``tree_map(all_gather)`` emits one collective per
    pytree leaf — more instructions (and collective launches) than the
    algorithm needs, and it makes the "one gather per exchange" claim of
    §3.2 unverifiable in the HLO. Instead: flatten every leaf of the
    (already encoded, already privatized) upload to ``(stack, size)``,
    concatenate per dtype into one contiguous buffer, gather that, and
    split back. Uncompressed float uploads produce exactly one
    ``all-gather`` instruction in the compiled round; int8 compression
    produces two (payload + scales), still independent of leaf count
    and of ``local_steps``.

    Leaves must share a leading stacked-silo axis (what the runtime's
    vmapped ``per_silo`` emits); the gather tiles along it.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return tree
    stack = leaves[0].shape[0]
    groups: Dict[Any, list] = {}
    for i, x in enumerate(leaves):
        groups.setdefault(jnp.dtype(x.dtype), []).append(i)
    out: list = [None] * len(leaves)
    for dt in sorted(groups, key=lambda d: d.name):
        idxs = groups[dt]
        flat = jnp.concatenate(
            [leaves[i].reshape(stack, -1) for i in idxs], axis=1
        )
        gathered = jax.lax.all_gather(flat, axis_name, axis=0, tiled=True)
        off = 0
        for i in idxs:
            size = int(np.prod(leaves[i].shape[1:], dtype=np.int64))
            piece = gathered[:, off : off + size]
            out[i] = piece.reshape((-1,) + leaves[i].shape[1:])
            off += size
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Fused-wire plumbing (wire="fused"): the upload pipeline and the server
# reduction run as the Pallas kernels of repro.kernels.wire, applied to the
# stacked (J, P) block AFTER the per-silo vmap instead of leaf-by-leaf
# inside it. Semantics match the flat path exactly (same op sequence, same
# PRNG stream); only the pass structure changes.
# ---------------------------------------------------------------------------


def _fused_keys(privacy, round_key, t, sids):
    """(J, 2) per-row DP noise keys: fold_in(upload_key(rk, t, j), 0).

    The trailing fold_in(·, 0) is ``PrivacyPolicy.noise``'s per-leaf
    fold for the single flat leaf — precomputing it per row makes the
    in-kernel draw bit-identical to the policy's stream.
    """
    if privacy is None or privacy.noise_multiplier <= 0.0:
        return None
    return jax.vmap(
        lambda s: jax.random.fold_in(privacy.upload_key(round_key, t, s), 0)
    )(sids)


def _fused_ship(mat, mask_sh, keys, reference, privacy, comp, int8):
    """Privatize + mask + encode a stacked (J, P) block in one fused pass."""
    out = wire_kernels.fused_upload(
        mat,
        mask=mask_sh,
        keys=keys,
        reference=reference,
        clip_norm=None if privacy is None else privacy.clip_norm,
        noise_multiplier=0.0 if privacy is None else privacy.noise_multiplier,
        quantize=int8,
    )
    if int8:
        q, scales = out
        return {"q": q, "scale": scales}
    if _wire_codec(comp) == "identity":
        return out
    # Custom codec: fall back to the per-silo encode on the fused output.
    return jax.vmap(comp.encode)(out)


def _fused_decode(enc, comp, int8):
    """Gathered fused wire -> dequantized (J, P) float32 matrix."""
    if int8:
        return enc["q"].astype(jnp.float32) * enc["scale"][:, None]
    if _wire_codec(comp) == "identity":
        return enc
    return jax.vmap(comp.decode)(enc)


def _wire_codec(comp) -> str:
    """The compressor's fused-wire capability (Compressor protocol).

    "identity"/"int8" run as the fused Pallas kernels; "custom" (the
    default for compressors that don't declare the attribute) falls
    back to per-silo ``encode``/``decode`` around the same gather.
    """
    return getattr(comp, "wire_codec", "custom")


class Server:
    """Round-based federation driver over a compiled multi-silo graph.

    Owns the replicated server state (θ, η_G, server optimizer) and the
    silo-sharded state (stacked η_{L_j}, local optimizer states, and any
    per-silo strategy state), and advances them one *round* at a time
    through a jitted ``shard_map`` graph. The update rule is a
    :class:`~repro.federated.strategy.ServerStrategy` resolved from the
    registry by name: step-cadence strategies (SFVI) synchronize every
    local step; round-cadence strategies (SFVI-Avg, PVI, federated EP)
    run ``local_steps`` local VI steps and aggregate once per round.

    Args:
      problem: the :class:`~repro.core.sfvi.SFVIProblem` to optimize.
      datas: list of J per-silo data pytrees with equal leaf shapes.
      theta: initial model parameters θ (``{}`` for fully-Bayesian).
      eta_G: initial global variational parameters η_G.
      num_obs: per-silo observation counts N_j (default: leading dim of
        each silo's first data leaf) — drives SFVI-Avg's N/N_j rescale.
      server_opt: optimizer for (θ, η_G). Descent convention; the
        strategies flip signs to ascend the ELBO.
      local_opt: optimizer for each η_{L_j} (state is stacked per silo).
      aggregator: cross-silo combine rule (mean / trimmed mean / custom).
      compressor: silo→server wire codec (identity / int8 quantization).
      eta_mode: ``"barycenter"`` (paper §3.2 — any family exposing the
        ``to_moments``/``from_moments`` bridge: analytic for diag-form
        families, the in-graph Newton–Schulz fixed point for
        full-covariance ones) or ``"param"`` (FedAvg in parameter
        space) for SFVI-Avg's η_G merge.
      wire: silo→server wire layout. ``"flat"`` (default) packs each
        upload into ONE contiguous float32 vector
        (:class:`~repro.core.flatten.TreeSpec`), so DP clip+noise,
        compression, the cross-silo gather and the aggregator all
        operate on a single (J, P) matrix — fewer HLO ops per round and
        one int8 scale per silo instead of one per leaf. ``"fused"``
        keeps the flat layout but runs the upload pipeline (clip + DP
        noise + mask + int8 quantize) and the server reduction as the
        fused Pallas kernels of :mod:`repro.kernels.wire` — identical
        semantics (bit-exact without DP/compression; the DP noise
        stream is bit-identical by construction), fewer memory passes.
        ``"legacy"`` keeps the per-leaf pytree wire (benchmark/debug
        reference).
      privacy: optional :class:`~repro.federated.privacy.PrivacyPolicy`.
        When set, every silo upload is L2-clipped and Gaussian-noised
        *inside* the compiled round — before the compression hook and
        the ``all_gather``, so the wire carries already-privatized bytes
        (the clipped quantity is the strategy's upload measured against
        its wire reference: raw gradients / deltas for zero-reference
        strategies, the parameter delta from the round's public
        broadcast for broadcast-reference ones). The Server then owns an
        :class:`~repro.federated.privacy.RdpAccountant` composing every
        exchange; ``run`` reports cumulative ε per round.
      mesh: optional pre-built federated mesh (a 1-D ``(silo,)`` or 2-D
        ``(silo, model)`` :class:`jax.sharding.Mesh`). Mutually
        exclusive with ``mesh_spec``; default ``build_mesh`` over the
        spec (or ``MeshSpec()`` — the historical 1-D auto mesh).
      mesh_spec: declarative topology
        (:class:`~repro.launch.mesh.MeshSpec`) — what
        ``ExperimentSpec.runtime.mesh`` carries. ``model > 1`` shards
        each silo row's P wire parameters across the ``model`` axis
        (flat/fused wire with identity or int8 codec only);
        ``multiprocess=True`` builds the mesh over the global device
        list of a ``jax.distributed`` run and globalizes silo state,
        data and control inputs accordingly.
      seed: base seed for the round key stream.
      strategy: default update rule for :meth:`run` — a registry name,
        a :class:`~repro.federated.strategy.StrategySpec`, or a
        :class:`~repro.federated.strategy.ServerStrategy` instance.
        Per-silo strategy state (if any) is initialized here so it
        checkpoints alongside ``eta_L``.
      federation_size: the FULL federation width the estimators scale
        by (SFVI's ``J`` inflation, the ELBO's ``J/n_active`` rescale).
        Defaults to ``len(datas)``. A dynamic population sets this to
        the roster maximum so the estimator target — the full-roster
        ELBO — stays fixed while silos join through
        :meth:`grow_silos` (absent silos are just non-participants of
        the roster-wide federation, the §3 Remark).
      federation_obs: the full federation's N = Σ_j N_j (SFVI-Avg's
        N/N_j rescale). Defaults to the sum over ``datas``; a dynamic
        population passes the roster-wide total for the same reason.
    """

    def __init__(
        self,
        problem: SFVIProblem,  # repro-lint: allow[R5] — the seed's problem protocol (local ELBO interface), not a strategy branch
        datas: Sequence[PyTree],
        theta: PyTree,
        eta_G: PyTree,
        *,
        num_obs: Optional[Sequence[int]] = None,
        server_opt: GradientTransformation,
        local_opt: Optional[GradientTransformation] = None,
        aggregator=None,
        compressor=None,
        eta_mode: str = "barycenter",
        wire: str = "flat",
        privacy: Optional[PrivacyPolicy] = None,
        mesh=None,
        mesh_spec: Optional[MeshSpec] = None,
        seed: int = 0,
        strategy: Union[str, ServerStrategy, None] = None,
        graph_cache_token: Optional[str] = None,
        federation_size: Optional[int] = None,
        federation_obs: Optional[float] = None,
    ):
        self.problem = problem
        self.J = len(datas)
        self.aggregator = aggregator or MeanAggregator()
        self.compressor = compressor or NoCompression()
        self.privacy = privacy
        self.accountant = RdpAccountant() if privacy is not None else None
        if mesh is not None and mesh_spec is not None:
            raise ValueError(
                "pass either a pre-built mesh or a MeshSpec, not both")
        self.mesh = (mesh if mesh is not None
                     else build_mesh(mesh_spec, num_silos=self.J))
        self.model_world = model_world(self.mesh)
        self.n_processes = mesh_process_count(self.mesh)
        # The stacked silo axis is padded up to a multiple of the mesh
        # size with dummy silos (copies of silo 0's data, permanently
        # masked out), so ANY J shards over every device — a prime J on
        # a 4-device mesh no longer collapses the federation onto one
        # device. All masks/weights entering the compiled round carry
        # zeros for the padded tail; the J-rescales below always use the
        # real J. On divisible meshes J_pad == J and nothing changes.
        n_dev = int(self.mesh.shape["silo"])
        self.J_pad = ((self.J + n_dev - 1) // n_dev) * n_dev
        datas = list(datas)
        self.data = stack_silos(datas + [datas[0]] * (self.J_pad - self.J))
        self.seed = seed
        self._server_opt = server_opt
        self._local_opt = local_opt
        self._has_local = problem.model.has_local
        if eta_mode not in ("barycenter", "param"):
            raise ValueError(f"unknown eta_mode {eta_mode!r}")
        if eta_mode == "barycenter" and not supports_moments(
            problem.global_family
        ):
            raise ValueError(
                "eta_mode='barycenter' needs a global family exposing "
                "to_moments/from_moments (DiagGaussian, CholeskyGaussian, "
                "LowRankGaussian, ...); pass eta_mode='param' for "
                f"{type(problem.global_family).__name__}"
            )
        self.eta_mode = eta_mode
        if wire not in ("flat", "fused", "legacy"):
            raise ValueError(
                f"unknown wire layout {wire!r} (flat/fused/legacy)")
        self.wire = wire
        if self.model_world > 1:
            # Model-sharding slices the (J, P) wire by columns, which
            # needs the single-matrix layout and a codec whose payload
            # IS that matrix (identity/int8); per-leaf wires and custom
            # codecs carry pytrees the runtime cannot column-slice.
            if wire == "legacy":
                raise ValueError(
                    "wire='legacy' cannot shard parameters along the "
                    "model axis; use wire='flat' or 'fused' (or model=1)")
            if _wire_codec(self.compressor) == "custom":
                raise ValueError(
                    f"compressor {type(self.compressor).__name__} has no "
                    "wire_codec capability; model-axis sharding supports "
                    "identity/int8 codecs only (or set model=1)")

        if num_obs is None:
            num_obs = [
                int(jax.tree_util.tree_leaves(d)[0].shape[0])
                for d in datas[: self.J]
            ]
        num_obs = list(num_obs) + [num_obs[0]] * (self.J_pad - self.J)
        # repro-lint: allow[R4] — host staging of a Python list at init, not a device pull
        self.num_obs = np.asarray(num_obs, np.float32)
        # Roster-wide constants the strategies' estimators scale by —
        # trace-time facts that must NOT change when a dynamic
        # population grows the live J (see class docstring).
        self.fed_J = self.J if federation_size is None else int(federation_size)
        self.fed_obs = (float(np.sum(self.num_obs[: self.J]))
                        if federation_obs is None else float(federation_obs))

        if self._has_local:
            if local_opt is None:
                raise ValueError("local_opt is required when the model has Z_L")
            # Real silos draw the same keys regardless of padding (the
            # split width is J, not J_pad) so trajectories agree across
            # device counts; the padded rows reuse silo 0's init and are
            # frozen by their permanent zero mask.
            # repro-lint: allow[R1] — init-time root of the η_L stream: a pure function of the spec seed, so resume re-derives it bit-exactly
            keys = jax.random.split(jax.random.PRNGKey(seed + 1), self.J)
            eta_L = jax.vmap(problem.local_family.init)(keys)
            eta_L = self.pad_silo_axis(eta_L)
            opt_L = jax.vmap(local_opt.init)(eta_L)
        else:
            eta_L, opt_L = {}, {}
        self._strategy = resolve_strategy(
            strategy if strategy is not None else DEFAULT_STRATEGY
        )
        self._strategy.validate(self)  # fail fast, not at first run()
        self.state: Dict[str, PyTree] = {
            "theta": theta,
            "eta_G": eta_G,
            "eta_L": eta_L,
            "opt_server": server_opt.init({"theta": theta, "eta_G": eta_G}),
            "opt_local": opt_L,
            "strategy": {},
        }
        self.state["strategy"] = self._strategy.init_silo_state(self)
        if self.n_processes > 1:
            # Every process computed identical host values (pure
            # functions of the spec); turn them into global arrays so
            # the jitted round accepts them — silo-sharded leaves cost
            # each host only its own rows.
            from repro.federated import distributed

            self.data = distributed.globalize(self.data, self.mesh,
                                              P("silo"))
            for k in ("eta_L", "opt_local", "strategy"):
                self.state[k] = distributed.globalize(
                    self.state[k], self.mesh, P("silo"))
            for k in ("theta", "eta_G", "opt_server"):
                self.state[k] = distributed.globalize(
                    self.state[k], self.mesh, P())
        self.comm = CommMeter()
        # Shared across structurally-identical Servers (resume!) when the
        # builder hands in a token; private otherwise. See graph_cache.
        self._round_fns: Dict[tuple, Callable] = graph_cache.round_fns(
            graph_cache_token)

    # -- convenience accessors (mirror the host runtime's attributes) -------

    @property
    def theta(self) -> PyTree:
        """Current model parameters θ (replicated)."""
        return self.state["theta"]

    @property
    def eta_G(self) -> PyTree:
        """Current global variational parameters η_G (replicated)."""
        return self.state["eta_G"]

    @property
    def eta_L(self) -> PyTree:
        """Stacked per-silo variational parameters η_{L_j}.

        Leading axis is ``J_pad`` (= J rounded up to the mesh size);
        rows ``J:`` are permanently-masked padding — slice ``[:J]`` for
        the real federation.
        """
        return self.state["eta_L"]

    @property
    def strategy(self) -> ServerStrategy:
        """The server's default update rule (overridable per ``run``)."""
        return self._strategy

    # -- strategy resolution -------------------------------------------------

    def _resolve(self, algorithm) -> ServerStrategy:
        """None / name / spec / instance → a ServerStrategy instance."""
        if algorithm is None:
            return self._strategy
        return resolve_strategy(algorithm)

    def _ensure_strategy_state(self, strat: ServerStrategy) -> None:
        """Lazily create per-silo strategy state when first needed.

        Restored checkpoints (and the constructor's default strategy)
        arrive with state already populated; this only fills the gap
        when ``run`` is pointed at a stateful strategy the Server was
        not built with.
        """
        if strat.has_silo_state and not jax.tree_util.tree_leaves(
            self.state.get("strategy", {})
        ):
            self.state["strategy"] = strat.init_silo_state(self)
            if self.n_processes > 1:
                from repro.federated import distributed

                self.state["strategy"] = distributed.globalize(
                    self.state["strategy"], self.mesh, P("silo"))
        self.state.setdefault("strategy", {})

    # -- silo-axis padding ---------------------------------------------------

    def pad_silo_axis(self, tree: PyTree) -> PyTree:
        """Pad a J-leading stacked tree to ``J_pad`` rows (tile row 0).

        Padded rows never influence the run: every mask/weight vector
        carries zeros for them, so their state stays frozen and their
        uploads are masked out of the aggregation.
        """
        pad = self.J_pad - self.J
        if pad == 0:
            return tree
        return jax.tree_util.tree_map(
            lambda x: jnp.concatenate(
                [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])], axis=0
            ),
            tree,
        )

    def _pad_mask(self, mask: jnp.ndarray) -> jnp.ndarray:
        """Extend a (J,) mask/weight vector with zeros for padded silos."""
        pad = self.J_pad - self.J
        if pad == 0:
            return mask
        return jnp.concatenate([mask, jnp.zeros((pad,), mask.dtype)])

    # -- dynamic population growth ------------------------------------------

    def grow_silos(self, datas: Sequence[PyTree],
                   num_obs: Optional[Sequence[int]] = None,
                   eta_rows: Optional[Sequence[PyTree]] = None) -> None:
        """Append joining silos to the stacked silo axis, in place.

        The population engine's join path: the new silos' data shards
        (equal leaf shapes with the existing federation) are appended,
        J and the mesh-chunked ``J_pad`` are recomputed, and every
        silo-stacked tree is rebuilt — existing real rows are copied
        bitwise, new rows are initialized, padding is re-tiled. The
        compiled round retraces only when ``J_pad`` steps (the
        round-fn cache is keyed by it); growth within the padded chunk
        reuses the compiled graph, with the new silo entering through
        the ``n_j`` argument and its mask column.

        ``eta_rows`` optionally supplies each new silo's initial
        ``η_L`` (the amortized warm start); ``None`` draws the cold
        family init from a deterministic per-silo key — a pure
        function of ``(seed, roster index)``, so a resumed run
        re-grows bit-exactly whenever the join replays. New silos'
        optimizer moments are fresh; per-silo strategy state rows are
        the strategy's init (zero sites — PVI's continual-learning
        join: the new silo's cavity is the current global posterior).
        """
        if not datas:
            return
        if self.n_processes > 1:
            raise NotImplementedError(
                "dynamic population growth is single-process for now "
                "(multi-process federations own silo rows per host)")
        old_J = self.J
        new = list(datas)
        if num_obs is None:
            num_obs = [int(jax.tree_util.tree_leaves(d)[0].shape[0])
                       for d in new]
        real_data = jax.tree_util.tree_map(
            lambda x: x[:old_J], self.data)
        self.J = old_J + len(new)
        n_dev = int(self.mesh.shape["silo"])
        self.J_pad = ((self.J + n_dev - 1) // n_dev) * n_dev
        grown = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0),
            real_data, stack_silos(new))
        self.data = self.pad_silo_axis(grown)
        self.num_obs = np.concatenate([
            self.num_obs[:old_J],
            # repro-lint: allow[R4] — host staging of a Python list at growth time, not a device pull
            np.asarray(list(num_obs), np.float32),
        ])
        self.num_obs = np.concatenate([
            self.num_obs,
            np.broadcast_to(self.num_obs[:1], (self.J_pad - self.J,)),
        ]).astype(np.float32)

        if self._has_local:
            if eta_rows is None:
                # repro-lint: allow[R1] — per-silo growth init root: a pure function of (seed, roster index), re-derived bit-exactly on resume
                root = jax.random.PRNGKey(self.seed + 1)
                keys = jnp.stack([
                    jax.random.fold_in(root, j)
                    for j in range(old_J, self.J)])
                new_eta = jax.vmap(self.problem.local_family.init)(keys)
            else:
                if len(eta_rows) != len(new):
                    raise ValueError(
                        f"eta_rows has {len(eta_rows)} entries for "
                        f"{len(new)} joining silos")
                new_eta = stack_silos(list(eta_rows))
            new_opt = jax.vmap(self._local_opt.init)(new_eta)
            for k, rows in (("eta_L", new_eta), ("opt_local", new_opt)):
                real = jax.tree_util.tree_map(
                    lambda x: x[:old_J], self.state[k])
                self.state[k] = self.pad_silo_axis(jax.tree_util.tree_map(
                    lambda a, b: jnp.concatenate([a, b], axis=0),
                    real, rows))

        old_strat = self.state.get("strategy", {})
        if jax.tree_util.tree_leaves(old_strat):
            fresh = self._strategy.init_silo_state(self)
            self.state["strategy"] = jax.tree_util.tree_map(
                lambda f, o: f.at[:old_J].set(o[:old_J]), fresh, old_strat)

    # -- model-axis wire sharding -------------------------------------------
    #
    # On a 2-D (silo, model) mesh each device uploads one model-column
    # block of its silo rows' wire. The upload pipeline runs on FULL
    # rows first (DP noise and int8 row scales stay bit-identical to
    # the 1-D mesh), then every device slices its P/model_world column
    # block, so the big gather over "silo" moves (J_pad, Pb) blocks —
    # 1/model_world of the 1-D mesh's per-device gather traffic. A
    # second, row-local gather over "model" reconstructs the full
    # (J_pad, P) matrix BEFORE decode/aggregation, so the combine
    # compiles against the exact shapes and values of the 1-D mesh.

    def _model_block(self, P_dim: int):
        """(Pb, pad): the column-block width and zero-pad up to mw·Pb."""
        mw = self.model_world
        Pb = -(-P_dim // mw)
        return Pb, Pb * mw - P_dim

    def _shard_model_cols(self, enc: PyTree, P_dim: int) -> PyTree:
        """Slice every (rows, P) wire leaf to this device's column block.

        Per-silo side leaves (the int8 scale vector) have no P trailing
        dim and stay replicated over ``model``.
        """
        if self.model_world == 1:
            return enc
        Pb, pad = self._model_block(P_dim)
        mi = jax.lax.axis_index("model")

        def leaf(x):
            if x.ndim < 2 or x.shape[-1] != P_dim:
                return x
            xp = jnp.pad(x, ((0, 0),) * (x.ndim - 1) + ((0, pad),))
            return jax.lax.dynamic_slice_in_dim(
                xp, mi * Pb, Pb, axis=x.ndim - 1)

        return jax.tree_util.tree_map(leaf, enc)

    def _gather_model_cols(self, enc: PyTree, P_dim: int) -> PyTree:
        """Silo-gathered (J_pad, Pb) blocks -> the full (J_pad, P) wire.

        The inverse of :meth:`_shard_model_cols`, run BEFORE decode and
        aggregation: the combine then compiles against the exact shapes
        and values of the 1-D mesh, which is what keeps 2-D trajectories
        bit-exact. (XLA's axis-0 reductions are not bitwise invariant
        under column slicing — a columnwise combine + concat drifts at
        the last bit for some widths — so the blocks must be rejoined
        first.) The int8 wire gathers its quantized bytes here; per-row
        side leaves (the f32 scale vector) were never sliced and stay
        as gathered over ``silo``.
        """
        if self.model_world == 1:
            return enc
        Pb, pad = self._model_block(P_dim)

        def leaf(x):
            if x.ndim < 2 or x.shape[-1] != Pb:
                return x
            full = jax.lax.all_gather(x, "model", axis=x.ndim - 1,
                                      tiled=True)
            return full[..., :P_dim] if pad else full

        return jax.tree_util.tree_map(leaf, enc)

    # -- wire accounting -----------------------------------------------------

    def ship_template(self, algorithm=None) -> PyTree:
        """Shape-only pytree of one silo's upload (pre-compression)."""
        return self._resolve(algorithm).ship_template(self)

    def wire_spec(self, algorithm=None) -> TreeSpec:
        """The flat wire bijection of one upload (static; P = its dim)."""
        return TreeSpec.of(self.ship_template(algorithm))

    def bytes_up_per_silo(self, algorithm=None) -> int:
        """Post-compression upload bytes for one silo, one gather.

        On the flat wire the compressor sees ONE (P,) float32 vector —
        an int8 codec therefore pays a single 4-byte scale per silo
        instead of one per pytree leaf. The compressor's ``wire_bytes``
        is told the wire layout so the host meter matches what the
        compiled collective actually gathers.
        """
        template = self.ship_template(algorithm)
        return self.compressor.wire_bytes(template, wire=self.wire)

    def bytes_down_per_silo(self) -> int:
        """Broadcast bytes: (θ, η_G) raw; the round key is ~0 and elided."""
        return NoCompression().wire_bytes(
            {"theta": self.state["theta"], "eta_G": self.state["eta_G"]}
        )

    def compiled_collective_bytes(
        self, algorithm=None, local_steps: int = 1
    ) -> Dict[str, float]:
        """Ring-traffic bytes per collective kind in the compiled round.

        Lowers the jitted round function and applies
        ``launch.roofline.collective_bytes`` to the optimized HLO. On a
        single-device mesh XLA elides the collectives entirely (all
        entries 0); run under a multi-device mesh (or the forced-host-
        device trick of ``launch/comm.py``) for real numbers. On a 2-D
        ``(silo, model)`` mesh the total covers BOTH collectives: the
        silo gather of model-column blocks (1/model_world of the 1-D
        gather) plus the small reconstruction gather over ``model``.
        """
        from repro.launch.roofline import collective_bytes

        compiled = self._lower(algorithm, local_steps).compile()
        return collective_bytes(compiled.as_text())

    def compiled_roofline(
        self, algorithm=None, local_steps: int = 1
    ) -> Dict[str, float]:
        """Roofline terms of the compiled round: FLOPs + bytes moved.

        Lowers the jitted round function and reads XLA's
        ``cost_analysis`` (per-partition FLOPs and HBM bytes accessed)
        plus ``launch.roofline.collective_bytes`` on the optimized HLO.
        The ``bytes_accessed`` term is what the fused wire kernels
        attack: fewer memory passes over the (J, P) matrix per round.
        """
        from repro.launch.roofline import collective_bytes

        compiled = self._lower(algorithm, local_steps).compile()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # jax < 0.5 wraps it per-program
            ca = ca[0] if ca else {}
        return {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "collective_bytes": float(
                sum(collective_bytes(compiled.as_text()).values())),
        }

    def _lower(self, algorithm, local_steps: int):
        """Lower one compiled round with all-ones masks (for inspection)."""
        strat = self._resolve(algorithm)
        fn = self._get_round(strat, local_steps)
        mask_shape = ((local_steps, self.J_pad) if strat.cadence == "step"
                      else (self.J_pad,))
        ones = jnp.ones(mask_shape, jnp.float32)
        with debug.suspended_tracing():  # inspection traces are free
            return fn.lower(
                self.state, self.data, jnp.asarray(self.num_obs),
                # repro-lint: allow[R1] — dummy key for shape-only lowering; never executed
                jax.random.PRNGKey(0), ones, ones
            )

    def _fused_trim(self):
        """Fused-reduction mode for the configured aggregator.

        ``(None,)`` → fused weighted mean, ``(frac,)`` → fused trimmed
        mean, ``None`` → aggregator not expressible as a fused kernel
        (custom subclass): the fused wire falls back to
        ``aggregator.combine`` on the dequantized matrix.
        """
        fused = getattr(self.aggregator, "fused_reduction", None)
        if fused == "mean":
            return (None,)
        if fused == "trimmed":
            return (float(self.aggregator.trim_frac),)
        return None

    # -- the compiled round --------------------------------------------------

    def _get_round(self, algorithm, local_steps: int) -> Callable:
        strat = self._resolve(algorithm)
        strat.validate(self)
        self._ensure_strategy_state(strat)
        # J_pad keys the entry: growing the silo axis past a mesh-chunk
        # boundary is a NEW graph (every silo-sharded shape changes),
        # while growth within the padded chunk reuses the compiled one
        # — per-silo counts ride the jit boundary as the n_j argument.
        key = (strat.cache_key(), local_steps, self.J_pad)
        if key not in self._round_fns:
            if strat.cadence == "step":
                body = self._step_body(strat, local_steps)
            elif strat.cadence == "round":
                body = self._round_body(strat, local_steps)
            else:
                raise ValueError(
                    f"strategy {strat.name!r} has unknown cadence "
                    f"{strat.cadence!r} (step/round)"
                )
            sharded = shard_map(
                body,
                mesh=self.mesh,
                in_specs=(
                    P(), P(), P(),  # theta, eta_G, opt_server (replicated)
                    P("silo"), P("silo"),  # eta_L, opt_local
                    P("silo"),  # per-silo strategy state (λ_j, ...)
                    P("silo"), P("silo"), P("silo"),  # data, sids, n_j
                    # Participation mask rides ONCE, replicated; each block
                    # slices its silos' entries via sids. Passing it a
                    # second time with P("silo") made GSPMD reshard it with
                    # an extra 4-byte all-gather in the compiled round.
                    # ``weights`` are the aggregation weights (== mask on
                    # the sync path; staleness-decayed on the async path).
                    P(), P(), P(),  # full mask, full weights, round key
                ),
                out_specs=(
                    P(), P(), P(), P("silo"), P("silo"), P("silo"), P()
                ),
                check_rep=False,
            )

            # Mesh shape and J_pad ride the tag (a topology change or a
            # padded-chunk growth step is a legitimate new trace); the
            # wire stays LAST — that suffix is part of the watchdog-tag
            # contract (tests/test_sanitize).
            trace_tag = ("round", strat.cache_key(), local_steps,
                         self.J_pad,
                         tuple(sorted(self.mesh.shape.items())), self.wire)
            j_pad = self.J_pad

            def round_fn(state, data, n_j, round_key, mask, weights):
                # Trace-time only: the recompile watchdog's counter
                # (no-op unless repro.debug.sanitize is active).
                debug.trace_event(trace_tag)
                sids = jnp.arange(j_pad, dtype=jnp.int32)
                (theta, eta_G, opt_server, eta_L, opt_L, strat_state,
                 elbos) = sharded(
                    state["theta"], state["eta_G"], state["opt_server"],
                    state["eta_L"], state["opt_local"],
                    state.get("strategy", {}),
                    data, sids, n_j, mask, weights, round_key,
                )
                new_state = {
                    "theta": theta, "eta_G": eta_G, "eta_L": eta_L,
                    "opt_server": opt_server, "opt_local": opt_L,
                    "strategy": strat_state,
                }
                return new_state, {"elbo": elbos}

            self._round_fns[key] = jax.jit(round_fn)
        return self._round_fns[key]

    def _ctx(self, K: int, wire) -> StrategyContext:
        """Static per-body facts handed to every strategy hook."""
        return StrategyContext(
            problem=self.problem,
            # The FULL federation width, not the currently-joined J: a
            # dynamic population's estimators target the roster-wide
            # ELBO, with absent silos as non-participants (§3 Remark).
            # Without a population the two coincide.
            J=self.fed_J,
            K=K,
            server_opt=self._server_opt,
            local_opt=self._local_opt,
            has_local=self._has_local,
            eta_mode=self.eta_mode,
            aggregator=self.aggregator,
            wire=wire,
            fused=self.wire == "fused",
            # N = Σ_j N_j over the full federation — the padded tail
            # repeats silo 0's count purely to keep the dummy silos'
            # per-silo scale finite (their contribution is masked out).
            total_obs=self.fed_obs,
        )

    def _ship_upload(self, ship, m_j, key, ref, wire, fused):
        """The strategy-independent upload pipeline for one silo.

        pack → (fused: defer to the stacked fused pass) → DP privatize
        against the strategy's wire reference → data-independent filler
        for non-participants (the reference itself, or zeros) → encode.
        Non-participating silos never put data-dependent bytes on the
        wire — they "don't upload"; aggregation masks them anyway — so
        the accountant's subsampling amplification holds on what is
        actually transmitted.
        """
        if wire is not None:
            ship = wire.pack(ship)
        if fused:
            # Privatize/mask/quantize run as ONE fused pass over the
            # stacked (J, P) block after the per-silo vmap.
            return ship
        if self.privacy is not None:
            # Clip + noise BEFORE compression and the gather: the wire
            # never carries a raw silo quantity.
            ship = self.privacy.privatize(ship, key, reference=ref)
        idle = (ref if ref is not None
                else jax.tree_util.tree_map(jnp.zeros_like, ship))
        ship = _select(m_j > 0.5, ship, idle)
        return self.compressor.encode(ship)

    def _packed_reference(self, strat, ctx, wire, theta, eta_G):
        """The strategy's wire reference, packed to wire form (or None)."""
        ref = strat.reference_tree(ctx, theta, eta_G)
        if ref is not None and wire is not None:
            ref = wire.pack(ref)
        return ref

    def _step_body(self, strat: ServerStrategy, K: int) -> Callable:
        """Round = K synchronized steps: gather + server update every step."""
        problem = self.problem
        agg, comp = self.aggregator, self.compressor
        privacy = self.privacy
        # Flat wire: the whole upload is ONE (P,) f32 vector, so clip,
        # noise, quantization, the gather and the aggregation below all
        # see a single array per silo ((J, P) once stacked). The fused
        # wire keeps the same layout but runs those stages as the Pallas
        # kernels of repro.kernels.wire on the stacked block.
        wire = self.wire_spec(strat) if self.wire != "legacy" else None
        fused = self.wire == "fused"
        int8 = _wire_codec(comp) == "int8"
        trim = self._fused_trim()
        ctx = self._ctx(K, wire)

        def body(theta, eta_G, opt_server, eta_L, opt_L, strat_state,
                 data_sh, sids, n_j, masks_full, weights_full, round_key):
            # masks_full: (K, J) — step-cadence strategies sample
            # participation PER EXCHANGE (each gather is its own
            # subsampling event; this is what makes the accountant's
            # per-exchange amplification sound — one shared mask across
            # the K gathers would expose K correlated outputs per draw).
            # weights_full: (K, J) aggregation weights — identical to
            # masks_full on the sync path.

            def sync_step(carry, step_xs):
                t, mask_full, w_full = step_xs
                mask_sh = mask_full[sids]  # this block's silos
                n_active = jnp.maximum(jnp.sum(mask_full), 1.0)
                (theta, eta_G, opt_server, eta_L, opt_L,
                 strat_state) = carry
                eps_G = global_eps(problem, round_key, t)
                ref = self._packed_reference(strat, ctx, wire, theta, eta_G)

                def per_silo(eta_Lj, opt_Lj, st_j, data_j, sid, m_j,
                             n_obs_j):
                    eta_Lj, opt_Lj, st_j, ship, hatLj = strat.silo_step(
                        ctx, theta, eta_G, eta_Lj, opt_Lj, st_j,
                        data_j, sid, m_j, n_obs_j, round_key, t, eps_G,
                    )
                    key = (None if privacy is None
                           else privacy.upload_key(round_key, t, sid))
                    ship = self._ship_upload(ship, m_j, key, ref, wire,
                                             fused)
                    return eta_Lj, opt_Lj, st_j, ship, hatLj * m_j

                eta_L, opt_L, strat_state, enc, hatL = jax.vmap(per_silo)(
                    eta_L, opt_L, strat_state, data_sh, sids, mask_sh, n_j
                )
                if fused:
                    enc = _fused_ship(
                        enc, mask_sh,
                        _fused_keys(privacy, round_key, t, sids),
                        ref, privacy, comp, int8)
                if wire is not None:
                    # 2-D mesh: slice AFTER the full-row pipeline so DP
                    # noise / int8 scales match the 1-D mesh bit-exactly,
                    # then rejoin the gathered blocks before decoding.
                    enc = self._shard_model_cols(enc, wire.dim)
                enc = _coalesced_all_gather(enc, "silo")
                if wire is not None:
                    enc = self._gather_model_cols(enc, wire.dim)
                hatL_sum = jax.lax.psum(jnp.sum(hatL), "silo")

                if fused and int8 and trim is not None:
                    # Dequantize inside the reduction kernel: the server
                    # never materializes the dequantized (J, P) matrix.
                    mean_g = wire_kernels.fused_combine(
                        enc["q"], w_full, scales=enc["scale"],
                        trim_frac=trim[0])
                elif fused:
                    mat = _fused_decode(enc, comp, int8)
                    mean_g = (wire_kernels.fused_combine(
                        mat, w_full, trim_frac=trim[0])
                        if trim is not None else agg.combine(mat, w_full))
                else:
                    shipped = jax.vmap(comp.decode)(enc)  # (J, P) | per leaf
                    mean_g = agg.combine(shipped, w_full)
                if wire is not None:
                    mean_g = wire.unpack(mean_g)
                theta, eta_G, opt_server, elbo = strat.server_step(
                    ctx, theta, eta_G, opt_server, mean_g, hatL_sum,
                    n_active, eps_G,
                )
                carry = (theta, eta_G, opt_server, eta_L, opt_L,
                         strat_state)
                return carry, elbo

            carry = (theta, eta_G, opt_server, eta_L, opt_L, strat_state)
            carry, elbos = jax.lax.scan(
                sync_step, carry, (jnp.arange(K), masks_full, weights_full)
            )
            return (*carry, elbos)

        return body

    def _round_body(self, strat: ServerStrategy, K: int) -> Callable:
        """Round = K local steps per silo, ONE gather + one server merge."""
        agg, comp = self.aggregator, self.compressor
        privacy = self.privacy
        wire = self.wire_spec(strat) if self.wire != "legacy" else None
        fused = self.wire == "fused"
        int8 = _wire_codec(comp) == "int8"
        trim = self._fused_trim()
        ctx = self._ctx(K, wire)

        def body(theta, eta_G, opt_server, eta_L, opt_L, strat_state,
                 data_sh, sids, n_j, mask_full, w_full, round_key):
            mask_sh = mask_full[sids]  # this block's silos
            n_active = jnp.maximum(jnp.sum(mask_full), 1.0)
            # The strategy's wire reference — for broadcast-reference
            # strategies this is the round's public (θ, η_G) in wire
            # form: the DP delta reference AND the data-independent
            # upload of silos that did not participate.
            ref = self._packed_reference(strat, ctx, wire, theta, eta_G)

            def per_silo(eta_Lj, opt_Lj, st_j, data_j, sid, m_j, n_obs_j):
                eta_Lj, opt_Lj, st_j, ship, elbos = strat.local_run(
                    ctx, theta, eta_G, eta_Lj, opt_Lj, st_j,
                    data_j, sid, m_j, n_obs_j, round_key,
                )
                key = (None if privacy is None
                       else privacy.upload_key(round_key, 0, sid))
                ship = self._ship_upload(ship, m_j, key, ref, wire, fused)
                return eta_Lj, opt_Lj, st_j, ship, elbos * m_j

            eta_L, opt_L, strat_state, enc, elbos = jax.vmap(per_silo)(
                eta_L, opt_L, strat_state, data_sh, sids, mask_sh, n_j
            )
            if fused:
                enc = _fused_ship(
                    enc, mask_sh, _fused_keys(privacy, round_key, 0, sids),
                    ref, privacy, comp, int8)
            if wire is not None:
                # 2-D mesh: slice AFTER the full-row pipeline so DP
                # noise / int8 scales match the 1-D mesh bit-exactly,
                # then rejoin the gathered blocks before decoding.
                enc = self._shard_model_cols(enc, wire.dim)
            enc = _coalesced_all_gather(enc, "silo")
            if wire is not None:
                enc = self._gather_model_cols(enc, wire.dim)
            elbo_t = jax.lax.psum(jnp.sum(elbos, axis=0), "silo") / n_active

            if fused:
                # Round-cadence merges may need every silo's upload (the
                # barycenter), so the dequantized matrix is materialized
                # here (unlike the step cadence); the reduction itself
                # still runs as the fused kernel.
                shipped = _fused_decode(enc, comp, int8)
                vec = (wire_kernels.fused_combine(
                    shipped, w_full, trim_frac=trim[0])
                    if trim is not None else agg.combine(shipped, w_full))
                combined = wire.unpack(vec)
            elif wire is not None:
                shipped = jax.vmap(comp.decode)(enc)  # (J, P) matrix
                combined = wire.unpack(agg.combine(shipped, w_full))
            else:
                shipped = jax.vmap(comp.decode)(enc)  # stacked pytree
                combined = {k: agg.combine(v, w_full)
                            for k, v in shipped.items()}
            theta_new, eta_new, opt_server = strat.server_update(
                ctx, theta, eta_G, opt_server, combined, shipped,
                w_full, n_active,
            )
            return (theta_new, eta_new, opt_server, eta_L, opt_L,
                    strat_state, elbo_t)

        return body

    # -- driver --------------------------------------------------------------

    def run(
        self,
        num_rounds: int,
        *,
        algorithm=None,
        local_steps: int = 1,
        scheduler: Optional[RoundScheduler] = None,
        callback: Optional[Callable[[int, dict], None]] = None,
        start_round: int = 0,
        population=None,
    ) -> Dict[str, list]:
        """Advance the federation ``num_rounds`` rounds; returns history.

        ``algorithm`` selects the update rule — a registry name (any of
        :func:`repro.federated.strategy.strategy_names`), a
        ``StrategySpec``, or a ``ServerStrategy`` instance; None uses
        the Server's default strategy.

        ``start_round`` is the absolute index of the first round: the
        round PRNG key, the scheduler's participation draws and the
        accountant's exchange indices are all functions of the absolute
        round, so ``run(a); run(b, start_round=a)`` replays exactly the
        same stream as one ``run(a + b)`` — the property
        ``federated.api.Experiment`` builds its bit-exact save/resume
        guarantee on.

        One round is ``local_steps`` optimizer steps: a step-cadence
        strategy pays one up+down exchange per step, a round-cadence
        strategy one per round — the meter (``self.comm``) records
        exactly that asymmetry. ``scheduler`` injects partial
        participation / straggler masks: uninvited silos cost nothing;
        invited stragglers (dropout) receive the broadcast (download is
        billed) but never upload, and the aggregation is rescaled by
        the realized active count (unbiased, §3 Remark).

        With ``privacy`` set, each of the round's ``exchanges`` gathers
        is one (subsampled) Gaussian-mechanism invocation: the owned
        accountant composes them (q = the scheduler's invitation rate)
        and ``history["epsilon"]`` traces the cumulative ε at the
        policy's δ after each round. A step-cadence strategy draws a
        FRESH participation mask for every local step (schedule index =
        exchange index ``r * local_steps + t``), so each gather is an
        independent subsampling event and the per-exchange amplification
        is sound; a round-cadence strategy draws one mask per round
        (index ``r``).

        ``population`` optionally threads a
        :class:`~repro.federated.population.PopulationEngine` through
        the loop: its ``begin_round`` hook processes the round's churn
        events first (joins may grow the silo axis, which re-fetches
        the compiled round for the new ``J_pad``), and the resulting
        membership mask multiplies the scheduler's participation mask
        — with a returning silo's first round back staleness-decayed
        in the aggregation weights. The scheduler stays roster-wide
        (its masks are sliced to the currently-joined J), so the
        participation schedule is independent of the churn schedule.
        """
        if local_steps < 1:
            raise ValueError(f"local_steps must be >= 1, got {local_steps}")
        strat = self._resolve(algorithm)
        # One-time setup — graph construction and byte metering both
        # evaluate wire templates eagerly on host, which is sanctioned
        # under the transfer guard (repro.debug.host_bridge).
        with debug.host_bridge():
            fn = self._get_round(strat, local_steps)
            up1 = self.bytes_up_per_silo(strat)
            down1 = self.bytes_down_per_silo()
        # The default scheduler covers the FULL federation (fed_J == J
        # without a population): churn multiplies membership into the
        # roster-wide participation draws, it never re-shapes them.
        sched = scheduler or RoundScheduler(self.fed_J, seed=self.seed)
        step_cadence = strat.cadence == "step"
        exchanges = local_steps if step_cadence else 1
        history: Dict[str, list] = {
            "elbo": [], "elbo_trace": [], "bytes_up": [], "bytes_down": [],
            "n_active": [],
        }
        if self.accountant is not None:
            history["epsilon"] = []
            # Poisson-q surrogate for the scheduler's fixed-size invitation
            # (docs/privacy.md §Accounting); custom schedulers without a
            # participation attribute are accounted at full participation.
            q = float(getattr(sched, "participation", 1.0))
        with debug.host_bridge():
            # repro-lint: allow[R1] — root of the round stream; every key below folds in the absolute round index, so resume replays it exactly
            base_key = jax.random.PRNGKey(self.seed)
        for r in range(start_round, start_round + num_rounds):
            # A step-cadence strategy synchronizes every local step, so
            # each of the round's `exchanges` gathers is its OWN
            # participation draw (schedule index = exchange index) —
            # required for the accountant's per-exchange subsampling
            # amplification to be sound. Round cadence gathers once:
            # one draw per round.
            ex_idx = ([r * local_steps + t for t in range(local_steps)]
                      if step_cadence else [r])
            # Mask/key construction transfers tiny host scalars to
            # device, so it runs in the sanctioned control-plane window
            # (repro.debug.host_bridge); metric pulls below stay under
            # the transfer guard and must use explicit device_get.
            with debug.host_bridge():
                present = stale_w = None
                if population is not None:
                    # Churn first: a join may grow J (and step J_pad,
                    # re-fetching the compiled round); the membership
                    # and staleness vectors cover the post-growth J.
                    present, stale_w = population.begin_round(self, r)
                    fn = self._get_round(strat, local_steps)
                raw_masks = [sched.mask(i) for i in ex_idx]
                if present is not None:
                    pr = jnp.asarray(present)
                    sw = jnp.asarray(stale_w)
                    ex_masks = [m[: self.J] * pr for m in raw_masks]
                    wt_masks = [m[: self.J] * sw for m in raw_masks]
                else:
                    ex_masks = raw_masks
                    wt_masks = raw_masks
                padded = [self._pad_mask(m) for m in ex_masks]
                padded_w = [self._pad_mask(w) for w in wt_masks]
                mask = (jnp.stack(padded) if step_cadence else padded[0])
                weights = (jnp.stack(padded_w) if step_cadence
                           else padded_w[0])
                n_j = jnp.asarray(self.num_obs)
                round_key = jax.random.fold_in(base_key, r)
                if self.n_processes > 1:
                    # Control inputs must be global arrays in a
                    # multi-process run; every process computed the
                    # identical host values (scheduler and key stream
                    # are pure functions of seed and absolute round).
                    from repro.federated import distributed

                    mask = distributed.replicated(mask, self.mesh)
                    weights = distributed.replicated(weights, self.mesh)
                    n_j = distributed.replicated(n_j, self.mesh)
                    round_key = distributed.replicated(
                        round_key, self.mesh)
                # Stragglers received the broadcast before dropping:
                # bill their download. Schedulers without the optional
                # invited() protocol attribute bill reporters — and an
                # absent silo receives no broadcast at all.
                invited_fn = getattr(sched, "invited", None)
                inv_masks = [
                    invited_fn(i) if invited_fn is not None else ex_masks[k]
                    for k, i in enumerate(ex_idx)
                ]
                if present is not None:
                    inv_masks = [m[: self.J] * pr for m in inv_masks]
            active = [int(np.sum(jax.device_get(m))) for m in ex_masks]
            invited = [
                max(int(np.sum(jax.device_get(m))), active[k])
                for k, m in enumerate(inv_masks)
            ]
            # Sync rounds aggregate with the participation mask itself
            # (population churn decays a returning silo's weight); the
            # async engine passes staleness-decayed weights instead.
            self.state, metrics = fn(self.state, self.data, n_j,
                                     round_key, mask, weights)
            elbos = jax.device_get(metrics["elbo"])
            up = sum(active) * up1
            down = sum(invited) * down1
            n_active = active[-1]  # the round's final exchange
            self.comm.record(up, down)
            history["elbo"].append(float(elbos[-1]))
            history["elbo_trace"].extend(float(e) for e in elbos)
            history["bytes_up"].append(up)
            history["bytes_down"].append(down)
            history["n_active"].append(n_active)
            metrics_out = {
                "elbo": history["elbo"][-1], "bytes_up": up,
                "bytes_down": down, "n_active": n_active,
            }
            if self.accountant is not None:
                self.accountant.step(
                    noise_multiplier=self.privacy.noise_multiplier,
                    sampling_rate=q,
                    steps=exchanges,
                )
                eps = self.accountant.epsilon(self.privacy.delta)[0]
                history["epsilon"].append(eps)
                metrics_out["epsilon"] = eps
            if callback:
                callback(r, metrics_out)
        return history
