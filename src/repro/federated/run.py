"""Federated runtime CLI — a thin spec-builder over ``repro.federated.api``.

    PYTHONPATH=src python -m repro.federated.run --model hier_bnn \
        --silos 8 --rounds 5 --local-steps 4

Flags build a declarative :class:`~repro.federated.api.ExperimentSpec`
(model registry name + kwargs, scenario, optimizers, seed), which is the
ONLY construction path — the CLI never wires a Server by hand. That makes
every run serializable and resumable:

    ... --dump-spec > exp.json          # print the spec as JSON, exit
    ... --spec exp.json                 # run exactly that spec
    ... --ckpt-dir runs/a               # checkpoint full round state
    ... --resume runs/a                 # continue a preempted run
    ... --list-models                   # registered models + descriptions

Variational families are spec-overridable (``repro.core.family``):

    ... --global-family cholesky           # full unitriangular η_G factor
    ... --global-family lowrank --global-family-kwargs '{"rank": 2}'

Server strategies are pluggable (``repro.federated.strategy``): ``--algo``
picks a registered name (or ``both`` for the SFVI/SFVI-Avg pair), and
``--strategy``/``--strategy-kwargs`` select one with hyperparameters:

    ... --strategy pvi --strategy-kwargs '{"damping": 0.2}'

Scenario knobs cover partial participation, straggler dropout, robust
aggregation, int8 wire compression and differential privacy:

    ... --participation 0.5 --dropout 0.1 --aggregator trimmed --compress int8
    ... --dp-noise 1.0 --dp-clip 0.5 --dp-delta 1e-5   # DP round + (ε, δ)

Buffered-asynchronous execution (FedBuff-style, docs/federated.md):

    ... --async --buffer-size 2 --staleness-decay 0.5 --latency lognormal

``--sweep`` ignores the single-scenario knobs and walks the full
scenario matrix (participation × stragglers × compression × DP from
``scenario_matrix``) in one invocation, printing an ELBO/ε/bytes table:

    ... --sweep --sweep-participation 1.0,0.5 --sweep-dp-noise 0.0,1.0

``--devices N`` forces N XLA host devices (as ``launch/comm.py`` does) so
the ``silo`` mesh axis actually spans devices and
``Server.compiled_collective_bytes`` reports real collective traffic.

Execution topology is spec state (``spec.runtime``), set here with:

    ... --mesh silo=4,model=2 --devices 8    # 2-D (silo x model) mesh
    ... --wire fused                          # Pallas wire pipeline

Multi-process federation (one jax process per host; every process runs
the SAME command plus its process identity — or exports the
REPRO_COORDINATOR / REPRO_NUM_PROCESSES / REPRO_PROCESS_ID env schema):

    ... --mesh silo=8,multiprocess \
        --coordinator 10.0.0.1:8476 --num-processes 2 --process-id 0

JAX is imported *after* argument parsing so --devices can set XLA_FLAGS
(the registry lists model names without importing JAX).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.models.paper.registry import list_models, model_names


def build_parser() -> argparse.ArgumentParser:
    """CLI schema (kept separate so docs/tests can introspect flags)."""
    ap = argparse.ArgumentParser(prog="repro.federated.run", description=__doc__)
    ap.add_argument("--model", default="hier_bnn", choices=model_names())
    ap.add_argument("--model-kwargs", default="", metavar="JSON",
                    help="JSON dict forwarded to the registry builder")
    ap.add_argument("--global-family", default=None, metavar="NAME",
                    help="override the model's q(Z_G) family with a "
                         "registered one (diag, cholesky, lowrank, ...); "
                         "default: the model's own choice")
    ap.add_argument("--global-family-kwargs", default="", metavar="JSON",
                    help="JSON kwargs for --global-family (e.g. "
                         '\'{"rank": 2}\' for lowrank)')
    ap.add_argument("--local-family", default=None, metavar="NAME",
                    help="override the model's q(Z_L | Z_G) family "
                         "(conditional, batched_diag, ...)")
    ap.add_argument("--local-family-kwargs", default="", metavar="JSON",
                    help="JSON kwargs for --local-family")
    ap.add_argument("--silos", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=None,
                    help="total rounds (default 5; with --resume, extends "
                         "the checkpointed spec's budget)")
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--algo", default="both",
                    choices=["both", "sfvi", "sfvi_avg", "pvi", "fed_ep"])
    ap.add_argument("--strategy", default=None, metavar="NAME",
                    help="registered ServerStrategy name (sfvi, sfvi_avg, "
                         "pvi, fed_ep, or any plugin registered through "
                         "repro.federated.strategy); overrides --algo. "
                         "Validated against the registry at build time so "
                         "plugin strategies need no CLI change")
    ap.add_argument("--strategy-kwargs", default="", metavar="JSON",
                    help="JSON dict of strategy hyperparameters, e.g. "
                         '\'{"damping": 0.2}\' for --strategy pvi')
    ap.add_argument("--lr", type=float, default=2e-2)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--dropout", type=float, default=0.0)
    ap.add_argument("--aggregator", default="mean", choices=["mean", "trimmed"])
    ap.add_argument("--trim-frac", type=float, default=0.1)
    ap.add_argument("--compress", default="none", choices=["none", "int8"])
    ap.add_argument("--eta-mode", default="barycenter",
                    choices=["barycenter", "param"])
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="buffered-asynchronous execution (FedBuff-style "
                         "flushes; round-cadence strategies only: sfvi_avg, "
                         "pvi, fed_ep — see docs/federated.md)")
    ap.add_argument("--buffer-size", type=int, default=2,
                    help="with --async: contributions per server flush")
    ap.add_argument("--staleness-decay", type=float, default=0.5,
                    help="with --async: weight (1+staleness)^-decay")
    ap.add_argument("--latency", default="lognormal",
                    choices=["constant", "lognormal", "straggler"],
                    help="with --async: deterministic per-silo latency model")
    ap.add_argument("--latency-scale", type=float, default=1.0,
                    help="with --async: median simulated seconds per task")
    ap.add_argument("--latency-sigma", type=float, default=0.5,
                    help="with --async: lognormal latency spread")
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="Gaussian noise multiplier z (0 = DP off)")
    ap.add_argument("--dp-clip", type=float, default=1.0,
                    help="L2 clip norm C for silo uploads")
    ap.add_argument("--dp-delta", type=float, default=1e-5,
                    help="target delta for (eps, delta) reports")
    ap.add_argument("--eval-every", type=int, default=0,
                    help="run the registry eval hook every N rounds")
    ap.add_argument("--sweep", action="store_true",
                    help="run the full scenario matrix instead of one config")
    ap.add_argument("--sweep-participation", default="1.0,0.5")
    ap.add_argument("--sweep-dropout", default="0.0,0.2")
    ap.add_argument("--sweep-compress", default="none,int8")
    ap.add_argument("--sweep-dp-noise", default="0.0,1.0")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="", metavar="SPEC",
                    help="federated mesh topology as 'silo=N,model=N' "
                         "(append ',multiprocess' for jax.distributed "
                         "runs), e.g. --mesh silo=4,model=2; default: the "
                         "auto 1-D silo mesh. Lands on spec.runtime.mesh; "
                         "with --resume, overrides the checkpointed "
                         "topology (re-padding/resharding keeps the real "
                         "silos bit-exact)")
    ap.add_argument("--wire", default="flat",
                    choices=["flat", "fused", "legacy"],
                    help="silo->server wire layout (spec.runtime.wire)")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                    help="jax.distributed coordinator address; starts the "
                         "multi-process runtime before any jax use "
                         "(or export REPRO_COORDINATOR)")
    ap.add_argument("--num-processes", type=int, default=None,
                    help="with --coordinator: total process count "
                         "(or REPRO_NUM_PROCESSES)")
    ap.add_argument("--process-id", type=int, default=None,
                    help="with --coordinator: this process's rank "
                         "(or REPRO_PROCESS_ID)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N XLA host devices (0 = real devices)")
    ap.add_argument("--hlo-bytes", action="store_true",
                    help="also report compiled-HLO collective bytes")
    ap.add_argument("--sanitize", action="store_true",
                    help="run under repro.debug.sanitize(): transfer guard, "
                         "NaN checks, and a one-trace-per-config recompile "
                         "watchdog")
    ap.add_argument("--list-models", action="store_true",
                    help="print registered model names + descriptions, exit 0")
    ap.add_argument("--spec", default=None, metavar="FILE",
                    help="run this ExperimentSpec JSON (flags are ignored)")
    ap.add_argument("--dump-spec", action="store_true",
                    help="print the spec the flags build as JSON, exit 0 "
                         "(requires a single --algo)")
    ap.add_argument("--ckpt-dir", default=None, metavar="DIR",
                    help="save full round state here (at the end, and every "
                         "--ckpt-every rounds during the run)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="with --ckpt-dir: also checkpoint every N rounds, "
                         "making long runs preemption-safe (0 = end only)")
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="resume a checkpointed run (reads DIR/spec.json)")
    ap.add_argument("--population", default="", metavar="JSON",
                    help="population dynamics (docs/federated.md): a "
                         "PopulationSpec as JSON, e.g. "
                         '\'{"initial": 2, "arrival_rate": 0.3, '
                         '"departure_rate": 0.1, "return_rate": 0.5}\'. '
                         "--silos becomes the roster MAXIMUM; only "
                         "'initial' silos are live at round 0")
    return ap


def _async_cfg_from_args(args):
    """The --async flags as an AsyncConfig, or None without --async."""
    if not args.async_mode:
        return None
    from repro.federated.scheduler import AsyncConfig

    return AsyncConfig(
        buffer_size=args.buffer_size,
        staleness_decay=args.staleness_decay,
        latency=args.latency,
        latency_scale=args.latency_scale,
        latency_sigma=args.latency_sigma,
    )


def _family_spec(name, kwargs_json):
    """A FamilySpec from the CLI's (name, JSON-kwargs) flag pair."""
    if name is None:
        return None
    from repro.core.family import FamilySpec

    return FamilySpec(name, kwargs=json.loads(kwargs_json or "{}"))


def _spec_from_args(args, algorithm: str):
    """The thin spec-builder: CLI flags -> declarative ExperimentSpec."""
    from repro.federated.api import (ExperimentSpec, ModelSpec,
                                     OptimizerSpec, RuntimeSpec)
    from repro.federated.population import PopulationSpec
    from repro.federated.scheduler import Scenario
    from repro.federated.strategy import StrategySpec
    from repro.launch.mesh import MeshSpec

    strat_kwargs = json.loads(args.strategy_kwargs or "{}")
    async_cfg = _async_cfg_from_args(args)
    scenario = Scenario(
        algorithm=algorithm,
        participation=args.participation,
        dropout=args.dropout,
        compression=args.compress,
        dp_noise=args.dp_noise,
        dp_clip=args.dp_clip,
        dp_delta=args.dp_delta,
        aggregator=args.aggregator,
        trim_frac=args.trim_frac,
        async_cfg=async_cfg,
    )
    return ExperimentSpec(
        model=ModelSpec(
            args.model,
            kwargs=json.loads(args.model_kwargs or "{}"),
            global_family=_family_spec(
                args.global_family, args.global_family_kwargs),
            local_family=_family_spec(
                args.local_family, args.local_family_kwargs),
        ),
        scenario=scenario,
        strategy=(StrategySpec(algorithm, strat_kwargs)
                  if strat_kwargs else None),
        num_silos=args.silos,
        rounds=args.rounds if args.rounds is not None else 5,
        local_steps=args.local_steps,
        server_opt=OptimizerSpec("adam", args.lr),
        eta_mode=args.eta_mode,
        eval_every=args.eval_every,
        seed=args.seed,
        runtime=RuntimeSpec(
            wire=args.wire,
            mesh=MeshSpec.parse(args.mesh),
            sanitize=args.sanitize,
        ),
        population=(PopulationSpec.from_dict(json.loads(args.population))
                    if args.population else None),
    )


def _log_round(total_silos: int):
    def log(r, m):
        eps = f"  eps={m['epsilon']:7.3f}" if "epsilon" in m else ""
        # Async flushes additionally report simulated time + staleness.
        sim = (f"  t={m['sim_time']:8.2f}s  stale<={m['staleness']:.0f}"
               if "sim_time" in m else "")
        print(f"  round {r:3d}  elbo={m['elbo']:14.2f}  "
              f"up={m['bytes_up']:>9d}B  down={m['bytes_down']:>9d}B  "
              f"active={m['n_active']}/{total_silos}{sim}{eps}")
    return log


def _report(exp, hlo_bytes: bool) -> None:
    srv, spec = exp.server, exp.spec
    print(f"  total: {srv.comm.total:,} B in {srv.comm.rounds} rounds "
          f"({srv.comm.per_round:,.0f} B/round)")
    if srv.comm.sim_seconds:
        print(f"  simulated wall-clock: {srv.comm.sim_seconds:,.1f}s "
              f"({srv.comm.sim_seconds / max(srv.comm.rounds, 1):.2f}s/flush)")
    if exp.accountant is not None:
        policy = spec.scenario.privacy()
        eps, order = exp.accountant.epsilon(policy.delta)
        print(f"  privacy: ({eps:.3f}, {policy.delta:g})-DP after "
              f"{exp.accountant.steps} exchanges (RDP order {order})")
    for k, v in exp.evaluate().items():
        print(f"  {k}: {v:.3f}")
    if hlo_bytes:
        coll = srv.compiled_collective_bytes(spec.algorithm, spec.local_steps)
        total = sum(coll.values())
        print(f"  compiled-HLO collective bytes/round: {total:,.0f} "
              f"({ {k: int(v) for k, v in coll.items() if v} })")


def _run_one(spec, bundle, hlo_bytes: bool = False, ckpt_dir=None,
             ckpt_every: int = 0, sanitize=None):
    """Build + run one spec against a pre-staged bundle; print a report."""
    from repro.federated.api import build

    exp = build(spec, bundle=bundle)
    from repro.federated.scheduler import algorithm_label
    name = algorithm_label(spec.algorithm)
    sc = spec.scenario
    print(f"\n== {name}: {spec.model.name}, J={spec.num_silos}, "
          f"{spec.rounds} rounds x {spec.local_steps} local steps"
          + (f", {sc.async_cfg.name}" if sc.async_cfg is not None else "")
          + (f", DP(z={sc.dp_noise:g}, C={sc.dp_clip:g})" if sc.dp_noise > 0 else "")
          + " ==")
    t0 = time.time()
    log = _log_round(spec.num_silos)

    def cb(r, metrics):
        log(r, metrics)
        # Periodic mid-run checkpoint: a preempted run restarts from the
        # last multiple of --ckpt-every instead of from scratch.
        if ckpt_dir and ckpt_every and (r + 1) % ckpt_every == 0 \
                and (r + 1) < spec.rounds:
            exp.save(ckpt_dir)

    exp.run(callback=cb, sanitize=sanitize)
    print(f"  wall time: {time.time() - t0:.1f}s")
    if ckpt_dir:
        print(f"  checkpoint: {exp.save(ckpt_dir)}")
    _report(exp, hlo_bytes)
    return exp


def _run_sweep(args, base_spec, bundle) -> int:
    """One invocation, the whole scenario grid (ELBO / ε / bytes table)."""
    from repro.federated.api import build, scenario_specs
    from repro.federated.scheduler import scenario_matrix

    def floats(s):
        return tuple(float(x) for x in s.split(","))

    # --async adds an async axis to the sweep (sync rows kept for
    # comparison; the matrix drops invalid async combinations itself).
    async_cfg = _async_cfg_from_args(args)
    grid = scenario_matrix(
        algorithms=(["sfvi", "sfvi_avg"] if args.algo == "both"
                    else [args.algo]),
        participation=floats(args.sweep_participation),
        dropout=floats(args.sweep_dropout),
        compression=tuple(args.sweep_compress.split(",")),
        dp_noise=floats(args.sweep_dp_noise),
        dp_clip=args.dp_clip,
        dp_delta=args.dp_delta,
        async_cfgs=((None,) if async_cfg is None else (None, async_cfg)),
    )
    specs = scenario_specs(base_spec, grid)
    print(f"\n== scenario sweep: {base_spec.model.name}, J={base_spec.num_silos}, "
          f"{len(specs)} scenarios x {base_spec.rounds} rounds ==")
    rows = []
    for spec in specs:
        exp = build(spec, bundle=bundle)
        t0 = time.time()
        h = exp.run()
        dt = time.time() - t0
        eps = h["epsilon"][-1] if "epsilon" in h else float("inf")
        rows.append((spec.scenario.name, h["elbo"][-1], eps,
                     exp.comm.per_round / 1024, dt / spec.rounds))
    w = max(len(r[0]) for r in rows)
    print(f"  {'scenario':<{w}}  {'ELBO':>12}  {'eps':>8}  "
          f"{'KiB/round':>10}  {'s/round':>8}")
    for name, elbo, eps, kib, spr in rows:
        eps_s = f"{eps:8.3f}" if eps != float("inf") else "     inf"
        print(f"  {name:<{w}}  {elbo:12.2f}  {eps_s}  {kib:10.1f}  {spr:8.2f}")
    return 0


def _resume(args) -> int:
    """Continue a checkpointed run from ``--resume DIR``.

    ``--rounds N`` extends (or shrinks) the checkpointed spec's total
    budget — e.g. resume a finished 20-round run out to 50.
    """
    import dataclasses

    from repro.federated.api import Experiment, ExperimentSpec

    spec = ExperimentSpec.load(os.path.join(args.resume, "spec.json"))
    if args.rounds is not None:
        spec = dataclasses.replace(spec, rounds=args.rounds)
    if args.mesh:
        # Topology override at resume time: the runtime re-pads and
        # reshards the stacked silo state for the new mesh; the real
        # silos' trajectory is unchanged.
        from repro.launch.mesh import MeshSpec

        spec = dataclasses.replace(
            spec, runtime=dataclasses.replace(
                spec.runtime, mesh=MeshSpec.parse(args.mesh)))
    exp = Experiment.resume(args.resume, spec=spec)
    remaining = exp.remaining_rounds
    print(f"== resume: {spec.name} at round {exp.round}/{spec.rounds} "
          f"({remaining} remaining) ==")
    if remaining:
        out = args.ckpt_dir or args.resume
        log = _log_round(spec.num_silos)

        def cb(r, metrics):
            log(r, metrics)
            # Resumed runs stay preemption-safe under --ckpt-every too.
            if args.ckpt_every and (r + 1) % args.ckpt_every == 0 \
                    and (r + 1) < spec.rounds:
                exp.save(out)

        exp.run(callback=cb, sanitize=True if args.sanitize else None)
        exp.save(out)
    _report(exp, args.hlo_bytes)
    return 0


def main(argv=None) -> int:
    """Run the requested spec(s) and assert the §3.2 byte ordering."""
    args = build_parser().parse_args(argv)
    if args.list_models:
        width = max(len(n) for n, _ in list_models())
        for name, desc in list_models():
            print(f"{name:<{width}}  {desc}")
        return 0
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        )
    if args.coordinator or os.environ.get("REPRO_COORDINATOR"):
        # Multi-process runtime must start before ANY other jax use —
        # the gloo CPU-collectives switch and the device topology are
        # locked at first jax init.
        from repro.federated import distributed

        distributed.initialize(args.coordinator, args.num_processes,
                               args.process_id)
    if args.resume:
        return _resume(args)

    from repro.federated.api import ExperimentSpec

    if args.spec:
        specs = [ExperimentSpec.load(args.spec)]
    else:
        if args.strategy:
            algos = [args.strategy]
        elif args.async_mode:
            # Buffered-async execution needs a round-cadence strategy
            # (step-cadence SFVI has no round-granular contribution to
            # buffer); default to SFVI-Avg, or --strategy pvi/fed_ep.
            algos = ["sfvi_avg"]
        elif args.algo == "both":
            algos = ["sfvi", "sfvi_avg"]
        else:
            algos = [args.algo]
        specs = [_spec_from_args(args, a) for a in algos]
    if args.dump_spec:
        if len(specs) != 1:
            print("--dump-spec needs a single algorithm; pass --algo or "
                  "--strategy with one registered name", file=sys.stderr)
            return 2
        print(specs[0].to_json())
        return 0

    # One dataset/problem staging, shared by every run of this invocation.
    from repro.models.paper.registry import get_model

    base = specs[0]
    # Mirror api.build's staging rule: data_seed overrides seed. Staging
    # with base.seed here would hand --spec runs a different dataset than
    # build(spec)/--resume rebuild.
    data_seed = base.data_seed if base.data_seed is not None else base.seed
    bundle = get_model(base.model.name).build(
        data_seed, base.num_silos, **base.model.kwargs)
    if args.sweep:
        return _run_sweep(args, base, bundle)

    def ckpt_dir_for(spec):
        if not args.ckpt_dir:
            return None
        return (args.ckpt_dir if len(specs) == 1
                else os.path.join(args.ckpt_dir, spec.algorithm))

    exps = {s.algorithm: _run_one(s, bundle, args.hlo_bytes,
                                  ckpt_dir=ckpt_dir_for(s),
                                  ckpt_every=args.ckpt_every,
                                  sanitize=True if args.sanitize else None)
            for s in specs}
    if len(exps) == 2:
        sfvi_pr = exps["sfvi"].comm.per_round
        avg_pr = exps["sfvi_avg"].comm.per_round
        print(f"\nbytes/round: SFVI={sfvi_pr:,.0f}  SFVI-Avg={avg_pr:,.0f}  "
              f"(x{sfvi_pr / max(avg_pr, 1):.1f} reduction — §3.2: one sync "
              f"per round instead of one per local step)")
        if args.local_steps > 1:
            assert avg_pr < sfvi_pr, \
                "SFVI-Avg must ship strictly fewer bytes/round"
        else:
            # K=1: both algorithms exchange once per round — equal cost.
            assert avg_pr <= sfvi_pr, \
                "SFVI-Avg must never ship more bytes/round than SFVI"
    return 0


if __name__ == "__main__":
    sys.exit(main())
