"""Federated runtime CLI — drive a paper model through the compiled Server.

    PYTHONPATH=src python -m repro.federated.run --model hier_bnn \
        --silos 8 --rounds 5 --local-steps 4

Runs SFVI (sync every step) and SFVI-Avg (one sync per round) on the same
problem/seed and prints per-round ELBO plus bytes-on-wire; scenario knobs
cover partial participation, straggler dropout, robust aggregation, int8
wire compression and differential privacy:

    ... --participation 0.5 --dropout 0.1 --aggregator trimmed --compress int8
    ... --dp-noise 1.0 --dp-clip 0.5 --dp-delta 1e-5   # DP round + (ε, δ)

``--sweep`` ignores the single-scenario knobs and walks the full
scenario matrix (participation × stragglers × compression × DP from
``scenario_matrix``) in one invocation, printing an ELBO/ε/bytes table:

    ... --sweep --sweep-participation 1.0,0.5 --sweep-dp-noise 0.0,1.0

``--devices N`` forces N XLA host devices (as ``launch/comm.py`` does) so
the ``silo`` mesh axis actually spans devices and
``Server.compiled_collective_bytes`` reports real collective traffic.

JAX is imported *after* argument parsing so --devices can set XLA_FLAGS.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def build_parser() -> argparse.ArgumentParser:
    """CLI schema (kept separate so docs/tests can introspect flags)."""
    ap = argparse.ArgumentParser(prog="repro.federated.run", description=__doc__)
    ap.add_argument("--model", default="hier_bnn",
                    choices=["toy", "hier_bnn", "fedpop_bnn", "prodlda"])
    ap.add_argument("--silos", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--algo", default="both", choices=["both", "sfvi", "sfvi_avg"])
    ap.add_argument("--lr", type=float, default=2e-2)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--dropout", type=float, default=0.0)
    ap.add_argument("--aggregator", default="mean", choices=["mean", "trimmed"])
    ap.add_argument("--trim-frac", type=float, default=0.1)
    ap.add_argument("--compress", default="none", choices=["none", "int8"])
    ap.add_argument("--eta-mode", default="barycenter",
                    choices=["barycenter", "param"])
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="Gaussian noise multiplier z (0 = DP off)")
    ap.add_argument("--dp-clip", type=float, default=1.0,
                    help="L2 clip norm C for silo uploads")
    ap.add_argument("--dp-delta", type=float, default=1e-5,
                    help="target delta for (eps, delta) reports")
    ap.add_argument("--sweep", action="store_true",
                    help="run the full scenario matrix instead of one config")
    ap.add_argument("--sweep-participation", default="1.0,0.5")
    ap.add_argument("--sweep-dropout", default="0.0,0.2")
    ap.add_argument("--sweep-compress", default="none,int8")
    ap.add_argument("--sweep-dp-noise", default="0.0,1.0")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N XLA host devices (0 = real devices)")
    ap.add_argument("--hlo-bytes", action="store_true",
                    help="also report compiled-HLO collective bytes")
    return ap


def _build_problem(args):
    """Returns (problem, theta0, datas, num_obs, eval_fn|None)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    J = args.silos
    if args.model == "toy":
        from repro.core import (ConditionalGaussian, DiagGaussian, SFVIProblem,
                                StructuredModel)

        rng = np.random.default_rng(args.seed)
        true_b = rng.normal(2.0, 1.0, J)
        datas = [{"y": jnp.asarray(rng.normal(true_b[j], 0.5, 40))}
                 for j in range(J)]
        model = StructuredModel(
            global_dim=1, local_dim=1,
            log_prior_global=lambda th, zg: -0.5 * jnp.sum(zg**2) / 100.0,
            log_local=lambda th, zg, zl, d: (
                -0.5 * jnp.sum((zl - zg) ** 2)
                - 0.5 * jnp.sum((d["y"] - zl) ** 2) / 0.25
            ),
            name="toy_hier_gaussian",
        )
        prob = SFVIProblem(model, DiagGaussian(1),
                           ConditionalGaussian(1, 1, use_coupling=False))
        return prob, {}, datas, None, None

    if args.model in ("hier_bnn", "fedpop_bnn"):
        from repro.models.paper.fixtures import (bnn_posterior_accuracy,
                                                 hier_bnn_federation)

        bnn, datas, test = hier_bnn_federation(
            seed=args.seed, num_silos=J, fedpop=args.model == "fedpop_bnn")

        def eval_fn(srv):
            acc, _ = bnn_posterior_accuracy(bnn, srv.eta_G, srv.eta_L, test)
            return {"test_acc": acc}

        num_obs = [int(d["y"].shape[0]) for d in datas]
        return bnn.problem, {}, datas, num_obs, eval_fn

    # prodlda
    from repro.models.paper.fixtures import prodlda_federation
    from repro.models.paper.prodlda import init_theta, umass_coherence

    lda, datas, counts = prodlda_federation(seed=args.seed, num_silos=J)

    def eval_fn(srv):
        t = np.asarray(lda.topics(srv.eta_G["mu"]))
        coh = umass_coherence(t, counts, top_n=8)
        return {"coherence_median": float(np.median(coh))}

    return lda.problem, init_theta(), datas, [lda.docs_per_silo] * J, eval_fn


def _privacy_from(args):
    from repro.federated import PrivacyPolicy

    if args.dp_noise > 0.0:
        return PrivacyPolicy(clip_norm=args.dp_clip,
                             noise_multiplier=args.dp_noise,
                             delta=args.dp_delta)
    return None


def _run_one(args, algorithm: str, built):
    import jax

    from repro.federated import (Int8Compressor, MeanAggregator, NoCompression,
                                 RoundScheduler, Server, TrimmedMeanAggregator)
    from repro.optim.adam import adam

    prob, theta0, datas, num_obs, eval_fn = built
    privacy = _privacy_from(args)
    srv = Server(
        prob, datas, theta0,
        prob.global_family.init(jax.random.PRNGKey(args.seed)),
        num_obs=num_obs,
        server_opt=adam(args.lr),
        local_opt=adam(args.lr) if prob.model.has_local else None,
        aggregator=(TrimmedMeanAggregator(args.trim_frac)
                    if args.aggregator == "trimmed" else MeanAggregator()),
        compressor=(Int8Compressor() if args.compress == "int8"
                    else NoCompression()),
        eta_mode=args.eta_mode,
        privacy=privacy,
        seed=args.seed,
    )
    sched = RoundScheduler(args.silos, participation=args.participation,
                           dropout=args.dropout, seed=args.seed)
    name = {"sfvi": "SFVI", "sfvi_avg": "SFVI-Avg"}[algorithm]
    print(f"\n== {name}: {args.model}, J={args.silos}, "
          f"{args.rounds} rounds x {args.local_steps} local steps"
          + (f", DP(z={args.dp_noise:g}, C={args.dp_clip:g})" if privacy else "")
          + " ==")
    t0 = time.time()

    def log(r, m):
        eps = f"  eps={m['epsilon']:7.3f}" if "epsilon" in m else ""
        print(f"  round {r:3d}  elbo={m['elbo']:14.2f}  "
              f"up={m['bytes_up']:>9d}B  down={m['bytes_down']:>9d}B  "
              f"active={m['n_active']}/{args.silos}{eps}")

    srv.run(args.rounds, algorithm=algorithm, local_steps=args.local_steps,
            scheduler=sched, callback=log)
    print(f"  total: {srv.comm.total:,} B in {srv.comm.rounds} rounds "
          f"({srv.comm.per_round:,.0f} B/round), {time.time()-t0:.1f}s")
    if srv.accountant is not None:
        eps, order = srv.accountant.epsilon(privacy.delta)
        print(f"  privacy: ({eps:.3f}, {privacy.delta:g})-DP after "
              f"{srv.accountant.steps} exchanges (RDP order {order})")
    if eval_fn is not None:
        for k, v in eval_fn(srv).items():
            print(f"  {k}: {v:.3f}")
    if args.hlo_bytes:
        coll = srv.compiled_collective_bytes(algorithm, args.local_steps)
        total = sum(coll.values())
        print(f"  compiled-HLO collective bytes/round: {total:,.0f} "
              f"({ {k: int(v) for k, v in coll.items() if v} })")
    return srv


def _run_sweep(args, built) -> int:
    """One invocation, the whole scenario grid (ELBO / ε / bytes table)."""
    import jax

    from repro.federated import Server, scenario_matrix
    from repro.optim.adam import adam

    def floats(s):
        return tuple(float(x) for x in s.split(","))

    grid = scenario_matrix(
        algorithms=(["sfvi", "sfvi_avg"] if args.algo == "both"
                    else [args.algo]),
        participation=floats(args.sweep_participation),
        dropout=floats(args.sweep_dropout),
        compression=tuple(args.sweep_compress.split(",")),
        dp_noise=floats(args.sweep_dp_noise),
        dp_clip=args.dp_clip,
        dp_delta=args.dp_delta,
    )
    prob, theta0, datas, num_obs, eval_fn = built
    print(f"\n== scenario sweep: {args.model}, J={args.silos}, "
          f"{len(grid)} scenarios x {args.rounds} rounds ==")
    rows = []
    for sc in grid:
        srv = Server(
            prob, datas, theta0,
            prob.global_family.init(jax.random.PRNGKey(args.seed)),
            num_obs=num_obs,
            server_opt=adam(args.lr),
            local_opt=adam(args.lr) if prob.model.has_local else None,
            aggregator=sc.make_aggregator(),
            compressor=sc.compressor(),
            privacy=sc.privacy(),
            seed=args.seed,
        )
        t0 = time.time()
        h = srv.run(args.rounds, algorithm=sc.algorithm,
                    local_steps=args.local_steps,
                    scheduler=sc.scheduler(args.silos, seed=args.seed))
        dt = time.time() - t0
        eps = h["epsilon"][-1] if "epsilon" in h else float("inf")
        rows.append((sc.name, h["elbo"][-1], eps,
                     srv.comm.per_round / 1024, dt / args.rounds))
    w = max(len(r[0]) for r in rows)
    print(f"  {'scenario':<{w}}  {'ELBO':>12}  {'eps':>8}  "
          f"{'KiB/round':>10}  {'s/round':>8}")
    for name, elbo, eps, kib, spr in rows:
        eps_s = f"{eps:8.3f}" if eps != float("inf") else "     inf"
        print(f"  {name:<{w}}  {elbo:12.2f}  {eps_s}  {kib:10.1f}  {spr:8.2f}")
    return 0


def main(argv=None) -> int:
    """Run the requested algorithm(s) and assert the §3.2 byte ordering."""
    args = build_parser().parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        )
    built = _build_problem(args)  # one dataset/problem, shared by all runs
    if args.sweep:
        return _run_sweep(args, built)
    algos = ["sfvi", "sfvi_avg"] if args.algo == "both" else [args.algo]
    servers = {a: _run_one(args, a, built) for a in algos}
    if len(servers) == 2:
        sfvi_pr = servers["sfvi"].comm.per_round
        avg_pr = servers["sfvi_avg"].comm.per_round
        print(f"\nbytes/round: SFVI={sfvi_pr:,.0f}  SFVI-Avg={avg_pr:,.0f}  "
              f"(x{sfvi_pr / max(avg_pr, 1):.1f} reduction — §3.2: one sync "
              f"per round instead of one per local step)")
        if args.local_steps > 1:
            assert avg_pr < sfvi_pr, \
                "SFVI-Avg must ship strictly fewer bytes/round"
        else:
            # K=1: both algorithms exchange once per round — equal cost.
            assert avg_pr <= sfvi_pr, \
                "SFVI-Avg must never ship more bytes/round than SFVI"
    return 0


if __name__ == "__main__":
    sys.exit(main())
