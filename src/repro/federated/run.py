"""Federated runtime CLI — drive a paper model through the compiled Server.

    PYTHONPATH=src python -m repro.federated.run --model hier_bnn \
        --silos 8 --rounds 5 --local-steps 4

Runs SFVI (sync every step) and SFVI-Avg (one sync per round) on the same
problem/seed and prints per-round ELBO plus bytes-on-wire; scenario knobs
cover partial participation, straggler dropout, robust aggregation and
int8 wire compression:

    ... --participation 0.5 --dropout 0.1 --aggregator trimmed --compress int8

``--devices N`` forces N XLA host devices (as ``launch/comm.py`` does) so
the ``silo`` mesh axis actually spans devices and
``Server.compiled_collective_bytes`` reports real collective traffic.

JAX is imported *after* argument parsing so --devices can set XLA_FLAGS.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def build_parser() -> argparse.ArgumentParser:
    """CLI schema (kept separate so docs/tests can introspect flags)."""
    ap = argparse.ArgumentParser(prog="repro.federated.run", description=__doc__)
    ap.add_argument("--model", default="hier_bnn",
                    choices=["toy", "hier_bnn", "fedpop_bnn", "prodlda"])
    ap.add_argument("--silos", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--algo", default="both", choices=["both", "sfvi", "sfvi_avg"])
    ap.add_argument("--lr", type=float, default=2e-2)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--dropout", type=float, default=0.0)
    ap.add_argument("--aggregator", default="mean", choices=["mean", "trimmed"])
    ap.add_argument("--trim-frac", type=float, default=0.1)
    ap.add_argument("--compress", default="none", choices=["none", "int8"])
    ap.add_argument("--eta-mode", default="barycenter",
                    choices=["barycenter", "param"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N XLA host devices (0 = real devices)")
    ap.add_argument("--hlo-bytes", action="store_true",
                    help="also report compiled-HLO collective bytes")
    return ap


def _build_problem(args):
    """Returns (problem, theta0, datas, num_obs, eval_fn|None)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    J = args.silos
    if args.model == "toy":
        from repro.core import (ConditionalGaussian, DiagGaussian, SFVIProblem,
                                StructuredModel)

        rng = np.random.default_rng(args.seed)
        true_b = rng.normal(2.0, 1.0, J)
        datas = [{"y": jnp.asarray(rng.normal(true_b[j], 0.5, 40))}
                 for j in range(J)]
        model = StructuredModel(
            global_dim=1, local_dim=1,
            log_prior_global=lambda th, zg: -0.5 * jnp.sum(zg**2) / 100.0,
            log_local=lambda th, zg, zl, d: (
                -0.5 * jnp.sum((zl - zg) ** 2)
                - 0.5 * jnp.sum((d["y"] - zl) ** 2) / 0.25
            ),
            name="toy_hier_gaussian",
        )
        prob = SFVIProblem(model, DiagGaussian(1),
                           ConditionalGaussian(1, 1, use_coupling=False))
        return prob, {}, datas, None, None

    if args.model in ("hier_bnn", "fedpop_bnn"):
        from repro.models.paper.fixtures import (bnn_posterior_accuracy,
                                                 hier_bnn_federation)

        bnn, datas, test = hier_bnn_federation(
            seed=args.seed, num_silos=J, fedpop=args.model == "fedpop_bnn")

        def eval_fn(srv):
            acc, _ = bnn_posterior_accuracy(bnn, srv.eta_G, srv.eta_L, test)
            return {"test_acc": acc}

        num_obs = [int(d["y"].shape[0]) for d in datas]
        return bnn.problem, {}, datas, num_obs, eval_fn

    # prodlda
    from repro.models.paper.fixtures import prodlda_federation
    from repro.models.paper.prodlda import init_theta, umass_coherence

    lda, datas, counts = prodlda_federation(seed=args.seed, num_silos=J)

    def eval_fn(srv):
        t = np.asarray(lda.topics(srv.eta_G["mu"]))
        coh = umass_coherence(t, counts, top_n=8)
        return {"coherence_median": float(np.median(coh))}

    return lda.problem, init_theta(), datas, [lda.docs_per_silo] * J, eval_fn


def _run_one(args, algorithm: str, built):
    import jax

    from repro.federated import (Int8Compressor, MeanAggregator, NoCompression,
                                 RoundScheduler, Server, TrimmedMeanAggregator)
    from repro.optim.adam import adam

    prob, theta0, datas, num_obs, eval_fn = built
    srv = Server(
        prob, datas, theta0,
        prob.global_family.init(jax.random.PRNGKey(args.seed)),
        num_obs=num_obs,
        server_opt=adam(args.lr),
        local_opt=adam(args.lr) if prob.model.has_local else None,
        aggregator=(TrimmedMeanAggregator(args.trim_frac)
                    if args.aggregator == "trimmed" else MeanAggregator()),
        compressor=(Int8Compressor() if args.compress == "int8"
                    else NoCompression()),
        eta_mode=args.eta_mode,
        seed=args.seed,
    )
    sched = RoundScheduler(args.silos, participation=args.participation,
                           dropout=args.dropout, seed=args.seed)
    name = {"sfvi": "SFVI", "sfvi_avg": "SFVI-Avg"}[algorithm]
    print(f"\n== {name}: {args.model}, J={args.silos}, "
          f"{args.rounds} rounds x {args.local_steps} local steps ==")
    t0 = time.time()

    def log(r, m):
        print(f"  round {r:3d}  elbo={m['elbo']:14.2f}  "
              f"up={m['bytes_up']:>9d}B  down={m['bytes_down']:>9d}B  "
              f"active={m['n_active']}/{args.silos}")

    srv.run(args.rounds, algorithm=algorithm, local_steps=args.local_steps,
            scheduler=sched, callback=log)
    print(f"  total: {srv.comm.total:,} B in {srv.comm.rounds} rounds "
          f"({srv.comm.per_round:,.0f} B/round), {time.time()-t0:.1f}s")
    if eval_fn is not None:
        for k, v in eval_fn(srv).items():
            print(f"  {k}: {v:.3f}")
    if args.hlo_bytes:
        coll = srv.compiled_collective_bytes(algorithm, args.local_steps)
        total = sum(coll.values())
        print(f"  compiled-HLO collective bytes/round: {total:,.0f} "
              f"({ {k: int(v) for k, v in coll.items() if v} })")
    return srv


def main(argv=None) -> int:
    """Run the requested algorithm(s) and assert the §3.2 byte ordering."""
    args = build_parser().parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        )
    algos = ["sfvi", "sfvi_avg"] if args.algo == "both" else [args.algo]
    built = _build_problem(args)  # one dataset/problem, shared by both runs
    servers = {a: _run_one(args, a, built) for a in algos}
    if len(servers) == 2:
        sfvi_pr = servers["sfvi"].comm.per_round
        avg_pr = servers["sfvi_avg"].comm.per_round
        print(f"\nbytes/round: SFVI={sfvi_pr:,.0f}  SFVI-Avg={avg_pr:,.0f}  "
              f"(x{sfvi_pr / max(avg_pr, 1):.1f} reduction — §3.2: one sync "
              f"per round instead of one per local step)")
        if args.local_steps > 1:
            assert avg_pr < sfvi_pr, \
                "SFVI-Avg must ship strictly fewer bytes/round"
        else:
            # K=1: both algorithms exchange once per round — equal cost.
            assert avg_pr <= sfvi_pr, \
                "SFVI-Avg must never ship more bytes/round than SFVI"
    return 0


if __name__ == "__main__":
    sys.exit(main())
