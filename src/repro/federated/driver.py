"""Generic metered round loop for step-function federations.

The SPMD LLM path (``launch/train.py``) virtualizes its server into a
GSPMD psum inside a jitted step function, so it cannot use
:class:`~repro.federated.runtime.Server` (which owns the round graph
itself) — but it still wants the same per-round communication accounting
and logging hooks. ``run_rounds`` is that loop: advance a step over a
batch stream, bill a fixed (up, down) cost per round into a
:class:`CommMeter`, compose DP exchanges into an
:class:`~repro.federated.privacy.RdpAccountant`, collect metrics.
``Server.run`` keeps its own loop because its billing depends on the
realized participation mask.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterable, Optional, Tuple

from repro.federated.metering import CommMeter
from repro.federated.privacy import PrivacyPolicy, RdpAccountant

PyTree = Any
StepFn = Callable[[PyTree, Any, int], Tuple[PyTree, Dict[str, Any]]]
MetricsHook = Callable[[int, Dict[str, Any], PyTree], None]


def run_rounds(
    step_fn: StepFn,
    state: PyTree,
    batches: Iterable[Any],
    *,
    meter: Optional[CommMeter] = None,
    bytes_per_round: Tuple[int, int] = (0, 0),
    privacy: Optional[PrivacyPolicy] = None,
    accountant: Optional[RdpAccountant] = None,
    sampling_rate: float = 1.0,
    exchanges_per_round=1,
    on_metrics: Optional[MetricsHook] = None,
) -> Tuple[PyTree, Dict[str, list]]:
    """Drive ``state`` through ``step_fn`` once per batch.

    Args:
      step_fn: ``(state, batch, round_idx) -> (state, metrics)``; metrics
        values must be scalar-convertible.
      state: initial pytree, threaded through every step.
      batches: one element per round (list, generator, ...).
      meter: optional :class:`CommMeter`; ``bytes_per_round`` is the
        (up, down) cost recorded per round.
      privacy: optional DP policy. The loop does NOT apply the mechanism
        (that belongs inside ``step_fn``'s compiled graph); it accounts
        it: each round composes ``exchanges_per_round`` sampled-Gaussian
        invocations at the policy's noise multiplier into ``accountant``
        (one is created if None) and appends the cumulative ε at the
        policy's δ to ``history["epsilon"]`` and the round's metrics.
      accountant: accountant to compose into (shared across phases);
        ignored when ``privacy`` is None.
      sampling_rate: per-round silo sampling rate q for the accountant.
      exchanges_per_round: mechanism invocations per round — an int, or
        a callable ``round_idx -> int`` for cadenced schedules (SFVI
        pays one per step; SFVI-Avg one every ``avg_every`` steps, zero
        on the steps in between).
      on_metrics: per-round hook ``(round_idx, metrics, state)`` for
        logging or checkpointing; ``state`` is the post-step state.
        Metrics arrive as the step's raw (possibly still-on-device)
        scalars so the hook decides when to block — formatting a value
        syncs it; ignoring it keeps dispatch async.

    Returns the final state and a dict of per-round metric lists
    (floats, materialized once after the loop so the loop itself never
    forces a host-device sync).
    """
    raw_history: list = []
    up1, down1 = bytes_per_round
    if privacy is not None and accountant is None:
        accountant = RdpAccountant()
    for i, batch in enumerate(batches):
        state, metrics = step_fn(state, batch, i)
        if meter is not None:
            meter.record(up1, down1)
        if privacy is not None:
            n_ex = (exchanges_per_round(i) if callable(exchanges_per_round)
                    else exchanges_per_round)
            accountant.step(
                noise_multiplier=privacy.noise_multiplier,
                sampling_rate=sampling_rate,
                steps=n_ex,
            )
            metrics = dict(
                metrics, epsilon=accountant.epsilon(privacy.delta)[0]
            )
        raw_history.append(metrics)
        if on_metrics:
            on_metrics(i, metrics, state)
    history: Dict[str, list] = {}
    for metrics in raw_history:
        for k, v in metrics.items():
            history.setdefault(k, []).append(float(v))
    return state, history
