"""One declarative experiment API: spec → build → run → resume.

The paper's experiments are a grid of (model × algorithm × participation
× compression × DP) runs. Instead of wiring that grid by hand at every
call site, this module gives the repo ONE serializable experiment
object:

  * :class:`ExperimentSpec` — a frozen dataclass tree (model reference +
    kwargs, silos, rounds × local steps, optimizers, a
    :class:`~repro.federated.scheduler.Scenario` carrying participation /
    stragglers / compression / aggregation / differential privacy, eval
    cadence, seed) with a lossless ``to_dict()`` / ``from_dict()`` JSON
    round trip;
  * :func:`build` — resolves the model through the registry
    (:mod:`repro.models.paper.registry`) and assembles the compiled
    :class:`~repro.federated.runtime.Server`, scheduler, privacy policy
    and accountant into an :class:`Experiment`;
  * :class:`Experiment` — owns the run loop (`run`), evaluation cadence,
    and checkpointing: ``save(dir)`` persists the FULL round state
    (θ, η_G, stacked η_{L_j}, both optimizer states, the RDP ledger, the
    communication meter, and the absolute round index) through
    :class:`~repro.checkpoint.CheckpointManager`; ``Experiment.resume(dir)``
    rebuilds from ``spec.json`` and restores that state. Because every
    random stream in the runtime (round keys, participation masks, DP
    noise) is a function of (seed, absolute round index), a resumed run
    replays the uninterrupted run's remaining rounds **bit-exactly** —
    asserted in ``tests/test_api.py``.

This is the single construction path the CLI
(``python -m repro.federated.run``), the examples, and the benchmark
suite all build on; the legacy eager ``SFVIServer``/``SFVIAvgServer``
are deprecated adapters over the same compiled runtime. See
``docs/api.md``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import warnings
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.family import FamilySpec
from repro.federated.population import PopulationEngine, PopulationSpec, PopulationState
from repro.federated.scheduler import RoundScheduler, Scenario
from repro.federated.strategy import StrategySpec
from repro.launch.mesh import MeshSpec, build_mesh

PyTree = Any

_SPEC_FILE = "spec.json"
_SERVER_KEYS = ("theta", "eta_G", "opt_server")

# The deprecated out-of-band wire kwarg warns ONCE per process — sweeps
# over many specs shouldn't drown their output in repeats.
_WIRE_KWARG_WARNED = False


def _warn_wire_kwarg(where: str) -> None:
    global _WIRE_KWARG_WARNED
    if not _WIRE_KWARG_WARNED:
        warnings.warn(
            f"the wire= kwarg on {where} is deprecated; set it on the spec "
            "instead: ExperimentSpec(runtime=RuntimeSpec(wire=...)). The "
            "kwarg still overrides the spec for now.",
            DeprecationWarning, stacklevel=3)
        _WIRE_KWARG_WARNED = True


# ---------------------------------------------------------------------------
# Spec tree
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OptimizerSpec:
    """Declarative optimizer: resolved by name at build time.

    Attributes:
      name: ``"adam"``, ``"adamw"`` or ``"sgd"``.
      learning_rate: step size.
      kwargs: extra keyword arguments for the optimizer factory
        (JSON-native values only: betas, momentum, weight decay, ...).
    """

    name: str = "adam"
    learning_rate: float = 1e-2
    kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def build(self):
        """Instantiate the :class:`~repro.optim.base.GradientTransformation`."""
        if self.name == "adam":
            from repro.optim.adam import adam
            return adam(self.learning_rate, **self.kwargs)
        if self.name == "adamw":
            from repro.optim.adam import adamw
            return adamw(self.learning_rate, **self.kwargs)
        if self.name == "sgd":
            from repro.optim.sgd import sgd
            return sgd(self.learning_rate, **self.kwargs)
        raise ValueError(f"unknown optimizer {self.name!r} (adam/adamw/sgd)")

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> OptimizerSpec:
        return cls(name=d.get("name", "adam"),
                   learning_rate=d.get("learning_rate", 1e-2),
                   kwargs=dict(d.get("kwargs", {})))


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Reference to a registered model plus its build kwargs.

    ``name`` resolves through :mod:`repro.models.paper.registry`;
    ``kwargs`` are forwarded to the registered builder and must be
    JSON-native (the spec round-trips through ``json.dumps``).

    ``global_family`` / ``local_family`` optionally override the staged
    problem's variational families with a
    :class:`~repro.core.family.FamilySpec` — ``null`` keeps the model's
    default (the paper's choice). Structural dimensions are filled from
    the model at build time, so ``FamilySpec("cholesky")`` upgrades any
    model's η_G to a full unitriangular factor and
    ``FamilySpec("lowrank", {"rank": 2})`` to diag + rank-2.
    """

    name: str
    kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    global_family: Optional[FamilySpec] = None
    local_family: Optional[FamilySpec] = None

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> ModelSpec:
        return cls(
            name=d["name"],
            kwargs=dict(d.get("kwargs", {})),
            global_family=(FamilySpec.from_dict(d["global_family"])
                           if d.get("global_family") is not None else None),
            local_family=(FamilySpec.from_dict(d["local_family"])
                          if d.get("local_family") is not None else None),
        )


@dataclasses.dataclass(frozen=True)
class RuntimeSpec:
    """Execution topology and wire layout — spec-carried, JSON-native.

    Historically the wire layout rode an out-of-band ``wire=`` kwarg on
    :func:`build` and the mesh was whatever ``make_silo_mesh`` decided;
    both now live on the spec so a run's topology serializes, resumes
    and sweeps like every other knob.

    Attributes:
      wire: silo→server wire layout — ``"flat"`` (packed (J, P)
        matrix, the default), ``"fused"`` (same layout, Pallas-kernel
        pipeline) or ``"legacy"`` (per-leaf reference).
      mesh: the federated mesh topology
        (:class:`~repro.launch.mesh.MeshSpec`): ``silo`` devices × a
        ``model`` axis sharding each row's P wire parameters, plus the
        ``multiprocess`` flag for ``jax.distributed`` runs.
      sanitize: default for :meth:`Experiment.run`'s runtime sanitizer
        (transfer guard + NaN checks + recompile watchdog); an explicit
        ``run(sanitize=...)`` still overrides.
    """

    wire: str = "flat"
    mesh: MeshSpec = MeshSpec()
    sanitize: bool = False

    def __post_init__(self):
        if self.wire not in ("flat", "fused", "legacy"):
            raise ValueError(
                f"unknown wire layout {self.wire!r} (flat/fused/legacy)")

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> RuntimeSpec:
        return cls(wire=d.get("wire", "flat"),
                   mesh=MeshSpec.from_dict(d.get("mesh") or {}),
                   sanitize=d.get("sanitize", False))


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """The complete, serializable description of one federated run.

    Attributes:
      model: registry reference + kwargs (:class:`ModelSpec`).
      scenario: the runtime scenario — algorithm (any registered
        :class:`~repro.federated.strategy.ServerStrategy` name:
        ``sfvi``/``sfvi_avg``/``pvi``/``fed_ep``), participation,
        stragglers, wire compression, aggregation rule and the
        differential-privacy policy (dp_noise/dp_clip/dp_delta) — as
        one :class:`~repro.federated.scheduler.Scenario`.
      strategy: optional
        :class:`~repro.federated.strategy.StrategySpec` carrying the
        strategy's hyperparameters (e.g. PVI's ``damping``). ``None``
        builds the scenario's algorithm with registry defaults; when
        set, its name must match ``scenario.algorithm``.
      num_silos: J, the federation width.
      rounds: total rounds the experiment runs (``Experiment.run()`` with
        no argument runs whatever remains of this budget).
      local_steps: K optimizer steps per round (step-cadence strategies
        sync after each, round-cadence ones once per round).
      server_opt: optimizer for (θ, η_G).
      local_opt: optimizer for each η_{L_j}; None mirrors ``server_opt``
        when the model has local latents.
      eta_mode: SFVI-Avg's η_G merge — ``"barycenter"`` (paper §3.2,
        DiagGaussian) or ``"param"`` (parameter-space FedAvg).
      eval_every: evaluate the registry's eval_fn every this many rounds
        (0 disables the cadence; ``Experiment.evaluate()`` is always
        available on demand).
      seed: base seed for initialization, round keys and the
        participation schedule (and data staging, unless ``data_seed``
        overrides it).
      data_seed: seed the registry stages data with; None mirrors
        ``seed``. Separate so one dataset can be crossed with many run
        seeds while the spec still rebuilds the exact data on resume.
      runtime: execution topology — wire layout, federated mesh
        (:class:`~repro.launch.mesh.MeshSpec`) and the sanitizer
        default, as one :class:`RuntimeSpec`. A resume may change the
        topology (device or process count): silo re-padding and
        resharding keep the REAL silos' trajectory bit-exact.
      population: optional dynamic-population churn
        (:class:`~repro.federated.population.PopulationSpec`). When
        set, ``num_silos`` is the ROSTER maximum (the registry stages
        every shard up front); only ``population.initial`` silos are
        live at round 0 and the rest join, depart and return through
        the deterministic event process of
        :mod:`repro.federated.population`. ``None`` (the default) is
        the paper's fixed-J federation, byte-for-byte unchanged.
    """

    model: ModelSpec
    scenario: Scenario = Scenario()
    strategy: Optional[StrategySpec] = None
    num_silos: int = 4
    rounds: int = 10
    local_steps: int = 1
    server_opt: OptimizerSpec = OptimizerSpec()
    local_opt: Optional[OptimizerSpec] = None
    eta_mode: str = "barycenter"
    eval_every: int = 0
    seed: int = 0
    data_seed: Optional[int] = None
    runtime: RuntimeSpec = RuntimeSpec()
    population: Optional[PopulationSpec] = None

    @property
    def algorithm(self) -> str:
        """The sync cadence, carried by the scenario."""
        return self.scenario.algorithm

    @property
    def name(self) -> str:
        """Human-readable label: model + the scenario's knob summary."""
        return f"{self.model.name} {self.scenario.name}"

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        """Plain-dict form, JSON-ready (nested dataclasses flattened)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> ExperimentSpec:
        """Inverse of :meth:`to_dict`: ``from_dict(to_dict(s)) == s``."""
        return cls(
            model=ModelSpec.from_dict(d["model"]),
            scenario=Scenario.from_dict(d.get("scenario", {})),
            strategy=(StrategySpec.from_dict(d["strategy"])
                      if d.get("strategy") is not None else None),
            num_silos=d.get("num_silos", 4),
            rounds=d.get("rounds", 10),
            local_steps=d.get("local_steps", 1),
            server_opt=OptimizerSpec.from_dict(d.get("server_opt", {})),
            local_opt=(OptimizerSpec.from_dict(d["local_opt"])
                       if d.get("local_opt") is not None else None),
            eta_mode=d.get("eta_mode", "barycenter"),
            eval_every=d.get("eval_every", 0),
            seed=d.get("seed", 0),
            data_seed=d.get("data_seed"),
            runtime=RuntimeSpec.from_dict(d.get("runtime") or {}),
            population=(PopulationSpec.from_dict(d["population"])
                        if d.get("population") is not None else None),
        )

    def to_json(self, indent: int = 2) -> str:
        """JSON text of :meth:`to_dict` (what ``--dump-spec`` prints)."""
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> ExperimentSpec:
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> None:
        """Write the spec as JSON (atomically) to ``path``."""
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(self.to_json() + "\n")
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> ExperimentSpec:
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# build: spec -> Experiment
# ---------------------------------------------------------------------------


def build(spec: ExperimentSpec, bundle=None, *,
          wire: Optional[str] = None) -> Experiment:
    """Assemble the compiled runtime for ``spec``.

    Resolves the model through the registry (unless a pre-staged
    ``bundle`` is supplied — benchmarks reuse one dataset across many
    scenario specs that way), applies the spec's family overrides
    (``ModelSpec.global_family`` / ``local_family``), instantiates
    optimizers, aggregation, compression, the privacy policy AND the
    execution topology — wire layout and federated mesh, from
    ``spec.runtime`` — and returns a ready-to-run :class:`Experiment`.

    ``wire`` is a DEPRECATED override of ``spec.runtime.wire`` (warns
    once); topology belongs on the spec so it serializes and resumes
    with everything else.
    """
    if wire is not None:
        _warn_wire_kwarg("build()")
    return _build(spec, bundle, wire)


def _bundle_num_obs(bundle) -> List[int]:
    """Per-silo N_j for the FULL staged roster (inferring when absent)."""
    if bundle.num_obs is not None:
        return [int(n) for n in bundle.num_obs]
    return [int(jax.tree_util.tree_leaves(d)[0].shape[0])
            for d in bundle.datas]


def _build(spec: ExperimentSpec, bundle=None,
           wire: Optional[str] = None,
           joined: Optional[int] = None) -> Experiment:
    """The warning-free core of :func:`build` (resume calls this).

    ``joined`` is the resume path's population head-count: a churn
    run's Server is built with exactly the silos that had joined at
    the checkpoint (their shards restore on top), and the engine's
    state is restored right after. A fresh population build starts at
    ``spec.population.initial``.
    """
    from repro.federated import graph_cache
    from repro.federated.runtime import Server
    from repro.models.paper.registry import apply_family_spec, get_model

    wire = wire if wire is not None else spec.runtime.wire
    spec.scenario.validate(spec.num_silos)
    strat_spec = (spec.strategy if spec.strategy is not None
                  else StrategySpec(spec.scenario.algorithm))
    if strat_spec.name != spec.scenario.algorithm:
        raise ValueError(
            f"spec.strategy names {strat_spec.name!r} but "
            f"scenario.algorithm is {spec.scenario.algorithm!r}; they must "
            f"agree (the scenario label drives scheduling/validation, the "
            f"StrategySpec only adds hyperparameters)")
    strategy = strat_spec.build()
    mesh = build_mesh(spec.runtime.mesh, num_silos=spec.num_silos)
    pop = spec.population
    # The live federation at build time: the full roster, or — under a
    # population — the silos joined so far (churn grows the rest).
    if pop is None:
        j_live = spec.num_silos
    else:
        j_live = int(joined) if joined is not None else min(
            pop.initial, spec.num_silos)
    n_dev = int(mesh.shape["silo"])
    j_pad = ((j_live + n_dev - 1) // n_dev) * n_dev
    token = None
    if bundle is None:
        entry = get_model(spec.model.name)
        data_seed = spec.data_seed if spec.data_seed is not None else spec.seed
        bundle = entry.build(data_seed, spec.num_silos, **spec.model.kwargs)
        # Registry-staged builds are pure functions of the spec, so
        # structurally-equal Servers may share compiled round graphs —
        # resume then re-traces nothing. A caller-supplied bundle is
        # opaque to the token and opts out. J_pad rides the token: the
        # compiled shapes are functions of the PADDED silo axis, which
        # a population grows in mesh-sized chunks.
        token = graph_cache.build_token(
            spec.to_json(indent=0), wire, spec.num_silos,
            mesh_shape=tuple(sorted(mesh.shape.items())), j_pad=j_pad)
    if len(bundle.datas) != spec.num_silos:
        raise ValueError(
            f"bundle stages {len(bundle.datas)} silos, spec.num_silos is "
            f"{spec.num_silos}")
    bundle = apply_family_spec(
        bundle, spec.model.global_family, spec.model.local_family)

    problem = bundle.problem
    has_local = problem.model.has_local
    local_spec = spec.local_opt if spec.local_opt is not None else spec.server_opt
    num_obs_full = _bundle_num_obs(bundle)
    server = Server(
        problem,
        bundle.datas[:j_live],
        bundle.theta0,
        # repro-lint: allow[R1] — η_G init root: a pure function of spec.seed, re-derived bit-exactly by resume
        problem.global_family.init(jax.random.PRNGKey(spec.seed)),
        num_obs=num_obs_full[:j_live],
        server_opt=spec.server_opt.build(),
        local_opt=local_spec.build() if has_local else None,
        aggregator=spec.scenario.make_aggregator(),
        compressor=spec.scenario.compressor(),
        eta_mode=spec.eta_mode,
        wire=wire,
        mesh=mesh,
        privacy=spec.scenario.privacy(),
        seed=spec.seed,
        strategy=strategy,
        graph_cache_token=token,
        # The estimators scale by the ROSTER width and total N: absent
        # silos are non-participants of the full federation, so the
        # optimization target is fixed while the population churns.
        federation_size=spec.num_silos,
        federation_obs=float(sum(num_obs_full)),
    )
    population = None
    if pop is not None:
        if server.n_processes > 1:
            raise ValueError(
                "population churn is single-process for now (dynamic "
                "growth re-shards silo rows, which multi-process "
                "federations pin to their owning host)")
        population = PopulationEngine(pop, bundle, spec.num_silos)
    scheduler = spec.scenario.scheduler(spec.num_silos, seed=spec.seed)
    return Experiment(spec, bundle, server, scheduler,
                      population=population)


# ---------------------------------------------------------------------------
# Experiment: run / evaluate / save / resume
# ---------------------------------------------------------------------------


class Experiment:
    """A built federated run: owns the Server, scheduler and round index.

    Construct through :func:`build` (or :meth:`resume`); drive with
    :meth:`run`. ``history`` accumulates across calls, ``round`` is the
    absolute number of rounds completed so far.
    """

    def __init__(self, spec: ExperimentSpec, bundle, server, scheduler: RoundScheduler,
                 population: Optional[PopulationEngine] = None):
        self.spec = spec
        self.bundle = bundle
        self.server = server
        self.scheduler = scheduler
        # Churn driver (spec.population): joins/departures/returns fire
        # between rounds; None for a fixed federation.
        self.population = population
        self.round = 0
        self.history: Dict[str, list] = {}
        # Buffered-async event-loop state (None until the first async
        # flush, or restored by resume); rounds count flushes in async
        # mode, so `self.round` needs no second counter.
        self.async_state = None

    # -- delegation conveniences -------------------------------------------

    @property
    def theta(self) -> PyTree:
        return self.server.theta

    @property
    def eta_G(self) -> PyTree:
        return self.server.eta_G

    @property
    def eta_L(self) -> PyTree:
        return self.server.eta_L

    @property
    def comm(self):
        return self.server.comm

    @property
    def accountant(self):
        return self.server.accountant

    @property
    def remaining_rounds(self) -> int:
        return max(self.spec.rounds - self.round, 0)

    def warm_start(self, theta: Optional[PyTree] = None,
                   eta_G: Optional[PyTree] = None) -> Experiment:
        """Override the initial (θ, η_G) — e.g. from a previous fit
        (the paper's Figure S2 warm-starting protocol). Optimizer
        moments are left at their fresh init."""
        if theta is not None:
            self.server.state["theta"] = theta
        if eta_G is not None:
            self.server.state["eta_G"] = eta_G
        return self

    # -- running ------------------------------------------------------------

    def run(self, rounds: Optional[int] = None,
            callback: Optional[Callable[[int, dict], None]] = None,
            sanitize: Union[None, bool, Dict[str, Any]] = None
            ) -> Dict[str, list]:
        """Advance ``rounds`` rounds (default: the spec's remaining budget).

        Returns the accumulated history. ``callback(r, metrics)`` fires
        per round with the ABSOLUTE round index; when the spec sets
        ``eval_every``, the registry's eval metrics are merged into the
        round's metrics (and recorded under ``history["eval"]``) at that
        cadence.

        ``sanitize=True`` wraps the loop in :func:`repro.debug.sanitize`
        — transfer guard, NaN debugging and the recompile watchdog (a
        dict passes keyword options through, e.g.
        ``sanitize={"debug_nans": False}``). The default (``None``)
        defers to ``spec.runtime.sanitize``. See docs/dev.md.

        When the scenario carries an async block, "rounds" are buffered
        flushes driven by :func:`repro.federated.async_engine.run_buffered`
        over the same compiled graph; the engine's
        :class:`~repro.federated.async_engine.BufferState` lives on
        ``self.async_state`` and is checkpointed with everything else.
        """
        n = self.remaining_rounds if rounds is None else rounds
        if n <= 0:
            return self.history
        spec = self.spec
        if sanitize is None:
            sanitize = spec.runtime.sanitize
        start = self.round

        def cb(r: int, metrics: dict) -> None:
            # Keep the absolute round index current DURING the run, so a
            # callback may checkpoint mid-run (``save`` stamps the state
            # with ``self.round``) and the resume replays from the right
            # absolute round.
            self.round = r + 1
            if (spec.eval_every and self.bundle.eval_fn is not None
                    and (r + 1) % spec.eval_every == 0):
                scores = self.bundle.eval_fn(self.server)
                metrics = dict(metrics, **scores)
                self.history.setdefault("eval", []).append(
                    {"round": r + 1, **scores})
            if callback is not None:
                callback(r, metrics)

        if sanitize:
            from repro import debug as _debug

            guard = _debug.sanitize(
                **(sanitize if isinstance(sanitize, dict) else {}))
        else:
            guard = contextlib.nullcontext()
        with guard:
            if spec.scenario.async_cfg is not None:
                from repro.federated.async_engine import (BufferState,
                                                          run_buffered)

                # Materialize the event-loop state BEFORE the loop: the
                # engine mutates it in place, so a callback that saves
                # mid-run checkpoints the live clock/tasks/buffer (and a
                # resume replays the remaining flushes bit-exactly).
                if self.async_state is None:
                    self.async_state = BufferState.init(
                        self.server.J, spec.scenario.async_cfg,
                        self.server.seed)
                chunk, self.async_state = run_buffered(
                    self.server, n, spec.scenario.async_cfg,
                    local_steps=spec.local_steps,
                    start_flush=start,
                    state=self.async_state,
                    callback=cb,
                    population=self.population,
                )
            else:
                # algorithm=None: the Server already carries the built
                # strategy INSTANCE (spec.strategy hyperparameters
                # included); passing spec.algorithm's NAME would rebuild
                # it with registry defaults.
                chunk = self.server.run(
                    n,
                    local_steps=spec.local_steps,
                    scheduler=self.scheduler,
                    callback=cb,
                    start_round=start,
                    population=self.population,
                )
        for k, v in chunk.items():
            self.history.setdefault(k, []).extend(v)
        self.round = start + n
        return self.history

    def evaluate(self) -> Dict[str, float]:
        """Run the registry's eval hook on the current state ({} if none)."""
        if self.bundle.eval_fn is None:
            return {}
        return dict(self.bundle.eval_fn(self.server))

    # -- checkpointing -------------------------------------------------------

    def _meta_dict(self) -> Dict[str, Any]:
        meta: Dict[str, Any] = {
            "round": self.round,
            "comm": self.comm.state_dict(),
            # The wire layout is an execution knob, not spec state — but
            # DP noise keys and int8 scales depend on it, so a resume
            # must rebuild with the SAME layout to stay bit-exact.
            "wire": self.server.wire,
        }
        if self.accountant is not None:
            acct = self.accountant.state_dict()
            # JSON, not the msgpack/jnp path: the RDP ledger is float64
            # and jnp.asarray would silently downcast it to float32
            # (x64 disabled), breaking the bit-exact epsilon trace.
            # Python's repr-based JSON floats round-trip doubles exactly.
            meta["acct"] = {"rdp": [float(x) for x in np.asarray(acct["rdp"])],
                            "steps": int(acct["steps"])}
        if self.async_state is not None:
            # Buffered-async event loop: simulated clock, in-flight tasks
            # and the partially-filled buffer (JSON doubles are exact, so
            # the arrival schedule resumes bit-exactly).
            meta["async_state"] = self.async_state.state_dict()
        if self.population is not None:
            # Roster head-count + per-silo status/last-present: resume
            # rebuilds the Server at the saved width and replays the
            # event stream from the saved index, mid-event included.
            meta["population"] = self.population.state.state_dict()
        return meta

    @staticmethod
    def _meta_path(directory: str, step: int) -> str:
        return os.path.join(directory, f"step_{step:08d}.meta.json")

    @staticmethod
    def _silo_state_tree(state: Dict[str, Any]) -> Dict[str, Any]:
        """The per-silo shard contents: every stacked-(J, ...) state group
        with any leaves. η_{L_j}/opt_local exist when the model has local
        latents; ``strategy`` when the strategy keeps per-silo state
        (e.g. PVI/FedEP site parameters λ_j) — a stateful strategy on a
        global-only model still gets its shards."""
        silo_state: Dict[str, Any] = {}
        if jax.tree_util.tree_leaves(state["eta_L"]):
            silo_state["eta_L"] = state["eta_L"]
            silo_state["opt_local"] = state["opt_local"]
        if jax.tree_util.tree_leaves(state.get("strategy", {})):
            silo_state["strategy"] = state["strategy"]
        return silo_state

    def save(self, directory: str, keep: int = 3) -> str:
        """Persist the full round state under ``directory``.

        Layout (all through :class:`~repro.checkpoint.CheckpointManager`,
        ``keep`` most recent steps retained):

          * ``spec.json`` — the experiment spec (written once);
          * ``step_NNNNNNNN.msgpack`` — server state (θ, η_G, server
            optimizer);
          * ``step_NNNNNNNN.silo_JJJJ.msgpack`` — silo J's private state
            (η_{L_J} + its optimizer moments, plus per-silo strategy
            state such as PVI/FedEP site parameters λ_J), one file per
            silo so the server checkpoint never contains local
            variational parameters (the paper's privacy boundary, see
            ``repro.checkpoint.io``);
          * ``step_NNNNNNNN.meta.json`` — round index, communication
            counters, RDP ledger (JSON so the float64 ledger round-trips
            exactly).

        On a multi-process run, host I/O is routed through silo
        ownership: process 0 writes the spec, the replicated server
        state and the meta sidecar, and each process writes ONLY the
        silo shards it owns (its addressable rows of the stacked silo
        axis — reading another host's rows would dispatch a cross-host
        collective). Every process must call ``save``; the shared
        ``directory`` must be visible to all of them.

        Returns the directory.
        """
        from repro.federated import distributed

        multi = self.server.n_processes > 1
        lead = (not multi) or jax.process_index() == 0
        os.makedirs(directory, exist_ok=True)
        mgr = CheckpointManager(directory, keep=keep)
        state = self.server.state
        if lead:
            self.spec.save(os.path.join(directory, _SPEC_FILE))
            mgr.save(self.round, {k: state[k] for k in _SERVER_KEYS})
        silo_state = self._silo_state_tree(state)
        if silo_state:
            if multi:
                rows = [r for r in distributed.owned_rows(
                    self.server.mesh, self.server.J_pad)
                    if r < self.server.J]
                for j in rows:
                    mgr.save(
                        self.round,
                        jax.tree_util.tree_map(
                            lambda x, jj=j: distributed.host_rows(
                                x, [jj])[jj],
                            silo_state),
                        shard=f"silo_{j:04d}",
                    )
            else:
                for j in range(self.server.J):
                    mgr.save(
                        self.round,
                        jax.tree_util.tree_map(lambda x: x[j], silo_state),
                        shard=f"silo_{j:04d}",
                    )
        if lead:
            tmp = self._meta_path(directory, self.round) + ".tmp"
            with open(tmp, "w") as f:
                json.dump(self._meta_dict(), f)
            os.replace(tmp, self._meta_path(directory, self.round))
            # Retention for the JSON sidecars mirrors the msgpack GC.
            live = set(mgr.steps())
            for fn in os.listdir(directory):
                if fn.startswith("step_") and fn.endswith(".meta.json"):
                    s = fn[len("step_"):-len(".meta.json")]
                    if s.isdigit() and int(s) not in live:
                        os.remove(os.path.join(directory, fn))
        if multi:
            # All shards on disk before ANY process proceeds — a resume
            # right after save must never read a half-written step.
            from jax.experimental import multihost_utils

            multihost_utils.sync_global_devices(f"repro_save_{self.round}")
        return directory

    @classmethod
    def resume(cls, directory: str, spec: Optional[ExperimentSpec] = None,
               step: Optional[int] = None, bundle=None,
               wire: Optional[str] = None) -> Experiment:
        """Rebuild from ``directory`` and restore the saved round state.

        Reads ``spec.json`` (unless ``spec`` overrides it), rebuilds the
        experiment with :func:`build` — the registry re-stages the data
        deterministically from the spec's seed — then restores θ, η_G,
        stacked η_{L_j}, both optimizer states, the communication meter,
        the RDP ledger and the round index from the latest (or ``step``)
        checkpoint. Continuing with :meth:`run` reproduces the
        uninterrupted run bit-exactly.

        ``wire`` is a DEPRECATED override (warns once; prefer
        ``spec.runtime.wire``) of the checkpoint's recorded layout —
        switching between ``"flat"`` and ``"fused"`` mid-run is safe
        (the fused kernels replay the identical op sequence and DP
        noise stream, so the continued trajectory is unchanged);
        switching to/from ``"legacy"`` changes per-leaf DP fold-ins and
        int8 scale granularity and will diverge under DP/compression.

        A resume may land on a DIFFERENT topology than the run that
        saved (device count, ``MeshSpec`` shape, process count):
        checkpoints hold the J real silos one file each, so the stacked
        axis is re-padded and resharded for the new mesh and the real
        silos' trajectory stays bit-exact. On a multi-process resume
        every process calls this; each reads only the silo shards it
        owns on the new mesh.
        """
        if wire is not None:
            _warn_wire_kwarg("Experiment.resume()")
        if spec is None:
            spec = ExperimentSpec.load(os.path.join(directory, _SPEC_FILE))
        mgr = CheckpointManager(directory)
        if step is None:
            step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory!r}")
        # Meta first: the run's wire layout must be restored before the
        # Server is built (DP keys / int8 scales are layout-dependent,
        # so resuming a wire='legacy' run as 'flat' would diverge).
        with open(cls._meta_path(directory, step)) as f:
            meta = json.load(f)
        pop_meta = meta.get("population")
        exp = _build(spec, bundle,
                     wire if wire is not None
                     else meta.get("wire", spec.runtime.wire),
                     joined=(int(pop_meta["joined"])
                             if pop_meta is not None else None))
        if exp.population is not None and pop_meta is not None:
            exp.population.state = PopulationState.from_state(pop_meta)

        from repro.federated import distributed

        multi = exp.server.n_processes > 1
        state = exp.server.state
        like = {k: state[k] for k in _SERVER_KEYS}
        restored = mgr.restore(step, like)
        if multi:
            # Host trees -> global arrays replicated over the new mesh
            # (every process read the identical file).
            restored = distributed.globalize(
                restored, exp.server.mesh,
                jax.sharding.PartitionSpec())
        for k in _SERVER_KEYS:
            state[k] = restored[k]
        silo_like = cls._silo_state_tree(state)
        if silo_like and multi:
            J, J_pad = exp.server.J, exp.server.J_pad
            row_like = jax.tree_util.tree_map(
                lambda x: np.zeros(x.shape[1:], x.dtype), silo_like)
            loaded = {
                j: mgr.restore(step, row_like, shard=f"silo_{j:04d}")
                for j in distributed.owned_rows(exp.server.mesh, J_pad)
                if j < J
            }
            for k in silo_like:
                state[k] = distributed.silo_sharded_from_rows(
                    silo_like[k], exp.server.mesh,
                    {j: t[k] for j, t in loaded.items()})
        elif silo_like:
            # Shard-tolerant: a resume may rebuild with MORE silos than
            # the run that saved (e.g. a fixed-J spec override growing
            # the roster) — silos with no shard on disk keep their fresh
            # init row; every saved silo restores bit-exactly.
            slices = []
            for j in range(exp.server.J):
                row = jax.tree_util.tree_map(
                    lambda x, jj=j: x[jj], silo_like)
                if mgr.has(step, shard=f"silo_{j:04d}"):
                    row = mgr.restore(step, row, shard=f"silo_{j:04d}")
                slices.append(row)
            stacked = jax.tree_util.tree_map(
                lambda *xs: jax.numpy.stack(xs), *slices)
            # Checkpoints hold the J REAL silos; re-pad the stacked axis
            # to this mesh's J_pad (a resume may land on a different
            # device count — padded rows are masked and never read).
            for k in silo_like:
                state[k] = exp.server.pad_silo_axis(stacked[k])

        exp.round = int(meta["round"])
        exp.comm.load_state(meta["comm"])
        if exp.accountant is not None and "acct" in meta:
            exp.accountant.load_state({
                "rdp": np.asarray(meta["acct"]["rdp"], np.float64),
                "steps": int(meta["acct"]["steps"]),
            })
        if "async_state" in meta:
            from repro.federated.async_engine import BufferState

            exp.async_state = BufferState.from_state(meta["async_state"])
        return exp


def run_spec(spec: ExperimentSpec,
             callback: Optional[Callable[[int, dict], None]] = None) -> Experiment:
    """One-shot convenience: ``build(spec)`` then run the full budget."""
    exp = build(spec)
    exp.run(callback=callback)
    return exp


def scenario_specs(base: ExperimentSpec, scenarios: List[Scenario]) -> List[ExperimentSpec]:
    """Cross one base spec with a scenario list (the --sweep expansion)."""
    return [dataclasses.replace(base, scenario=sc) for sc in scenarios]
