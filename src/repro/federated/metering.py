"""Byte accounting for federated exchanges — the single metering path.

Leaf module (imports only jax/numpy) so both runtimes can share it: the
compiled :class:`~repro.federated.runtime.Server` bills its rounds into a
:class:`CommMeter`, and the deprecated eager adapters in
``repro.core.runtime`` alias it as ``CommLog``. :func:`tree_bytes` is the
one primitive every byte figure in the repo is computed with.

The meter is topology-independent: it bills ALGORITHM-level bytes
(what each silo ships), so its figures are identical on a 1-device
mesh, a 2-D (silo x model) mesh, or a multi-process world — and every
process of a ``jax.distributed`` run meters the same totals, since the
control plane is replicated. The compiled-HLO cross-check
(``Server.compiled_collective_bytes``) is the per-topology view: on a
2-D mesh it additionally counts the model-axis rejoin gather
(``docs/federated.md`` §Sharding layout), while the silo gather's
result bytes still equal J x the per-silo upload metered here
(asserted end to end in ``tests/test_multiprocess.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def is_array(x: Any) -> bool:
    """True for the leaves that occupy wire bytes (device or host arrays).

    Scalars and static leaves (treedefs, python numbers) ride along in
    message pytrees but never cross the wire as payload.
    """
    return isinstance(x, (jax.Array, np.ndarray))


def tree_bytes(tree: PyTree) -> int:
    """Metered size of a message pytree in bytes (Σ elements × itemsize)."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
        if is_array(x)
    )


@dataclasses.dataclass
class CommMeter:
    """Algorithm-level bytes-on-wire accounting (host side, per round).

    ``sim_seconds`` accumulates the *simulated* wall-clock of the async
    engine's deterministic latency model (zero on synchronous runs) —
    the quantity buffered-asynchronous execution trades bytes against.
    """

    rounds: int = 0
    bytes_up: int = 0  # silo -> server (post-compression)
    bytes_down: int = 0  # server -> silo broadcast
    sim_seconds: float = 0.0  # simulated wall-clock (async latency model)

    def record(self, up: int, down: int, sim_seconds: float = 0.0) -> None:
        """Log one round's realized (up, down) bytes [+ simulated time]."""
        self.rounds += 1
        self.bytes_up += int(up)
        self.bytes_down += int(down)
        self.sim_seconds += float(sim_seconds)

    @property
    def total(self) -> int:
        return self.bytes_up + self.bytes_down

    @property
    def per_round(self) -> float:
        return self.total / max(self.rounds, 1)

    def state_dict(self) -> Dict[str, Any]:
        """Serializable counters (checkpointed by ``federated.api``)."""
        return {"rounds": self.rounds, "bytes_up": self.bytes_up,
                "bytes_down": self.bytes_down,
                "sim_seconds": self.sim_seconds}

    def load_state(self, state: Dict[str, Any]) -> None:
        """Restore counters saved by :meth:`state_dict`."""
        self.rounds = int(state["rounds"])
        self.bytes_up = int(state["bytes_up"])
        self.bytes_down = int(state["bytes_down"])
        # Pre-async checkpoints lack the key; they are sync runs (0.0).
        self.sim_seconds = float(state.get("sim_seconds", 0.0))
