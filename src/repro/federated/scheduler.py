"""Round scheduling and scenario construction for the federated runtime.

The scheduler is the scenario knob of the runtime: full participation
reproduces the paper's Algorithms 1–2 exactly; ``participation < 1``
samples a random subset per round (cross-device FL); ``dropout > 0``
models stragglers that accept the round but fail to report back. Masks
are deterministic functions of (seed, round index) so a schedule can be
replayed — and so the compiled round function can take the mask as a
plain (J,) array argument without retracing.

:class:`Scenario` bundles every orthogonal knob — sync cadence,
participation, stragglers, wire compression, differential privacy —
into one named configuration, and :func:`scenario_matrix` crosses the
axes into a grid so one CLI/benchmark invocation sweeps the whole
scenario space (``python -m repro.federated.run --sweep``).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.federated.aggregation import (
    Int8Compressor,
    MeanAggregator,
    NoCompression,
    TrimmedMeanAggregator,
)
from repro.federated.privacy import PrivacyPolicy
from repro.federated.strategy import get_strategy, strategy_names

# Human-readable labels for registry strategies (fallback: upper-cased name).
_ALGO_LABELS = {
    "sfvi": "SFVI",
    "sfvi_avg": "SFVI-Avg",
    "pvi": "PVI",
    "fed_ep": "FedEP",
}


def algorithm_label(algorithm: str) -> str:
    """Human-readable label for a registry strategy name."""
    return _ALGO_LABELS.get(algorithm, algorithm.upper())


@dataclasses.dataclass(frozen=True)
class RoundScheduler:
    """Samples a per-round participation mask over J silos.

    Attributes:
      num_silos: J, the federation width.
      participation: fraction of silos the server *invites* each round
        (at least one silo is always invited).
      dropout: probability that an invited silo straggles and drops out
        of the round after receiving the broadcast (its upload never
        arrives; the server rescales by the realized active count).
      seed: PRNG seed for the schedule.
    """

    num_silos: int
    participation: float = 1.0
    dropout: float = 0.0
    seed: int = 0

    def _keys(self, round_idx: int):
        # repro-lint: allow[R1] — participation stream root, folded with the absolute round index on the same line
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), round_idx)
        return jax.random.split(key)

    def invited(self, round_idx: int) -> jnp.ndarray:
        """(J,) float32 mask of silos the server *broadcasts to* this round.

        Stragglers (``dropout``) are invited — they receive (θ, η_G) and
        cost download bytes — but may still be absent from :meth:`mask`.
        """
        k_inv, _ = self._keys(round_idx)
        J = self.num_silos
        mask = np.ones((J,), np.float32)
        if self.participation < 1.0:
            # Half-up, not Python's round(): banker's rounding resolves
            # the .5 tie to the nearest EVEN count, so participation=0.5
            # with J=5 invited round(2.5) = 2 silos instead of the
            # documented "fraction of silos" (3). Even-J schedules are
            # unchanged (their products never tie on .5 at x.0 inputs).
            n_inv = max(1, int(self.participation * J + 0.5))
            chosen = np.asarray(
                jax.random.choice(k_inv, J, shape=(n_inv,), replace=False)
            )
            mask = np.zeros((J,), np.float32)
            mask[chosen] = 1.0
        return jnp.asarray(mask)

    def mask(self, round_idx: int) -> jnp.ndarray:
        """(J,) float32 mask: 1.0 = silo reports this round, 0.0 = absent."""
        _, k_drop = self._keys(round_idx)
        J = self.num_silos
        mask = np.asarray(self.invited(round_idx)).copy()
        if self.dropout > 0.0:
            survive = np.asarray(
                jax.random.bernoulli(k_drop, 1.0 - self.dropout, (J,))
            ).astype(np.float32)
            dropped = mask * survive
            # Never lose the whole round: keep the lowest-index invited silo.
            mask = dropped if dropped.any() else _first_invited(mask)
        return jnp.asarray(mask)

    def masks(self, num_rounds: int) -> jnp.ndarray:
        """(num_rounds, J) stacked schedule (for logging / tests)."""
        return jnp.stack([self.mask(r) for r in range(num_rounds)])


def _first_invited(mask: np.ndarray) -> np.ndarray:
    out = np.zeros_like(mask)
    out[int(np.argmax(mask))] = 1.0
    return out


# ---------------------------------------------------------------------------
# Asynchronous execution block (consumed by repro.federated.async_engine)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Declarative knobs of the buffered-asynchronous execution mode.

    All fields are JSON-native so the block round-trips inside
    :class:`Scenario` / ``ExperimentSpec``. The semantics (FedBuff-style
    buffer, staleness-decayed weights, deterministic latency models) are
    implemented by :mod:`repro.federated.async_engine`.

    Attributes:
      buffer_size: B — the server applies one aggregate ("flush") as
        soon as B silo contributions have arrived (1 ≤ B ≤ J;
        ``B == J`` with constant latency reproduces the synchronous
        SFVI-Avg trajectory bit-exactly).
      staleness_decay: exponent d of the weight ``(1 + s)^-d`` applied
        to a contribution that is ``s`` server versions behind
        (0 disables staleness weighting).
      latency: per-task silo latency model — ``"constant"`` (every task
        takes ``latency_scale``), ``"lognormal"`` (median
        ``latency_scale``, log-sd ``latency_sigma``), or
        ``"straggler"`` (constant, but a ``straggler_frac`` fraction of
        tasks run ``straggler_slowdown``× slower — the heavy-tail
        regime). Every draw is a pure function of
        (seed, silo, task index), so schedules replay bit-exactly.
      latency_scale: median simulated seconds per silo task.
      latency_sigma: log-normal spread (``"lognormal"`` only).
      straggler_frac: probability a task straggles (``"straggler"``).
      straggler_slowdown: multiplier for straggling tasks.
    """

    buffer_size: int = 2
    staleness_decay: float = 0.5
    latency: str = "lognormal"
    latency_scale: float = 1.0
    latency_sigma: float = 0.5
    straggler_frac: float = 0.1
    straggler_slowdown: float = 10.0

    @property
    def name(self) -> str:
        """Compact label fragment for scenario tables."""
        bits = [f"B={self.buffer_size}", self.latency]
        if self.staleness_decay:
            bits.append(f"d={self.staleness_decay:g}")
        return f"async({','.join(bits)})"


# ---------------------------------------------------------------------------
# Scenario matrix: participation × stragglers × compression × DP [× async]
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named point in the runtime's scenario space.

    A Scenario is declarative: it records the knob settings and builds
    the concrete runtime pieces on demand (:meth:`scheduler`,
    :meth:`compressor`, :meth:`privacy`), so grids stay cheap to
    enumerate and trivially serializable for logs.

    Attributes:
      algorithm: any registered server-strategy name
        (:func:`repro.federated.strategy.strategy_names`): ``"sfvi"``
        (sync every local step), ``"sfvi_avg"``, ``"pvi"``,
        ``"fed_ep"``, ...
      participation: fraction of silos invited per round.
      dropout: per-round straggler probability for invited silos.
      compression: ``"none"`` or ``"int8"`` wire codec.
      dp_noise: Gaussian noise multiplier z; 0 disables DP.
      dp_clip: L2 clip norm C for the upload (used when ``dp_noise > 0``
        or ``dp_clip_only``).
      dp_delta: target δ for (ε, δ) reports.
      dp_clip_only: apply clipping without noise (isolates the utility
        cost of clipping; ε stays ∞).
      aggregator: ``"mean"`` or ``"trimmed"`` server combine rule.
      trim_frac: trim fraction for the ``"trimmed"`` aggregator.
      async_cfg: buffered-asynchronous execution block
        (:class:`AsyncConfig`), or None for synchronous rounds. Async
        scenarios require a round-cadence algorithm (SFVI-Avg, PVI,
        FedEP) with full participation and no dropout — the latency
        model owns the arrival dynamics (:meth:`validate`).
    """

    algorithm: str = "sfvi_avg"
    participation: float = 1.0
    dropout: float = 0.0
    compression: str = "none"
    dp_noise: float = 0.0
    dp_clip: float = 1.0
    dp_delta: float = 1e-5
    dp_clip_only: bool = False
    aggregator: str = "mean"
    trim_frac: float = 0.1
    async_cfg: Optional[AsyncConfig] = None

    @property
    def name(self) -> str:
        """Compact human-readable label for tables and logs."""
        bits = [_ALGO_LABELS.get(self.algorithm, self.algorithm.upper())]
        if self.async_cfg is not None:
            bits.append(self.async_cfg.name)
        if self.participation < 1.0:
            bits.append(f"part={self.participation:g}")
        if self.dropout > 0.0:
            bits.append(f"drop={self.dropout:g}")
        if self.compression != "none":
            bits.append(self.compression)
        if self.dp_noise > 0.0:
            bits.append(f"dp(z={self.dp_noise:g},C={self.dp_clip:g})")
        elif self.dp_clip_only:
            bits.append(f"clip(C={self.dp_clip:g})")
        if self.aggregator != "mean":
            bits.append(f"{self.aggregator}({self.trim_frac:g})")
        return " ".join(bits)

    def validate(self, num_silos: Optional[int] = None) -> Scenario:
        """Reject physically-meaningless knob combinations (returns self).

        Async mode composes with compression, aggregation and DP, but
        not with the synchronous scheduler's participation/straggler
        knobs (the latency model subsumes them) and only under a
        round-cadence strategy (step-cadence strategies synchronize
        every local step — there is no round-granular contribution to
        buffer).
        """
        try:
            strategy_cls = get_strategy(self.algorithm)
        except KeyError:
            raise ValueError(
                f"unknown algorithm {self.algorithm!r}; registered "
                f"strategies: {list(strategy_names())}") from None
        if self.async_cfg is None:
            return self
        if strategy_cls.cadence != "round":
            raise ValueError(
                f"async execution requires a round-cadence strategy "
                f"(sfvi_avg, pvi, fed_ep, ...); {self.algorithm!r} "
                "synchronizes every local step and has no round-granular "
                "contribution to buffer")
        if self.participation < 1.0 or self.dropout > 0.0:
            raise ValueError(
                "async scenarios model arrival dynamics with the latency "
                "model; set participation=1.0 and dropout=0.0 (got "
                f"participation={self.participation}, dropout={self.dropout})")
        if self.async_cfg.buffer_size < 1:
            raise ValueError("async buffer_size must be >= 1")
        if num_silos is not None and self.async_cfg.buffer_size > num_silos:
            raise ValueError(
                f"async buffer_size={self.async_cfg.buffer_size} exceeds "
                f"the federation width J={num_silos}")
        if self.async_cfg.latency not in ("constant", "lognormal", "straggler"):
            raise ValueError(
                f"unknown latency model {self.async_cfg.latency!r} "
                "(constant/lognormal/straggler)")
        return self

    @classmethod
    def from_dict(cls, d: dict) -> Scenario:
        """Inverse of ``dataclasses.asdict`` (rebuilds the async block).

        Validates on deserialization: a hand-edited spec JSON combining
        contradictory knobs (e.g. ``async_cfg`` with a step-cadence
        algorithm) fails HERE, not rounds into a silently-wrong run.
        The federation width is not known yet, so the J-dependent
        checks re-run in ``api.build``.
        """
        d = dict(d)
        if d.get("async_cfg") is not None:
            d["async_cfg"] = AsyncConfig(**d["async_cfg"])
        return cls(**d).validate()

    def scheduler(self, num_silos: int, seed: int = 0) -> RoundScheduler:
        """The participation/straggler schedule for this scenario."""
        return RoundScheduler(
            num_silos, participation=self.participation,
            dropout=self.dropout, seed=seed,
        )

    def compressor(self):
        """The wire codec for this scenario."""
        if self.compression == "int8":
            return Int8Compressor()
        if self.compression == "none":
            return NoCompression()
        raise ValueError(f"unknown compression {self.compression!r}")

    def make_aggregator(self):
        """The server combine rule for this scenario."""
        if self.aggregator == "trimmed":
            return TrimmedMeanAggregator(self.trim_frac)
        if self.aggregator == "mean":
            return MeanAggregator()
        raise ValueError(f"unknown aggregator {self.aggregator!r}")

    def privacy(self) -> Optional[PrivacyPolicy]:
        """The DP policy, or None when this scenario is non-private."""
        if self.dp_noise > 0.0 or self.dp_clip_only:
            return PrivacyPolicy(
                clip_norm=self.dp_clip,
                noise_multiplier=self.dp_noise,
                delta=self.dp_delta,
            )
        return None


def scenario_matrix(
    *,
    algorithms: Sequence[str] = ("sfvi", "sfvi_avg"),
    participation: Sequence[float] = (1.0, 0.5),
    dropout: Sequence[float] = (0.0, 0.2),
    compression: Sequence[str] = ("none", "int8"),
    dp_noise: Sequence[float] = (0.0, 1.0),
    dp_clip: float = 1.0,
    dp_delta: float = 1e-5,
    async_cfgs: Sequence[Optional[AsyncConfig]] = (None,),
) -> list:
    """Cross participation × stragglers × compression × DP × async.

    The full cartesian product, minus physically-meaningless rows:
    dropout without partial participation is kept (stragglers exist
    under full invitation too), but async rows are emitted only for
    round-cadence algorithms under full participation (see
    :meth:`Scenario.validate`). ``algorithms`` accepts any registered
    strategy name — e.g. ``("sfvi", "sfvi_avg", "pvi", "fed_ep")``
    sweeps the whole zoo. One invocation of
    ``python -m repro.federated.run --sweep`` walks the returned list.
    """
    grid = []
    for algo, part, drop, comp, z, acfg in itertools.product(
        algorithms, participation, dropout, compression, dp_noise, async_cfgs
    ):
        if acfg is not None and (
            get_strategy(algo).cadence != "round" or part < 1.0 or drop > 0.0
        ):
            continue
        grid.append(Scenario(
            algorithm=algo, participation=part, dropout=drop,
            compression=comp, dp_noise=z, dp_clip=dp_clip, dp_delta=dp_delta,
            async_cfg=acfg,
        ))
    return grid
