"""Round scheduling: which silos participate in which round.

The scheduler is the scenario knob of the runtime: full participation
reproduces the paper's Algorithms 1–2 exactly; ``participation < 1``
samples a random subset per round (cross-device FL); ``dropout > 0``
models stragglers that accept the round but fail to report back. Masks
are deterministic functions of (seed, round index) so a schedule can be
replayed — and so the compiled round function can take the mask as a
plain (J,) array argument without retracing.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class RoundScheduler:
    """Samples a per-round participation mask over J silos.

    Attributes:
      num_silos: J, the federation width.
      participation: fraction of silos the server *invites* each round
        (at least one silo is always invited).
      dropout: probability that an invited silo straggles and drops out
        of the round after receiving the broadcast (its upload never
        arrives; the server rescales by the realized active count).
      seed: PRNG seed for the schedule.
    """

    num_silos: int
    participation: float = 1.0
    dropout: float = 0.0
    seed: int = 0

    def _keys(self, round_idx: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), round_idx)
        return jax.random.split(key)

    def invited(self, round_idx: int) -> jnp.ndarray:
        """(J,) float32 mask of silos the server *broadcasts to* this round.

        Stragglers (``dropout``) are invited — they receive (θ, η_G) and
        cost download bytes — but may still be absent from :meth:`mask`.
        """
        k_inv, _ = self._keys(round_idx)
        J = self.num_silos
        mask = np.ones((J,), np.float32)
        if self.participation < 1.0:
            n_inv = max(1, int(round(self.participation * J)))
            chosen = np.asarray(
                jax.random.choice(k_inv, J, shape=(n_inv,), replace=False)
            )
            mask = np.zeros((J,), np.float32)
            mask[chosen] = 1.0
        return jnp.asarray(mask)

    def mask(self, round_idx: int) -> jnp.ndarray:
        """(J,) float32 mask: 1.0 = silo reports this round, 0.0 = absent."""
        _, k_drop = self._keys(round_idx)
        J = self.num_silos
        mask = np.asarray(self.invited(round_idx)).copy()
        if self.dropout > 0.0:
            survive = np.asarray(
                jax.random.bernoulli(k_drop, 1.0 - self.dropout, (J,))
            ).astype(np.float32)
            dropped = mask * survive
            # Never lose the whole round: keep the lowest-index invited silo.
            mask = dropped if dropped.any() else _first_invited(mask)
        return jnp.asarray(mask)

    def masks(self, num_rounds: int) -> jnp.ndarray:
        """(num_rounds, J) stacked schedule (for logging / tests)."""
        return jnp.stack([self.mask(r) for r in range(num_rounds)])


def _first_invited(mask: np.ndarray) -> np.ndarray:
    out = np.zeros_like(mask)
    out[int(np.argmax(mask))] = 1.0
    return out
