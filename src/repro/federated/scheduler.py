"""Round scheduling and scenario construction for the federated runtime.

The scheduler is the scenario knob of the runtime: full participation
reproduces the paper's Algorithms 1–2 exactly; ``participation < 1``
samples a random subset per round (cross-device FL); ``dropout > 0``
models stragglers that accept the round but fail to report back. Masks
are deterministic functions of (seed, round index) so a schedule can be
replayed — and so the compiled round function can take the mask as a
plain (J,) array argument without retracing.

:class:`Scenario` bundles every orthogonal knob — sync cadence,
participation, stragglers, wire compression, differential privacy —
into one named configuration, and :func:`scenario_matrix` crosses the
axes into a grid so one CLI/benchmark invocation sweeps the whole
scenario space (``python -m repro.federated.run --sweep``).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.federated.aggregation import (
    Int8Compressor,
    MeanAggregator,
    NoCompression,
    TrimmedMeanAggregator,
)
from repro.federated.privacy import PrivacyPolicy


@dataclasses.dataclass(frozen=True)
class RoundScheduler:
    """Samples a per-round participation mask over J silos.

    Attributes:
      num_silos: J, the federation width.
      participation: fraction of silos the server *invites* each round
        (at least one silo is always invited).
      dropout: probability that an invited silo straggles and drops out
        of the round after receiving the broadcast (its upload never
        arrives; the server rescales by the realized active count).
      seed: PRNG seed for the schedule.
    """

    num_silos: int
    participation: float = 1.0
    dropout: float = 0.0
    seed: int = 0

    def _keys(self, round_idx: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), round_idx)
        return jax.random.split(key)

    def invited(self, round_idx: int) -> jnp.ndarray:
        """(J,) float32 mask of silos the server *broadcasts to* this round.

        Stragglers (``dropout``) are invited — they receive (θ, η_G) and
        cost download bytes — but may still be absent from :meth:`mask`.
        """
        k_inv, _ = self._keys(round_idx)
        J = self.num_silos
        mask = np.ones((J,), np.float32)
        if self.participation < 1.0:
            n_inv = max(1, int(round(self.participation * J)))
            chosen = np.asarray(
                jax.random.choice(k_inv, J, shape=(n_inv,), replace=False)
            )
            mask = np.zeros((J,), np.float32)
            mask[chosen] = 1.0
        return jnp.asarray(mask)

    def mask(self, round_idx: int) -> jnp.ndarray:
        """(J,) float32 mask: 1.0 = silo reports this round, 0.0 = absent."""
        _, k_drop = self._keys(round_idx)
        J = self.num_silos
        mask = np.asarray(self.invited(round_idx)).copy()
        if self.dropout > 0.0:
            survive = np.asarray(
                jax.random.bernoulli(k_drop, 1.0 - self.dropout, (J,))
            ).astype(np.float32)
            dropped = mask * survive
            # Never lose the whole round: keep the lowest-index invited silo.
            mask = dropped if dropped.any() else _first_invited(mask)
        return jnp.asarray(mask)

    def masks(self, num_rounds: int) -> jnp.ndarray:
        """(num_rounds, J) stacked schedule (for logging / tests)."""
        return jnp.stack([self.mask(r) for r in range(num_rounds)])


def _first_invited(mask: np.ndarray) -> np.ndarray:
    out = np.zeros_like(mask)
    out[int(np.argmax(mask))] = 1.0
    return out


# ---------------------------------------------------------------------------
# Scenario matrix: participation × stragglers × compression × DP
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named point in the runtime's scenario space.

    A Scenario is declarative: it records the knob settings and builds
    the concrete runtime pieces on demand (:meth:`scheduler`,
    :meth:`compressor`, :meth:`privacy`), so grids stay cheap to
    enumerate and trivially serializable for logs.

    Attributes:
      algorithm: ``"sfvi"`` (sync every local step) or ``"sfvi_avg"``.
      participation: fraction of silos invited per round.
      dropout: per-round straggler probability for invited silos.
      compression: ``"none"`` or ``"int8"`` wire codec.
      dp_noise: Gaussian noise multiplier z; 0 disables DP.
      dp_clip: L2 clip norm C for the upload (used when ``dp_noise > 0``
        or ``dp_clip_only``).
      dp_delta: target δ for (ε, δ) reports.
      dp_clip_only: apply clipping without noise (isolates the utility
        cost of clipping; ε stays ∞).
      aggregator: ``"mean"`` or ``"trimmed"`` server combine rule.
      trim_frac: trim fraction for the ``"trimmed"`` aggregator.
    """

    algorithm: str = "sfvi_avg"
    participation: float = 1.0
    dropout: float = 0.0
    compression: str = "none"
    dp_noise: float = 0.0
    dp_clip: float = 1.0
    dp_delta: float = 1e-5
    dp_clip_only: bool = False
    aggregator: str = "mean"
    trim_frac: float = 0.1

    @property
    def name(self) -> str:
        """Compact human-readable label for tables and logs."""
        bits = ["SFVI" if self.algorithm == "sfvi" else "SFVI-Avg"]
        if self.participation < 1.0:
            bits.append(f"part={self.participation:g}")
        if self.dropout > 0.0:
            bits.append(f"drop={self.dropout:g}")
        if self.compression != "none":
            bits.append(self.compression)
        if self.dp_noise > 0.0:
            bits.append(f"dp(z={self.dp_noise:g},C={self.dp_clip:g})")
        elif self.dp_clip_only:
            bits.append(f"clip(C={self.dp_clip:g})")
        if self.aggregator != "mean":
            bits.append(f"{self.aggregator}({self.trim_frac:g})")
        return " ".join(bits)

    def scheduler(self, num_silos: int, seed: int = 0) -> RoundScheduler:
        """The participation/straggler schedule for this scenario."""
        return RoundScheduler(
            num_silos, participation=self.participation,
            dropout=self.dropout, seed=seed,
        )

    def compressor(self):
        """The wire codec for this scenario."""
        if self.compression == "int8":
            return Int8Compressor()
        if self.compression == "none":
            return NoCompression()
        raise ValueError(f"unknown compression {self.compression!r}")

    def make_aggregator(self):
        """The server combine rule for this scenario."""
        if self.aggregator == "trimmed":
            return TrimmedMeanAggregator(self.trim_frac)
        if self.aggregator == "mean":
            return MeanAggregator()
        raise ValueError(f"unknown aggregator {self.aggregator!r}")

    def privacy(self) -> Optional[PrivacyPolicy]:
        """The DP policy, or None when this scenario is non-private."""
        if self.dp_noise > 0.0 or self.dp_clip_only:
            return PrivacyPolicy(
                clip_norm=self.dp_clip,
                noise_multiplier=self.dp_noise,
                delta=self.dp_delta,
            )
        return None


def scenario_matrix(
    *,
    algorithms: Sequence[str] = ("sfvi", "sfvi_avg"),
    participation: Sequence[float] = (1.0, 0.5),
    dropout: Sequence[float] = (0.0, 0.2),
    compression: Sequence[str] = ("none", "int8"),
    dp_noise: Sequence[float] = (0.0, 1.0),
    dp_clip: float = 1.0,
    dp_delta: float = 1e-5,
) -> list:
    """Cross participation × stragglers × compression × DP into Scenarios.

    The full cartesian product, minus physically-meaningless rows
    (dropout without partial participation is kept — stragglers exist
    under full invitation too). One invocation of
    ``python -m repro.federated.run --sweep`` walks the returned list.
    """
    grid = []
    for algo, part, drop, comp, z in itertools.product(
        algorithms, participation, dropout, compression, dp_noise
    ):
        grid.append(Scenario(
            algorithm=algo, participation=part, dropout=drop,
            compression=comp, dp_noise=z, dp_clip=dp_clip, dp_delta=dp_delta,
        ))
    return grid
