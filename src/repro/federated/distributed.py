"""Multi-process federation support (``jax.distributed`` execution).

One federated run can span several jax processes — one per host (or, in
the CPU smoke tests, several local processes each owning a slice of
forced host devices). The mesh is GLOBAL: every process constructs the
identical ``(silo[, model])`` mesh over ``jax.devices()`` and runs the
identical compiled round (SPMD), but each process *owns* the silo rows
that live on its local devices:

  * device-resident silo state (η_{L_j}, optimizer moments, strategy
    state, the data shard) exists only on the owning process — privacy
    by placement extends across hosts;
  * host I/O is routed through the owner: checkpoint shards for silo j
    are written and read only by j's owner
    (:func:`owned_rows` / :func:`host_rows`);
  * control-plane values every process must agree on (scheduler masks,
    round keys, metering counts) are pure functions of (seed, absolute
    round), so each process recomputes them identically — zero
    cross-host control traffic, the same determinism contract bit-exact
    resume already relies on.

CPU processes need the gloo collectives backend, selected BEFORE
``jax.distributed.initialize`` — :func:`initialize` owns that ordering.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

import jax
import numpy as np

PyTree = Any

# Environment schema for CLI-driven multi-process launches.
ENV_COORD = "REPRO_COORDINATOR"
ENV_NUM_PROCS = "REPRO_NUM_PROCESSES"
ENV_PROC_ID = "REPRO_PROCESS_ID"


def initialize(coordinator: Optional[str] = None,
               num_processes: Optional[int] = None,
               process_id: Optional[int] = None) -> None:
    """``jax.distributed.initialize`` with the CPU collectives fixed up.

    Arguments default to the ``REPRO_COORDINATOR`` /
    ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID`` environment schema
    (what the CLI's ``--coordinator``/... flags export). On a CPU-only
    platform the default collectives backend cannot run multi-process
    computations at all; gloo can, and must be selected before the
    distributed client starts.
    """
    coordinator = coordinator or os.environ.get(ENV_COORD)
    if num_processes is None and os.environ.get(ENV_NUM_PROCS):
        num_processes = int(os.environ[ENV_NUM_PROCS])
    if process_id is None and os.environ.get(ENV_PROC_ID):
        process_id = int(os.environ[ENV_PROC_ID])
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except (AttributeError, ValueError):  # repro-lint: allow[R6] — jax cross-version feature shim (flag name varies), not a protocol probe
        pass
    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=num_processes,
        process_id=process_id,
    )


def is_multiprocess() -> bool:
    return jax.process_count() > 1


def replicated(x, mesh):
    """Host value → global array replicated over the whole mesh."""
    from jax.sharding import NamedSharding, PartitionSpec

    host = np.asarray(jax.device_get(x))
    return jax.make_array_from_callback(
        host.shape, NamedSharding(mesh, PartitionSpec()),
        lambda idx: host[idx])


def globalize(tree: PyTree, mesh, pspec) -> PyTree:
    """Host-replicated pytree → global arrays sharded as ``pspec``.

    Every process passes the SAME host values (they are deterministic
    functions of the spec); ``make_array_from_callback`` materializes
    only this process's addressable shards, so a silo-sharded leaf
    costs each host only its own rows.
    """
    from jax.sharding import NamedSharding

    def leaf(x):
        host = np.asarray(jax.device_get(x))
        return jax.make_array_from_callback(
            host.shape, NamedSharding(mesh, pspec), lambda idx: host[idx])

    return jax.tree_util.tree_map(leaf, tree)


def row_owner_process(mesh, row: int, rows_total: int) -> int:
    """Process index owning padded silo row ``row`` of ``rows_total``.

    Rows shard over the ``silo`` axis in equal contiguous blocks; the
    owner is the process of the block's device (first model column on a
    2-D mesh — the whole row of model columns is co-hosted per process
    under the contiguous device layout ``build_mesh`` produces).
    """
    devs = np.asarray(mesh.devices)
    n_blocks = mesh.shape["silo"]
    block = row // (rows_total // n_blocks)
    dev = devs[block] if devs.ndim == 1 else devs[block, 0]
    return int(dev.process_index)


def owned_rows(mesh, rows_total: int) -> list:
    """Padded-row indices this process owns (contiguous silo blocks)."""
    me = jax.process_index()
    return [r for r in range(rows_total)
            if row_owner_process(mesh, r, rows_total) == me]


def silo_sharded_from_rows(like: PyTree, mesh, rows: Dict[int, PyTree]) -> PyTree:
    """Owner-held row trees → a global silo-sharded stacked tree.

    ``like`` supplies shape/dtype (leading axis J_pad); ``rows`` maps
    padded-row index → that row's host tree and need only contain THIS
    process's owned real rows — ``make_array_from_callback`` asks each
    process for its addressable shards alone. Missing rows (padded
    dummies, rows owned elsewhere) fill with zeros: padded rows are
    permanently masked, and remote rows materialize on their owners.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    leaves, treedef = jax.tree_util.tree_flatten(like)
    row_leaves = {j: jax.tree_util.tree_flatten(t)[0] for j, t in rows.items()}

    def build(i, leaf):
        shape, dtype = leaf.shape, leaf.dtype

        def cb(idx):
            sl = idx[0] if idx else slice(0, shape[0])
            start = 0 if sl.start is None else sl.start
            stop = shape[0] if sl.stop is None else sl.stop
            out = np.zeros((stop - start,) + shape[1:], dtype)
            for r in range(start, stop):
                if r in row_leaves:
                    out[r - start] = np.asarray(row_leaves[r][i])
            return out

        return jax.make_array_from_callback(
            shape, NamedSharding(mesh, PartitionSpec("silo")), cb)

    return jax.tree_util.tree_unflatten(
        treedef, [build(i, leaf) for i, leaf in enumerate(leaves)])


def host_rows(x, rows: list) -> Dict[int, np.ndarray]:
    """{row index: host value} for owned rows of a silo-sharded global.

    Reads only this process's addressable shards — never triggers a
    cross-process collective (plain ``x[j]`` on a global array would
    dispatch one, deadlocking per-process checkpoint I/O).
    """
    out: Dict[int, np.ndarray] = {}
    want = set(rows)
    for shard in x.addressable_shards:
        sl = shard.index[0] if shard.index else slice(0, x.shape[0])
        data = np.asarray(shard.data)
        start = sl.start or 0
        for i in range(data.shape[0]):
            if start + i in want and start + i not in out:
                out[start + i] = data[i]
    return out
