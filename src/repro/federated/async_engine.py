"""Buffered-asynchronous federated execution (FedBuff-style flushes).

Real federations are asynchronous: silos finish local work at wildly
different speeds, and a server that waits for the slowest silo every
round (the synchronous ``Server.run``) wastes the fast ones. This module
adds the buffered-asynchronous execution mode of Nguyen et al. (2022,
FedBuff), in the damped/asynchronous update regime that Partitioned
Variational Inference (Ashman et al., 2022) shows remains sound for the
structured-VI update family:

  * every silo loops forever: pull the current (θ, η_G), run
    ``local_steps`` local VI steps, upload the contribution, repeat;
  * the server buffers arriving contributions and applies one aggregate
    — a **flush** — as soon as ``buffer_size`` of them are waiting,
    weighting each contribution by ``(1 + staleness)^-staleness_decay``
    where staleness counts how many flushes the server applied since
    that silo last pulled;
  * per-silo task latencies come from a deterministic model
    (constant / lognormal / straggler-tail) keyed on
    ``(seed, silo, task index)``, so a run — and a checkpoint-resumed
    run — replays **bit-exactly**.

The implementation keeps everything compiled: the arrival process is
simulated on the host (microseconds — it is a tiny event loop), yielding
per-flush participation **counts** and **staleness** vectors, and each
flush executes the *existing* ``shard_map`` round graph of any
round-cadence strategy (SFVI-Avg, PVI, FedEP) with
those static tensors — the participation mask gates local-state updates
and the staleness-decayed weights drive the aggregation. DP clip/noise,
int8 wire compression and the single coalesced ``all_gather`` therefore
apply to async rounds unchanged.

Two deliberate modeling choices, documented in docs/federated.md:

  * contributions are *computed* against the flush-time server state and
    staleness enters through the aggregation weight (the damped-update
    view of asynchrony); the arrival process — which silos contribute,
    how often, how stale — is simulated faithfully;
  * with ``buffer_size == J`` and constant latency every flush contains
    every silo at staleness 0 with weight 1, which reproduces the
    synchronous SFVI-Avg trajectory **bit-exactly**
    (``tests/test_async.py``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import debug
from repro.federated.scheduler import AsyncConfig

PyTree = Any

# Salt for the latency stream, so it can never collide with the round-key
# or DP-noise streams (which are jax PRNG folds of the same user seed).
_LATENCY_SALT = 0x5AF0


def latency_draw(cfg: AsyncConfig, seed: int, silo: int, task: int) -> float:
    """Simulated seconds silo ``silo`` spends on its ``task``-th task.

    A pure function of ``(seed, silo, task)`` — NumPy's ``SeedSequence``
    hashing makes the draw reproducible across runs, platforms and
    resume boundaries, which is what makes the whole arrival schedule
    replayable.
    """
    if cfg.latency == "constant":
        return float(cfg.latency_scale)
    rng = np.random.default_rng([_LATENCY_SALT, seed, silo, task])
    if cfg.latency == "lognormal":
        return float(
            cfg.latency_scale * math.exp(cfg.latency_sigma * rng.standard_normal())
        )
    if cfg.latency == "straggler":
        slow = rng.random() < cfg.straggler_frac
        return float(cfg.latency_scale * (cfg.straggler_slowdown if slow else 1.0))
    raise ValueError(
        f"unknown latency model {cfg.latency!r} (constant/lognormal/straggler)"
    )


@dataclasses.dataclass
class BufferState:
    """The server-side event-loop state between flushes.

    This is the "buffer state" of the checkpoint/resume guarantee: it
    captures the simulated clock, each silo's in-flight task (which
    server version it pulled, when it will finish) and the contributions
    already buffered toward the next flush. ``state_dict``/``load_state``
    round-trip it losslessly through JSON (Python floats are doubles and
    ``json`` serializes them via repr, which is exact), so a resumed run
    continues the arrival schedule bit-exactly mid-buffer.

    Attributes:
      version: flushes applied so far (the server's parameter version).
      clock: simulated wall-clock seconds.
      last_flush: simulated time of the previous flush (0.0 initially).
      task_idx: per-silo index of the task currently in flight.
      start_version: per-silo server version pulled at task start.
      start_time: per-silo simulated time the in-flight task started
        (used to resolve pull-vs-flush ties: a silo that re-pulls at the
        exact instant of a flush sees the post-flush model).
      finish_time: per-silo simulated completion time of the in-flight
        task.
      buffer: pending contributions as (silo, staleness) pairs, in
        arrival order — staleness is recorded at buffering time
        (versions elapsed since that silo's pull; 0 in the synchronous
        regime, matching FedBuff's convention); flushed when it reaches
        ``buffer_size``.
    """

    version: int
    clock: float
    last_flush: float
    task_idx: List[int]
    start_version: List[int]
    start_time: List[float]
    finish_time: List[float]
    buffer: List[Tuple[int, int]]

    @classmethod
    def init(cls, num_silos: int, cfg: AsyncConfig, seed: int) -> BufferState:
        """All silos pull version 0 at t=0 and start their first task."""
        return cls(
            version=0,
            clock=0.0,
            last_flush=0.0,
            task_idx=[0] * num_silos,
            start_version=[0] * num_silos,
            start_time=[0.0] * num_silos,
            finish_time=[
                latency_draw(cfg, seed, j, 0) for j in range(num_silos)
            ],
            buffer=[],
        )

    def state_dict(self) -> Dict[str, Any]:
        """JSON-native snapshot (checkpointed by ``federated.api``)."""
        return {
            "version": self.version,
            "clock": self.clock,
            "last_flush": self.last_flush,
            "task_idx": list(self.task_idx),
            "start_version": list(self.start_version),
            "start_time": list(self.start_time),
            "finish_time": list(self.finish_time),
            "buffer": [[int(j), int(s)] for j, s in self.buffer],
        }

    @classmethod
    def from_state(cls, state: Dict[str, Any]) -> BufferState:
        """Inverse of :meth:`state_dict`."""
        return cls(
            version=int(state["version"]),
            clock=float(state["clock"]),
            last_flush=float(state["last_flush"]),
            task_idx=[int(x) for x in state["task_idx"]],
            start_version=[int(x) for x in state["start_version"]],
            start_time=[float(x) for x in state["start_time"]],
            finish_time=[float(x) for x in state["finish_time"]],
            buffer=[(int(j), int(s)) for j, s in state["buffer"]],
        )


def simulate_flush(
    state: BufferState, cfg: AsyncConfig, seed: int, num_silos: int,
    active: Optional[List[int]] = None,
) -> Tuple[np.ndarray, np.ndarray, float]:
    """Advance the event loop to the next flush; mutates ``state``.

    Pops arrivals in (finish_time, silo id) order — the id tie-break is
    what pins the schedule down under constant latency — buffering each
    with its staleness (server versions elapsed since that silo's pull,
    recorded AT BUFFERING TIME: a contribution that arrives before the
    server has moved is staleness 0, FedBuff's convention) and
    immediately restarting the silo on a fresh task pulled at the
    current server version. When ``buffer_size`` contributions are
    waiting, returns their per-silo counts (a fast silo can land twice
    in one buffer; duplicate entries keep the latest staleness), the
    staleness vector and the simulated flush time, bumps the version
    and clears the buffer.

    Tie resolution at the flush instant: a silo whose re-pull coincides
    with the flush (its arrival completed the buffer, or it arrived at
    the exact same simulated time) downloads the POST-flush model —
    uploads are processed before downloads are served. This is what
    makes the ``buffer_size == J`` constant-latency schedule exactly
    synchronous: every silo re-pulls the just-flushed version, so the
    next flush is staleness 0 again.

    ``active`` (population churn) restricts the arrival pop to the
    currently-present silos: a departed silo's in-flight task is
    frozen — never popped, never restarted — until it returns. The
    flush target is clamped to the active head-count so a shrunken
    population can still fill a buffer.
    """
    J = num_silos
    pool = (list(range(J)) if active is None
            else [i for i in range(J) if active[i]])
    if not pool:
        raise ValueError("simulate_flush needs at least one active silo")
    target = min(cfg.buffer_size, len(pool))
    restarted = set()
    while len(state.buffer) < target:
        j = min(pool, key=lambda i: (state.finish_time[i], i))
        state.clock = state.finish_time[j]
        state.buffer.append((j, state.version - state.start_version[j]))
        state.task_idx[j] += 1
        state.start_version[j] = state.version
        state.start_time[j] = state.clock
        state.finish_time[j] = state.clock + latency_draw(
            cfg, seed, j, state.task_idx[j]
        )
        restarted.add(j)
    counts = np.zeros((J,), np.float32)
    staleness = np.zeros((J,), np.float32)
    for j, s in state.buffer:
        counts[j] += 1.0
        staleness[j] = float(s)
    flush_time = state.clock
    state.version += 1
    state.buffer = []
    for j in restarted:
        # Pulls at the flush instant see the post-flush model. Only
        # THIS drain's restarts qualify — a silo that re-pulled at an
        # EARLIER flush sharing the same simulated timestamp (common
        # under constant latency) keeps its recorded pull version, or
        # its staleness would be silently under-counted.
        if state.start_time[j] == flush_time:
            state.start_version[j] = state.version
    return counts, staleness, flush_time


def flush_weights(
    counts: np.ndarray, staleness: np.ndarray, decay: float
) -> np.ndarray:
    """Aggregation weights: ``count · (1 + staleness)^-decay`` per silo.

    Zero staleness gives weight exactly ``count`` (``x**-0.0 == 1.0`` in
    IEEE arithmetic), which is what makes the ``buffer_size == J``
    constant-latency flush bit-identical to a synchronous full round.

    The aggregator normalizes by the realized total weight (a weighted
    MEAN — parameter uploads must not shrink toward zero when Σw < 1),
    so these weights act RELATIVELY: a stale contribution is
    down-weighted against fresher ones sharing its buffer, and a
    single-contribution buffer (B=1) is applied at full strength
    whatever its staleness (``tests/test_async.py``).
    """
    return (counts * (1.0 + staleness) ** (-decay)).astype(np.float32)


def run_buffered(
    server,
    num_flushes: int,
    cfg: AsyncConfig,
    *,
    algorithm=None,
    local_steps: int = 1,
    start_flush: int = 0,
    state: Optional[BufferState] = None,
    callback: Optional[Callable[[int, dict], None]] = None,
    population=None,
) -> Tuple[Dict[str, list], BufferState]:
    """Drive a :class:`~repro.federated.runtime.Server` asynchronously.

    The async counterpart of ``Server.run``: each flush executes the
    compiled round graph of a round-cadence
    :class:`~repro.federated.strategy.ServerStrategy` (``algorithm``;
    the server's own strategy when None) with the flush's participation
    mask (which silos ran local steps and may update their η_{L_j}) and
    its staleness-decayed aggregation weights. Step-cadence strategies
    synchronize inside their local loop and have no single round-granular
    contribution to buffer, so they are rejected here. ``start_flush``
    is the absolute flush index — the round-key stream is the same
    ``fold_in(seed, absolute index)`` stream the synchronous path uses,
    so checkpoint/resume replays bit-exactly given the saved
    :class:`BufferState`.

    Billing: uploads are the buffered contributions (``counts`` per
    flush); each buffered arrival immediately triggers a fresh broadcast
    pull, so downloads are billed at the same multiplicity. The meter
    additionally accumulates the simulated wall-clock between flushes
    (``CommMeter.sim_seconds``); ``history["sim_time"]`` carries the
    absolute flush times.

    With DP, every flush is one (subsampled) Gaussian-mechanism gather;
    the accountant composes them at the Poisson surrogate rate
    ``q = buffer_size / J`` (same surrogate the synchronous path uses
    for its fixed-size invitations — docs/privacy.md).

    ``population`` threads a
    :class:`~repro.federated.population.PopulationEngine` through the
    event loop: before each flush, ``begin_flush`` processes the churn
    events (a join grows the silo axis and starts the new silo's first
    task at the current simulated clock; a return restarts the silo's
    interrupted task but keeps its stale pull version, so its
    contribution enters :func:`flush_weights` with the server-version
    staleness the gap implies) and hands back the activity mask the
    flush simulation pops arrivals under.

    Returns ``(history, state)`` — pass ``state`` back in to continue.
    """
    if local_steps < 1:
        raise ValueError(f"local_steps must be >= 1, got {local_steps}")
    J = server.J
    if not 1 <= cfg.buffer_size <= J:
        raise ValueError(
            f"buffer_size must be in [1, J={J}], got {cfg.buffer_size}")
    strat = server._resolve(algorithm)
    if strat.cadence != "round":
        raise ValueError(
            f"buffered-async execution needs a round-cadence strategy; "
            f"{strat.name!r} synchronizes every local step")
    # One-time setup — graph construction, byte metering, and the PRNG
    # root all move tiny host scalars to device; sanctioned under the
    # transfer guard (repro.debug.host_bridge).
    with debug.host_bridge():
        fn = server._get_round(strat, local_steps)
        if state is None:
            state = BufferState.init(J, cfg, server.seed)
        up1 = server.bytes_up_per_silo(strat)
        down1 = server.bytes_down_per_silo()
    history: Dict[str, list] = {
        "elbo": [], "elbo_trace": [], "bytes_up": [], "bytes_down": [],
        "n_active": [], "staleness": [], "sim_time": [],
    }
    if server.accountant is not None:
        history["epsilon"] = []
        q = cfg.buffer_size / J
    with debug.host_bridge():
        base_key = jax.random.PRNGKey(server.seed)
    for f in range(start_flush, start_flush + num_flushes):
        active = None
        if population is not None:
            with debug.host_bridge():
                # Churn first: joins grow the silo axis (stepping J_pad
                # re-fetches the compiled round) and extend the event
                # loop's per-silo task lists at the current clock.
                active = population.begin_flush(server, state, cfg, f)
                J = server.J
                fn = server._get_round(strat, local_steps)
        counts, staleness, t_flush = simulate_flush(
            state, cfg, server.seed, J, active=active)
        mask = (counts > 0.0).astype(np.float32)
        weights = flush_weights(counts, staleness, cfg.staleness_decay)
        with debug.host_bridge():
            round_key = jax.random.fold_in(base_key, f)
        # Explicit H2D/D2H transfers (device_put/device_get) keep the
        # flush loop legal under jax.transfer_guard("disallow") — see
        # repro.debug.sanitize. The latency model itself stays on host.
        server.state, metrics = fn(
            server.state,
            server.data,
            jax.device_put(np.asarray(server.num_obs, np.float32)),
            round_key,
            server._pad_mask(jax.device_put(mask)),
            server._pad_mask(jax.device_put(weights)),
        )
        elbos = jax.device_get(metrics["elbo"])
        n_contrib = int(counts.sum())
        n_active = int((counts > 0).sum())
        up, down = n_contrib * up1, n_contrib * down1
        sim_dt = t_flush - state.last_flush
        state.last_flush = t_flush
        server.comm.record(up, down, sim_seconds=sim_dt)
        stale_max = float(staleness.max(initial=0.0, where=counts > 0))
        history["elbo"].append(float(elbos[-1]))
        history["elbo_trace"].extend(float(e) for e in elbos)
        history["bytes_up"].append(up)
        history["bytes_down"].append(down)
        history["n_active"].append(n_active)
        history["staleness"].append(stale_max)
        history["sim_time"].append(t_flush)
        metrics_out = {
            "elbo": history["elbo"][-1], "bytes_up": up, "bytes_down": down,
            "n_active": n_active, "staleness": stale_max, "sim_time": t_flush,
        }
        if server.accountant is not None:
            server.accountant.step(
                noise_multiplier=server.privacy.noise_multiplier,
                sampling_rate=q,
                steps=1,
            )
            eps = server.accountant.epsilon(server.privacy.delta)[0]
            history["epsilon"].append(eps)
            metrics_out["epsilon"] = eps
        if callback:
            callback(f, metrics_out)
    return history, state
