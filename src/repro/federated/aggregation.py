"""Pluggable server-side aggregation and wire compression.

Every round the runtime gathers one pytree per silo (gradients for SFVI,
locally-updated parameters for SFVI-Avg), stacked along a leading silo
axis of size J. An :class:`Aggregator` turns that stack plus the round's
participation mask into a single *mean-like* estimate over the active
silos; the runtime rescales by J where the paper's algebra needs the sum
Σ_j (unbiased under partial participation, §3 Remark).

A :class:`Compressor` sits on the silo→server edge: silos ``encode`` the
shipped pytree before the ``all_gather`` and the server ``decode``s after
it, so the collective itself moves the compressed representation — the
byte reduction is visible both in the host-side meter (``wire_bytes``)
and in the compiled HLO via ``launch.roofline.collective_bytes``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.federated.metering import is_array, tree_bytes

PyTree = Any


def _check_wire(wire: str) -> None:
    if wire not in ("flat", "fused", "legacy"):
        raise ValueError(f"unknown wire layout {wire!r} (flat/fused/legacy)")


def _tree_elements(tree: PyTree) -> int:
    return sum(
        int(np.prod(x.shape))
        for x in jax.tree_util.tree_leaves(tree)
        if is_array(x)
    )


def _bcast_mask(mask: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    return mask.reshape(mask.shape[0], *([1] * (x.ndim - 1)))


@dataclasses.dataclass(frozen=True)
class MeanAggregator:
    """Weighted mean over the round's active silos.

    ``combine`` returns Σ_j m_j x_j / Σ_j m_j — for a binary
    participation mask m this is the paper's server reduction up to the
    J rescale applied by the runtime (J · mean over active =
    (J/|A|) Σ_active, the unbiased partial-participation estimator).
    The async engine passes fractional staleness-decay weights instead
    of a 0/1 mask, turning the same expression into the FedBuff-style
    staleness-weighted mean.

    The denominator guards only against an exactly-zero total weight
    (an empty round): clamping it to 1.0, as an earlier version did,
    silently shrank every aggregate whose fractional weights summed
    below 1 — e.g. a single stale async arrival with weight 0.25 was
    divided by 1.0 instead of 0.25, scaling the (parameter!) upload by
    4× toward zero.

    ``fused_reduction`` is the Aggregator protocol's *capability
    attribute*: the name of the fused Pallas reduction that computes
    this rule on the fused wire ("mean"/"trimmed"), or ``None``
    (the default the runtime assumes via ``getattr``) to fall back to
    :meth:`combine` on the dequantized (J, P) matrix.  Custom
    aggregators omit it; the runtime never type-probes.
    """

    fused_reduction = "mean"

    def combine(self, stacked: PyTree, mask: jnp.ndarray) -> PyTree:
        """Weighted mean over the leading silo axis of every leaf."""
        total = jnp.sum(mask)
        denom = jnp.where(total > 0.0, total, 1.0)

        def leaf(x):
            return jnp.sum(_bcast_mask(mask, x) * x, axis=0) / denom

        return jax.tree_util.tree_map(leaf, stacked)


@dataclasses.dataclass(frozen=True)
class TrimmedMeanAggregator:
    """Coordinate-wise trimmed mean over active silos (Yin et al., 2018).

    Drops the ``trim_frac`` fraction of smallest and largest values per
    coordinate among the *active* silos before averaging — a robust
    aggregation rule for straggler/Byzantine scenarios. Inactive silos
    are excluded by sorting them to the top (+inf sentinel) and masking
    by rank. Degenerates to :class:`MeanAggregator` at ``trim_frac=0``.
    """

    fused_reduction = "trimmed"

    trim_frac: float = 0.1

    def combine(self, stacked: PyTree, mask: jnp.ndarray) -> PyTree:
        """Per-coordinate trimmed mean over the active silos of every leaf.

        Any silo with weight > 0 counts as active; the trimmed mean
        itself is unweighted (rank statistics have no canonical
        fractional weighting), so under the async engine staleness
        affects only WHICH silos enter the trim, not their weight.
        """
        any_active = jnp.sum((mask > 0.0).astype(mask.dtype)) > 0.0
        n_active = jnp.maximum(jnp.sum((mask > 0.0).astype(mask.dtype)), 1.0)
        k = jnp.floor(self.trim_frac * n_active)
        k = jnp.minimum(k, jnp.floor((n_active - 1.0) / 2.0))

        def leaf(x):
            m = _bcast_mask(mask, x) > 0.0
            order = jnp.sort(jnp.where(m, x, jnp.inf), axis=0)
            rank = jnp.arange(x.shape[0]).reshape(-1, *([1] * (x.ndim - 1)))
            keep = (rank >= k) & (rank < n_active - k)
            total = jnp.sum(jnp.where(keep, order, 0.0), axis=0)
            mean = total / jnp.maximum(jnp.sum(keep, axis=0), 1)
            # Zero active silos would average the +inf sentinel; return
            # zeros instead, like MeanAggregator's zero-total guard.
            return jnp.where(any_active, mean, jnp.zeros_like(mean))

        return jax.tree_util.tree_map(leaf, stacked)


# ---------------------------------------------------------------------------
# Wire compression
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NoCompression:
    """Identity codec: ships raw float leaves (4 bytes/element for f32).

    ``wire_codec`` is the Compressor protocol's capability attribute:
    the fused wire inlines the codecs it has Pallas kernels for
    ("identity" and "int8") and calls ``encode``/``decode`` per silo
    for anything else (``getattr`` default "custom").
    """

    wire_codec = "identity"

    def encode(self, tree: PyTree) -> PyTree:
        """Identity — the shipped tree is the wire format."""
        return tree

    def decode(self, enc: PyTree) -> PyTree:
        """Identity inverse of :meth:`encode`."""
        return enc

    def wire_bytes(self, tree: PyTree, wire: str = "legacy") -> int:
        """Wire size of the raw upload for the given wire layout.

        ``legacy`` ships the pytree leaf-by-leaf at native dtypes —
        delegates to :func:`repro.federated.metering.tree_bytes`, the
        repo's single byte-accounting primitive. ``flat``/``fused``
        pack the whole tree into ONE contiguous float32 vector
        (:class:`~repro.core.flatten.TreeSpec`), so the wire carries
        4 bytes per element regardless of leaf dtypes.
        """
        _check_wire(wire)
        if wire in ("flat", "fused"):
            return 4 * _tree_elements(tree)
        return tree_bytes(tree)


@dataclasses.dataclass(frozen=True)
class Int8Compressor:
    """Per-leaf symmetric int8 quantization of shipped pytrees.

    Each leaf x is shipped as (round(x / s) : int8, s : f32) with
    s = max|x| / 127, a 4× wire reduction on f32 gradients at <1%
    relative error on the aggregate (quantization noise is zero-mean and
    averages down across silos). Because ``encode`` runs *before* the
    cross-silo ``all_gather``, the collective moves int8 payloads — the
    saving shows up in the optimized HLO's collective bytes, not just in
    the host-side meter.
    """

    wire_codec = "int8"

    def encode(self, tree: PyTree) -> PyTree:
        """Quantize every leaf to (int8 payload, f32 scale) wire format."""
        def leaf(x):
            scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
            q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
            return {"q": q, "scale": scale.astype(jnp.float32)}

        return {"leaves": [leaf(x) for x in jax.tree_util.tree_leaves(tree)],
                "treedef": _Static(jax.tree_util.tree_structure(tree))}

    def decode(self, enc: PyTree) -> PyTree:
        """Dequantize and rebuild the original pytree structure."""
        leaves = [d["q"].astype(jnp.float32) * d["scale"] for d in enc["leaves"]]
        return jax.tree_util.tree_unflatten(enc["treedef"].value, leaves)

    def wire_bytes(self, tree: PyTree, wire: str = "legacy") -> int:
        """Wire size of the quantized upload for the given wire layout.

        ``legacy`` quantizes leaf-by-leaf: 1 B/element + one 4-byte f32
        scale PER LEAF. ``flat``/``fused`` pack the whole upload into a
        single (P,) vector first, so the silo ships one int8 row and
        exactly ONE scale: P + 4 bytes. Billing the per-leaf scales on
        the flat wire over-billed multi-leaf models relative to what
        the compiled collective actually gathers (one s8 payload + one
        f32 scale per silo — see ``launch.roofline.collective_bytes``).
        """
        _check_wire(wire)
        n = _tree_elements(tree)
        if wire in ("flat", "fused"):
            return n + 4  # one int8 payload row + ONE f32 scale per silo
        n_leaves = sum(
            1 for x in jax.tree_util.tree_leaves(tree) if is_array(x)
        )
        return n + 4 * n_leaves


@dataclasses.dataclass(frozen=True)
class _Static:
    """Wraps a treedef so it rides through pytree ops as a static leaf."""

    value: Any

    def __hash__(self):
        return hash(self.value)


jax.tree_util.register_pytree_node(
    _Static, lambda s: ((), s.value), lambda aux, _: _Static(aux)
)
