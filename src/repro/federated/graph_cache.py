"""Process-level cache of compiled round functions across Server rebuilds.

``Server`` keeps its jitted round functions in a per-instance dict, so
every rebuild — most importantly ``Experiment.resume`` — used to pay a
full retrace+compile of a graph the process had already compiled.  This
module shares that dict between Servers that are *structurally
identical*: same spec JSON, same wire layout, same silo count, same
device signature.

Soundness leans on exactly the contract bit-exact resume already
relies on: a registry-staged build is a pure function of its spec, so
two Servers built from equal specs close over equal configuration
(aggregator, compressor, privacy, mesh, num_obs) and their round
bodies trace to identical graphs; everything that varies per round
(state, data, key, masks) flows through the jit boundary as arguments.
Builds with a caller-supplied bundle carry arbitrary Python objects the
token cannot see, so they opt out (``token=None``) and keep a private
dict.

The cache also closes the recompile-watchdog loop
(:mod:`repro.debug`): with it, ``save→resume`` on the same device
count re-traces nothing, and the watchdog can assert one trace per
config across a resume boundary.
"""

from __future__ import annotations

import hashlib
import json
from collections import OrderedDict
from typing import Callable, Dict, Optional

import jax

__all__ = ["build_token", "round_fns", "clear"]

# A handful of configs covers any realistic process (one experiment plus
# its resume, a sweep over a few strategies); LRU keeps sweeps over many
# specs from pinning every compiled graph in memory forever.
_LIMIT = 8
_CACHE: OrderedDict[str, Dict[tuple, Callable]] = OrderedDict()


def build_token(spec_json: str, wire: str, num_silos: int,
                mesh_shape=None, j_pad: Optional[int] = None) -> str:
    """Structural identity of a registry-staged build.

    Covers everything the round graph closes over: the full spec (model,
    strategy, optimizers, privacy, compression — via its canonical
    JSON), the wire layout, J, the RESOLVED mesh shape, the process
    count, the device signature, and the padded silo-axis width
    ``j_pad``. The mesh shape and process count must be hashed
    explicitly: the device signature alone let two builds with
    different forced-device counts (or different ``MeshSpec``
    topologies over the same devices) collide on one compiled graph
    whose shard_map was traced for the other mesh. ``j_pad`` is the
    population-growth boundary: every silo-sharded shape in the round
    graph is a function of it, so two builds whose live J differs but
    lands in the same padded chunk share a token (and the compiled
    graph — joined silos ride the runtime ``n_j``/mask arguments),
    while crossing a chunk boundary changes the token exactly when the
    shapes change.
    """
    devices = tuple((d.platform, d.id) for d in jax.devices())
    shape = [list(t) for t in (mesh_shape or ())]
    payload = json.dumps(
        [spec_json, wire, num_silos, devices, shape, jax.process_count(),
         j_pad],
        sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def round_fns(token: Optional[str]) -> Dict[tuple, Callable]:
    """The shared round-fn dict for ``token``; a private one for None."""
    if token is None:
        return {}
    if token in _CACHE:
        _CACHE.move_to_end(token)
    else:
        _CACHE[token] = {}
        while len(_CACHE) > _LIMIT:
            _CACHE.popitem(last=False)
    return _CACHE[token]


def clear() -> None:
    """Drop every shared entry (tests; frees compiled executables)."""
    _CACHE.clear()
