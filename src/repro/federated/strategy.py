"""Pluggable server-side update rules: the ``ServerStrategy`` protocol.

The compiled :class:`~repro.federated.runtime.Server` advances all J silos
through one shard_map graph per round, but everything *algorithm-specific*
— what each silo computes locally, what it ships, and how the server folds
the aggregate back into (θ, η_G) — lives here, behind a name-keyed
registry mirroring :mod:`repro.core.family`'s ``VariationalFamily``:

  * ``register_strategy``/``get_strategy``/``strategy_names`` — the
    registry; :class:`StrategySpec` is the serializable handle that rides
    on ``ExperimentSpec`` (exactly like ``FamilySpec``).
  * :class:`ServerStrategy` — the protocol. Capability flags
    (``cadence``, ``has_silo_state``, ``wire_reference``) tell the
    runtime how to wire a strategy into the generic round bodies; the
    hooks supply the per-silo and server-side math.

Two cadences cover every federated-VI update rule in the zoo:

  * ``cadence == "step"`` — synchronize every local step (one gather per
    optimizer step). Hooks: :meth:`ServerStrategy.silo_step` +
    :meth:`ServerStrategy.server_step`. SFVI (paper Algorithm 1).
  * ``cadence == "round"`` — K local steps per silo, ONE gather, one
    server merge. Hooks: :meth:`ServerStrategy.local_run` +
    :meth:`ServerStrategy.server_update`. SFVI-Avg (§3.2), PVI
    (Ashman et al., arXiv:2202.12275) and federated EP (Guo et al.,
    arXiv:2302.04228).

Every strategy ships ONE pytree per silo per exchange, and the runtime
packs it over the same flat/fused ``(J, P)`` wire regardless of what the
tree means (gradients, parameters, natural-parameter deltas) — so DP
clip+noise, int8 quantization, async staleness weights and the single
coalesced all_gather apply to PVI/EP exactly as they do to the paper's
two algorithms, and DP composition threads through the one
``RdpAccountant`` unchanged (one privatized flat upload per exchange).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, ClassVar, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.barycenter import family_barycenter
from repro.core.family import eps_shape as family_eps_shape
from repro.kernels import wire as wire_kernels
from repro.optim.base import apply_updates

PyTree = Any

DEFAULT_STRATEGY = "sfvi"


# ---------------------------------------------------------------------------
# Shared-randomness helpers (canonical definitions; runtime re-exports them
# so tests can replay the exact draws)
# ---------------------------------------------------------------------------


def global_eps(problem, round_key: jnp.ndarray, t) -> jnp.ndarray:
    """ε_G for local step ``t`` of a round — identical on every silo."""
    return jax.random.normal(
        jax.random.fold_in(round_key, t),
        family_eps_shape(problem.global_family),
    )


def silo_eps(problem, round_key: jnp.ndarray, t, silo_id):
    """ε_{L_j} for local step ``t`` on silo ``silo_id`` (None if Z_L = ∅)."""
    if not problem.model.has_local:
        return None
    key = jax.random.fold_in(jax.random.fold_in(round_key, 100_003 + t), silo_id)
    return jax.random.normal(key, family_eps_shape(problem.local_family))


def _neg(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda x: -x, tree)


def _add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def _sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def _select(keep, new: PyTree, old: PyTree) -> PyTree:
    """Per-leaf ``where`` that preserves dtypes (masked silo-state update)."""
    return jax.tree_util.tree_map(lambda n, o: jnp.where(keep, n, o), new, old)


def _stop(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jax.lax.stop_gradient, tree)


# ---------------------------------------------------------------------------
# Diagonal-Gaussian natural parameters (PVI / EP site algebra)
# ---------------------------------------------------------------------------


def natural_from_eta(family, eta: PyTree) -> Dict[str, jnp.ndarray]:
    """η → natural parameters {h = Σ⁻¹μ, prec = Σ⁻¹} (diag form).

    Uses the family's moment bridge, so any ``moment_form == "diag"``
    family (DiagGaussian, BatchedDiagGaussian, ...) participates without
    knowing about PVI.
    """
    mu, sigma = family.to_moments(eta)
    prec = 1.0 / (sigma * sigma)
    return {"h": mu * prec, "prec": prec}


def eta_from_natural(
    family, nat: Dict[str, jnp.ndarray], prec_floor: float = 1e-6
) -> PyTree:
    """Natural parameters → η, with the precision floored for validity.

    Damped-delta and cavity subtractions can transiently drive a
    precision nonpositive; flooring keeps the resulting distribution
    proper (standard PVI practice) without touching the fixed point,
    where precisions are strictly positive.
    """
    prec = jnp.maximum(nat["prec"], prec_floor)
    sigma = prec ** -0.5
    mu = nat["h"] / prec
    return family.from_moments(mu, sigma)


# ---------------------------------------------------------------------------
# The protocol
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StrategyContext:
    """Static per-body facts the runtime hands every strategy hook.

    Frozen (hashable) so it rides into jitted closures as a constant.
    ``wire`` is the flat :class:`~repro.core.flatten.TreeSpec` bijection
    of one upload (None on the legacy per-leaf wire); ``shipped`` values
    passed to :meth:`ServerStrategy.server_update` are ``(J, P)``
    matrices exactly when ``wire is not None``.
    """

    problem: Any
    J: int
    K: int
    server_opt: Any
    local_opt: Any
    has_local: bool
    eta_mode: str
    aggregator: Any
    wire: Any
    fused: bool
    total_obs: float


class ServerStrategy:
    """Base class for pluggable server-side update rules.

    Subclasses are frozen dataclasses (their fields are the strategy's
    hyperparameters, e.g. PVI's ``damping``) registered by name via
    :func:`register_strategy`. Capability flags:

    ``cadence``
        ``"step"`` — one gather per local optimizer step (implement
        :meth:`silo_step` / :meth:`server_step`); ``"round"`` — K local
        steps then one gather (implement :meth:`local_run` /
        :meth:`server_update`).
    ``has_silo_state``
        True when the strategy carries per-silo state beyond η_{L_j}
        (e.g. PVI's site approximations λ_j). The runtime stacks it on
        the silo axis, shards it through the round graph next to
        ``eta_L``, and the checkpoint layer rides it on the per-silo
        shards — so save/resume of strategy state is bit-exact for free.
    ``wire_reference``
        What a silo's upload is measured against on the wire:
        ``"zero"`` — ships an absolute quantity; DP privatizes the raw
        tree and non-participants ship zeros. ``"broadcast"`` — ships
        parameters; DP privatizes the delta from the round's public
        broadcast and non-participants ship the broadcast itself. Both
        keep every wire row data-independent for unsampled silos, which
        is what makes the accountant's subsampling amplification sound.
    """

    name: ClassVar[str] = ""
    cadence: ClassVar[str] = "round"
    has_silo_state: ClassVar[bool] = False
    wire_reference: ClassVar[str] = "zero"

    # -- identity ------------------------------------------------------------

    def cache_key(self) -> tuple:
        """Hashable identity for the runtime's compiled-round cache."""
        return (self.name,) + tuple(
            sorted(dataclasses.asdict(self).items())  # type: ignore[call-overload]
        )

    # -- capability / wiring hooks ------------------------------------------

    def validate(self, server) -> None:
        """Raise if the server's configuration cannot host this strategy."""

    def ship_template(self, server) -> PyTree:
        """Shape-only pytree of one silo's upload (pre-compression)."""
        raise NotImplementedError

    def reference_tree(self, ctx: StrategyContext, theta, eta_G):
        """The wire reference (see ``wire_reference``); None means zeros."""
        if self.wire_reference == "broadcast":
            return {"theta": theta, "eta_G": eta_G}
        return None

    def init_silo_state(self, server) -> PyTree:
        """Initial stacked (J_pad, ...) strategy state ({} if stateless)."""
        return {}

    # -- cadence == "step" hooks --------------------------------------------

    def silo_step(
        self, ctx, theta, eta_G, eta_Lj, opt_Lj, state_j,
        data_j, sid, m_j, n_obs_j, round_key, t, eps_G,
    ) -> Tuple[PyTree, PyTree, PyTree, PyTree, jnp.ndarray]:
        """One silo's work for one synchronized step.

        Returns ``(eta_Lj, opt_Lj, state_j, ship_tree, hatLj)``; the
        runtime packs/privatizes/masks/encodes ``ship_tree`` and gathers.
        """
        raise NotImplementedError

    def server_step(
        self, ctx, theta, eta_G, opt_server, mean_tree,
        hatL_sum, n_active, eps_G,
    ) -> Tuple[PyTree, PyTree, PyTree, jnp.ndarray]:
        """Fold one gathered aggregate into the server state.

        ``mean_tree`` is the aggregator's mean-like combine of the
        decoded uploads, unpacked back to ship_template structure.
        Returns ``(theta, eta_G, opt_server, elbo)``.
        """
        raise NotImplementedError

    # -- cadence == "round" hooks -------------------------------------------

    def local_run(
        self, ctx, theta, eta_G, eta_Lj, opt_Lj, state_j,
        data_j, sid, m_j, n_obs_j, round_key,
    ) -> Tuple[PyTree, PyTree, PyTree, PyTree, jnp.ndarray]:
        """One silo's K local steps for a round-cadence strategy.

        Returns ``(eta_Lj, opt_Lj, state_j, ship_tree, elbos)`` with
        ``elbos`` shaped (K,).
        """
        raise NotImplementedError

    def server_update(
        self, ctx, theta, eta_G, opt_server, combined, shipped,
        w_full, n_active,
    ) -> Tuple[PyTree, PyTree, PyTree]:
        """Merge the round's gathered uploads into the server state.

        ``combined`` is the aggregator's combine unpacked to
        ship_template structure; ``shipped`` is the full decoded stack
        ((J, P) matrix on the flat/fused wire, stacked pytree on the
        legacy wire) for strategies that need every silo's upload (the
        barycenter). Returns ``(theta, eta_G, opt_server)``.
        """
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Registry + spec
# ---------------------------------------------------------------------------

STRATEGIES: Dict[str, type] = {}


def register_strategy(name: str) -> Callable[[type], type]:
    """Class decorator: register a ServerStrategy subclass under ``name``."""

    def wrap(cls: type) -> type:
        if name in STRATEGIES:
            raise ValueError(f"strategy {name!r} already registered")
        cls.name = name
        STRATEGIES[name] = cls
        return cls

    return wrap


def get_strategy(name: str) -> type:
    """Look up a registered strategy class by name."""
    try:
        return STRATEGIES[name]
    except KeyError:
        raise KeyError(
            f"unknown strategy {name!r}; registered: {sorted(STRATEGIES)}"
        ) from None


def strategy_names() -> Tuple[str, ...]:
    """Names of all registered strategies (sorted)."""
    return tuple(sorted(STRATEGIES))


def resolve_strategy(algorithm) -> ServerStrategy:
    """Name / spec / instance → a ServerStrategy instance."""
    if isinstance(algorithm, ServerStrategy):  # repro-lint: allow[R6] — registry front door: input-KIND dispatch (instance | spec | name), not a capability probe
        return algorithm
    if isinstance(algorithm, StrategySpec):  # repro-lint: allow[R6] — registry front door: input-kind dispatch, see above
        return algorithm.build()
    return get_strategy(algorithm)()


@dataclasses.dataclass
class StrategySpec:
    """Serializable handle for a registry strategy (mirrors FamilySpec).

    ``kwargs`` feed the strategy dataclass's hyperparameter fields, e.g.
    ``StrategySpec("pvi", {"damping": 0.2})``.
    """

    name: str = DEFAULT_STRATEGY
    kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def build(self) -> ServerStrategy:
        """Instantiate the registered strategy with this spec's kwargs."""
        cls = get_strategy(self.name)
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(self.kwargs) - fields
        if unknown:
            raise ValueError(
                f"strategy {self.name!r} got unknown kwargs {sorted(unknown)}; "
                f"accepted: {sorted(fields)}"
            )
        return cls(**self.kwargs)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> StrategySpec:
        return StrategySpec(
            name=d.get("name", DEFAULT_STRATEGY),
            kwargs=dict(d.get("kwargs", {})),
        )


# ---------------------------------------------------------------------------
# The paper's two algorithms as registry entries (bit-exact re-expressions
# of the pre-refactor round bodies — the equivalence suite in
# tests/test_strategies.py holds them to the frozen legacy Server)
# ---------------------------------------------------------------------------


@register_strategy("sfvi")
@dataclasses.dataclass(frozen=True)
class SFVIStrategy(ServerStrategy):
    """Paper Algorithm 1: synchronize (g_j^θ, g_j^η) every local step."""

    cadence: ClassVar[str] = "step"
    has_silo_state: ClassVar[bool] = False
    wire_reference: ClassVar[str] = "zero"

    def ship_template(self, server) -> PyTree:
        return {"g_theta": server.state["theta"], "g_eta": server.state["eta_G"]}

    def silo_step(self, ctx, theta, eta_G, eta_Lj, opt_Lj, state_j,
                  data_j, sid, m_j, n_obs_j, round_key, t, eps_G):
        problem = ctx.problem
        el = eta_Lj if ctx.has_local else None
        eps_L = silo_eps(problem, round_key, t, sid)
        g_th, g_eta, g_loc, hatLj = problem.silo_grads(
            theta, eta_G, el, eps_G, eps_L, data_j
        )
        if ctx.has_local:
            upd, new_opt = ctx.local_opt.update(_neg(g_loc), opt_Lj, el)
            eta_Lj = _select(m_j > 0.5, apply_updates(el, upd), el)
            opt_Lj = _select(m_j > 0.5, new_opt, opt_Lj)
        return eta_Lj, opt_Lj, state_j, {"g_theta": g_th, "g_eta": g_eta}, hatLj

    def server_step(self, ctx, theta, eta_G, opt_server, mean_tree,
                    hatL_sum, n_active, eps_G):
        # J × mean over active = (J/|A|) Σ_active — the unbiased
        # partial-participation estimator of Σ_j (§3 Remark). Scaling
        # after the unpack is bit-identical to scaling the packed
        # vector (elementwise ops commute with reshape/slice).
        J = float(ctx.J)
        g_sum = jax.tree_util.tree_map(lambda x: x * J, mean_tree)
        g_th0, g_eta0, hatL0 = ctx.problem.server_grads(theta, eta_G, eps_G)
        g = {
            "theta": _add(g_sum["g_theta"], g_th0),
            "eta_G": _add(g_sum["g_eta"], g_eta0),
        }
        params = {"theta": theta, "eta_G": eta_G}
        updates, opt_server = ctx.server_opt.update(_neg(g), opt_server, params)
        merged = apply_updates(params, updates)
        elbo = hatL0 + (J / n_active) * hatL_sum
        return merged["theta"], merged["eta_G"], opt_server, elbo


@register_strategy("sfvi_avg")
@dataclasses.dataclass(frozen=True)
class SFVIAvgStrategy(ServerStrategy):
    """§3.2: K local VI steps on the N/N_j-rescaled objective, one merge.

    Ships locally-updated (θ^(j), η_G^(j)); the server FedAvgs θ and
    merges η_G by moment barycenter (or parameter mean, per the server's
    ``eta_mode``).
    """

    cadence: ClassVar[str] = "round"
    has_silo_state: ClassVar[bool] = False
    wire_reference: ClassVar[str] = "broadcast"

    def ship_template(self, server) -> PyTree:
        return {"theta": server.state["theta"], "eta_G": server.state["eta_G"]}

    def local_run(self, ctx, theta, eta_G, eta_Lj, opt_Lj, state_j,
                  data_j, sid, m_j, n_obs_j, round_key):
        problem = ctx.problem
        scale = ctx.total_obs / n_obs_j  # §3.2 point 2: N / N_j
        el0 = eta_Lj if ctx.has_local else None
        s_state = ctx.server_opt.init({"theta": theta, "eta_G": eta_G})

        def local_step(carry, t):
            th, eg, el, s_st, l_st = carry
            eps_G = global_eps(problem, round_key, t)
            eps_L = silo_eps(problem, round_key, t, sid)

            def objective(th_, eg_, el_):
                val = problem.hat_L0(th_, eg_, eps_G)
                return val + problem.hat_Lj(
                    th_, eg_, el_, eps_G, eps_L, data_j, scale
                )

            if ctx.has_local:
                val, (g_th, g_eg, g_el) = jax.value_and_grad(
                    objective, argnums=(0, 1, 2)
                )(th, eg, el)
                upd_l, l_st = ctx.local_opt.update(_neg(g_el), l_st, el)
                el = apply_updates(el, upd_l)
            else:
                val, (g_th, g_eg) = jax.value_and_grad(
                    lambda a, b: objective(a, b, None), argnums=(0, 1)
                )(th, eg)
            params = {"theta": th, "eta_G": eg}
            upd_s, s_st = ctx.server_opt.update(
                _neg({"theta": g_th, "eta_G": g_eg}), s_st, params
            )
            merged = apply_updates(params, upd_s)
            return (merged["theta"], merged["eta_G"], el, s_st, l_st), val

        carry = (theta, eta_G, el0, s_state, opt_Lj)
        (th, eg, el, _, l_st), elbos = jax.lax.scan(
            local_step, carry, jnp.arange(ctx.K)
        )
        if ctx.has_local:
            eta_Lj = _select(m_j > 0.5, el, el0)
            opt_Lj = _select(m_j > 0.5, l_st, opt_Lj)
        return eta_Lj, opt_Lj, state_j, {"theta": th, "eta_G": eg}, elbos

    def server_update(self, ctx, theta, eta_G, opt_server, combined,
                      shipped, w_full, n_active):
        theta_new = combined["theta"]
        if ctx.eta_mode == "param":
            eta_new = combined["eta_G"]
        else:
            # W2 barycenter in moment space, generic over the family's
            # moment bridge (the fused wire plugs in the fused
            # Newton–Schulz step kernel for full-covariance families).
            if ctx.wire is not None:
                eta_shipped = jax.vmap(
                    lambda v: ctx.wire.unpack(v)["eta_G"]
                )(shipped)
            else:
                eta_shipped = shipped["eta_G"]
            sqrtm_kw = (
                {"sqrtm": wire_kernels.sqrtm_newton_schulz_fused}
                if ctx.fused else {})
            eta_new = family_barycenter(
                ctx.problem.global_family, eta_shipped, w_full,
                ctx.aggregator, **sqrtm_kw)
        return theta_new, eta_new, opt_server


# ---------------------------------------------------------------------------
# Partitioned VI and federated EP: damped natural-parameter deltas
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _NaturalDeltaStrategy(ServerStrategy):
    """Shared machinery for PVI and federated EP.

    Both maintain per-silo site approximations λ_j in natural-parameter
    space with q_G ∝ p(Z_G) Π_j exp⟨λ_j, T(Z_G)⟩, refine silo j's site
    against the cavity q_G^{\\j} ∝ q_G / exp⟨λ_j, ·⟩ by local VI on the
    tilted objective, and ship the natural-parameter delta
    Δ_j = λ(q_j) − λ(q_G); the server applies the damped sum
    λ(q_G) ← λ(q_G) + ρ Σ_j Δ_j and each silo folds ρ Δ_j into its own
    λ_j. They differ only in where the local VI starts (see subclasses):
    same fixed points, genuinely different finite-K trajectories.

    θ (the model's point parameters) is updated FedAvg-style alongside:
    silos ship θ^(j) − θ and the server applies ρ × the aggregated mean.

    Requires a ``moment_form == "diag"`` global family — the site
    algebra runs through the family's moment bridge.
    """

    damping: float = 0.25
    prec_floor: float = 1e-6

    cadence: ClassVar[str] = "round"
    has_silo_state: ClassVar[bool] = True
    wire_reference: ClassVar[str] = "zero"
    # Where silo j's local VI over η starts: "posterior" (PVI — damped
    # delta from the current broadcast) or "cavity" (EP — refine the
    # site from scratch against the cavity).
    local_init: ClassVar[str] = "posterior"

    def validate(self, server) -> None:
        fam = server.problem.global_family
        if getattr(fam, "moment_form", None) != "diag":
            raise ValueError(
                f"strategy {self.name!r} needs a moment_form='diag' global "
                f"family (DiagGaussian, BatchedDiagGaussian, ...); got "
                f"{type(fam).__name__}"
            )

    def _nat_template(self, server) -> Dict[str, jnp.ndarray]:
        return natural_from_eta(
            server.problem.global_family, server.state["eta_G"]
        )

    def ship_template(self, server) -> PyTree:
        return {"theta": server.state["theta"],
                "eta": self._nat_template(server)}

    def init_silo_state(self, server) -> PyTree:
        """λ_j = 0 for every silo: q_G starts as the unrefined prior fit."""
        nat = self._nat_template(server)
        return {
            "lam": jax.tree_util.tree_map(
                lambda x: jnp.zeros((server.J_pad,) + x.shape, x.dtype), nat
            )
        }

    def local_run(self, ctx, theta, eta_G, eta_Lj, opt_Lj, state_j,
                  data_j, sid, m_j, n_obs_j, round_key):
        problem = ctx.problem
        fam = problem.global_family
        lam = state_j["lam"]
        nat_G = natural_from_eta(fam, eta_G)
        cav_eta = eta_from_natural(fam, _sub(nat_G, lam), self.prec_floor)
        init_eta = eta_G if self.local_init == "posterior" else cav_eta
        el0 = eta_Lj if ctx.has_local else None
        s_state = ctx.server_opt.init({"theta": theta, "eta_G": init_eta})

        def local_step(carry, t):
            th, eg, el, s_st, l_st = carry
            eps_G = global_eps(problem, round_key, t)
            eps_L = silo_eps(problem, round_key, t, sid)

            def objective(th_, eg_, el_):
                # Tilted local ELBO: E_q[log q_cav(Z_G)] + H(q) replaces
                # hat_L0's prior/entropy pair — the cavity is silo j's
                # effective prior. STL-stopped log q, like hat_L0.
                z_G = fam.sample(eg_, eps_G)
                val = fam.log_prob(cav_eta, z_G) - fam.log_prob(
                    _stop(eg_), z_G
                )
                return val + problem.hat_Lj(
                    th_, eg_, el_, eps_G, eps_L, data_j, 1.0
                )

            if ctx.has_local:
                val, (g_th, g_eg, g_el) = jax.value_and_grad(
                    objective, argnums=(0, 1, 2)
                )(th, eg, el)
                upd_l, l_st = ctx.local_opt.update(_neg(g_el), l_st, el)
                el = apply_updates(el, upd_l)
            else:
                val, (g_th, g_eg) = jax.value_and_grad(
                    lambda a, b: objective(a, b, None), argnums=(0, 1)
                )(th, eg)
            params = {"theta": th, "eta_G": eg}
            upd_s, s_st = ctx.server_opt.update(
                _neg({"theta": g_th, "eta_G": g_eg}), s_st, params
            )
            merged = apply_updates(params, upd_s)
            return (merged["theta"], merged["eta_G"], el, s_st, l_st), val

        carry = (theta, init_eta, el0, s_state, opt_Lj)
        (th, eg, el, _, l_st), elbos = jax.lax.scan(
            local_step, carry, jnp.arange(ctx.K)
        )
        if ctx.has_local:
            eta_Lj = _select(m_j > 0.5, el, el0)
            opt_Lj = _select(m_j > 0.5, l_st, opt_Lj)
        # Site delta Δ_j = λ(q_j) − λ(q_G): identical for both inits
        # (λ_j^new − λ_j = [λ(q_j) − cav] − λ_j = λ(q_j) − λ(q_G)).
        delta_nat = _sub(natural_from_eta(fam, eg), nat_G)
        delta_th = _sub(th, theta)
        # The silo folds the CLEAN damped delta into its own site; the
        # server only ever sees the privatized aggregate (the DP-PVI
        # convention: local state is exact, the wire is noised).
        new_lam = jax.tree_util.tree_map(
            lambda val, d: val + self.damping * d, lam, delta_nat
        )
        state_j = {"lam": _select(m_j > 0.5, new_lam, lam)}
        ship = {"theta": delta_th, "eta": delta_nat}
        return eta_Lj, opt_Lj, state_j, ship, elbos

    def server_update(self, ctx, theta, eta_G, opt_server, combined,
                      shipped, w_full, n_active):
        fam = ctx.problem.global_family
        rho = self.damping
        # θ: damped FedAvg of the per-silo moves (mean over active).
        theta_new = jax.tree_util.tree_map(
            lambda p, d: p + rho * d, theta, combined["theta"]
        )
        # η_G: the posterior is the product of sites, so the update is
        # the damped SUM of deltas — n_active × the aggregated mean.
        nat_G = natural_from_eta(fam, eta_G)
        nat_new = jax.tree_util.tree_map(
            lambda n, d: n + rho * n_active * d, nat_G, combined["eta"]
        )
        eta_new = eta_from_natural(fam, nat_new, self.prec_floor)
        return theta_new, eta_new, opt_server


@register_strategy("pvi")
@dataclasses.dataclass(frozen=True)
class PVIStrategy(_NaturalDeltaStrategy):
    """Partitioned Variational Inference (Ashman et al., 2202.12275).

    Local VI starts at the current broadcast posterior, so each silo
    computes a small refinement against its cavity and the exchange is a
    damped natural-parameter *delta step*. ``damping=0`` is an exact
    fixed point (nothing moves) — the sanity anchor in the tests.
    """

    local_init: ClassVar[str] = "posterior"


@register_strategy("fed_ep")
@dataclasses.dataclass(frozen=True)
class FedEPStrategy(_NaturalDeltaStrategy):
    """Federated EP-style site refinement (Guo et al., 2302.04228).

    Identical site algebra to PVI, but each silo re-derives its site
    from scratch: local VI starts at the CAVITY (the posterior with the
    silo's own site removed), the classic EP refinement view. Same
    fixed points as PVI; different finite-K trajectories — at the fixed
    point the tilted optimum equals the posterior either way.
    """

    local_init: ClassVar[str] = "cavity"
