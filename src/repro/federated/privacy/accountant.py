"""RDP (moments) accountant for the federated Gaussian mechanism.

Tracks the cumulative Rényi differential privacy of a sequence of
(sub)sampled-Gaussian-mechanism invocations — one per silo→server
exchange of the DP round — and converts to (ε, δ) on demand. Pure
numpy/host-side: accounting runs *outside* the compiled round (the
mechanism itself lives in :mod:`repro.federated.privacy.policy`), so it
adds zero graph cost.

Formulas (all standard):

  * Gaussian mechanism, no subsampling (q = 1), Mironov (2017) Prop. 7:
        RDP(α) = α / (2 σ²)            for any order α > 1.
  * Poisson-subsampled Gaussian at integer orders α, the exact
    expression of Mironov, Talwar & Zhang (2019), Thm. 5 — identical to
    tensorflow-privacy's ``_compute_log_a_int``:
        RDP(α) = 1/(α−1) · log Σ_{k=0..α} C(α,k) (1−q)^{α−k} q^k
                                          · exp(k(k−1) / (2σ²)).
  * Composition is additive per order (RDP's raison d'être).
  * Conversion, Mironov (2017) Prop. 3:
        ε(δ) = min_α [ RDP(α) + log(1/δ) / (α−1) ].

The default order grid is integers (exact at q < 1; fractional orders
would need the quadrature bound of Mironov et al. §3.3, which never
changes the minimum by much on this grid). The subsampling bound assumes
Poisson sampling; the :class:`~repro.federated.scheduler.RoundScheduler`
invites a fixed-size uniform subset, for which the Poisson-q bound is
the standard (slightly optimistic in δ, standard-practice) surrogate —
see docs/privacy.md for the threat model and this caveat.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

# Integer orders: dense where the optimum usually lands, sparse tail for
# very private / very subsampled regimes.
DEFAULT_ORDERS: Tuple[int, ...] = tuple(range(2, 65)) + (
    72, 80, 96, 128, 160, 192, 256, 384, 512,
)


def _log_comb(n: int, k: int) -> float:
    return (
        math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1)
    )


def _logsumexp(xs: Sequence[float]) -> float:
    m = max(xs)
    if m == -math.inf:
        return -math.inf
    return m + math.log(sum(math.exp(x - m) for x in xs))


def rdp_sampled_gaussian(
    q: float, noise_multiplier: float, orders: Sequence[int]
) -> np.ndarray:
    """Per-order RDP of ONE sampled-Gaussian invocation.

    Args:
      q: sampling rate in (0, 1]; 1 means every silo participates.
      noise_multiplier: σ, the noise std in units of the clip norm.
      orders: integer RDP orders (α ≥ 2).

    Returns ``float64`` array of RDP values, one per order (``inf`` when
    σ = 0: no noise means no RDP guarantee).
    """
    if not (0.0 < q <= 1.0):
        raise ValueError(f"sampling rate must be in (0, 1], got {q}")
    if noise_multiplier < 0:
        raise ValueError(f"noise_multiplier must be >= 0, got {noise_multiplier}")
    out = np.empty(len(orders), np.float64)
    if noise_multiplier == 0.0:
        out.fill(math.inf)
        return out
    s2 = float(noise_multiplier) ** 2
    for i, alpha in enumerate(orders):
        a = int(alpha)
        if a != alpha or a < 2:
            raise ValueError(f"orders must be integers >= 2, got {alpha}")
        if q == 1.0:
            out[i] = a / (2.0 * s2)
            continue
        terms = [
            _log_comb(a, k)
            + (a - k) * math.log1p(-q)
            + (k * math.log(q) if k else 0.0)
            + k * (k - 1) / (2.0 * s2)
            for k in range(a + 1)
        ]
        out[i] = _logsumexp(terms) / (a - 1)
    return out


def rdp_to_epsilon(
    rdp: np.ndarray, orders: Sequence[int], delta: float
) -> Tuple[float, int]:
    """(ε, best order) from a per-order RDP curve at target δ."""
    if not (0.0 < delta < 1.0):
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    orders = np.asarray(orders, np.float64)
    eps = np.asarray(rdp, np.float64) + math.log(1.0 / delta) / (orders - 1.0)
    i = int(np.argmin(eps))
    return float(eps[i]), int(orders[i])


@dataclasses.dataclass
class RdpAccountant:
    """Composes sampled-Gaussian rounds; reports cumulative (ε, δ).

    One accountant instance rides one federation (the ``Server`` owns
    it): every DP exchange calls :meth:`step`, and :meth:`epsilon` can
    be read at any time — per round for the history trace, once at the
    end for the headline number.
    """

    orders: Sequence[int] = DEFAULT_ORDERS

    def __post_init__(self):
        self._rdp = np.zeros(len(self.orders), np.float64)
        self._steps = 0

    @property
    def steps(self) -> int:
        """Number of mechanism invocations composed so far."""
        return self._steps

    @property
    def rdp(self) -> np.ndarray:
        """Cumulative per-order RDP curve (copy)."""
        return self._rdp.copy()

    def step(
        self,
        *,
        noise_multiplier: float,
        sampling_rate: float = 1.0,
        steps: int = 1,
    ) -> None:
        """Compose ``steps`` invocations at (σ, q) into the running total."""
        if steps < 0:
            raise ValueError(f"steps must be >= 0, got {steps}")
        if steps == 0:
            return
        self._rdp += steps * rdp_sampled_gaussian(
            sampling_rate, noise_multiplier, self.orders
        )
        self._steps += steps

    def state_dict(self) -> Dict[str, object]:
        """Serializable ledger: cumulative per-order RDP + step count.

        Checkpointed by ``federated.api.Experiment.save`` so a resumed
        run keeps composing on top of the pre-interruption privacy loss
        instead of restarting the ledger at ε = 0.
        """
        return {"rdp": self._rdp.copy(), "steps": self._steps}

    def load_state(self, state: Dict[str, object]) -> None:
        """Restore a ledger saved by :meth:`state_dict`."""
        rdp = np.asarray(state["rdp"], np.float64)
        if rdp.shape != self._rdp.shape:
            raise ValueError(
                f"ledger has {rdp.shape[0]} orders, accountant expects "
                f"{self._rdp.shape[0]} — order grids must match"
            )
        self._rdp = rdp.copy()
        self._steps = int(state["steps"])

    def epsilon(self, delta: float) -> Tuple[float, int]:
        """Cumulative (ε, optimal order) at target ``delta``."""
        if self._steps == 0:
            return 0.0, int(self.orders[0])
        return rdp_to_epsilon(self._rdp, self.orders, delta)

    def summary(self, delta: float) -> Dict[str, float]:
        """Flat dict for logs/benchmarks: ε, δ, steps, argmin order."""
        eps, order = self.epsilon(delta)
        return {
            "epsilon": eps,
            "delta": delta,
            "mechanism_steps": float(self._steps),
            "rdp_order": float(order),
        }
