"""Differentially private federated rounds (docs/privacy.md).

Two halves, split by where they run:

  * :class:`PrivacyPolicy` — the mechanism. Pure jax clip-and-noise of
    one silo upload, executed *inside* the compiled round (before the
    compression hook and the cross-silo ``all_gather``), so the wire
    carries already-privatized bytes.
  * :class:`RdpAccountant` — the ledger. Host-side RDP composition of
    every (subsampled) Gaussian exchange, converted to (ε, δ) per round
    and cumulatively.

``Server(..., privacy=PrivacyPolicy(...))`` wires both up; the CLI
exposes them as ``--dp-clip / --dp-noise / --dp-delta``.
"""
from repro.federated.privacy.accountant import (
    DEFAULT_ORDERS,
    RdpAccountant,
    rdp_sampled_gaussian,
    rdp_to_epsilon,
)
from repro.federated.privacy.policy import PrivacyPolicy

__all__ = [
    "DEFAULT_ORDERS",
    "PrivacyPolicy",
    "RdpAccountant",
    "rdp_sampled_gaussian",
    "rdp_to_epsilon",
]
