"""Per-silo differential privacy mechanism for federated uploads.

The unit of privacy is the silo→server message of one exchange (a
gradient pytree for SFVI, a locally-updated parameter pytree for
SFVI-Avg) — the same client→server surface that partitioned VI hardens
in Heikkilä et al. (2022) and that PVI (Ashman et al., 2022) frames as
the natural thing to clip and noise. :class:`PrivacyPolicy` implements
the Gaussian mechanism on that message *inside* the compiled round:

  1. the shipped pytree (or its delta from the round's public broadcast,
     for parameter uploads) is clipped to global L2 norm ``clip_norm``;
  2. i.i.d. Gaussian noise with per-coordinate std
     ``noise_multiplier * clip_norm`` is added, drawn from a PRNG key
     folded per (round, local step, silo) so every silo's noise is
     independent yet fully replayable from the round key;
  3. only then does the compression hook and the cross-silo
     ``all_gather`` run — the wire carries already-privatized bytes, so
     an honest-but-curious server (or wire observer) never sees a raw
     silo message.

All methods are pure jax functions over ANY pytree: the runtime's flat
wire format hands the mechanism one packed ``(P,)`` vector per silo
(``core.flatten.TreeSpec``), so the clip is a single norm and the noise
a single Gaussian draw — no per-leaf tree_map on the hot path (the
per-leaf fold-in below still applies verbatim to multi-leaf trees, e.g.
the legacy wire). The mechanism lives in the same ``shard_map`` graph
as the round itself (verified by ``Server.compiled_collective_bytes`` /
the one-``all_gather``-per-wire-dtype HLO tests).
Accounting lives in :mod:`repro.federated.privacy.accountant`; the
threat model is spelled out in ``docs/privacy.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any

# Fold-in tag separating DP noise draws from the runtime's ε_G / ε_{L_j}
# streams (which use offsets 0 and 100_003 of the same round key).
_DP_SALT = 777_013


@dataclasses.dataclass(frozen=True)
class PrivacyPolicy:
    """Clip-and-noise policy for one silo upload.

    Attributes:
      clip_norm: L2 bound C applied to the uploaded pytree (its global
        norm across all leaves). This is the mechanism's sensitivity
        under ADD/REMOVE adjacency — the DP-FedAvg convention the
        accountant (σ = z·C) assumes: a silo's contribution is either
        its clipped upload (norm ≤ C) or the data-independent zero
        upload the runtime ships for non-participants, so presence vs
        absence moves the gathered sum by at most C. (Replace-one-silo
        adjacency would double the sensitivity; account it by halving
        ``noise_multiplier``.)
      noise_multiplier: z — per-coordinate noise std is ``z * C``. Zero
        disables noising (clipping still applies), which is useful for
        isolating the utility cost of clipping alone.
      delta: target δ for (ε, δ) reports; threaded to the accountant by
        the runtime, not used by the mechanism itself.
    """

    clip_norm: float = 1.0
    noise_multiplier: float = 1.0
    delta: float = 1e-5

    def __post_init__(self):
        if self.clip_norm <= 0:
            raise ValueError(f"clip_norm must be > 0, got {self.clip_norm}")
        if self.noise_multiplier < 0:
            raise ValueError(
                f"noise_multiplier must be >= 0, got {self.noise_multiplier}"
            )
        if not (0.0 < self.delta < 1.0):
            raise ValueError(f"delta must be in (0, 1), got {self.delta}")

    # -- mechanism pieces (each jittable) -----------------------------------

    def global_norm(self, tree: PyTree) -> jnp.ndarray:
        """Global L2 norm over every leaf of ``tree`` (0 for empty trees)."""
        leaves = jax.tree_util.tree_leaves(tree)
        if not leaves:
            return jnp.zeros(())
        return jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in leaves))

    def clip(self, tree: PyTree) -> PyTree:
        """Scale ``tree`` so its global L2 norm is at most ``clip_norm``."""
        norm = self.global_norm(tree)
        factor = jnp.minimum(1.0, self.clip_norm / jnp.maximum(norm, 1e-12))
        return jax.tree_util.tree_map(lambda x: x * factor, tree)

    def noise(self, tree: PyTree, key: jnp.ndarray) -> PyTree:
        """Fresh N(0, (z·C)²) per coordinate; one folded subkey per leaf."""
        std = self.noise_multiplier * self.clip_norm
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        noised = [
            x + std * jax.random.normal(jax.random.fold_in(key, i), x.shape, x.dtype)
            for i, x in enumerate(leaves)
        ]
        return jax.tree_util.tree_unflatten(treedef, noised)

    def privatize(
        self, tree: PyTree, key: jnp.ndarray, reference: Optional[PyTree] = None
    ) -> PyTree:
        """Clip-and-noise ``tree`` (or its delta from ``reference``).

        ``reference`` handles parameter uploads (SFVI-Avg): the round's
        broadcast (θ, η_G) is public to the server, so the private
        quantity is the silo's *update* — the delta is clipped, noised,
        and added back so the wire format stays a parameter pytree and
        the downstream aggregator is untouched.
        """
        if reference is not None:
            delta = jax.tree_util.tree_map(jnp.subtract, tree, reference)
            priv = self.noise(self.clip(delta), key)
            return jax.tree_util.tree_map(jnp.add, reference, priv)
        return self.noise(self.clip(tree), key)

    def upload_key(
        self, round_key: jnp.ndarray, step: Any, silo_id: Any
    ) -> jnp.ndarray:
        """Noise key for (round, local step, silo) — disjoint from the
        runtime's shared-randomness streams via ``_DP_SALT``."""
        k = jax.random.fold_in(round_key, _DP_SALT)
        return jax.random.fold_in(jax.random.fold_in(k, step), silo_id)
