"""Dynamic silo populations: arrivals, departures, stale returns.

The paper's federation is a fixed set of J silos; a production
federation is not — silos join mid-run, go offline, and come back
stale. This module layers a deterministic population process over the
compiled round engine (:class:`~repro.federated.runtime.Server`) and
the buffered-async event loop (:mod:`~repro.federated.async_engine`):

  * **join** — a cold silo enters the federation. Its data shard is
    appended to the stacked silo axis (``Server.grow_silos``; the
    padded ``(J_pad, P)`` wire grows in mesh-sized chunks, so the
    compiled round graph only retraces when ``J_pad`` actually steps)
    and its ``η_L`` is *warm-started* through the amortized encoder of
    :mod:`repro.core.amortized`: the silo encodes its own observations
    into an initial mean/scale instead of burning rounds of cold
    optimization. PVI's continual-learning view (Bui et al.,
    1811.11206) is the correctness anchor: the joining silo's site
    state initializes at zero (its cavity is the current global
    posterior), so the site-sum invariant is preserved.
  * **depart** — the silo's participation mask goes to zero. Its
    ``η_L``, optimizer moments and per-silo strategy state (PVI/FedEP
    site λ_j) stay in place, frozen by the mask — a departure deletes
    nothing, exactly as PVI's frozen-site semantics require.
  * **return** — the silo re-enters with a staleness counter (rounds
    absent on the sync path; server versions elapsed since its pull on
    the async path) that feeds the existing FedBuff weighting
    ``(1 + staleness)^-decay``.

Every event is a pure function of ``(population seed, event index,
silo)`` — no RNG state to checkpoint — and the tiny mutable remainder
(:class:`PopulationState`) round-trips losslessly through JSON, so a
churn run checkpoints and resumes **bit-exactly**, mid-event included
(``tests/test_population.py``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import amortized

PyTree = Any

# Salt for the population event stream: distinct from the async
# latency stream (0x5AF0) and the jax PRNG folds of the user seed, so
# arrival draws can never collide with latency or noise draws.
_POP_SALT = 0x9D07

# Sub-stream codes per event kind (part of the SeedSequence entropy).
_ARRIVAL, _DEPART, _RETURN = 0, 1, 2

# Silo lifecycle codes (PopulationState.status).
ACTIVE, DEPARTED = 1, 2


def event_draw(pop_seed: int, kind: int, index: int, silo: int) -> float:
    """U(0,1) draw for one (event kind, round/flush index, silo) cell.

    A pure function — NumPy's ``SeedSequence`` hashing makes it
    reproducible across runs, platforms and resume boundaries, the
    same contract :func:`~repro.federated.async_engine.latency_draw`
    gives the arrival schedule.
    """
    # repro-lint: allow[R1] — the churn stream's root: a pure function of (pop seed, kind, index, silo), replayed exactly from the spec
    rng = np.random.default_rng([_POP_SALT, pop_seed, kind, index, silo])
    return float(rng.random())


@dataclasses.dataclass(frozen=True)
class PopulationSpec:
    """Declarative population dynamics — a node on ``ExperimentSpec``.

    Attributes:
      initial: silos present at round 0 (the rest of the roster is
        cold and joins through the arrival process).
      arrival_rate: per-round probability that the next cold silo
        joins (at most one arrival per round; silos join in roster
        order, so the stacked silo axis only ever appends).
      departure_rate: per-round, per-active-silo probability of going
        offline (the engine never lets the last active silo depart).
      return_rate: per-round, per-departed-silo probability of coming
        back.
      max_silos: roster cap; ``None`` means ``spec.num_silos`` (the
        registry stages the full roster's data up front, so joins
        never re-stage anything).
      warm_start: warm-start a joining silo's ``η_L`` through the
        amortized encoder (:func:`amortized_warm_start`); ``False``
        joins it with the cold family init (the ablation the
        warm-start test measures against).
      staleness_decay: sync-path weight decay for a returning silo:
        its first round back aggregates with weight
        ``(1 + rounds_absent)^-staleness_decay``. The async path
        ignores this and reuses the flush weighting of
        :func:`~repro.federated.async_engine.flush_weights` (staleness
        there is the server-version gap of the silo's stale pull).
      seed: population event stream seed (separate from the run seed
        so one churn schedule can be crossed with many run seeds).
    """

    initial: int = 2
    arrival_rate: float = 0.0
    departure_rate: float = 0.0
    return_rate: float = 0.0
    max_silos: Optional[int] = None
    warm_start: bool = True
    staleness_decay: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if self.initial < 1:
            raise ValueError(f"initial must be >= 1, got {self.initial}")
        for name in ("arrival_rate", "departure_rate", "return_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.max_silos is not None and self.max_silos < self.initial:
            raise ValueError(
                f"max_silos ({self.max_silos}) < initial ({self.initial})")

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PopulationSpec":
        return cls(
            initial=d.get("initial", 2),
            arrival_rate=d.get("arrival_rate", 0.0),
            departure_rate=d.get("departure_rate", 0.0),
            return_rate=d.get("return_rate", 0.0),
            max_silos=d.get("max_silos"),
            warm_start=d.get("warm_start", True),
            staleness_decay=d.get("staleness_decay", 0.5),
            seed=d.get("seed", 0),
        )


@dataclasses.dataclass
class PopulationState:
    """The mutable remainder of the population process.

    Everything else is a pure function of the spec, so this — like the
    async engine's :class:`~repro.federated.async_engine.BufferState`
    — is all a checkpoint needs to resume the churn schedule
    bit-exactly mid-event.

    Attributes:
      round: next round/flush index whose events are unprocessed.
      joined: silos that have ever joined (== the Server's current J;
        silos join in roster order, so this is also the next arrival).
      status: per-joined-silo lifecycle code (ACTIVE / DEPARTED).
      last_present: per-joined-silo index of the last round it was
        active — the sync path's staleness counter on return.
    """

    round: int
    joined: int
    status: List[int]
    last_present: List[int]

    @classmethod
    def init(cls, initial: int) -> "PopulationState":
        return cls(round=0, joined=initial, status=[ACTIVE] * initial,
                   last_present=[-1] * initial)

    def state_dict(self) -> Dict[str, Any]:
        """JSON-native snapshot (checkpointed by ``federated.api``)."""
        return {"round": self.round, "joined": self.joined,
                "status": list(self.status),
                "last_present": list(self.last_present)}

    @classmethod
    def from_state(cls, state: Dict[str, Any]) -> "PopulationState":
        return cls(round=int(state["round"]), joined=int(state["joined"]),
                   status=[int(x) for x in state["status"]],
                   last_present=[int(x) for x in state["last_present"]])

    @property
    def n_active(self) -> int:
        return sum(1 for s in self.status if s == ACTIVE)


def amortized_warm_start(problem, data_j: PyTree, key) -> PyTree:
    """Encode a joining silo's data into its initial ``η_L``.

    The cold path draws ``local_family.init(key)`` and spends rounds
    pulling the mean toward the data; the warm path keeps that init as
    the template (so warm vs cold differ ONLY in the leaves the
    encoder informs) and overwrites the mean/scale leaves with the
    amortized statistics of :mod:`repro.core.amortized`: a
    deterministic near-linear encoder (:func:`~repro.core.amortized.
    encoder_warm_init`) maps each observation to a per-observation
    (μ, log σ) and the silo-level init is their average, with the
    posterior-contraction scale ``σ₀ = n^-1/2``. Families without a
    recognized mean leaf (``mu`` / ``mu_bar``) fall back to the cold
    init unchanged.
    """
    template = problem.local_family.init(key)
    if not isinstance(template, dict):
        return template
    mu_leaf = "mu" if "mu" in template else (
        "mu_bar" if "mu_bar" in template else None)
    if mu_leaf is None:
        return template
    leaves = jax.tree_util.tree_leaves(data_j)
    if not leaves:
        return template
    y = data_j["y"] if isinstance(data_j, dict) and "y" in data_j else leaves[0]
    n = int(y.shape[0]) if y.ndim else 1
    y2 = jnp.asarray(y, jnp.float32).reshape(n, -1)
    latent_dim = int(np.prod(template[mu_leaf].shape)) or 1
    phi = amortized.encoder_warm_init(
        int(y2.shape[1]), latent_dim,
        log_sigma=float(-0.5 * math.log(max(n, 1))))
    mu_k, ls_k = amortized.encode(phi, y2)
    out = dict(template)
    out[mu_leaf] = jnp.mean(mu_k, axis=0).reshape(template[mu_leaf].shape)
    if "log_sigma" in template:
        out["log_sigma"] = jnp.mean(ls_k, axis=0).reshape(
            template["log_sigma"].shape)
    return out


class PopulationEngine:
    """Drives churn events against a live Server, one round at a time.

    Owns a :class:`PopulationSpec` + :class:`PopulationState` and the
    staged roster data (the registry bundle stages all ``max_silos``
    shards up front). ``Experiment`` threads the engine into the run
    loop: the sync path calls :meth:`begin_round` before each round,
    the async path calls :meth:`begin_flush` before each flush — both
    process the index's events exactly once, in a fixed order
    (returns → arrival → departures), and both are replay-exact after
    a resume because the draws are pure and the state is checkpointed.
    """

    def __init__(self, pop: PopulationSpec, bundle, num_silos: int,
                 state: Optional[PopulationState] = None):
        self.pop = pop
        self.bundle = bundle
        self.max_silos = (pop.max_silos if pop.max_silos is not None
                          else num_silos)
        if self.max_silos > num_silos:
            raise ValueError(
                f"population.max_silos ({self.max_silos}) exceeds the "
                f"staged roster (num_silos={num_silos})")
        if pop.initial > self.max_silos:
            raise ValueError(
                f"population.initial ({pop.initial}) exceeds max_silos "
                f"({self.max_silos})")
        self.state = state if state is not None else PopulationState.init(
            pop.initial)

    # -- event processing ----------------------------------------------------

    def _bundle_row(self, j: int):
        data_j = self.bundle.datas[j]
        if self.bundle.num_obs is not None:
            n_j = int(self.bundle.num_obs[j])
        else:
            n_j = int(jax.tree_util.tree_leaves(data_j)[0].shape[0])
        return data_j, n_j

    def _join(self, server, j: int) -> None:
        """Append roster silo ``j`` to the live federation."""
        data_j, n_j = self._bundle_row(j)
        eta_row = None
        if self.pop.warm_start and server._has_local:
            # Same per-silo key the cold growth path uses, so warm vs
            # cold differ only in the encoder-informed leaves.
            # repro-lint: allow[R1] — deterministic per-silo warm-start root, re-derived bit-exactly on resume
            root = jax.random.PRNGKey(server.seed + 1)
            key = jax.random.fold_in(root, j)
            eta_row = amortized_warm_start(server.problem, data_j, key)
        server.grow_silos([data_j], num_obs=[n_j],
                          eta_rows=None if eta_row is None else [eta_row])

    def _advance(self, server, index: int) -> Tuple[List[int], List[int]]:
        """Process event index ``index``; returns (joins, returns).

        Events run in a fixed order — returns, then at most one
        arrival, then departures — and each is one pure draw, so the
        schedule is identical however the run is chunked or resumed.
        """
        st = self.state
        if st.round != index:
            raise RuntimeError(
                f"population state is at event index {st.round}, but the "
                f"run loop asked for index {index}; population runs must "
                f"advance one round/flush at a time (resume restores the "
                f"saved index)")
        pop = self.pop
        returns: List[int] = []
        for j in range(st.joined):
            if st.status[j] == DEPARTED and event_draw(
                    pop.seed, _RETURN, index, j) < pop.return_rate:
                st.status[j] = ACTIVE
                returns.append(j)
        joins: List[int] = []
        if st.joined < self.max_silos and event_draw(
                pop.seed, _ARRIVAL, index, st.joined) < pop.arrival_rate:
            j = st.joined
            self._join(server, j)
            st.status.append(ACTIVE)
            st.last_present.append(-1)
            st.joined += 1
            joins.append(j)
        for j in range(st.joined):
            if st.status[j] != ACTIVE or j in returns or j in joins:
                continue
            if st.n_active <= 1:
                break  # never let the last active silo depart
            if event_draw(pop.seed, _DEPART, index, j) < pop.departure_rate:
                st.status[j] = DEPARTED
        st.round = index + 1
        return joins, returns

    # -- sync path -----------------------------------------------------------

    def begin_round(self, server, r: int) -> Tuple[np.ndarray, np.ndarray]:
        """Process round ``r``'s events; returns (presence, weights).

        Both vectors cover the server's CURRENT J (post-growth).
        ``presence`` is the 0/1 membership mask multiplied into the
        scheduler's participation mask; ``weights`` additionally decays
        a returning silo's first round back by
        ``(1 + rounds_absent)^-staleness_decay`` — the same decay law
        the async engine applies per flush.
        """
        st = self.state
        _, returns = self._advance(server, r)
        present = np.array(
            [1.0 if s == ACTIVE else 0.0 for s in st.status], np.float32)
        weights = present.copy()
        for j in returns:
            absent = max(r - st.last_present[j], 0) if st.last_present[j] >= 0 else 0
            weights[j] = (1.0 + absent) ** (-self.pop.staleness_decay)
        for j in range(st.joined):
            if st.status[j] == ACTIVE:
                st.last_present[j] = r
        return present, weights

    # -- async path ----------------------------------------------------------

    def begin_flush(self, server, buf, cfg, f: int) -> List[int]:
        """Process flush ``f``'s events against the async BufferState.

        Joins start their first task at the current simulated clock;
        a returning silo restarts its interrupted task from the return
        instant but KEEPS its recorded pull version, so its
        contribution arrives with the large staleness the version gap
        implies — which is exactly what feeds
        :func:`~repro.federated.async_engine.flush_weights`. Returns
        the 0/1 activity mask ``simulate_flush`` pops arrivals under
        (departed silos' in-flight tasks are frozen, not dropped).
        """
        from repro.federated.async_engine import latency_draw

        st = self.state
        joins, returns = self._advance(server, f)
        for j in joins:
            buf.task_idx.append(0)
            buf.start_version.append(buf.version)
            buf.start_time.append(buf.clock)
            buf.finish_time.append(
                buf.clock + latency_draw(cfg, server.seed, j, 0))
        for j in returns:
            buf.finish_time[j] = buf.clock + latency_draw(
                cfg, server.seed, j, buf.task_idx[j])
        for j in range(st.joined):
            if st.status[j] == ACTIVE:
                st.last_present[j] = f
        return [1 if s == ACTIVE else 0 for s in st.status]
