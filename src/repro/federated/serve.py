"""Federated posterior serving: answer ``q(Z_L | Z_G)`` queries from a
checkpoint.

Training ends with the structured posterior split across the privacy
boundary — the server's ``q_{η_G}(Z_G)`` plus one private
``q_{η_{L_j}}(Z_{L_j} | Z_G)`` per silo. This module turns a saved run
(:meth:`repro.federated.api.Experiment.save`) into a query endpoint:

  * :meth:`Posterior.global_sample` — draws from ``q_{η_G}(Z_G)``;
  * :meth:`Posterior.sample` — joint ``(Z_G, Z_{L_j})`` draws for one
    silo, routed through the same :class:`~repro.core.sfvi.SFVIProblem`
    sampling path training used (conditional families condition on the
    drawn ``Z_G``, so the serving-time posterior is exactly the
    variational family the paper optimizes);
  * :meth:`Posterior.predict` — posterior-predictive outputs for new
    inputs through the model's optional ``predict`` hook, averaged over
    posterior draws;
  * :meth:`Posterior.answer_batch` — a request batcher: queries are
    grouped by (kind, silo) and each group is served by ONE vectorized
    sampling call (the per-query draws are slices of a single
    ``num_samples = Σ n`` batch), then scattered back in request order.

Every query is deterministic in its ``seed`` — two replicas serving the
same checkpoint return bit-identical answers, the serving-side analogue
of the trainer's bit-exact resume contract.

CLI::

    python -m repro.federated.serve --ckpt-dir runs/demo --silo 0 --n 3
    python -m repro.federated.serve --ckpt-dir runs/demo --global-sample 5
    python -m repro.federated.serve --ckpt-dir runs/demo \
        --queries '[{"kind": "sample", "silo": 1, "n": 2}]'

Latency/throughput numbers live in ``benchmarks/bench_serving.py``
(the federated-posterior row).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# Fold-in salt separating the serving key stream from training's
# round keys (fold_in(seed, round)) and the population/latency salts.
_SERVE_SALT = 0x53E7


@dataclasses.dataclass(frozen=True)
class Query:
    """One serving request.

    ``kind`` is ``"sample"`` (joint ``(Z_G, Z_{L_silo})`` draws),
    ``"global_sample"`` (``Z_G`` only; ``silo`` ignored) or
    ``"predict"`` (posterior-predictive outputs for inputs ``x``
    through the model's ``predict`` hook, averaged over ``n`` draws).
    """

    kind: str
    silo: Optional[int] = None
    n: int = 1
    x: Optional[Any] = None

    def __post_init__(self):
        if self.kind not in ("sample", "global_sample", "predict"):
            raise ValueError(f"unknown query kind {self.kind!r}")
        if self.n < 1:
            raise ValueError(f"n must be >= 1, got {self.n}")
        if self.kind != "global_sample" and self.silo is None:
            raise ValueError(f"{self.kind!r} queries need a silo index")
        if self.kind == "predict" and self.x is None:
            raise ValueError("predict queries need inputs x")

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Query":
        x = d.get("x")
        return cls(kind=d["kind"], silo=d.get("silo"), n=d.get("n", 1),
                   x=None if x is None else jnp.asarray(x))


class Posterior:
    """A checkpointed federated posterior, ready to answer queries.

    Wraps a restored :class:`~repro.federated.api.Experiment` —
    construct with :meth:`from_checkpoint` (the usual path) or directly
    from a live experiment (``Posterior(exp)``) to serve mid-training
    state without a disk round-trip.
    """

    def __init__(self, experiment):
        self.experiment = experiment
        self.server = experiment.server
        self.problem = self.server.problem
        # Sampling shapes are static per (kind, n, x-shape); memoize the
        # jitted closures so a serving loop pays one trace per shape.
        self._compiled: Dict[tuple, Any] = {}

    @classmethod
    def from_checkpoint(cls, directory: str,
                        step: Optional[int] = None) -> "Posterior":
        """Restore the latest (or ``step``) checkpoint under ``directory``."""
        from repro.federated.api import Experiment

        return cls(Experiment.resume(directory, step=step))

    # -- state accessors -----------------------------------------------------

    @property
    def num_silos(self) -> int:
        """Live silos (a population checkpoint restores mid-roster)."""
        return self.server.J

    @property
    def round(self) -> int:
        return self.experiment.round

    def eta_row(self, silo: int) -> PyTree:
        """Silo ``silo``'s private ``η_{L_j}`` (row of the stacked axis)."""
        if not 0 <= silo < self.server.J:
            raise IndexError(
                f"silo {silo} out of range: checkpoint serves "
                f"{self.server.J} silos")
        if not self.problem.model.has_local:
            return None
        return jax.tree_util.tree_map(
            lambda x: x[silo], self.server.state["eta_L"])

    # -- sampling ------------------------------------------------------------

    def _key(self, seed: int, silo: int) -> jax.Array:
        with self._bridge():
            # silo + 1: fold_in data is uint32 and the global stream
            # uses silo = -1.
            # repro-lint: allow[R1] — serving key root: pure function of the query seed, disjoint from training streams
            root = jax.random.PRNGKey(_SERVE_SALT + seed)
            return jax.random.fold_in(root, silo + 1)

    @staticmethod
    def _bridge():
        from repro import debug

        return debug.host_bridge()

    def _sampler(self, n: int):
        key = ("sample", n)
        if key not in self._compiled:
            prob = self.problem

            def draw(eta_G, eta_L, k):
                return prob.sample_posterior(eta_G, eta_L, k, num_samples=n)

            self._compiled[key] = jax.jit(draw)
        return self._compiled[key]

    def _global_sampler(self, n: int):
        key = ("global", n)
        if key not in self._compiled:
            prob = self.problem

            def draw(eta_G, k):
                return prob.sample_posterior(eta_G, None, k, num_samples=n)[0]

            self._compiled[key] = jax.jit(draw)
        return self._compiled[key]

    def _predictor(self, n: int, x_shape: tuple):
        key = ("predict", n, x_shape)
        if key not in self._compiled:
            prob = self.problem
            predict = prob.model.predict

            def run(theta, eta_G, eta_L, x, k):
                z_G, z_L = prob.sample_posterior(eta_G, eta_L, k,
                                                 num_samples=n)
                if z_L is None:
                    out = jax.vmap(lambda zg: predict(theta, zg, None, x))(z_G)
                else:
                    out = jax.vmap(
                        lambda zg, zl: predict(theta, zg, zl, x))(z_G, z_L)
                return jnp.mean(out, axis=0)

            self._compiled[key] = jax.jit(run)
        return self._compiled[key]

    def global_sample(self, n: int = 1, seed: int = 0) -> jax.Array:
        """``n`` draws of ``Z_G`` from ``q_{η_G}`` — shape ``(n, d_G)``."""
        fn = self._global_sampler(int(n))
        return fn(self.server.state["eta_G"], self._key(seed, -1))

    def sample(self, silo: int, n: int = 1,
               seed: int = 0) -> Dict[str, Optional[jax.Array]]:
        """``n`` joint draws for ``silo``: ``{"z_G": (n, d_G), "z_L": (n, d_L)}``.

        ``z_L`` is None for global-only models. Conditional local
        families draw ``Z_L | Z_G`` from the SAME ``Z_G`` realization
        returned, so the pair is a joint posterior draw.
        """
        eta_L = self.eta_row(silo)
        fn = self._sampler(int(n))
        z_G, z_L = fn(self.server.state["eta_G"], eta_L,
                      self._key(seed, silo))
        return {"z_G": z_G, "z_L": z_L}

    def predict(self, silo: int, x, n: int = 8, seed: int = 0) -> jax.Array:
        """Posterior-predictive output for inputs ``x`` at ``silo``.

        Averages the model's ``predict(θ, Z_G, Z_{L_silo}, x)`` over
        ``n`` joint posterior draws. Raises for models without a
        ``predict`` hook.
        """
        if self.problem.model.predict is None:
            raise ValueError(
                f"model {self.problem.model.name!r} has no predict hook; "
                f"only sample/global_sample queries are servable")
        eta_L = self.eta_row(silo)
        x = jnp.asarray(x)
        fn = self._predictor(int(n), tuple(x.shape))
        return fn(self.server.state["theta"], self.server.state["eta_G"],
                  eta_L, x, self._key(seed, silo))

    # -- request batching ----------------------------------------------------

    def answer_batch(self, queries: Sequence[Query],
                     seed: int = 0) -> List[Any]:
        """Serve ``queries``, batching draws per (kind, silo) group.

        All ``sample``/``global_sample`` queries hitting the same silo
        are served by ONE vectorized ``num_samples = Σ n`` call and the
        per-query answers are contiguous slices of that batch, in
        request order — the amortization that makes many small queries
        as cheap as one big one. ``predict`` queries keep one call per
        query (their ``x`` shapes differ), but still share the group's
        compiled sampler. Answers are returned in request order; the
        batching is invisible in the results (same draws as issuing the
        grouped queries back-to-back with one shared key per group).
        """
        groups: Dict[Tuple[str, int], List[int]] = {}
        for i, q in enumerate(queries):
            silo = -1 if q.kind == "global_sample" else int(q.silo)
            groups.setdefault((q.kind, silo), []).append(i)
        answers: List[Any] = [None] * len(queries)
        for (kind, silo), idxs in groups.items():
            if kind == "predict":
                for i in idxs:
                    q = queries[i]
                    answers[i] = self.predict(silo, q.x, n=q.n, seed=seed)
                continue
            total = sum(queries[i].n for i in idxs)
            if kind == "global_sample":
                z = self.global_sample(total, seed=seed)
                batch = {"z_G": z, "z_L": None}
            else:
                batch = self.sample(silo, total, seed=seed)
            off = 0
            for i in idxs:
                n = queries[i].n
                answers[i] = {
                    k: (None if v is None else v[off:off + n])
                    for k, v in batch.items()
                }
                off += n
        return answers


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _jsonable(x):
    if x is None:
        return None
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    return np.asarray(x).tolist()


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.federated.serve",
        description="Answer q(Z_L|Z_G) queries from a federated checkpoint.")
    ap.add_argument("--ckpt-dir", required=True, metavar="DIR",
                    help="checkpoint directory written by Experiment.save")
    ap.add_argument("--step", type=int, default=None,
                    help="checkpoint step (default: latest)")
    ap.add_argument("--silo", type=int, default=None,
                    help="serve n joint (Z_G, Z_L) draws for this silo")
    ap.add_argument("--n", type=int, default=1,
                    help="draws per query (with --silo / --global-sample)")
    ap.add_argument("--global-sample", type=int, default=None, metavar="N",
                    help="serve N draws of Z_G from q(Z_G)")
    ap.add_argument("--queries", default=None, metavar="JSON",
                    help='batched request list, e.g. \'[{"kind": "sample", '
                         '"silo": 0, "n": 2}]\' — grouped by silo and '
                         "served with one vectorized call per group")
    ap.add_argument("--seed", type=int, default=0,
                    help="query seed (same seed -> bit-identical answers)")
    args = ap.parse_args(argv)

    post = Posterior.from_checkpoint(args.ckpt_dir, step=args.step)
    out: Dict[str, Any] = {
        "round": post.round,
        "num_silos": post.num_silos,
    }
    if args.queries is not None:
        qs = [Query.from_dict(d) for d in json.loads(args.queries)]
        out["answers"] = [_jsonable(a) for a in post.answer_batch(
            qs, seed=args.seed)]
    elif args.global_sample is not None:
        out["z_G"] = _jsonable(post.global_sample(args.global_sample,
                                                  seed=args.seed))
    elif args.silo is not None:
        out["answer"] = _jsonable(post.sample(args.silo, args.n,
                                              seed=args.seed))
    else:
        ap.error("one of --silo, --global-sample or --queries is required")
    json.dump(out, sys.stdout)
    sys.stdout.write("\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
