"""Federated orchestration runtime (paper §3; docs/federated.md).

The compiled client/server layer over the per-silo math of
``repro.core.sfvi``: a :class:`~repro.federated.runtime.Server` advances
J silos per round inside one ``shard_map`` graph along the dedicated
``silo`` mesh axis, with pluggable aggregation
(:class:`~repro.federated.aggregation.MeanAggregator`,
:class:`~repro.federated.aggregation.TrimmedMeanAggregator`), wire
compression (:class:`~repro.federated.aggregation.Int8Compressor`),
partial-participation scheduling
(:class:`~repro.federated.scheduler.RoundScheduler`), per-round
communication accounting (:class:`~repro.federated.runtime.CommMeter`),
and differentially private rounds
(:class:`~repro.federated.privacy.PrivacyPolicy` clip-and-noise inside
the compiled graph, :class:`~repro.federated.privacy.RdpAccountant`
(ε, δ) tracking — docs/privacy.md). A
:func:`~repro.federated.scheduler.scenario_matrix` crosses
participation × stragglers × compression × DP × async into named
:class:`~repro.federated.scheduler.Scenario` rows for one-invocation
sweeps.

Asynchronous execution (docs/federated.md §Async): a Scenario carrying
an :class:`~repro.federated.scheduler.AsyncConfig` runs FedBuff-style
buffered flushes (:mod:`repro.federated.async_engine`) — the server
applies an aggregate whenever ``buffer_size`` contributions arrive,
staleness-weighted, under a deterministic per-(seed, silo, task)
latency model — through the SAME compiled round graph, so DP,
compression and the coalesced gather apply unchanged.

Population dynamics (docs/federated.md §Population): an
:class:`~repro.federated.population.PopulationSpec` on the spec layers
deterministic silo churn over either event loop — cold silos join
mid-run (amortized warm-start of their ``η_L`` through
:mod:`repro.core.amortized`; the padded silo axis grows in mesh-sized
chunks via ``Server.grow_silos``), depart with their state frozen in
place, and return stale under the FedBuff staleness weighting — with
bit-exact checkpoint/resume mid-event. A trained checkpoint serves
``q(Z_L | Z_G)`` queries through
:class:`~repro.federated.serve.Posterior`
(``python -m repro.federated.serve --ckpt-dir ...``).

Declarative layer (docs/api.md): an
:class:`~repro.federated.api.ExperimentSpec` serializes a whole run
(model ref + kwargs, scenario, optimizers, eval cadence, seed) to JSON;
:func:`~repro.federated.api.build` assembles it into an
:class:`~repro.federated.api.Experiment` whose ``run``/``save``/``resume``
own the Server, scheduler, accountant and meter — with bit-exact
checkpoint/resume through ``repro.checkpoint``.

CLI: ``python -m repro.federated.run --model hier_bnn --silos 8``
(add ``--sweep`` for the scenario matrix, ``--dp-noise`` for DP,
``--dump-spec``/``--spec file.json`` for the declarative path,
``--list-models`` for the registry).
"""
from repro.federated.aggregation import (
    Int8Compressor,
    MeanAggregator,
    NoCompression,
    TrimmedMeanAggregator,
)
from repro.federated.driver import run_rounds
from repro.federated.metering import CommMeter, tree_bytes
from repro.federated.privacy import PrivacyPolicy, RdpAccountant
from repro.federated.runtime import (
    Server,
    global_eps,
    silo_eps,
    stack_silos,
)
from repro.federated.strategy import (
    ServerStrategy,
    StrategySpec,
    get_strategy,
    register_strategy,
    resolve_strategy,
    strategy_names,
)
from repro.federated.async_engine import BufferState, run_buffered
from repro.federated.scheduler import (
    AsyncConfig,
    RoundScheduler,
    Scenario,
    scenario_matrix,
)
from repro.core.family import FamilySpec
from repro.federated.population import (
    PopulationEngine,
    PopulationSpec,
    PopulationState,
)
from repro.federated.serve import Posterior, Query
from repro.federated.api import (
    Experiment,
    ExperimentSpec,
    ModelSpec,
    OptimizerSpec,
    RuntimeSpec,
    build,
    run_spec,
    scenario_specs,
)
from repro.launch.mesh import MeshSpec

__all__ = [
    "AsyncConfig",
    "BufferState",
    "CommMeter",
    "run_buffered",
    "Experiment",
    "ExperimentSpec",
    "FamilySpec",
    "MeshSpec",
    "ModelSpec",
    "OptimizerSpec",
    "RuntimeSpec",
    "build",
    "run_spec",
    "scenario_specs",
    "tree_bytes",
    "Int8Compressor",
    "MeanAggregator",
    "NoCompression",
    "PopulationEngine",
    "PopulationSpec",
    "PopulationState",
    "Posterior",
    "Query",
    "PrivacyPolicy",
    "RdpAccountant",
    "RoundScheduler",
    "Scenario",
    "Server",
    "ServerStrategy",
    "StrategySpec",
    "TrimmedMeanAggregator",
    "get_strategy",
    "register_strategy",
    "resolve_strategy",
    "strategy_names",
    "global_eps",
    "run_rounds",
    "scenario_matrix",
    "silo_eps",
    "stack_silos",
]
