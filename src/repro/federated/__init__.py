"""Federated orchestration runtime (paper §3; docs/federated.md).

The compiled client/server layer over the per-silo math of
``repro.core.sfvi``: a :class:`~repro.federated.runtime.Server` advances
J silos per round inside one ``shard_map`` graph along the dedicated
``silo`` mesh axis, with pluggable aggregation
(:class:`~repro.federated.aggregation.MeanAggregator`,
:class:`~repro.federated.aggregation.TrimmedMeanAggregator`), wire
compression (:class:`~repro.federated.aggregation.Int8Compressor`),
partial-participation scheduling
(:class:`~repro.federated.scheduler.RoundScheduler`) and per-round
communication accounting (:class:`~repro.federated.runtime.CommMeter`).

CLI: ``python -m repro.federated.run --model hier_bnn --silos 8``.
"""
from repro.federated.aggregation import (
    Int8Compressor,
    MeanAggregator,
    NoCompression,
    TrimmedMeanAggregator,
)
from repro.federated.driver import run_rounds
from repro.federated.runtime import (
    CommMeter,
    Server,
    global_eps,
    silo_eps,
    stack_silos,
)
from repro.federated.scheduler import RoundScheduler

__all__ = [
    "CommMeter",
    "Int8Compressor",
    "MeanAggregator",
    "NoCompression",
    "RoundScheduler",
    "Server",
    "TrimmedMeanAggregator",
    "global_eps",
    "run_rounds",
    "silo_eps",
    "stack_silos",
]
