"""Backbone assembly: one module covering all six assigned arch families.

Layer stacking uses **scan-over-units**: the layer list is grouped into its
repeating unit (dense: [attn]; zamba2: [mamba2 x5, attn]; xlstm:
[mlstm x7, slstm]); parameters are stacked with a leading ``n_units`` axis
and the stack is applied with ``jax.lax.scan`` (+ remat in training). This
keeps the HLO size O(unit) instead of O(num_layers) — essential for the
40 x 2-mesh dry-run compiles — and matches how MaxText-class frameworks
lower deep stacks.

Three entry modes share the block code:
  * ``forward``      — full-sequence teacher-forced logits (train).
  * ``prefill``      — full sequence, returns logits + decode cache.
  * ``decode_step``  — ONE token against the cache (serve_step for
                       decode_32k / long_500k).

Whisper (enc-dec) adds a bidirectional encoder over stub frame embeddings
and cross-attention in each decoder block; the cross K/V are computed once
at prefill and stored in the cache. Qwen2-VL prepends stub patch
embeddings and uses M-RoPE positions.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as _P

from repro.models.backbone.attention import (
    attention_block,
    attention_decode,
    attention_prefill,
    attn_init,
    cross_attention,
    cross_attn_init,
    init_kv_cache,
)
from repro.models.backbone.config import ArchConfig
from repro.models.backbone.layers import (
    dense_init,
    embed,
    embed_init,
    mlp,
    mlp_init,
    mrope_positions,
    mrope_text_start,
    rmsnorm,
    rmsnorm_init,
)
from repro.models.backbone.moe import moe_block, moe_block_dense, moe_init
from repro.models.backbone.ssm import (
    mamba2_block,
    mamba2_decode,
    mamba2_init,
    mamba2_init_cache,
    mamba2_prefill,
)
from repro.models.backbone.xlstm import (
    mlstm_block,
    mlstm_decode,
    mlstm_init,
    mlstm_init_cache,
    mlstm_prefill,
    slstm_block,
    slstm_decode,
    slstm_init,
    slstm_init_cache,
    slstm_prefill,
)

PyTree = Any


# ---------------------------------------------------------------------------
# Stacking structure
# ---------------------------------------------------------------------------

def unit_structure(cfg: ArchConfig) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    """(unit_pattern, n_units, tail_pattern)."""
    pattern = cfg.block_pattern
    period = cfg.hybrid_attn_period or cfg.slstm_period or 1
    if period <= 1:
        return (pattern[0],), len(pattern), ()
    n_units = len(pattern) // period
    unit = pattern[:period]
    tail = pattern[n_units * period :]
    return unit, n_units, tail


def _block_init(key, cfg: ArchConfig, kind: str, decoder: bool = False) -> PyTree:
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    if kind == "attn":
        if cfg.arch_type == "hybrid" and cfg.shared_attn:
            # Weights live in params["shared_attn"]; block carries only norms.
            return {"norm1": rmsnorm_init(cfg.d_model, dtype)}
        p = {
            "norm1": rmsnorm_init(cfg.d_model, dtype),
            "attn": attn_init(ks[0], cfg),
            "norm2": rmsnorm_init(cfg.d_model, dtype),
        }
        if cfg.is_moe:
            p["moe"] = moe_init(ks[1], cfg)
        elif cfg.d_ff:
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
        if decoder and cfg.is_encoder_decoder:
            p["norm_x"] = rmsnorm_init(cfg.d_model, dtype)
            p["xattn"] = cross_attn_init(ks[2], cfg)
        return p
    if kind == "mamba2":
        return {"norm1": rmsnorm_init(cfg.d_model, dtype), "mixer": mamba2_init(ks[0], cfg)}
    if kind == "mlstm":
        return {"norm1": rmsnorm_init(cfg.d_model, dtype), "mixer": mlstm_init(ks[0], cfg)}
    if kind == "slstm":
        return {"norm1": rmsnorm_init(cfg.d_model, dtype), "mixer": slstm_init(ks[0], cfg)}
    raise ValueError(kind)


def init_params(key, cfg: ArchConfig) -> PyTree:
    unit, n_units, tail = unit_structure(cfg)
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.padded_vocab, dtype)
    decoder = cfg.is_encoder_decoder
    # Stacked unit params: vmap the initializer over n_units keys.
    unit_params = {}
    for s, kind in enumerate(unit):
        unit_keys = jax.random.split(jax.random.fold_in(keys[2], s), n_units)
        unit_params[f"slot{s}"] = jax.vmap(
            lambda k: _block_init(k, cfg, kind, decoder)
        )(unit_keys)
    params["units"] = unit_params
    params["tail"] = {
        f"layer{i}": _block_init(jax.random.fold_in(keys[3], i), cfg, kind, decoder)
        for i, kind in enumerate(tail)
    }
    if cfg.arch_type == "hybrid" and cfg.shared_attn:
        shared = {
            "attn": attn_init(keys[4], cfg),
            "norm2": rmsnorm_init(cfg.d_model, dtype),
        }
        if cfg.d_ff:
            shared["mlp"] = mlp_init(keys[5], cfg.d_model, cfg.d_ff, dtype)
        params["shared_attn"] = shared
    if cfg.is_encoder_decoder:
        enc_keys = jax.random.split(keys[6], cfg.num_encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: _block_init(k, cfg, "attn", decoder=False))(
                enc_keys
            ),
            "final_norm": rmsnorm_init(cfg.d_model, dtype),
        }
    if cfg.num_vision_tokens:
        # Projector from the (stubbed) vision encoder's embedding space.
        params["vision_proj"] = dense_init(keys[7], cfg.d_model, cfg.d_model, dtype)
    return params


def param_count(params: PyTree) -> int:
    return sum(int(jnp.size(x)) for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Block application (shared across modes)
# ---------------------------------------------------------------------------

def _apply_block(
    p: PyTree,
    shared: Optional[PyTree],
    cfg: ArchConfig,
    kind: str,
    x: jnp.ndarray,
    positions,
    mode: str,
    cache: Optional[PyTree],
    memory: Optional[jnp.ndarray],
    causal: bool = True,
):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache
    if kind == "attn":
        attn_p = shared if (shared is not None) else p
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        if mode == "train":
            y = attention_block(attn_p["attn"], cfg, h, positions, causal=causal)
        elif mode == "prefill":
            y, new_attn_cache = attention_prefill(attn_p["attn"], cfg, h, positions)
        else:  # decode
            y, new_attn_cache = attention_decode(
                attn_p["attn"], cfg, h, cache["attn"], positions
            )
        x = x + y
        if mode != "train":
            new_cache = dict(cache) if cache is not None else {}
            new_cache["attn"] = new_attn_cache
        if cfg.is_encoder_decoder and memory is not None:
            hx = rmsnorm(x, p["norm_x"], cfg.norm_eps)
            x = x + cross_attention(p["xattn"], cfg, hx, memory)
        ffn_p = attn_p if (shared is not None) else p
        if "moe" in ffn_p or "mlp" in ffn_p:
            h2 = rmsnorm(x, ffn_p["norm2"] if shared is None else shared["norm2"], cfg.norm_eps)
            if "moe" in ffn_p:
                if mode == "decode":
                    y2, a = moe_block_dense(ffn_p["moe"], cfg, h2)
                else:
                    y2, a = moe_block(ffn_p["moe"], cfg, h2)
                aux = aux + a
            else:
                y2 = mlp(ffn_p["mlp"], h2)
            x = x + y2
        return x, new_cache, aux

    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    mixer = p["mixer"]
    if kind == "mamba2":
        fns = (mamba2_block, mamba2_prefill, mamba2_decode)
    elif kind == "mlstm":
        fns = (mlstm_block, mlstm_prefill, mlstm_decode)
    elif kind == "slstm":
        fns = (slstm_block, slstm_prefill, slstm_decode)
    else:
        raise ValueError(kind)
    if mode == "train":
        y = fns[0](mixer, cfg, h)
    elif mode == "prefill":
        y, new_cache = fns[1](mixer, cfg, h)
    else:
        y, new_cache = fns[2](mixer, cfg, h, cache)
    return x + y, new_cache, aux


def _init_block_cache(params_block, cfg, kind, batch, max_len, dtype):
    if kind == "attn":
        return {"attn": init_kv_cache(cfg, batch, max_len, dtype)}
    if kind == "mamba2":
        return mamba2_init_cache(params_block, cfg, batch, dtype)
    if kind == "mlstm":
        return mlstm_init_cache(params_block, cfg, batch, dtype)
    if kind == "slstm":
        return slstm_init_cache(params_block, cfg, batch, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Stack application: scan over units + unrolled tail
# ---------------------------------------------------------------------------

def _apply_stack(params, cfg, x, positions, mode, caches, memory, remat=False):
    """caches: {"units": {slotS: stacked cache}, "tail": {layerI: cache}} or None."""
    unit, n_units, tail = unit_structure(cfg)
    shared = params.get("shared_attn")
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {"units": {}, "tail": {}}

    def unit_fn(x, unit_params, unit_caches):
        aux = jnp.zeros((), jnp.float32)
        out_caches = {}
        for s, kind in enumerate(unit):
            c = unit_caches.get(f"slot{s}") if unit_caches else None
            sh = shared if (kind == "attn" and shared is not None) else None
            x, nc, a = _apply_block(
                unit_params[f"slot{s}"], sh, cfg, kind, x, positions, mode, c, memory
            )
            aux = aux + a
            if nc is not None:
                out_caches[f"slot{s}"] = nc
        if cfg.perf.act_shard and mode == "train":
            # §Perf lever 4 (Megatron sequence parallelism): activations
            # between units live sequence-sharded on the model axis, so the
            # per-unit tensor saved for backward is 1/model_size the size
            # and the TP all-reduce splits into reduce-scatter + all-gather.
            x = jax.lax.with_sharding_constraint(x, _P(None, "model", None))
        return x, out_caches, aux

    if n_units == 1 or cfg.analysis_mode:
        # Unrolled path: exact per-layer FLOP counting for the roofline
        # analysis compiles (scan bodies are counted once by XLA cost
        # analysis), and trivially correct for single-unit stacks.
        uc_stacked = (caches or {}).get("units") if caches else None
        fn = jax.checkpoint(unit_fn) if remat else unit_fn
        outs = []
        for i in range(n_units):
            up = jax.tree_util.tree_map(lambda a: a[i], params["units"])
            ucc = (
                jax.tree_util.tree_map(lambda a: a[i], uc_stacked)
                if uc_stacked
                else None
            )
            x, out_c, aux = fn(x, up, ucc)
            aux_total += aux
            outs.append(out_c)
        new_caches["units"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *outs
        )
    else:
        def scan_body(carry, xs):
            x, aux = carry
            unit_params, unit_caches = xs
            fn = jax.checkpoint(unit_fn) if remat else unit_fn
            x, out_c, a = fn(x, unit_params, unit_caches)
            return (x, aux + a), out_c

        unit_caches_stacked = (caches or {}).get("units") if caches else None
        if unit_caches_stacked is None:
            # lax.scan needs a pytree with a leading axis; use per-unit None
            # via a dummy zeros array so the tree structure is static.
            unit_caches_stacked = {"_none": jnp.zeros((n_units,), jnp.float32)}

            def unit_fn_nocache(x, unit_params, _):
                return unit_fn(x, unit_params, None)

            def scan_body(carry, xs):  # noqa: F811 — cache-free variant
                x, aux = carry
                unit_params, _dummy = xs
                fn = jax.checkpoint(unit_fn_nocache) if remat else unit_fn_nocache
                x, out_c, a = fn(x, unit_params, None)
                return (x, aux + a), out_c

        (x, aux_total), out_caches = jax.lax.scan(
            scan_body, (x, aux_total), (params["units"], unit_caches_stacked)
        )
        new_caches["units"] = out_caches

    for i, kind in enumerate(tail):
        c = (caches or {}).get("tail", {}).get(f"layer{i}") if caches else None
        sh = shared if (kind == "attn" and shared is not None) else None
        x, nc, a = _apply_block(
            params["tail"][f"layer{i}"], sh, cfg, kind, x, positions, mode, c, memory
        )
        aux_total += a
        if nc is not None:
            new_caches["tail"][f"layer{i}"] = nc
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# Inputs: embedding + positions (+ modality stubs)
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg, batch, pos_offset=0):
    """batch: {"tokens": (B,S), optional "vision": (B,nv,D)}.

    Returns (x, positions). For M-RoPE positions has shape (3,B,S')."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    if cfg.num_vision_tokens and "vision" in batch:
        vis = batch["vision"].astype(x.dtype) @ params["vision_proj"]
        x = jnp.concatenate([vis, x], axis=1)
        S_total = x.shape[1]
        if cfg.mrope:
            positions = mrope_positions(B, S_total, cfg.num_vision_tokens)
        else:
            positions = jnp.broadcast_to(jnp.arange(S_total)[None], (B, S_total))
        return x, positions
    if cfg.mrope:
        positions = mrope_positions(B, S, 0)
    else:
        positions = jnp.broadcast_to(
            (pos_offset + jnp.arange(S))[None], (B, S)
        )
    return x, positions


def _logits(params, cfg, h):
    out = (h @ params["embed"]["tok"].T) if cfg.tie_embeddings else (
        h @ params["lm_head"])
    if cfg.padded_vocab != cfg.vocab_size:
        # Padding columns must never win softmax/argmax.
        col = jax.lax.broadcasted_iota(jnp.int32, out.shape, out.ndim - 1)
        out = jnp.where(col < cfg.vocab_size, out, -1e30)
    return out


def encode(params, cfg, frames):
    """Whisper encoder over stub frame embeddings (B, S_enc, D)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    blocks = params["encoder"]["blocks"]

    def body(x, p):
        x, _, _ = _apply_block(
            p, None, cfg, "attn", x, positions, "train", None, None, causal=False
        )
        return x, None

    if cfg.analysis_mode:
        for i in range(cfg.num_encoder_layers):
            x, _ = body(x, jax.tree_util.tree_map(lambda a: a[i], blocks))
    else:
        x, _ = jax.lax.scan(body, x, blocks)
    return rmsnorm(x, params["encoder"]["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def forward(params, cfg: ArchConfig, batch, remat: bool = True):
    """Teacher-forced logits. Returns (logits, aux_loss, h_final)."""
    memory = None
    if cfg.is_encoder_decoder:
        memory = encode(params, cfg, batch["frames"])
    x, positions = _embed_inputs(params, cfg, batch)
    x, _, aux = _apply_stack(params, cfg, x, positions, "train", None, memory, remat=remat)
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.num_vision_tokens and "vision" in batch:
        h = h[:, cfg.num_vision_tokens :]  # loss only over text positions
    return _logits(params, cfg, h), aux, h


def init_cache(params, cfg: ArchConfig, batch: int, max_len: int):
    """Zero-initialized decode cache (for decode-only lowering)."""
    unit, n_units, tail = unit_structure(cfg)
    dtype = jnp.dtype(cfg.dtype)
    caches: Dict[str, Any] = {"units": {}, "tail": {}}
    for s, kind in enumerate(unit):
        one = _init_block_cache(
            jax.tree_util.tree_map(lambda a: a[0], params["units"][f"slot{s}"]),
            cfg, kind, batch, max_len, dtype,
        )
        caches["units"][f"slot{s}"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_units,) + a.shape), one
        )
    for i, kind in enumerate(tail):
        caches["tail"][f"layer{i}"] = _init_block_cache(
            params["tail"][f"layer{i}"], cfg, kind, batch, max_len, dtype
        )
    if cfg.is_encoder_decoder:
        caches["memory"] = jnp.zeros((batch, cfg.encoder_seq_len, cfg.d_model), dtype)
    caches["t"] = jnp.zeros((), jnp.int32)  # absolute token counter (incl. vision)
    return caches


def prefill(params, cfg: ArchConfig, batch, max_len: int):
    """Full-sequence prefill. Returns (last-position logits, cache)."""
    memory = None
    if cfg.is_encoder_decoder:
        memory = encode(params, cfg, batch["frames"])
    x, positions = _embed_inputs(params, cfg, batch)
    x, caches, _ = _apply_stack(params, cfg, x, positions, "prefill", None, memory)
    h = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    if cfg.is_encoder_decoder:
        caches["memory"] = memory
    # Right-size attention caches to max_len ring buffers.
    caches = _resize_attn_caches(params, cfg, caches, max_len)
    caches["t"] = jnp.asarray(x.shape[1], jnp.int32)
    return _logits(params, cfg, h), caches, h


def _resize_attn_caches(params, cfg, caches, max_len):
    """Pad prefill KV caches out to the serving ring-buffer length."""
    def fix(c):
        if not (isinstance(c, dict) and set(c) >= {"k", "v", "pos"}):
            return c
        window = cfg.sliding_window
        cur_len = c["k"].shape[-3]
        # Non-windowed caches must never truncate (e.g. vision-prefix tokens).
        target = min(window, max_len) if window else max(max_len, cur_len)
        def pad_to(a):
            cur = a.shape[-3]
            if cur >= target:
                # Keep the last ``target`` keys AND place each absolute
                # position p at ring slot p % target so subsequent decode
                # writes (slot = pos % target) overwrite the oldest entry.
                kept = a[..., cur - target :, :, :]
                shift = (cur - target) % target if target else 0
                return jnp.roll(kept, shift=shift, axis=-3)
            pad = [(0, 0)] * a.ndim
            pad[-3] = (0, target - cur)
            return jnp.pad(a, pad)
        return {"k": pad_to(c["k"]), "v": pad_to(c["v"]), "pos": c["pos"]}

    def walk(tree):
        if isinstance(tree, dict):
            if set(tree) >= {"k", "v", "pos"}:
                return fix(tree)
            return {k: walk(v) for k, v in tree.items()}
        return tree

    return walk(caches)


def decode_step(params, cfg: ArchConfig, tokens, caches):
    """One decode step. tokens: (B, 1). Returns (logits (B,1,V), new caches)."""
    memory = caches.get("memory") if cfg.is_encoder_decoder else None
    x = embed(params["embed"], tokens)
    t = caches["t"]
    if cfg.mrope:
        # Text M-RoPE position: start + (t - num_vision); all 3 channels equal.
        p = mrope_text_start(cfg.num_vision_tokens) + t - cfg.num_vision_tokens
        positions = jnp.broadcast_to(p, (3, tokens.shape[0], 1)).astype(jnp.int32)
    else:
        positions = None  # attention_decode derives positions from cache["pos"]
    x, new_caches, _ = _apply_stack(params, cfg, x, positions, "decode", caches, memory)
    if cfg.is_encoder_decoder:
        new_caches["memory"] = memory
    new_caches["t"] = t + 1
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _logits(params, cfg, h), new_caches, h
