"""Mamba2 (SSD) blocks + a generic chunked gated-linear-attention scan.

Mamba2's state-space duality (SSD) recurrence

    S_t = exp(a_t) S_{t-1} + k_t v_t^T          (state: (H, dk, dv))
    y_t = q_t . S_t

is shared by every gated linear-attention family (Mamba2, mLSTM, GLA);
``chunked_gla`` implements it once with the standard chunked algorithm:
quadratic *within* a chunk (MXU-friendly matmuls) and a ``lax.scan`` of
states *across* chunks — O(S·C) instead of O(S²) work, O(S) memory.

TPU adaptation (DESIGN.md §5): the chunk length is a multiple of the MXU
tile (128) so the within-chunk matmuls are hardware-aligned, and the scan
carries only the (H, dk, dv) state — it never materializes per-step decay
products along the full sequence.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.backbone.layers import dense_init, rmsnorm, rmsnorm_init


def chunked_gla(
    q: jnp.ndarray,  # (B, S, H, dk)
    k: jnp.ndarray,  # (B, S, H, dk)
    v: jnp.ndarray,  # (B, S, H, dv)
    log_a: jnp.ndarray,  # (B, S, H) per-step log decay (<= 0)
    chunk: int = 256,
) -> jnp.ndarray:
    """y_t = q_t^T ( sum_{s<=t} (prod_{r=s+1..t} exp(log_a_r)) k_s v_s^T ).

    All accumulation in f32. Returns (B, S, H, dv) in q.dtype.
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
    n = (S + pad) // chunk
    # (B, n, C, H, ...)
    qc = q.reshape(B, n, chunk, H, dk).astype(jnp.float32)
    kc = k.reshape(B, n, chunk, H, dk).astype(jnp.float32)
    vc = v.reshape(B, n, chunk, H, dv).astype(jnp.float32)
    ac = log_a.reshape(B, n, chunk, H).astype(jnp.float32)

    # Cumulative log-decay within each chunk: L_t = sum_{r<=t} log_a_r.
    cum = jnp.cumsum(ac, axis=2)  # (B, n, C, H)
    total = cum[:, :, -1]  # (B, n, H) — full-chunk decay

    # Within-chunk (intra) term: y_t += sum_{s<=t} exp(L_t - L_s) q_t.k_s v_s
    # Decay matrix D[t, s] = exp(L_t - L_s) for s <= t else 0.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,n,C_t,C_s,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # Mask *before* exp so no inf ever materializes (NaN-safe gradients).
    D = jnp.exp(jnp.where(tri, diff, -jnp.inf))
    scores = jnp.einsum("bnthd,bnshd->bntsh", qc, kc) * D
    y_intra = jnp.einsum("bntsh,bnshv->bnthv", scores, vc)

    # Cross-chunk (inter) term via scan of the state.
    # State entering chunk i is S_i; contribution y_t += exp(L_t) q_t . S_i.
    # State update: S_{i+1} = exp(total_i) S_i + sum_s exp(total_i - L_s) k_s v_s.
    k_dec = kc * jnp.exp(total[:, :, None] - cum)[..., None]  # (B,n,C,H,dk)
    chunk_kv = jnp.einsum("bnshd,bnshv->bnhdv", k_dec, vc)  # (B,n,H,dk,dv)

    def scan_body(state, inp):
        chunk_kv_i, total_i = inp  # (B,H,dk,dv), (B,H)
        new_state = state * jnp.exp(total_i)[..., None, None] + chunk_kv_i
        return new_state, state  # emit state *entering* the chunk

    init = jnp.zeros((B, H, dk, dv), jnp.float32)
    _, states = jax.lax.scan(
        scan_body,
        init,
        (jnp.moveaxis(chunk_kv, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    states = jnp.moveaxis(states, 0, 1)  # (B, n, H, dk, dv)
    q_dec = qc * jnp.exp(cum)[..., None]
    y_inter = jnp.einsum("bnthd,bnhdv->bnthv", q_dec, states)

    y = (y_intra + y_inter).reshape(B, n * chunk, H, dv)
    return y[:, :S].astype(q.dtype)


def gla_final_state(k, v, log_a, chunk: int = 256) -> jnp.ndarray:
    """The recurrent state after the last position (for prefill -> decode).

    Returns (B, H, dk, dv) f32.
    """
    B, S, H, dk = k.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:  # padded steps must be identity: decay 1 (log 0), kv 0
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
    n = (S + pad) // chunk
    kc = k.reshape(B, n, chunk, H, dk).astype(jnp.float32)
    vc = v.reshape(B, n, chunk, H, dv).astype(jnp.float32)
    ac = log_a.reshape(B, n, chunk, H).astype(jnp.float32)
    cum = jnp.cumsum(ac, axis=2)
    total = cum[:, :, -1]
    k_dec = kc * jnp.exp(total[:, :, None] - cum)[..., None]
    chunk_kv = jnp.einsum("bnshd,bnshv->bnhdv", k_dec, vc)

    def body(state, inp):
        ckv, tot = inp
        return state * jnp.exp(tot)[..., None, None] + ckv, None

    init = jnp.zeros((B, H, dk, dv), jnp.float32)
    final, _ = jax.lax.scan(
        body, init, (jnp.moveaxis(chunk_kv, 1, 0), jnp.moveaxis(total, 1, 0))
    )
    return final


def gla_decode_step(state, q, k, v, log_a):
    """One recurrent step. state: (B,H,dk,dv) f32; q/k/v: (B,H,d*); log_a: (B,H)."""
    state = state * jnp.exp(log_a.astype(jnp.float32))[..., None, None] + jnp.einsum(
        "bhd,bhv->bhdv", k.astype(jnp.float32), v.astype(jnp.float32)
    )
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), state)
    return state, y


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba2_init(key, cfg):
    """Mamba2 block parameters.

    d_inner = expand * d_model, H = d_inner / ssm_head_dim heads,
    N = ssm_state. Single B/C group shared across heads (G=1), per-head
    scalar A (the SSD restriction), depthwise conv of width ssm_conv over
    the x/B/C streams, learned dt bias, and a gated RMSNorm before out-proj.
    """
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    N = cfg.ssm_state
    H = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * N
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    # in_proj emits [z (gate), x, B, C, dt] like the reference mamba2.
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_inner + 2 * N + H, dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim))).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),  # A = -exp(A_log)
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 1e-2))).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),  # skip connection
        "out_norm": rmsnorm_init(d_inner, dtype),
        "out_proj": dense_init(ks[2], d_inner, d, dtype),
    }


def _mamba2_split(params, cfg, u):
    """Shared projection + causal conv. u: (B, S, D). Returns z, x, Bm, Cm, dt."""
    d_inner = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    H = d_inner // cfg.ssm_head_dim
    proj = u @ params["in_proj"]  # (B,S,2*di+2N+H)
    z, xBC, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xBC, dt, d_inner, N, H


def _causal_conv(xBC, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv, width K. xBC: (B,S,C). conv_state: (B,K-1,C)."""
    K = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[-1]), xBC.dtype)
    else:
        pad = conv_state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)  # (B, S+K-1, C)
    out = sum(xp[:, i : i + xBC.shape[1]] * conv_w[i] for i in range(K))
    new_state = xp[:, xp.shape[1] - (K - 1) :]
    return jax.nn.silu(out + conv_b), new_state


def _mamba2_qkva(params, cfg, x_conv, dt_raw, d_inner, N, H):
    """Map conv output + dt to the GLA (q, k, v, log_a) views."""
    P = cfg.ssm_head_dim
    x, Bm, Cm = jnp.split(x_conv, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (...,H)
    A = -jnp.exp(params["A_log"])  # (H,) negative
    log_a = dt * A  # (..., H)
    shape = x.shape[:-1]
    xh = x.reshape(*shape, H, P)
    v = xh * dt[..., None].astype(x.dtype)  # dt folds into v (SSD form)
    # Single B/C group broadcast across heads.
    k = jnp.broadcast_to(Bm[..., None, :], (*shape, H, N)).astype(x.dtype)
    q = jnp.broadcast_to(Cm[..., None, :], (*shape, H, N)).astype(x.dtype)
    return q, k, v, log_a, xh


def _gla_dispatch(cfg, q, k, v, log_a):
    """jnp chunked scan (default) or the Pallas GLA kernel (TPU hot path)."""
    if cfg is not None and getattr(cfg, "use_pallas", False):
        from repro.kernels import ops as kops

        return kops.gla(q, k, v, log_a)
    return chunked_gla(q, k, v, log_a)


def mamba2_block(params, cfg, u):
    """Full-sequence Mamba2 (train / prefill). u: (B, S, D) -> (B, S, D)."""
    z, xBC, dt_raw, d_inner, N, H = _mamba2_split(params, cfg, u)
    x_conv, _ = _causal_conv(xBC, params["conv_w"], params["conv_b"])
    q, k, v, log_a, xh = _mamba2_qkva(params, cfg, x_conv, dt_raw, d_inner, N, H)
    y = _gla_dispatch(cfg, q, k, v, log_a)
    y = y + xh * params["D"][:, None].astype(xh.dtype)
    y = y.reshape(*u.shape[:2], d_inner)
    y = rmsnorm(y * jax.nn.silu(z), params["out_norm"], cfg.norm_eps)
    return y @ params["out_proj"]


def mamba2_init_cache(params, cfg, batch: int, dtype):
    d_inner = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    H = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, H, N, cfg.ssm_head_dim), jnp.float32),
    }


def mamba2_prefill(params, cfg, u):
    """Like mamba2_block but also returns the decode cache."""
    z, xBC, dt_raw, d_inner, N, H = _mamba2_split(params, cfg, u)
    x_conv, conv_state = _causal_conv(xBC, params["conv_w"], params["conv_b"])
    q, k, v, log_a, xh = _mamba2_qkva(params, cfg, x_conv, dt_raw, d_inner, N, H)
    y = chunked_gla(q, k, v, log_a)
    ssm_state = gla_final_state(k, v, log_a)
    y = y + xh * params["D"][:, None].astype(xh.dtype)
    y = y.reshape(*u.shape[:2], d_inner)
    y = rmsnorm(y * jax.nn.silu(z), params["out_norm"], cfg.norm_eps)
    return y @ params["out_proj"], {"conv": conv_state, "ssm": ssm_state}


def mamba2_decode(params, cfg, u, cache):
    """One-token step. u: (B, 1, D). O(1) state — enables long_500k."""
    z, xBC, dt_raw, d_inner, N, H = _mamba2_split(params, cfg, u)
    x_conv, conv_state = _causal_conv(
        xBC, params["conv_w"], params["conv_b"], conv_state=cache["conv"]
    )
    q, k, v, log_a, xh = _mamba2_qkva(params, cfg, x_conv, dt_raw, d_inner, N, H)
    # Squeeze the length-1 axis for the recurrent step.
    state, y = gla_decode_step(
        cache["ssm"], q[:, 0], k[:, 0], v[:, 0], log_a[:, 0]
    )
    y = y[:, None].astype(u.dtype) + xh * params["D"][:, None].astype(xh.dtype)
    y = y.reshape(u.shape[0], 1, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), params["out_norm"], cfg.norm_eps)
    return y @ params["out_proj"], {"conv": conv_state, "ssm": state}
