"""Architecture configuration.

One ``ArchConfig`` instance per assigned architecture (see repro/configs/).
``reduced()`` derives the CPU smoke-test variant (≤2 layers, d_model ≤ 512,
≤4 experts) from the same family, per the assignment's requirements.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple

ArchType = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
BlockKind = Literal["attn", "mamba2", "mlstm", "slstm"]


@dataclasses.dataclass(frozen=True)
class BayesConfig:
    """SFVI latent decomposition for LLM-scale models (DESIGN.md §3).

    θ   = backbone weights;
    Z_G = global Gaussian latent over a rank-r LM-head adapter;
    Z_L = per-silo latents (rank-r_l head adapter + logit bias).
    """

    global_rank: int = 8
    local_rank: int = 2
    local_bias: bool = True

    def global_dim(self, d_model: int, vocab: int) -> int:
        return self.global_rank * (d_model + vocab)

    def local_dim(self, d_model: int, vocab: int) -> int:
        d = self.local_rank * (d_model + vocab)
        if self.local_bias:
            d += vocab
        return d


@dataclasses.dataclass(frozen=True)
class PerfConfig:
    """Beyond-paper performance levers (EXPERIMENTS.md §Perf). All default
    OFF — the paper-faithful baseline; the dry-run's --optimized flag and
    the §Perf iterations turn them on one at a time."""

    masked_nll: bool = False   # gold-logit gather -> masked sum (shards over vocab)
    pad_vocab: bool = False    # pad embed/head vocab dim to a multiple of 256
    zero_opt: bool = False     # ZeRO: shard Adam state over the data axes
    act_shard: bool = False    # sequence-sharded activations between units
    microbatch: int = 0        # gradient accumulation over k microbatches
    pad_heads: int = 0         # pad ATTENTION ACTIVATIONS to a multiple of
                               # this head count (0=off; 16 = model axis) so
                               # the QK contraction shards on heads, not hd

    @property
    def any(self) -> bool:
        return any((self.masked_nll, self.pad_vocab, self.zero_opt,
                    self.act_shard, self.microbatch > 1, self.pad_heads > 0))


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: ArchType
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads

    # attention details
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope: bool = False  # Qwen2-VL multimodal RoPE
    sliding_window: Optional[int] = None  # enables long_500k for dense archs

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    d_expert: int = 0  # per-expert FFN width (olmoe: 1024)
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64

    # hybrid (zamba2): attention block period; others are mamba2
    hybrid_attn_period: int = 0  # 0 = not hybrid; e.g. 6 = every 6th block is attn
    shared_attn: bool = False  # zamba2: ONE attn block's weights reused at every period
    # xLSTM: sLSTM block period; others are mLSTM
    slstm_period: int = 0

    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500  # whisper 30 s of audio frames

    # VLM stub frontend
    num_vision_tokens: int = 0  # prepended patch embeddings

    # training details
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # SFVI
    bayes: BayesConfig = dataclasses.field(default_factory=BayesConfig)

    # Roofline-analysis mode (launch/roofline.py): unroll the unit stack and
    # use unblocked attention so XLA cost_analysis counts every FLOP (scan
    # bodies are otherwise counted ONCE, not x trip-count).
    analysis_mode: bool = False

    # Performance levers (all off = paper-faithful baseline)
    perf: PerfConfig = dataclasses.field(default_factory=PerfConfig)

    # Execute attention/GLA through the Pallas kernels (kernels/): the TPU
    # hot path. On CPU the kernels run in interpret mode (correct, slow) —
    # smoke tests exercise it on small shapes; default remains the jnp path.
    use_pallas: bool = False

    source: str = ""  # paper/model-card citation

    # ------------------------------------------------------------------

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def padded_vocab(self) -> int:
        """Embed/head table rows. §Perf lever 2: padding the vocab to a
        multiple of 256 makes the head matmul and the (B,S,V) logits
        shardable on any model-axis size (whisper: 51865 -> 52096)."""
        if self.perf.pad_vocab:
            return -(-self.vocab_size // 256) * 256
        return self.vocab_size

    def block_kind(self, layer_idx: int) -> BlockKind:
        """Which block family does layer ``layer_idx`` use?"""
        if self.arch_type == "hybrid" and self.hybrid_attn_period:
            return "attn" if (layer_idx % self.hybrid_attn_period) == (self.hybrid_attn_period - 1) else "mamba2"
        if self.arch_type == "ssm" and self.slstm_period:
            return "slstm" if (layer_idx % self.slstm_period) == (self.slstm_period - 1) else "mlstm"
        if self.arch_type == "ssm":
            return "mlstm"
        return "attn"

    @property
    def block_pattern(self) -> Tuple[BlockKind, ...]:
        """The repeating unit of block kinds (for scan-over-layers grouping)."""
        kinds = tuple(self.block_kind(i) for i in range(self.num_layers))
        return kinds

    def supports_long_context(self) -> bool:
        """long_500k eligibility: recurrent state or sliding window."""
        if self.is_encoder_decoder:
            return False  # see DESIGN.md §Arch-applicability (whisper skip)
        if self.arch_type in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def long_context_variant(self) -> ArchConfig:
        """Sub-quadratic variant used for long_500k: dense archs get a
        sliding window (block-sparse-in-time attention); SSM/hybrid archs
        are already O(1)-state and return themselves."""
        if self.arch_type in ("ssm", "hybrid") or self.sliding_window is not None:
            return self
        return dataclasses.replace(
            self, name=self.name + "-swa", sliding_window=8192
        )

    def reduced(self) -> ArchConfig:
        """CPU smoke-test variant of the same family."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=2,
            d_model=min(self.d_model, 128),
            num_heads=4,
            num_kv_heads=min(max(1, self.num_kv_heads * 4 // self.num_heads), 4),
            head_dim=32,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2) if self.num_experts_per_tok else 0,
            d_expert=min(self.d_expert, 64) if self.d_expert else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            sliding_window=min(self.sliding_window, 128) if self.sliding_window else None,
            hybrid_attn_period=min(self.hybrid_attn_period, 2) if self.hybrid_attn_period else 0,
            slstm_period=2 if self.slstm_period else 0,
            num_encoder_layers=min(self.num_encoder_layers, 2),
            encoder_seq_len=min(self.encoder_seq_len, 64),
            num_vision_tokens=min(self.num_vision_tokens, 16) if self.num_vision_tokens else 0,
            dtype="float32",
            bayes=BayesConfig(global_rank=2, local_rank=1),
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
