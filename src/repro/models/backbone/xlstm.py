"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, true recurrence).

mLSTM is a gated linear attention: with forget gate f_t and input gate i_t,

    C_t = sigmoid_f(f_t) C_{t-1} + exp(i_t - m_t) k_t v_t^T
    y_t = q_t C_t / max(|q_t n_t|, 1)

We fold the input gate into k and route through the shared ``chunked_gla``
scan (ssm.py); the normalizer n_t is obtained by augmenting v with a ones
column — one extra dv column instead of a second scan. Stabilization uses
the running maximum of the cumulative log gates, applied per chunk.

sLSTM has genuine hidden-to-hidden recurrence (block-diagonal per head), so
it admits no parallel form — it lowers to a ``lax.scan`` over time. This is
the paper-faithful choice; xLSTM-1.3b places sLSTM in 1 of every 8 blocks
(the 7:1 ratio of the paper) so the scan is a small fraction of total work.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.backbone.layers import dense_init, rmsnorm, rmsnorm_init
from repro.models.backbone.ssm import (_gla_dispatch, chunked_gla,
                                        gla_decode_step, gla_final_state)


# ---------------------------------------------------------------------------
# mLSTM block (projection factor 2, conv-free variant)
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg):
    d = cfg.d_model
    d_inner = 2 * d
    H = cfg.num_heads
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "w_up": dense_init(ks[0], d, 2 * d_inner, dtype),  # [x_inner, z_gate]
        "wq": dense_init(ks[1], d_inner, d_inner, dtype),
        "wk": dense_init(ks[2], d_inner, d_inner, dtype),
        "wv": dense_init(ks[3], d_inner, d_inner, dtype),
        "w_gates": dense_init(ks[4], d_inner, 2 * H, dtype, scale=0.01),
        "f_bias": jnp.linspace(3.0, 6.0, H).astype(jnp.float32),  # open forget gates
        "i_bias": jnp.zeros((H,), jnp.float32),
        "out_norm": rmsnorm_init(d_inner, dtype),
        "w_down": dense_init(ks[5], d_inner, d, dtype),
    }


def _mlstm_qkva(params, cfg, u):
    """u: (B, S, D) -> q, k, v(+ones), log_f, plus the gate branch."""
    B, S, _ = u.shape
    d_inner = 2 * cfg.d_model
    H = cfg.num_heads
    P = d_inner // H
    xz = u @ params["w_up"]
    x, z = jnp.split(xz, 2, axis=-1)
    q = (x @ params["wq"]).reshape(B, S, H, P)
    k = (x @ params["wk"]).reshape(B, S, H, P) / math.sqrt(P)
    v = (x @ params["wv"]).reshape(B, S, H, P)
    gates = (x @ params["w_gates"]).astype(jnp.float32).reshape(B, S, H, 2)
    log_f = jax.nn.log_sigmoid(gates[..., 0] + params["f_bias"])  # (B,S,H)
    log_i = gates[..., 1] + params["i_bias"]
    return q, k, v, log_f, log_i, z, d_inner, H, P


def _mlstm_combine(params, cfg, y, nrm, z, B, S, d_inner, m):
    """Normalize, gate, down-project.

    Denominator is max(|q.n|, exp(-m)) — with the exp(i - m) scaling folded
    into k, this equals the paper's unstabilized max(|q.n|, 1) EXACTLY, so
    the result is independent of m (streaming prefill->decode consistent).
    """
    y = y / jnp.maximum(jnp.abs(nrm), jnp.exp(-m)[..., None])
    y = y.reshape(B, S, d_inner).astype(z.dtype)
    y = rmsnorm(y, params["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ params["w_down"]


def mlstm_block(params, cfg, u):
    B, S, _ = u.shape
    q, k, v, log_f, log_i, z, d_inner, H, P = _mlstm_qkva(params, cfg, u)
    # Fold input gate into k; stabilize with a global per-head max.
    m = jnp.max(log_i, axis=1, keepdims=True)  # (B,1,H)
    k_g = k * jnp.exp(log_i - m)[..., None].astype(k.dtype)
    v_aug = jnp.concatenate([v, jnp.ones((B, S, H, 1), v.dtype)], axis=-1)
    y_aug = _gla_dispatch(cfg, q, k_g, v_aug, log_f)
    y, nrm = y_aug[..., :P], y_aug[..., P:]
    return _mlstm_combine(params, cfg, y, nrm, z, B, S, d_inner, m)


def mlstm_init_cache(params, cfg, batch: int, dtype):
    d_inner = 2 * cfg.d_model
    H = cfg.num_heads
    P = d_inner // H
    return {
        "state": jnp.zeros((batch, H, P, P + 1), jnp.float32),
        "m": jnp.zeros((batch, 1, H), jnp.float32),
    }


def mlstm_prefill(params, cfg, u):
    B, S, _ = u.shape
    q, k, v, log_f, log_i, z, d_inner, H, P = _mlstm_qkva(params, cfg, u)
    m = jnp.max(log_i, axis=1, keepdims=True)
    k_g = k * jnp.exp(log_i - m)[..., None].astype(k.dtype)
    v_aug = jnp.concatenate([v, jnp.ones((B, S, H, 1), v.dtype)], axis=-1)
    y_aug = chunked_gla(q, k_g, v_aug, log_f)
    state = gla_final_state(k_g, v_aug, log_f)
    y, nrm = y_aug[..., :P], y_aug[..., P:]
    out = _mlstm_combine(params, cfg, y, nrm, z, B, S, d_inner, m)
    return out, {"state": state, "m": m}


def mlstm_decode(params, cfg, u, cache):
    B = u.shape[0]
    q, k, v, log_f, log_i, z, d_inner, H, P = _mlstm_qkva(params, cfg, u)
    m = cache["m"]  # keep the prefill stabilizer (running max would rescale state)
    k_g = (k * jnp.exp(log_i - m)[..., None].astype(k.dtype))[:, 0]
    v_aug = jnp.concatenate([v, jnp.ones((B, 1, H, 1), v.dtype)], axis=-1)[:, 0]
    state, y_aug = gla_decode_step(cache["state"], q[:, 0], k_g, v_aug, log_f[:, 0])
    y_aug = y_aug[:, None]
    y, nrm = y_aug[..., :P], y_aug[..., P:]
    out = _mlstm_combine(params, cfg, y.astype(u.dtype), nrm, z, B, 1, d_inner, m)
    return out, {"state": state, "m": m}


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def slstm_init(key, cfg):
    d = cfg.d_model
    H = cfg.num_heads
    P = d // H
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        # 4 gates (i, f, z, o) from input
        "w_in": dense_init(ks[0], d, 4 * d, dtype),
        # block-diagonal recurrence: per head (P, 4P)
        "r": (jax.random.normal(ks[1], (H, P, 4 * P)) / math.sqrt(P)).astype(jnp.float32),
        "b": jnp.concatenate(
            [jnp.zeros((d,)), jnp.linspace(3.0, 6.0, d), jnp.zeros((2 * d,))]
        ).astype(jnp.float32),
        "out_norm": rmsnorm_init(d, dtype),
        "w_ff1": dense_init(ks[2], d, 4 * d // 3, dtype),
        "w_ff2": dense_init(ks[3], 4 * d // 3, d, dtype),
    }


def slstm_init_cache(params, cfg, batch: int, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    P = d // H
    z = jnp.zeros((batch, H, P), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def _slstm_cell(params, cfg, x_t, state):
    """x_t: (B, 4d) pre-projected gates input; state dict of (B,H,P)."""
    H = cfg.num_heads
    d = cfg.d_model
    P = d // H
    B = x_t.shape[0]
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    rec = jnp.einsum("bhp,hpk->bhk", h, params["r"])  # (B,H,4P)
    # Gate-major layout: x_t (B, 4d) -> (B, 4, H, P); recurrence likewise.
    xg = x_t.astype(jnp.float32).reshape(B, 4, H, P)
    rg = rec.reshape(B, H, 4, P).transpose(0, 2, 1, 3)  # (B,4,H,P)
    bg = params["b"].reshape(4, H, P)
    z_in = xg + rg + bg[None]
    i_t, f_t, z_t, o_t = z_in[:, 0], z_in[:, 1], z_in[:, 2], z_in[:, 3]
    # Stabilized exponential gating (xLSTM paper eqs. 15-17).
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + m, i_t)
    i_s = jnp.exp(i_t - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(z_t)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1.0)
    # carry m at (B,H,1)? keep per-unit m: shapes (B,H,P)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_block(params, cfg, u):
    """u: (B, S, D). lax.scan over time (true recurrence)."""
    B, S, d = u.shape
    H = cfg.num_heads
    P = d // H
    x_all = u @ params["w_in"]  # (B,S,4d)
    state0 = {
        "c": jnp.zeros((B, H, P), jnp.float32),
        "n": jnp.zeros((B, H, P), jnp.float32),
        "h": jnp.zeros((B, H, P), jnp.float32),
        "m": jnp.zeros((B, H, P), jnp.float32),
    }

    def step(state, x_t):
        new = _slstm_cell(params, cfg, x_t, state)
        return new, new["h"]

    _, hs = jax.lax.scan(step, state0, jnp.moveaxis(x_all, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(u.dtype)
    y = rmsnorm(y, params["out_norm"], cfg.norm_eps)
    return jax.nn.gelu(y @ params["w_ff1"]) @ params["w_ff2"]


def slstm_prefill(params, cfg, u):
    B, S, d = u.shape
    H = cfg.num_heads
    P = d // H
    x_all = u @ params["w_in"]
    state0 = {
        "c": jnp.zeros((B, H, P), jnp.float32),
        "n": jnp.zeros((B, H, P), jnp.float32),
        "h": jnp.zeros((B, H, P), jnp.float32),
        "m": jnp.zeros((B, H, P), jnp.float32),
    }

    def step(state, x_t):
        new = _slstm_cell(params, cfg, x_t, state)
        return new, new["h"]

    final, hs = jax.lax.scan(step, state0, jnp.moveaxis(x_all, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(u.dtype)
    y = rmsnorm(y, params["out_norm"], cfg.norm_eps)
    return jax.nn.gelu(y @ params["w_ff1"]) @ params["w_ff2"], final


def slstm_decode(params, cfg, u, cache):
    B, _, d = u.shape
    x_t = (u @ params["w_in"])[:, 0]
    new = _slstm_cell(params, cfg, x_t, cache)
    y = new["h"].reshape(B, 1, d).astype(u.dtype)
    y = rmsnorm(y, params["out_norm"], cfg.norm_eps)
    return jax.nn.gelu(y @ params["w_ff1"]) @ params["w_ff2"], new
