"""Transformer/MoE/SSM backbone stack for the assigned architectures."""
from repro.models.backbone.config import ArchConfig, BayesConfig

__all__ = ["ArchConfig", "BayesConfig"]
