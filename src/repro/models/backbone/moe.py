"""Mixture-of-Experts layer: top-k routing with capacity-based einsum
dispatch (the classic shard-friendly formulation of GShard / Switch / t5x).

TPU adaptation (DESIGN.md §5): the expert dimension E is sharded along the
``model`` mesh axis (expert parallelism); tokens arrive sharded along
``data``. The dispatch einsum reshards (groups@data, E, C, D) ->
(E@model, ...) — XLA SPMD lowers that resharding to the all-to-all that a
hand-written torch/NCCL MoE would issue explicitly. Router logits and
load-balance statistics are computed where the tokens live, so per-silo
routing information never crosses the silo boundary (the paper's privacy
structure extends to the router).

Groups are sequence chunks of ``group_size`` tokens; capacity is
``group_size * top_k / E * capacity_factor``. Tokens overflowing an
expert's capacity within their group are dropped (standard GShard
behaviour) — the residual path carries them unchanged.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.backbone.layers import dense_init


def moe_init(key, cfg):
    d = cfg.d_model
    E = cfg.num_experts
    dff = cfg.d_expert if cfg.d_expert else cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "router": dense_init(ks[0], d, E, jnp.float32, scale=0.02),
        "w_gate": (s * jax.random.normal(ks[1], (E, d, dff))).astype(dtype),
        "w_up": (s * jax.random.normal(ks[2], (E, d, dff))).astype(dtype),
        "w_down": ((1.0 / math.sqrt(dff)) * jax.random.normal(ks[3], (E, dff, d))).astype(dtype),
    }


def _route(router_w, x_flat, E: int, top_k: int):
    """Router probabilities + top-k assignment. x_flat: (T, D)."""
    logits = (x_flat.astype(jnp.float32) @ router_w).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # (T, k)
    # Renormalize the selected gates (standard for top-k > 1).
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    return probs, gate_vals, expert_idx


def load_balance_loss(probs: jnp.ndarray, expert_idx: jnp.ndarray, E: int) -> jnp.ndarray:
    """Switch-style auxiliary loss: E * <fraction routed to e> . <router prob e>."""
    T = probs.shape[0]
    counts = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(expert_idx.size, 1)
    mean_prob = probs.mean(axis=0)
    return E * jnp.sum(frac * mean_prob)


def _dispatch_masks(expert_idx, gate_vals, E: int, capacity: int):
    """Build (T, E, C) dispatch (bool->dtype) and combine (gated) tensors."""
    T, k = expert_idx.shape
    # Position of each (token, slot) in its expert's queue, computed per
    # expert via a masked cumulative sum over the flattened (T*k) order.
    flat_expert = expert_idx.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.float32)  # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot) * onehot  # (T*k, E)
    pos = pos_in_expert.sum(-1).astype(jnp.int32)  # (T*k,)
    keep = pos < capacity
    pos_oh = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)  # (T*k, C)
    disp = (onehot * keep[:, None].astype(jnp.float32))[:, :, None] * pos_oh[:, None, :]
    disp = disp.reshape(T, k, E, capacity).sum(axis=1)  # (T, E, C)
    comb = (
        (onehot * (gate_vals.reshape(-1)[:, None] * keep[:, None]))[:, :, None]
        * pos_oh[:, None, :]
    ).reshape(T, k, E, capacity).sum(axis=1)
    return disp, comb


def moe_block(params, cfg, x, group_size: int = 1024) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss). Grouped capacity-based top-k MoE."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    gs = min(group_size, B * S)
    T = B * S
    assert T % gs == 0, f"tokens {T} not divisible by group {gs}"
    G = T // gs
    capacity = max(int(gs * k / E * cfg.capacity_factor), 1)
    xg = x.reshape(G, gs, D)

    probs, gate_vals, expert_idx = jax.vmap(
        lambda xf: _route(params["router"], xf, E, k)
    )(xg)
    aux = jax.vmap(lambda p, i: load_balance_loss(p, i, E))(probs, expert_idx).mean()

    disp, comb = jax.vmap(lambda ei, gv: _dispatch_masks(ei, gv, E, capacity))(
        expert_idx, gate_vals
    )  # (G, gs, E, C) each

    # Dispatch: (G,gs,E,C),(G,gs,D) -> (E, G, C, D). Expert-major layout so
    # the expert matmuls shard cleanly along the model axis.
    xe = jnp.einsum("gsec,gsd->egcd", disp.astype(x.dtype), xg)
    h = jnp.einsum("egcd,edf->egcf", xe, params["w_gate"])
    u = jnp.einsum("egcd,edf->egcf", xe, params["w_up"])
    y_e = jnp.einsum("egcf,efd->egcd", jax.nn.silu(h) * u, params["w_down"])
    y = jnp.einsum("gsec,egcd->gsd", comb.astype(x.dtype), y_e)
    return y.reshape(B, S, D), aux


def moe_block_dense(params, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Reference/decode path: every expert on every token, gate-weighted sum
    restricted to the top-k (no capacity drops). O(E/k) extra FLOPs — used
    for single-token decode (T = B is tiny) and as the correctness oracle
    for ``moe_block``."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    xf = x.reshape(-1, D)
    probs, gate_vals, expert_idx = _route(params["router"], xf, E, k)
    aux = load_balance_loss(probs, expert_idx, E)
    # Gate matrix (T, E): gate value where selected, else 0.
    gates = jnp.zeros_like(probs).at[
        jnp.arange(xf.shape[0])[:, None], expert_idx
    ].set(gate_vals)
    h = jnp.einsum("td,edf->etf", xf, params["w_gate"])
    u = jnp.einsum("td,edf->etf", xf, params["w_up"])
    y_e = jnp.einsum("etf,efd->etd", jax.nn.silu(h) * u, params["w_down"])
    y = jnp.einsum("te,etd->td", gates.astype(x.dtype), y_e)
    return y.reshape(B, S, D), aux
