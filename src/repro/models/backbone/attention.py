"""Attention: GQA with optional qk-norm, causal/bidirectional/sliding-window
masking, chunked (flash-style) softmax for long prefill, KV-cache decode with
ring-buffer sliding windows, and cross-attention for the enc-dec path.

The chunked implementation is the pure-JAX analogue of the Pallas flash
kernel in repro/kernels/attention.py (which is the TPU-target hot path);
both share the same oracle (kernels/ref.py).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.backbone.layers import (
    apply_mrope,
    apply_rope,
    dense_init,
    rmsnorm,
    rmsnorm_init,
)

NEG_INF = -1e30


def attn_init(key, cfg):
    hd = cfg.head_dim_
    dtype = jnp.dtype(cfg.dtype)
    k = jax.random.split(key, 6)
    params = {
        "wq": dense_init(k[0], cfg.d_model, cfg.num_heads * hd, dtype),
        "wk": dense_init(k[1], cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(k[2], cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(k[3], cfg.num_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        params["q_norm"] = rmsnorm_init(hd, dtype)
        params["k_norm"] = rmsnorm_init(hd, dtype)
    return params


def _project_qkv(params, cfg, x, positions):
    B, S, _ = x.shape
    hd = cfg.head_dim_
    q = (x @ params["wq"]).reshape(B, S, cfg.num_heads, hd)
    k = (x @ params["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = (x @ params["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    if positions is not None:
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k: jnp.ndarray, num_heads: int) -> jnp.ndarray:
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating each KV group."""
    B, S, KV, hd = k.shape
    rep = num_heads // KV
    return jnp.repeat(k, rep, axis=2)


def _pad_heads(cfg, q, kf, vf):
    """§Perf lever 6 (pad_heads): zero-pad the head axis of the attention
    ACTIVATIONS to a multiple of the model-axis size. When num_heads does
    not divide the tensor-parallel degree (llama3.2: 24 heads on 16-way),
    GSPMD falls back to sharding head_dim, and the QK^T contraction over
    the sharded hd emits a partial-sum ALL-REDUCE of the full (B,H,S,S)
    score tensor per layer. With padded heads the contraction is local.
    The padded heads' outputs are sliced away before w_o — mathematically
    exact (params unchanged, gradients of real heads unchanged)."""
    m = cfg.perf.pad_heads
    H = q.shape[2]
    if not m or H % m == 0:
        return q, kf, vf, H
    Hp = -(-H // m) * m
    pad = ((0, 0), (0, 0), (0, Hp - H), (0, 0))
    q = jnp.pad(q, pad)
    kf = jnp.pad(kf, pad)
    vf = jnp.pad(vf, pad)
    try:  # hint GSPMD to shard the padded head axis (no-op without a mesh)
        from jax.sharding import PartitionSpec as _P

        spec = _P(None, None, "model", None)
        q = jax.lax.with_sharding_constraint(q, spec)
        kf = jax.lax.with_sharding_constraint(kf, spec)
        vf = jax.lax.with_sharding_constraint(vf, spec)
    except Exception:
        pass
    return q, kf, vf, H


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Flash-style online-softmax attention, O(S·W) memory.

    q: (B, Sq, H, hd); k, v: (B, Skv, H, hd) (already GQA-expanded).
    ``q_offset`` is the absolute position of q[0] relative to k[0]
    (decode: Skv-1; prefill: 0).
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = -(-Sq // q_chunk)
    nkv = -(-Skv // kv_chunk)
    # Pad to multiples (padding keys are masked out).
    q_pad = nq * q_chunk - Sq
    kv_pad = nkv * kv_chunk - Skv
    qp = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    qp = qp.reshape(B, nq, q_chunk, H, hd)
    kp = kp.reshape(B, nkv, kv_chunk, H, hd)
    vp = vp.reshape(B, nkv, kv_chunk, H, hd)

    q_pos_base = jnp.arange(nq)[:, None] * q_chunk + jnp.arange(q_chunk)[None]  # (nq, qc)
    kv_pos_base = jnp.arange(nkv)[:, None] * kv_chunk + jnp.arange(kv_chunk)[None]

    def q_block(qi, q_blk):
        # Online softmax over kv blocks.
        q_pos = q_pos_base[qi] + q_offset  # (qc,)

        def kv_step(carry, kv_idx):
            acc, m, l = carry
            k_blk = kp[:, kv_idx]  # (B, kc, H, hd)
            v_blk = vp[:, kv_idx]
            kv_pos = kv_pos_base[kv_idx]  # (kc,)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk).astype(jnp.float32) * scale
            mask = kv_pos[None, :] < Skv  # mask kv padding
            if causal:
                mask = mask & (kv_pos[None, :] <= q_pos[:, None])
            if sliding_window is not None:
                mask = mask & (kv_pos[None, :] > q_pos[:, None] - sliding_window)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, H, q_chunk, hd), jnp.float32)
        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), jnp.arange(nkv))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out  # (B, H, qc, hd)

    outs = jax.lax.map(lambda qi: q_block(qi, qp[:, qi]), jnp.arange(nq))
    # (nq, B, H, qc, hd) -> (B, nq*qc, H, hd)
    out = jnp.transpose(outs, (1, 0, 3, 2, 4)).reshape(B, nq * q_chunk, H, hd)
    return out[:, :Sq].astype(q.dtype)


def full_attention(q, k, v, causal=True, sliding_window=None, q_offset=0):
    """Naive reference attention (small S only; used by smoke tests/oracles)."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / math.sqrt(hd)
    q_pos = jnp.arange(Sq) + q_offset
    kv_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if sliding_window is not None:
        mask &= kv_pos[None, :] > q_pos[:, None] - sliding_window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


# ---------------------------------------------------------------------------
# Block-level entry points
# ---------------------------------------------------------------------------

def attention_block(params, cfg, x, positions, causal=True, use_chunked=None):
    """Self-attention over a full sequence (train / prefill). Returns output
    of shape (B, S, D)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    window = cfg.sliding_window
    if cfg.use_pallas:
        # TPU hot path: the Pallas flash kernel takes UNEXPANDED KV heads
        # (GQA handled in its index maps — KV tiles fetched once per group).
        from repro.kernels import ops as kops

        out = kops.flash_attention(q, k, v, causal=causal, window=window)
        return out.reshape(B, S, cfg.num_heads * cfg.head_dim_) @ params["wo"]
    k = _repeat_kv(k, cfg.num_heads)
    v = _repeat_kv(v, cfg.num_heads)
    q, k, v, h_real = _pad_heads(cfg, q, k, v)
    if use_chunked is None:
        use_chunked = S > 2048 and not cfg.analysis_mode
    if use_chunked:
        out = chunked_attention(q, k, v, causal=causal, sliding_window=window)
    else:
        out = full_attention(q, k, v, causal=causal, sliding_window=window)
    out = out[:, :, :h_real]  # drop padded heads (exact)
    return out.reshape(B, S, cfg.num_heads * cfg.head_dim_) @ params["wo"]


def attention_prefill(params, cfg, x, positions):
    """Prefill: like attention_block but also returns the KV cache
    (B, S, KV, hd) pair for subsequent decode."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    kf = _repeat_kv(k, cfg.num_heads)
    vf = _repeat_kv(v, cfg.num_heads)
    qp, kf, vf, h_real = _pad_heads(cfg, q, kf, vf)
    if cfg.analysis_mode:
        out = full_attention(qp, kf, vf, causal=True, sliding_window=cfg.sliding_window)
    else:
        out = chunked_attention(qp, kf, vf, causal=True, sliding_window=cfg.sliding_window)
    out = out[:, :, :h_real]
    y = out.reshape(B, S, cfg.num_heads * cfg.head_dim_) @ params["wo"]
    return y, {"k": k, "v": v, "pos": jnp.asarray(S, jnp.int32)}


def init_kv_cache(cfg, batch: int, max_len: int, dtype) -> dict:
    hd = cfg.head_dim_
    window = cfg.sliding_window
    cache_len = min(window, max_len) if window else max_len
    return {
        "k": jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),  # absolute position of next token
    }


def attention_decode(params, cfg, x, cache, positions=None):
    """One-token decode with KV cache. x: (B, 1, D).

    Sliding-window archs keep a ring buffer of ``window`` entries — O(1)
    memory in sequence length, which is what makes long_500k lowerable.
    ``positions`` overrides the rope position (needed for M-RoPE, whose
    text positions differ from the raw cache counter).
    """
    B, _, _ = x.shape
    hd = cfg.head_dim_
    pos = cache["pos"]
    if positions is None:
        positions = jnp.full((B, 1), pos, jnp.int32)
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[None], (3, B, 1))
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)

    cache_len = cache["k"].shape[1]
    slot = jnp.mod(pos, cache_len)  # ring-buffer index (== pos when no window)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)

    kf = _repeat_kv(k, cfg.num_heads)
    vf = _repeat_kv(v, cfg.num_heads)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kf).astype(jnp.float32) / math.sqrt(hd)
    # Valid entries: absolute positions (pos - age) with age < cache_len,
    # i.e. every slot written so far.
    idx = jnp.arange(cache_len)
    written = jnp.where(pos + 1 >= cache_len, cache_len, pos + 1)
    valid = idx < written
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(vf.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    y = out.reshape(B, 1, cfg.num_heads * hd) @ params["wo"]
    new_cache = {"k": k, "v": v, "pos": pos + 1}
    return y, new_cache


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg):
    hd = cfg.head_dim_
    dtype = jnp.dtype(cfg.dtype)
    k = jax.random.split(key, 4)
    return {
        "wq": dense_init(k[0], cfg.d_model, cfg.num_heads * hd, dtype),
        "wk": dense_init(k[1], cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(k[2], cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(k[3], cfg.num_heads * hd, cfg.d_model, dtype),
    }


def cross_attention(params, cfg, x, memory):
    """x: (B, Sq, D) queries; memory: (B, Skv, D) encoder states."""
    B, Sq, _ = x.shape
    Skv = memory.shape[1]
    hd = cfg.head_dim_
    q = (x @ params["wq"]).reshape(B, Sq, cfg.num_heads, hd)
    k = (memory @ params["wk"]).reshape(B, Skv, cfg.num_kv_heads, hd)
    v = (memory @ params["wv"]).reshape(B, Skv, cfg.num_kv_heads, hd)
    kf = _repeat_kv(k, cfg.num_heads)
    vf = _repeat_kv(v, cfg.num_heads)
    out = full_attention(q, kf, vf, causal=False)
    return out.reshape(B, Sq, cfg.num_heads * hd) @ params["wo"]
