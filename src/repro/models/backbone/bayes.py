"""SFVI <-> backbone integration: the paper's structured latent decomposition
applied to LLM-scale architectures (DESIGN.md §3, "fully-Bayesian FedPop"
generalization of paper §4.1).

    θ    = backbone weights (embedding, blocks, head)            — trainable
    Z_G  = global latent: rank-r_g low-rank LM-head adapter
           (A_G: r_g x d, B_G: r_g x V) + a log-scale ω_G           — shared
    Z_Lj = per-silo latent: rank-r_l head adapter + logit bias   — private

Generative model (paper eqs. (1)-(3)):

    Z_G  ~ N(0, I)                                   [adapter] , ω_G ~ N(0,1)
    Z_Lj | Z_G ~ N(0, exp(2 ω_G) I)                  (hierarchical scale —
                                                      exactly the GLMM/BNN
                                                      pattern of §4.1/S3.1)
    y_j | Z_G, Z_Lj ~ Categorical(softmax(logits))

    logits = h W_head + (h A_Gᵀ) B_G / r_g + (h A_Ljᵀ) B_Lj / r_l + b_j

The low-rank path means the Bayesian head costs O(r (d+V)) extra FLOPs per
token — negligible next to the backbone — yet every silo gets a personal,
uncertainty-carrying head, and the global adapter is inferred jointly
across silos exactly as SFVI prescribes.

The variational family is the paper's diagonal Gaussian over both Z_G and
(batched over silos) Z_Lj — the same choice the paper makes for its
high-dimensional MNIST experiment (§S2.1).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.backbone.config import ArchConfig

PyTree = Any


def latent_dims(cfg: ArchConfig) -> Tuple[int, int]:
    d, V = cfg.d_model, cfg.vocab_size
    b = cfg.bayes
    n_G = b.global_rank * (d + V) + 1  # +1: ω_G hierarchical log-scale
    n_L = b.local_rank * (d + V) + (V if b.local_bias else 0)
    return n_G, n_L


def split_global(cfg: ArchConfig, z_G: jnp.ndarray):
    """z_G -> (A_G (r,d), B_G (r,V), ω_G scalar)."""
    d, V, r = cfg.d_model, cfg.vocab_size, cfg.bayes.global_rank
    A = z_G[: r * d].reshape(r, d)
    B = z_G[r * d : r * (d + V)].reshape(r, V)
    omega = z_G[-1]
    return A, B, omega


def split_local(cfg: ArchConfig, z_L: jnp.ndarray):
    """z_L -> (A_L (r,d), B_L (r,V), bias (V) or None). Supports a leading
    silo axis: (J, n_L) -> (J, r, d), ..."""
    d, V, r = cfg.d_model, cfg.vocab_size, cfg.bayes.local_rank
    lead = z_L.shape[:-1]
    A = z_L[..., : r * d].reshape(*lead, r, d)
    B = z_L[..., r * d : r * (d + V)].reshape(*lead, r, V)
    bias = z_L[..., r * (d + V) :] if cfg.bayes.local_bias else None
    return A, B, bias


def log_prior_global(cfg: ArchConfig, z_G: jnp.ndarray) -> jnp.ndarray:
    """log p(Z_G) = standard normal over all components."""
    return -0.5 * jnp.sum(z_G.astype(jnp.float32) ** 2)


def log_prior_local(cfg: ArchConfig, z_G: jnp.ndarray, z_L: jnp.ndarray) -> jnp.ndarray:
    """log p(Z_Lj | Z_G) = N(0, exp(2 ω_G) I) — per-silo, z_L: (n_L,)."""
    omega = z_G[-1].astype(jnp.float32)
    zl = z_L.astype(jnp.float32)
    n = zl.size
    return -0.5 * jnp.sum(zl * zl) * jnp.exp(-2.0 * omega) - n * omega


def bayes_logits(
    cfg: ArchConfig,
    base_logits: jnp.ndarray,  # (..., S, Vp) — h @ W_head, computed by backbone
    h: jnp.ndarray,  # (..., S, d)
    z_G: jnp.ndarray,  # (n_G,)
    z_L: jnp.ndarray,  # (n_L,) — ONE silo's latents (silo axis handled by caller)
) -> jnp.ndarray:
    Vp = base_logits.shape[-1]
    V = cfg.vocab_size

    def vpad(m):  # pad adapter vocab columns to the padded head width
        if Vp == V:
            return m
        return jnp.pad(m, [(0, 0)] * (m.ndim - 1) + [(0, Vp - V)])

    A_G, B_G, _ = split_global(cfg, z_G)
    out = base_logits + (h @ A_G.T.astype(h.dtype)) @ vpad(B_G).astype(base_logits.dtype) / cfg.bayes.global_rank
    A_L, B_L, bias = split_local(cfg, z_L)
    out = out + (h @ A_L.T.astype(h.dtype)) @ vpad(B_L).astype(base_logits.dtype) / cfg.bayes.local_rank
    if bias is not None:
        out = out + vpad(bias).astype(out.dtype)
    return out


def token_nll(logits: jnp.ndarray, labels: jnp.ndarray,
              masked_gather: bool = False) -> jnp.ndarray:
    """Summed negative log-likelihood. logits (..., S, V); labels (..., S).

    ``masked_gather`` replaces the per-token gather of the gold logit with
    an iota-masked sum. A gather along a model-sharded vocab axis forces
    GSPMD to all-gather the logits; the masked sum is elementwise on the
    shard followed by a tiny (…, S) reduction — §Perf lever 1.
    """
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    if masked_gather:
        V = logits.shape[-1]
        col = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
        gold = jnp.sum(jnp.where(col == labels[..., None], lf, 0.0), axis=-1)
    else:
        gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.sum(logz - gold)


def silo_log_lik(cfg, base_logits_j, h_j, z_G, z_Lj, labels_j):
    """log p(y_j | Z_G, Z_Lj, θ) for one silo's batch shard."""
    logits = bayes_logits(cfg, base_logits_j, h_j, z_G, z_Lj)
    return -token_nll(logits, labels_j, masked_gather=cfg.perf.masked_nll)
