"""Primitive layers (pure functions over param dicts).

Conventions:
  * params are nested dicts of jnp arrays; init_* functions build them.
  * activations flow in ``cfg.dtype`` (bf16 on the production mesh);
    norms/softmax accumulate in f32.
  * weight layout favours (in, out) so einsums read left-to-right.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (scale * jax.random.normal(key, (in_dim, out_dim))).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype) -> jnp.ndarray:
    return jnp.ones((dim,), dtype)


def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return ((x32 * rms) * weight.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_grid(num_vision_tokens: int) -> tuple:
    """Default square-ish patch grid for the stub vision frontend."""
    side = max(1, int(math.sqrt(max(num_vision_tokens, 1))))
    return (side, max(1, num_vision_tokens // side))


def mrope_text_start(num_vision_tokens: int) -> int:
    """First text position after the vision block (M-RoPE convention)."""
    gh, gw = mrope_grid(num_vision_tokens)
    return int(max(gh, gw)) if num_vision_tokens else 0


def mrope_positions(batch: int, seq_len: int, num_vision_tokens: int,
                    grid_hw: Optional[tuple] = None) -> jnp.ndarray:
    """Qwen2-VL multimodal rotary positions: 3 channels (temporal, h, w).

    Vision tokens get (t=0, h=row, w=col) over the patch grid; text tokens get
    (t=h=w = running index). Returns (3, batch, seq_len).
    """
    if grid_hw is None:
        grid_hw = mrope_grid(num_vision_tokens)
    gh, gw = grid_hw
    rows = jnp.arange(num_vision_tokens) // gw
    cols = jnp.arange(num_vision_tokens) % gw
    t_vis = jnp.zeros(num_vision_tokens, jnp.int32)
    n_text = seq_len - num_vision_tokens
    # Text positions continue after the max vision position.
    start = int(max(gh, gw))
    text_pos = start + jnp.arange(n_text, dtype=jnp.int32)
    pos_t = jnp.concatenate([t_vis, text_pos])
    pos_h = jnp.concatenate([rows.astype(jnp.int32), text_pos])
    pos_w = jnp.concatenate([cols.astype(jnp.int32), text_pos])
    pos = jnp.stack([pos_t, pos_h, pos_w])  # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq_len))


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float) -> jnp.ndarray:
    """M-RoPE: head_dim is split into 3 sections (t, h, w), each rotated by
    its own position channel. x: (B, S, H, hd); positions3: (3, B, S)."""
    hd = x.shape[-1]
    # Section sizes in *pairs* (must be even in dims); Qwen2-VL uses 16/24/24
    # of 64 pairs -> we generalize proportionally 1:1.5:1.5 ≈ (t,h,w).
    pairs = hd // 2
    pt = pairs // 4
    ph = (pairs - pt) // 2
    pw = pairs - pt - ph
    sections = [2 * pt, 2 * ph, 2 * pw]
    outs = []
    start = 0
    for i, width in enumerate(sections):
        if width == 0:
            continue
        outs.append(apply_rope(x[..., start : start + width], positions3[i], theta))
        start += width
    return jnp.concatenate(outs, axis=-1)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, dtype):
    return {"tok": (0.02 * jax.random.normal(key, (vocab, d_model))).astype(dtype)}


def embed(params, tokens: jnp.ndarray) -> jnp.ndarray:
    return params["tok"][tokens]


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(params, x: jnp.ndarray) -> jnp.ndarray:
    gate = jax.nn.silu(x @ params["w_gate"])
    return (gate * (x @ params["w_up"])) @ params["w_down"]
