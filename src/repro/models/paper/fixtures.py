"""Shared federation fixtures for the paper's §4 experiments.

One place for the synthetic-data protocols and evaluation conventions so
the example scripts, the benchmark suite and the ``repro.federated.run``
CLI cannot silently diverge: all three build their silos here.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (
    heterogeneous_label_partition,
    make_lda_corpus,
    make_synthetic_mnist,
)
from repro.models.paper.hier_bnn import HierBNN, build_hier_bnn
from repro.models.paper.prodlda import ProdLDA, build_prodlda


def hier_bnn_federation(
    seed: int,
    num_silos: int,
    *,
    fedpop: bool = False,
    in_dim: int = 196,
    hidden: int = 32,
    train_per_silo: int = 200,
    test_per_silo: int = 40,
    prototype_scale: float = 1.0,
    noise_scale: float = 2.5,
) -> Tuple[HierBNN, List[dict], List[dict]]:
    """§4.1 protocol: synthetic MNIST under 90%-one-label heterogeneity.

    Returns ``(bnn, train, test)`` where train/test are J per-silo dicts
    with equal-shaped ``x``/``y`` leaves, ready for ``federated.Server``.
    """
    key = jax.random.PRNGKey(seed)
    tr, te = make_synthetic_mnist(
        key, train_per_silo * num_silos, test_per_silo * num_silos,
        dim=in_dim, prototype_scale=prototype_scale, noise_scale=noise_scale,
    )
    rng = np.random.default_rng(seed)
    parts_tr = heterogeneous_label_partition(rng, tr.y, num_silos)
    parts_te = heterogeneous_label_partition(rng, te.y, num_silos)
    train = [{"x": jnp.asarray(tr.x[p]), "y": jnp.asarray(tr.y[p])}
             for p in parts_tr]
    test = [{"x": jnp.asarray(te.x[p]), "y": jnp.asarray(te.y[p])}
            for p in parts_te]
    bnn = build_hier_bnn(in_dim=in_dim, hidden=hidden, fedpop=fedpop)
    return bnn, train, test


def bnn_posterior_accuracy(
    bnn: HierBNN, eta_G: dict, eta_L_stacked: dict, test: List[dict]
) -> Tuple[float, float]:
    """Per-silo posterior-mean test accuracy (MC-1 at the mean).

    ``eta_L_stacked`` carries a leading silo axis (``Server.eta_L``
    layout). Returns (mean, std) over silos.
    """
    accs = []
    for j in range(len(test)):
        eta_Lj = jax.tree_util.tree_map(lambda x: x[j], eta_L_stacked)
        accs.append(float(bnn.accuracy(
            eta_G["mu"], eta_Lj["mu_bar"], test[j]["x"], test[j]["y"])))
    return float(np.mean(accs)), float(np.std(accs))


def prodlda_federation(
    seed: int,
    num_silos: int,
    *,
    vocab_size: int = 300,
    num_topics: int = 8,
    docs_per_silo: int = 40,
) -> Tuple[ProdLDA, List[dict], np.ndarray]:
    """§4.2 protocol: synthetic LDA corpus split into equal doc shards.

    Returns ``(lda, datas, counts)`` — counts is the full (docs, vocab)
    matrix for coherence evaluation.
    """
    counts, _ = make_lda_corpus(
        jax.random.PRNGKey(seed), num_docs=num_silos * docs_per_silo,
        vocab_size=vocab_size, num_topics=num_topics,
    )
    lda = build_prodlda(vocab_size=vocab_size, num_topics=num_topics,
                        docs_per_silo=docs_per_silo)
    datas = [
        {"counts": jnp.asarray(counts[j * docs_per_silo:(j + 1) * docs_per_silo])}
        for j in range(num_silos)
    ]
    return lda, datas, np.asarray(counts)
