"""Bayesian logistic mixed model — six-cities (paper supplement S3.1).

    y_ij | β, b_i ~ Bern(logit⁻¹(β₀ + β₁ smoke_i + β₂ age_ij + β₃ smoke·age + b_i))
    β_k ~ N(0, 10²),  ω ~ N(0, 10²),  b_i | ω ~ N(0, exp(−2ω))

Z_G = (β, ω) ∈ R⁵; Z_{L_j} = silo j's random intercepts b (one per child);
θ = ∅. The local family uses the C_j coupling with L_j ≡ I, exactly as the
paper prescribes ("we set L_j ≡ I as each b_i is conditionally independent
a posteriori given Z_G and the data").
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.families import ConditionalGaussian, DiagGaussian
from repro.core.model import StructuredModel
from repro.core.sfvi import SFVIProblem

_LOG_2PI = math.log(2.0 * math.pi)


def glmm_logits(beta: jnp.ndarray, b: jnp.ndarray, smoke: jnp.ndarray, age: jnp.ndarray):
    return (
        beta[0]
        + beta[1] * smoke[:, None]
        + beta[2] * age
        + beta[3] * smoke[:, None] * age
        + b[:, None]
    )


def glmm_log_joint_local(z_G, b, data):
    """log p(y_j, b | β, ω) for one silo — shared by SFVI and the MCMC oracle."""
    beta, omega = z_G[:4], z_G[4]
    # b_i | ω ~ N(0, exp(−2ω))
    lp_b = jnp.sum(-0.5 * b**2 * jnp.exp(2.0 * omega) + omega - 0.5 * _LOG_2PI)
    logits = glmm_logits(beta, b, data["smoke"], data["age"])
    ll = jnp.sum(data["y"] * jax.nn.log_sigmoid(logits) + (1.0 - data["y"]) * jax.nn.log_sigmoid(-logits))
    return lp_b + ll


@dataclasses.dataclass(frozen=True)
class GLMM:
    problem: SFVIProblem
    num_children: int


def build_glmm(num_children_j: int, use_coupling: bool = True) -> GLMM:
    global_dim = 5  # (β₀..β₃, ω)

    def log_prior_global(theta, z_G):
        del theta
        return jnp.sum(-0.5 * z_G**2 / 100.0 - 0.5 * math.log(100.0) - 0.5 * _LOG_2PI)

    def log_local(theta, z_G, z_L, data_j):
        del theta
        return glmm_log_joint_local(z_G, z_L, data_j)

    model = StructuredModel(
        global_dim=global_dim,
        local_dim=num_children_j,
        log_prior_global=log_prior_global,
        log_local=log_local,
        name="glmm_six_cities",
    )
    gfam = DiagGaussian(global_dim)
    lfam = ConditionalGaussian(
        num_children_j, global_dim, use_coupling=use_coupling, use_chol=False
    )
    return GLMM(problem=SFVIProblem(model, gfam, lfam), num_children=num_children_j)
