"""Empirically-Bayesian multinomial regression (paper supplement S3.2).

    W_jk ~ N(0, σ_W²),  b_j ~ N(0, σ_b²),  c_k | W,b ~ Cat(softmax(W x_k + b))

Z_G = (vec(W), b) ∈ R^7850, Z_L = ∅, θ = (log σ_W, log σ_b) — prior scales
learned by empirical Bayes. This is the model the paper uses to study
SFVI-Avg's averaging frequency (Table S1) and warm-starting (Figure S2);
its diagonal q enables the *analytic* barycenter.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.families import DiagGaussian
from repro.core.flatten import VectorSpec
from repro.core.model import StructuredModel
from repro.core.sfvi import SFVIProblem

_LOG_2PI = math.log(2.0 * math.pi)


@dataclasses.dataclass(frozen=True)
class MultinomialRegression:
    problem: SFVIProblem
    spec: VectorSpec
    in_dim: int
    num_classes: int

    def predict_logits(self, z_G, x):
        g = self.spec.unpack(z_G)
        return x @ g["W"] + g["b"]

    def accuracy(self, z_G, x, y):
        return jnp.mean((jnp.argmax(self.predict_logits(z_G, x), -1) == y).astype(jnp.float32))


def build_multinomial(in_dim: int = 784, num_classes: int = 10) -> MultinomialRegression:
    spec = VectorSpec.create({"W": (in_dim, num_classes), "b": (num_classes,)})

    def log_prior_global(theta, z_G):
        g = spec.unpack(z_G)
        var_w = jnp.exp(2.0 * theta["log_sigma_w"])
        var_b = jnp.exp(2.0 * theta["log_sigma_b"])
        lp_w = jnp.sum(-0.5 * g["W"] ** 2 / var_w) - 0.5 * g["W"].size * (
            2.0 * theta["log_sigma_w"] + _LOG_2PI
        )
        lp_b = jnp.sum(-0.5 * g["b"] ** 2 / var_b) - 0.5 * g["b"].size * (
            2.0 * theta["log_sigma_b"] + _LOG_2PI
        )
        return lp_w + lp_b

    def log_local(theta, z_G, z_L, data_j):
        del theta, z_L
        g = spec.unpack(z_G)
        logits = data_j["x"] @ g["W"] + g["b"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        rows = jnp.take_along_axis(logp, data_j["y"][:, None], axis=-1)[:, 0]
        if "w" in data_j:
            # Ragged federations pad silo shards to a common size and
            # mark real rows with w=1 (repro.data.pad_ragged_silos);
            # weighting here makes padded rows contribute exactly 0.
            rows = rows * data_j["w"]
        return jnp.sum(rows)

    model = StructuredModel(
        global_dim=spec.dim,
        local_dim=0,
        log_prior_global=log_prior_global,
        log_local=log_local,
        name="eb_multinomial",
    )
    gfam = DiagGaussian(spec.dim)
    return MultinomialRegression(
        problem=SFVIProblem(model, gfam, None),
        spec=spec,
        in_dim=in_dim,
        num_classes=num_classes,
    )


def init_theta() -> dict:
    return {"log_sigma_w": jnp.asarray(0.0), "log_sigma_b": jnp.asarray(0.0)}
