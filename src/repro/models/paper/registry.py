"""Model registry — the paper's experiment models as named, buildable entries.

The declarative experiment API (:mod:`repro.federated.api`) refers to a
model by name plus JSON-serializable kwargs; this registry resolves the
name to a builder that stages everything a federation run needs:

  * the :class:`~repro.core.sfvi.SFVIProblem` (model + variational
    families),
  * initial model parameters θ₀,
  * J per-silo data pytrees with equal leaf shapes (what the compiled
    :class:`~repro.federated.runtime.Server` stacks along the ``silo``
    mesh axis),
  * per-silo observation counts N_j (SFVI-Avg's N/N_j rescale),
  * an evaluation hook ``eval_fn(server) -> {metric: value}``,
  * model-specific extras (test splits, oracles, closed-form answers)
    that benchmarks and examples read.

This module imports nothing heavy at module level — listing names (e.g.
``repro.federated.run --list-models``) must work before JAX is imported
so the CLI can still set ``XLA_FLAGS`` from ``--devices``. Builders do
their imports lazily when called.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    """Everything one federation run needs, staged and ready to serve.

    Attributes:
      problem: the SFVI problem (generative model + variational families).
      theta0: initial model parameters θ (``{}`` for fully-Bayesian).
      datas: J per-silo data pytrees with equal leaf shapes.
      num_obs: per-silo observation counts N_j, or None to infer from
        the leading data dimension.
      eval_fn: ``eval_fn(server) -> {name: float}`` evaluated on the
        live :class:`~repro.federated.runtime.Server`, or None.
      extras: model-specific artifacts (test splits, pooled data for
        oracles, closed-form posteriors) for benchmarks/examples.
    """

    problem: Any
    theta0: PyTree
    datas: List[PyTree]
    num_obs: Optional[List[int]] = None
    eval_fn: Optional[Callable[[Any], Dict[str, float]]] = None
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class ModelEntry:
    """One registered model: a name, a help string, and a builder."""

    name: str
    description: str
    build: Callable[..., ModelBundle]


_REGISTRY: Dict[str, ModelEntry] = {}


def register(name: str, description: str):
    """Decorator: register ``fn(seed, num_silos, **kwargs) -> ModelBundle``."""

    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"model {name!r} registered twice")
        _REGISTRY[name] = ModelEntry(name=name, description=description, build=fn)
        return fn

    return deco


def get_model(name: str) -> ModelEntry:
    """Resolve a registry name; raises with the available names on miss."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown model {name!r}; registered models: "
            + ", ".join(sorted(_REGISTRY))
        ) from None


def list_models() -> List[Tuple[str, str]]:
    """Sorted (name, description) pairs — what ``--list-models`` prints."""
    return [(e.name, e.description) for _, e in sorted(_REGISTRY.items())]


def model_names() -> List[str]:
    """Sorted registered names (CLI ``choices``)."""
    return sorted(_REGISTRY)


def apply_family_spec(bundle: ModelBundle, global_family=None,
                      local_family=None) -> ModelBundle:
    """Swap the staged problem's variational families from FamilySpecs.

    Every registered model stages a default family pair (the paper's
    choice); a :class:`~repro.core.family.FamilySpec` on the
    ``ModelSpec`` overrides either side — the structural dimensions
    (``dim``, ``global_dim``) come from the staged model, so the same
    spec applies to any registry entry. Data, θ₀, counts and eval hooks
    are untouched (family choice never changes the generative model).

    Imports lazily: the registry module must stay importable before JAX
    (``--list-models`` runs pre-``XLA_FLAGS``).
    """
    if global_family is None and local_family is None:
        return bundle
    import dataclasses as _dc

    from repro.core.family import build_family

    problem = bundle.problem
    model = problem.model
    gfam, lfam = problem.global_family, problem.local_family
    if global_family is not None:
        gfam = build_family(global_family, dim=model.global_dim)
    if local_family is not None:
        lfam = build_family(local_family, dim=model.local_dim,
                            global_dim=model.global_dim)
    problem = _dc.replace(problem, global_family=gfam, local_family=lfam)
    return _dc.replace(bundle, problem=problem)


# ---------------------------------------------------------------------------
# Builders (imports deferred to call time; see module docstring)
# ---------------------------------------------------------------------------


@register("toy", "Hierarchical Gaussian with a closed-form posterior (quickstart)")
def _build_toy(seed: int, num_silos: int, *, num_obs: int = 40,
               true_mu: float = 2.0, use_coupling: bool = True) -> ModelBundle:
    """μ ~ N(0, 10²); b_j | μ ~ N(μ, 1); y_jk | b_j ~ N(b_j, 0.5²).

    Z_G = μ, Z_{L_j} = b_j, θ = ∅. The exact posterior of μ given the
    silo means is Gaussian; ``extras`` carries it for correctness checks.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core import ConditionalGaussian, DiagGaussian, SFVIProblem, StructuredModel

    rng = np.random.default_rng(seed)
    true_b = rng.normal(true_mu, 1.0, num_silos)
    datas = [{"y": jnp.asarray(rng.normal(true_b[j], 0.5, num_obs))}
             for j in range(num_silos)]

    model = StructuredModel(
        global_dim=1, local_dim=1,
        log_prior_global=lambda th, zg: -0.5 * jnp.sum(zg**2) / 10.0**2,
        log_local=lambda th, zg, zl, d: (
            -0.5 * jnp.sum((zl - zg) ** 2)
            - 0.5 * jnp.sum((d["y"] - zl) ** 2) / 0.5**2
        ),
        name="toy_hier_gaussian",
    )
    problem = SFVIProblem(
        model, DiagGaussian(1),
        ConditionalGaussian(1, 1, use_coupling=use_coupling),
    )

    # Closed form: posterior of μ given silo means ȳ_j (b_j integrated out).
    ybar = np.array([float(np.mean(np.asarray(d["y"]))) for d in datas])
    var_j = 1.0 + 0.5**2 / num_obs  # var of ȳ_j | μ, identical across silos
    post_prec = 1.0 / 10.0**2 + num_silos / var_j
    post_mu = float(np.sum(ybar) / var_j / post_prec)

    def eval_fn(server):
        mu_hat = float(np.asarray(server.eta_G["mu"])[0])
        return {"abs_error_vs_exact": abs(mu_hat - post_mu)}

    return ModelBundle(
        problem=problem, theta0={}, datas=datas,
        num_obs=[num_obs] * num_silos, eval_fn=eval_fn,
        extras={"true_mu": true_mu, "posterior_mu": post_mu,
                "posterior_sd": float(np.sqrt(1.0 / post_prec))},
    )


def _bnn_bundle(seed: int, num_silos: int, *, fedpop: bool, kwargs) -> ModelBundle:
    from repro.models.paper.fixtures import bnn_posterior_accuracy, hier_bnn_federation

    bnn, train, test = hier_bnn_federation(
        seed=seed, num_silos=num_silos, fedpop=fedpop, **kwargs)

    def eval_fn(server):
        acc, std = bnn_posterior_accuracy(bnn, server.eta_G, server.eta_L, test)
        return {"test_acc": acc, "test_acc_std": std}

    return ModelBundle(
        problem=bnn.problem, theta0={}, datas=train,
        num_obs=[int(d["y"].shape[0]) for d in train], eval_fn=eval_fn,
        extras={"bnn": bnn, "test": test},
    )


@register("hier_bnn", "Hierarchical BNN on heterogeneous synthetic MNIST (§4.1)")
def _build_hier_bnn(seed: int, num_silos: int, **kwargs) -> ModelBundle:
    return _bnn_bundle(seed, num_silos, fedpop=False, kwargs=kwargs)


@register("fedpop_bnn", "Fully-Bayesian FedPop BNN variant (§4.1, Table 1 row 2)")
def _build_fedpop_bnn(seed: int, num_silos: int, **kwargs) -> ModelBundle:
    return _bnn_bundle(seed, num_silos, fedpop=True, kwargs=kwargs)


@register("prodlda", "Federated ProdLDA topic model on a synthetic corpus (§4.2)")
def _build_prodlda(seed: int, num_silos: int, **kwargs) -> ModelBundle:
    import numpy as np

    from repro.models.paper.fixtures import prodlda_federation
    from repro.models.paper.prodlda import init_theta, umass_coherence

    lda, datas, counts = prodlda_federation(seed=seed, num_silos=num_silos, **kwargs)

    def eval_fn(server):
        t = np.asarray(lda.topics(server.eta_G["mu"]))
        coh = umass_coherence(t, counts, top_n=8)
        return {"coherence_median": float(np.median(coh)),
                "coherence_mean": float(np.mean(coh))}

    return ModelBundle(
        problem=lda.problem, theta0=init_theta(), datas=datas,
        num_obs=[lda.docs_per_silo] * num_silos, eval_fn=eval_fn,
        extras={"lda": lda, "counts": counts},
    )


@register("glmm", "Bayesian logistic GLMM, six-cities protocol (supplement S3.1)")
def _build_glmm(seed: int, num_silos: int, *, num_children: int = 120) -> ModelBundle:
    """Even split of the six-cities children across silos.

    The compiled Server stacks silo data along a leading axis, so every
    silo carries ``num_children // num_silos`` children (the leftover
    children are dropped; the paper's uneven 300/237 split corresponds
    to the host-level protocol, not the stacked SPMD layout).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.data import make_six_cities, sizes_partition
    from repro.models.paper.glmm import build_glmm

    per_silo = num_children // num_silos
    total = per_silo * num_silos
    data, truth = make_six_cities(jax.random.PRNGKey(seed + 3), num_children=total)
    rng = np.random.default_rng(seed)
    parts = sizes_partition(rng, total, [per_silo] * num_silos)
    datas = [{k: jnp.asarray(v[p]) for k, v in data.items()} for p in parts]
    glmm = build_glmm(num_children_j=per_silo)

    return ModelBundle(
        problem=glmm.problem, theta0={}, datas=datas,
        num_obs=[per_silo] * num_silos, eval_fn=None,
        extras={"pooled": {k: jnp.asarray(v) for k, v in data.items()},
                "truth": truth, "num_children": total},
    )


@register("hetero_mn",
          "Multinomial regression under Dirichlet non-IID silos "
          "(unequal N_j, label skew)")
def _build_hetero_mn(seed: int, num_silos: int, *, n_total: int = 240,
                     in_dim: int = 196, alpha: float = 0.5,
                     min_per_silo: int = 2, prototype_scale: float = 0.6,
                     noise_scale: float = 3.0) -> ModelBundle:
    """The heterogeneous-silo scenario generator.

    Stages the multinomial model over a Dirichlet(α) label partition
    (Hsu et al., 2019): each class's samples are split across silos by
    ``p ~ Dir(α·1_J)``, producing the two hallmarks of real federations
    — per-silo label skew AND unequal shard sizes N_j. Small α is
    extreme non-IID, large α approaches IID. Ragged shards are padded
    to the widest silo with a 0/1 row-weight vector consumed by the
    weighted likelihood, so the compiled stacked runtime runs unchanged
    and padded rows contribute exactly nothing; ``num_obs`` carries the
    TRUE unequal N_j, which is what SFVI-Avg's N/N_j rescale sees.
    Composes freely with async execution, DP and compression — one spec
    covers async × non-IID × DP × int8.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.data import (dirichlet_label_partition, make_synthetic_mnist,
                            pad_ragged_silos)
    from repro.models.paper.multinomial import build_multinomial, init_theta

    tr, te = make_synthetic_mnist(
        jax.random.PRNGKey(seed), n_total, max(200, num_silos * 20),
        dim=in_dim, prototype_scale=prototype_scale, noise_scale=noise_scale,
    )
    rng = np.random.default_rng(seed)
    parts = dirichlet_label_partition(
        rng, tr.y, num_silos, alpha=alpha, min_per_silo=min_per_silo)
    num_obs = [len(p) for p in parts]
    ragged = [{"x": tr.x[p], "y": tr.y[p]} for p in parts]
    datas = [{k: jnp.asarray(v) for k, v in d.items()}
             for d in pad_ragged_silos(ragged)]
    test = {"x": jnp.asarray(te.x), "y": jnp.asarray(te.y)}
    train_all = {"x": jnp.asarray(tr.x), "y": jnp.asarray(tr.y)}
    model = build_multinomial(in_dim=in_dim)

    def eval_fn(server):
        return {
            "train_acc": float(model.accuracy(
                server.eta_G["mu"], train_all["x"], train_all["y"])),
            "test_acc": float(model.accuracy(
                server.eta_G["mu"], test["x"], test["y"])),
        }

    skew = float(np.std(num_obs) / np.mean(num_obs))
    return ModelBundle(
        problem=model.problem, theta0=init_theta(), datas=datas,
        num_obs=num_obs, eval_fn=eval_fn,
        extras={"model": model, "train_all": train_all, "test": test,
                "partitions": parts, "alpha": alpha, "size_skew": skew},
    )


@register("multinomial",
          "Empirically-Bayesian multinomial regression (supplement S3.2)")
def _build_multinomial(seed: int, num_silos: int, *, n_per: int = 60,
                       in_dim: int = 196, prototype_scale: float = 0.6,
                       noise_scale: float = 3.0) -> ModelBundle:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.data import iid_partition, make_synthetic_mnist
    from repro.models.paper.multinomial import build_multinomial, init_theta

    tr, te = make_synthetic_mnist(
        jax.random.PRNGKey(seed), num_silos * n_per, max(200, num_silos * 20),
        dim=in_dim, prototype_scale=prototype_scale, noise_scale=noise_scale,
    )
    rng = np.random.default_rng(seed)
    parts = iid_partition(rng, len(tr.y), num_silos)
    datas = [{"x": jnp.asarray(tr.x[p]), "y": jnp.asarray(tr.y[p])} for p in parts]
    test = {"x": jnp.asarray(te.x), "y": jnp.asarray(te.y)}
    train_all = {"x": jnp.asarray(tr.x), "y": jnp.asarray(tr.y)}
    model = build_multinomial(in_dim=in_dim)

    def eval_fn(server):
        return {
            "train_acc": float(model.accuracy(
                server.eta_G["mu"], train_all["x"], train_all["y"])),
            "test_acc": float(model.accuracy(
                server.eta_G["mu"], test["x"], test["y"])),
        }

    return ModelBundle(
        problem=model.problem, theta0=init_theta(), datas=datas,
        num_obs=[len(p) for p in parts], eval_fn=eval_fn,
        extras={"model": model, "train_all": train_all, "test": test},
    )
