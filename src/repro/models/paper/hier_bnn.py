"""Hierarchical Bayesian neural network (paper §4.1).

    μ_ik ~ N(0,1),  σ ~ N₊(0,1)                     — global
    ε_ik^(j) ~ N(0,1),  W^(1,j) = μ + σ ε^(j)       — local (non-centered)
    W^(2,j) ~ N(0,1)                                 — local
    f_j(x) = softmax(ReLU(x W^(1,j)) W^(2,j))

Z_G = (μ, log σ) with the half-normal prior on σ handled by a log-space
change of variables; Z_{L_j} = (ε^(j), W^(2,j)); θ = ∅.

``fedpop=True`` gives the *fully-Bayesian FedPop* variant the paper also
fits (Table 1): the first layer becomes a purely global latent (no per-silo
ε), and only the final layer is silo-personal.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.families import ConditionalGaussian, DiagGaussian
from repro.core.flatten import VectorSpec
from repro.core.model import StructuredModel
from repro.core.sfvi import SFVIProblem

_LOG_2PI = math.log(2.0 * math.pi)


def _std_normal_logpdf(x):
    return -0.5 * jnp.sum(x * x) - 0.5 * x.size * _LOG_2PI


@dataclasses.dataclass(frozen=True)
class HierBNN:
    problem: SFVIProblem
    global_spec: VectorSpec
    local_spec: VectorSpec
    in_dim: int
    hidden: int
    num_classes: int
    fedpop: bool

    def predict_logits(self, z_G: jnp.ndarray, z_L: jnp.ndarray, x: jnp.ndarray):
        return _predict_logits(self.global_spec, self.local_spec, self.fedpop, z_G, z_L, x)

    def accuracy(self, z_G, z_L, x, y) -> jnp.ndarray:
        logits = self.predict_logits(z_G, z_L, x)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))


def _predict_logits(gspec, lspec, fedpop, z_G, z_L, x):
    g = gspec.unpack(z_G)
    l = lspec.unpack(z_L)
    if fedpop:
        w1 = g["mu_w1"]
    else:
        w1 = g["mu_w1"] + jnp.exp(g["log_sigma_w1"]) * l["eps_w1"]
    return jax.nn.relu(x @ w1) @ l["w2"]


def build_hier_bnn(
    in_dim: int = 784,
    hidden: int = 64,
    num_classes: int = 10,
    fedpop: bool = False,
    use_coupling: bool = False,
) -> HierBNN:
    if fedpop:
        gspec = VectorSpec.create({"mu_w1": (in_dim, hidden)})
        lspec = VectorSpec.create({"w2": (hidden, num_classes)})
    else:
        gspec = VectorSpec.create({"mu_w1": (in_dim, hidden), "log_sigma_w1": ()})
        lspec = VectorSpec.create(
            {"eps_w1": (in_dim, hidden), "w2": (hidden, num_classes)}
        )

    def log_prior_global(theta, z_G):
        del theta
        g = gspec.unpack(z_G)
        lp = _std_normal_logpdf(g["mu_w1"])
        if not fedpop:
            # σ ~ N₊(0,1) via ω = log σ: log p(ω) = log 2 + log N(e^ω;0,1) + ω.
            omega = g["log_sigma_w1"]
            sigma = jnp.exp(omega)
            lp = lp + (-0.5 * sigma**2 + math.log(2.0) - 0.5 * _LOG_2PI) + omega
        return lp

    def log_local(theta, z_G, z_L, data_j):
        del theta
        l = lspec.unpack(z_L)
        lp = _std_normal_logpdf(l["w2"])
        if not fedpop:
            lp = lp + _std_normal_logpdf(l["eps_w1"])
        logits = _predict_logits(gspec, lspec, fedpop, z_G, z_L, data_j["x"])
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.sum(jnp.take_along_axis(logp, data_j["y"][:, None], axis=-1))
        return lp + ll

    def predict(theta, z_G, z_L, x):
        del theta
        return _predict_logits(gspec, lspec, fedpop, z_G, z_L, x)

    model = StructuredModel(
        global_dim=gspec.dim,
        local_dim=lspec.dim,
        log_prior_global=log_prior_global,
        log_local=log_local,
        predict=predict,
        name="fedpop_bnn" if fedpop else "hier_bnn",
    )
    gfam = DiagGaussian(gspec.dim)
    lfam = ConditionalGaussian(
        lspec.dim, gspec.dim, use_coupling=use_coupling, use_chol=False
    )
    return HierBNN(
        problem=SFVIProblem(model, gfam, lfam),
        global_spec=gspec,
        local_spec=lspec,
        in_dim=in_dim,
        hidden=hidden,
        num_classes=num_classes,
        fedpop=fedpop,
    )
