from repro.models.paper.hier_bnn import build_hier_bnn
from repro.models.paper.prodlda import build_prodlda
from repro.models.paper.glmm import build_glmm
from repro.models.paper.multinomial import build_multinomial

__all__ = ["build_hier_bnn", "build_prodlda", "build_glmm", "build_multinomial"]
