"""The paper's experiment models (§4, supplement S3).

Lazy re-exports (PEP 562): importing this package — e.g. to read the
model registry (``repro.models.paper.registry``) for CLI ``--list-models``
or ``choices`` — must not pull in JAX; the model modules import it at
top level, so they load only when a builder is actually touched.
"""
_LAZY = {
    "build_hier_bnn": "repro.models.paper.hier_bnn",
    "build_prodlda": "repro.models.paper.prodlda",
    "build_glmm": "repro.models.paper.glmm",
    "build_multinomial": "repro.models.paper.multinomial",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
