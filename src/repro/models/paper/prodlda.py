"""Product Latent Dirichlet Allocation (paper §4.2; Srivastava & Sutton 2017).

    T_t  ~ Dirichlet(β·1_vocab)             t = 1..n_topics     — global
    W_k  ~ N(α·1_topics, I)                 k = 1..n_docs       — local (per doc)
    c_k  ~ Multinom(l_k, softmax(T W_k))                        — bag-of-words

θ = (α, β). Z_G = vec(T) in *softmax basis* with the logistic-normal
Laplace approximation to the Dirichlet prior (exactly the Srivastava–Sutton
construction the paper builds on — a Gaussian q over a simplex-constrained
latent requires an unconstrained basis). Z_{L_j} = the W_k for silo j's
documents (BatchedDiagGaussian). The approximating family is diagonal, as
the paper specifies for this experiment.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.families import BatchedDiagGaussian, DiagGaussian
from repro.core.model import StructuredModel
from repro.core.sfvi import SFVIProblem

_LOG_2PI = math.log(2.0 * math.pi)


def dirichlet_laplace_moments(beta: jnp.ndarray, dim: int):
    """Logistic-normal (softmax-basis) Laplace approximation to
    Dirichlet(β·1_dim): mean and variance per coordinate
    (Srivastava & Sutton 2017, eq. 4; Hennig et al. 2012)."""
    # Symmetric concentration: mean 0; var = (1 − 2/K)/β + 1/(K β) · ... for
    # the symmetric case this reduces to:
    mean = jnp.zeros(dim)
    var = (1.0 / beta) * (1.0 - 2.0 / dim) + (1.0 / (dim * beta)) * 1.0
    return mean, jnp.full((dim,), var)


@dataclasses.dataclass(frozen=True)
class ProdLDA:
    problem: SFVIProblem
    num_topics: int
    vocab_size: int
    docs_per_silo: int

    def topics(self, z_G: jnp.ndarray) -> jnp.ndarray:
        """Softmax-basis latent -> (n_topics, vocab) word distributions."""
        t = z_G.reshape(self.num_topics, self.vocab_size)
        return jax.nn.softmax(t, axis=-1)

    def doc_word_probs(self, z_G, w):
        """ProdLDA mixes in *natural-parameter* space: softmax(T w)."""
        t = z_G.reshape(self.num_topics, self.vocab_size)
        return jax.nn.softmax(w @ t, axis=-1)


def umass_coherence(topics: np.ndarray, counts: np.ndarray, top_n: int = 10) -> np.ndarray:
    """UMass topic coherence (Mimno et al., 2011) per topic.

    C(t) = Σ_{m<l} log [ (D(w_m, w_l) + 1) / D(w_l) ]
    over the topic's top-N words, with document co-occurrence counts D.
    """
    doc_occ = counts > 0  # (docs, vocab) bool
    scores = []
    for t in range(topics.shape[0]):
        top = np.argsort(-topics[t])[:top_n]
        c = 0.0
        for m in range(1, top_n):
            for l in range(m):
                d_l = doc_occ[:, top[l]].sum()
                d_ml = (doc_occ[:, top[m]] & doc_occ[:, top[l]]).sum()
                c += np.log((d_ml + 1.0) / max(d_l, 1.0))
        scores.append(c)
    return np.asarray(scores)


def build_prodlda(
    vocab_size: int = 2000,
    num_topics: int = 21,
    docs_per_silo: int = 400,
    learn_theta: bool = True,
) -> ProdLDA:
    global_dim = num_topics * vocab_size

    def log_prior_global(theta, z_G):
        # Dirichlet(β 1) in softmax basis via the Laplace approximation.
        beta = jnp.exp(theta["log_beta"]) if learn_theta else jnp.asarray(0.05)
        mean, var = dirichlet_laplace_moments(beta, vocab_size)
        t = z_G.reshape(num_topics, vocab_size)
        resid = t - mean[None, :]
        return jnp.sum(-0.5 * resid**2 / var[None, :] - 0.5 * jnp.log(var)[None, :]
                       - 0.5 * _LOG_2PI)

    def log_local(theta, z_G, z_L, data_j):
        # z_L: (docs_per_silo, num_topics) doc-topic weights W_k.
        alpha = theta["alpha"] if learn_theta else jnp.asarray(0.0)
        w = z_L
        lp = jnp.sum(-0.5 * (w - alpha) ** 2 - 0.5 * _LOG_2PI)
        t = z_G.reshape(num_topics, vocab_size)
        logits = w @ t  # (docs, vocab)
        logp = jax.nn.log_softmax(logits, axis=-1)
        counts = data_j["counts"].astype(logp.dtype)
        # Multinomial log-lik up to the (data-only) normalizing constant.
        return lp + jnp.sum(counts * logp)

    model = StructuredModel(
        global_dim=global_dim,
        local_dim=num_topics,  # per-document; batched over docs_per_silo
        log_prior_global=log_prior_global,
        log_local=log_local,
        name="prodlda",
    )
    gfam = DiagGaussian(global_dim)
    lfam = BatchedDiagGaussian(batch=docs_per_silo, dim=num_topics)
    return ProdLDA(
        problem=SFVIProblem(model, gfam, lfam),
        num_topics=num_topics,
        vocab_size=vocab_size,
        docs_per_silo=docs_per_silo,
    )


def init_theta(key=None) -> dict:
    return {"alpha": jnp.asarray(0.0), "log_beta": jnp.asarray(math.log(0.05))}
