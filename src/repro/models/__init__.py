"""Model zoo.

``repro.models.paper``   — the paper's four experiment models (§4, supplement S3).
``repro.models.backbone``— the transformer/MoE/SSM stack used by the ten
                            assigned LLM-scale architectures.
"""
