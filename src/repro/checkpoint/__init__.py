from repro.checkpoint.io import load_pytree, save_pytree, CheckpointManager

__all__ = ["load_pytree", "save_pytree", "CheckpointManager"]
