"""Pytree checkpointing via msgpack (orbax unavailable offline).

Federated nuance: silo-private state (η_{L_j}, local optimizer moments) is
checkpointed *per silo* into separate files so a restored deployment keeps
the paper's privacy boundary — the server checkpoint never contains local
variational parameters.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any

_KIND_ARRAY = 0
_KIND_SCALAR = 1


def _encode_leaf(x):
    arr = np.asarray(x)
    # dtype *name* (not .str): extended dtypes like bfloat16 round-trip by
    # name through ml_dtypes but serialize as opaque '|V2' via .str.
    return {
        b"k": _KIND_ARRAY,
        b"d": arr.dtype.name,
        b"s": list(arr.shape),
        b"b": arr.tobytes(),
    }


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # vendored with jax

        return np.dtype(getattr(ml_dtypes, name))


def _decode_leaf(obj):
    name = obj[b"d"].decode() if isinstance(obj[b"d"], bytes) else obj[b"d"]
    arr = np.frombuffer(obj[b"b"], dtype=_resolve_dtype(name)).reshape(obj[b"s"])
    return jnp.asarray(arr)


def save_pytree(path: str, tree: PyTree) -> None:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        b"treedef": str(treedef).encode(),
        b"leaves": [_encode_leaf(l) for l in leaves],
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload))
    os.replace(tmp, path)  # atomic


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (structure is not serialized
    executably; the caller supplies the template, as with orbax)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read())
    leaves = [_decode_leaf(l) for l in payload[b"leaves"]]
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(leaves) != len(like_leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, template has {len(like_leaves)}"
        )
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Step-indexed checkpoints with retention, plus per-silo private shards."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int, shard: Optional[str] = None) -> str:
        name = f"step_{step:08d}" + (f".{shard}" if shard else "") + ".msgpack"
        return os.path.join(self.directory, name)

    def save(self, step: int, tree: PyTree, shard: Optional[str] = None) -> str:
        path = self._path(step, shard)
        save_pytree(path, tree)
        self._gc(shard)
        return path

    def restore(self, step: int, like: PyTree, shard: Optional[str] = None) -> PyTree:
        return load_pytree(self._path(step, shard), like)

    def has(self, step: int, shard: Optional[str] = None) -> bool:
        """Whether ``step`` (optionally a specific shard) is on disk.

        A resume may rebuild with MORE silos than the run that saved
        (a grown roster): the missing shards keep their fresh init and
        only the saved ones restore, so callers probe before reading.
        """
        return os.path.exists(self._path(step, shard))

    def latest_step(self, shard: Optional[str] = None) -> Optional[int]:
        steps = self._steps(shard)
        return steps[-1] if steps else None

    def steps(self, shard: Optional[str] = None) -> list:
        """Sorted step indices currently retained (post-GC).

        Public so sidecar files keyed by step (e.g. the experiment API's
        ``step_NNNNNNNN.meta.json``) can keep their retention in lock
        step with the manager's.
        """
        return self._steps(shard)

    def _steps(self, shard: Optional[str]):
        suffix = (f".{shard}" if shard else "") + ".msgpack"
        steps = []
        for fn in os.listdir(self.directory):
            if fn.startswith("step_") and fn.endswith(suffix):
                core = fn[len("step_") :][: -len(suffix)]
                if core.isdigit():
                    steps.append(int(core))
        return sorted(steps)

    def _gc(self, shard: Optional[str]):
        steps = self._steps(shard)
        for s in steps[: -self.keep]:
            os.remove(self._path(s, shard))
