"""Tests for repro.federated.privacy: mechanism, accountant, invariances.

Three layers:
  * golden-value tests: the RDP accountant must reproduce recorded
    (ε, δ) reference values to 1e-6. The goldens were generated from
    this implementation and cross-validated against (a) the closed-form
    Gaussian-mechanism RDP α/(2σ²) (Mironov 2017, Prop. 7) and (b) an
    independent high-precision numerical quadrature of
    E_{x~N(0,σ²)}[((1-q) + q e^{(2x-1)/(2σ²)})^α] (agreement < 1e-8),
    the same integral tensorflow-privacy's accountant evaluates;
  * mechanism tests: clipping/noising semantics and replayability;
  * compiled-graph invariances (subprocess, 4 forced host devices): a
    DP round lowers to ONE all_gather instruction regardless of
    local_steps (the §3.2 exchange structure survives privatization,
    and the upload is coalesced), and the round is deterministic given
    the round key.
"""
import math
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ConditionalGaussian,
    DiagGaussian,
    SFVIProblem,
    StructuredModel,
)
from repro.federated import PrivacyPolicy, RdpAccountant, Server
from repro.federated.privacy import (
    DEFAULT_ORDERS,
    rdp_sampled_gaussian,
    rdp_to_epsilon,
)
from repro.optim.sgd import sgd

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# ---------------------------------------------------------------------------
# Accountant: golden values
# ---------------------------------------------------------------------------

# (q, noise_multiplier, steps, delta) -> (epsilon, optimal integer order).
# Generated with RdpAccountant on DEFAULT_ORDERS; validated against the
# analytic q=1 curve and the independent quadrature described above.
GOLDEN = [
    (1.0, 1.0, 1, 1e-5, 5.302585092994046, 6),
    (1.0, 2.0, 100, 1e-6, 38.815510557964274, 2),
    (0.01, 1.1, 1000, 1e-5, 2.0867961135743176, 10),
    (0.1, 0.8, 50, 1e-5, 10.509389686292767, 3),
    (0.25, 2.0, 200, 1e-6, 12.488513195117264, 3),
    (0.5, 4.0, 500, 1e-5, 17.945480599036802, 3),
]


class TestAccountantGolden:
    @pytest.mark.parametrize("q,z,steps,delta,eps_ref,order_ref", GOLDEN)
    def test_epsilon_matches_golden(self, q, z, steps, delta, eps_ref, order_ref):
        acc = RdpAccountant()
        acc.step(noise_multiplier=z, sampling_rate=q, steps=steps)
        eps, order = acc.epsilon(delta)
        assert abs(eps - eps_ref) < 1e-6, (eps, eps_ref)
        assert order == order_ref

    def test_gaussian_rdp_is_analytic(self):
        """q=1: RDP(α) = α/(2σ²) exactly (Mironov 2017, Prop. 7)."""
        for sigma in (0.5, 1.0, 2.0, 8.0):
            rdp = rdp_sampled_gaussian(1.0, sigma, DEFAULT_ORDERS)
            ref = np.asarray(DEFAULT_ORDERS, np.float64) / (2 * sigma**2)
            np.testing.assert_allclose(rdp, ref, rtol=1e-12)

    def test_composition_is_additive(self):
        """T steps at once == T times one step == the T-scaled curve."""
        a, b = RdpAccountant(), RdpAccountant()
        a.step(noise_multiplier=1.3, sampling_rate=0.2, steps=40)
        for _ in range(40):
            b.step(noise_multiplier=1.3, sampling_rate=0.2, steps=1)
        np.testing.assert_allclose(a.rdp, b.rdp, rtol=1e-12)
        one = rdp_sampled_gaussian(0.2, 1.3, DEFAULT_ORDERS)
        np.testing.assert_allclose(a.rdp, 40 * one, rtol=1e-12)

    def test_epsilon_decreases_with_noise_and_subsampling(self):
        def eps(q, z):
            acc = RdpAccountant()
            acc.step(noise_multiplier=z, sampling_rate=q, steps=100)
            return acc.epsilon(1e-5)[0]

        assert eps(1.0, 2.0) < eps(1.0, 1.0) < eps(1.0, 0.5)
        assert eps(0.1, 1.0) < eps(0.5, 1.0) < eps(1.0, 1.0)

    def test_no_noise_means_no_guarantee(self):
        acc = RdpAccountant()
        acc.step(noise_multiplier=0.0, sampling_rate=1.0, steps=1)
        assert acc.epsilon(1e-5)[0] == math.inf

    def test_zero_steps_is_free(self):
        acc = RdpAccountant()
        assert acc.epsilon(1e-5)[0] == 0.0
        acc.step(noise_multiplier=1.0, sampling_rate=1.0, steps=0)
        assert acc.epsilon(1e-5)[0] == 0.0

    def test_conversion_matches_direct_minimum(self):
        """rdp_to_epsilon is exactly min_α [rdp + log(1/δ)/(α-1)]."""
        rdp = rdp_sampled_gaussian(0.3, 1.5, DEFAULT_ORDERS) * 25
        eps, order = rdp_to_epsilon(rdp, DEFAULT_ORDERS, 1e-6)
        direct = rdp + math.log(1e6) / (np.asarray(DEFAULT_ORDERS) - 1.0)
        assert abs(eps - direct.min()) < 1e-12
        assert order == DEFAULT_ORDERS[int(np.argmin(direct))]

    def test_rejects_bad_inputs(self):
        with pytest.raises(ValueError):
            rdp_sampled_gaussian(0.0, 1.0, DEFAULT_ORDERS)
        with pytest.raises(ValueError):
            rdp_sampled_gaussian(1.5, 1.0, DEFAULT_ORDERS)
        with pytest.raises(ValueError):
            rdp_to_epsilon(np.zeros(3), (2, 3, 4), 0.0)
        with pytest.raises(ValueError):
            rdp_sampled_gaussian(0.5, 1.0, (1.5, 2.0))


# ---------------------------------------------------------------------------
# Mechanism
# ---------------------------------------------------------------------------


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {"a": scale * jax.random.normal(k1, (5,)),
            "b": {"c": scale * jax.random.normal(k2, (2, 3))}}


class TestPolicy:
    def test_clip_bounds_norm(self):
        pol = PrivacyPolicy(clip_norm=1.0, noise_multiplier=0.0)
        big = _tree(jax.random.PRNGKey(0), scale=100.0)
        clipped = pol.clip(big)
        assert float(pol.global_norm(clipped)) <= 1.0 + 1e-5
        # Direction is preserved: clipping is a scalar rescale.
        ratio = np.asarray(clipped["a"]) / np.asarray(big["a"])
        np.testing.assert_allclose(ratio, ratio[0], rtol=1e-5)

    def test_clip_is_identity_inside_ball(self):
        pol = PrivacyPolicy(clip_norm=1e6, noise_multiplier=0.0)
        t = _tree(jax.random.PRNGKey(1))
        for l_in, l_out in zip(jax.tree_util.tree_leaves(t),
                               jax.tree_util.tree_leaves(pol.clip(t)), strict=True):
            np.testing.assert_allclose(l_in, l_out, rtol=1e-6)

    def test_noise_scale_and_replayability(self):
        pol = PrivacyPolicy(clip_norm=2.0, noise_multiplier=3.0)
        zeros = {"a": jnp.zeros((20_000,))}
        key = jax.random.PRNGKey(2)
        noised = pol.noise(zeros, key)
        std = float(jnp.std(noised["a"]))
        assert abs(std - 6.0) / 6.0 < 0.05  # z*C = 6 within MC tolerance
        again = pol.noise(zeros, key)
        np.testing.assert_array_equal(np.asarray(noised["a"]),
                                      np.asarray(again["a"]))

    def test_privatize_with_reference_returns_reference_plus_delta(self):
        """With zero noise and a huge clip, privatize(·, ref) is identity."""
        pol = PrivacyPolicy(clip_norm=1e9, noise_multiplier=0.0)
        ref = _tree(jax.random.PRNGKey(3))
        t = _tree(jax.random.PRNGKey(4))
        out = pol.privatize(t, jax.random.PRNGKey(5), reference=ref)
        for l_t, l_o in zip(jax.tree_util.tree_leaves(t),
                            jax.tree_util.tree_leaves(out), strict=True):
            np.testing.assert_allclose(l_t, l_o, rtol=1e-5, atol=1e-6)

    def test_upload_keys_are_distinct(self):
        pol = PrivacyPolicy()
        rk = jax.random.PRNGKey(0)
        keys = {tuple(np.asarray(pol.upload_key(rk, t, s)))
                for t in range(3) for s in range(3)}
        assert len(keys) == 9

    def test_validation(self):
        with pytest.raises(ValueError):
            PrivacyPolicy(clip_norm=0.0)
        with pytest.raises(ValueError):
            PrivacyPolicy(noise_multiplier=-1.0)
        with pytest.raises(ValueError):
            PrivacyPolicy(delta=0.0)


# ---------------------------------------------------------------------------
# Server integration: determinism + accounting thread-through
# ---------------------------------------------------------------------------


def _hier_problem(dG=3, dL=2):
    model = StructuredModel(
        global_dim=dG, local_dim=dL,
        log_prior_global=lambda th, zg: -0.5 * jnp.sum((zg - th["m"]) ** 2),
        log_local=lambda th, zg, zl, d: (
            -0.5 * jnp.sum((zl - jnp.mean(zg)) ** 2)
            - 0.5 * jnp.sum((d["y"] - zl[None, :]) ** 2)
        ),
    )
    return SFVIProblem(
        model, DiagGaussian(dG), ConditionalGaussian(dL, dG, use_coupling=False)
    )


def _flat(tree):
    return jnp.concatenate([jnp.ravel(x) for x in jax.tree_util.tree_leaves(tree)])


def _server(privacy, seed=11):
    prob = _hier_problem()
    datas = [{"y": jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(9), j),
                                     (4, 2))} for j in range(3)]
    return Server(
        prob, datas, {"m": jnp.asarray(0.2)},
        prob.global_family.init(jax.random.PRNGKey(1)),
        server_opt=sgd(3e-2), local_opt=sgd(3e-2), privacy=privacy, seed=seed,
    )


class TestServerDP:
    def test_deterministic_given_seed(self):
        """Same seed -> bit-identical trajectory, DP noise included."""
        pol = PrivacyPolicy(clip_norm=1.0, noise_multiplier=1.0)
        a, b = _server(pol), _server(pol)
        ha = a.run(3, algorithm="sfvi", local_steps=2)
        hb = b.run(3, algorithm="sfvi", local_steps=2)
        np.testing.assert_array_equal(np.asarray(_flat(a.theta)),
                                      np.asarray(_flat(b.theta)))
        np.testing.assert_array_equal(np.asarray(_flat(a.eta_G)),
                                      np.asarray(_flat(b.eta_G)))
        assert ha["epsilon"] == hb["epsilon"]

    def test_noise_perturbs_trajectory(self):
        noisy = _server(PrivacyPolicy(clip_norm=1.0, noise_multiplier=1.0))
        clean = _server(None)
        noisy.run(2, algorithm="sfvi")
        clean.run(2, algorithm="sfvi")
        assert not np.allclose(np.asarray(_flat(noisy.eta_G)),
                               np.asarray(_flat(clean.eta_G)))

    def test_clip_only_changes_updates_but_reports_inf(self):
        clipped = _server(PrivacyPolicy(clip_norm=1e-3, noise_multiplier=0.0))
        h = clipped.run(2, algorithm="sfvi")
        assert h["epsilon"][-1] == math.inf  # noise-free: no DP guarantee
        clean = _server(None)
        clean.run(2, algorithm="sfvi")
        assert not np.allclose(np.asarray(_flat(clipped.eta_G)),
                               np.asarray(_flat(clean.eta_G)))

    @pytest.mark.parametrize("algorithm", ["sfvi", "sfvi_avg"])
    def test_inactive_silo_data_cannot_influence_round(self, algorithm):
        """Under partial participation the DP round's output must be
        invariant to an excluded silo's data (its upload is replaced by
        a data-independent tree before the gather — the property the
        accountant's subsampling amplification rests on)."""
        pol = PrivacyPolicy(clip_norm=1.0, noise_multiplier=1.0)
        prob = _hier_problem()
        key = jax.random.PRNGKey(9)
        datas = [{"y": jax.random.normal(jax.random.fold_in(key, j), (4, 2))}
                 for j in range(3)]
        poisoned = [dict(d) for d in datas]
        poisoned[2] = {"y": 1e6 * jnp.ones((4, 2))}
        mask = jnp.asarray([1.0, 1.0, 0.0])
        # SFVI takes one participation mask PER exchange (K, J).
        mask_arg = jnp.stack([mask, mask]) if algorithm == "sfvi" else mask

        outs = []
        for ds in (datas, poisoned):
            srv = Server(prob, ds, {"m": jnp.asarray(0.2)},
                         prob.global_family.init(jax.random.PRNGKey(1)),
                         server_opt=sgd(3e-2), local_opt=sgd(3e-2),
                         privacy=pol, seed=11)
            fn = srv._get_round(algorithm, 2)
            state, _ = fn(srv.state, srv.data, jnp.asarray(srv.num_obs),
                          jax.random.PRNGKey(0), mask_arg, mask_arg)
            outs.append((state["theta"], state["eta_G"]))
        np.testing.assert_array_equal(np.asarray(_flat(outs[0][0])),
                                      np.asarray(_flat(outs[1][0])))
        np.testing.assert_array_equal(np.asarray(_flat(outs[0][1])),
                                      np.asarray(_flat(outs[1][1])))

    @pytest.mark.parametrize("algorithm", ["sfvi", "sfvi_avg"])
    def test_epsilon_grows_per_round_and_matches_accountant(self, algorithm):
        pol = PrivacyPolicy(clip_norm=1.0, noise_multiplier=1.0, delta=1e-5)
        srv = _server(pol)
        K = 2
        h = srv.run(3, algorithm=algorithm, local_steps=K)
        assert np.all(np.diff(h["epsilon"]) > 0)
        exchanges = K if algorithm == "sfvi" else 1
        ref = RdpAccountant()
        ref.step(noise_multiplier=1.0, sampling_rate=1.0, steps=3 * exchanges)
        assert abs(h["epsilon"][-1] - ref.epsilon(1e-5)[0]) < 1e-9
        # SFVI pays K mechanism invocations per round; the server's own
        # accountant must agree.
        assert srv.accountant.steps == 3 * exchanges


# ---------------------------------------------------------------------------
# Compiled-graph invariance (multi-device subprocess)
# ---------------------------------------------------------------------------

_HLO_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")
    import re, sys
    import jax, jax.numpy as jnp
    from repro.core import (ConditionalGaussian, DiagGaussian, SFVIProblem,
                            StructuredModel)
    from repro.federated import PrivacyPolicy, Server
    from repro.optim.adam import adam

    model = StructuredModel(
        global_dim=3, local_dim=2,
        log_prior_global=lambda th, zg: -0.5 * jnp.sum((zg - th["m"]) ** 2),
        log_local=lambda th, zg, zl, d: (
            -0.5 * jnp.sum((zl - jnp.mean(zg)) ** 2)
            - 0.5 * jnp.sum((d["y"] - zl[None, :]) ** 2)),
    )
    prob = SFVIProblem(model, DiagGaussian(3),
                       ConditionalGaussian(2, 3, use_coupling=False))
    datas = [{"y": jax.random.normal(
        jax.random.fold_in(jax.random.PRNGKey(2), j), (4, 2))}
        for j in range(4)]
    pol = PrivacyPolicy(clip_norm=1.0, noise_multiplier=1.0)
    for algo, K in (("sfvi", 1), ("sfvi", 3), ("sfvi_avg", 3)):
        srv = Server(prob, datas, {"m": jnp.asarray(0.1)},
                     prob.global_family.init(jax.random.PRNGKey(1)),
                     server_opt=adam(1e-2), local_opt=adam(1e-2),
                     privacy=pol, seed=0)
        fn = srv._get_round(algo, K)
        mask_shape = (K, 4) if algo == "sfvi" else (4,)
        ones = jnp.ones(mask_shape, jnp.float32)
        args = (srv.state, srv.data, jnp.asarray(srv.num_obs),
                jax.random.PRNGKey(0), ones, ones)
        hlo = fn.lower(*args).compile().as_text()
        n_ag = len(re.findall(r"\\ball-gather(?:-start)?\\(", hlo))
        coll = srv.compiled_collective_bytes(algo, K)
        assert n_ag == 1, (algo, K, n_ag)
        assert coll.get("all-gather", 0) > 0, (algo, K, coll)
        print(algo, K, "OK", n_ag, coll["all-gather"])
""")


@pytest.mark.slow
def test_dp_round_is_single_gather_graph():
    """DP rounds compile to exactly ONE all_gather — independent of
    local_steps and identical in structure for SFVI and SFVI-Avg —
    verified on a real 4-device mesh (forced host devices) where XLA
    cannot elide the collective. compiled_collective_bytes must see the
    gather too (acceptance criterion)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _HLO_SCRIPT],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1200,
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert out.stdout.count("OK") == 3, out.stdout
