"""Tests for Wasserstein barycenters (paper §3.2, point 3).

Hypothesis-driven where installed; seeded sweeps keep the same
invariants covered offline (the two-tier convention of
``test_aggregation_properties.py``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # offline container: seeded sweeps below still run
    HAVE_HYPOTHESIS = False

from repro.core import (
    CholeskyGaussian,
    DiagGaussian,
    diag_barycenter,
    family_barycenter,
    gaussian_barycenter,
    gaussian_barycenter_cov,
    sqrtm_eigh,
    sqrtm_newton_schulz,
    wasserstein2_gaussian,
)
from repro.core.barycenter import barycenter_params_diag, barycenter_params_full
from repro.core.families import ConditionalGaussian, LowRankGaussian
from repro.federated.aggregation import MeanAggregator


def _random_spd(key, d, scale=1.0):
    a = jax.random.normal(key, (d, d))
    return scale * (a @ a.T + d * jnp.eye(d))


class TestSqrtm:
    @staticmethod
    def _check_newton_schulz(d, seed):
        m = _random_spd(jax.random.PRNGKey(seed), d)
        s1 = sqrtm_eigh(m)
        s2 = sqrtm_newton_schulz(m, num_iters=30)
        np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-3)

    def test_newton_schulz_matches_eigh_seeded(self):
        rng = np.random.default_rng(0)
        for _ in range(15):
            self._check_newton_schulz(int(rng.integers(1, 7)),
                                      int(rng.integers(0, 1000)))

    if HAVE_HYPOTHESIS:

        @settings(max_examples=15, deadline=None)
        @given(d=st.integers(1, 6), seed=st.integers(0, 1000))
        def test_newton_schulz_matches_eigh(self, d, seed):
            self._check_newton_schulz(d, seed)

    def test_sqrtm_squares_back(self):
        m = _random_spd(jax.random.PRNGKey(0), 4)
        s = sqrtm_eigh(m)
        np.testing.assert_allclose(s @ s, m, rtol=1e-4, atol=1e-4)


class TestDiagBarycenter:
    def test_identical_inputs_fixed_point(self):
        mus = jnp.tile(jnp.array([1.0, -1.0]), (4, 1))
        sigmas = jnp.tile(jnp.array([0.5, 2.0]), (4, 1))
        mu, sigma = diag_barycenter(mus, sigmas)
        np.testing.assert_allclose(mu, mus[0], rtol=1e-6)
        np.testing.assert_allclose(sigma, sigmas[0], rtol=1e-6)

    def test_analytic_formula(self):
        """σ* = (J⁻¹ Σ_j Σ_j^{1/2})² — i.e. stds average linearly."""
        sigmas = jnp.array([[1.0], [4.0]])  # stds
        mus = jnp.zeros((2, 1))
        _, sigma = diag_barycenter(mus, sigmas)
        np.testing.assert_allclose(sigma, jnp.array([2.5]), rtol=1e-6)

    def test_diag_agrees_with_full_fixed_point(self):
        """The fixed-point iteration on diagonal covariances must reproduce
        the analytic diagonal solution."""
        stds = jnp.array([[0.5, 1.0], [1.5, 2.0], [1.0, 0.3]])
        covs = jax.vmap(lambda s: jnp.diag(s**2))(stds)
        cov_star = gaussian_barycenter_cov(covs, num_fp_iters=100)
        _, sigma_star = diag_barycenter(jnp.zeros((3, 2)), stds)
        np.testing.assert_allclose(
            jnp.diag(cov_star), sigma_star**2, rtol=1e-4, atol=1e-5
        )
        # off-diagonals stay ~0
        np.testing.assert_allclose(cov_star[0, 1], 0.0, atol=1e-5)

    def test_weighted(self):
        mus = jnp.array([[0.0], [1.0]])
        sigmas = jnp.ones((2, 1))
        mu, _ = diag_barycenter(mus, sigmas, weights=jnp.array([0.25, 0.75]))
        np.testing.assert_allclose(mu, jnp.array([0.75]), rtol=1e-6)


class TestFullBarycenter:
    def test_barycenter_satisfies_fixed_point(self):
        """Σ* = J⁻¹ Σ_j (Σ*^{1/2} Σ_j Σ*^{1/2})^{1/2} at the solution."""
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        covs = jnp.stack([_random_spd(k, 3, 0.5) for k in keys])
        cov_star = gaussian_barycenter_cov(covs, num_fp_iters=200)
        root = sqrtm_eigh(cov_star)
        rhs = jnp.mean(
            jax.vmap(lambda c: sqrtm_eigh(root @ c @ root))(covs), axis=0
        )
        np.testing.assert_allclose(cov_star, rhs, rtol=5e-3, atol=5e-3)

    def test_barycenter_minimizes_w2_sum(self):
        """Perturbing the barycenter increases Σ_j W₂²."""
        keys = jax.random.split(jax.random.PRNGKey(1), 3)
        covs = jnp.stack([_random_spd(k, 2, 0.3) for k in keys])
        mus = jax.random.normal(jax.random.PRNGKey(2), (3, 2))
        mu_s, cov_s = gaussian_barycenter(mus, covs, num_fp_iters=200)

        def w2_sum(mu, cov):
            return sum(
                float(wasserstein2_gaussian(mu, cov, mus[j], covs[j]))
                for j in range(3)
            )

        base = w2_sum(mu_s, cov_s)
        for seed in range(3):
            d_mu = 0.05 * jax.random.normal(jax.random.PRNGKey(10 + seed), (2,))
            perturbed_cov = cov_s + 0.05 * _random_spd(jax.random.PRNGKey(20 + seed), 2, 0.05)
            assert w2_sum(mu_s + d_mu, perturbed_cov) > base - 1e-6

    def test_w2_zero_for_identical(self):
        cov = _random_spd(jax.random.PRNGKey(3), 4)
        mu = jax.random.normal(jax.random.PRNGKey(4), (4,))
        np.testing.assert_allclose(
            wasserstein2_gaussian(mu, cov, mu, cov), 0.0, atol=1e-3
        )


def _stacked_cholesky(fam, J, seed, spread=0.35):
    ps = []
    for j in range(J):
        k = jax.random.fold_in(jax.random.PRNGKey(seed), j)
        p = fam.init(k, mu_scale=1.0, log_sigma_init=-0.3)
        p["L_packed"] = spread * jax.random.normal(
            jax.random.fold_in(k, 99), p["L_packed"].shape)
        ps.append(p)
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ps)


class TestGenericFamilyBarycenter:
    """family_barycenter — the eta_mode='barycenter' merge, generic over
    the moment bridge (acceptance criteria of the family API redesign)."""

    def test_diag_form_matches_analytic_formula(self):
        fam = DiagGaussian(3)
        J = 4
        stacked = jax.vmap(lambda k: fam.init(k, mu_scale=1.0))(
            jax.random.split(jax.random.PRNGKey(0), J))
        w = jnp.asarray([0.25, 1.0, 0.5, 1.0])
        out = family_barycenter(fam, stacked, w, MeanAggregator())
        ww = np.asarray(w) / np.asarray(w).sum()
        mu_ref = (ww[:, None] * np.asarray(stacked["mu"])).sum(0)
        sig_ref = (ww[:, None] * np.exp(np.asarray(stacked["log_sigma"]))).sum(0)
        np.testing.assert_allclose(out["mu"], mu_ref, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.exp(out["log_sigma"]), sig_ref,
                                   rtol=1e-5, atol=1e-6)

    def test_cholesky_in_graph_matches_eigh_host_oracle_1e5(self):
        """The jitted Newton–Schulz fixed point must match the host-side
        sqrtm_eigh oracle to 1e-5 (acceptance criterion)."""
        fam = CholeskyGaussian(4)
        J = 3
        stacked = _stacked_cholesky(fam, J, seed=1)
        w = jnp.ones((J,))
        out = jax.jit(
            lambda s, ww: family_barycenter(fam, s, ww, MeanAggregator())
        )(stacked, w)
        cov_got = np.asarray(fam.covariance(out))

        mus = np.asarray(stacked["mu"])
        covs = jnp.stack([
            fam.covariance(jax.tree_util.tree_map(lambda x, jj=j: x[jj],
                                                  stacked))
            for j in range(J)])
        mu_ref, cov_ref = gaussian_barycenter(
            jnp.asarray(mus), covs, num_fp_iters=50, sqrtm=sqrtm_eigh)
        np.testing.assert_allclose(np.asarray(out["mu"]),
                                   np.asarray(mu_ref), atol=1e-5)
        np.testing.assert_allclose(cov_got, np.asarray(cov_ref), atol=1e-5)

    def test_sqrtm_iters_forwarded_to_wrapped_backends(self):
        """A functools.partial of Newton–Schulz must receive the
        caller's sqrtm_iters (the identity check would drop it)."""
        import functools

        fam = CholeskyGaussian(3)
        stacked = _stacked_cholesky(fam, 3, seed=2)
        w = jnp.ones((3,))
        direct = family_barycenter(fam, stacked, w, sqrtm_iters=35)
        wrapped = family_barycenter(
            fam, stacked, w,
            sqrtm=functools.partial(sqrtm_newton_schulz), sqrtm_iters=35)
        for k in direct:
            np.testing.assert_array_equal(np.asarray(direct[k]),
                                          np.asarray(wrapped[k]))

    def test_lowrank_full_form_runs(self):
        fam = LowRankGaussian(4, rank=2)
        J = 3
        stacked = jax.vmap(lambda k: fam.init(k, mu_scale=0.5))(
            jax.random.split(jax.random.PRNGKey(3), J))
        stacked["U"] = 0.3 * jax.random.normal(
            jax.random.PRNGKey(4), stacked["U"].shape)
        out = family_barycenter(fam, stacked, jnp.ones((J,)), MeanAggregator())
        assert np.all(np.isfinite(np.asarray(fam.covariance(out))))

    def test_zero_weight_members_are_excluded(self):
        """Padded/inactive silos (weight 0) must not move the merge —
        even when their parameters are garbage."""
        fam = CholeskyGaussian(3)
        stacked = _stacked_cholesky(fam, 3, seed=5)
        w = jnp.asarray([1.0, 1.0, 0.0])
        base = family_barycenter(fam, stacked, w, MeanAggregator())
        poisoned = {k: v.at[2].set(17.0 * jnp.ones_like(v[2]))
                    for k, v in stacked.items()}
        out = family_barycenter(fam, poisoned, w, MeanAggregator())
        for k in base:
            np.testing.assert_allclose(np.asarray(base[k]),
                                       np.asarray(out[k]),
                                       rtol=1e-5, atol=1e-6)

    def test_family_without_moments_raises(self):
        fam = ConditionalGaussian(2, 2)
        stacked = jax.vmap(fam.init)(jax.random.split(jax.random.PRNGKey(0), 2))
        with pytest.raises(ValueError, match="to_moments"):
            family_barycenter(fam, stacked, jnp.ones((2,)), MeanAggregator())


class TestFamilyBarycenterBridges:
    def test_diag_params_barycenter(self):
        fam = DiagGaussian(3)
        ps = [fam.init(jax.random.PRNGKey(i), mu_scale=1.0) for i in range(4)]
        out = barycenter_params_diag(fam, ps)
        mus = jnp.stack([p["mu"] for p in ps])
        np.testing.assert_allclose(out["mu"], jnp.mean(mus, 0), rtol=1e-5)

    def test_full_params_barycenter_identity_case(self):
        fam = CholeskyGaussian(2)
        p = fam.init(jax.random.PRNGKey(0))
        p["L_packed"] = jnp.array([0.4])
        out = barycenter_params_full(fam, [p, p, p])
        np.testing.assert_allclose(
            fam.covariance(out), fam.covariance(p), rtol=1e-3, atol=1e-4
        )
