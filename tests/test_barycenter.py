"""Tests for Wasserstein barycenters (paper §3.2, point 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed; pip install -e .[test]")
from hypothesis import given, settings, strategies as st

from repro.core import (
    CholeskyGaussian,
    DiagGaussian,
    diag_barycenter,
    gaussian_barycenter,
    gaussian_barycenter_cov,
    sqrtm_eigh,
    sqrtm_newton_schulz,
    wasserstein2_gaussian,
)
from repro.core.barycenter import barycenter_params_diag, barycenter_params_full


def _random_spd(key, d, scale=1.0):
    a = jax.random.normal(key, (d, d))
    return scale * (a @ a.T + d * jnp.eye(d))


class TestSqrtm:
    @settings(max_examples=15, deadline=None)
    @given(d=st.integers(1, 6), seed=st.integers(0, 1000))
    def test_newton_schulz_matches_eigh(self, d, seed):
        m = _random_spd(jax.random.PRNGKey(seed), d)
        s1 = sqrtm_eigh(m)
        s2 = sqrtm_newton_schulz(m, num_iters=30)
        np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-3)

    def test_sqrtm_squares_back(self):
        m = _random_spd(jax.random.PRNGKey(0), 4)
        s = sqrtm_eigh(m)
        np.testing.assert_allclose(s @ s, m, rtol=1e-4, atol=1e-4)


class TestDiagBarycenter:
    def test_identical_inputs_fixed_point(self):
        mus = jnp.tile(jnp.array([1.0, -1.0]), (4, 1))
        sigmas = jnp.tile(jnp.array([0.5, 2.0]), (4, 1))
        mu, sigma = diag_barycenter(mus, sigmas)
        np.testing.assert_allclose(mu, mus[0], rtol=1e-6)
        np.testing.assert_allclose(sigma, sigmas[0], rtol=1e-6)

    def test_analytic_formula(self):
        """σ* = (J⁻¹ Σ_j Σ_j^{1/2})² — i.e. stds average linearly."""
        sigmas = jnp.array([[1.0], [4.0]])  # stds
        mus = jnp.zeros((2, 1))
        _, sigma = diag_barycenter(mus, sigmas)
        np.testing.assert_allclose(sigma, jnp.array([2.5]), rtol=1e-6)

    def test_diag_agrees_with_full_fixed_point(self):
        """The fixed-point iteration on diagonal covariances must reproduce
        the analytic diagonal solution."""
        stds = jnp.array([[0.5, 1.0], [1.5, 2.0], [1.0, 0.3]])
        covs = jax.vmap(lambda s: jnp.diag(s**2))(stds)
        cov_star = gaussian_barycenter_cov(covs, num_fp_iters=100)
        _, sigma_star = diag_barycenter(jnp.zeros((3, 2)), stds)
        np.testing.assert_allclose(
            jnp.diag(cov_star), sigma_star**2, rtol=1e-4, atol=1e-5
        )
        # off-diagonals stay ~0
        np.testing.assert_allclose(cov_star[0, 1], 0.0, atol=1e-5)

    def test_weighted(self):
        mus = jnp.array([[0.0], [1.0]])
        sigmas = jnp.ones((2, 1))
        mu, _ = diag_barycenter(mus, sigmas, weights=jnp.array([0.25, 0.75]))
        np.testing.assert_allclose(mu, jnp.array([0.75]), rtol=1e-6)


class TestFullBarycenter:
    def test_barycenter_satisfies_fixed_point(self):
        """Σ* = J⁻¹ Σ_j (Σ*^{1/2} Σ_j Σ*^{1/2})^{1/2} at the solution."""
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        covs = jnp.stack([_random_spd(k, 3, 0.5) for k in keys])
        cov_star = gaussian_barycenter_cov(covs, num_fp_iters=200)
        root = sqrtm_eigh(cov_star)
        rhs = jnp.mean(
            jax.vmap(lambda c: sqrtm_eigh(root @ c @ root))(covs), axis=0
        )
        np.testing.assert_allclose(cov_star, rhs, rtol=5e-3, atol=5e-3)

    def test_barycenter_minimizes_w2_sum(self):
        """Perturbing the barycenter increases Σ_j W₂²."""
        keys = jax.random.split(jax.random.PRNGKey(1), 3)
        covs = jnp.stack([_random_spd(k, 2, 0.3) for k in keys])
        mus = jax.random.normal(jax.random.PRNGKey(2), (3, 2))
        mu_s, cov_s = gaussian_barycenter(mus, covs, num_fp_iters=200)

        def w2_sum(mu, cov):
            return sum(
                float(wasserstein2_gaussian(mu, cov, mus[j], covs[j]))
                for j in range(3)
            )

        base = w2_sum(mu_s, cov_s)
        for seed in range(3):
            d_mu = 0.05 * jax.random.normal(jax.random.PRNGKey(10 + seed), (2,))
            perturbed_cov = cov_s + 0.05 * _random_spd(jax.random.PRNGKey(20 + seed), 2, 0.05)
            assert w2_sum(mu_s + d_mu, perturbed_cov) > base - 1e-6

    def test_w2_zero_for_identical(self):
        cov = _random_spd(jax.random.PRNGKey(3), 4)
        mu = jax.random.normal(jax.random.PRNGKey(4), (4,))
        np.testing.assert_allclose(
            wasserstein2_gaussian(mu, cov, mu, cov), 0.0, atol=1e-3
        )


class TestFamilyBarycenterBridges:
    def test_diag_params_barycenter(self):
        fam = DiagGaussian(3)
        ps = [fam.init(jax.random.PRNGKey(i), mu_scale=1.0) for i in range(4)]
        out = barycenter_params_diag(fam, ps)
        mus = jnp.stack([p["mu"] for p in ps])
        np.testing.assert_allclose(out["mu"], jnp.mean(mus, 0), rtol=1e-5)

    def test_full_params_barycenter_identity_case(self):
        fam = CholeskyGaussian(2)
        p = fam.init(jax.random.PRNGKey(0))
        p["L_packed"] = jnp.array([0.4])
        out = barycenter_params_full(fam, [p, p, p])
        np.testing.assert_allclose(
            fam.covariance(out), fam.covariance(p), rtol=1e-3, atol=1e-4
        )
