"""The §Perf levers must be mathematically transparent: same loss, same
predictions — they only change sharding/layout."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import steps as S
from repro.models.backbone import transformer as T
from repro.models.backbone.bayes import token_nll
from repro.models.backbone.config import PerfConfig

KEY = jax.random.PRNGKey(3)


def test_masked_nll_equals_gather_nll():
    logits = jax.random.normal(KEY, (4, 16, 97))
    labels = jax.random.randint(KEY, (4, 16), 0, 97)
    a = token_nll(logits, labels, masked_gather=False)
    b = token_nll(logits, labels, masked_gather=True)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)


def test_pad_vocab_preserves_logits():
    """Padded model with the SAME weights produces identical logits on the
    real vocab columns and -inf on padding."""
    cfg0 = dataclasses.replace(get_config("qwen3-4b").reduced(), vocab_size=387)
    cfg1 = dataclasses.replace(cfg0, perf=PerfConfig(pad_vocab=True))
    p0 = T.init_params(KEY, cfg0)
    p1 = T.init_params(KEY, cfg1)
    # graft the unpadded weights into the padded tables
    V = cfg0.vocab_size
    p1["embed"]["tok"] = p1["embed"]["tok"].at[:V].set(p0["embed"]["tok"])
    p1["lm_head"] = p1["lm_head"].at[:, :V].set(p0["lm_head"])
    for k in ("units", "tail", "final_norm"):
        p1[k] = p0[k]
    tokens = jax.random.randint(KEY, (2, 8), 0, V)
    l0, _, _ = T.forward(p0, cfg0, {"tokens": tokens}, remat=False)
    l1, _, _ = T.forward(p1, cfg1, {"tokens": tokens}, remat=False)
    assert l1.shape[-1] == cfg1.padded_vocab == 512
    np.testing.assert_allclose(np.asarray(l1[..., :V]), np.asarray(l0),
                               atol=1e-5, rtol=1e-5)
    assert float(l1[..., V:].max()) < -1e29  # padding masked


def test_levers_train_step_loss_close():
    """All levers on vs off: loss agrees to float tolerance on CPU (the
    levers are resharding-only; pad_vocab adds masked columns that carry
    no probability mass)."""
    cfg0 = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                               vocab_size=387)
    cfg1 = dataclasses.replace(cfg0, perf=PerfConfig(
        masked_nll=True, pad_vocab=True, zero_opt=True, act_shard=False))
    B, Sq = 4, 16
    batch = {"tokens": jax.random.randint(KEY, (B, Sq), 0, 387),
             "labels": jax.random.randint(KEY, (B, Sq), 0, 387)}
    # identical theta via grafting (tied embeddings arch: one table)
    st0, _ = S.init_train_state(KEY, cfg0, 2, lr=1e-3)
    st1, _ = S.init_train_state(KEY, cfg1, 2, lr=1e-3)
    tok1 = st1.theta["embed"]["tok"].at[:387].set(st0.theta["embed"]["tok"])
    theta1 = dict(st1.theta)
    theta1["embed"] = {"tok": tok1}
    for k in ("units", "tail", "final_norm"):
        theta1[k] = st0.theta[k]
    st1 = S.TrainState(theta1, st0.eta_G, st0.eta_L, st1.opt_theta,
                       st0.opt_eta_G, st0.opt_eta_L, st1.step)
    m0 = jax.jit(S.make_train_step(cfg0, 2, remat=False))(st0, batch, jnp.int32(0))[1]
    m1 = jax.jit(S.make_train_step(cfg1, 2, remat=False))(st1, batch, jnp.int32(0))[1]
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]), rtol=1e-4)


def test_pad_heads_bitwise_exact():
    """Padded attention heads are sliced away before w_o: identical logits
    with identical params (lever 6)."""
    cfg0 = dataclasses.replace(get_config("llama3.2-3b").reduced(),
                               num_heads=3, num_kv_heads=1)
    cfg1 = dataclasses.replace(cfg0, perf=PerfConfig(pad_heads=4))
    p = T.init_params(KEY, cfg0)
    batch = {"tokens": jax.random.randint(KEY, (2, 16), 0, cfg0.vocab_size)}
    l0, _, _ = T.forward(p, cfg0, batch, remat=False)
    l1, _, _ = T.forward(p, cfg1, batch, remat=False)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
