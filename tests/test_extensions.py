"""Paper-Discussion extensions: IWAE/DReG objective and amortized local
inference (paper Remark)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DiagGaussian, iwae_objective, iwae_value, elbo_value
from repro.core.amortized import encode, encoder_init, log_q_local, sample_local

KEY = jax.random.PRNGKey(5)


def _gaussian_target(dim=3, mu0=1.5, sigma0=0.7):
    def log_joint(z):
        return -0.5 * jnp.sum((z - mu0) ** 2) / sigma0**2 - dim * jnp.log(sigma0)
    return log_joint


def test_iwae_bound_at_least_elbo():
    dim = 3
    fam = DiagGaussian(dim)
    params = fam.init(KEY)
    lj = _gaussian_target(dim)
    elbos, iwaes = [], []
    for s in range(8):
        k = jax.random.fold_in(KEY, s)
        elbos.append(float(elbo_value(lj, fam, params, k, num_samples=64)))
        iwaes.append(float(iwae_value(lj, fam, params, k, num_samples=64)))
    assert np.mean(iwaes) >= np.mean(elbos) - 1e-2


def test_iwae_dreg_optimizes_to_target():
    """Optimizing the DReG surrogate recovers the (Gaussian) target."""
    dim = 2
    fam = DiagGaussian(dim)
    params = fam.init(KEY)
    lj = _gaussian_target(dim, mu0=2.0, sigma0=0.5)

    @jax.jit
    def step(params, key):
        eps = jax.random.normal(key, (8, dim))
        g = jax.grad(lambda p: -iwae_objective(lj, fam, p, eps))(params)
        return jax.tree_util.tree_map(lambda p, gg: p - 0.05 * gg, params, g)

    for i in range(400):
        params = step(params, jax.random.fold_in(KEY, i))
    np.testing.assert_allclose(np.asarray(params["mu"]), 2.0, atol=0.15)
    np.testing.assert_allclose(
        np.exp(np.asarray(params["log_sigma"])), 0.5, atol=0.15)


def test_amortized_encoder_stl():
    """The amortized log q must carry no score gradient to φ (STL), and the
    reparametrized sample must be differentiable through φ."""
    phi = encoder_init(KEY, in_dim=4, hidden=8, latent_dim=2)
    y = jax.random.normal(KEY, (5, 4))
    eps = jax.random.normal(jax.random.fold_in(KEY, 1), (5, 2))

    def logq_of_phi(phi):
        z = jax.lax.stop_gradient(sample_local(phi, y, eps))
        return log_q_local(phi, y, z, stop_params=True)

    g = jax.grad(logq_of_phi)(phi)
    assert max(float(jnp.abs(x).max()) for x in jax.tree_util.tree_leaves(g)) == 0.0

    def path_obj(phi):  # pathwise gradient flows through the sample
        z = sample_local(phi, y, eps)
        return jnp.sum(z**2)

    g2 = jax.grad(path_obj)(phi)
    assert max(float(jnp.abs(x).max()) for x in jax.tree_util.tree_leaves(g2)) > 0


def test_amortized_fits_posterior_mean():
    """Toy conjugate check: y_k | z_k ~ N(z_k, 1), z_k ~ N(0,1) — the exact
    posterior is N(y/2, 1/2). Train the encoder with the amortized STL
    objective (Adam, per-obs normalized) and verify it learns the y/2 map
    and the sqrt(1/2) posterior scale."""
    from repro.optim.adam import adam
    from repro.optim.base import apply_updates

    N = 256
    phi = encoder_init(KEY, in_dim=1, hidden=16, latent_dim=1)
    ys = jax.random.normal(KEY, (N, 1)) * 1.5

    def objective(phi, key):
        eps = jax.random.normal(key, (N, 1))
        z = sample_local(phi, ys, eps)
        logp = -0.5 * jnp.sum((ys - z) ** 2) - 0.5 * jnp.sum(z**2)
        return -(logp - log_q_local(phi, ys, z)) / N

    opt = adam(1e-2)
    st = opt.init(phi)

    @jax.jit
    def step(phi, st, key):
        g = jax.grad(objective)(phi, key)
        up, st = opt.update(g, st, phi)
        return apply_updates(phi, up), st

    for i in range(800):
        phi, st = step(phi, st, jax.random.fold_in(KEY, i))
    mu, ls = encode(phi, ys)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(ys) / 2, atol=0.25)
    np.testing.assert_allclose(
        np.exp(np.asarray(ls)).mean(), np.sqrt(0.5), atol=0.1)
