"""Population dynamics: churn, warm-start, padded growth, serving.

Covers the acceptance surface of the population subsystem:
  * the event stream is a pure function of (seed, kind, index, silo);
  * a churn run (sync AND buffered-async) checkpoints and resumes
    bit-exactly mid-run — population state, buffer state, η_L and the
    remaining trajectory all match the uninterrupted run;
  * amortized warm-start of a joining silo reaches the
    frozen-population ELBO level in measurably fewer rounds than the
    cold family init;
  * a join leaves the pre-existing silos' trajectory untouched up to
    the join round (the growth is purely additive);
  * PVI/FedEP churn: a departed silo's site λ_j is bit-frozen across
    the depart→return gap and the site-sum invariant
    Σλ_j == nat(q_G) − nat(q_init) survives churn;
  * (forced 2 host devices) the padded silo axis grows in mesh-sized
    chunks: the compiled round retraces exactly when J_pad steps, and
    a resume that re-grows past a J_pad boundary stays bit-exact;
  * graph-cache tokens split on j_pad exactly when it changes.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.federated import graph_cache
from repro.federated.api import (Experiment, ExperimentSpec, ModelSpec,
                                 build)
from repro.federated.population import (_ARRIVAL, _DEPART, _RETURN, ACTIVE,
                                        DEPARTED, PopulationSpec,
                                        PopulationState, event_draw)
from repro.federated.scheduler import AsyncConfig, Scenario

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _spec(pop, *, algorithm="sfvi", num_silos=6, rounds=12, seed=0,
          async_buf=None, **over):
    scenario = Scenario(
        algorithm=algorithm,
        async_cfg=(AsyncConfig(buffer_size=async_buf)
                   if async_buf is not None else None))
    base = dict(model=ModelSpec("toy"), scenario=scenario,
                num_silos=num_silos, rounds=rounds, seed=seed,
                population=pop)
    base.update(over)
    return ExperimentSpec(**base)


_CHURN = PopulationSpec(initial=2, arrival_rate=0.6, departure_rate=0.2,
                        return_rate=0.5, seed=3)


def _tree_equal(a, b):
    flat_a = jax.tree_util.tree_leaves(a)
    flat_b = jax.tree_util.tree_leaves(b)
    return len(flat_a) == len(flat_b) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(flat_a, flat_b))


class TestEventStream:
    def test_draws_are_pure_and_distinct_per_cell(self):
        assert event_draw(0, _ARRIVAL, 3, 1) == event_draw(0, _ARRIVAL, 3, 1)
        cells = {(k, i, j): event_draw(7, k, i, j)
                 for k in (_ARRIVAL, _DEPART, _RETURN)
                 for i in range(4) for j in range(3)}
        assert len(set(cells.values())) == len(cells)
        assert all(0.0 <= v < 1.0 for v in cells.values())

    def test_state_round_trips_through_json(self):
        st = PopulationState(round=5, joined=3, status=[ACTIVE, DEPARTED,
                                                        ACTIVE],
                             last_present=[4, 1, 4])
        back = PopulationState.from_state(
            json.loads(json.dumps(st.state_dict())))
        assert back == st

    def test_spec_validation(self):
        with pytest.raises(ValueError, match="initial"):
            PopulationSpec(initial=0)
        with pytest.raises(ValueError, match="arrival_rate"):
            PopulationSpec(arrival_rate=1.5)
        with pytest.raises(ValueError, match="max_silos"):
            PopulationSpec(initial=4, max_silos=2)

    def test_roster_cap_enforced_against_staged_bundle(self):
        spec = _spec(PopulationSpec(initial=2, max_silos=9), num_silos=4)
        with pytest.raises(ValueError, match="max_silos"):
            build(spec)


class TestChurnResume:
    """Mid-run save → resume replays the churn schedule bit-exactly."""

    def _check(self, spec, tmp_path, cut):
        full = build(spec)
        h_full = full.run()

        exp = build(spec)
        exp.run(rounds=cut,
                callback=lambda r, m: exp.save(str(tmp_path))
                if r + 1 == cut else None)
        res = Experiment.resume(str(tmp_path))
        assert res.round == cut
        assert res.server.J == res.population.state.joined
        h_res = res.run()

        np.testing.assert_array_equal(
            np.asarray(h_full["elbo"][cut:]), np.asarray(h_res["elbo"]))
        assert _tree_equal(full.server.state["eta_L"],
                           res.server.state["eta_L"])
        assert _tree_equal(full.theta, res.theta)
        assert (full.population.state.state_dict()
                == res.population.state.state_dict())
        return full, res

    def test_sync_churn_resumes_bit_exact(self, tmp_path):
        spec = _spec(_CHURN)
        full, res = self._check(spec, tmp_path, cut=6)
        # The schedule actually churned: silos joined AND departed.
        assert full.population.state.joined > _CHURN.initial
        assert DEPARTED in full.population.state.status

    def test_async_churn_resumes_bit_exact(self, tmp_path):
        spec = _spec(_CHURN, algorithm="sfvi_avg", rounds=10, async_buf=2)
        full, res = self._check(spec, tmp_path, cut=5)
        assert (full.async_state.state_dict()
                == res.async_state.state_dict())

    def test_population_state_is_checkpointed_mid_async_run(self, tmp_path):
        """The regression this suite exists for: a mid-run save used to
        miss the async BufferState (it was only assigned after
        run_buffered returned), silently restarting the event loop."""
        spec = _spec(_CHURN, algorithm="sfvi_avg", rounds=10, async_buf=2)
        exp = build(spec)
        exp.run(rounds=4,
                callback=lambda r, m: exp.save(str(tmp_path))
                if r + 1 == 2 else None)
        step2 = json.load(open(os.path.join(tmp_path, "step_00000002.meta.json")))
        assert "async_state" in step2
        assert "population" in step2
        assert step2["population"]["round"] == 2


class TestWarmStart:
    def test_joining_silo_reaches_frozen_population_elbo_faster(self):
        """Acceptance criterion: the amortized warm-start closes the
        joining silo's ELBO gap in measurably fewer rounds than the
        cold family init. Target level: the same-length run with the
        full population present from round 0 (all-cold, so the target
        is what the federation itself reaches in this budget)."""
        rounds = 40

        def run(pop):
            spec = _spec(pop, num_silos=3, rounds=rounds)
            return np.asarray(build(spec).run()["elbo"])

        fixed = run(None)
        join = dict(initial=2, arrival_rate=1.0, seed=1)
        warm = run(PopulationSpec(warm_start=True, **join))
        cold = run(PopulationSpec(warm_start=False, **join))
        target = fixed[-5:].mean()

        def rounds_to_target(elbo):
            idx = np.nonzero(elbo >= target)[0]
            return int(idx[0]) if idx.size else len(elbo)

        r_warm, r_cold = rounds_to_target(warm), rounds_to_target(cold)
        assert r_warm + 5 <= r_cold, (r_warm, r_cold, target)


class TestAdditiveGrowth:
    def test_join_leaves_preexisting_trajectory_untouched(self):
        """Runs identical up to the join round: the growth is purely
        additive (satellite: pre-existing silos' trajectories
        unaffected by a mid-run join). pop seed 2 @ rate 0.3 first
        fires the arrival draw at round 3."""
        join_round = 3
        assert event_draw(2, _ARRIVAL, join_round, 2) < 0.3
        assert all(event_draw(2, _ARRIVAL, r, 2) >= 0.3
                   for r in range(join_round))

        def run(rate):
            pop = PopulationSpec(initial=2, arrival_rate=rate, seed=2)
            spec = _spec(pop, num_silos=3, rounds=6)
            exp = build(spec)
            snaps = []
            exp.run(callback=lambda r, m: snaps.append(
                jax.tree_util.tree_map(
                    lambda x: np.asarray(x)[:2].copy(),
                    exp.server.state["eta_L"])))
            return exp, snaps

        grown, snaps_g = run(0.3)
        frozen, snaps_f = run(0.0)
        assert grown.server.J == 3 and frozen.server.J == 2
        for r in range(join_round):
            assert _tree_equal(snaps_g[r], snaps_f[r]), r
        np.testing.assert_array_equal(
            np.asarray(grown.history["elbo"][:join_round]),
            np.asarray(frozen.history["elbo"][:join_round]))
        # ... and the join round itself diverges (the new silo enters
        # the round's aggregate ELBO) — additive, not inert.
        assert (grown.history["elbo"][join_round]
                != frozen.history["elbo"][join_round])


class TestSiteChurn:
    """Satellite: PVI/FedEP site state survives depart/return gaps."""

    @pytest.mark.parametrize("algorithm", ["pvi", "fed_ep"])
    def test_lambda_frozen_across_gap_and_site_sum_invariant(
            self, algorithm):
        from repro.federated.strategy import natural_from_eta

        pop = PopulationSpec(initial=3, arrival_rate=0.5,
                             departure_rate=0.25, return_rate=0.4,
                             staleness_decay=0.0, seed=5)
        spec = _spec(pop, algorithm=algorithm, num_silos=4, rounds=25,
                     local_steps=4)
        exp = build(spec)
        traj = []
        exp.run(callback=lambda r, m: traj.append((
            list(exp.population.state.status),
            jax.tree_util.tree_map(
                lambda x: np.asarray(x).copy(),
                exp.server.state["strategy"]["lam"]))))

        # Every depart→(return|end) gap: the λ row is bit-frozen while
        # the silo is away, for every silo that ever departed.
        gaps = 0
        J = exp.server.J
        for j in range(J):
            r = 0
            while r < len(traj):
                status, _ = traj[r]
                if j < len(status) and status[j] == DEPARTED:
                    start = r
                    while r < len(traj) and traj[r][0][j] == DEPARTED:
                        r += 1
                    gaps += 1
                    ref = jax.tree_util.tree_map(
                        lambda x: x[j], traj[start][1])
                    for rr in range(start + 1, r):
                        assert _tree_equal(ref, jax.tree_util.tree_map(
                            lambda x: x[j], traj[rr][1])), (j, rr)
                else:
                    r += 1
        assert gaps >= 2  # the schedule actually exercised the property

        # Σλ_j == nat(q_G) − nat(q_init), extended to churn.
        prob = exp.server.problem
        fam = prob.global_family
        eta0 = fam.init(jax.random.PRNGKey(spec.seed))
        nat0 = natural_from_eta(fam, eta0)
        natG = natural_from_eta(fam, exp.server.state["eta_G"])
        lam = exp.server.state["strategy"]["lam"]
        for k in ("h", "prec"):
            lam_sum = np.asarray(lam[k])[:exp.server.J].sum(axis=0)
            np.testing.assert_allclose(
                lam_sum, np.asarray(natG[k]) - np.asarray(nat0[k]),
                rtol=1e-3, atol=1e-3)


class TestGraphCacheToken:
    def test_token_changes_exactly_when_j_pad_does(self):
        spec_json = _spec(_CHURN).to_json(indent=0)
        mk = lambda jp: graph_cache.build_token(
            spec_json, "flat", 6, mesh_shape=(("silo", 2),), j_pad=jp)
        assert mk(2) == mk(2)
        assert mk(2) != mk(4)
        assert mk(4) == mk(4)


_GROWTH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")
    import tempfile

    import jax
    import numpy as np
    from repro.federated import (Experiment, ExperimentSpec, ModelSpec,
                                 PopulationSpec, Scenario, build)

    assert jax.device_count() == 2

    def leaves(exp):
        st = exp.server.state
        return [np.asarray(x) for k in ("theta", "eta_G", "eta_L")
                for x in jax.tree_util.tree_leaves(st[k])]

    # Every round joins the next roster silo: J walks 2,3,4,5 so the
    # padded axis must cross the 2-device chunk boundary (2 -> 4 -> 6).
    spec = ExperimentSpec(
        model=ModelSpec("toy", {"num_obs": 8}),
        scenario=Scenario(algorithm="sfvi"),
        num_silos=5, rounds=4, seed=0,
        population=PopulationSpec(initial=2, arrival_rate=1.0, seed=0))

    full = build(spec)
    pads, fns = [], []
    def snap(r, m):
        pads.append(full.server.J_pad)
        fns.append(len(full.server._round_fns))
    h_full = full.run(callback=snap)
    assert full.server.J == 5, full.server.J
    # Joins fire BEFORE their round, so the post-round snapshots see J
    # walk 3,4,5,5 — J_pad grows in mesh-sized (2) chunks...
    assert pads == [4, 4, 6, 6], pads
    # ...and the compiled round is refetched EXACTLY when J_pad steps:
    # the round-fn cache holds one entry per distinct J_pad seen (2
    # pre-join, then 4, then 6), none added within a chunk (round 1:
    # J 3->4 inside the 4-chunk, no new entry).
    assert fns == [2, 2, 3, 3], fns
    print("chunked-growth OK")

    # Resume saved at J=4 (J_pad=4) re-grows past the 6-boundary
    # bit-exactly: re-padding + per-(seed, j) fold-in init make the
    # re-grown rows identical to the uninterrupted run's.
    d = tempfile.mkdtemp()
    exp = build(spec)
    exp.run(rounds=2,
            callback=lambda r, m: exp.save(d) if r + 1 == 2 else None)
    res = Experiment.resume(d)
    assert res.server.J == 4 and res.server.J_pad == 4, (
        res.server.J, res.server.J_pad)
    h_res = res.run()
    np.testing.assert_array_equal(np.asarray(h_full["elbo"][2:]),
                                  np.asarray(h_res["elbo"]))
    for a, b in zip(leaves(full), leaves(res)):
        np.testing.assert_array_equal(a, b)
    print("boundary-resume OK")
""")


@pytest.mark.slow
def test_padded_growth_on_two_device_mesh():
    """Satellite: mesh-chunked silo-axis growth under forced host
    devices — J_pad steps in chunks, retrace count matches, and a
    resume that crosses a J_pad boundary stays bit-exact."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _GROWTH_SCRIPT],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1200,
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "chunked-growth OK" in out.stdout
    assert "boundary-resume OK" in out.stdout
