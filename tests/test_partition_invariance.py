"""Property tests for the paper's central Remark (§3): SFVI is invariant to
how the data is partitioned across silos — the federated gradient equals the
centralized gradient, for any partition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed; pip install -e .[test]")
from hypothesis import given, settings, strategies as st

from repro.core import (
    ConditionalGaussian,
    DiagGaussian,
    SFVIProblem,
    StructuredModel,
    tree_add,
)

# NOTE: float32 throughout (x64 would leak into the whole pytest session);
# invariance holds up to float32 reduction-order epsilon.


def _make_problem(dG, dL, use_coupling):
    def log_prior_global(theta, zg):
        return -0.5 * jnp.sum((zg - theta["m"]) ** 2)

    def log_local(theta, zg, zl, data):
        lp = -0.5 * jnp.sum((zl - jnp.mean(zg)) ** 2)
        ll = -0.5 * jnp.sum((data - zl[None, :]) ** 2) * jnp.exp(theta["lt"])
        return lp + ll

    model = StructuredModel(
        global_dim=dG, local_dim=dL,
        log_prior_global=log_prior_global, log_local=log_local,
    )
    gfam = DiagGaussian(dG)
    lfam = ConditionalGaussian(dL, dG, use_coupling=use_coupling)
    return SFVIProblem(model, gfam, lfam)


def _flat(tree):
    return jnp.concatenate([x.ravel() for x in jax.tree_util.tree_leaves(tree)])


@settings(max_examples=20, deadline=None)
@given(
    num_silos=st.integers(1, 5),
    dG=st.integers(1, 4),
    dL=st.integers(1, 3),
    use_coupling=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_federated_equals_centralized_gradient(num_silos, dG, dL, use_coupling, seed):
    prob = _make_problem(dG, dL, use_coupling)
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4 + 3 * num_silos)
    theta = {"m": jax.random.normal(ks[0], ()), "lt": jnp.asarray(-0.5)}
    eta_G = prob.global_family.init(ks[1], mu_scale=0.5)
    eps_G = jax.random.normal(ks[2], (dG,))
    etas_L, eps_L, datas = [], [], []
    for j in range(num_silos):
        etas_L.append(prob.local_family.init(ks[3 + 3 * j], mu_scale=0.5))
        eps_L.append(jax.random.normal(ks[4 + 3 * j], (dL,)))
        datas.append(jax.random.normal(ks[5 + 3 * j], (3, dL)))

    # Federated: server term + Σ_j silo terms.
    g_theta, g_eta, _ = prob.server_grads(theta, eta_G, eps_G)
    for j in range(num_silos):
        gtj, gej, _, _ = prob.silo_grads(
            theta, eta_G, etas_L[j], eps_G, eps_L[j], datas[j]
        )
        g_theta, g_eta = tree_add(g_theta, gtj), tree_add(g_eta, gej)

    # Centralized single-graph gradient.
    cent = jax.grad(
        lambda th, eg: prob.centralized_objective(th, eg, etas_L, eps_G, eps_L, datas),
        argnums=(0, 1),
    )(theta, eta_G)

    np.testing.assert_allclose(_flat(g_theta), _flat(cent[0]), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(_flat(g_eta), _flat(cent[1]), rtol=2e-4, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_repartitioning_preserves_objective(seed):
    """Moving observations between silos (with their local latents) leaves the
    total objective unchanged when local latents are per-observation."""
    # Model where each silo's latent is per-observation: split freely.
    dG = 2
    prob = _make_problem(dG, 1, use_coupling=False)
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    theta = {"m": jnp.asarray(0.1), "lt": jnp.asarray(0.0)}
    eta_G = prob.global_family.init(k1, mu_scale=0.3)
    eps_G = jax.random.normal(k2, (dG,))

    # 6 observations, each its own "micro-silo".
    n = 6
    etas = [prob.local_family.init(jax.random.fold_in(k3, i)) for i in range(n)]
    eps = [jax.random.normal(jax.random.fold_in(k4, i), (1,)) for i in range(n)]
    datas = [jax.random.normal(jax.random.fold_in(k4, 100 + i), (1, 1)) for i in range(n)]

    def total_for_partition(groups):
        val = prob.hat_L0(theta, eta_G, eps_G)
        for grp in groups:
            for i in grp:
                val = val + prob.hat_Lj(theta, eta_G, etas[i], eps_G, eps[i], datas[i])
        return float(val)

    v1 = total_for_partition([[0, 1, 2], [3, 4, 5]])
    v2 = total_for_partition([[0], [1, 2, 3, 4], [5]])
    v3 = total_for_partition([[0, 1, 2, 3, 4, 5]])
    np.testing.assert_allclose(v1, v2, rtol=1e-6)
    np.testing.assert_allclose(v1, v3, rtol=1e-6)
