"""CI regression gate: compare a junit XML report to the seed baseline.

    python tests/check_regressions.py junit.xml tests/seed_baseline.txt

Exit codes: 0 when every failure is recorded in the baseline (tier-1 is
no worse than the seed), 1 on any new failure. Fixed baseline entries
are reported (so the baseline file can be pruned) but do not fail the
job. Collection errors count as failures of their nodeid.
"""
from __future__ import annotations

import sys
import xml.etree.ElementTree as ET


def junit_failures(path: str):
    """(nodeids of failed/errored testcases, total testcases) in a report."""
    failed, total = set(), 0
    for case in ET.parse(path).getroot().iter("testcase"):
        total += 1
        if case.find("failure") is not None or case.find("error") is not None:
            cls = case.get("classname", "")
            name = case.get("name", "")
            # pytest junit classname is dotted (tests.test_x.TestY);
            # rebuild the nodeid-ish "tests/test_x.py::TestY::name" form.
            parts = cls.split(".") if cls else []
            file_parts, cls_parts = [], []
            for p in parts:
                (cls_parts if cls_parts or p[:1].isupper() else file_parts).append(p)
            nodeid = "/".join(file_parts) + ".py::" + "::".join(cls_parts + [name])
            failed.add(nodeid if file_parts else name)
    return failed, total


def baseline_entries(path: str) -> set:
    entries = set()
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line and not line.startswith("#"):
                entries.add(line)
    return entries


def main(argv) -> int:
    if len(argv) != 3:
        print(__doc__)
        return 2
    failed, total = junit_failures(argv[1])
    if total == 0:
        # A usage/collection-wide abort produces an empty report; the
        # pytest step defers to this gate, so an empty report must fail
        # — otherwise CI goes green having executed zero tests.
        print("REGRESSION: junit report contains no testcases "
              "(collection error or pytest abort?)")
        return 1
    baseline = baseline_entries(argv[2])
    new = sorted(failed - baseline)
    fixed = sorted(baseline - failed)
    if fixed:
        print("baseline entries now passing (prune them):")
        for t in fixed:
            print(f"  {t}")
    if new:
        print(f"REGRESSION: {len(new)} failure(s) not in the seed baseline:")
        for t in new:
            print(f"  {t}")
        return 1
    print(f"no regressions: {len(failed)} failure(s) among {total} tests, "
          f"all in baseline ({len(baseline)} recorded)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
