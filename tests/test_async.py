"""Tests for the buffered-asynchronous engine (repro.federated.async_engine)
and the padded silo mesh (prime-J fix).

Acceptance anchors:
  * ``buffer_size == J`` with constant latency reproduces the synchronous
    SFVI-Avg trajectory BIT-EXACTLY (same round-key stream, unit weights);
  * an async + DP + int8 spec round-trips through save -> resume
    bit-exactly, buffer state included;
  * a prime federation (J=7) on a forced 4-device host mesh uses all 4
    devices and matches the single-device trajectory (subprocess — JAX's
    device count is locked at first init in this process).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.federated import (
    AsyncConfig,
    BufferState,
    Experiment,
    ExperimentSpec,
    ModelSpec,
    OptimizerSpec,
    Scenario,
    build,
    scenario_matrix,
)
from repro.federated.async_engine import (
    flush_weights,
    latency_draw,
    simulate_flush,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _spec(sc: Scenario, *, silos=3, rounds=4, seed=3) -> ExperimentSpec:
    return ExperimentSpec(
        model=ModelSpec("toy", {"num_obs": 6}), scenario=sc,
        num_silos=silos, rounds=rounds, local_steps=2,
        server_opt=OptimizerSpec("adam", 2e-2), seed=seed,
    )


def _assert_trees_bit_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb, strict=True):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Latency model + event loop
# ---------------------------------------------------------------------------


class TestLatencyModel:
    def test_draws_are_deterministic(self):
        cfg = AsyncConfig(latency="lognormal", latency_scale=2.0)
        for j, t in [(0, 0), (3, 17), (1, 5)]:
            assert latency_draw(cfg, 7, j, t) == latency_draw(cfg, 7, j, t)

    def test_draws_vary_per_silo_and_task(self):
        cfg = AsyncConfig(latency="lognormal")
        draws = {latency_draw(cfg, 0, j, t) for j in range(4) for t in range(4)}
        assert len(draws) == 16

    def test_constant_is_constant(self):
        cfg = AsyncConfig(latency="constant", latency_scale=1.5)
        assert {latency_draw(cfg, 0, j, t) for j in range(3)
                for t in range(3)} == {1.5}

    def test_straggler_tail(self):
        cfg = AsyncConfig(latency="straggler", latency_scale=1.0,
                          straggler_frac=0.3, straggler_slowdown=10.0)
        draws = [latency_draw(cfg, 0, j, t) for j in range(20) for t in range(20)]
        assert set(draws) == {1.0, 10.0}
        frac = sum(d == 10.0 for d in draws) / len(draws)
        assert 0.15 < frac < 0.45  # ~straggler_frac

    def test_unknown_model_raises(self):
        cfg = AsyncConfig(latency="uniform")
        with pytest.raises(ValueError, match="latency model"):
            latency_draw(cfg, 0, 0, 0)


class TestEventLoop:
    def test_constant_full_buffer_is_synchronous_schedule(self):
        cfg = AsyncConfig(buffer_size=4, latency="constant", latency_scale=1.0)
        st = BufferState.init(4, cfg, seed=0)
        for f in range(3):
            counts, stale, t = simulate_flush(st, cfg, 0, 4)
            np.testing.assert_array_equal(counts, np.ones(4))
            np.testing.assert_array_equal(stale, np.zeros(4))
            assert t == pytest.approx(float(f + 1))

    def test_same_timestamp_flushes_keep_symmetric_staleness(self):
        """Two flushes sharing one simulated timestamp (J=4, B=2,
        constant latency) must not cross-contaminate pull versions:
        the flush-instant re-pull bump applies only to the silos that
        restarted in THAT drain, so the steady state is staleness == 1
        for every contributor, alternating {0,1} / {2,3} — not a
        spurious 0 for whichever pair restarted at the shared time."""
        cfg = AsyncConfig(buffer_size=2, latency="constant",
                          latency_scale=1.0)
        st = BufferState.init(4, cfg, seed=0)
        flushes = [simulate_flush(st, cfg, 0, 4) for _ in range(8)]
        np.testing.assert_array_equal(flushes[0][0], [1, 1, 0, 0])
        np.testing.assert_array_equal(flushes[0][1], [0, 0, 0, 0])
        np.testing.assert_array_equal(flushes[1][0], [0, 0, 1, 1])
        np.testing.assert_array_equal(flushes[1][1], [0, 0, 1, 1])
        for counts, stale, _ in flushes[2:]:
            np.testing.assert_array_equal(stale[counts > 0], [1.0, 1.0])

    def test_staleness_grows_for_slow_silo(self):
        # Silo 1 is ~10x slower than silo 0 under the straggler model:
        # force it by a lognormal with a huge spread and checking that
        # SOME flush carries staleness > 0.
        cfg = AsyncConfig(buffer_size=1, latency="lognormal",
                          latency_sigma=1.5)
        st = BufferState.init(3, cfg, seed=1)
        max_stale = 0.0
        for _ in range(12):
            counts, stale, _ = simulate_flush(st, cfg, 1, 3)
            max_stale = max(max_stale, float(stale.max(where=counts > 0,
                                                       initial=0.0)))
        assert max_stale > 0.0

    def test_buffer_state_json_round_trip(self):
        cfg = AsyncConfig(buffer_size=2, latency="lognormal")
        st = BufferState.init(3, cfg, seed=5)
        simulate_flush(st, cfg, 5, 3)
        blob = json.dumps(st.state_dict())
        back = BufferState.from_state(json.loads(blob))
        assert back == st  # dataclass equality: every field, floats exact

    def test_resumed_event_loop_matches_uninterrupted(self):
        cfg = AsyncConfig(buffer_size=2, latency="straggler")
        full = BufferState.init(4, cfg, seed=2)
        ref = [simulate_flush(full, cfg, 2, 4) for _ in range(6)]

        part = BufferState.init(4, cfg, seed=2)
        got = [simulate_flush(part, cfg, 2, 4) for _ in range(3)]
        part = BufferState.from_state(
            json.loads(json.dumps(part.state_dict())))
        got += [simulate_flush(part, cfg, 2, 4) for _ in range(3)]
        for (c0, s0, t0), (c1, s1, t1) in zip(ref, got, strict=True):
            np.testing.assert_array_equal(c0, c1)
            np.testing.assert_array_equal(s0, s1)
            assert t0 == t1

    def test_flush_weights(self):
        w = flush_weights(np.array([1.0, 2.0, 0.0]), np.array([0.0, 3.0, 0.0]),
                          decay=1.0)
        np.testing.assert_allclose(w, [1.0, 0.5, 0.0])
        # decay=0 disables staleness weighting entirely.
        w0 = flush_weights(np.array([1.0, 1.0]), np.array([0.0, 9.0]), 0.0)
        np.testing.assert_array_equal(w0, [1.0, 1.0])


# ---------------------------------------------------------------------------
# Acceptance: sync equivalence + save/resume
# ---------------------------------------------------------------------------


class TestAsyncEngine:
    def test_full_buffer_zero_jitter_matches_sync_bit_exact(self):
        """buffer_size == J + constant latency == the synchronous
        SFVI-Avg trajectory, bit for bit (acceptance criterion)."""
        sync = build(_spec(Scenario(algorithm="sfvi_avg")))
        h_sync = sync.run()
        async_ = build(_spec(Scenario(
            algorithm="sfvi_avg",
            async_cfg=AsyncConfig(buffer_size=3, staleness_decay=1.0,
                                  latency="constant"))))
        h_async = async_.run()
        for k in ("theta", "eta_G", "eta_L"):
            _assert_trees_bit_equal(sync.server.state[k], async_.server.state[k])
        assert h_sync["elbo"] == h_async["elbo"]
        # Full buffer at zero jitter: everyone contributes every flush.
        assert h_async["n_active"] == [3] * 4
        assert h_async["staleness"] == [0.0] * 4

    def test_async_runs_make_progress_under_stragglers(self):
        exp = build(_spec(Scenario(
            algorithm="sfvi_avg",
            async_cfg=AsyncConfig(buffer_size=2, latency="straggler")),
            rounds=12))
        h = exp.run()
        assert h["elbo"][-1] > h["elbo"][0]
        # Simulated time advances monotonically and the meter tracked it.
        assert np.all(np.diff(h["sim_time"]) >= 0)
        assert exp.comm.sim_seconds == pytest.approx(h["sim_time"][-1])

    def test_single_contribution_flush_invariant_to_decay(self):
        """MeanAggregator denominator regression (the async path): with
        buffer_size=1 every flush holds exactly one contribution, so the
        staleness-decayed weighted MEAN must equal that contribution
        regardless of the decay exponent — the old ``max(Σw, 1.0)``
        clamp divided a weight-0.25 parameter upload by 1.0, silently
        shrinking it 4× toward zero."""
        cfg = dict(buffer_size=1, latency="lognormal", latency_sigma=1.0)
        runs = []
        for decay in (0.0, 5.0):
            exp = build(_spec(Scenario(
                algorithm="sfvi_avg",
                async_cfg=AsyncConfig(staleness_decay=decay, **cfg)),
                rounds=8))
            h = exp.run()
            # The schedule really produced stale (weight < 1) arrivals.
            assert max(h["staleness"]) > 0.0
            runs.append(exp)
        for k in ("theta", "eta_G", "eta_L"):
            _assert_trees_bit_equal(runs[0].server.state[k],
                                    runs[1].server.state[k])

    def test_async_dp_int8_save_resume_bit_exact(self, tmp_path):
        """Async + DP + int8 spec: save -> resume reproduces the
        uninterrupted run bit-exactly, buffer state included
        (acceptance criterion)."""
        sc = Scenario(algorithm="sfvi_avg", compression="int8", dp_noise=0.6,
                      dp_clip=0.9,
                      async_cfg=AsyncConfig(buffer_size=2, latency="lognormal"))
        spec = _spec(sc, rounds=6)
        full = build(spec)
        full.run()

        part = build(spec)
        part.run(3)
        part.save(str(tmp_path))
        resumed = Experiment.resume(str(tmp_path))
        assert resumed.round == 3
        # The buffer state crossed the checkpoint boundary.
        assert resumed.async_state == part.async_state
        resumed.run()

        for k in ("theta", "eta_G", "eta_L"):
            _assert_trees_bit_equal(full.server.state[k],
                                    resumed.server.state[k])
        assert (full.accountant.epsilon(sc.dp_delta)
                == resumed.accountant.epsilon(sc.dp_delta))
        assert full.comm.state_dict() == resumed.comm.state_dict()

    def test_spec_json_round_trip_with_async_block(self):
        sc = Scenario(algorithm="sfvi_avg", dp_noise=0.5,
                      async_cfg=AsyncConfig(buffer_size=4, latency="straggler",
                                            straggler_slowdown=25.0))
        spec = _spec(sc, silos=6)
        assert ExperimentSpec.from_json(spec.to_json()) == spec
        d = json.loads(spec.to_json())
        assert d["scenario"]["async_cfg"]["buffer_size"] == 4

    def test_async_name_in_scenario_label(self):
        sc = Scenario(algorithm="sfvi_avg",
                      async_cfg=AsyncConfig(buffer_size=2, latency="straggler"))
        assert "async(B=2,straggler" in sc.name

    def test_scenario_matrix_emits_async_rows_only_where_valid(self):
        grid = scenario_matrix(async_cfgs=(None, AsyncConfig(buffer_size=2)))
        async_rows = [s for s in grid if s.async_cfg is not None]
        assert async_rows, "matrix must include async rows"
        for s in async_rows:
            s.validate(4)  # must not raise

    def test_validation_rejects_bad_combinations(self):
        acfg = AsyncConfig(buffer_size=2)
        with pytest.raises(ValueError, match="sfvi_avg"):
            Scenario(algorithm="sfvi", async_cfg=acfg).validate()
        with pytest.raises(ValueError, match="participation"):
            Scenario(participation=0.5, async_cfg=acfg).validate()
        with pytest.raises(ValueError, match="exceeds"):
            Scenario(async_cfg=AsyncConfig(buffer_size=9)).validate(4)
        with pytest.raises(ValueError, match="sfvi_avg"):
            build(_spec(Scenario(algorithm="sfvi", async_cfg=acfg)))


# ---------------------------------------------------------------------------
# Padded silo mesh: the prime-J regression (subprocess, 4 forced devices)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")
    import jax, numpy as np
    import jax.sharding
    from repro.federated import (ExperimentSpec, ModelSpec, OptimizerSpec,
                                 Scenario, build)
    from repro.federated.runtime import Server
    from repro.launch.mesh import make_silo_mesh
    from repro.models.paper.registry import get_model

    assert jax.device_count() == 4
    # Regression: a prime J used to shrink the mesh to its largest
    # divisor of J — gcd(7, 4) = 1 device, the whole federation on one
    # chip. The mesh must now span all 4 devices.
    mesh = make_silo_mesh(7)
    assert mesh.shape["silo"] == 4, mesh.shape

    spec = ExperimentSpec(model=ModelSpec("toy", {"num_obs": 6}),
                          scenario=Scenario(algorithm="sfvi_avg"),
                          num_silos=7, rounds=3, local_steps=2,
                          server_opt=OptimizerSpec("adam", 2e-2), seed=0)
    multi = build(spec)
    assert multi.server.mesh.shape["silo"] == 4
    assert multi.server.J_pad == 8
    h4 = multi.run()

    bundle = get_model("toy").build(0, 7, num_obs=6)
    prob = bundle.problem
    srv = Server(prob, bundle.datas, bundle.theta0,
                 prob.global_family.init(jax.random.PRNGKey(0)),
                 num_obs=bundle.num_obs, server_opt=spec.server_opt.build(),
                 local_opt=spec.server_opt.build(),
                 mesh=jax.sharding.Mesh(jax.devices()[:1], ("silo",)), seed=0)
    h1 = srv.run(3, algorithm="sfvi_avg", local_steps=2)
    for x, y in zip(jax.tree_util.tree_leaves(multi.server.eta_G),
                    jax.tree_util.tree_leaves(srv.eta_G)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(h4["elbo"], h1["elbo"], rtol=1e-5)
    print("MESH-OK")
""")


@pytest.mark.slow
def test_prime_j_uses_all_devices_and_matches_single_device():
    """J=7 on a 4-device CPU mesh spans all 4 devices (padded silo axis)
    and reproduces the J=7 single-device trajectory (satellite task)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1200,
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "MESH-OK" in out.stdout


class TestPaddedMeshSingleDevice:
    def test_no_padding_on_divisible_mesh(self):
        exp = build(_spec(Scenario(algorithm="sfvi_avg"), silos=3))
        assert exp.server.J_pad == exp.server.J == 3

    def test_resume_repads_silo_axis(self, tmp_path):
        """Resume restores the J real silo shards and re-pads to the
        current mesh's J_pad (single-device here: J_pad == J)."""
        spec = _spec(Scenario(algorithm="sfvi_avg"), silos=3)
        exp = build(spec)
        exp.run(2)
        exp.save(str(tmp_path))
        resumed = Experiment.resume(str(tmp_path))
        leaves = jax.tree_util.tree_leaves(resumed.server.eta_L)
        assert all(x.shape[0] == resumed.server.J_pad for x in leaves)
        _assert_trees_bit_equal(exp.server.eta_L, resumed.server.eta_L)
