"""Tests for the from-scratch optimizers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    adam,
    adamw,
    apply_updates,
    chain,
    clip_by_global_norm,
    cosine_decay_schedule,
    linear_warmup_cosine_decay,
    momentum,
    scale_by_schedule,
    sgd,
)
from repro.optim.base import global_norm


def _quadratic_losses(opt, steps=200):
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
    target = {"w": jnp.array([0.5, 0.5]), "b": jnp.array(-0.25)}

    def loss(p):
        return jnp.sum((p["w"] - target["w"]) ** 2) + (p["b"] - target["b"]) ** 2

    state = opt.init(params)
    losses = []
    for _ in range(steps):
        g = jax.grad(loss)(params)
        updates, state = opt.update(g, state, params)
        params = apply_updates(params, updates)
        losses.append(float(loss(params)))
    return losses


class TestAdam:
    def test_converges_on_quadratic(self):
        losses = _quadratic_losses(adam(0.1))
        assert losses[-1] < 1e-4

    def test_first_step_is_lr_sized(self):
        """Adam's bias correction makes the first update ~= lr * sign(g)."""
        opt = adam(0.1)
        params = {"x": jnp.array([1.0])}
        state = opt.init(params)
        updates, _ = opt.update({"x": jnp.array([123.0])}, state, params)
        np.testing.assert_allclose(updates["x"], jnp.array([-0.1]), rtol=1e-4)

    def test_maximize_flag(self):
        opt = adam(0.1, maximize=True)
        params = {"x": jnp.array([0.0])}
        state = opt.init(params)
        updates, _ = opt.update({"x": jnp.array([1.0])}, state, params)
        assert float(updates["x"][0]) > 0

    def test_adamw_decays_weights(self):
        opt = adamw(0.1, weight_decay=0.5)
        params = {"x": jnp.array([10.0])}
        state = opt.init(params)
        updates, _ = opt.update({"x": jnp.array([0.0])}, state, params)
        assert float(updates["x"][0]) < 0  # pure decay pull toward zero


class TestSGD:
    def test_sgd_step(self):
        opt = sgd(0.5)
        updates, _ = opt.update({"x": jnp.array([2.0])}, (), None)
        np.testing.assert_allclose(updates["x"], jnp.array([-1.0]))

    def test_momentum_accumulates(self):
        opt = momentum(0.1, beta=0.9)
        params = {"x": jnp.array([1.0])}
        state = opt.init(params)
        g = {"x": jnp.array([1.0])}
        u1, state = opt.update(g, state, params)
        u2, state = opt.update(g, state, params)
        assert abs(float(u2["x"][0])) > abs(float(u1["x"][0]))


class TestClippingAndSchedules:
    def test_clip_by_global_norm(self):
        clip = clip_by_global_norm(1.0)
        g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}  # norm 5
        clipped, _ = clip.update(g, (), None)
        np.testing.assert_allclose(global_norm(clipped), 1.0, rtol=1e-5)

    def test_clip_noop_below_threshold(self):
        clip = clip_by_global_norm(10.0)
        g = {"a": jnp.array([3.0])}
        clipped, _ = clip.update(g, (), None)
        np.testing.assert_allclose(clipped["a"], g["a"], rtol=1e-6)

    def test_cosine_schedule_endpoints(self):
        sched = cosine_decay_schedule(1.0, 100)
        np.testing.assert_allclose(sched(jnp.asarray(0)), 1.0, rtol=1e-5)
        np.testing.assert_allclose(sched(jnp.asarray(100)), 0.0, atol=1e-6)

    def test_warmup_cosine(self):
        sched = linear_warmup_cosine_decay(1.0, warmup_steps=10, total_steps=110)
        assert float(sched(jnp.asarray(0))) < 0.2
        np.testing.assert_allclose(sched(jnp.asarray(10)), 1.0, rtol=1e-2)
        assert float(sched(jnp.asarray(109))) < 0.01

    def test_chained_clip_then_adam(self):
        opt = chain(clip_by_global_norm(1.0), adam(0.05))
        losses = _quadratic_losses(opt, steps=400)
        assert losses[-1] < 1e-3

    def test_scale_by_schedule_counts(self):
        opt = scale_by_schedule(lambda c: 1.0 / (1.0 + c.astype(jnp.float32)))
        state = opt.init(None)
        g = {"x": jnp.array([1.0])}
        u1, state = opt.update(g, state)
        u2, state = opt.update(g, state)
        np.testing.assert_allclose(u1["x"], jnp.array([1.0]))
        np.testing.assert_allclose(u2["x"], jnp.array([0.5]))
