"""Block-level correctness: chunked GLA vs exact recurrence, Mamba2/mLSTM
streaming, sLSTM scan, MoE dispatch vs dense oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed; pip install -e .[test]")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.kernels.ref import gla_chunk_ref
from repro.models.backbone.moe import moe_block, moe_block_dense, moe_init
from repro.models.backbone.ssm import (
    chunked_gla,
    gla_decode_step,
    gla_final_state,
)

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# chunked GLA == exact recurrence (the SSD identity)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,chunk", [(8, 4), (16, 16), (33, 8), (64, 256)])
def test_chunked_gla_matches_recurrence(S, chunk):
    B, H, dk, dv = 2, 3, 8, 5
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    log_a = -jnp.abs(0.3 * jax.random.normal(ks[3], (B, S, H)))
    y = chunked_gla(q, k, v, log_a, chunk=chunk)
    for b in range(B):
        y_ref, state_ref = gla_chunk_ref(q[b], k[b], v[b], log_a[b])
        np.testing.assert_allclose(np.asarray(y[b]), np.asarray(y_ref),
                                   atol=2e-5, rtol=2e-5)
    state = gla_final_state(k, v, log_a, chunk=chunk)
    _, state_last = gla_chunk_ref(q[-1], k[-1], v[-1], log_a[-1])
    np.testing.assert_allclose(np.asarray(state[-1]), np.asarray(state_last),
                               atol=2e-5, rtol=2e-5)


@given(s_pre=st.integers(1, 20), s_post=st.integers(1, 8))
@settings(max_examples=12, deadline=None)
def test_gla_streaming_split_invariance(s_pre, s_post):
    """Prefill state + recurrent decode == one full pass (any split point)."""
    B, H, dk, dv = 1, 2, 4, 3
    S = s_pre + s_post
    ks = jax.random.split(jax.random.fold_in(KEY, s_pre * 31 + s_post), 4)
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    log_a = -jnp.abs(0.2 * jax.random.normal(ks[3], (B, S, H)))
    y_full = chunked_gla(q, k, v, log_a, chunk=8)
    state = gla_final_state(k[:, :s_pre], v[:, :s_pre], log_a[:, :s_pre], chunk=8)
    ys = []
    for t in range(s_pre, S):
        state, y = gla_decode_step(state, q[:, t], k[:, t], v[:, t], log_a[:, t])
        ys.append(y)
    y_dec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_dec), np.asarray(y_full[:, s_pre:], np.float32),
        atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_cfg(capacity_factor=8.0):
    return dataclasses.replace(get_config("olmoe-1b-7b").reduced(),
                               capacity_factor=capacity_factor)


def test_moe_dispatch_matches_dense_when_dropfree():
    cfg = _moe_cfg()
    p = moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    # one group == the dense oracle's pooled token set (the per-group
    # load-balance loss is averaged across groups, so multi-group values
    # legitimately differ from the pooled formulation)
    y1, a1 = moe_block(p, cfg, x, group_size=64)
    y2, a2 = moe_block_dense(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5,
                               rtol=2e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_moe_group_size_invariance_dropfree():
    cfg = _moe_cfg()
    p = moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 32, cfg.d_model))
    y1, _ = moe_block(p, cfg, x, group_size=32)
    y2, _ = moe_block(p, cfg, x, group_size=128)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5,
                               rtol=2e-5)


def test_moe_capacity_drops_bounded():
    """With a tight capacity factor some (token, expert) assignments drop
    (their contribution is simply missing — the residual path carries the
    token); outputs stay finite and the deviation from the drop-free
    oracle shrinks monotonically as capacity grows."""
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, _moe_cfg().d_model))
    errs = []
    for cf in (0.5, 1.0, 8.0):
        cfg = _moe_cfg(capacity_factor=cf)
        p = moe_init(KEY, cfg)
        y, aux = moe_block(p, cfg, x, group_size=32)
        assert jnp.isfinite(y).all() and jnp.isfinite(aux)
        y_dense, _ = moe_block_dense(p, cfg, x)
        errs.append(float(jnp.abs(y - y_dense).mean()))
    assert errs[0] > errs[2], errs      # tight capacity really drops
    assert errs[1] >= errs[2]           # monotone in capacity
    assert errs[2] < 1e-5               # ample capacity == oracle


def test_moe_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives aux loss == 1 (Switch normalization)."""
    from repro.models.backbone.moe import load_balance_loss
    T, E, k = 64, 4, 2
    probs = jnp.full((T, E), 1.0 / E)
    idx = jnp.stack([jnp.arange(T) % E, (jnp.arange(T) + 1) % E], axis=1)
    val = float(load_balance_loss(probs, idx, E))
    assert abs(val - 1.0) < 1e-5
