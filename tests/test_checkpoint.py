"""Checkpoint roundtrip tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def test_roundtrip(tmp_path):
    tree = {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.array([1, 2, 3], jnp.int32), "s": jnp.array(2.5)},
    }
    path = str(tmp_path / "ckpt.msgpack")
    save_pytree(path, tree)
    restored = load_pytree(path, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_bf16_roundtrip(tmp_path):
    tree = {"w": jnp.ones((4,), jnp.bfloat16) * 1.5}
    path = str(tmp_path / "c.msgpack")
    save_pytree(path, tree)
    restored = load_pytree(path, tree)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32), 1.5)


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros(3)}
    for step in [1, 2, 3, 4]:
        mgr.save(step, tree)
    assert mgr.latest_step() == 4
    assert mgr._steps(None) == [3, 4]  # older checkpoints GC'd


def test_manager_per_silo_shards(tmp_path):
    """Server and silo checkpoints live in separate files (privacy boundary)."""
    mgr = CheckpointManager(str(tmp_path))
    server_tree = {"eta_G": jnp.ones(2)}
    silo_tree = {"eta_L": jnp.full((5,), 3.0)}
    mgr.save(1, server_tree)
    mgr.save(1, silo_tree, shard="silo_0")
    r_server = mgr.restore(1, server_tree)
    r_silo = mgr.restore(1, silo_tree, shard="silo_0")
    np.testing.assert_array_equal(np.asarray(r_server["eta_G"]), 1.0)
    np.testing.assert_array_equal(np.asarray(r_silo["eta_L"]), 3.0)
    assert mgr.latest_step(shard="silo_0") == 1


def test_structure_mismatch_raises(tmp_path):
    path = str(tmp_path / "c.msgpack")
    save_pytree(path, {"a": jnp.zeros(2)})
    with pytest.raises(ValueError):
        load_pytree(path, {"a": jnp.zeros(2), "b": jnp.zeros(2)})
