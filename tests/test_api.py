"""Tests for the declarative experiment API (repro.federated.api).

Covers the acceptance surface of the API redesign:
  * spec JSON round trip, including scenario/privacy fields;
  * save -> resume bit-exactness vs an uninterrupted run;
  * the deprecated eager adapters (SFVIServer / SFVIAvgServer) produce
    the compiled Server's trajectories exactly (K = 1 equivalence);
  * registry lookup + the CLI's --list-models / --dump-spec paths.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CholeskyGaussian,
    ConditionalGaussian,
    DiagGaussian,
    SFVIAvgServer,
    SFVIProblem,
    SFVIServer,
    Silo,
    StructuredModel,
)
from repro.core.families import LowRankGaussian
from repro.federated import (
    AsyncConfig,
    Experiment,
    ExperimentSpec,
    FamilySpec,
    ModelSpec,
    OptimizerSpec,
    Scenario,
    Server,
    build,
    stack_silos,
)
from repro.federated import run as cli
from repro.models.paper.registry import get_model, list_models, model_names
from repro.optim.sgd import sgd

PAPER_MODELS = ["toy", "hier_bnn", "fedpop_bnn", "prodlda", "glmm", "multinomial"]


def _full_spec(**over):
    """A spec exercising every field, privacy, scenario and families."""
    base = dict(
        model=ModelSpec("toy", {"num_obs": 8},
                        global_family=FamilySpec("lowrank", {"rank": 1}),
                        local_family=FamilySpec("conditional",
                                                {"use_coupling": False})),
        scenario=Scenario(
            algorithm="sfvi_avg", participation=0.75, dropout=0.1,
            compression="int8", dp_noise=0.6, dp_clip=0.8, dp_delta=1e-6,
            aggregator="trimmed", trim_frac=0.2,
        ),
        num_silos=4, rounds=6, local_steps=2,
        server_opt=OptimizerSpec("adam", 3e-2, {"b1": 0.85}),
        local_opt=OptimizerSpec("sgd", 1e-2),
        eta_mode="param", eval_every=2, seed=5, data_seed=2,
    )
    base.update(over)
    return ExperimentSpec(**base)


class TestSpecRoundTrip:
    def test_dict_round_trip_includes_privacy_and_scenario(self):
        s = _full_spec()
        d = s.to_dict()
        assert d["scenario"]["dp_noise"] == 0.6
        assert d["scenario"]["participation"] == 0.75
        assert d["local_opt"]["name"] == "sgd"
        assert ExperimentSpec.from_dict(d) == s

    def test_json_round_trip(self):
        s = _full_spec()
        assert ExperimentSpec.from_json(s.to_json()) == s
        # And through an actual serialize -> parse cycle of the dict form.
        assert ExperimentSpec.from_dict(json.loads(json.dumps(s.to_dict()))) == s

    def test_defaults_round_trip(self):
        s = ExperimentSpec(model=ModelSpec("toy"))
        assert ExperimentSpec.from_json(s.to_json()) == s
        assert s.local_opt is None
        assert s.algorithm == s.scenario.algorithm

    def test_file_round_trip(self, tmp_path):
        s = _full_spec()
        path = str(tmp_path / "spec.json")
        s.save(path)
        assert ExperimentSpec.load(path) == s

    def test_unknown_optimizer_rejected_at_build(self):
        with pytest.raises(ValueError, match="unknown optimizer"):
            OptimizerSpec("lbfgs").build()

    def test_family_spec_round_trips_inside_model_spec(self):
        s = _full_spec()
        d = json.loads(s.to_json())
        assert d["model"]["global_family"] == {"name": "lowrank",
                                               "kwargs": {"rank": 1}}
        assert ExperimentSpec.from_dict(d) == s
        # Default (no override) serializes as null and round-trips too.
        bare = ExperimentSpec(model=ModelSpec("toy"))
        assert bare.model.global_family is None
        assert ExperimentSpec.from_json(bare.to_json()) == bare


class TestFamilyOverrides:
    def _spec(self, gfam, scenario=None, rounds=4):
        return ExperimentSpec(
            model=ModelSpec("toy", {"num_obs": 6}, global_family=gfam),
            scenario=scenario or Scenario(algorithm="sfvi_avg"),
            num_silos=4, rounds=rounds, local_steps=2,
            server_opt=OptimizerSpec("adam", 2e-2), seed=3,
        )

    def test_build_swaps_the_global_family(self):
        exp = build(self._spec(FamilySpec("cholesky")))
        fam = exp.server.problem.global_family
        # repro-lint: allow[R6] — registry-construction test: asserting WHICH class was built is the point
        assert isinstance(fam, CholeskyGaussian)
        assert fam.dim == exp.server.problem.model.global_dim
        assert "L_packed" in exp.server.eta_G
        exp.run(2)
        assert np.isfinite(exp.history["elbo"][-1])

    def test_lowrank_family_runs_end_to_end(self):
        exp = build(self._spec(FamilySpec("lowrank", {"rank": 1})))
        # repro-lint: allow[R6] — registry-construction test: asserting WHICH class was built is the point
        assert isinstance(exp.server.problem.global_family, LowRankGaussian)
        h = exp.run()
        assert np.all(np.isfinite(np.asarray(h["elbo"])))

    def test_default_spec_keeps_model_family(self):
        exp = build(self._spec(None))
        # repro-lint: allow[R6] — registry-construction test: asserting WHICH class was built is the point
        assert isinstance(exp.server.problem.global_family, DiagGaussian)

    def test_nondefault_family_resumes_bit_exact_under_dp_int8_async(
            self, tmp_path):
        """Acceptance: a spec carrying a non-default FamilySpec resumes
        bit-exactly mid-run with DP + int8 + async all live — the same
        guarantee the default family has."""
        sc = Scenario(algorithm="sfvi_avg", compression="int8",
                      dp_noise=0.5, dp_clip=0.9,
                      async_cfg=AsyncConfig(buffer_size=2,
                                            latency="lognormal"))
        spec = self._spec(FamilySpec("cholesky"), scenario=sc, rounds=6)
        full = build(spec)
        full.run()

        part = build(spec)
        part.run(3)
        part.save(str(tmp_path))
        resumed = Experiment.resume(str(tmp_path))
        # repro-lint: allow[R6] — resume-fidelity test: asserts the concrete family class survives the round trip
        assert isinstance(resumed.server.problem.global_family,
                          CholeskyGaussian)
        resumed.run()
        _assert_trees_bit_equal(_run_state(full), _run_state(resumed))
        assert full.comm.state_dict() == resumed.comm.state_dict()

    def test_unknown_family_raises_with_names(self):
        with pytest.raises(KeyError, match="registered families"):
            build(self._spec(FamilySpec("gumbel")))

    def test_underivable_family_kwargs_raise_cleanly(self):
        """batched_diag needs a 'batch' the model cannot supply — the
        error must name the missing kwarg, not die in __init__."""
        with pytest.raises(ValueError, match="batch"):
            build(self._spec(FamilySpec("batched_diag")))

    def test_legacy_wire_run_resumes_on_legacy_wire(self, tmp_path):
        """The wire layout is recorded in the checkpoint meta: a run
        built with wire='legacy' under DP+int8 (layout-dependent noise
        keys and scales) must resume on the SAME layout, bit-exactly."""
        sc = Scenario(algorithm="sfvi_avg", compression="int8",
                      dp_noise=0.5, dp_clip=0.9)
        spec = self._spec(None, scenario=sc, rounds=4)
        full = build(spec, wire="legacy")
        full.run()

        part = build(spec, wire="legacy")
        part.run(2)
        part.save(str(tmp_path))
        resumed = Experiment.resume(str(tmp_path))
        assert resumed.server.wire == "legacy"
        resumed.run()
        _assert_trees_bit_equal(_run_state(full), _run_state(resumed))


class TestRegistry:
    def test_all_paper_models_registered(self):
        names = model_names()
        for name in PAPER_MODELS:
            assert name in names, f"{name} missing from registry"

    def test_descriptions_nonempty(self):
        for name, desc in list_models():
            assert desc.strip(), f"{name} has no description"

    def test_unknown_model_raises_with_available_names(self):
        with pytest.raises(KeyError, match="registered models"):
            get_model("nope")

    def test_toy_bundle_stages_equal_silos(self):
        bundle = get_model("toy").build(0, 3, num_obs=5)
        assert len(bundle.datas) == 3
        assert all(d["y"].shape == (5,) for d in bundle.datas)
        assert bundle.num_obs == [5, 5, 5]
        assert "posterior_mu" in bundle.extras

    def test_hetero_mn_stages_unequal_weighted_silos(self):
        """The heterogeneity generator: Dirichlet label skew, TRUE
        unequal N_j in num_obs, equal padded shapes + row weights."""
        bundle = get_model("hetero_mn").build(
            0, 5, n_total=120, in_dim=16, alpha=0.3)
        assert len(set(bundle.num_obs)) > 1  # genuinely unequal N_j
        assert sum(bundle.num_obs) == 120
        shapes = {d["x"].shape for d in bundle.datas}
        assert len(shapes) == 1  # padded to a common stackable shape
        for d, n in zip(bundle.datas, bundle.num_obs, strict=True):
            w = np.asarray(d["w"])
            assert w.sum() == n  # weights mark exactly the real rows
        # Padded rows contribute nothing to the likelihood: doubling a
        # padded row's features must not change log_local.
        prob = bundle.problem
        d0 = bundle.datas[int(np.argmin(bundle.num_obs))]
        z = jnp.zeros((prob.model.global_dim,))
        poked = dict(d0, x=d0["x"].at[-1].mul(2.0))
        assert float(prob.model.log_local({}, z, None, d0)) == pytest.approx(
            float(prob.model.log_local({}, z, None, poked)))


class TestCLI:
    def test_list_models_exits_zero(self, capsys):
        assert cli.main(["--list-models"]) == 0
        out = capsys.readouterr().out
        for name in PAPER_MODELS:
            assert name in out

    def test_dump_spec_round_trips_through_from_json(self, capsys):
        rc = cli.main(["--model", "toy", "--algo", "sfvi", "--silos", "3",
                       "--rounds", "2", "--dp-noise", "0.5", "--dump-spec"])
        assert rc == 0
        spec = ExperimentSpec.from_json(capsys.readouterr().out)
        assert spec.model.name == "toy"
        assert spec.algorithm == "sfvi"
        assert spec.num_silos == 3
        assert spec.scenario.dp_noise == 0.5
        assert ExperimentSpec.from_json(spec.to_json()) == spec

    def test_dump_spec_requires_single_algo(self, capsys):
        assert cli.main(["--model", "toy", "--dump-spec"]) == 2

    def test_spec_run_stages_with_data_seed(self, tmp_path, capsys):
        """The CLI must stage data with data_seed (api.build's rule) —
        staging with the run seed would hand --spec runs a different
        dataset than --resume/build(spec) rebuild."""
        spec = ExperimentSpec(
            model=ModelSpec("toy", {"num_obs": 6}),
            scenario=Scenario(algorithm="sfvi"),
            num_silos=3, rounds=1, local_steps=1, seed=1, data_seed=9)
        path = str(tmp_path / "spec.json")
        spec.save(path)
        assert cli.main(["--spec", path]) == 0
        out = capsys.readouterr().out
        ref = build(spec)
        ref.run()
        expected = ref.evaluate()["abs_error_vs_exact"]
        assert f"abs_error_vs_exact: {expected:.3f}" in out


def _run_state(exp):
    return {k: exp.server.state[k] for k in ("theta", "eta_G", "eta_L")}


def _assert_trees_bit_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb, strict=True):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestSaveResume:
    def _spec(self):
        # DP + compression + partial participation: the states the resume
        # guarantee must thread (accountant ledger, scheduler stream,
        # round keys) are all live.
        return ExperimentSpec(
            model=ModelSpec("toy", {"num_obs": 6}),
            scenario=Scenario(algorithm="sfvi_avg", participation=0.75,
                              compression="int8", dp_noise=0.5, dp_clip=0.9),
            num_silos=4, rounds=6, local_steps=2,
            server_opt=OptimizerSpec("adam", 2e-2), seed=3,
        )

    def test_resume_is_bit_exact(self, tmp_path):
        spec = self._spec()
        full = build(spec)
        full.run()  # uninterrupted: all 6 rounds

        part = build(spec)
        part.run(3)
        part.save(str(tmp_path))
        resumed = Experiment.resume(str(tmp_path))
        assert resumed.round == 3
        resumed.run()  # the remaining 3 rounds

        _assert_trees_bit_equal(_run_state(full), _run_state(resumed))
        # Accountant composed the same ledger -> identical epsilon.
        eps_full = full.accountant.epsilon(spec.scenario.dp_delta)
        eps_res = resumed.accountant.epsilon(spec.scenario.dp_delta)
        assert eps_full == eps_res
        # Communication counters carried across the boundary too.
        assert full.comm.state_dict() == resumed.comm.state_dict()

    def test_resume_restores_round_and_counters(self, tmp_path):
        spec = self._spec()
        exp = build(spec)
        exp.run(2)
        exp.save(str(tmp_path))
        resumed = Experiment.resume(str(tmp_path))
        assert resumed.round == 2
        assert resumed.remaining_rounds == spec.rounds - 2
        assert resumed.comm.state_dict() == exp.comm.state_dict()
        assert resumed.accountant.steps == exp.accountant.steps

    def test_midrun_callback_save_resumes_bit_exact(self, tmp_path):
        """Checkpointing FROM a run callback (the CLI's --ckpt-every
        path) records the in-flight absolute round, so the resume
        continues bit-exactly from mid-chunk."""
        spec = self._spec()
        full = build(spec)
        full.run()

        part = build(spec)

        def save_at_4(r, metrics):
            if r + 1 == 4:
                part.save(str(tmp_path))

        part.run(callback=save_at_4)
        resumed = Experiment.resume(str(tmp_path))
        assert resumed.round == 4
        resumed.run()
        _assert_trees_bit_equal(_run_state(full), _run_state(resumed))

    def test_data_seed_decouples_staging_from_run_seed(self):
        """Same data_seed + different run seeds -> identical silo data."""
        import dataclasses

        a = build(dataclasses.replace(self._spec(), seed=1, data_seed=9))
        b = build(dataclasses.replace(self._spec(), seed=2, data_seed=9))
        _assert_trees_bit_equal(a.bundle.datas, b.bundle.datas)

    def test_resume_without_checkpoint_raises(self, tmp_path):
        spec = self._spec()
        spec.save(str(tmp_path / "spec.json"))
        with pytest.raises(FileNotFoundError):
            Experiment.resume(str(tmp_path))


# ---------------------------------------------------------------------------
# Adapter equivalence: the deprecated eager API runs the compiled graph
# ---------------------------------------------------------------------------


def _global_only_problem(dG=3):
    model = StructuredModel(
        global_dim=dG, local_dim=0,
        log_prior_global=lambda th, zg: -0.5 * jnp.sum((zg - th["m"]) ** 2),
        log_local=lambda th, zg, zl, d: -0.5 * jnp.sum((d["y"] - zg[None, :]) ** 2),
    )
    return SFVIProblem(model, DiagGaussian(dG))


def _hier_problem(dG=3, dL=2):
    model = StructuredModel(
        global_dim=dG, local_dim=dL,
        log_prior_global=lambda th, zg: -0.5 * jnp.sum((zg - th["m"]) ** 2),
        log_local=lambda th, zg, zl, d: (
            -0.5 * jnp.sum((zl - jnp.mean(zg)) ** 2)
            - 0.5 * jnp.sum((d["y"] - zl[None, :]) ** 2)
        ),
    )
    return SFVIProblem(model, DiagGaussian(dG), ConditionalGaussian(dL, dG))


def _datas(key, J, n, d):
    return [{"y": jax.random.normal(jax.random.fold_in(key, j), (n, d))}
            for j in range(J)]


class TestAdapterEquivalence:
    def test_sfvi_adapter_matches_server_k1(self):
        """Legacy SFVIServer == compiled Server, bit for bit, at K=1."""
        lr, J, n = 0.05, 3, 4
        prob = _global_only_problem()
        theta = {"m": jnp.asarray(0.2)}
        eta_G = prob.global_family.init(jax.random.PRNGKey(1), mu_scale=0.4)
        datas = _datas(jax.random.PRNGKey(2), J, n, 3)
        silos = [Silo(j, prob, datas[j], None, None, n) for j in range(J)]

        with pytest.warns(DeprecationWarning):
            legacy = SFVIServer(prob, silos, theta, eta_G, sgd(lr), seed=7)
        direct = Server(prob, datas, theta, eta_G, num_obs=[n] * J,
                        server_opt=sgd(lr), eta_mode="param", seed=7)
        h_legacy = legacy.run(3)
        h_direct = direct.run(3, algorithm="sfvi", local_steps=1)

        _assert_trees_bit_equal(legacy.theta, direct.theta)
        _assert_trees_bit_equal(legacy.eta_G, direct.eta_G)
        assert h_legacy["elbo"] == h_direct["elbo"]
        assert h_legacy["bytes_up"] == h_direct["bytes_up"]

    def test_sfvi_adapter_matches_server_with_locals(self):
        """Same, with local latents: caller-initialized η_{L_j} are
        honoured and the trajectories coincide exactly."""
        lr, J, n = 0.05, 3, 4
        prob = _hier_problem()
        theta = {"m": jnp.asarray(0.1)}
        eta_G = prob.global_family.init(jax.random.PRNGKey(3), mu_scale=0.4)
        datas = _datas(jax.random.PRNGKey(4), J, n, 2)
        key = jax.random.PRNGKey(9)
        etas_L = [prob.local_family.init(jax.random.fold_in(key, j))
                  for j in range(J)]
        silos = [Silo(j, prob, datas[j], etas_L[j], sgd(lr), n)
                 for j in range(J)]

        with pytest.warns(DeprecationWarning):
            legacy = SFVIServer(prob, silos, theta, eta_G, sgd(lr), seed=11)
        direct = Server(prob, datas, theta, eta_G, num_obs=[n] * J,
                        server_opt=sgd(lr), local_opt=sgd(lr),
                        eta_mode="param", seed=11)
        direct.state["eta_L"] = stack_silos(etas_L)

        legacy.run(2)
        direct.run(2, algorithm="sfvi", local_steps=1)

        _assert_trees_bit_equal(legacy.theta, direct.theta)
        _assert_trees_bit_equal(legacy.eta_G, direct.eta_G)
        _assert_trees_bit_equal(legacy._compiled.eta_L, direct.eta_L)
        # And the adapter wrote the updated slices back into the Silos.
        for j, silo in enumerate(silos):
            _assert_trees_bit_equal(
                silo.eta_L,
                jax.tree_util.tree_map(lambda x: x[j], direct.eta_L))

    def test_avg_adapter_runs_real_cholesky_barycenter(self):
        """The adapter no longer downgrades CholeskyGaussian to
        parameter-space averaging: it runs the generic in-graph W2
        barycenter and matches the direct Server bit for bit."""
        lr, J, n, K = 0.03, 3, 4, 2
        dG = 3
        model = StructuredModel(
            global_dim=dG, local_dim=2,
            log_prior_global=lambda th, zg: -0.5 * jnp.sum((zg - th["m"]) ** 2),
            log_local=lambda th, zg, zl, d: (
                -0.5 * jnp.sum((zl - jnp.mean(zg)) ** 2)
                - 0.5 * jnp.sum((d["y"] - zl[None, :]) ** 2)
            ),
        )
        prob = SFVIProblem(model, CholeskyGaussian(dG),
                           ConditionalGaussian(2, dG))
        theta = {"m": jnp.asarray(0.1)}
        eta_G = prob.global_family.init(jax.random.PRNGKey(5), mu_scale=0.4)
        datas = _datas(jax.random.PRNGKey(6), J, n, 2)
        key = jax.random.PRNGKey(13)
        etas_L = [prob.local_family.init(jax.random.fold_in(key, j))
                  for j in range(J)]
        silos = [Silo(j, prob, datas[j], etas_L[j], sgd(lr), n)
                 for j in range(J)]

        import warnings as _w

        with _w.catch_warnings(record=True) as caught:
            _w.simplefilter("always")
            legacy = SFVIAvgServer(prob, silos, theta, eta_G,
                                   lambda: sgd(lr), seed=17)
        # Deprecation only — the barycenter->param downgrade warning is gone.
        assert all(issubclass(w.category, DeprecationWarning) for w in caught)
        assert legacy._compiled.eta_mode == "barycenter"

        direct = Server(prob, datas, theta, eta_G, num_obs=[n] * J,
                        server_opt=sgd(lr), local_opt=sgd(lr),
                        eta_mode="barycenter", seed=17)
        direct.state["eta_L"] = stack_silos(etas_L)
        legacy.run(2, local_steps=K)
        direct.run(2, algorithm="sfvi_avg", local_steps=K)
        _assert_trees_bit_equal(legacy.theta, direct.theta)
        _assert_trees_bit_equal(legacy.eta_G, direct.eta_G)

    def test_avg_adapter_rejects_family_without_moments(self):
        """A global family with no to_moments has no barycenter: the
        adapter fails loudly instead of silently averaging parameters."""
        class NoMoments(DiagGaussian):
            has_moments = False

        prob = _hier_problem()
        prob = SFVIProblem(prob.model, NoMoments(3), prob.local_family)
        datas = _datas(jax.random.PRNGKey(2), 2, 4, 2)
        silos = [Silo(j, prob, datas[j],
                      prob.local_family.init(jax.random.PRNGKey(j)),
                      sgd(0.05), 4) for j in range(2)]
        with pytest.warns(DeprecationWarning), \
                pytest.raises(ValueError, match="to_moments"):
            SFVIAvgServer(prob, silos, {"m": jnp.asarray(0.1)},
                          NoMoments(3).init(jax.random.PRNGKey(1)),
                          lambda: sgd(0.05))

    def test_avg_adapter_matches_server(self):
        """Legacy SFVIAvgServer == compiled Server (sfvi_avg), bit for bit."""
        lr, J, n, K = 0.03, 3, 4, 3
        prob = _hier_problem()
        theta = {"m": jnp.asarray(0.1)}
        eta_G = prob.global_family.init(jax.random.PRNGKey(5), mu_scale=0.4)
        datas = _datas(jax.random.PRNGKey(6), J, n, 2)
        key = jax.random.PRNGKey(13)
        etas_L = [prob.local_family.init(jax.random.fold_in(key, j))
                  for j in range(J)]
        silos = [Silo(j, prob, datas[j], etas_L[j], sgd(lr), n)
                 for j in range(J)]

        with pytest.warns(DeprecationWarning):
            legacy = SFVIAvgServer(prob, silos, theta, eta_G,
                                   lambda: sgd(lr), seed=17)
        direct = Server(prob, datas, theta, eta_G, num_obs=[n] * J,
                        server_opt=sgd(lr), local_opt=sgd(lr),
                        eta_mode="barycenter", seed=17)
        direct.state["eta_L"] = stack_silos(etas_L)

        h_legacy = legacy.run(2, local_steps=K)
        h_direct = direct.run(2, algorithm="sfvi_avg", local_steps=K)

        _assert_trees_bit_equal(legacy.theta, direct.theta)
        _assert_trees_bit_equal(legacy.eta_G, direct.eta_G)
        assert h_legacy["elbo"] == h_direct["elbo"]
