"""End-to-end integration: the SPMD-path SFVI/SFVI-Avg steps actually
train (loss decreases) and the serve path is consistent with training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import steps as S
from repro.optim.adam import adam

KEY = jax.random.PRNGKey(0)


def _fixed_batch(cfg, B, Sq, seed=0):
    k = jax.random.fold_in(KEY, seed)
    toks = jax.random.randint(k, (B, Sq + 1), 0, cfg.vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def test_sfvi_training_reduces_loss():
    """Memorize one fixed batch for 40 steps: loss must drop markedly."""
    cfg = get_config("qwen3-4b").reduced()
    J = 2
    state, _ = S.init_train_state(KEY, cfg, J, lr=3e-3)
    step = jax.jit(S.make_train_step(cfg, J, lr=3e-3, remat=False))
    batch = _fixed_batch(cfg, 4, 32)
    losses = []
    for i in range(40):
        state, m = step(state, batch, jnp.int32(0))  # fixed seed: same eps
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])
    assert np.isfinite(losses).all()


def test_sfvi_avg_training_runs_and_averages():
    cfg = get_config("llama3.2-3b").reduced()
    J = 2
    state0, _ = S.init_train_state(KEY, cfg, J, lr=3e-3)
    eta_G = S.init_eta_G_silo(KEY, cfg, J)
    opt = adam(3e-3)
    state = S.TrainState(state0.theta, eta_G, state0.eta_L, state0.opt_theta,
                         opt.init(eta_G), state0.opt_eta_L, state0.step)
    step = jax.jit(S.make_train_step_avg(cfg, J, avg_every=5, lr=3e-3,
                                         remat=False))
    batch = _fixed_batch(cfg, 4, 32)
    losses = []
    for i in range(20):
        state, m = step(state, batch, jnp.int32(0))
        losses.append(float(m["loss"]))
        mus = state.eta_G["mu"]
        gap = float(jnp.abs(mus[0] - mus[1]).max())
        if (i + 1) % 5 == 0:
            # barycenter round: per-silo global posteriors coincide
            assert gap < 1e-6, (i, gap)
    assert losses[-1] < losses[0], (losses[0], losses[-1])


@pytest.mark.parametrize("arch", ["qwen3-4b", "zamba2-7b"])
def test_serve_steps_consistent_with_decode(arch):
    """serve prefill + N decode steps: greedy tokens are deterministic and
    finite; per-silo adapters give per-silo logits."""
    cfg = get_config(arch).reduced()
    J, B, P = 2, 4, 16
    state, _ = S.init_train_state(KEY, cfg, J)
    prefill = jax.jit(S.make_serve_prefill(cfg, J, max_len=P + 8))
    decode = jax.jit(S.make_serve_decode(cfg, J))
    batch = {"tokens": jax.random.randint(KEY, (B, P), 0, cfg.vocab_size)}
    logits, cache = prefill(state.theta, state.eta_G, state.eta_L, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1], axis=-1)
    for _ in range(4):
        logits, cache = decode(state.theta, state.eta_G, state.eta_L,
                               tok[:, None], cache)
        assert jnp.isfinite(logits).all()
        tok = jnp.argmax(logits[:, -1], axis=-1)
    # silo personalization: different eta_L biases -> different logits for
    # identical inputs in different silos
    same_input = {"tokens": jnp.tile(batch["tokens"][:1], (B, 1))}
    lg, _ = prefill(state.theta, state.eta_G, state.eta_L, same_input)
    assert float(jnp.abs(lg[0] - lg[B // J]).max()) > 1e-6
