"""Two-process ``jax.distributed`` CPU smoke test (gloo collectives).

Launches 2 local processes, each with 2 forced host devices, forming a
global 4-device ``silo`` mesh (``MeshSpec(silo=4, multiprocess=True)``).
Runs a 2-round federated toy experiment and asserts:

  * the metered wire bytes equal the compiled collective's bytes
    (``all-gather`` result bytes == J x ``bytes_up_per_silo`` per sync,
    J divisible so J_pad == J);
  * both processes replicate bit-identical trajectories;
  * owner-routed checkpointing round-trips: every process writes only
    its owned silo shards, resumes, and replays the next round
    bit-exactly;
  * the FULL parameter state (θ, η_G, server optimizer, every silo's
    η_L + optimizer row) is bit-identical to a single-process run on
    the same 4-device mesh. Only the REPORTED ELBO scalar may differ at
    float tolerance: gloo's cross-host psum of hatL associates
    differently than XLA's intra-process reduction, and hatL never
    enters a parameter update (same story as across device counts).
"""
import os
import re
import socket
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_DIGEST_HELPERS = textwrap.dedent("""
    import hashlib
    import numpy as np

    def _dig(leaves):
        h = hashlib.sha256()
        for x in leaves:
            h.update(np.ascontiguousarray(np.asarray(x)).tobytes())
        return h.hexdigest()[:16]
""")

_WORKER = _DIGEST_HELPERS + textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")
    from repro.federated import distributed
    distributed.initialize()  # REPRO_COORDINATOR / _NUM_PROCESSES / _PROCESS_ID

    import jax
    assert jax.process_count() == 2, jax.process_count()
    assert jax.device_count() == 4, jax.device_count()
    assert jax.local_device_count() == 2

    from repro.federated import (Experiment, ExperimentSpec, MeshSpec,
                                 ModelSpec, RuntimeSpec, Scenario, build)
    from repro.launch.roofline import collective_bytes

    J, K = 8, 2
    spec = ExperimentSpec(
        model=ModelSpec("toy", {"num_obs": 8}),
        scenario=Scenario(algorithm="sfvi"),
        num_silos=J, rounds=3, local_steps=K,
        runtime=RuntimeSpec(mesh=MeshSpec(silo=4, multiprocess=True)))
    exp = build(spec)
    srv = exp.server
    assert srv.n_processes == 2, srv.n_processes
    assert dict(srv.mesh.shape) == {"silo": 4}
    exp.run(2)

    # Metered bytes == compiled collective bytes: the all-gather result
    # is J x the host meter's per-silo upload, and the meter bills
    # K syncs x J x that per round.
    hlo = srv._lower(None, K).compile().as_text()
    gathered = collective_bytes(hlo)["all-gather"]
    host = srv.bytes_up_per_silo()
    assert gathered == J * host, (gathered, J, host)
    assert exp.history["bytes_up"][-1] == K * J * host, (
        exp.history["bytes_up"][-1], K * J * host)

    # Owner-routed checkpoint round trip: save (each process writes its
    # owned silo shards), resume, replay the last round bit-exactly.
    ckpt = os.environ["MP_CKPT_DIR"]
    exp.save(ckpt)
    resumed = Experiment.resume(ckpt)
    assert resumed.round == 2, resumed.round
    exp.run(1)
    resumed.run(1)
    a = float(np.asarray(exp.history["elbo"][-1], np.float64))
    b = float(np.asarray(resumed.history["elbo"][-1], np.float64))
    assert a == b, (a, b)

    st = srv.state
    print("GLOBAL", _dig(jax.tree_util.tree_leaves(
        [st["theta"], st["eta_G"], st["opt_server"]])))
    rows = [r for r in distributed.owned_rows(srv.mesh, srv.J_pad)
            if r < J]
    for r in rows:
        row = jax.tree_util.tree_map(
            lambda x, rr=r: distributed.host_rows(x, [rr])[rr],
            [st["eta_L"], st["opt_local"]])
        print("ROW", r, _dig(jax.tree_util.tree_leaves(row)))
    traj = [float(np.asarray(x, np.float64)) for x in exp.history["elbo"]]
    print("ELBO", jax.process_index(), repr(traj))
""")

_REFERENCE = _DIGEST_HELPERS + textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")
    import jax
    from repro.federated import (ExperimentSpec, MeshSpec, ModelSpec,
                                 RuntimeSpec, Scenario, build)

    spec = ExperimentSpec(
        model=ModelSpec("toy", {"num_obs": 8}),
        scenario=Scenario(algorithm="sfvi"),
        num_silos=8, rounds=3, local_steps=2,
        runtime=RuntimeSpec(mesh=MeshSpec(silo=4)))
    exp = build(spec)
    exp.run()
    st = exp.server.state
    print("GLOBAL", _dig(jax.tree_util.tree_leaves(
        [st["theta"], st["eta_G"], st["opt_server"]])))
    for r in range(8):
        row = jax.tree_util.tree_map(
            lambda x, rr=r: np.asarray(x)[rr], [st["eta_L"], st["opt_local"]])
        print("ROW", r, _dig(jax.tree_util.tree_leaves(row)))
    traj = [float(np.asarray(x, np.float64)) for x in exp.history["elbo"]]
    print("ELBO ref", repr(traj))
""")


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def _parse(out: str):
    glob = re.search(r"^GLOBAL (\S+)$", out, re.M).group(1)
    rows = dict(re.findall(r"^ROW (\d+) (\S+)$", out, re.M))
    traj = eval(re.search(r"^ELBO \S+ (\[.*\])$", out, re.M).group(1))
    return glob, rows, traj


@pytest.mark.slow
def test_two_process_distributed_round(tmp_path):
    port = _free_port()
    base_env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
                    MP_CKPT_DIR=str(tmp_path / "ckpt"),
                    REPRO_COORDINATOR=f"localhost:{port}",
                    REPRO_NUM_PROCESSES="2")
    base_env.pop("XLA_FLAGS", None)
    procs = []
    for rank in range(2):
        env = dict(base_env, REPRO_PROCESS_ID=str(rank))
        procs.append(subprocess.Popen(
            [sys.executable, "-c", _WORKER],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True, env=env, cwd=REPO))
    outs = []
    for p in procs:
        out, err = p.communicate(timeout=1200)
        outs.append((p.returncode, out, err))
    for rc, out, err in outs:
        assert rc == 0, out[-2000:] + err[-2000:]

    g0, rows0, traj0 = _parse(outs[0][1])
    g1, rows1, traj1 = _parse(outs[1][1])
    # Replicated server state and the trajectory agree bit-for-bit
    # across the two processes; silo rows partition by ownership.
    assert g0 == g1
    assert traj0 == traj1
    assert sorted(rows0) == ["0", "1", "2", "3"], rows0
    assert sorted(rows1) == ["4", "5", "6", "7"], rows1

    ref_env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    ref_env.pop("XLA_FLAGS", None)
    ref = subprocess.run([sys.executable, "-c", _REFERENCE],
                         capture_output=True, text=True, env=ref_env,
                         cwd=REPO, timeout=1200)
    assert ref.returncode == 0, ref.stdout[-2000:] + ref.stderr[-2000:]
    gr, rowsr, trajr = _parse(ref.stdout)

    # Full parameter state matches the single-process run bit-exactly;
    # the reported ELBO matches to float tolerance (gloo psum
    # association — it never enters a parameter update).
    assert g0 == gr
    assert {**rows0, **rows1} == rowsr
    np.testing.assert_allclose(np.asarray(traj0), np.asarray(trajr),
                               rtol=1e-5)
