"""Tests for the runtime sanitizer harness (repro.debug).

The watchdog's core promise: the compiled federated round traces
EXACTLY ONCE per (strategy, local_steps, wire) config — across R > 1
rounds, across both wire layouts, and across a save→resume boundary on
the same device count (the process-level graph cache of
repro.federated.graph_cache shares compiled round fns between
structurally identical Servers).  The transfer guard and NaN check are
smoke-tested end to end through ``Experiment.run(sanitize=True)``.
"""
import tempfile

import numpy as np
import pytest

from repro import debug
from repro.federated import graph_cache
from repro.federated.api import (
    Experiment,
    ExperimentSpec,
    ModelSpec,
    OptimizerSpec,
    RuntimeSpec,
    build,
)
from repro.federated.scheduler import AsyncConfig, Scenario


def _spec(algorithm="sfvi_avg", rounds=3, **over):
    base = dict(
        model=ModelSpec("toy"),
        scenario=Scenario(algorithm=algorithm),
        num_silos=4,
        rounds=rounds,
        local_steps=2,
        server_opt=OptimizerSpec("adam", 2e-2),
        seed=0,
    )
    base.update(over)
    return ExperimentSpec(**base)


@pytest.fixture(autouse=True)
def _fresh_graph_cache():
    """Each test sees an empty process-level cache (and leaves none)."""
    graph_cache.clear()
    yield
    graph_cache.clear()


@pytest.mark.parametrize("algorithm", ["sfvi_avg", "pvi"])
@pytest.mark.parametrize("wire", ["flat", "fused"])
def test_one_trace_per_config(algorithm, wire):
    """R > 1 rounds compile the round graph exactly once per config."""
    exp = build(_spec(algorithm, runtime=RuntimeSpec(wire=wire)))
    with debug.watch_recompiles() as wd:
        h = exp.run(3)
    assert wd.total == 1, dict(wd.counts)
    (tag,) = wd.counts
    assert tag[-1] == wire
    assert len(h["elbo"]) == 3
    assert np.all(np.isfinite(h["elbo"]))


@pytest.mark.parametrize("wire", ["flat", "fused"])
def test_resume_does_not_retrace(wire, tmp_path):
    """save→resume on the same device count reuses the compiled round.

    Experiment.resume builds a fresh Server; without the process-level
    graph cache that would be a second trace of an identical graph.
    """
    with debug.watch_recompiles() as wd:
        exp = build(_spec(rounds=4, runtime=RuntimeSpec(wire=wire)))
        exp.run(2)
        ckpt = str(tmp_path / "ckpt")
        exp.save(ckpt)
        resumed = Experiment.resume(ckpt)
        assert resumed.remaining_rounds == 2
        resumed.run()
    assert wd.total == 1, dict(wd.counts)


def test_watchdog_raises_on_retrace():
    """A second trace of the same config raises RecompileError.

    Two bundle-built Servers share a tag but not a graph cache entry
    (caller-supplied bundles opt out of the cache), so the second
    Server's first round is a genuine retrace the watchdog must stop.
    """
    from repro.models.paper.registry import get_model

    spec = _spec()
    bundle = get_model("toy").build(spec.seed, spec.num_silos)
    with debug.watch_recompiles() as wd:
        build(spec, bundle=bundle).run(1)
        assert wd.total == 1
        with pytest.raises(debug.RecompileError, match="traced 2 times"):
            build(spec, bundle=bundle).run(1)


def test_watchdog_suspension_and_inactive():
    """suspended_tracing() windows are free; no watchdog, no counting."""
    # trace_event with no active watchdog is a no-op.
    debug.trace_event(("round", "x"))
    wd = debug.TraceWatchdog(limit=1)
    wd.record("a")
    with wd.suspended():
        wd.record("a")  # deliberate (e.g. .lower() inspection): not billed
    assert wd.counts["a"] == 1
    with pytest.raises(debug.RecompileError):
        wd.record("a")


def test_sanitize_run_end_to_end():
    """Experiment.run(sanitize=True): guard + NaN check + watchdog live."""
    exp = build(_spec())
    h = exp.run(sanitize=True)
    assert len(h["elbo"]) == 3
    assert np.all(np.isfinite(h["elbo"]))


def test_sanitize_async_end_to_end():
    """The buffered-async flush loop is transfer-guard clean too."""
    spec = _spec(
        scenario=Scenario(algorithm="sfvi_avg",
                          async_cfg=AsyncConfig(buffer_size=2)))
    exp = build(spec)
    h = exp.run(sanitize=True)
    assert len(h["elbo"]) == 3
    assert np.all(np.isfinite(h["elbo"]))


def test_sanitize_matches_unsanitized_trajectory():
    """Sanitizers observe; they must not change the trajectory."""
    h_plain = build(_spec()).run()
    graph_cache.clear()
    h_guarded = build(_spec()).run(sanitize=True)
    np.testing.assert_array_equal(h_plain["elbo"], h_guarded["elbo"])


def test_graph_cache_token_sensitivity():
    """Structurally different builds never share a cache entry."""
    s = _spec()
    t1 = graph_cache.build_token(s.to_json(indent=0), "flat", s.num_silos)
    assert t1 == graph_cache.build_token(
        s.to_json(indent=0), "flat", s.num_silos)
    assert t1 != graph_cache.build_token(s.to_json(indent=0), "fused",
                                         s.num_silos)
    s2 = _spec(seed=1)
    assert t1 != graph_cache.build_token(s2.to_json(indent=0), "flat",
                                         s2.num_silos)
    d1 = graph_cache.round_fns(t1)
    d1["k"] = "v"
    assert graph_cache.round_fns(t1) is d1
    assert graph_cache.round_fns(None) == {}
