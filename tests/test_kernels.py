"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs ref.py.

Kernels execute in interpret mode on CPU (the kernel body runs in Python,
semantically identical to the Mosaic lowering's grid/BlockSpec behaviour),
so the whole suite runs on CPU-only CI. hypothesis is optional: without
it the property tests degrade to a fixed seeded sweep over the same
domain (never skip the module — missing optional deps lose example
diversity, not coverage).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref
from repro.kernels.rmsnorm import rmsnorm_rows

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

SHAPES = [
    # (B, Sq, Skv, H, KV, hd)
    (1, 128, 128, 4, 4, 64),
    (2, 256, 256, 8, 2, 64),
    (1, 96, 96, 4, 1, 32),     # padding path (96 < block)
    (1, 384, 384, 4, 2, 128),
    (2, 1, 160, 4, 2, 64),     # decode: single query vs cache
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(shape, dtype, causal):
    B, Sq, Skv, H, KV, hd = shape
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, hd), dtype)
    off = Skv - Sq if (causal and Sq < Skv) else 0
    out = ops.flash_attention(q, k, v, causal=causal, q_offset=off)
    kf = jnp.repeat(k, H // KV, axis=2)
    vf = jnp.repeat(v, H // KV, axis=2)
    want = ref.flash_attention_ref(q, kf, vf, causal=causal, q_offset=off)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("window", [16, 64, 250])
def test_flash_attention_sliding_window(window):
    B, S, H, hd = 1, 256, 4, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=True, sliding_window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5,
                               rtol=3e-5)


def test_flash_attention_block_size_invariance():
    """The same inputs through different BlockSpec tilings agree bitwise-ish."""
    B, S, H, hd = 1, 256, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    a = ops.flash_attention(q, k, v, block_q=128, block_kv=128)
    b = ops.flash_attention(q, k, v, block_q=64, block_kv=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-6, rtol=2e-6)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,d", [(8, 128), (64, 256), (33, 512), (256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(rows, d, dtype):
    x = jax.random.normal(KEY, (rows, d), dtype)
    w = 1.0 + 0.2 * jax.random.normal(KEY, (d,), jnp.float32)
    out = ops.rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_rmsnorm_leading_dims():
    x = jax.random.normal(KEY, (2, 3, 5, 128), jnp.float32)
    w = jnp.ones((128,))
    np.testing.assert_allclose(
        np.asarray(ops.rmsnorm(x, w)), np.asarray(ref.rmsnorm_ref(x, w)),
        atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# fused reparam + STL
# ---------------------------------------------------------------------------

def _check_reparam_stl(n):
    """Kernel == oracle for any latent dimension (incl. pad path)."""
    ks = jax.random.split(jax.random.fold_in(KEY, n), 3)
    mu = jax.random.normal(ks[0], (n,))
    ls = -1.0 + 0.3 * jax.random.normal(ks[1], (n,))
    eps = jax.random.normal(ks[2], (n,))
    z, lq = ops.reparam_stl(mu, ls, eps)
    z_ref, lq_ref = ref.reparam_stl_ref(mu, ls, eps)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), atol=1e-5,
                               rtol=1e-5)
    assert abs(float(lq) - float(lq_ref.sum())) < 1e-2 + 1e-6 * n


if HAVE_HYPOTHESIS:
    @given(n=st.integers(1, 20000))
    @settings(max_examples=20, deadline=None)
    def test_reparam_stl_property(n):
        _check_reparam_stl(n)
else:
    # Seeded fallback: boundary dims (block edges at 4096) + a fixed
    # pseudo-random sample of the same domain hypothesis would draw from.
    _FALLBACK_NS = sorted({1, 2, 3, 4095, 4096, 4097, 20000}.union(
        int(n) for n in np.random.default_rng(20240601).integers(1, 20000, 13)))

    @pytest.mark.parametrize("n", _FALLBACK_NS)
    def test_reparam_stl_property(n):
        _check_reparam_stl(n)


def test_reparam_stl_grad_is_stl():
    """The fused kernel's logq must carry NO gradient to (mu, log_sigma)
    through the density (the STL estimator's defining property) — eps is
    the only input the logq term reads."""
    n = 64
    mu = jnp.zeros((n,))
    ls = jnp.zeros((n,))
    eps = jax.random.normal(KEY, (n,))

    def logq_of_eta(mu, ls):
        _, lq = ops.reparam_stl(mu, ls, eps)
        return lq

    g_mu, g_ls = jax.grad(logq_of_eta, argnums=(0, 1))(mu, ls)
    # d logq / d mu == 0 exactly; d logq / d log_sigma == -1 (entropy term
    # from the -log_sigma), NOT the pathwise term.
    np.testing.assert_allclose(np.asarray(g_mu), 0.0, atol=1e-7)
    np.testing.assert_allclose(np.asarray(g_ls), -1.0, atol=1e-6)


@pytest.mark.parametrize("arch", ["qwen3-4b", "zamba2-7b", "xlstm-1.3b"])
def test_pallas_model_path_matches_jnp(arch):
    """cfg.use_pallas routes attention/GLA through the Pallas kernels
    (interpret mode on CPU); logits must match the jnp path."""
    import dataclasses

    from repro.configs import get_config
    from repro.models.backbone import transformer as T

    cfg0 = get_config(arch).reduced()
    cfg1 = dataclasses.replace(cfg0, use_pallas=True)
    p = T.init_params(KEY, cfg0)
    batch = {"tokens": jax.random.randint(KEY, (2, 24), 0, cfg0.vocab_size)}
    l0, _, _ = T.forward(p, cfg0, batch, remat=False)
    l1, _, _ = T.forward(p, cfg1, batch, remat=False)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=5e-4,
                               rtol=1e-3)
