"""Tests for the pluggable ServerStrategy layer (repro.federated.strategy).

Three anchors:

  * **Bit-exactness of the refactor** — the registry-built SFVI /
    SFVI-Avg strategies reproduce the pre-refactor ``Server`` round maps
    EXACTLY (elbo history, θ, η_G, η_L), on every wire layout, with and
    without DP + int8, synchronously and through the buffered-async
    engine. The oracle is ``tests/_legacy_server.py`` — a frozen
    verbatim snapshot of the pre-refactor runtime.
  * **PVI / federated-EP correctness** — damping=0 is an exact fixed
    point, and on a conjugate global-only Gaussian problem both
    strategies recover the analytic posterior (the η_G init is the
    implicit prior factor of the site decomposition).
  * **Registry / spec plumbing** — names, kwargs validation, scenario
    validation on deserialization, scheduler invitation rounding, and
    strategy state checkpoint/resume.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _legacy_server import LegacyServer
from repro.core import (
    ConditionalGaussian,
    DiagGaussian,
    SFVIProblem,
    StructuredModel,
)
from repro.federated import (
    Int8Compressor,
    PrivacyPolicy,
    RoundScheduler,
    Scenario,
    Server,
    ServerStrategy,
    StrategySpec,
    get_strategy,
    register_strategy,
    resolve_strategy,
    run_buffered,
    strategy_names,
)
from repro.federated.scheduler import AsyncConfig
from repro.federated.strategy import SFVIStrategy
from repro.optim.adam import adam

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _hier_problem(dG=3, dL=2):
    def log_prior_global(theta, zg):
        return -0.5 * jnp.sum((zg - theta["m"]) ** 2)

    def log_local(theta, zg, zl, data):
        lp = -0.5 * jnp.sum((zl - jnp.mean(zg)) ** 2)
        ll = -0.5 * jnp.sum((data["y"] - zl[None, :]) ** 2) * jnp.exp(theta["lt"])
        return lp + ll

    model = StructuredModel(
        global_dim=dG, local_dim=dL,
        log_prior_global=log_prior_global, log_local=log_local,
    )
    return SFVIProblem(model, DiagGaussian(dG), ConditionalGaussian(dL, dG))


def _datas(key, J, n=6, d=2):
    return [
        {"y": jax.random.normal(jax.random.fold_in(key, j), (n, d))}
        for j in range(J)
    ]


def _init(prob):
    theta = {"m": jnp.asarray(0.3), "lt": jnp.asarray(-0.5)}
    eta_G = prob.global_family.init(jax.random.PRNGKey(1), mu_scale=0.5)
    return theta, eta_G


def _flat(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((0,))
    return jnp.concatenate([jnp.ravel(x) for x in leaves])


def _assert_same_state(a, b, keys=("theta", "eta_G", "eta_L")):
    for k in keys:
        np.testing.assert_array_equal(
            np.asarray(_flat(a.state[k])), np.asarray(_flat(b.state[k])), err_msg=k)


# ---------------------------------------------------------------------------
# Registry / spec plumbing
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_builtin_names(self):
        assert set(strategy_names()) >= {"sfvi", "sfvi_avg", "pvi", "fed_ep"}
        assert strategy_names() == tuple(sorted(strategy_names()))

    def test_unknown_name_lists_registry(self):
        with pytest.raises(KeyError, match="sfvi"):
            get_strategy("nope")

    def test_duplicate_registration_raises(self):
        with pytest.raises(ValueError, match="already registered"):
            register_strategy("sfvi")(SFVIStrategy)

    def test_resolve_passthrough_and_name(self):
        inst = get_strategy("pvi")(damping=0.5)
        assert resolve_strategy(inst) is inst
        assert resolve_strategy("sfvi").name == "sfvi"
        # repro-lint: allow[R6] — protocol-membership test for resolve_strategy itself
        assert isinstance(resolve_strategy(StrategySpec("fed_ep")),
                          ServerStrategy)

    def test_spec_kwargs_validated(self):
        assert StrategySpec("pvi", {"damping": 0.1}).build().damping == 0.1
        with pytest.raises(ValueError, match="unknown kwargs"):
            StrategySpec("pvi", {"bogus": 1}).build()
        with pytest.raises(ValueError, match="unknown kwargs"):
            # sfvi is stateless: ANY kwarg is unknown.
            StrategySpec("sfvi", {"damping": 0.1}).build()

    def test_spec_round_trip(self):
        spec = StrategySpec("pvi", {"damping": 0.3})
        assert StrategySpec.from_dict(
            {"name": "pvi", "kwargs": {"damping": 0.3}}) == spec

    def test_cadences(self):
        assert get_strategy("sfvi").cadence == "step"
        for name in ("sfvi_avg", "pvi", "fed_ep"):
            assert get_strategy(name).cadence == "round"
        for name in ("pvi", "fed_ep"):
            assert get_strategy(name).has_silo_state

    def test_runtime_has_no_algorithm_name_branches(self):
        """The refactor's contract: the round bodies are generic — no
        algorithm-name literals survive in the runtime module."""
        with open(os.path.join(
                REPO, "src", "repro", "federated", "runtime.py")) as f:
            src = f.read()
        assert '"sfvi"' not in src and "'sfvi'" not in src
        assert "sfvi_avg" not in src


# ---------------------------------------------------------------------------
# Bit-exactness vs the frozen pre-refactor oracle
# ---------------------------------------------------------------------------


class TestLegacyEquivalence:
    @pytest.mark.parametrize("wire", ["flat", "fused", "legacy"])
    def test_bit_exact_round_trajectories(self, wire):
        """Registry SFVI / SFVI-Avg == the pre-refactor Server, bit for
        bit over 3 rounds: elbo history and full (θ, η_G, η_L) state —
        plain AND under DP clip+noise with int8 wire compression."""
        prob = _hier_problem()
        datas = _datas(jax.random.PRNGKey(2), 4)
        theta, eta_G = _init(prob)
        for algo, K in (("sfvi", 2), ("sfvi_avg", 3)):
            for extra in ({}, {"compressor": Int8Compressor(),
                               "privacy": PrivacyPolicy(
                                   clip_norm=1.0, noise_multiplier=0.4)}):
                kw = dict(server_opt=adam(1e-2), local_opt=adam(1e-2),
                          seed=7, wire=wire, **extra)
                new = Server(prob, datas, theta, eta_G, strategy=algo, **kw)
                old = LegacyServer(prob, datas, theta, eta_G, **kw)
                h_new = new.run(3, local_steps=K)
                h_old = old.run(3, algorithm=algo, local_steps=K)
                np.testing.assert_array_equal(
                    np.asarray(h_new["elbo"]), np.asarray(h_old["elbo"]))
                _assert_same_state(new, old)

    def test_bit_exact_under_partial_participation(self):
        prob = _hier_problem()
        datas = _datas(jax.random.PRNGKey(2), 5)
        theta, eta_G = _init(prob)
        sched = RoundScheduler(num_silos=5, participation=0.6, dropout=0.2,
                               seed=3)
        kw = dict(server_opt=adam(1e-2), local_opt=adam(1e-2), seed=7)
        new = Server(prob, datas, theta, eta_G, strategy="sfvi_avg", **kw)
        old = LegacyServer(prob, datas, theta, eta_G, **kw)
        h_new = new.run(4, local_steps=2, scheduler=sched)
        h_old = old.run(4, algorithm="sfvi_avg", local_steps=2,
                        scheduler=RoundScheduler(num_silos=5,
                                                 participation=0.6,
                                                 dropout=0.2, seed=3))
        np.testing.assert_array_equal(
            np.asarray(h_new["elbo"]), np.asarray(h_old["elbo"]))
        _assert_same_state(new, old)

    def test_bit_exact_through_async_engine(self):
        """run_buffered drives the registry Server and the frozen oracle
        to identical trajectories (DP + int8, lognormal latencies)."""

        class _AsyncLegacy(LegacyServer):
            # run_buffered resolves the strategy through the server; the
            # oracle predates that API, so adapt it: the engine only
            # needs cadence/name for validation and the round fn itself.
            def _resolve(self, algorithm):
                return get_strategy("sfvi_avg")()

            def _get_round(self, algorithm, local_steps):
                fn = super()._get_round("sfvi_avg", local_steps)
                # The engine's round signature gained n_j (dynamic
                # population growth); the frozen oracle predates it and
                # bakes num_obs into the graph, so drop the argument.
                return lambda state, data, n_j, key, mask, weights: fn(
                    state, data, key, mask, weights)

            def bytes_up_per_silo(self, algorithm=None):
                return super().bytes_up_per_silo("sfvi_avg")

        prob = _hier_problem()
        datas = _datas(jax.random.PRNGKey(2), 4)
        theta, eta_G = _init(prob)
        cfg = AsyncConfig(buffer_size=2, latency="lognormal")
        kw = dict(server_opt=adam(1e-2), local_opt=adam(1e-2), seed=7,
                  compressor=Int8Compressor(),
                  privacy=PrivacyPolicy(clip_norm=1.0, noise_multiplier=0.4))
        new = Server(prob, datas, theta, eta_G, strategy="sfvi_avg", **kw)
        old = _AsyncLegacy(prob, datas, theta, eta_G, **kw)
        h_new, _ = run_buffered(new, 4, cfg, local_steps=2)
        h_old, _ = run_buffered(old, 4, cfg, local_steps=2)
        np.testing.assert_array_equal(
            np.asarray(h_new["elbo"]), np.asarray(h_old["elbo"]))
        assert h_new["bytes_up"] == h_old["bytes_up"]
        _assert_same_state(new, old)

    def test_async_rejects_step_cadence(self):
        prob = _hier_problem()
        datas = _datas(jax.random.PRNGKey(2), 3)
        theta, eta_G = _init(prob)
        srv = Server(prob, datas, theta, eta_G, server_opt=adam(1e-2),
                     local_opt=adam(1e-2), strategy="sfvi")
        with pytest.raises(ValueError, match="round-cadence"):
            run_buffered(srv, 1, AsyncConfig(buffer_size=2))


# ---------------------------------------------------------------------------
# PVI / federated-EP correctness
# ---------------------------------------------------------------------------


def _conjugate_setup(J=4, n=20, prior_sd=5.0, mu_true=1.5, seed=0):
    """Global-only Gaussian: y_jk ~ N(z_G, 1), flat log-prior — the
    implicit PVI prior factor is the η_G INIT (N(0, prior_sd²)), so the
    site fixed point has a closed form."""
    model = StructuredModel(
        global_dim=1, local_dim=0,
        log_prior_global=lambda th, zg: jnp.zeros(()),
        log_local=lambda th, zg, zl, d: -0.5 * jnp.sum((d["y"] - zg[0]) ** 2),
    )
    prob = SFVIProblem(model, DiagGaussian(1))
    rng = np.random.default_rng(seed)
    datas = [{"y": jnp.asarray(rng.normal(mu_true, 1.0, n), jnp.float32)}
             for _ in range(J)]
    eta0 = {"mu": jnp.zeros((1,)),
            "log_sigma": jnp.full((1,), np.log(prior_sd), jnp.float32)}
    ybar = float(np.mean([np.asarray(d["y"]).mean() for d in datas]))
    post_prec = prior_sd ** -2 + J * n
    post_mu = J * n * ybar / post_prec
    return prob, datas, eta0, post_mu, post_prec ** -0.5


class TestNaturalDeltaStrategies:
    def test_damping_zero_is_a_fixed_point(self):
        """ρ=0: θ and the sites λ_j do not move at all (bit-exact); η_G
        only round-trips through natural parameters (allclose)."""
        prob = _hier_problem()
        datas = _datas(jax.random.PRNGKey(2), 3)
        theta, eta_G = _init(prob)
        srv = Server(prob, datas, theta, eta_G, server_opt=adam(1e-2),
                     local_opt=adam(1e-2), seed=0,
                     strategy=get_strategy("pvi")(damping=0.0))
        srv.run(2, local_steps=3)
        np.testing.assert_array_equal(
            np.asarray(_flat(srv.state["theta"])), np.asarray(_flat(theta)))
        np.testing.assert_allclose(
            np.asarray(_flat(srv.state["eta_G"])), np.asarray(_flat(eta_G)),
            rtol=1e-5, atol=1e-6)
        lam = np.asarray(_flat(srv.state["strategy"]))
        np.testing.assert_array_equal(lam, np.zeros_like(lam))

    @pytest.mark.parametrize("algo", ["pvi", "fed_ep"])
    def test_recovers_conjugate_posterior(self, algo):
        """Both site strategies converge to the analytic posterior of
        the conjugate global-only Gaussian (paper's correctness anchor
        for the site decomposition: q_G → prior × Π_j lik_j)."""
        prob, datas, eta0, post_mu, post_sd = _conjugate_setup()
        srv = Server(prob, datas, {}, eta0, server_opt=adam(5e-2), seed=0,
                     strategy=algo)
        srv.run(60, local_steps=10)
        eg = srv.state["eta_G"]
        assert abs(float(eg["mu"][0]) - post_mu) < 0.05
        sd = float(np.exp(np.asarray(eg["log_sigma"])[0]))
        assert abs(sd / post_sd - 1.0) < 0.25

    def test_sites_sum_to_posterior_minus_prior(self):
        """The site decomposition invariant: Σ_j λ_j == nat(q_G) −
        nat(q_init), maintained exactly by the damped updates (full
        participation, no DP/compression)."""
        from repro.federated.strategy import natural_from_eta

        prob, datas, eta0, _, _ = _conjugate_setup()
        srv = Server(prob, datas, {}, eta0, server_opt=adam(5e-2), seed=0,
                     strategy="pvi")
        srv.run(5, local_steps=4)
        fam = prob.global_family
        nat0 = natural_from_eta(fam, eta0)
        natG = natural_from_eta(fam, srv.state["eta_G"])
        lam = srv.state["strategy"]["lam"]
        for k in ("h", "prec"):
            lam_sum = np.asarray(lam[k])[:srv.J].sum(axis=0)
            np.testing.assert_allclose(
                lam_sum, np.asarray(natG[k]) - np.asarray(nat0[k]),
                rtol=2e-3, atol=2e-3)

    def test_pvi_and_fed_ep_trajectories_differ(self):
        """Same fixed points, different finite-K paths: posterior-init
        (PVI) vs cavity-init (EP) local VI diverge once sites are
        non-zero."""
        prob, datas, eta0, _, _ = _conjugate_setup()
        out = {}
        for algo in ("pvi", "fed_ep"):
            srv = Server(prob, datas, {}, eta0, server_opt=adam(5e-2),
                         seed=0, strategy=algo)
            srv.run(3, local_steps=4)
            out[algo] = np.asarray(_flat(srv.state["eta_G"]))
        assert not np.array_equal(out["pvi"], out["fed_ep"])

    def test_requires_diag_moment_form(self):
        from repro.core.family import FamilySpec, build_family

        prob = _hier_problem()
        prob = prob.__class__(
            prob.model, build_family(FamilySpec("cholesky"), dim=3),
            prob.local_family)
        datas = _datas(jax.random.PRNGKey(2), 3)
        theta = {"m": jnp.asarray(0.3), "lt": jnp.asarray(-0.5)}
        eta_G = prob.global_family.init(jax.random.PRNGKey(1))
        with pytest.raises(ValueError, match="diag"):
            Server(prob, datas, theta, eta_G, server_opt=adam(1e-2),
                   local_opt=adam(1e-2), strategy="pvi")

    def test_run_time_strategy_switch_fills_state(self):
        """run(algorithm='pvi') on a Server built for SFVI-Avg lazily
        creates the per-silo site state."""
        prob, datas, eta0, _, _ = _conjugate_setup()
        srv = Server(prob, datas, {}, eta0, server_opt=adam(5e-2), seed=0,
                     strategy="sfvi_avg")
        assert not jax.tree_util.tree_leaves(srv.state["strategy"])
        srv.run(2, algorithm="pvi", local_steps=2)
        assert jax.tree_util.tree_leaves(srv.state["strategy"])


# ---------------------------------------------------------------------------
# Scheduler bugfixes (invitation rounding, from_dict validation)
# ---------------------------------------------------------------------------


class TestSchedulerFixes:
    @staticmethod
    def _n_invited(J, participation):
        sched = RoundScheduler(num_silos=J, participation=participation)
        counts = {int(np.asarray(sched.invited(r)).sum()) for r in range(6)}
        assert len(counts) == 1  # the invitation count is schedule-constant
        return counts.pop()

    def test_invited_rounds_half_up_on_odd_ties(self):
        """participation·J = 2.5 must invite 3 silos, not banker's-round
        down to 2: int(round(2.5)) == 2 under round-half-to-even."""
        assert self._n_invited(5, 0.5) == 3
        assert self._n_invited(7, 0.5) == 4

    def test_invited_even_j_unchanged(self):
        assert self._n_invited(8, 0.5) == 4
        assert self._n_invited(4, 0.25) == 1
        assert self._n_invited(8, 1.0) == 8

    def test_from_dict_validates(self):
        """Deserialized scenarios run the same validation as constructed
        ones — a bad spec fails at load, not deep inside build()."""
        with pytest.raises(ValueError, match="round-cadence"):
            Scenario.from_dict(
                {"algorithm": "sfvi", "async_cfg": {"buffer_size": 2}})
        with pytest.raises(ValueError, match="registered strategies"):
            Scenario.from_dict({"algorithm": "sfvi_average"})
        # A valid dict still round-trips.
        sc = Scenario.from_dict({"algorithm": "pvi", "compression": "int8"})
        assert sc.algorithm == "pvi"

    def test_scenario_matrix_covers_round_cadence_async(self):
        from repro.federated.scheduler import scenario_matrix

        grid = scenario_matrix(
            algorithms=("sfvi", "pvi"),
            participation=(1.0,), dropout=(0.0,), compression=("none",),
            dp_noise=(0.0,),
            async_cfgs=(None, AsyncConfig(buffer_size=2)),
        )
        by_algo = {}
        for sc in grid:
            by_algo.setdefault(sc.algorithm, []).append(sc.async_cfg)
        # Full-participation async SFVI rows are dropped; PVI keeps both.
        assert all(c is None for c in by_algo["sfvi"])
        assert any(c is not None for c in by_algo["pvi"])


# ---------------------------------------------------------------------------
# Strategy state checkpoint/resume (PVI sites ride the per-silo shards)
# ---------------------------------------------------------------------------


class TestStrategyCheckpoint:
    def _spec(self, **scenario_kw):
        from repro.federated.api import ExperimentSpec, ModelSpec, OptimizerSpec

        return ExperimentSpec(
            model=ModelSpec("toy"),
            scenario=Scenario(algorithm="pvi", **scenario_kw),
            strategy=StrategySpec("pvi", {"damping": 0.3}),
            num_silos=3, rounds=6, local_steps=2, seed=3,
            server_opt=OptimizerSpec("adam", 2e-2))

    def test_spec_round_trips_strategy(self):
        from repro.federated.api import ExperimentSpec

        spec = self._spec()
        assert ExperimentSpec.from_dict(spec.to_dict()) == spec
        assert ExperimentSpec.from_json(spec.to_json()) == spec

    def test_strategy_name_mismatch_raises(self):
        import dataclasses

        from repro.federated.api import build

        spec = dataclasses.replace(
            self._spec(), scenario=Scenario(algorithm="sfvi_avg"))
        with pytest.raises(ValueError, match="must agree"):
            build(spec)

    @pytest.mark.parametrize("scenario_kw", [
        {"compression": "int8", "dp_noise": 0.4, "dp_clip": 1.0},
        {"async_cfg": AsyncConfig(buffer_size=2, latency="lognormal")},
    ])
    def test_resume_is_bit_exact(self, tmp_path, scenario_kw):
        """save → resume of a PVI run (DP+int8, and buffered-async)
        replays the uninterrupted trajectory bit-exactly, INCLUDING the
        per-silo site state λ_j on the silo shards."""
        from repro.federated.api import Experiment, build

        spec = self._spec(**scenario_kw)
        full = build(spec)
        full.run(3)
        full.save(str(tmp_path))
        # PVI on the toy model: silo shards exist and carry λ even
        # though η_L does too — and the files are per-silo.
        assert (tmp_path / "step_00000003.silo_0002.msgpack").exists()
        full.run(3)

        resumed = Experiment.resume(str(tmp_path))
        assert resumed.round == 3
        resumed.run(3)
        np.testing.assert_array_equal(
            np.asarray(full.history["elbo"][3:]),
            np.asarray(resumed.history["elbo"]))
        for k in ("theta", "eta_G", "eta_L", "strategy"):
            np.testing.assert_array_equal(
                np.asarray(_flat(full.server.state[k])),
                np.asarray(_flat(resumed.server.state[k])), err_msg=k)


# ---------------------------------------------------------------------------
# Host meter == compiled collective (flat + int8, real 4-device mesh)
# ---------------------------------------------------------------------------

_METER_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")
    import jax, jax.numpy as jnp
    from repro.core import (ConditionalGaussian, DiagGaussian, SFVIProblem,
                            StructuredModel)
    from repro.federated import Int8Compressor, Server
    from repro.launch.roofline import collective_bytes
    from repro.optim.adam import adam

    model = StructuredModel(
        global_dim=3, local_dim=2,
        log_prior_global=lambda th, zg: -0.5 * jnp.sum((zg - th["m"]) ** 2),
        log_local=lambda th, zg, zl, d: (
            -0.5 * jnp.sum((zl - jnp.mean(zg)) ** 2)
            - 0.5 * jnp.sum((d["y"] - zl[None, :]) ** 2)),
    )
    prob = SFVIProblem(model, DiagGaussian(3),
                       ConditionalGaussian(2, 3, use_coupling=False))
    J = 4
    datas = [{"y": jax.random.normal(
        jax.random.fold_in(jax.random.PRNGKey(2), j), (4, 2))}
        for j in range(J)]
    for algo in ("sfvi", "sfvi_avg"):
        srv = Server(prob, datas, {"m": jnp.asarray(0.1)},
                     prob.global_family.init(jax.random.PRNGKey(1)),
                     server_opt=adam(1e-2), local_opt=adam(1e-2),
                     compressor=Int8Compressor(), wire="flat", seed=0,
                     strategy=algo)
        # The ship template has several leaves; the flat wire must bill
        # ONE int8 row + ONE f32 scale per silo, matching the gathered
        # HLO result bytes exactly (gather result = J x per-silo bytes).
        n_leaves = len(jax.tree_util.tree_leaves(srv.ship_template()))
        assert n_leaves > 1, n_leaves
        hlo = srv._lower(None, 1).compile().as_text()
        gathered = collective_bytes(hlo)["all-gather"]
        host = srv.bytes_up_per_silo()
        assert gathered == J * host, (algo, gathered, J, host)
        print(algo, "OK", int(gathered), J * host)
""")


@pytest.mark.slow
def test_host_meter_matches_compiled_collective_bytes():
    """Satellite regression: ``bytes_up_per_silo`` (host meter) must
    equal the compiled all-gather's per-silo result bytes on the flat
    int8 wire. The pre-fix meter billed one 4-byte scale PER LEAF while
    the wire ships ONE (P,) int8 row + ONE f32 scale per silo."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _METER_SCRIPT],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1200,
    )
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert out.stdout.count("OK") == 2, out.stdout
