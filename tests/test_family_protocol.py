"""Property tests for the first-class variational-family API.

Mirrors the two-tier structure of ``test_aggregation_properties.py``:
hypothesis explores the space adversarially where installed, seeded
numpy sweeps keep the same invariants covered offline.

Invariants, for EVERY registered family (LowRankGaussian included):
  * ``unpack(pack(params)) == params`` bit for bit, and the packed
    vector has exactly ``num_params`` float32 entries;
  * ``log_prob`` matches an independent scipy multivariate-normal
    golden evaluation of the family's (mean, covariance);
  * ``entropy == -E[log q]`` (Monte-Carlo, sampled through ``sample``);
  * ``from_moments(to_moments(p)) ≈ p`` wherever the moment bridge
    exists (parameter space where the map is injective, moment space for
    LowRankGaussian whose factor U is only determined up to rotation);
  * the registry resolves every name, ``FamilySpec`` builds against it,
    and capability flags replace the old isinstance/hasattr probes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.families import (
    BatchedDiagGaussian,
    CholeskyGaussian,
    ConditionalGaussian,
    DiagGaussian,
    LowRankGaussian,
)
from repro.core.family import (
    FAMILIES,
    FamilySpec,
    VariationalFamily,
    build_family,
    eps_shape,
    family_names,
    get_family,
    is_conditional,
    supports_moments,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

try:
    from scipy import stats as scipy_stats

    HAVE_SCIPY = True
except ImportError:
    HAVE_SCIPY = False


# One representative instance per registered unconditional family.
UNCONDITIONAL = [
    DiagGaussian(4),
    CholeskyGaussian(4),
    LowRankGaussian(4, rank=2),
    BatchedDiagGaussian(batch=3, dim=2),
]
ALL_FAMILIES = UNCONDITIONAL + [ConditionalGaussian(3, 2, use_chol=True)]

_IDS = lambda f: type(f).__name__  # noqa: E731


def _rand_params(fam, seed, scale=0.6):
    """A generic, well-conditioned random parameter point for ``fam``."""
    key = jax.random.PRNGKey(seed)
    params = fam.init(key, mu_scale=1.0, log_sigma_init=-0.4)
    out = {}
    for i, (name, leaf) in enumerate(sorted(params.items())):
        sub = jax.random.fold_in(key, 101 + i)
        out[name] = leaf + scale * jax.random.normal(sub, leaf.shape)
    return out


def _dense_cov(fam, params):
    """(mean, covariance) as dense arrays, family-agnostic."""
    # repro-lint: allow[R6] — oracle helper: densifies the covariance of the two full-covariance families under test
    if isinstance(fam, (CholeskyGaussian, LowRankGaussian)):
        return params["mu"], fam.covariance(params)
    mu, sigma = fam.to_moments(params)
    return mu.reshape(-1), jnp.diag(sigma.reshape(-1) ** 2)


class TestPackUnpack:
    @pytest.mark.parametrize("fam", ALL_FAMILIES, ids=_IDS)
    def test_seeded_round_trip(self, fam):
        for seed in range(10):
            params = _rand_params(fam, seed)
            vec = fam.pack(params)
            assert vec.shape == (fam.num_params,)
            assert vec.dtype == jnp.float32
            back = fam.unpack(vec)
            assert set(back) == set(params)
            for k in params:
                np.testing.assert_array_equal(
                    np.asarray(params[k], np.float32), np.asarray(back[k]))

    @pytest.mark.parametrize("fam", ALL_FAMILIES, ids=_IDS)
    def test_pack_is_jittable(self, fam):
        params = _rand_params(fam, 0)
        vec = jax.jit(fam.pack)(params)
        back = jax.jit(fam.unpack)(vec)
        for k in params:
            np.testing.assert_allclose(params[k], back[k], rtol=1e-6)

    if HAVE_HYPOTHESIS:

        @settings(max_examples=25, deadline=None)
        @given(st.integers(0, 2**31 - 1),
               st.sampled_from(range(len(ALL_FAMILIES))))
        def test_hypothesis(self, seed, fam_i):
            fam = ALL_FAMILIES[fam_i]
            params = _rand_params(fam, seed)
            back = fam.unpack(fam.pack(params))
            for k in params:
                np.testing.assert_array_equal(
                    np.asarray(params[k], np.float32), np.asarray(back[k]))


@pytest.mark.skipif(not HAVE_SCIPY, reason="scipy not installed")
class TestLogProbVsScipy:
    @pytest.mark.parametrize(
        "fam", [DiagGaussian(3), CholeskyGaussian(3), LowRankGaussian(3, 2)],
        ids=_IDS)
    def test_matches_scipy_mvn(self, fam):
        for seed in range(5):
            params = _rand_params(fam, seed)
            mu, cov = _dense_cov(fam, params)
            z = np.asarray(
                fam.sample(params, jax.random.normal(
                    jax.random.PRNGKey(seed + 77), eps_shape(fam))))
            ref = scipy_stats.multivariate_normal.logpdf(
                z, mean=np.asarray(mu), cov=np.asarray(cov))
            got = float(fam.log_prob(params, jnp.asarray(z)))
            np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)

    def test_batched_matches_scipy_per_row(self):
        fam = BatchedDiagGaussian(batch=3, dim=2)
        params = _rand_params(fam, 1)
        z = fam.sample(params, jax.random.normal(
            jax.random.PRNGKey(5), eps_shape(fam)))
        ref = sum(
            scipy_stats.multivariate_normal.logpdf(
                np.asarray(z[i]),
                mean=np.asarray(params["mu"][i]),
                cov=np.diag(np.exp(2 * np.asarray(params["log_sigma"][i]))))
            for i in range(3))
        np.testing.assert_allclose(float(fam.log_prob(params, z)), ref,
                                   rtol=1e-5)


class TestEntropy:
    @pytest.mark.parametrize("fam", UNCONDITIONAL, ids=_IDS)
    def test_entropy_is_expected_neg_log_prob(self, fam):
        """H[q] == −E_q[log q], checked by Monte-Carlo through sample."""
        params = _rand_params(fam, 3, scale=0.3)
        eps = jax.random.normal(
            jax.random.PRNGKey(11), (120_000,) + eps_shape(fam))
        lps = jax.vmap(
            lambda e: fam.log_prob(params, fam.sample(params, e)))(eps)
        mc = -float(jnp.mean(lps))
        se = float(jnp.std(lps)) / np.sqrt(lps.shape[0])
        ent = float(fam.entropy(params))
        assert abs(mc - ent) < max(4.0 * se, 2e-3 * abs(ent)), (mc, ent, se)

    def test_conditional_entropy_matches_mc(self):
        fam = ConditionalGaussian(3, 2, use_coupling=True, use_chol=True)
        params = _rand_params(fam, 4, scale=0.3)
        z_G, mu_G = jnp.array([0.4, -0.2]), jnp.zeros(2)
        eps = jax.random.normal(jax.random.PRNGKey(12), (120_000, 3))
        lps = jax.vmap(lambda e: fam.log_prob(
            params, fam.sample(params, z_G, mu_G, e), z_G, mu_G))(eps)
        np.testing.assert_allclose(-float(jnp.mean(lps)),
                                   float(fam.entropy(params)), rtol=1e-2)


class TestMomentBridge:
    @pytest.mark.parametrize(
        "fam", [DiagGaussian(4), CholeskyGaussian(4),
                BatchedDiagGaussian(batch=3, dim=2)], ids=_IDS)
    def test_param_space_round_trip(self, fam):
        """from_moments(to_moments(p)) ≈ p where the map is injective."""
        for seed in range(5):
            params = _rand_params(fam, seed)
            back = fam.from_moments(*fam.to_moments(params))
            for k in params:
                np.testing.assert_allclose(params[k], back[k],
                                           rtol=1e-4, atol=1e-5)

    def test_lowrank_moment_space_round_trip(self):
        """U is only identified up to right-rotation, so LowRankGaussian
        round-trips in MOMENT space: Σ(from_moments(Σ)) ≈ Σ. The
        alternating projection is linear-rate (from_moments docstring),
        hence the looser tolerance than the exact diag/cholesky maps."""
        fam = LowRankGaussian(5, rank=2)
        for seed in range(5):
            params = _rand_params(fam, seed)
            mu, cov = fam.to_moments(params)
            back = fam.from_moments(mu, cov)
            mu2, cov2 = fam.to_moments(back)
            np.testing.assert_allclose(mu, mu2, rtol=1e-6)
            np.testing.assert_allclose(cov, cov2, rtol=2e-2, atol=5e-3)

    def test_no_moments_raises(self):
        fam = ConditionalGaussian(2, 2)
        assert not supports_moments(fam)
        with pytest.raises(NotImplementedError, match="no Gaussian moments"):
            fam.to_moments(fam.init(jax.random.PRNGKey(0)))

    if HAVE_HYPOTHESIS:

        @settings(max_examples=20, deadline=None)
        @given(st.integers(0, 2**31 - 1), st.integers(1, 5))
        def test_hypothesis_cholesky_round_trip(self, seed, dim):
            fam = CholeskyGaussian(dim)
            params = _rand_params(fam, seed)
            back = fam.from_moments(*fam.to_moments(params))
            for k in params:
                np.testing.assert_allclose(params[k], back[k],
                                           rtol=1e-3, atol=1e-4)


class TestProtocolFlags:
    def test_capability_flags(self):
        assert not is_conditional(DiagGaussian(2))
        assert is_conditional(ConditionalGaussian(2, 2))
        assert supports_moments(CholeskyGaussian(2))
        assert supports_moments(LowRankGaussian(3, 1))
        assert DiagGaussian(2).moment_form == "diag"
        assert LowRankGaussian(3, 1).moment_form == "full"

    def test_eps_shapes(self):
        assert eps_shape(DiagGaussian(5)) == (5,)
        assert eps_shape(BatchedDiagGaussian(batch=3, dim=2)) == (3, 2)
        assert eps_shape(LowRankGaussian(4, rank=2)) == (6,)  # dim + rank

    def test_eps_shape_legacy_duck_type_fallback(self):
        class Legacy:
            batch, dim = 4, 3

        assert eps_shape(Legacy()) == (4, 3)
        assert not is_conditional(Legacy())

    def test_batch_shape(self):
        assert DiagGaussian(2).batch_shape == ()
        assert BatchedDiagGaussian(batch=7, dim=2).batch_shape == (7,)

    def test_sample_consumes_declared_eps_shape(self):
        for fam in UNCONDITIONAL:
            params = fam.init(jax.random.PRNGKey(0))
            z = fam.sample(params, jnp.zeros(eps_shape(fam)))
            assert z.shape == fam.batch_shape + (fam.dim,)


class TestRegistryAndSpec:
    def test_expected_names_registered(self):
        names = family_names()
        for name in ("diag", "cholesky", "lowrank", "conditional",
                     "batched_diag"):
            assert name in names, name
        for name in names:
            assert issubclass(FAMILIES[name], VariationalFamily)

    def test_unknown_name_lists_registered(self):
        with pytest.raises(KeyError, match="registered families"):
            get_family("gumbel")

    def test_family_spec_json_round_trip(self):
        import json

        spec = FamilySpec("lowrank", {"rank": 2})
        d = json.loads(json.dumps(dataclasses.asdict(spec)))
        assert FamilySpec.from_dict(d) == spec

    def test_build_family_fills_model_dims(self):
        fam = build_family(FamilySpec("cholesky"), dim=7)
        # repro-lint: allow[R6] — registry-construction test: asserting WHICH class was built is the point
        assert isinstance(fam, CholeskyGaussian) and fam.dim == 7
        lfam = build_family(FamilySpec("conditional"), dim=3, global_dim=5)
        assert lfam.dim == 3 and lfam.global_dim == 5

    def test_build_family_names_underivable_kwargs(self):
        with pytest.raises(ValueError, match=r"batch.*FamilySpec.kwargs"):
            build_family(FamilySpec("batched_diag"), dim=3)
        fam = build_family(FamilySpec("batched_diag", {"batch": 4}), dim=3)
        assert (fam.batch, fam.dim) == (4, 3)

    def test_build_family_explicit_kwargs_win(self):
        fam = build_family(FamilySpec("lowrank", {"rank": 3, "dim": 9}),
                           dim=4)
        assert (fam.dim, fam.rank) == (9, 3)

    def test_lowrank_rejects_bad_rank(self):
        with pytest.raises(ValueError, match="rank"):
            LowRankGaussian(3, rank=4)
